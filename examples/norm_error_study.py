"""Normalization-error study — reproduces the structure of Fig. 2 and Fig. 5.

Fig. 2: normalization error (|1-sum p|, |1-sigma|) versus approximation level
        for the tunable baselines — showing the paper's trade-off curve.
Fig. 5: distribution of normalization error measured over transformer-scale
        activations, GN vs exact vs unnormalized baselines; the paper reports
        77.1% of Softmax and 100% of LayerNorm errors below 0.2e-6 for GN.

Plus the serving-path extension (PR 9): the same normalization-error lens
pointed at the block-paged GN-softmax read over **int8-quantized KV blocks**
(per-block scales, dequantized per streamed tile) — the error must stay
within the analytic bound, because quantization only perturbs the scores
and the GN guarantee is score-independent: the same approximated numerators
feed the sum, one reciprocal normalizes, masked columns saturate the LUT to
exactly-zero numerators.

Run:  PYTHONPATH=src python examples/norm_error_study.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines
from repro.core.api import get_norm, get_softmax
from repro.core.gn_softmax import SoftmaxLUTConfig, gn_softmax_hwsim
from repro.core.metrics import layernorm_norm_error, softmax_norm_error


def paged_int8_read_norm_error(seed=0, n=3, chunk=4, block_size=4, nb=12,
                               kv_dtype="int8"):
    """Normalization error of the paged serving read (streamed block-tile
    scan, the serving default) with ``kv_dtype`` arenas.

    Crafts a scrambled block layout, quantizes a Gaussian K arena to int8
    with per-block scales, and sets the V arena so it dequantizes to
    *exactly* 1.0 (int8 value 64, scale 1/64 — both powers of two): the
    read's output then equals Σp per query row, so ``|1 - out|`` IS the
    normalization error of the GN softmax over int8-dequantized scores.

    Returns ``(measured_max, analytic_bound, t_max)``.  The bound is the
    float-datapath guarantee: Σp = Z·S with one reciprocal rounding plus one
    f32 rounding per accumulated numerator — ``(t + 1) · 2^-23`` for a
    ``t``-column valid stream.  The LUT-saturation half of the guarantee
    (masked/stale columns contribute exactly-zero numerators) is what keeps
    ``t`` the *valid* count: table entries past the causal prefix never
    enter the sum at all.
    """
    from repro.configs.registry import get_config, reduce_config
    from repro.models import attention as attention_mod

    cfg = reduce_config(get_config("internlm2-1.8b"))
    rng = np.random.default_rng(seed)
    kv, dh = cfg.n_kv_heads, cfg.head_dim
    g = cfg.n_heads // kv
    bs = block_size
    max_bt = nb // n

    kf = rng.standard_normal((nb, bs, kv, dh)).astype(np.float32)
    if kv_dtype == "int8":
        k_amax = np.abs(kf).reshape(nb, -1).max(axis=1)
        k_scale = np.maximum(k_amax, 1e-30) / 127.0
        arena_k = jnp.asarray(
            np.clip(np.round(kf / k_scale[:, None, None, None]), -127, 127),
            jnp.int8)
        arena_v = jnp.full((nb, bs, kv, dh), 64, jnp.int8)
        scales = (jnp.asarray(k_scale, jnp.float32),
                  jnp.full((nb,), 1.0 / 64.0, jnp.float32))
    else:
        arena_k = jnp.asarray(kf)
        arena_v = jnp.ones((nb, bs, kv, dh), jnp.float32)
        scales = None

    qg = jnp.asarray(rng.standard_normal((n, chunk, kv, g, dh)) * 2.0,
                     jnp.float32)
    tables = jnp.asarray(
        rng.permutation(nb).reshape(n, max_bt), jnp.int32)
    positions = jnp.asarray(rng.integers(0, (max_bt - 1) * bs, size=n),
                            jnp.int32)
    rows = positions[:, None] + jnp.arange(chunk)[None, :]
    out = attention_mod._stream_paged_tiles(
        cfg, qg, arena_k, arena_v, tables, rows, scales=scales)
    measured = float(jnp.max(jnp.abs(1.0 - out)))
    t_max = int(rows.max()) + 1
    bound = (t_max + 1) * 2.0**-23
    return measured, bound, t_max

key = jax.random.PRNGKey(42)
# attention-logit-scale inputs: (rows, seq) as seen inside a transformer head
X = jax.random.normal(key, (4096, 256)) * 5.0
H = jax.random.normal(jax.random.fold_in(key, 1), (4096, 1024)) * 6.0 + 2.0


def q(v):  # summary of an error distribution
    v = np.asarray(v, np.float64)
    return (f"mean {v.mean():.3e}  p50 {np.percentile(v, 50):.3e}  "
            f"p99 {np.percentile(v, 99):.3e}  max {v.max():.3e}  "
            f"<2e-7: {100.0 * (v < 2e-7).mean():.1f}%")


print("== Fig. 5 analogue: softmax normalization-error distribution ==")
for name in ("exact", "gn", "gn_hwsim", "softermax", "pseudo", "log_domain"):
    err = softmax_norm_error(get_softmax(name)(X))
    print(f"  {name:<12} {q(err)}")

print("\n== Fig. 5 analogue: layernorm |1-sigma| distribution ==")
for name in ("exact_ln", "gn_ln", "gn_ln_hwsim", "integer_ln", "lut_ln"):
    err = layernorm_norm_error(get_norm(name)(H))
    print(f"  {name:<12} {q(err)}")

print("\n== Fig. 2 analogue: approximation level vs normalization error ==")
print("  (GN-softmax hw-sim, sweeping the fixed-point fractional bits f:")
print("   more bits = finer Delta grid = lower approximation level)")
for f in (0, 1, 2, 3, 4):
    cfg = SoftmaxLUTConfig(frac_bits=f)  # radix fixed at the paper's R=8
    p = gn_softmax_hwsim(X, cfg)
    nerr = softmax_norm_error(p)
    aerr = jnp.abs(p - get_softmax("exact")(X)).max()
    print(f"  f={f} (LUT {8 << f} entries)  max|p-exact| {float(aerr):.3e}   "
          f"|1-sum p| max {float(nerr.max()):.3e}")
print("  -> approximation error falls with bigger LUTs, while the normalization")
print("     error stays pinned near zero: the guarantee is structural (the same")
print("     approximated y feeds numerator and denominator), not a precision effect.")

print("\n== Softermax contrast: its normalization error IS its approximation ==")
for bits in (4, 6, 8, 10):
    p = baselines.softermax(X, frac_bits=bits)
    print(f"  softermax frac_bits={bits:<2}  |1-sum p| max "
          f"{float(softmax_norm_error(p).max()):.3e}")

print("\n== Paged serving read: |1-sum p| over int8 KV blocks vs bound ==")
print("  (streamed block-tile read, per-block dequant; quantization perturbs")
print("   the scores, the GN guarantee holds over whatever scores arrive)")
for kd in ("fp", "int8"):
    measured, bound, t = paged_int8_read_norm_error(kv_dtype=kd)
    print(f"  kv_dtype={kd:<5} t={t:<3} measured {measured:.3e}  "
          f"analytic bound (t+1)*2^-23 = {bound:.3e}  "
          f"{'OK' if measured <= bound else 'VIOLATION'}")
