"""Normalization-error study — reproduces the structure of Fig. 2 and Fig. 5.

Fig. 2: normalization error (|1-sum p|, |1-sigma|) versus approximation level
        for the tunable baselines — showing the paper's trade-off curve.
Fig. 5: distribution of normalization error measured over transformer-scale
        activations, GN vs exact vs unnormalized baselines; the paper reports
        77.1% of Softmax and 100% of LayerNorm errors below 0.2e-6 for GN.

Run:  PYTHONPATH=src python examples/norm_error_study.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines
from repro.core.api import get_norm, get_softmax
from repro.core.gn_softmax import SoftmaxLUTConfig, gn_softmax_hwsim
from repro.core.metrics import layernorm_norm_error, softmax_norm_error

key = jax.random.PRNGKey(42)
# attention-logit-scale inputs: (rows, seq) as seen inside a transformer head
X = jax.random.normal(key, (4096, 256)) * 5.0
H = jax.random.normal(jax.random.fold_in(key, 1), (4096, 1024)) * 6.0 + 2.0


def q(v):  # summary of an error distribution
    v = np.asarray(v, np.float64)
    return (f"mean {v.mean():.3e}  p50 {np.percentile(v, 50):.3e}  "
            f"p99 {np.percentile(v, 99):.3e}  max {v.max():.3e}  "
            f"<2e-7: {100.0 * (v < 2e-7).mean():.1f}%")


print("== Fig. 5 analogue: softmax normalization-error distribution ==")
for name in ("exact", "gn", "gn_hwsim", "softermax", "pseudo", "log_domain"):
    err = softmax_norm_error(get_softmax(name)(X))
    print(f"  {name:<12} {q(err)}")

print("\n== Fig. 5 analogue: layernorm |1-sigma| distribution ==")
for name in ("exact_ln", "gn_ln", "gn_ln_hwsim", "integer_ln", "lut_ln"):
    err = layernorm_norm_error(get_norm(name)(H))
    print(f"  {name:<12} {q(err)}")

print("\n== Fig. 2 analogue: approximation level vs normalization error ==")
print("  (GN-softmax hw-sim, sweeping the fixed-point fractional bits f:")
print("   more bits = finer Delta grid = lower approximation level)")
for f in (0, 1, 2, 3, 4):
    cfg = SoftmaxLUTConfig(frac_bits=f)  # radix fixed at the paper's R=8
    p = gn_softmax_hwsim(X, cfg)
    nerr = softmax_norm_error(p)
    aerr = jnp.abs(p - get_softmax("exact")(X)).max()
    print(f"  f={f} (LUT {8 << f} entries)  max|p-exact| {float(aerr):.3e}   "
          f"|1-sum p| max {float(nerr.max()):.3e}")
print("  -> approximation error falls with bigger LUTs, while the normalization")
print("     error stays pinned near zero: the guarantee is structural (the same")
print("     approximated y feeds numerator and denominator), not a precision effect.")

print("\n== Softermax contrast: its normalization error IS its approximation ==")
for bits in (4, 6, 8, 10):
    p = baselines.softermax(X, frac_bits=bits)
    print(f"  softermax frac_bits={bits:<2}  |1-sum p| max "
          f"{float(softmax_norm_error(p).max()):.3e}")
