"""Quickstart: the paper's technique in 60 lines.

Demonstrates the two Guaranteed-Normalization (GN) non-GEMM blocks from
"Hardware-Efficient Softmax and Layer Normalization with Guaranteed
Normalization for Edge Devices" (Choi, Kim & Kim, CS.AR 2026):

  * GN-Softmax  — two-LUT factorized exponential + fixed-point renormalize,
                  guaranteeing sum(p) = 1
  * GN-LayerNorm — CoRN (LOD + Newton) reciprocal sqrt, guaranteeing sigma = 1

and shows the paper's central claim: approximation methods that look fine by
max-abs error can still carry *normalization error*, which the GN designs
eliminate by construction.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core.api import get_norm, get_softmax
from repro.core.metrics import layernorm_norm_error, softmax_norm_error

key = jax.random.PRNGKey(0)
x = jax.random.normal(key, (8, 128)) * 4.0  # logit-scale inputs


def show(name, p, exact):
    nerr = float(jnp.max(softmax_norm_error(p)))
    aerr = float(jnp.max(jnp.abs(p - exact)))
    print(f"  {name:<12} |1-sum(p)| = {nerr:.3e}   max|p-exact| = {aerr:.3e}")


print("== Softmax: normalization error vs approximation error ==")
exact = get_softmax("exact")(x)
for name in ("exact", "gn", "gn_hwsim", "softermax", "pseudo", "log_domain"):
    show(name, get_softmax(name)(x), exact)

print("\n== LayerNorm: |1 - sigma| of the normalized output ==")
h = jax.random.normal(key, (8, 1024)) * 7.0 + 3.0
for name in ("exact_ln", "gn_ln", "gn_ln_hwsim", "integer_ln", "lut_ln"):
    y = get_norm(name)(h)
    print(f"  {name:<12} max|1-sigma| = {float(jnp.max(layernorm_norm_error(y))):.3e}")

print("\n== GN ops are differentiable (custom JVP: exact Jacobian at the")
print("   approximated output — tangents preserve sum(dp) = 0) ==")
g = jax.grad(lambda z: get_softmax("gn")(z).var())(x[0])
print(f"  grad ok, sum over row (should be ~0 by the guarantee): {float(g.sum()):.2e}")

print("\n== Drop-in inside a model: softmax_impl / norm_impl config axis ==")
from repro.configs.registry import get_config, reduce_config
from repro.models.transformer import make_model

cfg = reduce_config(get_config("internlm2-1.8b"), softmax_impl="gn", norm_impl="gn_rms")
model = make_model(cfg)
params = model.init(jax.random.PRNGKey(1))
tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, cfg.vocab)
logits, _ = jax.jit(model.forward)(params, {"tokens": tokens})
print(f"  {cfg.name}: forward OK, logits {logits.shape}, finite={bool(jnp.isfinite(logits).all())}")
