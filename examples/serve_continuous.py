"""Continuous batching with the GN non-GEMM datapath — a serving timeline.

A synthetic staggered-arrival workload (mixed prompt lengths, mixed decode
budgets) streams through the FCFS scheduler + slot-paged KV pool + jit-once
fused prefill/decode engine: admitted prompts drain chunk-by-chunk through
idle lanes (P marks) while other slots decode (D marks).  The demo prints
the admission/completion timeline so you can watch requests join and leave
the running batch without any recompilation, then cross-checks greedy
outputs against the static engine.

``--devices N`` shards the slot pool over an N-device mesh (slot-axis
NamedSharding, least-loaded admission — see docs/serving.md §Device mesh);
the timeline then splits the slot marks per device (``|`` separators) and
reports per-device occupancy and admission balance.  This is a CPU demo at
reduced config, so the script forces N host-platform devices itself before
jax initializes — no env var needed.

``--shared-prefix`` switches the workload to N users over one common system
prompt + a few persona preambles (see ``shared_prefix_requests``) and turns
the radix prefix cache on: the timeline then annotates each admission with
the blocks it attached from the cache (``hit req3: 18tok/4blk+fork``) and
the epilogue reports hit rate, COW forks and evictions — watch later
arrivals skip straight to decoding their unshared tail.

``--sla`` switches to the bursty two-class workload (``sla_requests``) and
the SLA control plane: priority scheduling with an aging bound plus
block-level preemption (``--preempt spill|recompute``).  Slot marks gain a
class case (upper = interactive, lower = batch) and the timeline annotates
preemptions (``preempt req2@slot1``), resumes and rejections; the epilogue
prints per-class arrival-anchored TTFT on the engine step clock — the
interactive tail the priority policy exists to cut — and still
cross-checks every served request (preempted-and-resumed ones included)
against the static oracle.

Run:  PYTHONPATH=src python examples/serve_continuous.py [--arch internlm2-1.8b]
      PYTHONPATH=src python examples/serve_continuous.py --devices 2
      PYTHONPATH=src python examples/serve_continuous.py --shared-prefix
      PYTHONPATH=src python examples/serve_continuous.py --sla
"""
import argparse
import time

from repro.launch._host_devices import force_host_devices

# must run before jax initializes its backend (reduced-config CPU demo)
force_host_devices()

import jax
import numpy as np

from repro.configs.registry import get_config, list_archs, reduce_config
from repro.models.transformer import make_model
from repro.serve.engine import (
    ContinuousEngine,
    ServeConfig,
    round_slots_to_devices,
    static_reference,
)
from repro.serve.workload import (
    required_max_seq,
    shared_prefix_requests,
    sla_requests,
    staggered_requests,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b", choices=list_archs())
    ap.add_argument("--requests", type=int, default=9)
    ap.add_argument("--num-slots", type=int, default=3)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--devices", type=int, default=1,
                    help="shard the slot pool over N (forced host) devices")
    ap.add_argument("--shared-prefix", action="store_true",
                    help="shared system-prompt workload + radix prefix cache")
    ap.add_argument("--sla", action="store_true",
                    help="bursty two-class workload + priority scheduling "
                         "and block-level preemption")
    ap.add_argument("--preempt", default="spill",
                    choices=["spill", "recompute"],
                    help="preemption mechanism under --sla")
    args = ap.parse_args()
    if args.sla and args.shared_prefix:
        ap.error("--sla and --shared-prefix are separate demos")

    cfg = reduce_config(get_config(args.arch))
    model = make_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if args.shared_prefix:
        reqs = shared_prefix_requests(cfg, n_users=args.requests, n_personas=3,
                                      system_len=24, persona_len=10, user_len=6,
                                      max_new_tokens=args.new_tokens, stagger=4,
                                      seed=3)
    elif args.sla:
        reqs = sla_requests(cfg, n_requests=args.requests, base_len=12,
                            rate=0.4, max_new_interactive=args.new_tokens // 2,
                            max_new_batch=2 * args.new_tokens, seed=3)
    else:
        reqs = staggered_requests(cfg, n_requests=args.requests, base_len=16,
                                  max_new_tokens=args.new_tokens, stagger=2,
                                  seed=3)
    num_slots = round_slots_to_devices(args.num_slots, args.devices)
    engine = ContinuousEngine(model, params, num_slots=num_slots,
                              max_seq=required_max_seq(reqs), cfg=ServeConfig(),
                              devices=args.devices,
                              prefix_cache=args.shared_prefix,
                              sched="priority" if args.sla else "fcfs",
                              preempt=args.preempt if args.sla else "off",
                              aging_steps=24)
    for r in reqs:
        engine.submit(r)

    kind = ("shared-prefix " if args.shared_prefix
            else "sla " if args.sla else "")
    print(f"{args.requests} {kind}requests / {num_slots} slots "
          f"on {args.devices} device(s) "
          f"(prompt lens {sorted({r.prompt_len for r in reqs})}, "
          f"max_new {sorted({r.max_new_tokens for r in reqs})})\n")
    done = 0
    seen_hits = 0
    seen_events = 0
    pds = num_slots // args.devices
    t0 = time.time()
    while engine.step():
        newly = engine.completions[done:]
        done = len(engine.completions)
        live = sum(s is not None for s in engine._slots)
        # P = prefilling a prompt chunk, D = decoding, . = idle slot;
        # under --sla the case carries the class (P/D interactive,
        # p/d batch — the preemptible ones); '|' separates each device's
        # slot range under a sharded pool
        def _mark(s):
            if s is None:
                return "."
            m = "P" if s.phase == "prefilling" else "D"
            return m.lower() if s.req.req_class == "batch" else m
        marks = "|".join(
            "".join(_mark(s) for s in engine._slots[d * pds : (d + 1) * pds])
            for d in range(args.devices)
        )
        occ = engine.device_occupancy()
        dev = f"  per-device {occ}" if args.devices > 1 else ""
        fin = " ".join(f"req{c.request_id}[{c.finish_reason}]" for c in newly)
        # prefix-cache hits land at admission: blocks attached read-only
        # from the radix cache (+fork = a partial block was COW-forked)
        hits = list(engine.request_prefix_hits.items())[seen_hits:]
        seen_hits += len(hits)
        hit = " ".join(
            f"hit req{rid}: {h['tokens']}tok/{h['blocks']}blk"
            + ("+fork" if h["forked"] else "")
            for rid, h in hits
        )
        # SLA control-plane events: eviction (KV spilled or freed-for-
        # recompute), the later resume, and watermark rejections
        events = engine.event_log[seen_events:]
        seen_events = len(engine.event_log)
        sla = " ".join(
            f"preempt req{e[2]}@slot{e[4]}({e[3]})" if e[0] == "preempt"
            else f"resume req{e[2]}@slot{e[3]}" if e[0] == "resume"
            else f"REJECT req{e[2]}" if e[0] == "reject" else ""
            for e in events
            if e[0] in ("preempt", "resume", "reject")
        ).strip()
        print(f"step {engine.step_count - 1:3d}  slots [{marks}] "
              f"active={live}{dev}"
              + (f"  {hit}" if hit else "")
              + (f"  {sla}" if sla else "")
              + (f"  finished: {fin}" if fin else ""))
    dt = time.time() - t0

    m = engine.metrics()
    print(f"\nserved {m['completions']} requests, {m['generated_tokens']} tokens "
          f"in {dt:.2f}s ({m['generated_tokens']/dt:.1f} tok/s)")
    print(f"slot utilization {m['mean_slot_utilization']*100:.0f}%  "
          f"fused-step compilations {m['fused_step_compilations']} "
          f"(one per horizon bucket when paged, else jit-once), "
          f"per-length prefill compilations {m['prefill_compilations']}")
    if args.devices > 1:
        print(f"sharded: {m['num_devices']} devices x {m['per_device_slots']} "
              f"slots — admissions/device {m['device_admits']}, "
              f"balance {m['shard_balance']:.2f} (1.0 = perfectly even)")
    if args.shared_prefix:
        print(f"prefix cache: hit rate {m['prefix_hit_rate']*100:.0f}% "
              f"({m['prefix_hit_tokens']}/{m['prefix_prompt_tokens']} prompt "
              f"tokens), {m['prefix_hit_requests']} hit requests, "
              f"{m['prefix_forks']} COW forks, {m['prefix_evictions']} "
              f"evictions, {m['prefix_cached_blocks']} blocks retained")
    if args.sla:
        print(f"sla: {m['preemptions']} preemptions ({m['preempt_mode']}), "
              f"{m['preempt_resumes']} resumes, {m['rejections']} rejections")
        for klass in ("interactive", "batch"):
            cs = [c for c in engine.completions
                  if c.req_class == klass and c.finish_reason != "rejected"]
            if not cs:
                continue
            ttft = [c.ttft_steps for c in cs]
            wait = [c.queue_wait_steps for c in cs]
            # arrival-anchored step-clock latency: queue wait included,
            # deterministic under replay (see docs/serving.md §6)
            print(f"  {klass:<11} n={len(cs):2d}  ttft_steps "
                  f"p50 {np.median(ttft):.0f} max {max(ttft)}  "
                  f"queue_wait p50 {np.median(wait):.0f} max {max(wait)}")
    lat = [c.latency_s for c in engine.completions
           if c.finish_reason != "rejected"]
    print(f"latency p50 {np.median(lat)*1e3:.0f}ms  max {max(lat)*1e3:.0f}ms")

    ref = static_reference(model, params, reqs, ServeConfig())
    same = all(np.array_equal(c.tokens, ref[c.request_id])
               for c in engine.completions if c.finish_reason != "rejected")
    print(f"greedy outputs token-identical to the static engine: {same}")


if __name__ == "__main__":
    main()
