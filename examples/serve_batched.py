"""Batched serving with GN non-GEMM ops — the paper's deployment scenario.

The paper targets edge *inference*: Softmax/LayerNorm units inside a serving
datapath. This example runs the full serving stack on a small in-framework
model: prefill a batch of prompts, decode new tokens with the per-family
KV cache, and score the outputs — comparing the GN implementation against
an unnormalized baseline (Softermax) to show why guaranteed normalization
matters for score-oriented serving (log-prob scoring, perplexity).

Run:  PYTHONPATH=src python examples/serve_batched.py [--arch internlm2-1.8b]
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config, list_archs, reduce_config
from repro.data.synthetic import DataConfig, batch_at
from repro.models.transformer import make_model
from repro.serve.engine import ServeConfig, generate, perplexity


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b", choices=list_archs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    base = reduce_config(get_config(args.arch))
    data = DataConfig(vocab=base.vocab, seq_len=args.prompt_len,
                      global_batch=args.batch, seed=3)
    prompts = batch_at(data, 0)
    if base.family == "encdec":
        prompts["frames"] = jnp.zeros((args.batch, base.encoder_seq, base.d_model))
    if base.family == "vlm":
        prompts["patches"] = jnp.zeros((args.batch, base.num_patches, base.d_model))

    results = {}
    for impl in ("exact", "gn", "softermax"):
        cfg = dataclasses.replace(base, softmax_impl=impl)
        model = make_model(cfg)
        params = model.init(jax.random.PRNGKey(0))  # same weights across impls

        t0 = time.time()
        out = generate(model, params, prompts, ServeConfig(max_new_tokens=args.new_tokens))
        dt = time.time() - t0
        ppl = perplexity(model, params, prompts)
        results[impl] = (out, ppl, dt)
        print(f"[{impl:<9}] generated {out.shape} in {dt:.2f}s "
              f"(prefill+{args.new_tokens} steps)  prompt ppl {ppl:.4f}")

    exact_out, exact_ppl, _ = results["exact"]
    print("\n== score-oriented serving: deviation from the exact datapath ==")
    for impl in ("gn", "softermax"):
        out, ppl, _ = results[impl]
        tok_match = float((out == exact_out).mean())
        dppl = 100.0 * (ppl - exact_ppl) / exact_ppl
        print(f"  {impl:<9} token match {tok_match*100:5.1f}%   ppl drift {dppl:+.3f}%")
    print("\n(rank-oriented greedy argmax tolerates approximation; the ppl drift —")
    print(" the score-oriented metric — is where unnormalized baselines degrade.)")


if __name__ == "__main__":
    main()
