"""End-to-end driver: train an LM with GN non-GEMM ops, then score it.

Trains a decoder-only LM on the deterministic synthetic Zipf-Markov corpus,
with the paper's GN-Softmax/GN-LayerNorm inside every attention and norm site,
then reports held-out perplexity (the paper's score-oriented metric) against
the exact-ops twin — reproducing Table I's structure in-framework.

Defaults are CPU-friendly (~3M params, 200 steps, <2 min). ``--full`` selects
a ~100M-param config for real hardware.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200] [--full]
      PYTHONPATH=src python examples/train_lm.py --compare   # GN vs exact twin
"""
import argparse
import time

import jax

from repro.configs.base import ModelConfig
from repro.data.synthetic import DataConfig, batch_at, optimal_perplexity
from repro.models.transformer import make_model
from repro.serve.engine import perplexity
from repro.train.loop import make_train_step
from repro.train.optimizer import OptimizerConfig, init_opt_state


def lm_config(full: bool, softmax_impl: str, norm_impl: str) -> ModelConfig:
    if full:  # ~100M params (gpt-neo-small-ish)
        return ModelConfig(
            name="lm-100m", family="dense", n_layers=12, d_model=768,
            n_heads=12, n_kv_heads=12, d_ff=3072, vocab=50304,
            softmax_impl=softmax_impl, norm_impl=norm_impl, remat="none",
        )
    return ModelConfig(
        name="lm-3m", family="dense", n_layers=4, d_model=128, n_heads=4,
        n_kv_heads=4, d_ff=512, vocab=512, softmax_impl=softmax_impl,
        norm_impl=norm_impl, remat="none", dtype="float32",
    )


def train(cfg: ModelConfig, steps: int, seq: int, batch: int, seed: int = 0):
    model = make_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    n_params = sum(p.size for p in jax.tree.leaves(params))
    opt_cfg = OptimizerConfig(lr=3e-3, warmup_steps=20, total_steps=steps)
    opt_state = init_opt_state(opt_cfg, params)
    step_fn = jax.jit(make_train_step(model, opt_cfg), donate_argnums=(0, 1))
    data = DataConfig(vocab=cfg.vocab, seq_len=seq, global_batch=batch, seed=7)

    print(f"[{cfg.name}] {n_params/1e6:.1f}M params, softmax={cfg.softmax_impl}, "
          f"norm={cfg.norm_impl}")
    t0 = time.time()
    for step in range(steps):
        params, opt_state, metrics = step_fn(params, opt_state, batch_at(data, step))
        if step % max(1, steps // 10) == 0 or step == steps - 1:
            print(f"  step {step:4d}  loss {float(metrics['loss']):.4f}  "
                  f"({time.time()-t0:.1f}s)")
    # held-out eval: steps beyond the training range
    model_eval = make_model(cfg)
    ppl = perplexity(model_eval, params, batch_at(data, 10_000))
    print(f"  held-out perplexity: {ppl:.3f}  "
          f"(corpus optimum ~{optimal_perplexity(data):.3f})")
    return ppl


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--full", action="store_true", help="~100M params")
    ap.add_argument("--compare", action="store_true",
                    help="also train an exact-ops twin and compare perplexity")
    args = ap.parse_args()

    ppl_gn = train(lm_config(args.full, "gn", "gn_ln"), args.steps, args.seq, args.batch)
    if args.compare:
        ppl_exact = train(
            lm_config(args.full, "exact", "exact_ln"), args.steps, args.seq, args.batch
        )
        delta = 100.0 * (ppl_gn - ppl_exact) / ppl_exact
        print(f"\nGN vs exact perplexity: {ppl_gn:.3f} vs {ppl_exact:.3f} "
              f"({delta:+.2f}%)  [paper reports -0.09% on GPT-Neo/WikiText]")


if __name__ == "__main__":
    main()
