"""Core: the paper's contribution — GN-Softmax & GN-LayerNorm (CoRN-LN)."""
from repro.core.api import get_norm, get_softmax
from repro.core.gn_layernorm import (
    exact_layernorm,
    exact_rmsnorm,
    gn_layernorm,
    gn_layernorm_hwsim,
    gn_rmsnorm,
    newton_rsqrt,
)
from repro.core.gn_softmax import (
    exact_softmax,
    gn_log_softmax,
    gn_softmax,
    gn_softmax_hwsim,
)
from repro.core.luts import (
    PAPER_RSQRT,
    PAPER_SOFTMAX_LUT,
    TPU_SOFTMAX_LUT,
    RsqrtConfig,
    SoftmaxLUTConfig,
)
from repro.core.metrics import (
    error_histogram,
    layernorm_norm_error,
    softmax_norm_error,
)

__all__ = [
    "get_norm",
    "get_softmax",
    "exact_layernorm",
    "exact_rmsnorm",
    "gn_layernorm",
    "gn_layernorm_hwsim",
    "gn_rmsnorm",
    "newton_rsqrt",
    "exact_softmax",
    "gn_log_softmax",
    "gn_softmax",
    "gn_softmax_hwsim",
    "PAPER_RSQRT",
    "PAPER_SOFTMAX_LUT",
    "TPU_SOFTMAX_LUT",
    "RsqrtConfig",
    "SoftmaxLUTConfig",
    "error_histogram",
    "layernorm_norm_error",
    "softmax_norm_error",
]
