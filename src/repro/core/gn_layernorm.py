"""GN-LayerNorm — the paper's Algorithm 2 (CoRN-LN) as a composable JAX op.

The costly 1/sqrt is replaced by a Newton iteration whose initial guess comes
from a Leading-One-Detector (exponent extraction) refined by a small mantissa
LUT — the "compressed" CoRN table.  Reformulated in reciprocal-square-root
form, every Newton step and the output stage are multiplications only:

    x_{k+1} = x_k * (1.5 - 0.5 * n * x_k^2)          (mul-only NR for 1/sqrt n)

which is the division-free realization of the paper's Eq. (5) fixed point
(attractor 1/sqrt(n)).  Unit variance is guaranteed to the rsqrt's relative
error: with a 16-entry mantissa LUT and 2 iterations, |1 - sigma| < ~1e-6.

Variants:
* :func:`gn_layernorm`      — full LN (mean subtraction), paper-faithful.
* :func:`gn_rmsnorm`        — sigma-guaranteed RMSNorm for llama-family archs
                              (mean path disabled; Newton unit unchanged).
* :func:`gn_layernorm_hwsim`— bit-accurate integer datapath (Q8.8 in, Q.16
                              Newton, integer LOD) for accuracy experiments.
* :func:`exact_layernorm`   — FP32 oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import fixedpoint as fxp
from repro.core import luts
from repro.core.luts import INV_SQRT2, PAPER_RSQRT, RsqrtConfig


def exact_layernorm(x, gamma=None, beta=None, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mu), axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    if gamma is not None:
        y = y * gamma.astype(jnp.float32)
    if beta is not None:
        y = y + beta.astype(jnp.float32)
    return y.astype(x.dtype)


def exact_rmsnorm(x, gamma=None, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(ms + eps)
    if gamma is not None:
        y = y * gamma.astype(jnp.float32)
    return y.astype(x.dtype)


def newton_rsqrt(n: jax.Array, cfg: RsqrtConfig = PAPER_RSQRT) -> jax.Array:
    """CoRN reciprocal square root: LOD + mantissa LUT + mul-only NR steps.

    n: positive float32.  TPU-native LOD = exponent-field extraction (bitcast
    and mask), the direct analogue of a hardware priority encoder.
    """
    n32 = n.astype(jnp.float32)
    e = fxp.float_lod(n32)                         # floor(log2 n)
    idx = fxp.float_mantissa_index(n32, cfg.mantissa_bits)
    lut = jnp.asarray(luts.rsqrt_mantissa_lut(cfg))
    m_r = lut[idx]                                 # ~ 1/sqrt(mantissa)
    e_half = e >> 1                                # arithmetic shift == floor
    odd = (e & 1).astype(jnp.float32)
    # 2^{-e_half} built by exponent-field assembly (no transcendental).
    pow_bits = (127 - e_half) << 23
    pow2 = jax.lax.bitcast_convert_type(pow_bits.astype(jnp.int32), jnp.float32)
    x0 = m_r * pow2 * jnp.where(odd > 0, jnp.float32(INV_SQRT2), jnp.float32(1.0))
    x = x0
    for _ in range(cfg.iters):
        x = x * (1.5 - 0.5 * n32 * x * x)
    return x


@functools.partial(jax.custom_jvp, nondiff_argnums=(3, 4))
def _gn_normalize(x, gamma, beta, cfg: RsqrtConfig, subtract_mean: bool):
    x32 = x.astype(jnp.float32)
    if subtract_mean:
        # Algorithm 2 accumulates E[x], E[x^2] in *exact* integer accumulators;
        # the float32-faithful equivalent of that exactness is the centered
        # (cancellation-free) form.  The hw-sim path keeps the literal
        # one-pass E[x^2]-E[x]^2 in wide integers.  (DESIGN.md §2.)
        ex = jnp.mean(x32, axis=-1, keepdims=True)
        centered = x32 - ex
        var = jnp.mean(jnp.square(centered), axis=-1, keepdims=True)
    else:
        var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
        centered = x32
    rstd = newton_rsqrt(var + 1e-8, cfg)
    y = centered * rstd
    if gamma is not None:
        y = y * gamma.astype(jnp.float32)
    if beta is not None:
        y = y + beta.astype(jnp.float32)
    return y.astype(x.dtype)


@_gn_normalize.defjvp
def _gn_normalize_jvp(cfg, subtract_mean, primals, tangents):
    """Straight-through tangent: exact norm Jacobian at the approx normalizer."""
    x, gamma, beta = primals
    dx, dgamma, dbeta = tangents
    x32 = x.astype(jnp.float32)
    if subtract_mean:
        ex = jnp.mean(x32, axis=-1, keepdims=True)
        centered = x32 - ex
        var = jnp.mean(jnp.square(centered), axis=-1, keepdims=True)
    else:
        var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
        centered = x32
    rstd = newton_rsqrt(var + 1e-8, cfg)
    xhat = centered * rstd

    dx32 = jnp.zeros_like(x32) if _is_sym_zero(dx) else dx.astype(jnp.float32)
    if subtract_mean:
        dmu = jnp.mean(dx32, axis=-1, keepdims=True)
        dc = dx32 - dmu
    else:
        dc = dx32
    # d xhat = r*(dc - xhat * mean(xhat*dc))   [exact LN/RMS tangent at xhat]
    proj = jnp.mean(xhat * dc, axis=-1, keepdims=True)
    dxhat = rstd * (dc - xhat * proj)

    y = xhat
    dy = dxhat
    if gamma is not None:
        g32 = gamma.astype(jnp.float32)
        dg = jnp.zeros_like(g32) if _is_sym_zero(dgamma) else dgamma.astype(jnp.float32)
        dy = dy * g32 + xhat * dg
        y = y * g32
    if beta is not None:
        b32 = beta.astype(jnp.float32)
        db = jnp.zeros_like(b32) if _is_sym_zero(dbeta) else dbeta.astype(jnp.float32)
        dy = dy + db
        y = y + b32
    return y.astype(x.dtype), dy.astype(x.dtype)


def _is_sym_zero(t) -> bool:
    from jax.custom_derivatives import SymbolicZero  # local import: private-ish

    return isinstance(t, SymbolicZero) or (
        hasattr(jax.interpreters.ad, "Zero") and isinstance(t, jax.interpreters.ad.Zero)
    )


def gn_layernorm(x, gamma=None, beta=None, cfg: RsqrtConfig = PAPER_RSQRT):
    """Algorithm 2: sigma-guaranteed LayerNorm (mean subtraction on)."""
    return _gn_normalize(x, gamma, beta, cfg, True)


def gn_rmsnorm(x, gamma=None, cfg: RsqrtConfig = PAPER_RSQRT):
    """sigma-guaranteed RMSNorm (GN applied to llama-family norms)."""
    return _gn_normalize(x, gamma, None, cfg, False)


# --- Bit-accurate integer datapath (Fig. 4) ----------------------------------

def _int_rsqrt_q16(v: jax.Array, cfg: RsqrtConfig) -> jax.Array:
    """Integer CoRN rsqrt.  v: int64 variance in Q.16 (>0).  Returns Q.16.

    LOD (priority encoder) -> mantissa LUT -> ``cfg.iters`` integer NR steps:
        x <- x * (3*2^16 - ((v*x >> 16) * x >> 16)) >> 17
    """
    p = fxp.lod(v.astype(jnp.int32) | 1)           # leading-one position
    e = p - 16                                     # real exponent of n = v/2^16
    mb = cfg.mantissa_bits
    # mantissa bits just below the leading one (guard for small p)
    sh = jnp.maximum(p - mb, 0)
    idx = ((v >> sh) & ((1 << mb) - 1)).astype(jnp.int32)
    import numpy as np

    lut_q16 = jnp.asarray(
        np.round(luts.rsqrt_mantissa_lut(cfg) * (1 << 16)).astype("int64")
    )
    x = lut_q16[idx]                               # Q.16 of 1/sqrt(mantissa)
    h = e >> 1
    o = e & 1
    inv_sqrt2_q16 = jnp.int64(round(INV_SQRT2 * (1 << 16)))
    x = jnp.where(o == 1, (x * inv_sqrt2_q16) >> 16, x)
    # scale by 2^{-h} (clamped shifts: both jnp.where branches are evaluated)
    x = jnp.where(h >= 0, x >> jnp.maximum(h, 0), x << jnp.maximum(-h, 0))
    three = jnp.int64(3 << 16)

    for _ in range(cfg.iters):
        nx = (v * x) >> 16
        nxx = (nx * x) >> 16
        x = (x * (three - nxx)) >> 17
    return x


def gn_layernorm_hwsim(
    x, gamma=None, beta=None, cfg: RsqrtConfig = PAPER_RSQRT, subtract_mean: bool = True
):
    """Fig. 4 integer datapath: Q8.8 input, wide accumulators, integer CoRN."""
    q = fxp.LN_IN_Q
    xi32 = q.quantize(x.astype(jnp.float32))                     # Q8.8 int32
    c = x.shape[-1]
    with jax.experimental.enable_x64():
        xi = xi32.astype(jnp.int64)
        ex = jnp.sum(xi, axis=-1, keepdims=True) // c            # Q8.8 mean
        ex2 = jnp.sum(xi * xi, axis=-1, keepdims=True) // c      # Q.16
        if subtract_mean:
            var = jnp.maximum(ex2 - ex * ex, 1)                  # Q.16
            centered = xi - ex
        else:
            var = jnp.maximum(ex2, 1)
            centered = xi
        rstd = _int_rsqrt_q16(var, cfg)                          # Q.16
        # output stage: multiplier + round-to-nearest, Q8.8 out
        y_q8 = (centered * rstd + (jnp.int64(1) << 15)) >> 16
        y = y_q8.astype(jnp.float32) / q.scale
    if gamma is not None:
        y = y * gamma.astype(jnp.float32)
    if beta is not None:
        y = y + beta.astype(jnp.float32)
    return y.astype(x.dtype)
