"""Fixed-point (Q-format) arithmetic simulation for the hardware units.

The paper's datapath is INT8-in / fixed-point-internal.  We simulate it
bit-accurately with int32 JAX arrays so accuracy experiments measure the
*hardware's* numbers, not a float approximation of them.

Conventions
-----------
A Q(f) value stores ``round(x * 2**f)`` as an integer; ``f`` is the number of
fractional bits.  All helpers are pure and jit-safe.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class QFormat:
    """Fixed-point format: ``total_bits`` wide, ``frac_bits`` fractional."""

    total_bits: int
    frac_bits: int
    signed: bool = False

    @property
    def scale(self) -> float:
        return float(2 ** self.frac_bits)

    @property
    def max_int(self) -> int:
        if self.signed:
            return 2 ** (self.total_bits - 1) - 1
        return 2 ** self.total_bits - 1

    @property
    def min_int(self) -> int:
        if self.signed:
            return -(2 ** (self.total_bits - 1))
        return 0

    def quantize(self, x: jax.Array) -> jax.Array:
        """Float -> saturating Q(f) integer (round-to-nearest-even)."""
        q = jnp.round(x * self.scale)
        q = jnp.clip(q, self.min_int, self.max_int)
        return q.astype(jnp.int32)

    def dequantize(self, q: jax.Array) -> jax.Array:
        return q.astype(jnp.float32) / self.scale


# Formats used by the paper-faithful datapath ---------------------------------
# Softmax: Δ is the INT-domain stabilized logit, y the LUT product, Z the sum.
DELTA_Q = QFormat(total_bits=8, frac_bits=0)          # INT8 Δ (paper Table III)
LUT_Q = QFormat(total_bits=16, frac_bits=15)          # LUT entries in Q1.15
PROD_Q = QFormat(total_bits=16, frac_bits=15)         # y = a*b, renormalized
RECIP_BITS = 24  # D_max = 2**24 in FxP_Div (24-bit probabilities: the paper's
#                  sub-2e-7 Fig.5 normalization errors require >=24-bit rescale)
# LayerNorm: inputs Q8.8, accumulators wide.
LN_IN_Q = QFormat(total_bits=16, frac_bits=8, signed=True)
LN_STD_Q = QFormat(total_bits=24, frac_bits=16)


def shift_subtract_div(numer: jax.Array, denom: jax.Array, out_bits: int) -> jax.Array:
    """Restoring (shift-subtract) integer division: floor(numer << out_bits / denom).

    This is the FxP_Div primitive of the paper: one sequential divider shared
    per row, producing an ``out_bits``-fractional-bit reciprocal scale.  We
    simulate the restoring-division loop with a fori_loop over bit positions so
    the result is bit-exact with the RTL (floor division), not a float rcp.

    numer/denom: int32 (denom > 0).  Returns int32 quotient with ``out_bits``
    fractional bits.  Inputs must satisfy numer << out_bits < 2**62 — callers
    keep numer in <= 30 bits.
    """
    with jax.experimental.enable_x64():
        numer = jnp.asarray(numer).astype(jnp.int64)
        denom = jnp.asarray(denom).astype(jnp.int64)
        numer, denom = jnp.broadcast_arrays(numer, denom)

        # MSB-first restoring division over the virtual numerator
        # N = numer << out_bits.  The partial remainder is shifted (never the
        # divisor), so every intermediate fits comfortably in int64 — exactly
        # like the RTL's shift register.
        total_bits = 46 + out_bits  # numer is kept <= 46 bits by callers

        def body(i, carry):
            rem, quot = carry
            bit_pos = total_bits - 1 - i
            src = bit_pos - out_bits
            nbit = jnp.where(src >= 0, (numer >> jnp.maximum(src, 0)) & 1, 0)
            rem = (rem << 1) | nbit
            take = rem >= denom
            rem = jnp.where(take, rem - denom, rem)
            quot = (quot << 1) | take.astype(jnp.int64)
            return rem, quot

        rem0 = jnp.zeros_like(numer)
        quot0 = jnp.zeros_like(numer)
        _, quot = jax.lax.fori_loop(0, total_bits, body, (rem0, quot0))
        return quot


def lod(x: jax.Array) -> jax.Array:
    """Leading-one detector: position of the highest set bit of int32 x (>=1).

    lod(1) == 0, lod(2) == 1, lod(3) == 1 ...  Hardware LOD is a priority
    encoder; we simulate with a clz-style loop (jit-safe, no float log).
    """
    x = x.astype(jnp.uint32)

    def body(i, carry):
        pos, xs = carry
        has = xs > 1
        pos = jnp.where(has, pos + 1, pos)
        xs = jnp.where(has, xs >> 1, xs)
        return pos, xs

    pos0 = jnp.zeros_like(x, dtype=jnp.int32)
    pos, _ = jax.lax.fori_loop(0, 32, body, (pos0, x))
    return pos


def float_lod(x: jax.Array) -> jax.Array:
    """LOD for positive float32: floor(log2(x)) via exponent-field extraction.

    This is the TPU-native analogue of a hardware leading-one detector —
    bit-cast and mask, no transcendental.
    """
    bits = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.int32)
    return ((bits >> 23) & 0xFF) - 127


def float_mantissa_index(x: jax.Array, lut_bits: int) -> jax.Array:
    """Top ``lut_bits`` of the float32 mantissa (index into a refinement LUT)."""
    bits = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.int32)
    return (bits >> (23 - lut_bits)) & ((1 << lut_bits) - 1)
