"""Prior-work approximation baselines the paper compares against.

These are *rank-oriented* designs: they preserve ordering but break the
normalization (sum p != 1 for softmax, sigma != 1 for LN), which is exactly
what Table II / Fig. 5 of the paper measure.  Each is implemented faithfully
enough to reproduce its characteristic normalization error:

* :func:`softermax`        — Softermax [5]: base-2 exponential, low-precision
                             running (online) denominator.
* :func:`pseudo_softmax`   — pseudo-softmax [6]: 2^(x_i - sum-based offset),
                             no true normalization.
* :func:`log_domain_softmax` — Sole [4]-style: log-sum-exp with a LUT'd
                             log2(1+t) correction, probabilities re-exponentiated
                             with the base-2 LUT (unnormalized).
* :func:`integer_layernorm`— dynamic-quantization integer LN [16]-style: the
                             1/sigma factor is snapped to a power of two.
* :func:`lut_layernorm`    — [15]-style: 1/sigma from a coarse LUT on var.
* :func:`rmsnorm`          — RMSNorm [7] (exact, but sigma!=1 w.r.t. LN since
                             the mean is not removed).

All operate over the last axis and are jit-safe.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

LOG2E = 1.4426950408889634


def _quantize_unsigned(x, bits: int):
    """Round x in [0, 1] to ``bits`` fractional bits (truncating, like HW)."""
    s = float(1 << bits)
    return jnp.floor(x * s) / s


def softermax(x: jax.Array, frac_bits: int = 8) -> jax.Array:
    """Softermax: p_i = 2^(x_i - m) / sum 2^(x_j - m), low-precision terms.

    Base-2 replaces e^x (cheap shifter in HW).  Terms and the running sum are
    quantized to ``frac_bits`` fixed point, and the final division uses the
    quantized sum — the result is order-preserving but NOT normalized in the
    e^x sense, and its low-precision sum leaves |1-sum p| ~ 2^-frac_bits * N.
    """
    x32 = x.astype(jnp.float32)
    m = jnp.max(x32, axis=-1, keepdims=True)
    t = _quantize_unsigned(jnp.exp2(x32 - m), frac_bits)
    z = jnp.sum(t, axis=-1, keepdims=True)
    # reciprocal also in low precision (one Newton step from a 2^-k guess)
    z_q = jnp.maximum(z, 1.0 / (1 << frac_bits))
    p = t / z_q
    # output register truncation
    p = _quantize_unsigned(p, frac_bits)
    return p.astype(x.dtype)


def pseudo_softmax(x: jax.Array) -> jax.Array:
    """pseudo-softmax [6]: base-2 with the sum replaced by an exponent hack.

    p_i = 2^(x_i*log2e - A) where A = log2(sum 2^(x_j log2e)) is approximated
    by the *integer* exponent of the accumulated sum (mantissa dropped) —
    ordering preserved, scores off by the dropped mantissa in [1, 2).
    """
    x32 = x.astype(jnp.float32) * LOG2E
    m = jnp.max(x32, axis=-1, keepdims=True)
    t = jnp.exp2(x32 - m)
    z = jnp.sum(t, axis=-1, keepdims=True)
    # integer exponent of z only (hardware drops the mantissa normalizer)
    zbits = jax.lax.bitcast_convert_type(z, jnp.int32)
    zexp = ((zbits >> 23) & 0xFF) - 127
    p = t * jnp.exp2(-zexp.astype(jnp.float32))
    return p.astype(x.dtype)


def log_domain_softmax(x: jax.Array, lut_bits: int = 4) -> jax.Array:
    """Sole [4]-style log-domain softmax with LUT'd log2(1+t) correction.

    logsumexp is computed pairwise in log2 domain using max + LUT(log2(1+2^-d))
    with a 2^lut_bits-entry correction table; probabilities are 2^(x_i - lse)
    through a coarse base-2 LUT.  Unnormalized: LUT truncation accumulates in
    the denominator.
    """
    x32 = x.astype(jnp.float32) * LOG2E
    m = jnp.max(x32, axis=-1, keepdims=True)
    d = m - x32
    # log2-domain accumulation: lse = m + log2(sum 2^-d); correction LUT'd
    s = jnp.sum(jnp.exp2(-_quantize_unsigned(jnp.minimum(d, 31.0), 2)), axis=-1, keepdims=True)
    # coarse log2 via exponent + LUT on top mantissa bits
    sbits = jax.lax.bitcast_convert_type(s, jnp.int32)
    sexp = ((sbits >> 23) & 0xFF) - 127
    mant_idx = (sbits >> (23 - lut_bits)) & ((1 << lut_bits) - 1)
    # LUT(log2(1+i/2^b)) evaluated at bucket left edge (truncation)
    lut = jnp.log2(1.0 + jnp.arange(1 << lut_bits, dtype=jnp.float32) / (1 << lut_bits))
    lse = sexp.astype(jnp.float32) + lut[mant_idx]
    p = jnp.exp2(-d - lse)
    return p.astype(x.dtype)


def integer_layernorm(x, gamma=None, beta=None) -> jax.Array:
    """[16]-style dynamic-quant integer LN: 1/sigma snapped to a power of two.

    sigma_hat = 2^round(log2 sigma)  =>  output variance off by up to sqrt(2).
    """
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mu), axis=-1, keepdims=True) + 1e-6
    # round(log2 sigma) = round(0.5*log2 var) via exponent field
    vbits = jax.lax.bitcast_convert_type(var, jnp.int32)
    vexp = ((vbits >> 23) & 0xFF) - 127
    # include top mantissa bit for rounding to nearest exponent
    mant_top = (vbits >> 22) & 1
    log2var = vexp + mant_top  # ~round(log2 var)
    shift = -(log2var.astype(jnp.float32) / 2.0)
    rstd = jnp.exp2(jnp.round(shift))              # power-of-two reciprocal
    y = (x32 - mu) * rstd
    if gamma is not None:
        y = y * gamma.astype(jnp.float32)
    if beta is not None:
        y = y + beta.astype(jnp.float32)
    return y.astype(x.dtype)


def lut_layernorm(x, gamma=None, beta=None, lut_bits: int = 6) -> jax.Array:
    """[15]-style LN: 1/sqrt(var) from a coarse LUT over the var mantissa."""
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mu), axis=-1, keepdims=True) + 1e-6
    vbits = jax.lax.bitcast_convert_type(var, jnp.int32)
    vexp = ((vbits >> 23) & 0xFF) - 127
    idx = (vbits >> (23 - lut_bits)) & ((1 << lut_bits) - 1)
    # LUT(1/sqrt(m)) at bucket LEFT edge (truncating LUT, per [15])
    m_edge = 1.0 + jnp.arange(1 << lut_bits, dtype=jnp.float32) / (1 << lut_bits)
    lut = 1.0 / jnp.sqrt(m_edge)
    e_half = vexp >> 1
    odd = (vexp & 1).astype(jnp.float32)
    pow2 = jnp.exp2(-e_half.astype(jnp.float32))
    rstd = lut[idx] * pow2 * jnp.where(odd > 0, jnp.float32(2.0 ** -0.5), 1.0)
    y = (x32 - mu) * rstd
    if gamma is not None:
        y = y * gamma.astype(jnp.float32)
    if beta is not None:
        y = y + beta.astype(jnp.float32)
    return y.astype(x.dtype)


def rmsnorm(x, gamma=None, eps: float = 1e-6) -> jax.Array:
    """Exact RMSNorm [7] — no mean subtraction."""
    x32 = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(ms + eps)
    if gamma is not None:
        y = y * gamma.astype(jnp.float32)
    return y.astype(x.dtype)


SOFTMAX_IMPLS = {
    "exact": None,        # filled by api.py to avoid circular import
    "gn": None,
    "gn_hwsim": None,
    "softermax": softermax,
    "pseudo": pseudo_softmax,
    "log_domain": log_domain_softmax,
}

NORM_IMPLS = {
    "exact_ln": None,
    "gn_ln": None,
    "gn_rms": None,
    "integer_ln": integer_layernorm,
    "lut_ln": lut_layernorm,
    "rmsnorm": rmsnorm,
}
