"""Normalization-error metrics (paper Sec. II-A, Fig. 5).

normalization error := |1 - sum p|   (softmax)
                       |1 - sigma|   (layernorm output std)
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def softmax_norm_error(p) -> jnp.ndarray:
    """Per-row |1 - sum p| over the last axis."""
    return jnp.abs(1.0 - jnp.sum(p.astype(jnp.float32), axis=-1))


def layernorm_norm_error(y) -> jnp.ndarray:
    """Per-row |1 - std(y)| over the last axis (pre-gamma/beta output)."""
    std = jnp.std(y.astype(jnp.float32), axis=-1)
    return jnp.abs(1.0 - std)


def error_histogram(err: np.ndarray, edges=None) -> dict:
    """Fig.-5-style distribution summary of normalization errors."""
    err = np.asarray(err, dtype=np.float64).ravel()
    if edges is None:
        edges = [0.0, 0.2e-6, 1e-6, 1e-5, 1e-4, 1e-3, 1e-2, 1e-1, np.inf]
    counts, _ = np.histogram(err, bins=edges)
    frac = counts / max(err.size, 1)
    return {
        "edges": [float(e) for e in edges],
        "fraction": [float(f) for f in frac],
        "mean": float(err.mean()) if err.size else 0.0,
        "max": float(err.max()) if err.size else 0.0,
        "frac_below_0.2e-6": float((err < 0.2e-6).mean()) if err.size else 0.0,
    }
