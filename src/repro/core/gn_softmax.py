"""GN-Softmax — the paper's Algorithm 1 as a composable JAX op.

Three entry points, all row-wise over the last axis:

* :func:`gn_softmax` — float-faithful datapath (default inside models).  Same
  algorithm as the RTL (two-LUT factorized exponential on a fixed-point Δ grid
  + renormalization by the true sum of the approximated numerators) but with
  the integer product carried in float32.  Differentiable via ``custom_jvp``.
* :func:`gn_softmax_hwsim` — bit-accurate INT datapath: int32 LUT entries,
  integer product, shift-subtract FxP_Div.  This is what accuracy experiments
  measure; it matches the RTL number-for-number.
* :func:`exact_softmax` — the FP32 oracle.

The normalization guarantee: probabilities are ``y_i * S`` with a *single*
reciprocal scale ``S ≈ 1/Z``, ``Z = Σ y_i`` of the same approximated ``y`` —
so ``Σ p = Z * S ≈ 1`` regardless of how coarse the exponential approximation
is.  ``|1 − Σp|`` is bounded by the reciprocal's rounding, not by the LUT.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import luts
from repro.core.fixedpoint import RECIP_BITS, shift_subtract_div
from repro.core.luts import RADIX, SoftmaxLUTConfig, TPU_SOFTMAX_LUT


def exact_softmax(x: jax.Array, axis: int = -1) -> jax.Array:
    """FP32 reference softmax (the paper's 'FP32 baseline, ideal')."""
    x32 = x.astype(jnp.float32)
    m = jnp.max(x32, axis=axis, keepdims=True)
    e = jnp.exp(x32 - m)
    return (e / jnp.sum(e, axis=axis, keepdims=True)).astype(x.dtype)


def _round_fxp(y: jax.Array, value_bits: int) -> jax.Array:
    """Round to the Q1.value_bits fixed-point grid (the LUT/register grid)."""
    scale = float(1 << value_bits)
    return jnp.round(y * scale) / scale


def _factorized_exp(delta: jax.Array, cfg: SoftmaxLUTConfig) -> jax.Array:
    """e^{-delta} via the two-LUT factorization on the fixed-point Δ grid.

    delta >= 0, float32.  Returns the fixed-point-rounded product a*b.

    TPU lowering note (perf iteration B1, EXPERIMENTS.md §Perf): the obvious
    ``coarse[frac]`` indexing lowers to a *gather over the whole score
    tensor* — 2.4e14 bytes on the deepseek prefill_32k cell.  The LUT entries
    are by construction ``round_fxp(exp(-grid))``, so we compute them
    arithmetically from the quantized Δ — elementwise exp+round, zero gathers,
    same values.  (The ROM-indexed datapath survives bit-exactly in
    :func:`gn_softmax_hwsim`, which accuracy experiments use.)
    """
    inv_step = 1.0 / cfg.step
    # Quantize Δ to the grid (hardware: Δ arrives already quantized).
    d_int = jnp.round(delta * inv_step).astype(jnp.int32)
    d_int = jnp.clip(d_int, 0, cfg.max_delta_int)
    shift = 3 + cfg.frac_bits               # divide by R*2^f == >> (3+f)
    frac = d_int >> shift                   # coarse index (mul/div-free)
    rem = d_int & (cfg.residual_entries - 1)
    # LUT-entry values, computed instead of loaded: a = lut_coarse[frac],
    # b = lut_residual[rem] with the same Q1.vb rounding as luts.exp_luts.
    a = _round_fxp(
        jnp.exp(frac.astype(jnp.float32) * (-float(RADIX) * cfg.delta_scale)),
        cfg.lut_value_bits,
    )
    b = _round_fxp(jnp.exp(rem.astype(jnp.float32) * -cfg.step), cfg.lut_value_bits)
    # Product is rounded to the LUT fixed-point grid, as the RTL multiplier
    # output register would be.
    return _round_fxp(a * b, cfg.lut_value_bits)


def factorized_exp_ste(delta: jax.Array, cfg: SoftmaxLUTConfig = TPU_SOFTMAX_LUT) -> jax.Array:
    """:func:`_factorized_exp` with a straight-through backward.

    The streaming (flash) GN-attention path inlines the factorized exponential
    inside a scan, where the custom_jvp of :func:`gn_softmax` does not apply;
    integer quantization would otherwise kill the gradient.  Forward value is
    the fixed-point LUT product; backward is the exact d/dΔ e^{-Δ} evaluated
    at the continuous point.
    """
    cont = jnp.exp(-delta)
    return cont + jax.lax.stop_gradient(_factorized_exp(delta, cfg) - cont)


@functools.partial(jax.custom_jvp, nondiff_argnums=(1,))
def gn_softmax(x: jax.Array, cfg: SoftmaxLUTConfig = TPU_SOFTMAX_LUT) -> jax.Array:
    """Algorithm 1, float-faithful, over the last axis."""
    x32 = x.astype(jnp.float32)
    m = jnp.max(x32, axis=-1, keepdims=True)
    # snap the stabilizer UP onto the Δ grid: in the RTL the inputs are already
    # integer-quantized so max(X) is on-grid by construction; mirroring that
    # here makes tiled/online evaluation (flash attention) bit-consistent with
    # this one-pass form.  The uniform e^{-c} shift cancels in normalization.
    m = jnp.ceil(m / cfg.step) * cfg.step
    delta = jnp.maximum(m - x32, 0.0)        # Δ_i = max(X) − X_i  >= 0
    y = _factorized_exp(delta, cfg)
    z = jnp.sum(y, axis=-1, keepdims=True)
    # FxP_Div (float carrier): one reciprocal per row; numerator and
    # denominator share the same approximated y => Σp = 1 up to rcp rounding.
    p = y * (1.0 / z)
    return p.astype(x.dtype)


@gn_softmax.defjvp
def _gn_softmax_jvp(cfg, primals, tangents):
    """Straight-through Jacobian: exact softmax derivative at the approx p.

    Preserves Σ dp = 0, the tangent of the normalization guarantee.
    """
    (x,) = primals
    (dx,) = tangents
    p = gn_softmax(x, cfg)
    p32 = p.astype(jnp.float32)
    dx32 = dx.astype(jnp.float32)
    inner = jnp.sum(p32 * dx32, axis=-1, keepdims=True)
    dp = p32 * (dx32 - inner)
    return p, dp.astype(p.dtype)


def gn_softmax_hwsim(
    x: jax.Array,
    cfg: SoftmaxLUTConfig = luts.PAPER_SOFTMAX_LUT,
    recip_bits: int = RECIP_BITS,
) -> jax.Array:
    """Bit-accurate integer datapath of Fig. 3 (max-sub -> LUTs -> FxP_Div).

    Input is float; the unit quantizes Δ onto its INT grid (in hardware the
    quantizer lives upstream).  All arithmetic after that point is integer and
    matches the RTL: Q1.f LUT entries, integer product with truncation,
    restoring shift-subtract division for the reciprocal scale, shift-add
    rescale with truncation.
    """
    coarse_i, residual_i = luts.exp_luts_int(cfg)
    vb = cfg.lut_value_bits

    x32 = x.astype(jnp.float32)
    m = jnp.max(x32, axis=-1, keepdims=True)
    delta = m - x32
    d_int = jnp.round(delta / cfg.step).astype(jnp.int32)
    d_int = jnp.clip(d_int, 0, cfg.max_delta_int)
    shift = 3 + cfg.frac_bits
    frac = d_int >> shift
    rem = d_int & (cfg.residual_entries - 1)
    with jax.experimental.enable_x64():
        coarse = jnp.asarray(coarse_i).astype(jnp.int64)
        residual = jnp.asarray(residual_i).astype(jnp.int64)
        a = coarse[frac]                           # Q1.vb int
        b = residual[rem]                          # Q1.vb int
        y = (a * b) >> vb                          # Q1.vb, truncating mul
        z = jnp.sum(y, axis=-1, keepdims=True)     # row sum, wide accumulator
        z = jnp.maximum(z, 1)                      # Δ=0 term guarantees z>=~2^vb
        # FxP_Div: S = floor(2^recip_bits * 2^vb / Z)  (reciprocal in
        # Q.recip_bits of the Q1.vb domain).  One shift-subtract divider per
        # row, then a shift-add rescale of every y.
        s = shift_subtract_div(jnp.int64(1) << vb, z, recip_bits)
        # shift-add rescale with round-to-nearest (add half-ulp before shift)
        p_int = (y * s + (jnp.int64(1) << (vb - 1))) >> vb
        p = p_int.astype(jnp.float32) / float(1 << recip_bits)
    return p.astype(x.dtype)


def gn_log_softmax(x: jax.Array, cfg: SoftmaxLUTConfig = TPU_SOFTMAX_LUT) -> jax.Array:
    """log(gn_softmax) with a numerically safe floor (for perplexity eval)."""
    p = gn_softmax(x, cfg).astype(jnp.float32)
    return jnp.log(jnp.maximum(p, 1e-30))
