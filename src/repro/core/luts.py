"""LUT construction for the GN-Softmax / GN-LayerNorm approximation units.

The paper (Sec. III-C) uses two exponential LUTs with radix R=8:
  * coarse LUT, 7 entries:  CLUT[k] = e^{-R * k * s},  k = 0..6
  * residual LUT          :  RLUT[j] = e^{-j * s},      j = 0..R*2^f - 1
where ``s = 2^-f`` is the fixed-point step of the stabilized input Δ and the
factorization  e^{-Δ} = CLUT[Δ_int >> (3+f)] * RLUT[Δ_int & (R*2^f - 1)]
is *exact* in the integer domain (Eq. 4) — approximation error comes only from
(a) quantizing Δ to the grid and (b) fixed-point rounding of LUT entries.

Paper-faithful configuration: f=0 (INT Δ) -> 8-entry residual LUT.
TPU default: f=3 -> 64-entry residual LUT (VMEM entries are ~free; this is a
beyond-paper accuracy knob recorded in EXPERIMENTS.md).

CoRN-LN (Sec. III-D): Newton reciprocal-sqrt with an LOD initial guess that we
refine with a small mantissa LUT (the "compressed" table).
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

RADIX = 8  # paper's R


@dataclasses.dataclass(frozen=True)
class SoftmaxLUTConfig:
    """Configuration of the two-LUT exponential unit."""

    frac_bits: int = 0        # f: fractional bits of the Δ grid (paper: 0)
    coarse_entries: int = 7   # paper: 7 (e^{-8*6} already ~0 in Q1.15)
    lut_value_bits: int = 15  # Q1.15 LUT entries (paper: fixed-point 16)
    delta_scale: float = 1.0  # s0: logit units per integer step (quant scale)

    @property
    def residual_entries(self) -> int:
        return RADIX * (1 << self.frac_bits)

    @property
    def step(self) -> float:
        """Δ units represented by one integer step."""
        return self.delta_scale / (1 << self.frac_bits)

    @property
    def max_delta_int(self) -> int:
        """Largest representable Δ index (saturation point)."""
        return self.coarse_entries * self.residual_entries - 1


PAPER_SOFTMAX_LUT = SoftmaxLUTConfig(frac_bits=0)
TPU_SOFTMAX_LUT = SoftmaxLUTConfig(frac_bits=3)


@functools.lru_cache(maxsize=32)
def exp_luts(cfg: SoftmaxLUTConfig) -> tuple[np.ndarray, np.ndarray]:
    """Build (coarse, residual) LUTs as float32 (already fixed-point-rounded).

    Entries are rounded to ``lut_value_bits`` fractional bits, exactly what the
    ROM would store, then returned as float for use in either the float
    datapath or (times 2^bits) the integer datapath.
    """
    scale = float(1 << cfg.lut_value_bits)
    k = np.arange(cfg.coarse_entries, dtype=np.float64)
    # Coarse stride in Δ units is RADIX * step * 2^f == RADIX * delta_scale.
    coarse = np.exp(-float(RADIX) * cfg.delta_scale * k)
    j = np.arange(cfg.residual_entries, dtype=np.float64)
    residual = np.exp(-j * cfg.step)
    coarse_q = np.round(coarse * scale) / scale
    residual_q = np.round(residual * scale) / scale
    return coarse_q.astype(np.float32), residual_q.astype(np.float32)


def exp_luts_int(cfg: SoftmaxLUTConfig) -> tuple[np.ndarray, np.ndarray]:
    """Integer (Q1.f) LUT entries for the bit-accurate hw-sim datapath."""
    coarse, residual = exp_luts(cfg)
    scale = float(1 << cfg.lut_value_bits)
    return (
        np.round(coarse * scale).astype(np.int32),
        np.round(residual * scale).astype(np.int32),
    )


# --- CoRN-LN rsqrt mantissa LUT ----------------------------------------------

@dataclasses.dataclass(frozen=True)
class RsqrtConfig:
    """LOD + mantissa-LUT initial guess, then ``iters`` NR-rsqrt steps."""

    mantissa_bits: int = 5   # 32-entry compressed LUT (64 bytes of ROM)
    iters: int = 2           # paper: 2-cycle Newton
    lut_value_bits: int = 16


# 2 Newton cycles from a 32-entry mantissa LUT leave |1-sigma| < 2e-8 —
# matching the paper's "100% of LN errors below 0.2e-6" (Fig. 5).
PAPER_RSQRT = RsqrtConfig(mantissa_bits=5, iters=2)


@functools.lru_cache(maxsize=32)
def rsqrt_mantissa_lut(cfg: RsqrtConfig) -> np.ndarray:
    """LUT[i] ~= 1/sqrt(m) for mantissa bucket m in [1 + i/2^b, 1 + (i+1)/2^b).

    Entry is evaluated at the bucket midpoint and rounded to the LUT's
    fixed-point precision — this is the compressed CoRN table.
    """
    n = 1 << cfg.mantissa_bits
    i = np.arange(n, dtype=np.float64)
    mid = 1.0 + (i + 0.5) / n
    vals = 1.0 / np.sqrt(mid)
    scale = float(1 << cfg.lut_value_bits)
    return (np.round(vals * scale) / scale).astype(np.float32)


# sqrt(1/2) constant for odd-exponent correction, fixed-point rounded.
INV_SQRT2 = float(np.round((2.0 ** -0.5) * (1 << 16)) / (1 << 16))
