"""Public dispatch API: pick softmax/norm implementations by name.

Models take ``softmax_impl`` / ``norm_impl`` strings in their config, so the
paper's technique (and every baseline) is a first-class configuration axis.
"""
from __future__ import annotations

from typing import Callable

from repro.core import baselines
from repro.core.gn_layernorm import (
    exact_layernorm,
    exact_rmsnorm,
    gn_layernorm,
    gn_layernorm_hwsim,
    gn_rmsnorm,
)
from repro.core.gn_softmax import exact_softmax, gn_softmax, gn_softmax_hwsim


def get_softmax(name: str) -> Callable:
    table = {
        "exact": exact_softmax,
        "gn": gn_softmax,
        "gn_hwsim": gn_softmax_hwsim,
        "softermax": baselines.softermax,
        "pseudo": baselines.pseudo_softmax,
        "log_domain": baselines.log_domain_softmax,
    }
    if name not in table:
        raise KeyError(f"unknown softmax impl {name!r}; have {sorted(table)}")
    return table[name]


def get_norm(name: str) -> Callable:
    """Norm fns with signature (x, gamma=None, beta=None) -> y."""
    table = {
        "exact_ln": exact_layernorm,
        "gn_ln": gn_layernorm,
        "gn_ln_hwsim": gn_layernorm_hwsim,
        "exact_rms": lambda x, gamma=None, beta=None: exact_rmsnorm(x, gamma),
        "gn_rms": lambda x, gamma=None, beta=None: gn_rmsnorm(x, gamma),
        "integer_ln": baselines.integer_layernorm,
        "lut_ln": baselines.lut_layernorm,
        "rmsnorm": lambda x, gamma=None, beta=None: baselines.rmsnorm(x, gamma),
    }
    if name not in table:
        raise KeyError(f"unknown norm impl {name!r}; have {sorted(table)}")
    return table[name]
