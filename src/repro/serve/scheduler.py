"""Request/Completion API + FCFS admission scheduler for continuous batching.

The scheduler is deliberately dumb and deterministic: requests are admitted
strictly in submission order, each as soon as (a) its arrival step has been
reached on the engine clock and (b) a KV-cache slot is free.  The engine
clock is the decode-step counter, so synthetic staggered-arrival workloads
replay bit-identically — the property every serving test here leans on.

Layering (see ROADMAP.md §Serving and docs/serving.md):  scheduler (this
file, admission *order*) -> kv_cache (slot/block KV residency, device
placement) -> engine (ContinuousEngine, the jit-once fused step).  Under a
device mesh the scheduler's contract is unchanged — FCFS decides *who* is
admitted next; the engine + pool decide *where* (least-loaded device's slot
range), so placement never reorders admissions.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import numpy as np


def pad_to_grid(tokens, grid: int) -> np.ndarray:
    """Right-pad a prompt to the next multiple of the chunk grid.

    This is the bucketing rule of the fused serving step: every prompt is
    quantized to the chunk grid at intake, so the engine's per-tick shape is
    always (num_slots, chunk) and one compilation covers every prompt-length
    mix.  Padding is bounded by grid-1 tokens and the pad tokens are never
    computed on — the fused step masks lanes >= the true remaining length
    (they neither enter the cache nor advance recurrent state).
    """
    t = np.asarray(tokens, np.int32).reshape(-1)
    if grid <= 1:
        return t
    rem = (-t.shape[0]) % grid
    return np.concatenate([t, np.zeros(rem, np.int32)]) if rem else t


@dataclasses.dataclass
class Request:
    """One generation request.  ``tokens`` is the prompt, shape (prompt_len,).

    ``extras`` carries per-request modality stubs without a batch dim
    (``frames`` for encdec, ``patches`` for vlm); the engine adds the batch
    axis at prefill.  ``arrival_step`` stamps when the request becomes
    visible on the engine's decode-step clock (0 = already waiting).
    ``padded_tokens`` is stamped by a chunk-gridded scheduler at submit
    (see ``pad_to_grid``); engines fall back to padding at admission when
    it is absent or on a different grid.
    """

    tokens: np.ndarray
    max_new_tokens: int = 16
    temperature: Optional[float] = None  # None -> engine default
    stop_token: Optional[int] = None
    arrival_step: int = 0
    extras: dict = dataclasses.field(default_factory=dict)
    id: int = -1  # assigned by the scheduler on submit
    padded_tokens: Optional[np.ndarray] = dataclasses.field(default=None, repr=False)
    # submit-time prefix-cache hint: how many leading prompt tokens were
    # already indexed when the request entered the queue (telemetry only —
    # admission re-runs the authoritative lookup against the cache state at
    # admit time, which later finishes/evictions will have changed)
    prefix_hint: int = 0

    @property
    def prompt_len(self) -> int:
        return int(np.asarray(self.tokens).shape[-1])


@dataclasses.dataclass
class Completion:
    """A finished request plus its serving telemetry (steps = engine clock)."""

    request_id: int
    prompt_tokens: np.ndarray
    new_tokens: np.ndarray
    finish_reason: str  # 'length' | 'stop'
    arrival_step: int
    admit_step: int
    first_token_step: int
    finish_step: int
    admit_time: float
    first_token_time: float
    finish_time: float

    @property
    def tokens(self) -> np.ndarray:
        """Full sequence (prompt + generated), the static-engine layout."""
        return np.concatenate([self.prompt_tokens, self.new_tokens])

    @property
    def ttft_s(self) -> float:
        return self.first_token_time - self.admit_time

    @property
    def latency_s(self) -> float:
        return self.finish_time - self.admit_time


class FCFSScheduler:
    """First-come-first-served admission.  The head of the queue blocks —
    a later-arriving short request never jumps an earlier long one, which
    keeps admission order (and therefore slot assignment) deterministic.

    With ``chunk_grid`` > 0 the scheduler buckets waiting prompts to the
    fused step's chunk grid at submit (``pad_to_grid``): intake padding is
    bounded by grid-1 tokens per request and the engine's per-tick shape is
    independent of the prompt-length mix, so the fused step compiles once.

    With a ``prefix_cache`` bound (the engine passes its own), submit stamps
    each queued request's ``prefix_hint`` — the indexed prefix length at
    submit time, via the stamp-free ``match_len`` so queue traffic never
    perturbs LRU order.  The hint is telemetry (demos print it; operators
    see sharing potential at intake); admission re-runs the authoritative
    lookup, since the cache keeps changing while the request waits.
    """

    def __init__(self, chunk_grid: int = 0, prefix_cache=None):
        self.chunk_grid = int(chunk_grid)
        self.prefix_cache = prefix_cache
        self._queue: deque[Request] = deque()
        self._next_id = 0
        self._pad_tokens = 0  # total intake padding (bucketing overhead)

    def submit(self, req: Request) -> int:
        """Enqueue a *copy* of ``req`` and return its id.

        Submit is side-effect-free on the caller's object: id assignment and
        chunk-grid bucketing land on the queued copy only.  (The old in-place
        mutation meant re-submitting one workload list across the static
        oracle, engine resets and bench reps carried hidden state — and a
        stale ``padded_tokens`` from a different chunk grid was only caught
        by the ``% chunk`` fallback in the engine's admission path.)
        """
        if req.max_new_tokens < 1:
            raise ValueError(
                f"request needs max_new_tokens >= 1, got {req.max_new_tokens} "
                "(the engine always decodes at least one token per admission)"
            )
        rid = req.id if req.id >= 0 else self._next_id
        self._next_id = max(self._next_id, rid) + 1
        queued = dataclasses.replace(req, id=rid)
        # never trust a padded_tokens stamped by some other scheduler's grid
        queued.padded_tokens = (
            pad_to_grid(queued.tokens, self.chunk_grid) if self.chunk_grid else None
        )
        if self.chunk_grid:
            self._pad_tokens += int(queued.padded_tokens.shape[0]) - queued.prompt_len
        if self.prefix_cache is not None:
            queued.prefix_hint = self.prefix_cache.match_len(queued.tokens)
        self._queue.append(queued)
        return rid

    @property
    def intake_padding(self) -> int:
        """Total pad tokens added by bucketing (<= (grid-1) per request)."""
        return self._pad_tokens

    def peek_ready(self, step: int) -> Optional[Request]:
        """Head of the queue if it has arrived by engine step ``step``,
        without popping — admission checks resources (free blocks) first."""
        if self._queue and self._queue[0].arrival_step <= step:
            return self._queue[0]
        return None

    def pop_ready(self, step: int) -> Optional[Request]:
        """Head of the queue if it has arrived by engine step ``step``."""
        if self._queue and self._queue[0].arrival_step <= step:
            return self._queue.popleft()
        return None

    def has_pending(self) -> bool:
        return bool(self._queue)

    def __len__(self) -> int:
        return len(self._queue)
