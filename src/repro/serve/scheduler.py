"""Request/Completion API + FCFS admission scheduler for continuous batching.

The scheduler is deliberately dumb and deterministic: requests are admitted
strictly in submission order, each as soon as (a) its arrival step has been
reached on the engine clock and (b) a KV-cache slot is free.  The engine
clock is the decode-step counter, so synthetic staggered-arrival workloads
replay bit-identically — the property every serving test here leans on.

Layering (see ROADMAP.md §Serving and docs/serving.md):  scheduler (this
file, admission *order*) -> kv_cache (slot/block KV residency, device
placement) -> engine (ContinuousEngine, the jit-once fused step).  Under a
device mesh the scheduler's contract is unchanged — the scheduler decides
*who* is admitted next; the engine + pool decide *where* (least-loaded
device's slot range), so placement never reorders admissions.

``PriorityScheduler`` adds the SLA control plane on the same deterministic
clock: class-aware admission (interactive over batch), an aging bound so
batch traffic cannot starve, and watermark-based shedding of batch
backlog under overload.  Its rank rule is deliberately step-independent
(``interactive h outranks batch b  iff  h.arrival_step < b.arrival_step +
aging_steps``) so the engine can reuse the *same* rule for preemption
victim eligibility without admit/preempt livelock: the relative order of
two requests never changes as the clock advances.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import numpy as np

# request classes the SLA control plane understands.  'interactive' is the
# latency-sensitive class (chat turns: short budgets, TTFT-judged);
# 'batch' is throughput traffic (eval/summarization sweeps: long budgets,
# preemptible, sheddable under overload).
REQUEST_CLASSES = ("interactive", "batch")

# closed set of completion verdicts.  'length' = decode budget exhausted,
# 'stop' = stop token hit, 'rejected' = shed before admission, 'failed' =
# fault-recovery retry budget exhausted (the request's fault record is in
# the engine event log).  Validated at Completion construction so a typo'd
# or novel reason fails at the producer, never silently at a consumer.
FINISH_REASONS = ("length", "stop", "rejected", "failed")


def pad_to_grid(tokens, grid: int) -> np.ndarray:
    """Right-pad a prompt to the next multiple of the chunk grid.

    This is the bucketing rule of the fused serving step: every prompt is
    quantized to the chunk grid at intake, so the engine's per-tick shape is
    always (num_slots, chunk) and one compilation covers every prompt-length
    mix.  Padding is bounded by grid-1 tokens and the pad tokens are never
    computed on — the fused step masks lanes >= the true remaining length
    (they neither enter the cache nor advance recurrent state).
    """
    t = np.asarray(tokens, np.int32).reshape(-1)
    if grid <= 1:
        return t
    rem = (-t.shape[0]) % grid
    return np.concatenate([t, np.zeros(rem, np.int32)]) if rem else t


@dataclasses.dataclass
class Request:
    """One generation request.  ``tokens`` is the prompt, shape (prompt_len,).

    ``extras`` carries per-request modality stubs without a batch dim
    (``frames`` for encdec, ``patches`` for vlm); the engine adds the batch
    axis at prefill.  ``arrival_step`` stamps when the request becomes
    visible on the engine's decode-step clock (0 = already waiting).
    ``padded_tokens`` is stamped by a chunk-gridded scheduler at submit
    (see ``pad_to_grid``); engines fall back to padding at admission when
    it is absent or on a different grid.
    """

    tokens: np.ndarray
    max_new_tokens: int = 16
    temperature: Optional[float] = None  # None -> engine default
    stop_token: Optional[int] = None
    arrival_step: int = 0
    extras: dict = dataclasses.field(default_factory=dict)
    id: int = -1  # assigned by the scheduler on submit
    padded_tokens: Optional[np.ndarray] = dataclasses.field(default=None, repr=False)
    # submit-time prefix-cache hint: how many leading prompt tokens were
    # already indexed when the request entered the queue (telemetry only —
    # admission re-runs the authoritative lookup against the cache state at
    # admit time, which later finishes/evictions will have changed)
    prefix_hint: int = 0
    # SLA class ('interactive' | 'batch').  FCFS ignores it; the
    # PriorityScheduler ranks on it and the engine's preemption/shedding
    # paths only ever target 'batch' requests.
    req_class: str = "interactive"

    @property
    def prompt_len(self) -> int:
        return int(np.asarray(self.tokens).shape[-1])


@dataclasses.dataclass
class Completion:
    """A finished request plus its serving telemetry (steps = engine clock)."""

    request_id: int
    prompt_tokens: np.ndarray
    new_tokens: np.ndarray
    finish_reason: str  # one of FINISH_REASONS
    arrival_step: int
    admit_step: int  # -1 when rejected (never admitted)
    first_token_step: int  # -1 when rejected
    finish_step: int
    admit_time: float
    first_token_time: float
    finish_time: float
    req_class: str = "interactive"
    preemptions: int = 0  # times this request was evicted and later resumed

    def __post_init__(self):
        if self.finish_reason not in FINISH_REASONS:
            raise ValueError(
                f"unknown finish_reason {self.finish_reason!r}; expected one "
                f"of {FINISH_REASONS}"
            )

    @property
    def tokens(self) -> np.ndarray:
        """Full sequence (prompt + generated), the static-engine layout."""
        return np.concatenate([self.prompt_tokens, self.new_tokens])

    @property
    def ttft_s(self) -> float:
        return self.first_token_time - self.admit_time

    @property
    def latency_s(self) -> float:
        return self.finish_time - self.admit_time

    # --- arrival-anchored step-clock SLA fields -------------------------
    # ttft_s above measures from *admit*, which hides queue wait entirely —
    # exactly the quantity an overloaded system lies about.  The step-clock
    # fields anchor on arrival_step and are deterministic under replay
    # (wall-clock fields are kept as-is for compatibility).

    @property
    def queue_wait_steps(self) -> int:
        """Engine steps spent waiting for admission (arrival -> admit)."""
        if self.admit_step < 0:
            return self.finish_step - self.arrival_step  # rejected: wait-to-verdict
        return self.admit_step - self.arrival_step

    @property
    def ttft_steps(self) -> int:
        """Arrival -> first generated token, on the engine step clock.
        -1 for rejected requests (no token was ever produced)."""
        if self.first_token_step < 0:
            return -1
        return self.first_token_step - self.arrival_step

    @property
    def tpot_steps(self) -> float:
        """Mean steps per generated token after the first (first token ->
        finish).  Exactly 1.0 for an uninterrupted decode; preemption gaps
        and re-prefill ticks inflate it.  0.0 when < 2 tokens."""
        n = int(np.asarray(self.new_tokens).shape[0])
        if n < 2:
            return 0.0
        return (self.finish_step - self.first_token_step) / (n - 1)


class FCFSScheduler:
    """First-come-first-served admission.  The head of the queue blocks —
    a later-arriving short request never jumps an earlier long one, which
    keeps admission order (and therefore slot assignment) deterministic.

    With ``chunk_grid`` > 0 the scheduler buckets waiting prompts to the
    fused step's chunk grid at submit (``pad_to_grid``): intake padding is
    bounded by grid-1 tokens per request and the engine's per-tick shape is
    independent of the prompt-length mix, so the fused step compiles once.

    With a ``prefix_cache`` bound (the engine passes its own), submit stamps
    each queued request's ``prefix_hint`` — the indexed prefix length at
    submit time, via the stamp-free ``match_len`` so queue traffic never
    perturbs LRU order.  The hint is telemetry (demos print it; operators
    see sharing potential at intake); admission re-runs the authoritative
    lookup, since the cache keeps changing while the request waits.
    """

    def __init__(self, chunk_grid: int = 0, prefix_cache=None):
        self.chunk_grid = int(chunk_grid)
        self.prefix_cache = prefix_cache
        self._queue: deque[Request] = deque()
        self._next_id = 0
        self._pad_tokens = 0  # total intake padding (bucketing overhead)

    def submit(self, req: Request) -> int:
        """Enqueue a *copy* of ``req`` and return its id.

        Submit is side-effect-free on the caller's object: id assignment and
        chunk-grid bucketing land on the queued copy only.  (The old in-place
        mutation meant re-submitting one workload list across the static
        oracle, engine resets and bench reps carried hidden state — and a
        stale ``padded_tokens`` from a different chunk grid was only caught
        by the ``% chunk`` fallback in the engine's admission path.)
        """
        queued = self._prepare(req)
        self._enqueue(queued)
        return queued.id

    def _prepare(self, req: Request) -> Request:
        """Validate + copy + bucket a submission (shared by all policies)."""
        if req.max_new_tokens < 1:
            raise ValueError(
                f"request needs max_new_tokens >= 1, got {req.max_new_tokens} "
                "(the engine always decodes at least one token per admission)"
            )
        if req.req_class not in REQUEST_CLASSES:
            raise ValueError(
                f"unknown req_class {req.req_class!r}; expected one of "
                f"{REQUEST_CLASSES}"
            )
        rid = req.id if req.id >= 0 else self._next_id
        self._next_id = max(self._next_id, rid) + 1
        queued = dataclasses.replace(req, id=rid)
        # never trust a padded_tokens stamped by some other scheduler's grid
        queued.padded_tokens = (
            pad_to_grid(queued.tokens, self.chunk_grid) if self.chunk_grid else None
        )
        if self.chunk_grid:
            self._pad_tokens += int(queued.padded_tokens.shape[0]) - queued.prompt_len
        if self.prefix_cache is not None:
            queued.prefix_hint = self.prefix_cache.match_len(queued.tokens)
        return queued

    def _enqueue(self, queued: Request) -> None:
        self._queue.append(queued)

    def requeue_front(self, req: Request) -> None:
        """Put an already-prepared request back at the head of its queue.

        The engine's preemption path uses this: the victim keeps its id,
        padding and arrival_step (its place in time), and is the next
        candidate of its class — so a preempted request is never overtaken
        by a later submission of the same class, which is what makes the
        preemption trace replay-deterministic.
        """
        self._queue.appendleft(req)

    def next_ready_step(self) -> Optional[int]:
        """Earliest arrival_step over all queued requests, or None if empty.

        The engine's idle fast-forward jumps its step clock here when no
        slot is live: nothing observable can happen on the skipped ticks
        (no arrivals, no admissions, no decodes), so the event trace is
        identical to burning them one by one.
        """
        if not self._queue:
            return None
        # FCFS is head-blocking: nothing is admissible before the head
        # arrives, even if a later submission has an earlier arrival_step.
        return self._queue[0].arrival_step

    def poll_shed(self, step: int, live_units: int, unit_fn) -> list[Request]:
        """Overload shedding hook, called by the engine each admission pass.
        FCFS never sheds; the PriorityScheduler implements the watermark."""
        return []

    @property
    def intake_padding(self) -> int:
        """Total pad tokens added by bucketing (<= (grid-1) per request)."""
        return self._pad_tokens

    def peek_ready(self, step: int) -> Optional[Request]:
        """Head of the queue if it has arrived by engine step ``step``,
        without popping — admission checks resources (free blocks) first."""
        if self._queue and self._queue[0].arrival_step <= step:
            return self._queue[0]
        return None

    def pop_ready(self, step: int) -> Optional[Request]:
        """Head of the queue if it has arrived by engine step ``step``."""
        if self._queue and self._queue[0].arrival_step <= step:
            return self._queue.popleft()
        return None

    def has_pending(self) -> bool:
        return bool(self._queue)

    def __len__(self) -> int:
        return len(self._queue)


class PriorityScheduler(FCFSScheduler):
    """Class-aware admission with an aging bound and overload shedding.

    Two FCFS queues, one per request class.  When both heads have arrived,
    the *rank rule* picks the winner:

        interactive head h outranks batch head b
            iff  h.arrival_step < b.arrival_step + aging_steps

    i.e. interactive goes first unless the batch head has already waited
    ``aging_steps`` longer than the interactive head has existed — the
    starvation bound: every interactive request admitted before a given
    batch request arrived strictly less than ``aging_steps`` after it.
    The rule compares only arrival steps (never the current clock), so the
    relative order of two requests is a constant of the run.  The engine
    uses the *same* rule to decide which live batch slots an interactive
    head may preempt; sharing one total order is what rules out the
    admit/preempt livelock (preempt a victim, victim re-queues, victim
    outranks the head, victim re-admits, preempt again, ...).

    Shedding (``shed_backlog`` > 0, units = blocks under a paged pool,
    slots under a slab pool): each admission pass the engine reports the
    live reservation and a per-request footprint function; arrived batch
    backlog beyond the watermark is rejected (``finish_reason='rejected'``)
    head-ordered, so the survivor set is deterministic.  Interactive
    requests and preempted-then-requeued requests are never shed — a
    request the engine already spent prefill on is always allowed back.
    """

    def __init__(self, chunk_grid: int = 0, prefix_cache=None,
                 aging_steps: int = 64, shed_backlog: int = 0):
        super().__init__(chunk_grid, prefix_cache)
        if aging_steps < 1:
            raise ValueError(f"aging_steps must be >= 1, got {aging_steps}")
        self.aging_steps = int(aging_steps)
        self.shed_backlog = int(shed_backlog)
        self._queues: dict[str, deque[Request]] = {
            c: deque() for c in REQUEST_CLASSES
        }
        self._resumed: set[int] = set()  # ids requeued by preemption
        self.shed_count = 0

    def _enqueue(self, queued: Request) -> None:
        self._queues[queued.req_class].append(queued)

    def requeue_front(self, req: Request) -> None:
        self._resumed.add(req.id)
        self._queues[req.req_class].appendleft(req)

    def outranks(self, interactive_arrival: int, batch_arrival: int) -> bool:
        """The step-independent rank rule (see class docstring)."""
        return interactive_arrival < batch_arrival + self.aging_steps

    def _pick_class(self, step: int) -> Optional[str]:
        heads = {}
        for c in REQUEST_CLASSES:
            q = self._queues[c]
            if q and q[0].arrival_step <= step:
                heads[c] = q[0]
        if len(heads) == 2:
            i, b = heads["interactive"], heads["batch"]
            return ("interactive"
                    if self.outranks(i.arrival_step, b.arrival_step)
                    else "batch")
        return next(iter(heads), None)

    def peek_ready(self, step: int) -> Optional[Request]:
        c = self._pick_class(step)
        return self._queues[c][0] if c else None

    def pop_ready(self, step: int) -> Optional[Request]:
        c = self._pick_class(step)
        if c is None:
            return None
        req = self._queues[c].popleft()
        self._resumed.discard(req.id)
        return req

    def has_pending(self) -> bool:
        return any(self._queues.values())

    def __len__(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def next_ready_step(self) -> Optional[int]:
        # both class heads are admissible candidates, so the next
        # observable event is the earlier of the two head arrivals
        heads = [q[0].arrival_step for q in self._queues.values() if q]
        return min(heads) if heads else None

    def poll_shed(self, step: int, live_units: int, unit_fn) -> list[Request]:
        """Shed arrived batch backlog beyond the watermark.

        ``live_units`` is the engine's current reservation (blocks in
        reserve under paging, occupied slots under slab); ``unit_fn(req)``
        prices a queued request in the same units.  Demand accumulates
        head-ordered: live + arrived interactive + arrived batch in queue
        order; the first batch request that pushes demand past
        ``shed_backlog`` is shed, as is every arrived batch request after
        it that would too.  Scanning stops at the first not-yet-arrived
        request per queue (so idle fast-forward stays sound: a skipped
        tick can never have shed anything).
        """
        if self.shed_backlog <= 0:
            return []
        demand = live_units
        for r in self._queues["interactive"]:
            if r.arrival_step > step:
                break
            demand += unit_fn(r)
        kept: deque[Request] = deque()
        shed: list[Request] = []
        arrived_zone = True
        for r in self._queues["batch"]:
            if arrived_zone and r.arrival_step > step:
                arrived_zone = False
            if not arrived_zone:
                kept.append(r)
                continue
            need = unit_fn(r)
            if r.id in self._resumed:
                # preempted work is admitted debt, never shed
                demand += need
                kept.append(r)
            elif demand + need > self.shed_backlog:
                shed.append(r)
                self.shed_count += 1
            else:
                demand += need
                kept.append(r)
        self._queues["batch"] = kept
        return shed
