"""Batched serving engine: prefill the prompt batch, then greedy/temperature
decode with the per-family KV/state caches from models/transformer.py.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.transformer import Model


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0  # 0 = greedy
    seed: int = 0


def generate(model: Model, params, batch: dict, cfg: ServeConfig):
    """batch['tokens']: (B, S_prompt) -> (B, S_prompt + max_new) tokens.

    Prefill once, then `max_new_tokens` decode steps under jit (the decode
    step is compiled once; positions are traced scalars).
    """
    tokens = batch["tokens"]
    b, s = tokens.shape
    max_seq = s + cfg.max_new_tokens

    prefill = jax.jit(lambda p, bt: model.prefill(p, bt, max_seq))
    logits, cache = prefill(params, batch)
    decode = jax.jit(model.decode_step)

    key = jax.random.PRNGKey(cfg.seed)
    last_logits = logits[:, -1]
    out = tokens

    for i in range(cfg.max_new_tokens):
        if cfg.temperature > 0:
            key, sub = jax.random.split(key)
            nxt = jax.random.categorical(sub, last_logits / cfg.temperature, axis=-1)
        else:
            nxt = jnp.argmax(last_logits, axis=-1)
        nxt = nxt[:, None].astype(jnp.int32)
        out = jnp.concatenate([out, nxt], axis=1)
        logits_step, cache = decode(params, cache, nxt, jnp.int32(s + i))
        last_logits = logits_step[:, 0]
    return out


def perplexity(model: Model, params, batch: dict) -> float:
    """Teacher-forced perplexity over a token batch (score-oriented metric)."""
    logits, _ = jax.jit(model.forward)(params, batch)
    logits = logits[:, :-1].astype(jnp.float32)
    targets = batch["tokens"][:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return float(jnp.exp(jnp.mean(nll)))
