"""Serving engines for the GN non-GEMM datapath.

Two paths share the per-family caches from ``models/transformer.py``:

* ``generate`` — the original *static* batch engine (every request in the
  batch shares a prompt length, everyone decodes to ``max_new_tokens``).
  It stays as the correctness oracle: greedy continuous batching must be
  token-identical to it.  Decode writes into a preallocated output buffer
  (O(n) — the old per-token ``jnp.concatenate`` re-copied the whole buffer
  every step).

* ``ContinuousEngine`` — continuous batching over a ``SlotKVPool``.  The
  decode step is jitted ONCE over the fixed slot set: per-slot positions,
  per-slot temperatures and an active mask are traced arrays, so requests
  joining and leaving never trigger recompilation.  Prefill compiles per
  distinct prompt length (shape-polymorphic prompts are outside jit's
  vocabulary); the decode loop is where continuous batching lives.

Layering: scheduler (admission) -> kv_cache (slot residency) -> engine
(this file: sampling, stop conditions, metrics).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import Model
from repro.serve.kv_cache import SlotKVPool
from repro.serve.scheduler import Completion, FCFSScheduler, Request


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0  # 0 = greedy
    seed: int = 0


# ---------------------------------------------------------------- static ---
def _static_jits(model: Model, max_seq: int):
    """Per-model cache of the static path's jitted prefill/decode, so repeated
    ``generate`` calls (benchmarks, the static oracle) don't re-trace."""
    cache = model.__dict__.setdefault("_serve_jits", {})
    if "decode" not in cache:
        cache["decode"] = jax.jit(model.decode_step)
    key = ("prefill", max_seq)
    if key not in cache:
        cache[key] = jax.jit(lambda p, bt: model.prefill(p, bt, max_seq))
    return cache[key], cache["decode"]


def generate(model: Model, params, batch: dict, cfg: ServeConfig):
    """batch['tokens']: (B, S_prompt) -> (B, S_prompt + max_new) tokens.

    Prefill once, then ``max_new_tokens`` decode steps under jit (the decode
    step is compiled once; positions are traced scalars).
    """
    tokens = batch["tokens"]
    b, s = tokens.shape
    max_seq = s + cfg.max_new_tokens

    prefill, decode = _static_jits(model, max_seq)
    logits, cache = prefill(params, batch)

    key = jax.random.PRNGKey(cfg.seed)
    last_logits = logits[:, -1]
    out = jnp.zeros((b, max_seq), jnp.int32)
    out = jax.lax.dynamic_update_slice(out, tokens.astype(jnp.int32), (0, 0))

    for i in range(cfg.max_new_tokens):
        if cfg.temperature > 0:
            key, sub = jax.random.split(key)
            nxt = jax.random.categorical(sub, last_logits / cfg.temperature, axis=-1)
        else:
            nxt = jnp.argmax(last_logits, axis=-1)
        nxt = nxt[:, None].astype(jnp.int32)
        out = jax.lax.dynamic_update_slice(out, nxt, (0, s + i))
        logits_step, cache = decode(params, cache, nxt, jnp.int32(s + i))
        last_logits = logits_step[:, 0]
    return out


def perplexity(model: Model, params, batch: dict) -> float:
    """Teacher-forced perplexity over a token batch (score-oriented metric)."""
    logits, _ = jax.jit(model.forward)(params, batch)
    logits = logits[:, :-1].astype(jnp.float32)
    targets = batch["tokens"][:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return float(jnp.exp(jnp.mean(nll)))


def static_reference(model: Model, params, requests: Sequence[Request],
                     cfg: ServeConfig) -> dict[int, np.ndarray]:
    """Serve ``requests`` through the static engine: group by (prompt_len,
    max_new_tokens) in FCFS order, one ``generate`` call per group.  Returns
    request id -> full (prompt + generated) token array, truncated at a
    request's stop token if it has one (the static engine itself always
    decodes the full budget).  This is both the greedy-identity oracle and
    the static baseline in benchmarks — greedy only, since sampled paths use
    different key streams per engine."""
    if any(r.temperature not in (None, 0, 0.0) for r in requests) or cfg.temperature:
        raise ValueError("static_reference is a greedy oracle (temperature 0 only)")
    groups: dict[tuple, list[Request]] = {}
    for req in requests:
        groups.setdefault((req.prompt_len, req.max_new_tokens), []).append(req)
    out: dict[int, np.ndarray] = {}
    for (plen, max_new), reqs in groups.items():
        batch = {"tokens": jnp.stack([jnp.asarray(r.tokens, jnp.int32) for r in reqs])}
        for k in reqs[0].extras:
            batch[k] = jnp.stack([jnp.asarray(r.extras[k]) for r in reqs])
        gcfg = dataclasses.replace(cfg, max_new_tokens=max_new)
        toks = np.asarray(generate(model, params, batch, gcfg))
        for r, row in zip(reqs, toks):
            if r.stop_token is not None:
                hits = np.nonzero(row[plen:] == r.stop_token)[0]
                if hits.size:
                    row = row[: plen + hits[0] + 1]
            out[r.id] = row
    return out


# ------------------------------------------------------------ continuous ---
@dataclasses.dataclass
class _SlotState:
    req: Request
    admit_step: int
    admit_time: float
    generated: list
    first_token_step: int = -1
    first_token_time: float = 0.0


class ContinuousEngine:
    """Continuous-batching engine over a fixed slot set.

    Per engine tick: admit waiting requests into free slots (prefill + slot
    page-in), then run ONE masked decode over all ``num_slots`` slots —
    inactive slots compute dont-care lanes that are never committed (their
    cache is fully overwritten at the next admission).  Greedy outputs are
    token-identical to the static ``generate`` path.
    """

    def __init__(self, model: Model, params, num_slots: int, max_seq: int,
                 cfg: ServeConfig = ServeConfig(),
                 scheduler: Optional[FCFSScheduler] = None):
        self.model, self.params, self.cfg = model, params, cfg
        self.num_slots, self.max_seq = int(num_slots), int(max_seq)
        self.pool = SlotKVPool(model, num_slots, max_seq)

        self._prefill = jax.jit(lambda p, b: model.prefill(p, b, self.max_seq))
        self._decode = jax.jit(self._decode_sample)
        self._set_row = jax.jit(
            lambda buf, row, i: jax.lax.dynamic_update_slice(
                buf, row[None].astype(buf.dtype), (i, 0)
            )
        )
        self.reset(scheduler)

    def reset(self, scheduler: Optional[FCFSScheduler] = None) -> None:
        """Clear all serving state but keep compiled functions and the pool
        allocation (benchmarks re-run the same workload without recompiling).
        The pool's slot order is restored too, so a reset run replays a
        workload with identical slot assignment (and, for sampled requests,
        identical per-slot key streams)."""
        self.pool.reset()
        vocab = self.model.cfg.vocab
        # device-resident held logits; positions live host-side in the pool
        # (single source of truth), active/temps derive from _slots at step
        self._last_logits = jnp.zeros((self.num_slots, vocab), jnp.float32)
        self._temps = np.zeros(self.num_slots, np.float32)
        self._slots: list[Optional[_SlotState]] = [None] * self.num_slots
        self._key = jax.random.PRNGKey(self.cfg.seed)
        self.step_count = 0
        self.completions: list[Completion] = []
        self._active_steps = 0   # sum over decode steps of active-slot count
        self._decode_steps = 0
        self._generated = 0
        self.scheduler = scheduler or FCFSScheduler()

    # ---------------------------------------------------------- jitted step --
    def _decode_sample(self, params, cache, last_logits, positions, active,
                       temps, key):
        """Sample one token per slot from the held logits, then decode it.
        Everything per-slot is a traced array -> a single compilation."""
        greedy = jnp.argmax(last_logits, axis=-1)
        tsafe = jnp.where(temps > 0, temps, 1.0)
        keys = jax.random.split(key, self.num_slots)
        sampled = jax.vmap(jax.random.categorical)(keys, last_logits / tsafe[:, None])
        nxt = jnp.where(temps > 0, sampled, greedy).astype(jnp.int32)
        nxt = jnp.where(active, nxt, 0)
        pos = jnp.where(active, positions, 0)  # clamp dont-care lanes in range
        logits, ncache = self.model.decode_step_slots(params, cache, nxt[:, None], pos)
        new_last = jnp.where(
            active[:, None], logits[:, 0].astype(jnp.float32), last_logits
        )
        return nxt, new_last, ncache

    # ------------------------------------------------------------ admission --
    def submit(self, req: Request) -> int:
        return self.scheduler.submit(req)

    def _admit(self) -> list[int]:
        admitted = []
        while self.pool.num_free:
            req = self.scheduler.pop_ready(self.step_count)
            if req is None:
                break
            if req.prompt_len + req.max_new_tokens > self.max_seq:
                raise ValueError(
                    f"request {req.id}: prompt {req.prompt_len} + "
                    f"{req.max_new_tokens} new tokens exceeds max_seq {self.max_seq}"
                )
            slot = self.pool.allocate()
            batch = {"tokens": jnp.asarray(req.tokens, jnp.int32)[None]}
            for k, v in req.extras.items():
                batch[k] = jnp.asarray(v)[None]
            logits, cache = self._prefill(self.params, batch)
            self.pool.insert(cache, slot, req.prompt_len)
            self._last_logits = self._set_row(self._last_logits, logits[0, -1], slot)
            temp = self.cfg.temperature if req.temperature is None else req.temperature
            self._temps[slot] = float(temp)
            self._slots[slot] = _SlotState(
                req=req, admit_step=self.step_count,
                admit_time=time.time(), generated=[],
            )
            admitted.append(req.id)
        return admitted

    def _finish(self, slot: int, reason: str) -> None:
        st = self._slots[slot]
        now = time.time()
        self.completions.append(Completion(
            request_id=st.req.id,
            prompt_tokens=np.asarray(st.req.tokens, np.int32),
            new_tokens=np.asarray(st.generated, np.int32),
            finish_reason=reason,
            arrival_step=st.req.arrival_step,
            admit_step=st.admit_step,
            first_token_step=st.first_token_step,
            finish_step=self.step_count,
            admit_time=st.admit_time,
            first_token_time=st.first_token_time,
            finish_time=now,
        ))
        self._slots[slot] = None
        self.pool.free(slot)

    # ----------------------------------------------------------- main loop --
    def step(self) -> bool:
        """One engine tick.  Returns False once fully drained (no active
        slot, nothing queued)."""
        self._admit()
        live = [s for s, st in enumerate(self._slots) if st is not None]
        if not live:
            if self.scheduler.has_pending():
                self.step_count += 1  # idle tick: waiting on a future arrival
                return True
            return False

        self._key, sub = jax.random.split(self._key)
        active = np.array([st is not None for st in self._slots])
        nxt, self._last_logits, self.pool.cache = self._decode(
            self.params, self.pool.cache, self._last_logits,
            self.pool.positions, active, self._temps, sub,
        )
        toks = np.asarray(nxt)
        self.pool.advance(live)
        self._active_steps += len(live)
        self._decode_steps += 1
        self._generated += len(live)
        for slot in live:
            st = self._slots[slot]
            tok = int(toks[slot])
            st.generated.append(tok)
            if len(st.generated) == 1:
                st.first_token_step = self.step_count
                st.first_token_time = time.time()
            reason = None
            if st.req.stop_token is not None and tok == st.req.stop_token:
                reason = "stop"
            elif len(st.generated) >= st.req.max_new_tokens:
                reason = "length"
            if reason:
                self._finish(slot, reason)
        self.step_count += 1
        return True

    def run(self, requests: Sequence[Request]) -> list[Completion]:
        """Serve a workload to completion; returns completions in finish
        order."""
        for req in requests:
            self.submit(req)
        budget = 10_000 + sum(r.arrival_step + r.max_new_tokens for r in requests)
        while self.step():
            if self.step_count > budget:
                raise RuntimeError("ContinuousEngine failed to drain workload")
        return self.completions

    # -------------------------------------------------------------- metrics --
    def metrics(self) -> dict:
        util = self._active_steps / max(1, self._decode_steps * self.num_slots)
        return {
            "decode_steps": self._decode_steps,
            "generated_tokens": self._generated,
            "mean_slot_utilization": util,
            "completions": len(self.completions),
            "decode_compilations": _jit_compilations(self._decode),
            "prefill_compilations": _jit_compilations(self._prefill),
        }


def _jit_compilations(fn) -> Optional[int]:
    """Compilation count of a jitted callable, or None if jax's (private)
    cache-size probe is unavailable on this version."""
    probe = getattr(fn, "_cache_size", None)
    return probe() if callable(probe) else None
