"""Serving engines for the GN non-GEMM datapath.

Two paths share the per-family caches from ``models/transformer.py``:

* ``generate`` — the original *static* batch engine (every request in the
  batch shares a prompt length, everyone decodes to ``max_new_tokens``).
  It stays as the correctness oracle: greedy continuous batching must be
  token-identical to it.  Decode writes into a preallocated output buffer
  (O(n) — the old per-token ``jnp.concatenate`` re-copied the whole buffer
  every step).

* ``ContinuousEngine`` — continuous batching with chunked prefill fused
  into the per-tick step.  Admission pages an empty slot in; the fused step
  (jitted ONCE over the fixed (num_slots, chunk) token budget) then drains
  the prompt chunk-by-chunk through otherwise-idle lanes while other slots
  keep decoding.  Per-slot positions, valid counts, phases, temperatures,
  the active mask — and, under paging, the block tables — are all traced
  arrays, so requests joining/leaving/prefilling never trigger
  recompilation, and there is no per-prompt-length prefill jit at all
  (prompts are bucketed to the chunk grid at intake, see
  serve/scheduler.pad_to_grid).

  KV residency is block-granular wherever the family's cache is pageable
  (``BlockPagedKVPool``: dense/moe/encdec/vlm full-attention KV, MLA
  latents — HBM scales with live tokens, admission gates on free blocks);
  SSM/hybrid carries and sliding-window rings keep the slot-monolithic
  ``SlotKVPool``.  Paged reads are gather-free and *horizon-bucketed*:
  each tick slices the traced block tables to the smallest power-of-two
  bucket covering the live block horizon, so attention work scales with
  live context while compile counts stay pinned to one trace per (step
  kind, bucket) — see docs/serving.md §Paged read paths.

  ``devices=N`` shards the slot pool over an N-device mesh along the
  slot/batch axis (slot-axis NamedSharding from parallel/sharding.py's
  rules; the GN guarantees are layout-independent, so per-device slot
  shards change placement, never values): both compile-once jits run SPMD,
  admission places the FCFS head on the least-loaded device's slot range,
  and ``metrics()`` reports num_devices / per_device_slots / shard_balance.
  ``devices=1`` (default) builds no mesh and is bit-identical to the
  single-device engine.

Layering: scheduler (admission + chunk-grid bucketing) -> kv_cache (slot/
block residency, block tables, device placement + per-device ranges,
offset-ranged positions) -> engine (this file: the fused step, sampling,
phase state machine, least-loaded placement, stop conditions, metrics).
See docs/serving.md for the full architecture and docs/benchmarks.md for
how ``metrics()`` feeds the BENCH_serve.json schema.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import store as ckpt_store
from repro.models.attention import SCALE_SANITY_MAX
from repro.models.transformer import Model
from repro.parallel.sharding import make_slot_mesh
from repro.serve.kv_cache import BlockPagedKVPool, SlotKVPool
from repro.serve.scheduler import (
    Completion, FCFSScheduler, PriorityScheduler, Request, pad_to_grid,
)
from repro.serve.prefix_cache import PrefixCache

# --- GN sentinel thresholds (docs/serving.md §Fault tolerance) -------------
# Σp residual: the paper's analytic bound for a t-term GN softmax sum is
# (t+1)·ε with ε the softmax compute dtype's machine epsilon — (t+1)·2⁻²³
# in f32 (pinned empirically in examples/norm_error_study.py), (t+1)·2⁻⁸
# when the model runs in bf16 (Σp is exact in the kernel's own arithmetic;
# the probe re-reads the ε-quantized probabilities and re-sums in f32, so
# it sees up to one ulp per term).  The trip wire sits a small constant
# above the analytic bound.  Real corruption lands orders of magnitude
# past either bound (nonfinite, or O(1) deviations), so the slack costs
# no detection.
SENTINEL_SUM_SLACK = 4.0
# GN/exact norm σ residual |mean(x̂²) − 1|, measured in f32 on the f32-cast
# pre-head activations: exact impls land at f32 rounding (~1e-7); the gn_*
# impls guarantee normalization to their grid precision (~2⁻¹¹, observed
# ~1e-5).  1e-3 keeps two orders of headroom over the guarantee while still
# flagging the O(1) deviations corruption produces.  Approximate norm impls
# (integer/lut) are only checked for nonfinite values.
SENTINEL_SIGMA_BOUND = 1e-3


class CountingJit:
    """``jax.jit`` plus an explicit compilation counter.

    The wrapped python function body runs exactly once per trace — i.e. once
    per compilation — so ``compilations`` is always an int.  (The previous
    probe poked jax's private ``_cache_size`` and silently degraded to
    ``None`` on versions without it, writing nulls into ``metrics()`` /
    BENCH_serve.json and blinding the bench's compile-count trajectory.)
    """

    def __init__(self, fn, **jit_kwargs):
        self._count = 0
        self._fn = fn
        self._jit_kwargs = dict(jit_kwargs)
        # repro.analysis hook: when set (to a dict), every distinct call
        # signature records one ShapeDtypeStruct tree of its args, so the
        # auditor can re-trace the exact entry points a workload exercised
        # without holding (donated!) buffer references.
        self.capture_avals = None

        @functools.wraps(fn)
        def counted(*args, **kwargs):
            self._count += 1
            return fn(*args, **kwargs)

        self._jitted = jax.jit(counted, **jit_kwargs)

    def __call__(self, *args, **kwargs):
        if self.capture_avals is not None and not kwargs:
            avals = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(np.shape(x), np.asarray(x).dtype
                                               if not hasattr(x, "dtype") else x.dtype),
                args,
            )
            key = tuple(
                (leaf.shape, str(leaf.dtype)) for leaf in jax.tree.leaves(avals)
            )
            self.capture_avals.setdefault(key, avals)
        return self._jitted(*args, **kwargs)

    @property
    def compilations(self) -> int:
        return self._count

    @property
    def jitted(self):
        """The underlying ``jax.jit`` object (AOT trace/lower access)."""
        return self._jitted

    @property
    def donate_argnums(self) -> tuple:
        return tuple(self._jit_kwargs.get("donate_argnums", ()))

    def trace(self, *args, **kwargs):
        return self._jitted.trace(*args, **kwargs)


def round_slots_to_devices(num_slots: int, devices: int) -> int:
    """Smallest slot count >= ``num_slots`` that shards evenly over
    ``devices`` — the engine requires exact divisibility (per-device slot
    shards), so CLIs round their requested pool size up through this."""
    return -(-int(num_slots) // int(devices)) * int(devices)


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0  # 0 = greedy
    seed: int = 0


# ---------------------------------------------------------------- static ---
def _static_jits(model: Model, max_seq: int):
    """Per-model cache of the static path's jitted prefill/decode, so repeated
    ``generate`` calls (benchmarks, the static oracle) don't re-trace."""
    cache = model.__dict__.setdefault("_serve_jits", {})
    if "decode" not in cache:
        cache["decode"] = jax.jit(model.decode_step)
    key = ("prefill", max_seq)
    if key not in cache:
        cache[key] = jax.jit(lambda p, bt: model.prefill(p, bt, max_seq))
    return cache[key], cache["decode"]


def generate(model: Model, params, batch: dict, cfg: ServeConfig):
    """batch['tokens']: (B, S_prompt) -> (B, S_prompt + max_new) tokens.

    Prefill once, then ``max_new_tokens`` decode steps under jit (the decode
    step is compiled once; positions are traced scalars).
    """
    tokens = batch["tokens"]
    b, s = tokens.shape
    max_seq = s + cfg.max_new_tokens

    prefill, decode = _static_jits(model, max_seq)
    logits, cache = prefill(params, batch)

    key = jax.random.PRNGKey(cfg.seed)
    last_logits = logits[:, -1]
    out = jnp.zeros((b, max_seq), jnp.int32)
    out = jax.lax.dynamic_update_slice(out, tokens.astype(jnp.int32), (0, 0))

    for i in range(cfg.max_new_tokens):
        if cfg.temperature > 0:
            key, sub = jax.random.split(key)
            nxt = jax.random.categorical(sub, last_logits / cfg.temperature, axis=-1)
        else:
            nxt = jnp.argmax(last_logits, axis=-1)
        nxt = nxt[:, None].astype(jnp.int32)
        out = jax.lax.dynamic_update_slice(out, nxt, (0, s + i))
        logits_step, cache = decode(params, cache, nxt, jnp.int32(s + i))
        last_logits = logits_step[:, 0]
    return out


def perplexity(model: Model, params, batch: dict) -> float:
    """Teacher-forced perplexity over a token batch (score-oriented metric)."""
    logits, _ = jax.jit(model.forward)(params, batch)
    logits = logits[:, :-1].astype(jnp.float32)
    targets = batch["tokens"][:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return float(jnp.exp(jnp.mean(nll)))


def static_reference(model: Model, params, requests: Sequence[Request],
                     cfg: ServeConfig) -> dict[int, np.ndarray]:
    """Serve ``requests`` through the static engine: group by (prompt_len,
    max_new_tokens) in FCFS order, one ``generate`` call per group.  Returns
    request id -> full (prompt + generated) token array, truncated at a
    request's stop token if it has one (the static engine itself always
    decodes the full budget).  This is both the greedy-identity oracle and
    the static baseline in benchmarks — greedy only, since sampled paths use
    different key streams per engine."""
    if any(r.temperature not in (None, 0, 0.0) for r in requests) or cfg.temperature:
        raise ValueError("static_reference is a greedy oracle (temperature 0 only)")
    groups: dict[tuple, list[Request]] = {}
    for req in requests:
        groups.setdefault((req.prompt_len, req.max_new_tokens), []).append(req)
    out: dict[int, np.ndarray] = {}
    for (plen, max_new), reqs in groups.items():
        batch = {"tokens": jnp.stack([jnp.asarray(r.tokens, jnp.int32) for r in reqs])}
        for k in reqs[0].extras:
            batch[k] = jnp.stack([jnp.asarray(r.extras[k]) for r in reqs])
        gcfg = dataclasses.replace(cfg, max_new_tokens=max_new)
        toks = np.asarray(generate(model, params, batch, gcfg))
        for r, row in zip(reqs, toks):
            if r.stop_token is not None:
                hits = np.nonzero(row[plen:] == r.stop_token)[0]
                if hits.size:
                    row = row[: plen + hits[0] + 1]
            out[r.id] = row
    return out


# ------------------------------------------------------------ continuous ---
@dataclasses.dataclass
class _SlotState:
    req: Request
    admit_step: int
    admit_time: float
    generated: list
    phase: str = "decoding"       # 'prefilling' | 'decoding'
    padded: Optional[np.ndarray] = None  # prompt padded to the chunk grid
    written: int = 0              # prefill tokens committed to the cache
    # tokens the prefill phase must commit before the slot flips to
    # decoding.  == req.prompt_len normally; a recompute-resumed request
    # re-prefills prompt + already-generated tokens, so it is longer.
    prefill_len: int = 0
    first_token_step: int = -1
    first_token_time: float = 0.0
    preemptions: int = 0          # times this request has been evicted


@dataclasses.dataclass
class _Suspended:
    """A preempted request's carried state, keyed by request id until the
    scheduler hands the request back to admission.

    ``spill`` is None on the recompute path (resume re-prefills prompt +
    generated-so-far from scratch) and, on the spill path, the host-side
    mirror of everything the slot held: the block-chain payload (paged) or
    the batch-1 slab tree, the pool position, the prefill bookkeeping and
    the held next-token logits row — enough to restore the slot bitwise
    and continue as if the eviction never happened."""

    generated: list
    admit_step: int
    admit_time: float
    first_token_step: int
    first_token_time: float
    preemptions: int
    spill: Optional[dict] = None


class ContinuousEngine:
    """Continuous-batching engine over a fixed slot set, with chunked
    prefill fused into the decode step.

    Admission pages a *fresh* (empty) cache into a free slot — no blocking
    prefill call, no per-prompt-length compilation.  Each engine tick then
    runs ONE jitted step over a fixed (num_slots, chunk) token budget:
    every active slot contributes either its next decode token (phase
    'decoding', one valid lane) or the next chunk of its remaining prompt
    (phase 'prefilling', up to ``chunk`` valid lanes), so prompts stream
    through otherwise-idle lanes instead of stalling the batch.  Per-slot
    positions, valid counts, phases, temperatures and the active mask are
    all traced arrays -> requests joining/leaving/prefilling never trigger
    recompilation.  Ticks where every live slot is decoding take the
    cheaper (num_slots, 1) decode step (also compiled once).

    Greedy outputs are token-identical to the static ``generate`` path for
    every family whose serve shapes stay below the monolithic-path
    thresholds (conv fusion, chunked SSD/mLSTM, chunked attention) — see
    ``Model.prefill_chunk``.  MoE chunked prefill is the one exception:
    GShard capacity dropping depends on the dispatch group, so a chunked
    pass can route borderline tokens differently than a monolithic one.
    """

    def __init__(self, model: Model, params, num_slots: int, max_seq: int,
                 cfg: ServeConfig = ServeConfig(),
                 scheduler: Optional[FCFSScheduler] = None,
                 chunk: int = 8, block_size: int = 0, num_blocks: int = 0,
                 devices: int = 1, paged: Optional[bool] = None,
                 prefix_cache: bool = False,
                 sched: str = "fcfs", preempt: str = "off",
                 aging_steps: int = 64, shed_backlog: int = 0,
                 kv_dtype: str = "fp", sentinels: Optional[bool] = None,
                 fault_retry_budget: int = 3,
                 clip_fallback_frac: float = 0.5, clip_patience: int = 3,
                 device_loss_min_slots: int = 2):
        self.model, self.params, self.cfg = model, params, cfg
        self.num_slots, self.max_seq = int(num_slots), int(max_seq)
        self.chunk = int(chunk)
        win = model.cfg.sliding_window or 0
        limit = min(self.max_seq, win) if win else self.max_seq
        if not 1 <= self.chunk <= limit:
            raise ValueError(
                f"chunk {chunk} must be in [1, {limit}] "
                "(cache ring capacity bounds the per-tick chunk)"
            )
        # SLA control plane: the scheduling policy ('fcfs' | 'priority') is
        # an engine kwarg (not just a scheduler instance) so reset() can
        # rebuild an equivalent scheduler for replay — a bench rep must not
        # silently fall back to FCFS.  Preemption ('off' | 'recompute' |
        # 'spill') requires the priority policy: its victim-eligibility
        # check is the scheduler's rank rule, and under FCFS a requeued
        # victim becomes the head again and admission would thrash.
        if sched not in ("fcfs", "priority"):
            raise ValueError(f"sched must be 'fcfs' or 'priority', got {sched!r}")
        if preempt not in ("off", "recompute", "spill"):
            raise ValueError(
                f"preempt must be 'off', 'recompute' or 'spill', got {preempt!r}"
            )
        self.sched_policy = sched
        self.preempt_mode = preempt
        self.aging_steps = int(aging_steps)
        self.shed_backlog = int(shed_backlog)
        if isinstance(scheduler, PriorityScheduler):
            # adopt the instance's policy so reset() rebuilds an equivalent
            self.sched_policy = "priority"
            self.aging_steps = scheduler.aging_steps
            self.shed_backlog = scheduler.shed_backlog
        if self.preempt_mode != "off" and self.sched_policy != "priority":
            raise ValueError(
                "preempt requires sched='priority' (or a PriorityScheduler "
                "instance): victim eligibility is the priority rank rule"
            )
        # Slot-pool sharding over the batch axis: devices=N builds a 1-D
        # ('data',) mesh, the pools place every cache leaf with a slot-axis
        # NamedSharding and both compile-once jits run SPMD over per-device
        # slot shards.  devices=1 builds no mesh at all — the single-device
        # path is bit-identical to the unsharded engine.
        self.num_devices = int(devices)
        if self.num_devices < 1:
            raise ValueError(f"devices must be >= 1, got {devices}")
        if self.num_slots % self.num_devices:
            raise ValueError(
                f"num_slots {num_slots} must divide evenly over "
                f"{devices} devices (per-device slot shards)"
            )
        self.mesh = make_slot_mesh(self.num_devices) if self.num_devices > 1 else None
        if self.mesh is not None:
            self._sh_slot = NamedSharding(self.mesh, P("data"))       # (N,)
            self._sh_row = NamedSharding(self.mesh, P("data", None))  # (N, ...)
            self._sh_rep = NamedSharding(self.mesh, P())              # replicated
        else:
            self._sh_slot = self._sh_row = self._sh_rep = None
        # Block-paged KV wherever the family's cache is pageable (dense/moe/
        # encdec/vlm full-attention KV, MLA latents): HBM scales with live
        # tokens, admission gates on free blocks.  SSM/hybrid carries and
        # sliding-window rings keep the slot-monolithic pool.  ``paged``
        # overrides the auto-selection (False forces the slab pool for a
        # pageable family — the bench's HBM baseline and the sharded slab
        # test path; True on an unpageable family is an error).
        self.paged = model.supports_paging if paged is None else bool(paged)
        if self.paged and not model.supports_paging:
            raise ValueError(
                f"family {model.cfg.family!r} (sliding_window="
                f"{model.cfg.sliding_window}) has no pageable KV"
            )
        # Quantized paged KV: int8 arenas + per-block f32 dequant scales
        # carried as cache leaves alongside the arenas.  Paged-only — the
        # slab pool has no per-block scale granularity to hang scales on.
        if kv_dtype not in ("fp", "int8"):
            raise ValueError(f"kv_dtype must be 'fp' or 'int8', got {kv_dtype!r}")
        if kv_dtype == "int8" and not self.paged:
            raise ValueError(
                "kv_dtype='int8' requires the block-paged pool (per-block "
                "scales live in the block tables); the slab pool is fp-only"
            )
        self.kv_dtype = kv_dtype
        # GN runtime sentinels: in-tick Σp/σ-residual probes accumulated on
        # device into a per-slot health word, fetched with the tick's token
        # download and checked against the analytic bound (see
        # docs/serving.md §Fault tolerance).  ``sentinel`` is a static bool
        # closed over by the tick bodies — never a trace key — so enabling
        # them changes neither compile counts nor the tick's input avals.
        # Default on wherever the probe path exists (the paged tick bodies);
        # the slab pool has no probe plumbing, so sentinels=True there is an
        # error rather than a silent no-op.
        self.sentinels = self.paged if sentinels is None else bool(sentinels)
        if self.sentinels and not self.paged:
            raise ValueError(
                "sentinels ride the paged tick bodies; the slab pool has "
                "no probe path (pass sentinels=False or paged=True)"
            )
        self.fault_retry_budget = int(fault_retry_budget)
        self.clip_fallback_frac = float(clip_fallback_frac)
        self.clip_patience = int(clip_patience)
        self.device_loss_min_slots = int(device_loss_min_slots)
        if self.paged:
            self.pool = BlockPagedKVPool(
                model, num_slots, max_seq,
                block_size=block_size or self.chunk, num_blocks=num_blocks,
                mesh=self.mesh, num_devices=self.num_devices,
                kv_dtype=kv_dtype,
            )
            # Horizon-bucket grid: each paged tick slices the traced block
            # tables to the smallest bucket covering the *active block
            # horizon* (max blocks any live slot holds), so attention
            # compute/HBM traffic scales with live tokens while the jit
            # cache stays pinned — one compilation per (step kind, bucket),
            # i.e. fused <= len(grid) and decode <= len(grid) instead of
            # one tick shape per horizon.  Powers of two up to
            # max_blocks_per_slot, which caps the grid at
            # ceil(log2(max_bt)) + 1 entries.
            grid, b = [], 1
            while b < self.pool.max_blocks_per_slot:
                grid.append(b)
                b *= 2
            grid.append(self.pool.max_blocks_per_slot)
            self.horizon_bucket_grid: list[int] = grid
            # Prefix sharing (opt-in): a per-device radix index over finished
            # prompt prefixes.  Admission attaches fully-matched cached
            # blocks read-only (refcount++), COW-forks a partially-matched
            # tail, charges the reservation only for the unshared remainder,
            # and prefill starts at the shared length — cold-TTFT drops to
            # the unshared tail.  Off by default: a retaining cache keeps
            # blocks resident after drain, which the non-sharing pool
            # invariants (blocks_in_use == 0) deliberately forbid.
            self.prefix = (
                PrefixCache(self.pool.block_size, self.num_devices)
                if prefix_cache else None
            )
            if self.prefix is not None:
                self.pool.attach_prefix_cache(self.prefix)
        else:
            if prefix_cache:
                raise ValueError(
                    f"family {model.cfg.family!r} has no pageable KV; "
                    "prefix_cache shares paged blocks"
                )
            self.prefix = None
            if block_size or num_blocks:
                raise ValueError(
                    f"family {model.cfg.family!r} has no pageable KV; "
                    "block_size/num_blocks only apply to paged pools"
                    if not model.supports_paging else
                    "block_size/num_blocks only apply to paged pools "
                    "(paged=False forces the slab pool)"
                )
            self.pool = SlotKVPool(model, num_slots, max_seq,
                                   mesh=self.mesh, num_devices=self.num_devices)

        # Donating the tick-carried state (cache tree, held logits,
        # positions, key) lets XLA update the cache in place instead of
        # copying it every tick (~20% off a smoke-scale decode tick); the
        # engine immediately rebinds each donated input to the returned
        # value, so no stale reference survives.  Block tables are NOT
        # donated — the host mirror stays authoritative.  Paged steps
        # re-trace once per horizon bucket (the tables argument's width):
        # compile counts are bounded by len(horizon_bucket_grid) per step
        # kind, not 1 — CountingJit still reports the exact totals.
        if self.paged:
            self._decode = CountingJit(self._decode_sample_paged,
                                       donate_argnums=(1, 2, 3, 6))
            self._fused = CountingJit(self._fused_step_paged,
                                      donate_argnums=(1, 2, 4, 9))
        else:
            self._decode = CountingJit(self._decode_sample,
                                       donate_argnums=(1, 2, 3, 6))
            self._fused = CountingJit(self._fused_step,
                                      donate_argnums=(1, 2, 4, 9))
        # Per-prompt-length prefill jits.  Chunked prefill leaves this empty
        # by construction; any future fallback that traces a prompt-length-
        # dependent prefill MUST register it here so the metric (and the
        # bench's compile-count trajectory) actually counts it.
        self._length_prefills: dict = {}
        # family-initial batch-1 cache paged in at admission (chunked prefill
        # starts from an empty slot; built once, reused for every request).
        # Replicated under a mesh: admission writes it into any slot shard.
        self._fresh_cache = self._put(model.fresh_request_cache(self.max_seq),
                                      self._sh_rep)
        self._encode_cross = (
            jax.jit(model.encode_cross_kv)
            if model.cfg.family == "encdec" else None
        )
        self.reset(scheduler)

    def _put(self, x, sharding):
        """Commit ``x`` (array or tree) to the serving mesh with ``sharding``;
        identity placement when the engine is single-device (no mesh)."""
        return x if sharding is None else jax.device_put(x, sharding)

    def reset(self, scheduler: Optional[FCFSScheduler] = None) -> None:
        """Clear all serving state but keep compiled functions and the pool
        allocation (benchmarks re-run the same workload without recompiling).
        The pool's slot order is restored too, so a reset run replays a
        workload with identical slot assignment (and, for sampled requests,
        identical per-slot key streams)."""
        self.pool.reset()
        vocab = self.model.cfg.vocab
        # Device-resident per-tick state: held logits, positions, active
        # mask, temps and the PRNG key all live on device and evolve in-jit;
        # the host mirrors (pool.positions, _temps, _slots) are refreshed
        # onto the device only when admission/completion changes lane
        # residency (_lanes_dirty), so a steady-state tick costs exactly one
        # jitted dispatch + one token download.
        self._last_logits = self._put(
            jnp.zeros((self.num_slots, vocab), jnp.float32), self._sh_row
        )
        self._temps = np.zeros(self.num_slots, np.float32)
        self._slots: list[Optional[_SlotState]] = [None] * self.num_slots
        self._pos_dev = self._put(jnp.zeros(self.num_slots, jnp.int32), self._sh_slot)
        self._active_dev = self._put(jnp.zeros(self.num_slots, bool), self._sh_slot)
        self._temps_dev = self._put(
            jnp.zeros(self.num_slots, jnp.float32), self._sh_slot
        )
        self._lanes_dirty = True
        if self.paged:
            self._tables_dev = self._put(jnp.asarray(self.pool.tables), self._sh_row)
            # per-bucket slices of the device tables, rebuilt lazily when
            # residency grows — steady-state ticks reuse the cached slice
            # instead of dispatching a device slice every tick
            self._tables_sliced: dict[int, jax.Array] = {}
            self.pool.tables_dirty = False
        self._key = self._put(jax.random.PRNGKey(self.cfg.seed), self._sh_rep)
        self.step_count = 0
        self.completions: list[Completion] = []
        self._active_steps = 0   # sum over decode steps of active-slot count
        self._decode_steps = 0
        self._fused_ticks = 0    # ticks that carried at least one prefill lane
        self._prefill_lane_steps = 0  # sum over ticks of prefilling slots
        self._generated = 0
        self.phase_log: list[tuple[int, int]] = []  # (prefill, decode) lanes/tick
        # horizon bucketing (paged): raw active horizon + bucket per tick,
        # the bucket sets each step kind has been traced at (the exact
        # compile-count bound), and the summed attended-token width
        self.horizon_log: list[tuple[int, int]] = []  # (horizon, bucket)/tick
        # ticks dispatched under transfer_guard_host_to_device("disallow")
        # — the serve test helpers assert this equals the tick count,
        # proving no tick ran with an implicit host->device transfer.
        # Host->device only: under a device mesh, jit legitimately
        # reshards args device-to-device at dispatch.
        self._guarded_ticks = 0
        self._buckets_seen: dict[str, set] = {"fused": set(), "decode": set()}
        self._attended_tokens = 0  # sum over ticks of bucket * block_size
        self._device_admits = np.zeros(self.num_devices, np.int64)
        # prefix-sharing telemetry (pool.reset() already cleared the radix
        # index itself, so a reset engine replays identical hit sequences)
        self._prefix_hit_tokens = 0
        self._prefix_prompt_tokens = 0
        self._prefix_hit_requests = 0
        self.request_prefix_hits: dict[int, dict] = {}
        # SLA control-plane state: suspended (preempted, not yet resumed)
        # requests by id, counters, and the deterministic event trace —
        # every admission/resume/preempt/reject/finish lands here with its
        # step stamp, so two same-seed runs can be compared event by event.
        self._suspended: dict[int, _Suspended] = {}
        self._preemptions = 0
        self._resumes = 0
        self._rejections = 0
        self.event_log: list[tuple] = []
        # fault-tolerance state: sentinel telemetry, per-request fault-evict
        # retry counts, per-slot consecutive clip-pressure streaks (int8),
        # and the table-redundancy repair count.  All deterministic under
        # replay — every fault verdict lands in event_log with its step.
        self._sentinel_checks = 0
        self._sentinel_violations = 0
        self._retries = 0
        self._fallbacks = 0
        self._table_repairs = 0
        self._fault_retries: dict[int, int] = {}
        self._clip_streak = np.zeros(self.num_slots, np.int32)
        self.scheduler = scheduler or self._make_scheduler()

    def _make_scheduler(self) -> FCFSScheduler:
        """The policy-equivalent scheduler reset() rebuilds for replay."""
        if self.sched_policy == "priority":
            return PriorityScheduler(
                chunk_grid=self.chunk, prefix_cache=self.prefix,
                aging_steps=self.aging_steps, shed_backlog=self.shed_backlog,
            )
        return FCFSScheduler(chunk_grid=self.chunk, prefix_cache=self.prefix)

    # ---------------------------------------------------------- jitted step --
    def _pin(self, x, sharding):
        """Sharding constraint inside a jitted step (no-op without a mesh).
        Pinning the per-slot tick state at entry and exit makes both
        compile-once jits SPMD over per-device slot shards — the cache tree
        arrives pre-sharded (committed by the pool), and GSPMD propagates
        the slot axis through the vmapped/batched layer stack between the
        pins."""
        return x if sharding is None else jax.lax.with_sharding_constraint(x, sharding)

    def _pin_state(self, last_logits, positions, active, temps):
        return (
            self._pin(last_logits, self._sh_row),
            self._pin(positions, self._sh_slot),
            self._pin(active, self._sh_slot),
            self._pin(temps, self._sh_slot),
        )

    def _sample_next(self, last_logits, active, is_prefill, temps, key):
        """Next decode token per slot from the held logits.  The key evolves
        inside the step (split traced) so ticks cost no extra host dispatch."""
        key, sub = jax.random.split(key)
        greedy = jnp.argmax(last_logits, axis=-1)
        tsafe = jnp.where(temps > 0, temps, 1.0)
        keys = jax.random.split(sub, self.num_slots)
        sampled = jax.vmap(jax.random.categorical)(keys, last_logits / tsafe[:, None])
        nxt = jnp.where(temps > 0, sampled, greedy).astype(jnp.int32)
        return jnp.where(active & ~is_prefill, nxt, 0), key

    def _decode_sample(self, params, cache, last_logits, positions, active,
                       temps, key):
        """Sample one token per slot from the held logits, then decode it.
        Everything per-slot is a traced array -> a single compilation.
        Positions advance in-jit; the host mirror tracks them without a
        per-tick transfer."""
        last_logits, positions, active, temps = self._pin_state(
            last_logits, positions, active, temps
        )
        nxt, key = self._sample_next(
            last_logits, active, jnp.zeros_like(active), temps, key
        )
        pos = jnp.where(active, positions, 0)  # clamp dont-care lanes in range
        logits, ncache = self.model.decode_step_slots(params, cache, nxt[:, None], pos)
        new_last = jnp.where(
            active[:, None], logits[:, 0].astype(jnp.float32), last_logits
        )
        new_positions = positions + jnp.where(active, 1, 0).astype(positions.dtype)
        return (self._pin(nxt, self._sh_slot), self._pin(new_last, self._sh_row),
                ncache, self._pin(new_positions, self._sh_slot), key)

    def _fused_step(self, params, cache, last_logits, chunk_tokens, positions,
                    n_valid, is_prefill, active, temps, key):
        """The fused tick: every slot processes a (chunk,)-token lane set —
        decoding slots sample their next token from the held logits into
        lane 0 (n_valid=1), prefilling slots take the staged prompt chunk.
        One compilation covers every phase/length/occupancy mix."""
        last_logits, positions, active, temps = self._pin_state(
            last_logits, positions, active, temps
        )
        chunk_tokens = self._pin(chunk_tokens, self._sh_row)
        n_valid = self._pin(n_valid, self._sh_slot)
        is_prefill = self._pin(is_prefill, self._sh_slot)
        dec, key = self._sample_next(last_logits, active, is_prefill, temps, key)
        lane0 = jnp.zeros_like(chunk_tokens).at[:, 0].set(dec)
        tokens = jnp.where(is_prefill[:, None], chunk_tokens, lane0)
        nv = jnp.where(active & is_prefill, n_valid, 1)
        pos = jnp.where(active, positions, 0)  # clamp dont-care lanes in range
        logits, ncache = self.model.fused_step_slots(params, cache, tokens, pos, nv)
        # fused_step_slots already returns each slot's row n_valid-1 — the
        # next-token distribution after the chunk: for decoders that's lane
        # 0; for prefillers it becomes the first-token logits once the final
        # chunk lands (mid-prompt values are interim, overwritten by later
        # chunks).
        new_last = jnp.where(
            active[:, None], logits[:, 0].astype(jnp.float32), last_logits
        )
        new_positions = positions + jnp.where(active, nv, 0).astype(positions.dtype)
        return (self._pin(dec, self._sh_slot), self._pin(new_last, self._sh_row),
                ncache, self._pin(new_positions, self._sh_slot), key)

    # ------------------------------------------------- paged jitted steps --
    # Same tick contract as the slab steps, but the cache is the shared
    # block-arena tree and every step carries the (traced) block tables.
    # Inactive lanes get n_valid=0 — unlike a slab, a parked lane owns no
    # blocks, so its writes must be *dropped*, not merely aimed at a
    # don't-care slab row.
    #
    # With sentinels enabled (self.sentinels is a closure constant, not an
    # argument) both steps return one extra value: the per-slot health
    # pytree {"layers": (L, N, 3), "head": (N,)} of GN probes, which the
    # engine downloads with the tick's token fetch and checks host-side.
    # Health is output-only (never donated, never re-fed), so it changes
    # neither the donation contract nor the input avals.

    def _decode_sample_paged(self, params, cache, last_logits, positions,
                             active, temps, key, tables):
        last_logits, positions, active, temps = self._pin_state(
            last_logits, positions, active, temps
        )
        tables = self._pin(tables, self._sh_row)
        nxt, key = self._sample_next(
            last_logits, active, jnp.zeros_like(active), temps, key
        )
        pos = jnp.where(active, positions, 0)  # clamp dont-care lanes in range
        nv = jnp.where(active, 1, 0).astype(jnp.int32)
        out = self.model.fused_step_slots_paged(
            params, cache, nxt[:, None], pos, nv, tables,
            sentinel=self.sentinels,
        )
        logits, ncache = out[0], out[1]
        new_last = jnp.where(
            active[:, None], logits[:, 0].astype(jnp.float32), last_logits
        )
        new_positions = positions + nv.astype(positions.dtype)
        res = (self._pin(nxt, self._sh_slot), self._pin(new_last, self._sh_row),
               ncache, self._pin(new_positions, self._sh_slot), key)
        return res + ((out[2],) if self.sentinels else ())

    def _fused_step_paged(self, params, cache, last_logits, chunk_tokens,
                          positions, n_valid, is_prefill, active, temps, key,
                          tables):
        last_logits, positions, active, temps = self._pin_state(
            last_logits, positions, active, temps
        )
        chunk_tokens = self._pin(chunk_tokens, self._sh_row)
        n_valid = self._pin(n_valid, self._sh_slot)
        is_prefill = self._pin(is_prefill, self._sh_slot)
        tables = self._pin(tables, self._sh_row)
        dec, key = self._sample_next(last_logits, active, is_prefill, temps, key)
        lane0 = jnp.zeros_like(chunk_tokens).at[:, 0].set(dec)
        tokens = jnp.where(is_prefill[:, None], chunk_tokens, lane0)
        nv = jnp.where(active & is_prefill, n_valid, 1)
        nv = jnp.where(active, nv, 0).astype(jnp.int32)
        pos = jnp.where(active, positions, 0)
        out = self.model.fused_step_slots_paged(
            params, cache, tokens, pos, nv, tables, sentinel=self.sentinels
        )
        logits, ncache = out[0], out[1]
        new_last = jnp.where(
            active[:, None], logits[:, 0].astype(jnp.float32), last_logits
        )
        new_positions = positions + jnp.where(active, nv, 0).astype(positions.dtype)
        res = (self._pin(dec, self._sh_slot), self._pin(new_last, self._sh_row),
               ncache, self._pin(new_positions, self._sh_slot), key)
        return res + ((out[2],) if self.sentinels else ())

    # ------------------------------------------------------------ admission --
    def submit(self, req: Request) -> int:
        return self.scheduler.submit(req)

    def _admit(self) -> list[int]:
        """Page empty cache slots in for ready requests.  No forward pass
        happens here — the fused step drains the prompt chunk-by-chunk —
        so admission cost is one traced-slot insert regardless of prompt
        length, and there is no per-prompt-length prefill compilation.

        Placement is least-loaded-first across the device mesh: the FCFS
        head lands in the slot range of the device with the most free slots
        (paged: whose block range can also cover its whole-footprint
        reservation), so one hot device cannot strand free slots elsewhere.
        With one device this degenerates to the historical global FIFO."""
        admitted = []
        self._tick_admitted: set[int] = set()  # slots filled this pass
        # Backpressure first: under saturation, shed arrived batch backlog
        # beyond the watermark before anyone queues behind it.  FCFS's
        # poll_shed is a no-op; the PriorityScheduler sheds head-ordered.
        live_units, unit_fn = self._shed_signal()
        for req in self.scheduler.poll_shed(self.step_count, live_units, unit_fn):
            self._reject(req)
        while True:
            head = self.scheduler.peek_ready(self.step_count)
            if head is None:
                break
            footprint = head.prompt_len + head.max_new_tokens
            if footprint > self.max_seq:
                raise ValueError(
                    f"request {head.id}: prompt {head.prompt_len} + "
                    f"{head.max_new_tokens} new tokens exceeds max_seq {self.max_seq}"
                )
            if self.paged and (
                self.pool.blocks_for(footprint) > self.pool.max_request_blocks
            ):
                raise ValueError(
                    f"request {head.id}: footprint {footprint} tokens needs "
                    f"{self.pool.blocks_for(footprint)} blocks, a device's "
                    f"arena shard has {self.pool.max_request_blocks} — "
                    "unservable at any occupancy"
                )
            # Prefix lookup before placement: a hit pulls the request toward
            # the device already holding its prefix blocks (chains are
            # device-local), provided that device can still take it; the
            # reservation then charges only the unshared tail.  Misses (and
            # hits whose device is full) fall through to least-loaded.
            # Resuming (previously preempted) requests skip the lookup: the
            # spill path must rebuild the exact chain the payload was
            # gathered from, and the recompute path re-prefills a prompt +
            # generated sequence the prompt-only radix index doesn't cover.
            hit = device = None
            resuming = head.id in self._suspended
            if self.prefix is not None and not resuming:
                # cap at prompt_len - 1: the sampled first token needs the
                # request's own final prompt position to run through prefill
                hit = self.prefix.lookup(head.tokens, cap=head.prompt_len - 1)
                if hit is not None:
                    d = hit.device
                    if (self.pool.free_slots_on(d)
                            and self.pool.can_reserve(footprint, d, prefix=hit)):
                        device = d
                    else:
                        hit = None
            if device is None and self.pool.num_free:
                device = self.pool.pick_device(footprint if self.paged else 0)
            if device is None:
                # no device can take the head: an interactive head may evict
                # a batch victim it outranks; otherwise it waits for
                # recycling (admit gates on free *blocks* under paging)
                if self._try_preempt(head):
                    continue  # retry placement with the victim's resources
                break
            req = self.scheduler.pop_ready(self.step_count)
            sus = self._suspended.pop(req.id, None)
            slot = (
                self.pool.allocate(reserve_tokens=footprint, device=device,
                                   prefix=hit)
                if self.paged else self.pool.allocate(device=device)
            )
            if hit is not None:
                self.pool.attach_prefix(slot, hit)
            self._device_admits[device] += 1
            fresh = self._fresh_cache
            if self._encode_cross is not None:
                frames = jnp.asarray(req.extras["frames"])[None]
                fresh = {**fresh, "cross": self._encode_cross(self.params, frames)}
            if self.model.cfg.family == "vlm":
                dt = jnp.dtype(self.model.cfg.dtype)
                fresh = {**fresh,
                         "patches": jnp.asarray(req.extras["patches"])[None].astype(dt)}
            if sus is not None and sus.spill is not None:
                # --- spill resume: restore the evicted KV bitwise ---------
                sp = sus.spill
                if self.paged:
                    # insert() sets the position and ensures a fresh chain
                    # of exactly the spilled length; the scatter then fills
                    # it with the gathered values (physical ids may differ —
                    # only logical block order matters)
                    self.pool.insert(fresh, slot, position=sp["position"])
                    self.pool.restore_blocks(slot, sp["kv"])
                else:
                    self.pool.insert(sp["kv"], slot, position=sp["position"])
                self._last_logits = self._put(
                    self._last_logits.at[slot].set(jnp.asarray(sp["last_logits"])),
                    self._sh_row,
                )
                padded, written = sp["padded"], sp["written"]
                phase, prefill_len = sp["phase"], sp["prefill_len"]
            elif sus is not None:
                # --- recompute resume: re-prefill prompt + generated ------
                # Chunked prefill is token-identical to the decode path that
                # originally produced these tokens (the PR 2 invariant), so
                # after the re-prefill the held logits row is exactly the
                # next-token distribution the uninterrupted run would hold.
                seq = np.concatenate([
                    np.asarray(req.tokens, np.int32),
                    np.asarray(sus.generated, np.int32),
                ])
                prefill_len = int(seq.shape[0])
                padded = pad_to_grid(seq, self.chunk)
                self.pool.insert(fresh, slot, position=0)
                written, phase = 0, "prefilling"
            else:
                shared = hit.shared_len if hit is not None else 0
                self.pool.insert(fresh, slot, position=shared)
                padded = req.padded_tokens
                if shared:
                    # prefill starts at the shared length, so the chunk
                    # slices run [shared + k*chunk : ... + chunk): re-pad
                    # the prompt to cover the last (possibly overhanging)
                    # slice — grid-aligned padding from intake can be too
                    # short when ``shared`` is not chunk-aligned
                    need = shared + -(-(req.prompt_len - shared) // self.chunk) * self.chunk
                    if padded is None or padded.shape[0] < need:
                        toks = np.asarray(req.tokens, np.int32)
                        padded = np.concatenate(
                            [toks, np.zeros(need - toks.shape[0], np.int32)]
                        )
                    self._prefix_hit_tokens += shared
                    self._prefix_hit_requests += 1
                    self.request_prefix_hits[req.id] = {
                        "tokens": shared,
                        "blocks": len(hit.blocks),
                        "forked": hit.tail_src is not None,
                        "device": hit.device,
                    }
                elif padded is None or padded.shape[0] % self.chunk:
                    padded = pad_to_grid(req.tokens, self.chunk)
                written, phase = shared, "prefilling"
                prefill_len = req.prompt_len
            if self.prefix is not None and not resuming:
                self._prefix_prompt_tokens += req.prompt_len
            temp = self.cfg.temperature if req.temperature is None else req.temperature
            self._temps[slot] = float(temp)
            self._slots[slot] = _SlotState(
                req=req,
                admit_step=sus.admit_step if sus else self.step_count,
                admit_time=sus.admit_time if sus else time.time(),
                generated=sus.generated if sus else [],
                phase=phase, padded=padded, written=written,
                prefill_len=prefill_len,
                first_token_step=sus.first_token_step if sus else -1,
                first_token_time=sus.first_token_time if sus else 0.0,
                preemptions=sus.preemptions if sus else 0,
            )
            self._lanes_dirty = True
            self._clip_streak[slot] = 0
            self._tick_admitted.add(slot)
            if sus is not None:
                self._resumes += 1
                self.event_log.append(
                    ("resume", self.step_count, req.id, slot, device)
                )
            else:
                self.event_log.append(
                    ("admit", self.step_count, req.id, slot, device)
                )
            admitted.append(req.id)
        return admitted

    def _shed_signal(self) -> tuple:
        """(live reservation, per-request footprint fn) in the pool's
        admission units — blocks under paging, slots under a slab — for the
        scheduler's backpressure watermark."""
        if self.paged:
            return (
                self.pool.blocks_reserved,
                lambda r: self.pool.blocks_for(r.prompt_len + r.max_new_tokens),
            )
        return self.pool.num_used, lambda r: 1

    def _try_preempt(self, head: Request) -> bool:
        """Evict one batch victim so ``head`` (an interactive request that
        would otherwise queue) can place.  Victim selection is LIFO over the
        live batch slots the head *outranks under the scheduler's own rank
        rule* — the same step-independent order that decides admission, so
        an aged batch request that would beat the head in the queue can't
        be evicted by it either (no admit/preempt livelock).  LIFO (latest
        admission first) preempts the least sunk cost and mirrors the
        requeue-front resume order: the last victim out is the first back
        in.  Slots admitted this very pass are exempt — a resumed victim
        can't be re-evicted before it runs a single tick."""
        if self.preempt_mode == "off" or head.req_class != "interactive":
            return False
        outranks = getattr(self.scheduler, "outranks", None)
        best = None
        for s, st in enumerate(self._slots):
            if st is None or st.req.req_class != "batch":
                continue
            if s in self._tick_admitted:
                continue
            if outranks is not None and not outranks(
                head.arrival_step, st.req.arrival_step
            ):
                continue  # victim has aged past the head: immune
            if best is None or (st.admit_step, s) > (
                self._slots[best].admit_step, best
            ):
                best = s
        if best is None:
            return False
        self._preempt(best)
        return True

    def _preempt(self, slot: int) -> None:
        """Evict ``slot``'s request and requeue it at the head of its class.

        Spill mode mirrors the slot's KV to host first (block-chain gather
        under paging, batch-1 slab extract otherwise) along with the held
        logits row and the prefill bookkeeping — resume restores all of it
        bitwise.  Recompute mode just drops the chain: resume re-prefills
        prompt + generated-so-far.  Either way the freed blocks are
        recycled *unzeroed* into other requests' chains — the GN guarantee
        (masked scores -> exactly-zero numerators) makes eviction a
        table/length edit, never a memory edit."""
        st = self._slots[slot]
        rid = st.req.id
        spill = None
        if self.preempt_mode == "spill":
            if self.paged:
                kv = self.pool.extract_blocks(slot)
            else:
                kv = jax.tree.map(np.asarray, self.pool.extract(slot))
            spill = {
                "kv": kv,
                "position": int(self.pool.positions[slot]),
                "padded": st.padded,
                "written": st.written,
                "prefill_len": st.prefill_len,
                "phase": st.phase,
                "last_logits": np.asarray(self._last_logits[slot]),
            }
        self._suspended[rid] = _Suspended(
            generated=st.generated,
            admit_step=st.admit_step,
            admit_time=st.admit_time,
            first_token_step=st.first_token_step,
            first_token_time=st.first_token_time,
            preemptions=st.preemptions + 1,
            spill=spill,
        )
        self._slots[slot] = None
        self.pool.free(slot)
        self.scheduler.requeue_front(st.req)
        self._preemptions += 1
        self._lanes_dirty = True
        self.event_log.append(
            ("preempt", self.step_count, rid, self.preempt_mode, slot)
        )

    def _reject(self, req: Request) -> None:
        """Record a shed request as a completion with finish_reason
        'rejected' — the client-visible load-shedding verdict."""
        now = time.time()
        self.completions.append(Completion(
            request_id=req.id,
            prompt_tokens=np.asarray(req.tokens, np.int32),
            new_tokens=np.zeros(0, np.int32),
            finish_reason="rejected",
            arrival_step=req.arrival_step,
            admit_step=-1,
            first_token_step=-1,
            finish_step=self.step_count,
            admit_time=now,
            first_token_time=now,
            finish_time=now,
            req_class=req.req_class,
            preemptions=0,
        ))
        self._rejections += 1
        self.event_log.append(("reject", self.step_count, req.id))

    def _prefix_insert(self, slot: int, up_to: int) -> None:
        """Index ``slot``'s prompt prefix [0, up_to) in the radix cache.
        Called at prefill completion (full prompt blocks — from then on the
        owner writes only at decode positions, in later blocks) and again at
        finish with the partial prompt tail (the owner is gone; the decode
        tokens sharing that block sit beyond every sharer's causal mask, and
        GN maps masked columns to exactly-zero numerators).  Generated
        tokens are never indexed — sharing only prompt-position KV keeps
        greedy identity vs the unshared oracle exact by construction."""
        if up_to <= 0:
            return
        self.prefix.insert(
            np.asarray(self._slots[slot].req.tokens[:up_to], np.int32),
            self.pool.chain_of(slot)[: self.pool.blocks_for(up_to)],
            self.pool.device_of(slot),
        )

    def _finish(self, slot: int, reason: str) -> None:
        st = self._slots[slot]
        # written >= prompt_len: a recompute-resumed slot prefilled past the
        # prompt (prompt + generated), but its first blocks_for(prompt_len)
        # chain entries still hold exactly the prompt KV, so the tail insert
        # stays valid
        if self.prefix is not None and st.written >= st.req.prompt_len:
            bs = self.pool.block_size
            if st.req.prompt_len % bs:
                self._prefix_insert(slot, st.req.prompt_len)
        now = time.time()
        self.completions.append(Completion(
            request_id=st.req.id,
            prompt_tokens=np.asarray(st.req.tokens, np.int32),
            new_tokens=np.asarray(st.generated, np.int32),
            finish_reason=reason,
            arrival_step=st.req.arrival_step,
            admit_step=st.admit_step,
            first_token_step=st.first_token_step,
            finish_step=self.step_count,
            admit_time=st.admit_time,
            first_token_time=st.first_token_time,
            finish_time=now,
            req_class=st.req.req_class,
            preemptions=st.preemptions,
        ))
        self.event_log.append(("finish", self.step_count, st.req.id, reason))
        self._slots[slot] = None
        self.pool.free(slot)
        self._lanes_dirty = True

    # ------------------------------------------------------ fault tolerance --
    def _check_tables(self) -> None:
        """Host-side block-table redundancy check.  The per-slot chain
        (``_slot_blocks``) is the authoritative allocation record; the flat
        ``tables`` mirror is derived from it.  A divergence (bit-flip, stray
        write) is repaired from the chain, counted, and logged — the bad row
        never reaches the device because this runs before the dirty-mirror
        push in ``step``.  No quarantine or recompute is needed: arena
        contents were never touched."""
        for s, st in enumerate(self._slots):
            if st is None:
                continue
            chain = self.pool.chain_of(s)
            if not chain:
                continue
            want = np.asarray(chain, np.int32)
            if not np.array_equal(self.pool.tables[s, : len(chain)], want):
                self.pool.tables[s, : len(chain)] = want
                self.pool.tables_dirty = True
                self._table_repairs += 1
                self._sentinel_violations += 1
                self.event_log.append(
                    ("fault_table_repair", self.step_count, st.req.id, s)
                )

    def _sentinel_scan(self, health, live) -> None:
        """Check every live slot's health word against the GN bounds and
        contain/recover violations.  Channels (see attention.paged_probe_word
        and Model._paged_head):

        * layers[:, s, 0] — Σp residual, +inf on nonfinite scores/outputs.
          Bound: SENTINEL_SUM_SLACK · (t+1) · ε(compute dtype) with t the
          slot's attended width — (t+1)·2⁻²³ in f32, (t+1)·2⁻⁸ in bf16.
          NaN-safe comparison (``not (x <= bound)``).
        * head[s]        — final-norm σ residual, +inf on nonfinite logits.
        * layers[:, s, 1] — int8 clip fraction: sustained saturation flips
          the request to the full-precision static path (no quarantine —
          clipping is a range problem, not corruption).
        * layers[:, s, 2] — per-block scale sanity (int8): any nonfinite,
          negative, or implausibly large scale in the slot's live horizon.

        Every violating slot is contained uniformly: its chain is
        content-scanned, bad blocks are quarantined AND scrubbed (a NaN
        tile reachable through a stale table entry poisons healthy slots
        via IEEE 0·NaN=NaN — scrubbing closes that channel; healthy blocks
        are never zeroed), and the request is rebuilt token-identically via
        the free-and-recompute resume path under ``fault_retry_budget``.
        When every live slot on a device violates at once (>=
        ``device_loss_min_slots``), the whole device is declared lost."""
        layers = np.asarray(health["layers"], np.float64)  # (L, N, 3)
        head = np.asarray(health["head"], np.float64)      # (N,)
        sigma_certified = self.model.cfg.norm_impl.startswith(("gn", "exact"))
        # ε of the softmax compute dtype: 2⁻²³ (f32) or 2⁻⁸ (bf16)
        eps = float(jnp.finfo(jnp.dtype(self.model.cfg.dtype)).eps)
        violating: dict[int, list] = {}
        for s in live:
            st = self._slots[s]
            if st is None:
                continue
            self._sentinel_checks += 1
            kinds = []
            t = int(self.pool.positions[s])  # attended width incl. this tick
            bound = SENTINEL_SUM_SLACK * (t + 1) * eps
            sumres = layers[:, s, 0]
            worst = float(np.max(sumres))
            if not (worst <= bound):
                ok = sumres <= bound
                kinds.append(("sum", int(np.argmin(ok)), worst))
            h = float(head[s])
            if sigma_certified:
                if not (h <= SENTINEL_SIGMA_BOUND):
                    kinds.append(("sigma", -1, h))
            elif not np.isfinite(h):
                kinds.append(("sigma", -1, h))
            scl = layers[:, s, 2]
            if not (float(np.max(scl)) <= 0.0):
                kinds.append(("scale", int(np.argmax(scl)), float(np.max(scl))))
            if kinds:
                violating[s] = kinds
            elif self.kv_dtype == "int8":
                # clip-pressure channel, only meaningful on a clean tick
                frac = float(np.max(layers[:, s, 1]))
                if frac > self.clip_fallback_frac:
                    self._clip_streak[s] += 1
                    if self._clip_streak[s] >= self.clip_patience:
                        self._int8_fallback(s)
                else:
                    self._clip_streak[s] = 0
        if not violating:
            return
        self._sentinel_violations += len(violating)
        # device-loss aggregation BEFORE eviction mutates residency: a
        # device whose every live slot (>= the floor) tripped at once is
        # flaky hardware, not per-block corruption — retire its whole range
        if self.num_devices > 1:
            pds = self.num_slots // self.num_devices
            for d in range(self.num_devices):
                if d in self.pool._lost_devices:
                    continue
                live_d = [s for s in live if s // pds == d
                          and self._slots[s] is not None]
                viol_d = [s for s in violating if s // pds == d]
                if (len(viol_d) >= self.device_loss_min_slots
                        and len(viol_d) == len(live_d)):
                    self.pool.mark_device_lost(d)
                    self.event_log.append(
                        ("device_lost", self.step_count, d)
                    )
        # content diagnosis: quarantine + scrub the actually-corrupt blocks
        # (a flagged slot with a clean chain is collateral — its table
        # reached a poisoned block through a stale entry — and recovers the
        # same way, but its own blocks recycle normally)
        bad_blocks: set[int] = set()
        for s in violating:
            bad_blocks |= self._diagnose_chain(s)
        for b in sorted(bad_blocks):
            self.pool.quarantine_block(b)
            self.event_log.append(("quarantine", self.step_count, int(b)))
        if bad_blocks:
            self.pool.scrub_blocks(bad_blocks)
        # recovery: uniform free-and-recompute resume under the retry budget
        for s, kinds in violating.items():
            st = self._slots[s]
            rid = st.req.id
            self.event_log.append((
                "fault", self.step_count, rid, s,
                tuple(k for k, _, _ in kinds),
                tuple(lay for _, lay, _ in kinds),
            ))
            n = self._fault_retries.get(rid, 0)
            if n >= self.fault_retry_budget:
                self._finish(s, "failed")
            else:
                self._fault_retries[rid] = n + 1
                self._retries += 1
                self._fault_evict(s)

    def _diagnose_chain(self, slot: int) -> set:
        """Content-scan ``slot``'s block chain and return the physical
        blocks that are actually corrupt: fp arena tiles with nonfinite
        values, or int8 per-block scale entries that are nonfinite,
        negative, or past SCALE_SANITY_MAX.  int8 tiles themselves cannot
        encode NaN/Inf, and a bit-flipped-but-finite fp tile is below the
        GN detection floor by design — Σp = 1 holds exactly over wrong
        finite values — so finiteness is the whole content test."""
        bad: set[int] = set()
        chain = self.pool.chain_of(slot)
        if not chain:
            return bad
        ix = jnp.asarray(chain, jnp.int32)
        pulled = jax.device_get(
            jax.tree.map(lambda l: jnp.take(l, ix, axis=1),
                         self.pool.cache["layers"])
        )
        for name, arr in pulled.items():
            a = np.asarray(arr)
            if name.endswith("_scale"):
                f = a.astype(np.float64)  # (L, n)
                mask = ~np.isfinite(f) | (f < 0.0) | (f > SCALE_SANITY_MAX)
                hit = mask.any(axis=0)
            elif a.dtype == np.int8:
                continue
            else:
                f = a.astype(np.float32).reshape(a.shape[0], a.shape[1], -1)
                hit = ~np.isfinite(f).all(axis=(0, 2))
            for j, b in enumerate(chain):
                if hit[j]:
                    bad.add(int(b))
        return bad

    def _fault_evict(self, slot: int) -> None:
        """Free-and-recompute resume for a fault-flagged slot: identical to
        recompute-mode preemption (drop the chain, requeue at the head,
        re-prefill prompt + generated on resume — token-identical by the
        chunked-prefill invariant) but available under every scheduling
        policy, since the victim chose itself."""
        st = self._slots[slot]
        rid = st.req.id
        self._suspended[rid] = _Suspended(
            generated=st.generated,
            admit_step=st.admit_step,
            admit_time=st.admit_time,
            first_token_step=st.first_token_step,
            first_token_time=st.first_token_time,
            preemptions=st.preemptions + 1,
            spill=None,
        )
        self._slots[slot] = None
        self.pool.free(slot)  # doomed blocks divert to quarantine here
        self.scheduler.requeue_front(st.req)
        self._lanes_dirty = True
        self.event_log.append(("fault_evict", self.step_count, rid, slot))

    def _int8_fallback(self, slot: int) -> None:
        """Sustained int8 scale-overflow clipping: complete the request on
        the full-precision static path.  Clipping is quantizer range
        pressure, not corruption — the request's history is intact, so the
        static engine re-prefills prompt + generated-so-far in fp and
        decodes the remaining budget greedily (sampled requests fall back
        greedily too: the per-slot key stream cannot be replayed off-path).
        """
        st = self._slots[slot]
        req = st.req
        self._fallbacks += 1
        self.event_log.append(("kv_fallback", self.step_count, req.id, slot))
        seq = np.concatenate([
            np.asarray(req.tokens, np.int32),
            np.asarray(st.generated, np.int32),
        ])
        remaining = req.max_new_tokens - len(st.generated)
        reason = "length"
        if remaining > 0:
            batch = {"tokens": jnp.asarray(seq)[None]}
            for k in req.extras:
                batch[k] = jnp.asarray(req.extras[k])[None]
            gcfg = dataclasses.replace(
                self.cfg, max_new_tokens=remaining, temperature=0.0
            )
            row = np.asarray(generate(self.model, self.params, batch, gcfg))[0]
            gen = list(st.generated)
            for tok in row[seq.shape[0]:]:
                gen.append(int(tok))
                if req.stop_token is not None and int(tok) == req.stop_token:
                    reason = "stop"
                    break
            st.generated = gen
        self._finish(slot, reason)

    # ----------------------------------------------------------- main loop --
    def step(self) -> bool:
        """One engine tick.  Returns False once fully drained (no active
        slot, nothing queued)."""
        if self.paged and self.sentinels:
            # block-table redundancy check BEFORE the mirror is pushed to
            # device: the host chain (_slot_blocks) is authoritative, the
            # flat table row is derived — a scribbled entry is repaired in
            # place and the scribble never reaches a device gather.
            self._check_tables()
        self._admit()
        live = [s for s, st in enumerate(self._slots) if st is not None]
        if not live:
            if self.scheduler.has_pending():
                # Idle fast-forward: no slot is live and every queued
                # request's arrival is in the future, so jump the clock
                # straight to the next arrival.  Replay-identical to
                # burning the ticks one by one — nothing observable (no
                # arrival, admission, shed or decode) can happen on a
                # skipped tick, and the shed scan stops at the first
                # not-yet-arrived request so it could not have fired.
                nxt = self.scheduler.next_ready_step()
                self.step_count = max(
                    self.step_count + 1, nxt if nxt is not None else 0
                )
                return True
            return False

        prefills = [s for s in live if self._slots[s].phase == "prefilling"]
        decoders = [s for s in live if self._slots[s].phase == "decoding"]
        if self._lanes_dirty:  # residency changed: refresh device mirrors
            self._active_dev = self._put(
                jnp.asarray(np.array([st is not None for st in self._slots])),
                self._sh_slot,
            )
            self._temps_dev = self._put(jnp.asarray(self._temps), self._sh_slot)
            self._pos_dev = self._put(jnp.asarray(self.pool.positions), self._sh_slot)
            self._lanes_dirty = False

        takes: dict[int, int] = {}
        for s in prefills:
            st = self._slots[s]
            takes[s] = min(self.chunk, st.prefill_len - st.written)
        paged_args = ()
        if self.paged:
            # allocate blocks for the positions this tick will write, then
            # refresh the device table mirror only if residency grew
            for s in live:
                self.pool.ensure(s, int(self.pool.positions[s]) + takes.get(s, 1))
                if self.prefix is not None:
                    # COW assertion: the block this tick's first write lands
                    # in must be privately owned (attach-time forking makes
                    # shared-block writes impossible by construction)
                    self.pool.write_barrier(s, int(self.pool.positions[s]))
            if self.pool.tables_dirty:
                self._tables_dev = self._put(
                    jnp.asarray(self.pool.tables), self._sh_row
                )
                self._tables_sliced.clear()
                self.pool.tables_dirty = False
            # Horizon bucketing: slice the traced tables to the smallest
            # grid bucket covering the live block horizon, so the paged
            # reads (streamed tiles / kernel grid) touch only live context.
            # A new bucket is a new tick shape -> one extra compilation,
            # bounded by len(horizon_bucket_grid) per step kind.
            horizon = self.pool.active_horizon_blocks()
            bucket = next(b for b in self.horizon_bucket_grid if b >= horizon)
            self._buckets_seen["fused" if prefills else "decode"].add(bucket)
            self.horizon_log.append((horizon, bucket))
            self._attended_tokens += bucket * self.pool.block_size
            sliced = self._tables_sliced.get(bucket)
            if sliced is None:
                sliced = self._tables_sliced[bucket] = self._tables_dev[:, :bucket]
            paged_args = (sliced,)
        if prefills:
            chunk_toks = np.zeros((self.num_slots, self.chunk), np.int32)
            n_valid = np.ones(self.num_slots, np.int32)
            is_pref = np.zeros(self.num_slots, bool)
            for s in prefills:
                st = self._slots[s]
                chunk_toks[s] = st.padded[st.written : st.written + self.chunk]
                n_valid[s] = takes[s]
                is_pref[s] = True
            # Explicit uploads: every tick operand is a committed device
            # array before dispatch, so the transfer guard below can
            # disallow *implicit* host->device transfers — an accidental
            # numpy arg (a silent per-tick upload) fails loudly instead of
            # slowly.  ``repro.analysis`` audits the same invariant
            # statically (A-TRANSFER).
            chunk_dev = self._put(jnp.asarray(chunk_toks), self._sh_row)
            nv_dev = self._put(jnp.asarray(n_valid), self._sh_slot)
            pref_dev = self._put(jnp.asarray(is_pref), self._sh_slot)
            with jax.transfer_guard_host_to_device("disallow"):
                self._guarded_ticks += 1
                outs = self._fused(
                    self.params, self.pool.cache, self._last_logits, chunk_dev,
                    self._pos_dev, nv_dev, pref_dev, self._active_dev,
                    self._temps_dev, self._key, *paged_args,
                )
            # rebind the donated operands immediately after the call that
            # invalidated them, per branch — never across the if/else join
            nxt, self._last_logits, self.pool.cache, self._pos_dev, self._key = (
                outs[:5])
            self._fused_ticks += 1
        else:  # steady state: every live slot decodes -> the (N, 1) step
            with jax.transfer_guard_host_to_device("disallow"):
                self._guarded_ticks += 1
                outs = self._decode(
                    self.params, self.pool.cache, self._last_logits,
                    self._pos_dev, self._active_dev, self._temps_dev, self._key,
                    *paged_args,
                )
            nxt, self._last_logits, self.pool.cache, self._pos_dev, self._key = (
                outs[:5])
        if self.sentinels:
            # one fetch for token + health: the health word rides the tick's
            # existing device->host download, no extra transfer
            toks, health = jax.device_get((nxt, outs[5]))
        else:
            toks, health = jax.device_get(nxt), None
        self.pool.advance({s: takes.get(s, 1) for s in live})
        self._active_steps += len(live)
        self._prefill_lane_steps += len(prefills)
        self._decode_steps += 1
        self._generated += len(decoders)
        self.phase_log.append((len(prefills), len(decoders)))

        if health is not None:
            # sentinel scan runs BEFORE token append: a violating slot's
            # tick output is garbage, so its token must never land in
            # st.generated — the slot is evicted (recompute resume) or
            # failed here, and the loops below skip it (st is None).
            self._sentinel_scan(health, live)

        for slot in prefills:
            st = self._slots[slot]
            if st is None:  # fault-evicted this tick
                continue
            st.written += takes[slot]
            if st.written == st.prefill_len:
                st.phase = "decoding"  # first token samples next tick
                if self.prefix is not None:
                    bs = self.pool.block_size
                    self._prefix_insert(slot, (st.req.prompt_len // bs) * bs)
        for slot in decoders:
            st = self._slots[slot]
            if st is None:  # fault-evicted (or fell back) this tick
                continue
            tok = int(toks[slot])
            st.generated.append(tok)
            if len(st.generated) == 1:
                st.first_token_step = self.step_count
                st.first_token_time = time.time()
            reason = None
            if st.req.stop_token is not None and tok == st.req.stop_token:
                reason = "stop"
            elif len(st.generated) >= st.req.max_new_tokens:
                reason = "length"
            if reason:
                self._finish(slot, reason)
        self.step_count += 1
        return True

    def run(self, requests: Sequence[Request]) -> list[Completion]:
        """Serve a workload to completion; returns completions in finish
        order."""
        for req in requests:
            self.submit(req)
        # 2x per-request work: a preempted request pays (part of) its
        # prefill again on resume; the 10k constant absorbs pathological
        # preemption churn beyond that
        budget = 10_000 + 2 * sum(
            r.arrival_step + r.max_new_tokens + -(-r.prompt_len // self.chunk)
            for r in requests
        )
        while self.step():
            if self.step_count > budget:
                raise RuntimeError("ContinuousEngine failed to drain workload")
        return self.completions

    # ------------------------------------------------------------ snapshots --
    # Crash-consistent engine snapshots over checkpoint/store.py's atomic
    # npz + manifest format.  snapshot() may only be called between ticks —
    # step() boundaries are the engine's only consistent points — and
    # serializes EVERYTHING the next tick reads: arenas/scales (or slabs),
    # block tables and the whole pool ledger (including FIFO free-list
    # ORDER, which replay identity leans on), held logits, the PRNG key,
    # scheduler queues, live-slot and suspended-request state, completions,
    # counters and the event log.  restore() onto a compatibly-constructed
    # engine resumes greedy-token-identically: same values + same order +
    # same key => same tokens (verified by the kill-at-every-tick test).

    def _topology(self) -> dict:
        t = {
            "family": self.model.cfg.family,
            "norm_impl": self.model.cfg.norm_impl,
            "num_slots": self.num_slots,
            "max_seq": self.max_seq,
            "chunk": self.chunk,
            "paged": self.paged,
            "kv_dtype": self.kv_dtype,
            "num_devices": self.num_devices,
            "sched": self.sched_policy,
            "preempt": self.preempt_mode,
            "seed": self.cfg.seed,
            "sentinels": self.sentinels,
        }
        if self.paged:
            t["block_size"] = self.pool.block_size
            t["num_blocks"] = self.pool.num_blocks
        return t

    @staticmethod
    def _req_arrays(req: Request, tree: dict, prefix: str) -> dict:
        tree[f"{prefix}/tokens"] = np.asarray(req.tokens, np.int32)
        if req.padded_tokens is not None:
            tree[f"{prefix}/padded"] = np.asarray(req.padded_tokens, np.int32)
        for k, v in req.extras.items():
            tree[f"{prefix}/extras/{k}"] = np.asarray(v)
        return {
            "max_new_tokens": req.max_new_tokens,
            "temperature": req.temperature,
            "stop_token": req.stop_token,
            "arrival_step": req.arrival_step,
            "prefix_hint": req.prefix_hint,
            "req_class": req.req_class,
            "has_padded": req.padded_tokens is not None,
            "extras_keys": sorted(req.extras.keys()),
        }

    def snapshot(self, path) -> "str":
        """Write a crash-consistent snapshot under ``path`` (atomic: a kill
        mid-save never corrupts an existing snapshot).  Returns the
        checkpoint directory.  Unsupported with an attached prefix cache
        (the radix index is not serialized)."""
        if self.prefix is not None:
            raise ValueError(
                "snapshot with an attached prefix cache is not supported"
            )
        tree: dict = {
            "cache": jax.device_get(self.pool.cache),
            "last_logits": np.asarray(self._last_logits),
            "key": np.asarray(self._key),
            "temps": np.asarray(self._temps),
            "positions": np.asarray(self.pool.positions),
            "clip_streak": np.asarray(self._clip_streak),
            "device_admits": np.asarray(self._device_admits),
        }
        requests: dict[int, Request] = {}
        slots_meta = {}
        for s, st in enumerate(self._slots):
            if st is None:
                continue
            requests[st.req.id] = st.req
            if st.padded is not None:
                tree[f"slot/{s}/padded"] = np.asarray(st.padded, np.int32)
            slots_meta[str(s)] = {
                "rid": st.req.id,
                "admit_step": st.admit_step,
                "admit_time": st.admit_time,
                "generated": [int(t) for t in st.generated],
                "phase": st.phase,
                "written": st.written,
                "prefill_len": st.prefill_len,
                "first_token_step": st.first_token_step,
                "first_token_time": st.first_token_time,
                "preemptions": st.preemptions,
                "has_padded": st.padded is not None,
            }
        if self.sched_policy == "priority":
            sched_meta = {
                "queues": {
                    c: [r.id for r in q]
                    for c, q in self.scheduler._queues.items()
                },
                "resumed": sorted(self.scheduler._resumed),
                "shed_count": self.scheduler.shed_count,
            }
            queued = [r for q in self.scheduler._queues.values() for r in q]
        else:
            sched_meta = {"queue": [r.id for r in self.scheduler._queue]}
            queued = list(self.scheduler._queue)
        for r in queued:
            requests[r.id] = r
        sched_meta["next_id"] = self.scheduler._next_id
        sched_meta["pad_tokens"] = self.scheduler._pad_tokens
        req_meta = {
            str(rid): self._req_arrays(r, tree, f"req/{rid}")
            for rid, r in requests.items()
        }
        sus_meta = {}
        for rid, sus in self._suspended.items():
            spill_meta = None
            if sus.spill is not None:
                sp = sus.spill
                spill_meta = {
                    "position": sp["position"],
                    "written": sp["written"],
                    "prefill_len": sp["prefill_len"],
                    "phase": sp["phase"],
                    "has_padded": sp["padded"] is not None,
                }
                tree[f"sus/{rid}/last_logits"] = np.asarray(sp["last_logits"])
                if sp["padded"] is not None:
                    tree[f"sus/{rid}/padded"] = np.asarray(sp["padded"], np.int32)
                if self.paged:
                    spill_meta["len"] = sp["kv"]["len"]
                    if sp["kv"]["layers"] is not None:
                        tree[f"sus/{rid}/kv"] = sp["kv"]["layers"]
                else:
                    tree[f"sus/{rid}/kv"] = sp["kv"]
            sus_meta[str(rid)] = {
                "generated": [int(t) for t in sus.generated],
                "admit_step": sus.admit_step,
                "admit_time": sus.admit_time,
                "first_token_step": sus.first_token_step,
                "first_token_time": sus.first_token_time,
                "preemptions": sus.preemptions,
                "spill": spill_meta,
            }
        comp_meta = []
        for i, c in enumerate(self.completions):
            tree[f"comp/{i}/prompt"] = np.asarray(c.prompt_tokens, np.int32)
            tree[f"comp/{i}/new"] = np.asarray(c.new_tokens, np.int32)
            comp_meta.append({
                "request_id": c.request_id,
                "finish_reason": c.finish_reason,
                "arrival_step": c.arrival_step,
                "admit_step": c.admit_step,
                "first_token_step": c.first_token_step,
                "finish_step": c.finish_step,
                "admit_time": c.admit_time,
                "first_token_time": c.first_token_time,
                "finish_time": c.finish_time,
                "req_class": c.req_class,
                "preemptions": c.preemptions,
            })
        if self.paged:
            pool_meta = {
                "free_slots": list(self.pool._free_slots),
                "used": sorted(self.pool._used),
                "slot_blocks": {
                    str(s): list(ch)
                    for s, ch in self.pool._slot_blocks.items()
                },
                "free_blocks": [list(q) for q in self.pool._free_blocks],
                "quarantined": sorted(self.pool.quarantined),
                "doomed": sorted(self.pool._doomed),
                "lost_devices": sorted(self.pool._lost_devices),
                "peak_blocks_in_use": self.pool.peak_blocks_in_use,
                "peak_blocks_reserved": self.pool.peak_blocks_reserved,
                "peak_reserved_per_device": [
                    int(x) for x in self.pool.peak_reserved_per_device
                ],
                "peak_used_per_device": [
                    int(x) for x in self.pool.peak_used_per_device
                ],
            }
            tree["tables"] = np.asarray(self.pool.tables)
            tree["refcounts"] = np.asarray(self.pool.refcounts)
            tree["reserved"] = np.asarray(self.pool._reserved)
            tree["shared"] = np.asarray(self.pool._shared)
            tree["owned"] = np.asarray(self.pool._owned)
        else:
            pool_meta = {
                "free": list(self.pool._free),
                "used": sorted(self.pool._used),
            }
        extra = {
            "topology": self._topology(),
            "step_count": self.step_count,
            "counters": {
                "active_steps": self._active_steps,
                "decode_steps": self._decode_steps,
                "fused_ticks": self._fused_ticks,
                "prefill_lane_steps": self._prefill_lane_steps,
                "generated": self._generated,
                "guarded_ticks": self._guarded_ticks,
                "attended_tokens": self._attended_tokens,
                "preemptions": self._preemptions,
                "resumes": self._resumes,
                "rejections": self._rejections,
                "sentinel_checks": self._sentinel_checks,
                "sentinel_violations": self._sentinel_violations,
                "retries": self._retries,
                "fallbacks": self._fallbacks,
                "table_repairs": self._table_repairs,
            },
            "phase_log": [list(x) for x in self.phase_log],
            "horizon_log": [list(x) for x in self.horizon_log],
            "buckets_seen": {
                k: sorted(v) for k, v in self._buckets_seen.items()
            },
            "event_log": [list(e) for e in self.event_log],
            "fault_retries": {
                str(k): v for k, v in self._fault_retries.items()
            },
            "slots": slots_meta,
            "requests": req_meta,
            "scheduler": sched_meta,
            "suspended": sus_meta,
            "completions": comp_meta,
            "pool": pool_meta,
        }
        return str(ckpt_store.save(path, self.step_count, tree, extra=extra))

    @staticmethod
    def _nest(flat: dict, prefix: str) -> dict:
        """Rebuild a nested dict from flat ``prefix/...`` keys (digit path
        components become int keys — SSM carry trees index layers by int)."""
        out: dict = {}
        for name, arr in flat.items():
            if not name.startswith(prefix):
                continue
            parts = [
                int(p) if p.isdigit() else p
                for p in name[len(prefix):].split("/")
            ]
            d = out
            for p in parts[:-1]:
                d = d.setdefault(p, {})
            d[parts[-1]] = arr
        return out

    def _restore_request(self, flat: dict, rid: int, meta: dict) -> Request:
        return Request(
            tokens=np.asarray(flat[f"req/{rid}/tokens"], np.int32),
            max_new_tokens=meta["max_new_tokens"],
            temperature=meta["temperature"],
            stop_token=meta["stop_token"],
            arrival_step=meta["arrival_step"],
            extras={
                k: np.asarray(flat[f"req/{rid}/extras/{k}"])
                for k in meta["extras_keys"]
            },
            id=rid,
            padded_tokens=(
                np.asarray(flat[f"req/{rid}/padded"], np.int32)
                if meta["has_padded"] else None
            ),
            prefix_hint=meta["prefix_hint"],
            req_class=meta["req_class"],
        )

    def restore(self, path, step: Optional[int] = None) -> None:
        """Restore a ``snapshot`` into this engine (freshly constructed with
        the same model/params and a matching topology).  ``step`` defaults
        to the latest snapshot under ``path``.  After restore the engine
        continues greedy-token-identically to the run that wrote it."""
        if step is None:
            step = ckpt_store.latest_step(path)
            if step is None:
                raise FileNotFoundError(f"no snapshot under {path}")
        flat, manifest = ckpt_store.restore_flat(path, step)
        extra = manifest["extra"]
        want = extra["topology"]
        have = self._topology()
        diff = {k: (v, have.get(k)) for k, v in want.items() if have.get(k) != v}
        if diff:
            raise ValueError(f"snapshot topology mismatch: {diff}")
        self.reset()
        # --- device state -------------------------------------------------
        leaves, treedef = jax.tree_util.tree_flatten_with_path(self.pool.cache)
        vals = []
        for kpath, leaf in leaves:
            name = "cache/" + "/".join(
                str(getattr(p, "key", getattr(p, "idx", p))) for p in kpath
            )
            # match the fresh leaf's commitment, not just its sharding: an
            # uncommitted leaf re-placed with an explicit device_put comes
            # back *committed*, which is part of the pjit compilation-cache
            # key — every warmed tick entry would silently recompile
            new = jnp.asarray(flat[name], leaf.dtype)
            vals.append(jax.device_put(new, leaf.sharding)
                        if leaf.committed else new)
        self.pool.cache = jax.tree_util.tree_unflatten(treedef, vals)
        self._last_logits = self._put(
            jnp.asarray(flat["last_logits"]), self._sh_row
        )
        self._key = self._put(jnp.asarray(flat["key"]), self._sh_rep)
        self._temps = np.array(flat["temps"], np.float32)
        self.pool.positions[:] = flat["positions"]
        self._clip_streak = np.array(flat["clip_streak"], np.int32)
        self._device_admits = np.array(flat["device_admits"], np.int64)
        self._lanes_dirty = True  # step() refreshes pos/active/temps mirrors
        # --- pool ledger --------------------------------------------------
        import collections
        p = extra["pool"]
        if self.paged:
            self.pool.tables[:] = flat["tables"]
            self.pool.tables_dirty = True
            self.pool.refcounts = np.array(flat["refcounts"], np.int32)
            self.pool._reserved = np.array(flat["reserved"], np.int32)
            self.pool._shared = np.array(flat["shared"], np.int32)
            self.pool._owned = np.array(flat["owned"], np.int32)
            self.pool._free_slots = collections.deque(p["free_slots"])
            self.pool._used = set(p["used"])
            self.pool._slot_blocks = {
                int(s): list(ch) for s, ch in p["slot_blocks"].items()
            }
            self.pool._free_blocks = [
                collections.deque(q) for q in p["free_blocks"]
            ]
            self.pool.quarantined = set(p["quarantined"])
            self.pool._doomed = set(p["doomed"])
            self.pool._lost_devices = set(p["lost_devices"])
            self.pool.peak_blocks_in_use = p["peak_blocks_in_use"]
            self.pool.peak_blocks_reserved = p["peak_blocks_reserved"]
            self.pool.peak_reserved_per_device = np.array(
                p["peak_reserved_per_device"], np.int64
            )
            self.pool.peak_used_per_device = np.array(
                p["peak_used_per_device"], np.int64
            )
            self.pool.check_ledger()
        else:
            self.pool._free = collections.deque(p["free"])
            self.pool._used = set(p["used"])
        # --- requests / scheduler / slots / suspended ---------------------
        reqs = {
            int(rid): self._restore_request(flat, int(rid), m)
            for rid, m in extra["requests"].items()
        }
        sm = extra["scheduler"]
        self.scheduler._next_id = sm["next_id"]
        self.scheduler._pad_tokens = sm["pad_tokens"]
        if self.sched_policy == "priority":
            for c, ids in sm["queues"].items():
                self.scheduler._queues[c] = collections.deque(
                    reqs[rid] for rid in ids
                )
            self.scheduler._resumed = set(sm["resumed"])
            self.scheduler.shed_count = sm["shed_count"]
        else:
            self.scheduler._queue = collections.deque(
                reqs[rid] for rid in sm["queue"]
            )
        for s_str, m in extra["slots"].items():
            s = int(s_str)
            self._slots[s] = _SlotState(
                req=reqs[m["rid"]],
                admit_step=m["admit_step"],
                admit_time=m["admit_time"],
                generated=list(m["generated"]),
                phase=m["phase"],
                padded=(
                    np.asarray(flat[f"slot/{s}/padded"], np.int32)
                    if m["has_padded"] else None
                ),
                written=m["written"],
                prefill_len=m["prefill_len"],
                first_token_step=m["first_token_step"],
                first_token_time=m["first_token_time"],
                preemptions=m["preemptions"],
            )
        for rid_str, m in extra["suspended"].items():
            rid = int(rid_str)
            spill = None
            if m["spill"] is not None:
                sp = m["spill"]
                if self.paged:
                    layers = self._nest(flat, f"sus/{rid}/kv/") or None
                    kv = {"len": sp["len"], "layers": layers}
                else:
                    kv = self._nest(flat, f"sus/{rid}/kv/")
                spill = {
                    "kv": kv,
                    "position": sp["position"],
                    "padded": (
                        np.asarray(flat[f"sus/{rid}/padded"], np.int32)
                        if sp["has_padded"] else None
                    ),
                    "written": sp["written"],
                    "prefill_len": sp["prefill_len"],
                    "phase": sp["phase"],
                    "last_logits": np.asarray(flat[f"sus/{rid}/last_logits"]),
                }
            self._suspended[rid] = _Suspended(
                generated=list(m["generated"]),
                admit_step=m["admit_step"],
                admit_time=m["admit_time"],
                first_token_step=m["first_token_step"],
                first_token_time=m["first_token_time"],
                preemptions=m["preemptions"],
                spill=spill,
            )
        # --- completions / logs / counters --------------------------------
        for i, m in enumerate(extra["completions"]):
            self.completions.append(Completion(
                request_id=m["request_id"],
                prompt_tokens=np.asarray(flat[f"comp/{i}/prompt"], np.int32),
                new_tokens=np.asarray(flat[f"comp/{i}/new"], np.int32),
                finish_reason=m["finish_reason"],
                arrival_step=m["arrival_step"],
                admit_step=m["admit_step"],
                first_token_step=m["first_token_step"],
                finish_step=m["finish_step"],
                admit_time=m["admit_time"],
                first_token_time=m["first_token_time"],
                finish_time=m["finish_time"],
                req_class=m["req_class"],
                preemptions=m["preemptions"],
            ))
        def detuple(e):
            return tuple(detuple(x) if isinstance(x, list) else x for x in e)
        self.event_log = [detuple(e) for e in extra["event_log"]]
        self.phase_log = [tuple(x) for x in extra["phase_log"]]
        self.horizon_log = [tuple(x) for x in extra["horizon_log"]]
        self._buckets_seen = {
            k: set(v) for k, v in extra["buckets_seen"].items()
        }
        self._fault_retries = {
            int(k): v for k, v in extra["fault_retries"].items()
        }
        c = extra["counters"]
        self.step_count = extra["step_count"]
        self._active_steps = c["active_steps"]
        self._decode_steps = c["decode_steps"]
        self._fused_ticks = c["fused_ticks"]
        self._prefill_lane_steps = c["prefill_lane_steps"]
        self._generated = c["generated"]
        self._guarded_ticks = c["guarded_ticks"]
        self._attended_tokens = c["attended_tokens"]
        self._preemptions = c["preemptions"]
        self._resumes = c["resumes"]
        self._rejections = c["rejections"]
        self._sentinel_checks = c["sentinel_checks"]
        self._sentinel_violations = c["sentinel_violations"]
        self._retries = c["retries"]
        self._fallbacks = c["fallbacks"]
        self._table_repairs = c["table_repairs"]

    # -------------------------------------------------------------- metrics --
    def device_occupancy(self) -> list[int]:
        """Live (admitted) slots per device range right now — the quantity
        least-loaded placement balances."""
        pds = self.num_slots // self.num_devices
        return [
            sum(st is not None for st in self._slots[d * pds : (d + 1) * pds])
            for d in range(self.num_devices)
        ]

    @property
    def shard_balance(self) -> float:
        """Admission balance across device slot ranges: min/max of per-device
        admitted-request counts (1.0 = perfectly balanced, and trivially 1.0
        single-device).  The bench tracks it next to num_devices so a
        placement regression (one hot device hoarding admissions) shows up
        in the history trajectory."""
        if self.num_devices == 1 or self._device_admits.max() == 0:
            return 1.0
        return float(self._device_admits.min() / self._device_admits.max())

    def metrics(self) -> dict:
        util = self._active_steps / max(1, self._decode_steps * self.num_slots)
        pref = self._prefill_lane_steps / max(1, self._active_steps)
        out = {
            "decode_steps": self._decode_steps,
            "generated_tokens": self._generated,
            "mean_slot_utilization": util,
            "prefill_lane_fraction": pref,
            "fused_ticks": self._fused_ticks,
            "completions": len(self.completions),
            "chunk": self.chunk,
            "intake_padding": getattr(self.scheduler, "intake_padding", 0),
            # CountingJit: always ints (one trace == one compilation).
            # Slab pools: fused=1 / decode<=1.  Paged pools: exactly one
            # trace per (step kind, horizon bucket actually seen) — i.e.
            # len(fused_buckets) / len(decode_buckets), bounded by
            # len(horizon_bucket_grid) each.
            "decode_compilations": self._decode.compilations,
            "fused_step_compilations": self._fused.compilations,
            # chunked prefill rides the fused step: _length_prefills stays
            # empty unless a fallback reintroduces per-length tracing.  The
            # attribute access is deliberately strict: registering a plain
            # jax.jit here would silently count 0 — wrap it in CountingJit.
            "prefill_compilations": sum(
                f.compilations for f in self._length_prefills.values()
            ),
            "kv_paged": self.paged,
            "kv_hbm_bytes": self.pool.hbm_bytes(),
            "transfer_guarded_ticks": self._guarded_ticks,
            # SLA control plane: policy knobs + the preemption/shedding
            # counters the sla bench scenario reports per configuration
            "sched": self.sched_policy,
            "preempt_mode": self.preempt_mode,
            "preemptions": self._preemptions,
            "preempt_resumes": self._resumes,
            "rejections": self._rejections,
            "shed_count": getattr(self.scheduler, "shed_count", 0),
            # fault tolerance: sentinel probe telemetry + recovery counters
            # (docs/serving.md §Fault tolerance).  sentinel_checks counts
            # (slot, tick) health evaluations; violations count tripped
            # slots plus table repairs; retries/fallbacks count the two
            # recovery paths actually taken.
            "sentinels": self.sentinels,
            "sentinel_checks": self._sentinel_checks,
            "sentinel_violations": self._sentinel_violations,
            "quarantined_blocks": (
                len(self.pool.quarantined) if self.paged else 0
            ),
            "retries": self._retries,
            "fallbacks": self._fallbacks,
            "table_repairs": self._table_repairs,
            "failed_completions": sum(
                1 for c in self.completions if c.finish_reason == "failed"
            ),
            # slot-pool sharding over the batch axis (devices=1 -> one range,
            # balance trivially 1.0; see docs/serving.md §Device mesh)
            "num_devices": self.num_devices,
            "per_device_slots": self.num_slots // self.num_devices,
            "shard_balance": self.shard_balance,
            "device_admits": [int(n) for n in self._device_admits],
        }
        if self.paged:
            out.update(
                block_size=self.pool.block_size,
                num_blocks=self.pool.num_blocks,
                peak_blocks_in_use=self.pool.peak_blocks_in_use,
                peak_blocks_reserved=self.pool.peak_blocks_reserved,
                block_utilization=(
                    self.pool.peak_blocks_in_use / max(1, self.pool.num_blocks)
                ),
                # which gather-free read the tick ran (pallas/streamed;
                # 'gathered' only under a forced/baseline fallback) and the
                # horizon-bucketing trajectory: the grid, the buckets each
                # step kind actually traced (compile counters are exactly
                # one per (kind, bucket) -> the documented upper bound),
                # and the mean attended stream width per tick — the
                # quantity that now scales with live tokens, not max_seq
                read_path=self.model.paged_read_path,
                kv_dtype=self.pool.kv_dtype,
                horizon_bucket_grid=list(self.horizon_bucket_grid),
                horizon_buckets=sorted(
                    self._buckets_seen["fused"] | self._buckets_seen["decode"]
                ),
                fused_buckets=sorted(self._buckets_seen["fused"]),
                decode_buckets=sorted(self._buckets_seen["decode"]),
                mean_attended_tokens_per_tick=(
                    self._attended_tokens / max(1, self._decode_steps)
                ),
                prefix_cache=self.prefix is not None,
            )
            if self.prefix is not None:
                out.update(
                    # token-weighted: cached prompt tokens / admitted prompt
                    # tokens — the bench's headline hit metric
                    prefix_hit_rate=(
                        self._prefix_hit_tokens
                        / max(1, self._prefix_prompt_tokens)
                    ),
                    prefix_hit_tokens=self._prefix_hit_tokens,
                    prefix_prompt_tokens=self._prefix_prompt_tokens,
                    prefix_hit_requests=self._prefix_hit_requests,
                    prefix_forks=self.pool.prefix_forks,
                    prefix_evictions=self.pool.prefix_evictions,
                    prefix_cached_blocks=self.pool.cached_blocks,
                    prefix_inserts=self.prefix.inserts,
                )
        else:
            out["read_path"] = "slab"
            out["kv_dtype"] = "fp"
        return out
