"""Deterministic fault injection for the block-paged serving engine.

The injector corrupts engine state *between ticks* — every fault is a host
mutation re-uploaded with ``jax.device_put`` under the leaf's original
sharding, so the jitted tick bodies stay compile-once (no shape, dtype, or
sharding ever changes; the tick re-reads the same buffers it always does).
Target selection is seeded (``np.random.default_rng``): the same seed over
the same workload replays the identical fault sequence, which is what lets
the chaos tests assert exact detection latency and recovery identity.

Fault classes (``FaultInjector.KINDS``), mapped to the sentinel channel
that catches them:

``nan_tile`` / ``inf_tile``
    Poison one (layer, block) arena tile of a live slot's chain with
    NaN/Inf.  fp arenas only — int8 has no NaN encoding (by construction a
    quantized arena cannot carry nonfinite payloads; ``scale`` is the int8
    corruption channel).  Caught by the Σp probe's finiteness channels:
    NaN K surfaces in the scores, NaN V in the attention output.  The GN
    softmax itself *launders* NaN scores into a finite Σp = 1 distribution,
    so the residual alone would miss it — the explicit nonfinite checks are
    load-bearing.
``scale``
    Corrupt one per-block int8 dequant scale with a draw from
    {NaN, +Inf, -1.0, 1e6}.  Caught by the scale-sanity channel
    (nonfinite | negative | > SCALE_SANITY_MAX over the live horizon).
``table``
    Scribble one live block-table entry to point at a different (valid)
    physical block.  Caught by the engine's host-side redundancy check
    against the authoritative chain at the top of ``step()`` — repaired in
    place before the tick reads it, so nothing propagates.
``bit_flip``
    Flip one low-order mantissa bit of one arena element.  Documented
    DETECTION FLOOR: the GN softmax renormalizes any finite score set to
    Σp = 1 exactly, so a single-ulp perturbation produces a valid
    distribution over almost-right values — below every sentinel's
    threshold by design.  The injector records it (``detectable=False``)
    so chaos sweeps can report the miss rate honestly instead of counting
    it against detection latency.
``device_loss``
    Poison the entire block range owned by one device (fp arenas), so
    every live slot on that device violates in the same tick — the
    engine's aggregation declares the device lost, quarantines its whole
    range, and retires its slots from admission.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class FaultRecord:
    """One injected fault: what was corrupted, where, and at which tick
    (``step`` is the engine's step_count at injection time — detection
    latency is measured against it)."""

    kind: str
    step: int
    slot: int = -1      # victim slot (-1: not slot-targeted)
    block: int = -1     # physical block id (-1: not block-targeted)
    layer: int = -1     # arena layer index (-1: all / n.a.)
    leaf: str = ""      # arena leaf name ('k', 'v', 'k_scale', ...)
    device: int = -1    # device_loss only
    value: str = ""     # poison value ('nan', 'inf', '-1.0', '1e6', ...)
    detectable: bool = True


class FaultInjector:
    """Seeded between-tick fault injector over a ``ContinuousEngine``.

    Usage::

        inj = FaultInjector(engine, seed=0)
        rec = inj.inject("nan_tile")   # or inject() for a seeded mix
        engine.step()                  # sentinel must flag within this tick

    ``inject`` returns None when no viable target exists yet (no live slot
    with committed KV) — callers step the engine and retry.  All records
    accumulate in ``self.records``.
    """

    KINDS = ("nan_tile", "inf_tile", "scale", "table", "bit_flip",
             "device_loss")

    def __init__(self, engine, seed: int = 0,
                 kinds: Optional[tuple] = None):
        if not engine.paged:
            raise ValueError("FaultInjector targets the block-paged pool")
        self.engine = engine
        self.rng = np.random.default_rng(seed)
        self.kinds = tuple(kinds) if kinds else self.KINDS
        for k in self.kinds:
            if k not in self.KINDS:
                raise ValueError(f"unknown fault kind {k!r}")
        self.records: list[FaultRecord] = []

    # ------------------------------------------------------------- targets --
    def _live_slots(self) -> list[int]:
        """Live slots whose chains hold at least one committed block."""
        eng = self.engine
        out = []
        for s, st in enumerate(eng._slots):
            if st is None:
                continue
            if int(eng.pool.positions[s]) > 0 and eng.pool.chain_of(s):
                out.append(s)
        return out

    def _pick_block(self, slot: int) -> int:
        """A physical block inside the slot's *attended* horizon — blocks
        past blocks_for(position) are never read, so poisoning them would
        be undetectable by construction (and meaningless)."""
        pool = self.engine.pool
        chain = pool.chain_of(slot)
        n = max(1, min(len(chain), pool.blocks_for(int(pool.positions[slot]))))
        return int(chain[self.rng.integers(n)])

    def _arena_items(self, want_scale: bool) -> list[tuple[str, object]]:
        layers = self.engine.pool.cache["layers"]
        return [(k, v) for k, v in sorted(layers.items())
                if k.endswith("_scale") == want_scale]

    def _write_leaf(self, name: str, arr: np.ndarray) -> None:
        """Re-upload one mutated arena leaf under its original sharding —
        the only device write the injector ever performs."""
        pool = self.engine.pool
        old = pool.cache["layers"][name]
        # preserve the leaf's commitment: device_put commits, and a
        # committed leaf where an uncommitted one is expected changes the
        # tick's pjit compilation key — the injector must perturb *values*,
        # never the compile story (the chaos bench measures recovery cost,
        # not recompiles)
        new = jnp.asarray(arr, old.dtype)
        if old.committed:
            new = jax.device_put(new, old.sharding)
        pool.cache = {**pool.cache,
                      "layers": {**pool.cache["layers"], name: new}}

    # ----------------------------------------------------------- injection --
    def inject(self, kind: Optional[str] = None) -> Optional[FaultRecord]:
        """Inject one fault.  ``kind`` defaults to a seeded draw from the
        configured mix.  Returns the FaultRecord, or None if no viable
        target exists this tick (caller: step and retry)."""
        if kind is None:
            kind = self.kinds[self.rng.integers(len(self.kinds))]
        rec = getattr(self, f"_inject_{kind}")()
        if rec is not None:
            self.records.append(rec)
        return rec

    def _poison_tile(self, kind: str, value: float) -> Optional[FaultRecord]:
        eng = self.engine
        slots = self._live_slots()
        if not slots:
            return None
        items = self._arena_items(want_scale=False)
        name, leaf = items[self.rng.integers(len(items))]
        if not jnp.issubdtype(leaf.dtype, jnp.floating):
            raise ValueError(
                f"{kind} targets fp arenas; the {leaf.dtype} arena cannot "
                "encode nonfinite payloads — use 'scale' against int8")
        slot = int(slots[self.rng.integers(len(slots))])
        block = self._pick_block(slot)
        layer = int(self.rng.integers(leaf.shape[0]))
        arr = np.asarray(leaf).copy()
        arr[layer, block] = value
        self._write_leaf(name, arr)
        return FaultRecord(kind=kind, step=eng.step_count, slot=slot,
                           block=block, layer=layer, leaf=name,
                           value=kind[:3])

    def _inject_nan_tile(self) -> Optional[FaultRecord]:
        return self._poison_tile("nan_tile", np.nan)

    def _inject_inf_tile(self) -> Optional[FaultRecord]:
        return self._poison_tile("inf_tile", np.inf)

    def _inject_scale(self) -> Optional[FaultRecord]:
        eng = self.engine
        slots = self._live_slots()
        items = self._arena_items(want_scale=True)
        if not slots or not items:
            return None  # fp pool has no scale leaves
        name, leaf = items[self.rng.integers(len(items))]
        slot = int(slots[self.rng.integers(len(slots))])
        block = self._pick_block(slot)
        layer = int(self.rng.integers(leaf.shape[0]))
        vals = (np.nan, np.inf, -1.0, 1e6)
        v = vals[self.rng.integers(len(vals))]
        arr = np.asarray(leaf).copy()
        arr[layer, block] = v
        self._write_leaf(name, arr)
        return FaultRecord(kind="scale", step=eng.step_count, slot=slot,
                           block=block, layer=layer, leaf=name, value=str(v))

    def _inject_table(self) -> Optional[FaultRecord]:
        eng = self.engine
        slots = self._live_slots()
        if not slots:
            return None
        slot = int(slots[self.rng.integers(len(slots))])
        pool = eng.pool
        chain = pool.chain_of(slot)
        j = int(self.rng.integers(len(chain)))
        wrong = int((chain[j] + 1 + self.rng.integers(pool.num_blocks - 1))
                    % pool.num_blocks)
        pool.tables[slot, j] = wrong
        pool.tables_dirty = True
        return FaultRecord(kind="table", step=eng.step_count, slot=slot,
                           block=int(chain[j]), value=str(wrong))

    def _inject_bit_flip(self) -> Optional[FaultRecord]:
        eng = self.engine
        slots = self._live_slots()
        if not slots:
            return None
        items = self._arena_items(want_scale=False)
        name, leaf = items[self.rng.integers(len(items))]
        slot = int(slots[self.rng.integers(len(slots))])
        block = self._pick_block(slot)
        layer = int(self.rng.integers(leaf.shape[0]))
        arr = np.asarray(leaf).copy()
        tile = arr[layer, block]
        bits = tile.view(np.uint8).reshape(-1)
        i = int(self.rng.integers(bits.shape[0]))
        bits[i] ^= 1  # lowest mantissa bit of one element
        self._write_leaf(name, arr)
        return FaultRecord(kind="bit_flip", step=eng.step_count, slot=slot,
                           block=block, layer=layer, leaf=name,
                           detectable=False)

    def _inject_device_loss(self) -> Optional[FaultRecord]:
        eng = self.engine
        pool = eng.pool
        if eng.num_devices < 2:
            return None
        # a device with >= device_loss_min_slots live slots, else no loss
        # is declarable and the injection would read as per-slot faults
        counts: dict[int, int] = {}
        for s in self._live_slots():
            counts[pool.device_of(s)] = counts.get(pool.device_of(s), 0) + 1
        viable = [d for d, n in counts.items()
                  if n >= eng.device_loss_min_slots
                  and d not in pool._lost_devices]
        if not viable:
            return None
        dev = int(viable[self.rng.integers(len(viable))])
        lo = dev * pool.blocks_per_device
        hi = lo + pool.blocks_per_device
        for name, leaf in self._arena_items(want_scale=False):
            if not jnp.issubdtype(leaf.dtype, jnp.floating):
                raise ValueError(
                    "device_loss poisons arenas with NaN; int8 arenas "
                    "cannot encode it")
            arr = np.asarray(leaf).copy()
            arr[:, lo:hi] = np.nan
            self._write_leaf(name, arr)
        return FaultRecord(kind="device_loss", step=eng.step_count,
                           device=dev, value="nan")
