"""KV/state cache pools for continuous batching.

Two residency granularities:

* ``SlotKVPool`` — slot-monolithic: one device cache tree sized for
  ``num_slots`` sequences, every leaf's batch dim a *slot* dim, each slot a
  ``max_seq``-long slab.  Still the pool for the families without pageable
  KV (SSM/hybrid O(1) carries, sliding-window rings) and the HBM baseline
  the bench compares against.

* ``BlockPagedKVPool`` — block-granular: the per-layer KV/latent leaves
  become a fixed arena of ``num_blocks x block_size`` blocks shared by all
  slots, plus a per-slot block *table* (logical block -> physical block).
  Blocks are allocated on demand as a sequence grows and recycled the tick
  its request finishes, so resident HBM scales with live tokens instead of
  ``num_slots x max_seq`` — the long-tail-workload win.  Admission gates on
  free *blocks* (a whole-request reservation, so a request can never strand
  mid-decode with the arena full), not free slabs.

Both pools track per-slot absolute positions host-side; free lists are FIFO
so slot/block reuse order is deterministic (replay identity leans on it).
The per-family cache layouts are handled generically through
``Model.cache_batch_axes`` / ``Model.paged_cache_specs`` — this file never
looks inside the tree.
"""
from __future__ import annotations

from collections import deque

import jax
import numpy as np


def tree_bytes(tree) -> int:
    """Device bytes of a cache tree (leaf sizes x itemsize)."""
    return sum(
        int(np.prod(leaf.shape)) * leaf.dtype.itemsize
        for leaf in jax.tree.leaves(tree)
    )


class SlotKVPool:
    """Fixed-capacity slot pool over ``model.init_cache(num_slots, max_seq)``.

    Tracks per-slot absolute position (next KV write index) host-side and
    slot residency (free list is FIFO so slot reuse order is deterministic).
    """

    def __init__(self, model, num_slots: int, max_seq: int):
        self.model = model
        self.num_slots = int(num_slots)
        self.max_seq = int(max_seq)
        self.cache = model.init_cache(self.num_slots, self.max_seq)
        self.positions = np.zeros(self.num_slots, np.int32)
        self._free: deque[int] = deque(range(self.num_slots))
        self._used: set[int] = set()
        # the pool cache is rebound to insert's return value, so donating it
        # lets the per-slot page-in write in place instead of copying
        self._insert = jax.jit(model.insert_cache_slot, donate_argnums=(0,))
        self._extract = jax.jit(model.extract_cache_slot)

    # ------------------------------------------------------------ residency --
    def reset(self) -> None:
        """Free everything and restore the canonical slot order, so a reset
        engine assigns slots exactly like a fresh one (replay determinism).

        Stale KV *contents* stay resident by design: admission always pages
        a whole fresh (zeroed) request cache over the slot slab before any
        read, so no stale value is reachable.  (The block-paged pool below
        cannot rely on whole-slab overwrites — recycled blocks are guarded
        by the attention mask instead; see ``attn_paged_chunk``.)"""
        self.positions[:] = 0
        self._free = deque(range(self.num_slots))
        self._used.clear()

    def hbm_bytes(self) -> int:
        """Resident device bytes of the pool cache (the slab baseline the
        paged pool's ``kv_hbm_bytes`` is compared against)."""
        return tree_bytes(self.cache)

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_used(self) -> int:
        return len(self._used)

    def allocate(self) -> int:
        if not self._free:
            raise RuntimeError("SlotKVPool exhausted: no free slot")
        slot = self._free.popleft()
        self._used.add(slot)
        return slot

    def free(self, slot: int) -> None:
        if slot not in self._used:
            raise ValueError(f"slot {slot} is not allocated")
        self._used.remove(slot)
        self.positions[slot] = 0
        self._free.append(slot)

    # ------------------------------------------------------------- contents --
    def insert(self, request_cache, slot: int, position: int) -> None:
        """Page a prefilled single-request cache into ``slot``; ``position``
        is the request's next decode position (its prompt length)."""
        if slot not in self._used:
            raise ValueError(f"slot {slot} is not allocated")
        if position > self.max_seq:
            raise ValueError(f"position {position} exceeds max_seq {self.max_seq}")
        self.cache = self._insert(self.cache, request_cache, slot)
        self.positions[slot] = position

    def extract(self, slot: int):
        """Read a slot back out as a batch=1 cache (debug/migration path)."""
        return self._extract(self.cache, slot)

    def advance(self, slots, by: int = 1) -> None:
        """Advance slot positions.  ``slots`` is an iterable of slot ids
        (each advanced ``by`` — one decoded token by default) or a
        {slot: n} mapping for offset-ranged chunk writes, where n is the
        number of tokens the fused step just committed to that slot."""
        items = slots.items() if isinstance(slots, dict) else ((s, by) for s in slots)
        for slot, n in items:
            new = int(self.positions[slot]) + int(n)
            if new > self.max_seq:
                raise ValueError(
                    f"slot {slot}: position {new} exceeds max_seq {self.max_seq}"
                )
            self.positions[slot] = new


class BlockPagedKVPool:
    """Block-granular KV pool over ``model.init_paged_cache``.

    Device state: the shared block arenas (per-layer KV/latent leaves) plus
    the slot-batched non-paged leaves (encdec cross KV, vlm patches).  Host
    state: per-slot positions, per-slot block tables (np mirror, pushed to
    device by the engine when ``tables_dirty``), FIFO free lists for slots
    and blocks, and per-slot whole-request block *reservations*.

    Reservation contract: ``allocate(reserve_tokens=n)`` admits a request
    only after ``can_reserve(n)`` said the arena can cover its worst-case
    footprint (prompt + full decode budget).  Physical blocks are still
    handed out lazily by ``ensure`` as positions grow — the reservation is
    pure accounting — so admission can never deadlock mid-decode, while
    short-finishing requests (stop tokens) simply return unused headroom.

    Recycled blocks are NOT zeroed on free: every read is guarded by the
    causal mask, and the GN softmax maps masked scores to exactly-zero
    numerators, so stale contents are unreachable (the sampled-reset replay
    test in tests/test_serve_paged.py pins this).
    """

    def __init__(self, model, num_slots: int, max_seq: int,
                 block_size: int, num_blocks: int = 0):
        self.model = model
        self.num_slots = int(num_slots)
        self.max_seq = int(max_seq)
        self.block_size = int(block_size)
        if self.block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        self.max_blocks_per_slot = -(-self.max_seq // self.block_size)
        # 0 = slab-equivalent capacity (never admission-blocks); benches pass
        # a tight count to measure the live-token footprint
        self.num_blocks = int(num_blocks) or self.num_slots * self.max_blocks_per_slot
        self.cache = model.init_paged_cache(
            self.num_slots, self.num_blocks, self.block_size, self.max_seq
        )
        self.positions = np.zeros(self.num_slots, np.int32)
        # physical ids; entries past a slot's allocated prefix are stale but
        # unreachable (masked) — 0-filled so device gathers stay in range
        self.tables = np.zeros((self.num_slots, self.max_blocks_per_slot), np.int32)
        self.tables_dirty = True
        self._insert = jax.jit(model.insert_cache_slot_extras, donate_argnums=(0,))
        self.reset()

    # ------------------------------------------------------------ residency --
    def reset(self) -> None:
        """Free everything and restore canonical slot AND block order, so a
        reset engine replays a workload with identical slot assignment and
        block-table contents (bit-identical replay, sampled runs included —
        stale arena contents are mask-guarded, not zeroed)."""
        self.positions[:] = 0
        self.tables[:] = 0
        self.tables_dirty = True
        self._free_slots: deque[int] = deque(range(self.num_slots))
        self._free_blocks: deque[int] = deque(range(self.num_blocks))
        self._used: set[int] = set()
        self._slot_blocks: dict[int, list[int]] = {}
        self._reserved = np.zeros(self.num_slots, np.int32)  # blocks, whole-request
        self.peak_blocks_in_use = 0
        self.peak_blocks_reserved = 0

    @property
    def num_free(self) -> int:
        return len(self._free_slots)

    @property
    def num_used(self) -> int:
        return len(self._used)

    @property
    def blocks_in_use(self) -> int:
        return self.num_blocks - len(self._free_blocks)

    @property
    def blocks_reserved(self) -> int:
        return int(self._reserved.sum())

    def blocks_for(self, tokens: int) -> int:
        return -(-int(tokens) // self.block_size)

    def can_reserve(self, tokens: int) -> bool:
        """True if the arena can cover a ``tokens``-long request on top of
        every outstanding reservation (free blocks minus the lazily-unfilled
        remainder of other slots' reservations)."""
        unfilled = self.blocks_reserved - self.blocks_in_use
        return len(self._free_blocks) - unfilled >= self.blocks_for(tokens)

    def allocate(self, reserve_tokens: int = 0) -> int:
        if not self._free_slots:
            raise RuntimeError("BlockPagedKVPool exhausted: no free slot")
        need = self.blocks_for(reserve_tokens)
        if reserve_tokens and not self.can_reserve(reserve_tokens):
            raise RuntimeError(
                f"BlockPagedKVPool exhausted: {need} blocks wanted, "
                f"{len(self._free_blocks)} free minus "
                f"{self.blocks_reserved - self.blocks_in_use} reserved"
            )
        slot = self._free_slots.popleft()
        self._used.add(slot)
        self._slot_blocks[slot] = []
        self._reserved[slot] = need
        self.peak_blocks_reserved = max(self.peak_blocks_reserved, self.blocks_reserved)
        return slot

    def free(self, slot: int) -> None:
        """Recycle a slot and its blocks the tick its request finishes.
        Blocks return to the FIFO free list in allocation order."""
        if slot not in self._used:
            raise ValueError(f"slot {slot} is not allocated")
        self._used.remove(slot)
        self.positions[slot] = 0
        for b in self._slot_blocks.pop(slot):
            self._free_blocks.append(b)
        self._reserved[slot] = 0
        self._free_slots.append(slot)

    # --------------------------------------------------------- block tables --
    def ensure(self, slot: int, position: int) -> None:
        """Grow ``slot``'s block table to cover positions [0, position).
        Called by the engine before each tick for the positions that tick
        will write; reservation accounting makes exhaustion here a bug, not
        a load condition."""
        if position > self.max_seq:
            raise ValueError(f"position {position} exceeds max_seq {self.max_seq}")
        blocks = self._slot_blocks[slot]
        need = self.blocks_for(position)
        if need > self._reserved[slot]:
            # growth past the reservation would consume blocks other slots'
            # admissions were promised — the strand-free guarantee rests on
            # every slot staying inside its allocate(reserve_tokens=) budget
            raise RuntimeError(
                f"slot {slot}: {need} blocks exceed its reservation "
                f"{int(self._reserved[slot])}; allocate(reserve_tokens=...) "
                "must cover the full prompt + decode footprint"
            )
        while len(blocks) < need:
            if not self._free_blocks:
                raise RuntimeError(
                    f"BlockPagedKVPool exhausted mid-sequence (slot {slot}): "
                    "reservation accounting should have prevented this"
                )
            b = self._free_blocks.popleft()
            self.tables[slot, len(blocks)] = b
            blocks.append(b)
            self.tables_dirty = True
        self.peak_blocks_in_use = max(self.peak_blocks_in_use, self.blocks_in_use)

    # ------------------------------------------------------------- contents --
    def insert(self, request_cache, slot: int, position: int) -> None:
        """Page a request's *non-paged* leaves (cross KV, patches) into
        ``slot``.  KV itself streams through the block table, so for plain
        dense/MLA requests this is pure host bookkeeping."""
        if slot not in self._used:
            raise ValueError(f"slot {slot} is not allocated")
        if position > self.max_seq:
            raise ValueError(f"position {position} exceeds max_seq {self.max_seq}")
        extras = {k: v for k, v in request_cache.items() if k != "layers"}
        if extras:
            self.cache = self._insert(self.cache, extras, slot)
        self.positions[slot] = position
        if position:
            self.ensure(slot, position)

    def advance(self, slots, by: int = 1) -> None:
        """Advance slot positions (same contract as SlotKVPool.advance)."""
        items = slots.items() if isinstance(slots, dict) else ((s, by) for s in slots)
        for slot, n in items:
            new = int(self.positions[slot]) + int(n)
            if new > self.max_seq:
                raise ValueError(
                    f"slot {slot}: position {new} exceeds max_seq {self.max_seq}"
                )
            self.positions[slot] = new

    # -------------------------------------------------------------- metrics --
    def hbm_bytes(self) -> int:
        """Resident device bytes: block arenas + non-paged leaves + tables."""
        return tree_bytes(self.cache) + self.tables.nbytes
