"""Slot-paged KV/state cache pool for continuous batching.

One device-resident cache tree sized for ``num_slots`` sequences; the batch
dim of every leaf is reinterpreted as a *slot* dim.  A request is prefetched
into a free slot (single ``dynamic_update_slice`` per leaf, slot index
traced so one compilation covers all slots), decoded in place by the
engine's masked decode, and its slot is recycled the step it finishes.

The per-family cache layouts (dense k/v, MLA latent, SSM carries, hybrid
shared-attention kv, encdec cross kv, vlm patches) are all handled
generically through ``Model.cache_batch_axes`` — this file never looks
inside the tree.
"""
from __future__ import annotations

from collections import deque

import jax
import numpy as np


class SlotKVPool:
    """Fixed-capacity slot pool over ``model.init_cache(num_slots, max_seq)``.

    Tracks per-slot absolute position (next KV write index) host-side and
    slot residency (free list is FIFO so slot reuse order is deterministic).
    """

    def __init__(self, model, num_slots: int, max_seq: int):
        self.model = model
        self.num_slots = int(num_slots)
        self.max_seq = int(max_seq)
        self.cache = model.init_cache(self.num_slots, self.max_seq)
        self.positions = np.zeros(self.num_slots, np.int32)
        self._free: deque[int] = deque(range(self.num_slots))
        self._used: set[int] = set()
        # the pool cache is rebound to insert's return value, so donating it
        # lets the per-slot page-in write in place instead of copying
        self._insert = jax.jit(model.insert_cache_slot, donate_argnums=(0,))
        self._extract = jax.jit(model.extract_cache_slot)

    # ------------------------------------------------------------ residency --
    def reset(self) -> None:
        """Free everything and restore the canonical slot order, so a reset
        engine assigns slots exactly like a fresh one (replay determinism)."""
        self.positions[:] = 0
        self._free = deque(range(self.num_slots))
        self._used.clear()

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_used(self) -> int:
        return len(self._used)

    def allocate(self) -> int:
        if not self._free:
            raise RuntimeError("SlotKVPool exhausted: no free slot")
        slot = self._free.popleft()
        self._used.add(slot)
        return slot

    def free(self, slot: int) -> None:
        if slot not in self._used:
            raise ValueError(f"slot {slot} is not allocated")
        self._used.remove(slot)
        self.positions[slot] = 0
        self._free.append(slot)

    # ------------------------------------------------------------- contents --
    def insert(self, request_cache, slot: int, position: int) -> None:
        """Page a prefilled single-request cache into ``slot``; ``position``
        is the request's next decode position (its prompt length)."""
        if slot not in self._used:
            raise ValueError(f"slot {slot} is not allocated")
        if position > self.max_seq:
            raise ValueError(f"position {position} exceeds max_seq {self.max_seq}")
        self.cache = self._insert(self.cache, request_cache, slot)
        self.positions[slot] = position

    def extract(self, slot: int):
        """Read a slot back out as a batch=1 cache (debug/migration path)."""
        return self._extract(self.cache, slot)

    def advance(self, slots, by: int = 1) -> None:
        """Advance slot positions.  ``slots`` is an iterable of slot ids
        (each advanced ``by`` — one decoded token by default) or a
        {slot: n} mapping for offset-ranged chunk writes, where n is the
        number of tokens the fused step just committed to that slot."""
        items = slots.items() if isinstance(slots, dict) else ((s, by) for s in slots)
        for slot, n in items:
            new = int(self.positions[slot]) + int(n)
            if new > self.max_seq:
                raise ValueError(
                    f"slot {slot}: position {new} exceeds max_seq {self.max_seq}"
                )
            self.positions[slot] = new
