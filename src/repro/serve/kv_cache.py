"""KV/state cache pools for continuous batching.

Two residency granularities:

* ``SlotKVPool`` — slot-monolithic: one device cache tree sized for
  ``num_slots`` sequences, every leaf's batch dim a *slot* dim, each slot a
  ``max_seq``-long slab.  Still the pool for the families without pageable
  KV (SSM/hybrid O(1) carries, sliding-window rings) and the HBM baseline
  the bench compares against.

* ``BlockPagedKVPool`` — block-granular: the per-layer KV/latent leaves
  become a fixed arena of ``num_blocks x block_size`` blocks shared by all
  slots, plus a per-slot block *table* (logical block -> physical block).
  Blocks are allocated on demand as a sequence grows and recycled the tick
  its request finishes, so resident HBM scales with live tokens instead of
  ``num_slots x max_seq`` — the long-tail-workload win.  Admission gates on
  free *blocks* (a whole-request reservation, so a request can never strand
  mid-decode with the arena full), not free slabs.

Both pools track per-slot absolute positions host-side; free lists are FIFO
so slot/block reuse order is deterministic (replay identity leans on it).
The per-family cache layouts are handled generically through
``Model.cache_batch_axes`` / ``Model.paged_cache_specs`` — this file never
looks inside the tree.

Multi-device (``mesh`` != None): both pools place every device leaf with a
slot-axis ``NamedSharding`` built from the rules in ``parallel/sharding.py``
(the cache 'batch' axis — the slot axis — shards over the 1-D 'data' serving
mesh; see ``make_slot_mesh``).  Device d owns the contiguous slot range
[d*per_device_slots, (d+1)*per_device_slots), and, in the paged pool, the
matching contiguous block range — a slot only ever receives blocks from its
own device, so a sequence's KV stays resident with its slot shard.
Admission placement (``pick_device``) is least-loaded-first so one hot
device cannot strand free slots elsewhere; with one device every range
collapses to the whole pool and behavior is bit-identical to the unsharded
pools.
"""
from __future__ import annotations

from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


# --- jitted pool kernels -----------------------------------------------
# Module-level (not closures) so ``repro.analysis`` can audit their traced
# programs (donation aliasing, dtype discipline) against the exact
# functions the pools jit.

def fork_block(cache, src, dst):
    """Device-side COW block copy across every paged arena leaf.  All
    ``layers`` leaves are ``(L, num_blocks, block_size, ...)`` — the block
    axis is axis 1 for dense KV and MLA latents alike — so one jitted
    dynamic slice/update with traced indices covers every family with a
    single compilation."""
    def cp(leaf):
        blk = jax.lax.dynamic_slice_in_dim(leaf, src, 1, axis=1)
        return jax.lax.dynamic_update_slice_in_dim(leaf, blk, dst, axis=1)
    out = dict(cache)
    out["layers"] = jax.tree.map(cp, cache["layers"])
    return out


def spill_gather(layers, ix):
    """Preemption spill: gather a padded block chain out of the arenas."""
    return jax.tree.map(lambda l: jnp.take(l, ix, axis=1), layers)


def spill_scatter(cache, host, ix):
    """Preemption restore: scatter a spilled payload into a fresh chain.
    Duplicate trailing lanes carry identical values (index and data), so
    the scatter is deterministic under any ordering."""
    out = dict(cache)
    out["layers"] = jax.tree.map(
        lambda l, h: l.at[:, ix].set(h), cache["layers"], host
    )
    return out


def shard_cache_tree(cache, mesh, axes_tree):
    """Place a cache tree on the serving mesh: every leaf gets the
    ``NamedSharding`` its logical axes imply under the default rules
    (slot/batch axis -> 'data'; axes whose mesh axis is absent, or whose dim
    doesn't divide, replicate).  ``axes_tree`` is parallel to ``cache`` with
    logical-axis tuples as leaves (``Model.cache_logical_axes`` /
    ``paged_cache_logical_axes``).  No-op when ``mesh`` is None."""
    if mesh is None:
        return cache
    from repro.parallel.sharding import slot_ctx

    ctx = slot_ctx(mesh)
    shardings = jax.tree.map(
        lambda ax, leaf: ctx.sharding_for_shape(leaf.shape, ax),
        axes_tree, cache, is_leaf=lambda x: isinstance(x, tuple),
    )
    return jax.tree.map(jax.device_put, cache, shardings)


class _SlotRanges:
    """Per-device slot-range accounting shared by both pools.

    Device d owns slots [d*per_device_slots, (d+1)*per_device_slots) — the
    contiguous layout a slot-axis NamedSharding gives the cache leaves, so
    host placement and XLA placement agree.  ``num_devices=1`` makes the
    single range the whole pool and every method collapse to the unsharded
    behavior."""

    def _init_ranges(self, num_slots: int, mesh, num_devices: int) -> None:
        self.mesh = mesh
        self.num_devices = int(num_devices) or (
            int(mesh.devices.size) if mesh is not None else 1
        )
        if self.num_devices < 1:
            raise ValueError(f"num_devices must be >= 1, got {self.num_devices}")
        if mesh is not None and int(mesh.devices.size) != self.num_devices:
            raise ValueError(
                f"mesh has {int(mesh.devices.size)} devices, num_devices says "
                f"{self.num_devices}"
            )
        if num_slots % self.num_devices:
            raise ValueError(
                f"num_slots {num_slots} must divide evenly over "
                f"{self.num_devices} devices (per-device slot shards)"
            )
        self.per_device_slots = num_slots // self.num_devices

    def device_of(self, slot: int) -> int:
        return int(slot) // self.per_device_slots

    def free_slots_on(self, device: int) -> int:
        lo = device * self.per_device_slots
        hi = lo + self.per_device_slots
        return sum(1 for s in self._free_slot_list() if lo <= s < hi)

    def _pop_free_slot(self, device: Optional[int]) -> int:
        """Oldest free slot, optionally restricted to a device's range —
        FIFO within the range, so device-0/1-device allocation order is
        exactly the historical global FIFO order."""
        free = self._free_slot_list()
        if not free:
            raise RuntimeError(f"{type(self).__name__} exhausted: no free slot")
        if device is None:
            return free.popleft()
        lo = device * self.per_device_slots
        hi = lo + self.per_device_slots
        for slot in free:
            if lo <= slot < hi:
                free.remove(slot)
                return slot
        raise RuntimeError(
            f"{type(self).__name__}: no free slot on device {device}"
        )


def tree_bytes(tree) -> int:
    """Device bytes of a cache tree (leaf sizes x itemsize)."""
    return sum(
        int(np.prod(leaf.shape)) * leaf.dtype.itemsize
        for leaf in jax.tree.leaves(tree)
    )


class SlotKVPool(_SlotRanges):
    """Fixed-capacity slot pool over ``model.init_cache(num_slots, max_seq)``.

    Tracks per-slot absolute position (next KV write index) host-side and
    slot residency (free list is FIFO so slot reuse order is deterministic).
    With a serving ``mesh`` every cache leaf is placed with a slot-axis
    NamedSharding and device d owns the slot range
    [d*per_device_slots, (d+1)*per_device_slots).
    """

    def __init__(self, model, num_slots: int, max_seq: int,
                 mesh=None, num_devices: int = 0):
        self.model = model
        self.num_slots = int(num_slots)
        self.max_seq = int(max_seq)
        self._init_ranges(self.num_slots, mesh, num_devices)
        self.cache = shard_cache_tree(
            model.init_cache(self.num_slots, self.max_seq),
            mesh, model.cache_logical_axes(),
        )
        self.positions = np.zeros(self.num_slots, np.int32)
        self._free: deque[int] = deque(range(self.num_slots))
        self._used: set[int] = set()
        # the pool cache is rebound to insert's return value, so donating it
        # lets the per-slot page-in write in place instead of copying
        self._insert = jax.jit(model.insert_cache_slot, donate_argnums=(0,))
        self._extract = jax.jit(model.extract_cache_slot)

    def _free_slot_list(self) -> deque:
        return self._free

    # ------------------------------------------------------------ residency --
    def reset(self) -> None:
        """Free everything and restore the canonical slot order, so a reset
        engine assigns slots exactly like a fresh one (replay determinism).

        Stale KV *contents* stay resident by design: admission always pages
        a whole fresh (zeroed) request cache over the slot slab before any
        read, so no stale value is reachable.  (The block-paged pool below
        cannot rely on whole-slab overwrites — recycled blocks are guarded
        by the attention mask instead; see ``attn_paged_chunk``.)"""
        self.positions[:] = 0
        self._free = deque(range(self.num_slots))
        self._used.clear()

    def hbm_bytes(self) -> int:
        """Resident device bytes of the pool cache (the slab baseline the
        paged pool's ``kv_hbm_bytes`` is compared against)."""
        return tree_bytes(self.cache)

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_used(self) -> int:
        return len(self._used)

    def pick_device(self, reserve_tokens: int = 0) -> Optional[int]:
        """Admission placement: the least-loaded device (most free slots in
        its range; ties break toward the lowest index, which with one device
        is always device 0 — the historical behavior).  Returns None when no
        device has a free slot.  ``reserve_tokens`` is accepted for API
        parity with the paged pool and ignored (slabs reserve nothing)."""
        best, best_free = None, 0
        for d in range(self.num_devices):
            free = self.free_slots_on(d)
            if free > best_free:
                best, best_free = d, free
        return best

    def allocate(self, device: Optional[int] = None) -> int:
        slot = self._pop_free_slot(device)
        self._used.add(slot)
        return slot

    def free(self, slot: int) -> None:
        if slot not in self._used:
            raise ValueError(f"slot {slot} is not allocated")
        self._used.remove(slot)
        self.positions[slot] = 0
        self._free.append(slot)

    # ------------------------------------------------------------- contents --
    def insert(self, request_cache, slot: int, position: int) -> None:
        """Page a prefilled single-request cache into ``slot``; ``position``
        is the request's next decode position (its prompt length)."""
        if slot not in self._used:
            raise ValueError(f"slot {slot} is not allocated")
        if position > self.max_seq:
            raise ValueError(f"position {position} exceeds max_seq {self.max_seq}")
        # explicit uploads: the request cache may be a host tree (slab
        # spill-restore passes numpy mirrors) and the slot index a python
        # int — commit both so the jit call itself never transfers
        self.cache = self._insert(
            self.cache,
            jax.tree.map(jnp.asarray, request_cache),
            jnp.asarray(slot, jnp.int32),
        )
        self.positions[slot] = position

    def extract(self, slot: int):
        """Read a slot back out as a batch=1 cache (debug/migration path)."""
        return self._extract(self.cache, slot)

    def advance(self, slots, by: int = 1) -> None:
        """Advance slot positions.  ``slots`` is an iterable of slot ids
        (each advanced ``by`` — one decoded token by default) or a
        {slot: n} mapping for offset-ranged chunk writes, where n is the
        number of tokens the fused step just committed to that slot."""
        items = slots.items() if isinstance(slots, dict) else ((s, by) for s in slots)
        for slot, n in items:
            new = int(self.positions[slot]) + int(n)
            if new > self.max_seq:
                raise ValueError(
                    f"slot {slot}: position {new} exceeds max_seq {self.max_seq}"
                )
            self.positions[slot] = new


class BlockPagedKVPool(_SlotRanges):
    """Block-granular KV pool over ``model.init_paged_cache``.

    Device state: the shared block arenas (per-layer KV/latent leaves) plus
    the slot-batched non-paged leaves (encdec cross KV, vlm patches).  Host
    state: per-slot positions, per-slot block tables (np mirror, pushed to
    device by the engine when ``tables_dirty``), FIFO free lists for slots
    and blocks, and per-slot whole-request block *reservations*.

    Multi-device: the arenas shard over the *block* axis and device d owns
    the contiguous block range [d*blocks_per_device, (d+1)*blocks_per_device)
    alongside its slot range — ``ensure`` only hands a slot blocks from its
    own device, so the gathered logical stream is device-local and the
    reservation ledger (and therefore admission) is per-device.

    Reservation contract: ``allocate(reserve_tokens=n)`` admits a request
    only after ``can_reserve(n)`` said the arena can cover its worst-case
    footprint (prompt + full decode budget).  Physical blocks are still
    handed out lazily by ``ensure`` as positions grow — the reservation is
    pure accounting — so admission can never deadlock mid-decode, while
    short-finishing requests (stop tokens) simply return unused headroom.

    Recycled blocks are NOT zeroed on free: every read is guarded by the
    causal mask, and the GN softmax maps masked scores to exactly-zero
    numerators, so stale contents are unreachable (the sampled-reset replay
    test in tests/test_serve_paged.py pins this).

    Quarantine (fault containment): ``quarantine_block`` removes a block
    from circulation permanently — a free block leaves its free list now, a
    live block is marked *doomed* and diverted to the quarantine set the
    moment its refcount reaches zero (so in-flight readers of a shared
    block are never yanked mid-read).  Quarantined blocks are never
    recycled, never counted as in-use, and the three-way ledger
    (free + live + quarantined == num_blocks) is re-verified by
    ``check_ledger`` in ``reset()`` and after every recycle.
    ``mark_device_lost`` quarantines a whole device's block range and
    retires its slot range from admission.

    Prefix sharing (``attach_prefix_cache``): every block carries a host
    refcount.  A slot owns the blocks ``ensure`` popped for it (refcount 1),
    *attaches* cached full blocks from a ``PrefixCache`` hit (refcount++,
    read-only — the same GN mask guarantee that makes recycled blocks safe
    makes a block readable through any number of tables), and the cache
    itself holds one reference per indexed block.  A block returns to its
    device's FIFO free list only when its refcount reaches zero, so
    recycling order is unchanged whenever nothing is shared.  Reservations
    charge a request only for its *unshared* tail
    (``blocks_for(footprint) - attached``), and a partially-shared block is
    copy-on-write forked into a private block at attach time — before the
    request's first divergent write ever happens (``write_barrier`` asserts
    no live slot writes a refcount>1 block).  Under block pressure
    ``_pop_block`` reclaims LRU cache-only chains (refcount == 1) before
    declaring exhaustion.
    """

    def __init__(self, model, num_slots: int, max_seq: int,
                 block_size: int, num_blocks: int = 0,
                 mesh=None, num_devices: int = 0, kv_dtype: str = "fp"):
        self.model = model
        self.num_slots = int(num_slots)
        self.max_seq = int(max_seq)
        self.block_size = int(block_size)
        self.kv_dtype = str(kv_dtype)
        if self.block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        self._init_ranges(self.num_slots, mesh, num_devices)
        self.max_blocks_per_slot = -(-self.max_seq // self.block_size)
        # 0 = slab-equivalent capacity (never admission-blocks); benches pass
        # a tight count to measure the live-token footprint.  The arena is
        # rounded up to a device multiple so the block axis shards evenly.
        nb = int(num_blocks) or self.num_slots * self.max_blocks_per_slot
        self.num_blocks = -(-nb // self.num_devices) * self.num_devices
        self.blocks_per_device = self.num_blocks // self.num_devices
        self.cache = shard_cache_tree(
            model.init_paged_cache(
                self.num_slots, self.num_blocks, self.block_size, self.max_seq,
                kv_dtype=self.kv_dtype,
            ),
            mesh, model.paged_cache_logical_axes(kv_dtype=self.kv_dtype),
        )
        self.positions = np.zeros(self.num_slots, np.int32)
        # physical ids; entries past a slot's allocated prefix are stale but
        # unreachable (masked) — 0-filled so device gathers stay in range
        self.tables = np.zeros((self.num_slots, self.max_blocks_per_slot), np.int32)
        self.tables_dirty = True
        self._insert = jax.jit(model.insert_cache_slot_extras, donate_argnums=(0,))
        self.prefix_cache = None  # bound by attach_prefix_cache
        self._fork_jit = None  # lazy: one trace total (src/dst are traced)
        # preemption spill/restore jits (lazy; indices are traced, so each
        # retraces only per power-of-two padded chain length — the same
        # bounded-compile discipline as the horizon buckets)
        self._spill_gather_jit = None
        self._spill_scatter_jit = None
        self.reset()

    # ------------------------------------------------------------ residency --
    def reset(self) -> None:
        """Free everything and restore canonical slot AND block order, so a
        reset engine replays a workload with identical slot assignment and
        block-table contents (bit-identical replay, sampled runs included —
        stale arena contents are mask-guarded, not zeroed)."""
        self.positions[:] = 0
        self.tables[:] = 0
        self.tables_dirty = True
        self._free_slots: deque[int] = deque(range(self.num_slots))
        # per-device FIFO block lists: device d recycles only its own range,
        # so replay determinism holds per shard exactly as it did globally
        bpd = self.blocks_per_device
        self._free_blocks: list[deque[int]] = [
            deque(range(d * bpd, (d + 1) * bpd)) for d in range(self.num_devices)
        ]
        self._used: set[int] = set()
        self._slot_blocks: dict[int, list[int]] = {}
        self._reserved = np.zeros(self.num_slots, np.int32)  # blocks, whole-request
        # refcounts: owner allocation = 1, each sharing attach and each
        # prefix-cache index entry +1; a block recycles only at zero
        self.refcounts = np.zeros(self.num_blocks, np.int32)
        self._shared = np.zeros(self.num_slots, np.int32)  # attached (not owned)
        self._owned = np.zeros(self.num_slots, np.int32)   # popped for this slot
        self.prefix_forks = 0
        self.prefix_evictions = 0
        self.peak_blocks_in_use = 0
        self.peak_blocks_reserved = 0
        # per-device reservation peaks: the bench's tight-arena rerun sizes
        # each device's shard for ITS peak (a global peak split evenly could
        # under-provision the hotter shard under imbalanced placement)
        self.peak_reserved_per_device = np.zeros(self.num_devices, np.int64)
        # per-device in-use peaks (owned + attached + cached): with prefix
        # sharing the reservation ledger under-counts residency (cached
        # chains are reserved by nobody), so equal-HBM sizing needs this one
        self.peak_used_per_device = np.zeros(self.num_devices, np.int64)
        # fault containment: quarantined blocks are out of circulation for
        # good; doomed blocks are live-but-condemned (diverted to quarantine
        # at refcount zero instead of the free list).  reset() clears both —
        # it reinitializes the pool as if freshly constructed, and the fault
        # tests lean on that for replay.
        self.quarantined: set[int] = set()
        self._doomed: set[int] = set()
        self._lost_devices: set[int] = set()
        if self.prefix_cache is not None:
            self.prefix_cache.clear()
        self.check_ledger()

    def _free_slot_list(self) -> deque:
        return self._free_slots

    @property
    def num_free(self) -> int:
        return len(self._free_slots)

    @property
    def num_used(self) -> int:
        return len(self._used)

    @property
    def blocks_in_use(self) -> int:
        return (self.num_blocks - sum(len(f) for f in self._free_blocks)
                - len(self.quarantined))

    @property
    def blocks_reserved(self) -> int:
        return int(self._reserved.sum())

    @property
    def max_request_blocks(self) -> int:
        """Largest footprint one request can ever hold: a slot's blocks all
        come from its own device's range."""
        return self.blocks_per_device

    def blocks_for(self, tokens: int) -> int:
        return -(-int(tokens) // self.block_size)

    def free_blocks_on(self, device: int) -> int:
        return len(self._free_blocks[device])

    def quarantined_on(self, device: int) -> int:
        lo = device * self.blocks_per_device
        hi = lo + self.blocks_per_device
        return sum(1 for b in self.quarantined if lo <= b < hi)

    def blocks_in_use_on(self, device: int) -> int:
        return (self.blocks_per_device - len(self._free_blocks[device])
                - self.quarantined_on(device))

    def reserved_on(self, device: int) -> int:
        lo = device * self.per_device_slots
        return int(self._reserved[lo : lo + self.per_device_slots].sum())

    def unfilled_on(self, device: int) -> int:
        """Blocks promised to ``device``'s live slots but not yet popped for
        them.  Equal to ``reserved_on - blocks_in_use_on`` when nothing is
        cached or shared; with a prefix cache attached, cache-held blocks
        inflate ``blocks_in_use`` without belonging to any reservation, so
        the ledger is computed per slot (reserved minus owned)."""
        lo = device * self.per_device_slots
        hi = lo + self.per_device_slots
        return int((self._reserved[lo:hi] - self._owned[lo:hi]).sum())

    def can_reserve(self, tokens: int, device: int = 0, prefix=None) -> bool:
        """True if ``device``'s block range can cover a ``tokens``-long
        request on top of every outstanding reservation there (free blocks,
        plus LRU-evictable cache-only chains, minus the lazily-unfilled
        remainder of its slots' reservations).  ``prefix`` (a ``PrefixHit``)
        discounts the request's fully-shared blocks — they are attached, not
        allocated — and excludes the hit's own blocks from the evictable
        supply (attaching pins them; the COW fork source is pinned too)."""
        if device in self._lost_devices:
            return False
        need = self.blocks_for(tokens)
        avail = len(self._free_blocks[device])
        if self.prefix_cache is not None:
            avail += self.prefix_cache.evictable_count(device, self.refcounts)
        if prefix is not None:
            need -= len(prefix.blocks)
            held = list(prefix.blocks)
            if prefix.tail_src is not None:
                held.append(prefix.tail_src)
            avail -= sum(1 for b in held if self.refcounts[b] == 1)
        return avail - self.unfilled_on(device) >= need

    def pick_device(self, reserve_tokens: int = 0) -> Optional[int]:
        """Admission placement: the least-loaded device (most free slots)
        whose block range can also cover the request's whole-footprint
        reservation; ties break toward the lowest index.  None when no
        device can take the request — the FCFS head waits for recycling."""
        best, best_free = None, 0
        for d in range(self.num_devices):
            if d in self._lost_devices:
                continue
            free = self.free_slots_on(d)
            if free <= best_free:
                continue
            if reserve_tokens and not self.can_reserve(reserve_tokens, d):
                continue
            best, best_free = d, free
        return best

    def allocate(self, reserve_tokens: int = 0,
                 device: Optional[int] = None, prefix=None) -> int:
        need = self.blocks_for(reserve_tokens)
        if prefix is not None:
            # charge only the unshared tail: fully-matched blocks attach by
            # refcount, never by allocation (the COW fork block still counts
            # — it IS an allocation)
            need -= len(prefix.blocks)
        slot = self._pop_free_slot(device)
        # the reservation ledger is per-device, so the check runs against
        # the device the slot actually landed on (with an explicit device
        # the engine's pick_device already verified it; a legacy no-device
        # call checks the FIFO head's device and restores FIFO order on
        # failure)
        dev = self.device_of(slot)
        if reserve_tokens and not self.can_reserve(reserve_tokens, dev, prefix):
            self._free_slots.appendleft(slot)
            raise RuntimeError(
                f"BlockPagedKVPool exhausted: {need} blocks wanted on device "
                f"{dev}, {len(self._free_blocks[dev])} free minus "
                f"{self.unfilled_on(dev)} reserved"
            )
        self._used.add(slot)
        self._slot_blocks[slot] = []
        self._reserved[slot] = need
        self._shared[slot] = 0
        self._owned[slot] = 0
        self.peak_blocks_reserved = max(self.peak_blocks_reserved, self.blocks_reserved)
        d = self.device_of(slot)
        self.peak_reserved_per_device[d] = max(
            self.peak_reserved_per_device[d], self.reserved_on(d)
        )
        return slot

    def free(self, slot: int) -> None:
        """Release a slot's references the tick its request finishes.
        Blocks whose refcount drops to zero return to their device's FIFO
        free list in allocation order (a slot's blocks are all from its own
        device's range); blocks the prefix cache or another slot still
        references stay resident."""
        if slot not in self._used:
            raise ValueError(f"slot {slot} is not allocated")
        self._used.remove(slot)
        self.positions[slot] = 0
        for b in self._slot_blocks.pop(slot):
            self.refcounts[b] -= 1
            if self.refcounts[b] == 0:
                self._recycle(b)
        self._reserved[slot] = 0
        self._shared[slot] = 0
        self._owned[slot] = 0
        # a lost device's slot range is retired from admission: freed slots
        # there must not re-enter the FIFO (their blocks are quarantined)
        if self.device_of(slot) not in self._lost_devices:
            self._free_slots.append(slot)
        self.check_ledger()

    def _recycle(self, block: int) -> None:
        """A block's refcount just hit zero: back to its device's FIFO free
        list — unless it was condemned while live, in which case it goes to
        quarantine instead (the only path a doomed block ever takes)."""
        if block in self._doomed:
            self._doomed.discard(block)
            self.quarantined.add(block)
        else:
            self._free_blocks[block // self.blocks_per_device].append(block)

    # ----------------------------------------------------- fault containment --
    def quarantine_block(self, block: int) -> None:
        """Permanently remove ``block`` from circulation.  Free blocks leave
        their free list immediately; live blocks (refcount > 0) are marked
        doomed and diverted to quarantine when their last reference drops —
        so a shared block's other readers keep a consistent view until they
        release it.  Idempotent."""
        b = int(block)
        if b in self.quarantined or b in self._doomed:
            return
        if self.refcounts[b] > 0:
            self._doomed.add(b)
            return
        dev = b // self.blocks_per_device
        try:
            self._free_blocks[dev].remove(b)
        except ValueError:
            raise RuntimeError(
                f"block {b} is neither live nor free — ledger corrupt"
            ) from None
        self.quarantined.add(b)
        self.check_ledger()

    def mark_device_lost(self, device: int) -> None:
        """Retire a device: quarantine its entire block range and drop its
        free slots from admission.  Live slots on the device are the
        engine's problem (it fails or recovers them); their blocks become
        doomed here and reach quarantine as those slots are freed."""
        dev = int(device)
        if dev in self._lost_devices:
            return
        self._lost_devices.add(dev)
        lo, hi = dev * self.blocks_per_device, (dev + 1) * self.blocks_per_device
        for b in range(lo, hi):
            self.quarantine_block(b)
        slo = dev * self.per_device_slots
        shi = slo + self.per_device_slots
        for s in [s for s in self._free_slots if slo <= s < shi]:
            self._free_slots.remove(s)
        self.check_ledger()

    def scrub_blocks(self, blocks) -> None:
        """Zero the arena contents and per-block scale entries of
        ``blocks``.  Healthy recycled blocks are never zeroed (the GN mask
        guarantee makes that unnecessary); scrubbing exists for *quarantined*
        blocks only, whose poisoned contents would otherwise leak into
        healthy slots through stale table entries — IEEE 0 * NaN = NaN, so a
        masked (exactly-zero-weight) read of a NaN tile still contaminates
        the output.  A zeroed scale entry additionally reads as "unwritten"
        to the freeze-at-first-write quantizer, so a scrubbed block is
        indistinguishable from a pristine one."""
        blocks = sorted({int(b) for b in blocks})
        if not blocks:
            return
        ix = jnp.asarray(blocks, jnp.int32)

        def z(leaf):
            out = leaf.at[:, ix].set(jnp.zeros((), leaf.dtype))
            # re-pin the sharding only for committed (sharded) leaves: a
            # device_put on an uncommitted leaf would commit it, changing
            # the tick's pjit compilation key and forcing a silent
            # recompile of every warmed entry
            return jax.device_put(out, leaf.sharding) if leaf.committed else out

        self.cache = {**self.cache, "layers": jax.tree.map(z, self.cache["layers"])}

    def check_ledger(self) -> None:
        """The three-way block ledger must partition the arena exactly:
        free + live (refcount > 0) + quarantined == num_blocks, with doomed
        a subset of live.  Raises on any leak (double-free, quarantine
        escape, refcount drift) — called from ``reset()`` and after every
        recycle, so a leak is caught at the recycle that caused it."""
        free = sum(len(f) for f in self._free_blocks)
        live = int((self.refcounts > 0).sum())
        q = len(self.quarantined)
        if free + live + q != self.num_blocks:
            raise RuntimeError(
                f"block ledger leak: free {free} + live {live} + quarantined "
                f"{q} != {self.num_blocks}"
            )
        if any(self.refcounts[b] <= 0 for b in self._doomed):
            raise RuntimeError("doomed block with refcount <= 0 never recycled")
        if any(self.refcounts[b] != 0 for b in self.quarantined):
            raise RuntimeError("quarantined block still referenced")

    # --------------------------------------------------------- block tables --
    def active_horizon_blocks(self) -> int:
        """Max blocks any live slot holds right now — the tick's *active
        block horizon*.  The engine buckets this to a small power-of-two
        grid and slices the traced block tables down to it, so per-tick
        attention work (streamed tiles / kernel grid steps) is bounded by
        live context instead of ceil(max_seq / block_size).  0 when no slot
        holds blocks."""
        if not self._slot_blocks:
            return 0
        return max((len(b) for b in self._slot_blocks.values()), default=0)

    def ensure(self, slot: int, position: int) -> None:
        """Grow ``slot``'s block table to cover positions [0, position).
        Called by the engine before each tick for the positions that tick
        will write; reservation accounting makes exhaustion here a bug, not
        a load condition."""
        if position > self.max_seq:
            raise ValueError(f"position {position} exceeds max_seq {self.max_seq}")
        blocks = self._slot_blocks[slot]
        need = self.blocks_for(position)
        if need - self._shared[slot] > self._reserved[slot]:
            # growth past the reservation would consume blocks other slots'
            # admissions were promised — the strand-free guarantee rests on
            # every slot staying inside its allocate(reserve_tokens=) budget
            # (attached shared blocks are free growth: nobody was charged)
            raise RuntimeError(
                f"slot {slot}: {need} blocks exceed its reservation "
                f"{int(self._reserved[slot])} + {int(self._shared[slot])} "
                "shared; allocate(reserve_tokens=...) must cover the full "
                "prompt + decode footprint"
            )
        dev = self.device_of(slot)
        while len(blocks) < need:
            b = self._pop_block(dev, f"mid-sequence (slot {slot})")
            self.refcounts[b] = 1
            self._owned[slot] += 1
            self.tables[slot, len(blocks)] = b
            blocks.append(b)
            self.tables_dirty = True

    def _pop_block(self, dev: int, context: str) -> int:
        """Pop the oldest free block on ``dev``, reclaiming LRU cache-only
        chains from the prefix cache under pressure.  Admission accounting
        (``can_reserve`` counts free + evictable - unfilled) makes failure
        here a bug, not a load condition."""
        if not self._free_blocks[dev] and self.prefix_cache is not None:
            evicted = self.prefix_cache.evict_lru(dev, self.refcounts)
            if evicted is not None:
                self.prefix_evictions += 1
                self.cache_unref(evicted)
        if not self._free_blocks[dev]:
            raise RuntimeError(
                f"BlockPagedKVPool exhausted {context} (device {dev}): "
                "reservation accounting should have prevented this"
            )
        b = self._free_blocks[dev].popleft()
        self.peak_blocks_in_use = max(self.peak_blocks_in_use, self.blocks_in_use)
        self.peak_used_per_device[dev] = max(
            self.peak_used_per_device[dev], self.blocks_in_use_on(dev)
        )
        return b

    # -------------------------------------------------------- prefix sharing --
    def attach_prefix_cache(self, cache) -> None:
        """Bind a ``PrefixCache``: the cache indexes this pool's blocks (one
        refcount per entry) and the pool reclaims its LRU cache-only chains
        under block pressure.  Opt-in: with no cache bound, every refcount
        stays 1 and behavior is bit-identical to the unshared pool."""
        self.prefix_cache = cache
        cache.pool = self

    def cache_ref(self, block: int) -> None:
        self.refcounts[block] += 1

    def cache_unref(self, block: int) -> None:
        self.refcounts[block] -= 1
        if self.refcounts[block] == 0:
            self._recycle(int(block))
            self.check_ledger()

    @property
    def cached_blocks(self) -> int:
        return 0 if self.prefix_cache is None else self.prefix_cache.cached_blocks()

    def chain_of(self, slot: int) -> list[int]:
        """A copy of ``slot``'s physical block chain (logical order)."""
        return list(self._slot_blocks[slot])

    def attach_prefix(self, slot: int, prefix) -> None:
        """Wire a fresh slot to a ``PrefixHit``: fully-matched cached blocks
        attach read-only (refcount++), and a partially-matched tail block is
        copy-on-write forked — device-copied into a privately-owned block —
        *now*, before the request's first divergent write can ever land in
        shared storage.  The fork source is pinned for the duration so the
        fork's own allocation can't reclaim it."""
        if slot not in self._used or self._slot_blocks[slot]:
            raise ValueError(f"slot {slot} must be freshly allocated")
        dev = self.device_of(slot)
        chain = self._slot_blocks[slot]
        lo, hi = dev * self.blocks_per_device, (dev + 1) * self.blocks_per_device
        for b in list(prefix.blocks) + (
            [prefix.tail_src] if prefix.tail_src is not None else []
        ):
            if not lo <= b < hi:
                raise ValueError(
                    f"prefix block {b} is not on slot {slot}'s device {dev}"
                )
        for b in prefix.blocks:
            self.refcounts[b] += 1
            self.tables[slot, len(chain)] = b
            chain.append(b)
        self._shared[slot] = len(prefix.blocks)
        if prefix.tail_src is not None:
            self.refcounts[prefix.tail_src] += 1  # pin across the fork pop
            dst = self._pop_block(dev, f"forking for slot {slot}")
            self.refcounts[dst] = 1
            self._owned[slot] += 1
            self.tables[slot, len(chain)] = dst
            chain.append(dst)
            self._fork_copy(prefix.tail_src, dst)
            self.refcounts[prefix.tail_src] -= 1
            self.prefix_forks += 1
        self.tables_dirty = True

    def _fork_copy(self, src: int, dst: int) -> None:
        """Device-side block copy across every paged arena leaf.  All
        ``layers`` leaves are ``(L, num_blocks, block_size, ...)`` — the
        block axis is axis 1 for dense KV and MLA latents alike — so one
        jitted dynamic slice/update with traced indices covers every family
        with a single compilation."""
        if self._fork_jit is None:
            self._fork_jit = jax.jit(fork_block, donate_argnums=(0,))
        self.cache = self._fork_jit(
            self.cache, jnp.asarray(src, jnp.int32), jnp.asarray(dst, jnp.int32)
        )

    def write_barrier(self, slot: int, position: int) -> None:
        """COW safety assertion: the block ``slot``'s next write lands in
        must be privately owned (refcount 1).  Attach-time forking makes
        this true by construction — prompt blocks enter the cache only
        after their owner stops writing them — so a trip here is a sharing
        bug, never a load condition."""
        idx = int(position) // self.block_size
        chain = self._slot_blocks.get(slot, ())
        if idx < len(chain) and self.refcounts[chain[idx]] != 1:
            raise RuntimeError(
                f"COW violation: slot {slot} would write block {chain[idx]} "
                f"with refcount {int(self.refcounts[chain[idx]])}"
            )

    # ----------------------------------------------------- preemption spill --
    def _spill_pad(self, n: int) -> int:
        """Chain length padded to the next power of two (capped at
        ``max_blocks_per_slot``) so the spill gather/scatter jits compile
        once per bucket, not once per chain length."""
        b = 1
        while b < n:
            b *= 2
        return min(b, self.max_blocks_per_slot)

    def extract_blocks(self, slot: int) -> dict:
        """Read ``slot``'s block chain out of the arenas into host memory —
        the preemption *spill* path.  Returns ``{'len': n, 'layers': tree}``
        where every ``layers`` leaf is ``(L, n_padded, block_size, ...)``
        gathered at the chain's physical indices (padded by repeating the
        last block, so restore's duplicate scatter lanes carry identical
        values).  The payload is pure values — restoring it into a
        *different* physical chain later is fine, which is exactly what
        makes spilled blocks recyclable the moment the victim is evicted:
        the GN mask guarantee means the recycled blocks need no zeroing,
        and the spilled values need no fixed home."""
        chain = list(self._slot_blocks[slot])
        if not chain:
            return {"len": 0, "layers": None}
        npad = self._spill_pad(len(chain))
        idx = np.asarray(chain + [chain[-1]] * (npad - len(chain)), np.int32)
        if self._spill_gather_jit is None:
            self._spill_gather_jit = jax.jit(spill_gather)
        out = self._spill_gather_jit(self.cache["layers"], jnp.asarray(idx))
        return {"len": len(chain), "layers": jax.tree.map(np.asarray, out)}

    def restore_blocks(self, slot: int, payload: dict) -> None:
        """Scatter a spilled payload back into ``slot``'s (freshly ensured)
        block chain — the preemption *restore* path.  The chain's physical
        ids are generally different from the ones the payload was gathered
        from; only logical order matters.  Bitwise-exact: the scatter writes
        the same values the gather read, and every lane beyond ``len``
        duplicates logical block len-1 (index and data alike), so duplicate
        scatter indices always carry identical values — deterministic under
        any scatter ordering."""
        n = int(payload["len"])
        if n == 0:
            return
        chain = self._slot_blocks[slot]
        if len(chain) < n:
            raise ValueError(
                f"slot {slot}: restore needs {n} blocks ensured, chain has "
                f"{len(chain)} — call ensure(slot, position) first"
            )
        npad = self._spill_pad(n)
        idx = np.asarray(chain[:n] + [chain[n - 1]] * (npad - n), np.int32)
        if self._spill_scatter_jit is None:
            self._spill_scatter_jit = jax.jit(spill_scatter, donate_argnums=(0,))
        self.cache = self._spill_scatter_jit(
            self.cache,
            jax.tree.map(jnp.asarray, payload["layers"]),
            jnp.asarray(idx),
        )

    # ------------------------------------------------------------- contents --
    def insert(self, request_cache, slot: int, position: int) -> None:
        """Page a request's *non-paged* leaves (cross KV, patches) into
        ``slot``.  KV itself streams through the block table, so for plain
        dense/MLA requests this is pure host bookkeeping."""
        if slot not in self._used:
            raise ValueError(f"slot {slot} is not allocated")
        if position > self.max_seq:
            raise ValueError(f"position {position} exceeds max_seq {self.max_seq}")
        extras = {k: v for k, v in request_cache.items() if k != "layers"}
        if extras:
            self.cache = self._insert(
                self.cache,
                jax.tree.map(jnp.asarray, extras),
                jnp.asarray(slot, jnp.int32),
            )
        self.positions[slot] = position
        if position:
            self.ensure(slot, position)

    def advance(self, slots, by: int = 1) -> None:
        """Advance slot positions (same contract as SlotKVPool.advance)."""
        items = slots.items() if isinstance(slots, dict) else ((s, by) for s in slots)
        for slot, n in items:
            new = int(self.positions[slot]) + int(n)
            if new > self.max_seq:
                raise ValueError(
                    f"slot {slot}: position {new} exceeds max_seq {self.max_seq}"
                )
            self.positions[slot] = new

    # -------------------------------------------------------------- metrics --
    def hbm_bytes(self) -> int:
        """Resident device bytes: block arenas + non-paged leaves + tables."""
        return tree_bytes(self.cache) + self.tables.nbytes
