"""Radix prefix cache: token prefixes -> physical block chains.

Millions of users means massively shared prefixes (system prompts, few-shot
templates, multi-turn history).  ``BlockPagedKVPool`` already separates
physical blocks from per-slot tables, and the GN-softmax guarantee — masked
scores map to *exactly-zero* numerators with Σp = 1 through any block
layout — means a physical KV block reads identically through ANY slot's
table: sharing a block is a correctness-preserving transform, not an
approximation.  This module is the index that finds the blocks to share.

Structure: one radix tree per device (chains are device-local — a slot only
ever holds blocks from its own device's arena shard).  Each tree node keys
one **block-aligned token chunk** (``block_size`` tokens, hashed as the raw
int32 bytes) and holds the physical block whose KV covers those tokens at
those positions.  A node may additionally hold one *partial tail*: the
sub-block remainder of the most recently finished prompt under that node
(``len(tail_tokens) < block_size``), which is what lets admission share a
prefix past the last full-block boundary (the COW case — the engine forks
that block before the new request appends into it).

Content rule — only immutable prompt KV is ever indexed:

* full prompt blocks enter when their owner finishes *prefilling* (from
  then on the owner only writes at positions >= prompt_len, which live in
  later blocks);
* the partial prompt-tail block enters when the owner *finishes* (its
  decode appends land beyond every possible sharer's causal mask — matched
  reads stop at the matched token count, and masked columns contribute
  exactly 0 under GN);
* generated-token KV is never indexed: decode-step K need not be bitwise
  equal to prefill-chunk K, and greedy identity vs the unshared oracle is
  the subsystem's pinnable invariant — sharing only prompt-position KV
  keeps it exact by construction.

Reference counting lives in the pool (``BlockPagedKVPool.refcounts``); the
cache holds exactly one reference per indexed block and the pool recycles a
block only when its refcount hits zero.  Under block pressure the pool
reclaims cache-only blocks (refcount == 1) via ``evict_lru`` — leaf-first
(tails before childless nodes, never an interior node, so every surviving
chain stays matchable), LRU by a deterministic op counter (never wall
time — replay determinism is load-bearing for every serving test).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class PrefixHit:
    """One admission-time lookup result.

    ``blocks`` are the fully-matched chain blocks (``shared_len // bs`` of
    them) the slot will *attach* (refcount++, never written).  ``tail_src``
    is the source block for the partially-matched remainder
    (``shared_len % bs`` tokens), to be copy-on-write forked into a private
    block before the request's first divergent write; None when the match
    ends exactly on a block boundary."""

    device: int
    blocks: list[int]
    shared_len: int
    tail_src: Optional[int] = None

    @property
    def num_blocks(self) -> int:
        return len(self.blocks) + (1 if self.tail_src is not None else 0)


class _Node:
    __slots__ = ("block", "children", "tail", "stamp")

    def __init__(self, block: Optional[int]):
        self.block = block  # physical id; None only at the root
        self.children: dict[bytes, _Node] = {}
        # (tail_tokens bytes, token count, physical block) — at most one
        self.tail: Optional[tuple[bytes, int, int]] = None
        self.stamp = 0


class PrefixCache:
    """Per-device radix index from block-aligned token prefixes to physical
    block chains.  Pure host-side bookkeeping: the pool owns refcounts and
    free lists; the engine owns the device-side COW copy.  All ordering is
    driven by a deterministic op clock, so a reset engine replays a
    workload with identical hit/evict sequences."""

    def __init__(self, block_size: int, num_devices: int = 1):
        self.block_size = int(block_size)
        self.num_devices = int(num_devices)
        self.pool = None  # bound by BlockPagedKVPool.attach_prefix_cache
        self.clear()

    def clear(self) -> None:
        self._roots = [_Node(None) for _ in range(self.num_devices)]
        self._clock = 0
        self.hits = 0
        self.misses = 0
        self.inserts = 0
        self.evictions = 0

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    # --------------------------------------------------------------- size --
    def _iter_nodes(self, device: int):
        stack = [self._roots[device]]
        while stack:
            node = stack.pop()
            yield node
            stack.extend(node.children.values())

    def cached_blocks(self, device: Optional[int] = None) -> int:
        """Blocks currently held (referenced) by the index."""
        devs = range(self.num_devices) if device is None else (device,)
        n = 0
        for d in devs:
            for node in self._iter_nodes(d):
                if node.block is not None:
                    n += 1
                if node.tail is not None:
                    n += 1
        return n

    def evictable_count(self, device: int, refcounts: np.ndarray) -> int:
        """Cache-held blocks on ``device`` no live slot references
        (refcount == 1: the cache's own ref) — what block pressure can
        reclaim.  The simple count is exact *because* ``evict_lru`` has a
        subtree-cut fallback: an interior refcount-1 node whose descendants
        are pinned by live slots (a still-decoding request indexed its own
        chain at phase-flip) can't be reached leaf-first, but cutting its
        subtree drops the descendants' index entries (their owners re-index
        on finish) and reclaims it anyway — so every counted block is
        genuinely reachable and admission promises only what eviction can
        deliver."""
        n = 0
        for node in self._iter_nodes(device):
            if node.block is not None and refcounts[node.block] == 1:
                n += 1
            if node.tail is not None and refcounts[node.tail[2]] == 1:
                n += 1
        return n

    # ------------------------------------------------------------- lookup --
    def _chunks(self, tokens: np.ndarray, limit: int):
        bs = self.block_size
        for i in range(limit // bs):
            yield tokens[i * bs : (i + 1) * bs].tobytes()

    def match_len(self, tokens) -> int:
        """Longest indexed prefix of ``tokens`` in tokens, across devices,
        without touching LRU stamps — the scheduler's submit-time hint
        (admission re-runs the authoritative, stamp-touching ``lookup``)."""
        hit = self.lookup(tokens, touch=False)
        return hit.shared_len if hit else 0

    def lookup(self, tokens, cap: Optional[int] = None,
               touch: bool = True) -> Optional[PrefixHit]:
        """Longest matched prefix of ``tokens`` (over all devices; ties go
        to the lowest device — deterministic).  ``cap`` bounds the match
        length (the engine passes prompt_len - 1 so at least one prompt
        token always runs through prefill — the sampled next-token logits
        must come from the request's own final prompt position)."""
        tokens = np.ascontiguousarray(np.asarray(tokens, np.int32).reshape(-1))
        limit = tokens.shape[0] if cap is None else min(cap, tokens.shape[0])
        if limit <= 0:
            if touch:
                self.misses += 1
            return None
        bs = self.block_size
        best: Optional[PrefixHit] = None
        for d in range(self.num_devices):
            node = self._roots[d]
            path: list[_Node] = []
            for key in self._chunks(tokens, limit):
                child = node.children.get(key)
                if child is None:
                    break
                node = child
                path.append(node)
            shared = len(path) * bs
            tail_src = None
            if shared < limit:
                # extend past the full-block walk: the node's partial tail
                # and any partially-matching full-block child both offer a
                # COW fork source — take the longest token run (first
                # insertion wins ties: children iterate in insertion order,
                # deterministic)
                nxt = tokens[shared : min(shared + bs, limit)]
                extra, src, src_node = 0, None, None
                if node.tail is not None:
                    ttok, tlen, tblock = node.tail
                    want = np.frombuffer(ttok, np.int32)
                    n = min(tlen, nxt.shape[0])
                    run = int(np.cumprod(nxt[:n] == want[:n]).sum()) if n else 0
                    if run > extra:
                        extra, src = run, tblock
                for ckey, child in node.children.items():
                    have = np.frombuffer(ckey, np.int32)[: nxt.shape[0]]
                    run = int(np.cumprod(nxt == have).sum()) if nxt.size else 0
                    if run > extra:
                        extra, src, src_node = run, child.block, child
                if extra:
                    shared += extra
                    tail_src = src
                    if src_node is not None:
                        # a full-block child won: stamp it on touch so the
                        # fork source isn't the next LRU eviction victim
                        path.append(src_node)
            if shared and (best is None or shared > best.shared_len):
                full = [n.block for n in path[: shared // bs]]
                best = PrefixHit(device=d, blocks=full, shared_len=shared,
                                 tail_src=tail_src)
                best_path = path
        if best is None:
            if touch:
                self.misses += 1
            return None
        if touch:
            self.hits += 1
            stamp = self._tick()
            for n in best_path:
                n.stamp = stamp
        return best

    # ------------------------------------------------------------- insert --
    def insert(self, tokens, blocks: list[int], device: int) -> None:
        """Index ``tokens`` (a finished/prefilled prompt prefix) backed by
        the physical ``blocks`` chain (``ceil(len(tokens)/bs)`` entries).
        Existing nodes are kept (their block holds bitwise-identical KV —
        same tokens at same positions through the same jitted prefill), so
        only newly created nodes take a cache reference.  A sub-block
        remainder becomes the node's single partial tail, replacing (and
        releasing) any previous one."""
        if self.pool is None:
            raise RuntimeError("PrefixCache is not attached to a pool")
        tokens = np.ascontiguousarray(np.asarray(tokens, np.int32).reshape(-1))
        bs = self.block_size
        full = tokens.shape[0] // bs
        rem = tokens.shape[0] % bs
        node = self._roots[device]
        stamp = self._tick()
        for i, key in enumerate(self._chunks(tokens, full * bs)):
            child = node.children.get(key)
            if child is None:
                child = _Node(blocks[i])
                node.children[key] = child
                self.pool.cache_ref(blocks[i])
                self.inserts += 1
            child.stamp = stamp
            node = child
        if rem:
            tail_block = blocks[full]
            old = node.tail
            if old is not None and old[2] == tail_block and old[1] >= rem:
                return  # an equal-or-longer tail of the same block stands
            node.tail = (tokens[full * bs :].tobytes(), rem, tail_block)
            self.pool.cache_ref(tail_block)
            self.inserts += 1
            if old is not None:
                self.pool.cache_unref(old[2])

    # ------------------------------------------------------------ eviction --
    def evict_lru(self, device: int, refcounts: np.ndarray) -> Optional[int]:
        """Detach and return the least-recently-used evictable block on
        ``device`` (cache-only refcount).  Leaf-first: partial tails, then
        childless/tailless nodes, so surviving chains stay matchable.  When
        no leaf is evictable but refcount-1 nodes remain (their descendants
        are pinned — a live slot indexed its own chain at phase-flip), the
        deepest LRU such node's entire subtree is *cut*: every descendant's
        index entry is dropped (cache-only descendants recycle immediately;
        live-pinned ones merely lose their entry and are re-indexed when
        their owner finishes).  None when nothing is evictable; the caller
        (pool) drops the returned block's reference and recycles it."""
        root = self._roots[device]
        best = None  # leaf candidates: ((stamp, kind), holder, key, node)
        cut = None   # fallback: ((stamp, -depth), parent, key, node)
        stack = [(root, None, None, 0)]
        while stack:
            node, parent, key, depth = stack.pop()
            if node.tail is not None and refcounts[node.tail[2]] == 1:
                cand = ((node.stamp, 0), node, None, None)
                if best is None or cand[0] < best[0]:
                    best = cand
            if parent is not None and refcounts[node.block] == 1:
                if not node.children and node.tail is None:
                    cand = ((node.stamp, 1), parent, key, node)
                    if best is None or cand[0] < best[0]:
                        best = cand
                else:
                    # subtree-cut fallback: deepest LRU first, so ancestors
                    # (and the chains through them) survive the cut
                    cand = ((node.stamp, -depth), parent, key, node)
                    if cut is None or cand[0] < cut[0]:
                        cut = cand
            for k, child in node.children.items():
                stack.append((child, node, k, depth + 1))
        kind = 2
        if best is not None:
            (_, kind), holder, key, node = best
        elif cut is not None:
            _, holder, key, node = cut
        else:
            return None
        self.evictions += 1
        if kind == 0:
            block = holder.tail[2]
            holder.tail = None
            return block
        holder.children.pop(key)
        if kind == 2:
            # drop the subtree's index entries; the cut node's own block is
            # returned for the caller to unref, everything below unrefs here
            if node.tail is not None:
                self.pool.cache_unref(node.tail[2])
                node.tail = None
            stack = list(node.children.values())
            node.children = {}
            while stack:
                sub = stack.pop()
                self.pool.cache_unref(sub.block)
                if sub.tail is not None:
                    self.pool.cache_unref(sub.tail[2])
                stack.extend(sub.children.values())
        return node.block
