"""Deterministic synthetic serving workloads (staggered arrivals, mixed
prompt lengths) built on the Zipf-Markov corpus from ``data/synthetic.py``.

Shared by ``launch/serve.py --continuous``, ``examples/serve_continuous.py``
and ``benchmarks/serve_bench.py`` so the three always replay the same
requests for a given (arch, seed) — the greedy-identity check depends on it.
"""
from __future__ import annotations

import numpy as np

from repro.configs.base import ModelConfig
from repro.data.synthetic import DataConfig, batch_at
from repro.serve.scheduler import Request


def _extras_for(cfg: ModelConfig) -> dict:
    if cfg.family == "encdec":
        return {"frames": np.zeros((cfg.encoder_seq, cfg.d_model), np.float32)}
    if cfg.family == "vlm":
        return {"patches": np.zeros((cfg.num_patches, cfg.d_model), np.float32)}
    return {}


def staggered_requests(
    cfg: ModelConfig,
    n_requests: int = 12,
    base_len: int = 16,
    max_new_tokens: int = 8,
    stagger: int = 2,
    seed: int = 7,
    mixed_new: bool = True,
    tail_len: int = 0,
    tail_every: int = 0,
) -> list[Request]:
    """``n_requests`` prompts over 3 mixed lengths (base/2, base, 3*base/2),
    arriving every ``stagger`` engine steps; max_new alternates between the
    full budget and half of it when ``mixed_new`` (so the static baseline
    pays for stragglers that continuous batching retires early).

    ``tail_len``/``tail_every`` graft a long tail onto the mix: every
    ``tail_every``-th request (at phase tail_every-1) gets a ``tail_len``
    prompt instead.  One long request forces a slab pool to size *every*
    slot for it (num_slots x max_seq HBM); a block-paged pool only spends
    blocks on the tail itself — the regime the paged-KV bench measures."""
    lens = [max(4, base_len // 2), base_len, base_len + base_len // 2]
    reqs = []
    for i in range(n_requests):
        plen = lens[i % len(lens)]
        if tail_len and tail_every and i % tail_every == tail_every - 1:
            plen = tail_len
        data = DataConfig(vocab=cfg.vocab, seq_len=plen, global_batch=1, seed=seed + i)
        tokens = np.asarray(batch_at(data, 0)["tokens"][0], np.int32)
        new = max(1, max_new_tokens if (not mixed_new or i % 2 == 0)
                  else max(2, max_new_tokens // 2))
        reqs.append(Request(
            id=i,
            tokens=tokens,
            max_new_tokens=new,
            arrival_step=i * stagger,
            extras=_extras_for(cfg),
        ))
    return reqs


def shared_prefix_requests(
    cfg: ModelConfig,
    n_users: int = 12,
    n_personas: int = 3,
    system_len: int = 48,
    persona_len: int = 12,
    user_len: int = 8,
    max_new_tokens: int = 8,
    stagger: int = 2,
    seed: int = 11,
) -> list[Request]:
    """The prefix-sharing workload: ``n_users`` requests over ONE common
    system prompt (``system_len`` tokens, shared by everyone), each routed
    through one of ``n_personas`` persona preambles (``persona_len`` tokens,
    shared within a persona, round-robin assigned), followed by a
    per-user-unique ``user_len`` suffix:

        prompt_i = system ++ persona[i % n_personas] ++ user_i

    Arrivals stagger every ``stagger`` steps so early finishers seed the
    radix cache for later arrivals — the first request of each persona pays
    the full prefill, everyone after it should hit (system + persona) and
    prefill only the user tail.  Deterministic in ``seed`` (the same
    Zipf-Markov corpus as ``staggered_requests``), so engine resets replay
    identical hit/evict sequences."""
    def _draw(length: int, s: int) -> np.ndarray:
        data = DataConfig(vocab=cfg.vocab, seq_len=length, global_batch=1, seed=s)
        return np.asarray(batch_at(data, 0)["tokens"][0], np.int32)

    system = _draw(system_len, seed)
    personas = [_draw(persona_len, seed + 1 + p) for p in range(n_personas)]
    reqs = []
    for i in range(n_users):
        tail = _draw(user_len, seed + 100 + i)
        tokens = np.concatenate([system, personas[i % n_personas], tail])
        reqs.append(Request(
            id=i,
            tokens=tokens,
            max_new_tokens=max_new_tokens,
            arrival_step=i * stagger,
            extras=_extras_for(cfg),
        ))
    return reqs


def sla_requests(
    cfg: ModelConfig,
    n_requests: int = 32,
    base_len: int = 16,
    rate: float = 0.35,
    burst_factor: float = 4.0,
    interactive_frac: float = 0.5,
    max_new_interactive: int = 6,
    max_new_batch: int = 20,
    seed: int = 13,
) -> list[Request]:
    """Open-loop bursty arrivals with SLA classes, for the `sla` scenario.

    Arrivals follow a seeded two-state Markov-modulated Poisson process:
    a calm state with mean inter-arrival ``1/rate`` engine steps and a
    burst state running ``burst_factor`` times hotter; the state flips
    with fixed seeded probabilities per arrival (sticky bursts), so the
    trace alternates quiet stretches with pile-ups — the regime where
    queue wait dominates TTFT and FCFS lets batch traffic block chat.

    Each request is independently classed: ``interactive`` (short prompts
    from {base/2, base}, ``max_new_interactive`` budget) with probability
    ``interactive_frac``, else ``batch`` (longer prompts from
    {base, 3*base/2, 2*base}, ``max_new_batch`` budget).  Everything —
    arrivals, classes, lengths, token content — is a pure function of
    ``seed``, so the same seed replays the identical
    arrival/admission/preemption/shedding trace on the engine's step
    clock.
    """
    rng = np.random.default_rng(seed)
    short_lens = [max(4, base_len // 2), base_len]
    long_lens = [base_len, base_len + base_len // 2, 2 * base_len]
    reqs = []
    clock = 0.0
    burst = False
    for i in range(n_requests):
        # sticky two-state modulation: ~25% chance to enter a burst,
        # ~70% chance to stay in one
        burst = rng.random() < (0.70 if burst else 0.25)
        eff_rate = rate * (burst_factor if burst else 1.0)
        clock += rng.exponential(1.0 / eff_rate)
        is_interactive = rng.random() < interactive_frac
        if is_interactive:
            plen = short_lens[int(rng.integers(len(short_lens)))]
            new = max_new_interactive
            klass = "interactive"
        else:
            plen = long_lens[int(rng.integers(len(long_lens)))]
            new = max_new_batch
            klass = "batch"
        data = DataConfig(vocab=cfg.vocab, seq_len=plen, global_batch=1,
                          seed=seed + 1000 + i)
        tokens = np.asarray(batch_at(data, 0)["tokens"][0], np.int32)
        reqs.append(Request(
            id=i,
            tokens=tokens,
            max_new_tokens=new,
            arrival_step=int(clock),
            extras=_extras_for(cfg),
            req_class=klass,
        ))
    return reqs


def required_max_seq(requests) -> int:
    return max(r.prompt_len + r.max_new_tokens for r in requests)
