"""Three-term roofline analysis from a compiled AOT artifact.

  compute    = HLO_FLOPs / (chips x peak)          [peak: 197 TFLOP/s bf16 / chip]
  memory     = HLO_bytes / (chips x HBM_bw)        [819 GB/s / chip]
  collective = collective_bytes / (chips x link)   [~50 GB/s / link ICI]

``cost_analysis()`` reports the *per-device* partitioned program, so the
per-device quantities divided by per-chip peaks equal the formulas above.
collective_bytes is not in cost_analysis: we parse the optimized (post-SPMD)
HLO text and sum the tensor bytes moved by every collective op, with the
standard ring accounting (all-reduce counts 2x: reduce-scatter + all-gather).
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12  # bf16 / chip (TPU v5e)
HBM_BW = 819e9  # bytes/s / chip
ICI_BW = 50e9  # bytes/s / link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

# matches e.g. "bf16[256,1024]{1,0}" or "f32[]"
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

_COLLECTIVE_KINDS = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Per-device bytes moved by collectives, by kind (ring accounting)."""
    out = {k: 0 for k in _COLLECTIVE_KINDS}
    count = {k: 0 for k in _COLLECTIVE_KINDS}
    for line in hlo_text.splitlines():
        ls = line.strip()
        # result = <shape> <op>(...) — find which collective op this line is
        m = re.search(r"=\s*(\(?[\w\[\],{}\s/]*?\)?)\s*(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)(-start)?\(", ls)
        if not m:
            continue
        kind = m.group(2)
        if "-done" in ls.split("=")[1][:60]:
            continue  # paired -done carries no new traffic
        shapes = _SHAPE_RE.findall(m.group(1))
        nbytes = sum(_shape_bytes(d, s) for d, s in shapes)
        if kind == "all-reduce":
            nbytes *= 2  # reduce-scatter + all-gather ring phases
        out[kind] += nbytes
        count[kind] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVE_KINDS)
    out["op_counts"] = count
    return out


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    model_flops: float
    hlo_flops_total: float

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def total_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — catches remat/redundancy waste."""
        return self.model_flops / max(self.hlo_flops_total, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """Achievable MFU bound: useful flop-time over the dominant term."""
        useful_time = self.model_flops_per_device_s
        return useful_time / max(self.total_s, 1e-30)

    @property
    def model_flops_per_device_s(self) -> float:
        return self.model_flops / self.n_chips / PEAK_FLOPS if self.n_chips else 0.0

    n_chips: int = 256

    def to_dict(self) -> dict:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "model_flops": self.model_flops,
            "hlo_flops_total": self.hlo_flops_total,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "n_chips": self.n_chips,
        }


def analyze(compiled, n_chips: int, model_flops: float, hlo_text: str | None = None) -> Roofline:
    """Trip-count-aware roofline terms (see hlo_cost.py for why not
    cost_analysis(): XLA counts while/scan bodies once)."""
    from repro.roofline.hlo_cost import module_cost

    text = hlo_text if hlo_text is not None else compiled.as_text()
    cost = module_cost(text)
    flops = cost.flops
    nbytes = cost.bytes
    r = Roofline(
        compute_s=flops / PEAK_FLOPS,
        memory_s=nbytes / HBM_BW,
        collective_s=cost.collective_total / ICI_BW,
        flops_per_device=flops,
        bytes_per_device=nbytes,
        collective_bytes_per_device=cost.collective_total,
        model_flops=model_flops,
        hlo_flops_total=flops * n_chips,
        n_chips=n_chips,
    )
    return r


def model_flops_for(cfg, shape) -> float:
    """6·N·D (train) / 2·N·D (prefill fwd-only) / 2·N·B per decode step."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch  # one decode step
