"""Trip-count-aware HLO cost model.

``compiled.cost_analysis()`` counts the body of a ``while`` loop **once**,
regardless of trip count (verified empirically: an 8-step ``lax.scan`` of a
512x512 matmul reports 1 matmul of flops, the unrolled version reports 8).
Every model in this framework scans its layers, so the XLA numbers undercount
flops/bytes/collectives by ~n_layers — fatal for a roofline.

This module re-derives the three roofline quantities by walking the
post-optimization HLO text with loop multipliers:

  * ``while`` ops carry ``backend_config={"known_trip_count":{"n":...}}``
    (XLA annotates counted loops produced by ``lax.scan``/``fori_loop``);
    body costs are scaled by the trip count, condition by trip+1.
  * ``fusion`` ops contribute the *flops* of their fused computation but the
    *bytes* of only their operands/outputs (HBM <-> fusion boundary), matching
    XLA's HloCostAnalysis semantics.
  * collectives are summed per kind with ring accounting (all-reduce counts
    2x: reduce-scatter + all-gather phase), scaled by the enclosing loops'
    trip counts.

Calibration: on loop-free programs the flops agree exactly with
``cost_analysis()`` and bytes agree to fusion-boundary differences; on
scanned programs they agree with the *unrolled* oracle (tests/test_roofline_cost.py).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1,
}

_ARRAY_RE = re.compile(r"(\w+)\[([\d,]*)\]")

# elementwise opcodes: 1 flop per output element (XLA HloCostAnalysis default)
_ELEMENTWISE = frozenset({
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "remainder", "atan2", "compare", "select", "clamp", "and", "or", "xor",
    "not", "negate", "abs", "sign", "exponential", "exponential-minus-one",
    "log", "log-plus-one", "tanh", "sqrt", "rsqrt", "cbrt", "sine", "cosine",
    "tan", "erf", "logistic", "round-nearest-afz", "round-nearest-even",
    "floor", "ceil", "is-finite", "convert", "shift-left",
    "shift-right-arithmetic", "shift-right-logical", "popcnt",
    "count-leading-zeros", "stochastic-convert", "real", "imag",
})

# opcodes that move no HBM bytes of their own
_FREE_BYTES = frozenset({
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "add-dependency", "partition-id", "replica-id", "domain",
    "opt-barrier",
})

_COLLECTIVE_KINDS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast",
)


def _array_elems(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def _buffer_bytes(type_str: str) -> int:
    """Total bytes of every array in a (possibly tuple) HLO type string."""
    total = 0
    for dt, dims in _ARRAY_RE.findall(type_str):
        if dt in _DTYPE_BYTES:
            total += _array_elems(dims) * _DTYPE_BYTES[dt]
    return total


def _out_elems(type_str: str) -> int:
    """Element count of the first array in the result type."""
    m = _ARRAY_RE.search(type_str)
    return _array_elems(m.group(2)) if m else 0


@dataclasses.dataclass
class Instr:
    name: str
    result_type: str
    opcode: str
    operands: list[str]
    attrs: str  # raw text after the operand list

    _dims_re = re.compile(r"(\w+_dims)=\{([\d,]*)\}")
    _called_re = re.compile(
        r"(?:calls|to_apply|body|condition|true_computation|false_computation)"
        r"=%?([\w.\-]+)"
    )
    _branch_re = re.compile(r"branch_computations=\{([^}]*)\}")
    _trip_re = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')

    def dot_dims(self) -> dict[str, tuple[int, ...]]:
        return {
            k: tuple(int(x) for x in v.split(",")) if v else ()
            for k, v in self._dims_re.findall(self.attrs)
        }

    def called(self, key: str) -> Optional[str]:
        m = re.search(rf"{key}=%?([\w.\-]+)", self.attrs)
        return m.group(1) if m else None

    def branches(self) -> list[str]:
        m = self._branch_re.search(self.attrs)
        if m:
            return re.findall(r"%?([\w.\-]+)", m.group(1))
        out = []
        for key in ("true_computation", "false_computation"):
            c = self.called(key)
            if c:
                out.append(c)
        return out

    def trip_count(self) -> Optional[int]:
        m = self._trip_re.search(self.attrs)
        return int(m.group(1)) if m else None


_COMP_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_OPERAND_NAME_RE = re.compile(r"%([\w.\-]+)")


def _split_instr_rhs(rhs: str) -> Optional[tuple[str, str, list[str], str]]:
    """'<type> <opcode>(<operands>)<attrs>' -> (type, opcode, operands, attrs)."""
    rhs = rhs.strip()
    if rhs.startswith("("):  # tuple result type: find matching close paren
        depth = 0
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        type_str, rest = rhs[: i + 1], rhs[i + 1 :].strip()
    else:
        sp = rhs.find(" ")
        if sp < 0:
            return None
        type_str, rest = rhs[:sp], rhs[sp + 1 :].strip()
    m = re.match(r"([\w\-]+)\(", rest)
    if not m:
        return None
    opcode = m.group(1)
    depth = 0
    start = m.end() - 1
    for i in range(start, len(rest)):
        if rest[i] == "(":
            depth += 1
        elif rest[i] == ")":
            depth -= 1
            if depth == 0:
                break
    operand_str = rest[start + 1 : i]
    attrs = rest[i + 1 :]
    operands = _OPERAND_NAME_RE.findall(operand_str)
    return type_str, opcode, operands, attrs


def parse_hlo_computations(hlo_text: str) -> tuple[dict[str, list[Instr]], str]:
    """-> ({computation_name: [Instr, ...]}, entry_name)."""
    comps: dict[str, list[Instr]] = {}
    entry = ""
    cur: Optional[list[Instr]] = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HEADER_RE.match(line.strip())
            if m:
                name = m.group(2)
                comps[name] = []
                cur = comps[name]
                if m.group(1):
                    entry = name
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        parsed = _split_instr_rhs(m.group(2))
        if parsed is None:
            continue
        type_str, opcode, operands, attrs = parsed
        cur.append(Instr(m.group(1), type_str, opcode, operands, attrs))
    return comps, entry


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    collective: dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVE_KINDS}
    )
    warnings: list[str] = dataclasses.field(default_factory=list)

    def __iadd__(self, other: "Cost"):
        self.flops += other.flops
        self.bytes += other.bytes
        for k in _COLLECTIVE_KINDS:
            self.collective[k] += other.collective[k]
        self.warnings.extend(other.warnings)
        return self

    def scaled(self, mult: float) -> "Cost":
        return Cost(
            self.flops * mult,
            self.bytes * mult,
            {k: v * mult for k, v in self.collective.items()},
            list(self.warnings),
        )

    @property
    def collective_total(self) -> float:
        return sum(self.collective.values())


class HloCostModel:
    """Trip-count-aware cost walk over parsed HLO.

    ``tpu_native=True`` (default) corrects for XLA:CPU's bf16 legalization:
    the CPU backend rewrites every bf16 dot as convert->f32 dot->convert,
    materializing f32 copies that do not exist on the TPU target (the MXU
    consumes bf16 operands directly; output conversion fuses into the
    epilogue).  The adjustment (a) prices pure-convert fusions at zero bytes/
    flops, and (b) prices dot operands at the convert's *source* dtype and a
    dot output consumed only by a narrowing convert at the *destination*
    dtype.  Nothing else is touched, so genuinely-f32 traffic (norm
    statistics, cotangent chains) still counts at 4 bytes.
    """

    def __init__(self, hlo_text: str, tpu_native: bool = True):
        self.comps, self.entry = parse_hlo_computations(hlo_text)
        self._memo: dict[str, Cost] = {}
        self.tpu_native = tpu_native
        self._pure_convert: dict[str, tuple[str, str]] = {}
        if tpu_native:
            self._find_pure_converts()

    _CONVERT_OK = frozenset({"parameter", "convert", "copy", "bitcast", "reshape", "transpose"})

    def _find_pure_converts(self):
        """comp name -> (src_type_str, dst_type_str) for convert-only bodies.

        Also detects *in-place update fusions*: computations whose ROOT is a
        ``dynamic-update-slice`` applied directly to a parameter (the donated
        KV-cache / grad-buffer pattern).  On TPU these alias their operand and
        write only the update window; pricing them at whole-buffer size made
        every decode cell look ~1000x memory-bound (EXPERIMENTS.md §Perf D1).
        ``self._dus_fusions[name] = update_bytes``.
        """
        _DUS_OK = self._CONVERT_OK | {
            "dynamic-update-slice", "dynamic-slice", "broadcast", "constant",
            # scalar index plumbing around cache updates (clamps, ring-buffer
            # slot selects); the <10% size-ratio guard below bounds the risk
            # of discounting genuine whole-buffer arithmetic
            "select", "compare", "minimum", "maximum", "add", "subtract",
            "and", "or", "not", "clamp",
        }
        # dtype/layout pass-throughs; a dynamic-slice of a parameter is a
        # view of the (aliased) buffer under scan-over-layers
        _PASS = self._CONVERT_OK | {"dynamic-slice"}
        self._dus_fusions: dict[str, int] = {}
        for name, comp in self.comps.items():
            n_convert = 0
            src = dst = None
            pure_ok = True
            dus_ok = True
            dus = None
            for i in comp:
                if i.opcode not in self._CONVERT_OK:
                    pure_ok = False
                if i.opcode not in _DUS_OK:
                    dus_ok = False
                if i.opcode == "convert":
                    n_convert += 1
                    dst = i.result_type
                if i.opcode == "dynamic-update-slice":
                    dus = i
            if dus_ok and dus is not None and len(dus.operands) >= 2:
                # in-place iff the updated buffer chains to a parameter through
                # dtype/layout pass-throughs only (the wholesale f32 convert
                # around a bf16 KV cache is XLA:CPU legalization — on TPU the
                # cache is updated in place and the dot reads it natively)
                shapes = {i.name: i.result_type for i in comp}
                by_name = {i.name: i for i in comp}
                cur = by_name.get(dus.operands[0])
                hops = 0
                while cur is not None and cur.opcode in _PASS and cur.opcode != "parameter" and hops < 8:
                    cur = by_name.get(cur.operands[0]) if cur.operands else None
                    hops += 1
                if cur is not None and cur.opcode == "parameter":
                    upd = _buffer_bytes(shapes.get(dus.operands[1], ""))
                    buf = _buffer_bytes(dus.result_type)
                    if buf > 0 and upd < 0.1 * buf:  # true slice-update only
                        self._dus_fusions[name] = upd
                continue
            if pure_ok and n_convert == 1:
                self._pure_convert[name] = (src or "", dst or "")

    def _is_pure_convert_fusion(self, instr: Instr) -> bool:
        if instr.opcode == "convert":
            # newer XLA:CPU schedules leave legalization converts unfused
            return True
        if instr.opcode != "fusion":
            return False
        callee = instr.called("calls")
        return callee in self._pure_convert

    # ------------------------------------------------------------- flops ---
    def _dot_flops(self, instr: Instr, shapes: dict[str, str]) -> float:
        dims = instr.dot_dims()
        lhs_type = shapes.get(instr.operands[0], "") if instr.operands else ""
        m = _ARRAY_RE.search(lhs_type)
        if not m:
            return 0.0
        lhs_shape = [int(x) for x in m.group(2).split(",")] if m.group(2) else []
        contract = 1
        for d in dims.get("lhs_contracting_dims", ()):
            if d < len(lhs_shape):
                contract *= lhs_shape[d]
        return 2.0 * _out_elems(instr.result_type) * contract

    def _fusion_flops(self, comp_name: str, shapes_stack: set[str]) -> float:
        """Flops inside a fused computation (bytes stay at the boundary)."""
        if comp_name not in self.comps or comp_name in shapes_stack:
            return 0.0
        total = 0.0
        comp = self.comps[comp_name]
        shapes = {i.name: i.result_type for i in comp}
        for instr in comp:
            op = instr.opcode
            if op in _ELEMENTWISE:
                total += _out_elems(instr.result_type)
            elif op == "dot":
                total += self._dot_flops(instr, shapes)
            elif op in ("reduce", "reduce-window"):
                if instr.operands:
                    total += _out_elems(shapes.get(instr.operands[0], ""))
            elif op == "fusion" or op == "call":
                callee = instr.called("calls") or instr.called("to_apply")
                if callee:
                    total += self._fusion_flops(callee, shapes_stack | {comp_name})
        return total

    # -------------------------------------------------------- computation ---
    def comp_cost(self, name: str, _stack: tuple = ()) -> Cost:
        if name in self._memo:
            return self._memo[name]
        if name not in self.comps or name in _stack:
            return Cost()
        total = Cost()
        comp = self.comps[name]
        shapes = {i.name: i.result_type for i in comp}
        by_name = {i.name: i for i in comp}
        stack = _stack + (name,)

        uses: dict[str, list[Instr]] = {}
        if self.tpu_native:
            for i in comp:
                for o in i.operands:
                    uses.setdefault(o, []).append(i)

        def _native_operand_bytes(oname: str) -> int:
            """Operand bytes at the pre-legalization dtype (see class doc)."""
            prod = by_name.get(oname)
            if prod is not None and self._is_pure_convert_fusion(prod) and prod.operands:
                return _buffer_bytes(shapes.get(prod.operands[0], ""))
            return _buffer_bytes(shapes.get(oname, ""))

        for instr in comp:
            op = instr.opcode
            base = op[:-6] if op.endswith("-start") else op
            out_bytes = _buffer_bytes(instr.result_type)
            opnd_bytes = sum(_buffer_bytes(shapes.get(o, "")) for o in instr.operands)

            if op.endswith("-done") or op in _FREE_BYTES:
                continue
            if self.tpu_native and self._is_pure_convert_fusion(instr):
                continue  # does not exist on the TPU target (fuses away)
            if (
                self.tpu_native
                and op == "fusion"
                and instr.called("calls") in getattr(self, "_dus_fusions", {})
            ):
                # in-place aliased update: read+write the slice only
                total.bytes += 2.0 * self._dus_fusions[instr.called("calls")]
                continue
            if self.tpu_native and op == "dot":
                opnd_bytes = sum(_native_operand_bytes(o) for o in instr.operands)
                consumers = uses.get(instr.name, [])
                if consumers and all(self._is_pure_convert_fusion(c) for c in consumers):
                    out_bytes = min(
                        out_bytes,
                        sum(_buffer_bytes(c.result_type) for c in consumers),
                    )

            # --- control flow: descend with multipliers --------------------
            if op == "while":
                tc = instr.trip_count()
                if tc is None:
                    tc = 1
                    total.warnings.append(f"while {instr.name}: unknown trip count, using 1")
                body = instr.called("body")
                cond = instr.called("condition")
                if body:
                    total += self.comp_cost(body, stack).scaled(tc)
                if cond:
                    total += self.comp_cost(cond, stack).scaled(tc + 1)
                continue
            if op == "conditional":
                branches = [self.comp_cost(b, stack) for b in instr.branches()]
                if branches:
                    # max over branches: the executed path bound
                    best = max(branches, key=lambda c: (c.flops, c.bytes))
                    total += best
                continue
            if op in ("call", "async-start"):
                callee = instr.called("calls") or instr.called("to_apply")
                if callee:
                    total += self.comp_cost(callee, stack)
                continue

            # --- collectives ------------------------------------------------
            if base in _COLLECTIVE_KINDS:
                if base == "all-reduce":
                    moved = 2.0 * opnd_bytes
                elif base == "all-gather":
                    moved = float(out_bytes)
                else:  # reduce-scatter / all-to-all / permute: operand leaves
                    moved = float(opnd_bytes)
                total.collective[base] += moved
                total.bytes += opnd_bytes + out_bytes
                continue

            # --- leaf bytes -------------------------------------------------
            if op in ("dynamic-slice", "slice"):
                total.bytes += 2.0 * out_bytes  # reads only the slice
            elif op == "dynamic-update-slice":
                upd = _buffer_bytes(shapes.get(instr.operands[1], "")) if len(instr.operands) > 1 else 0
                total.bytes += 2.0 * upd  # in-place: read+write the update window
            elif op == "gather":
                idx = _buffer_bytes(shapes.get(instr.operands[1], "")) if len(instr.operands) > 1 else 0
                total.bytes += 2.0 * out_bytes + idx
            elif op == "scatter":
                upd = _buffer_bytes(shapes.get(instr.operands[-1], "")) if instr.operands else 0
                idx = _buffer_bytes(shapes.get(instr.operands[1], "")) if len(instr.operands) > 2 else 0
                total.bytes += 2.0 * upd + idx
            else:
                total.bytes += opnd_bytes + out_bytes

            # --- leaf flops -------------------------------------------------
            if op in _ELEMENTWISE:
                total.flops += _out_elems(instr.result_type)
            elif op == "dot":
                total.flops += self._dot_flops(instr, shapes)
            elif op in ("reduce", "reduce-window"):
                if instr.operands:
                    total.flops += _out_elems(shapes.get(instr.operands[0], ""))
            elif op == "fusion":
                callee = instr.called("calls")
                if callee:
                    total.flops += self._fusion_flops(callee, set(stack))
            elif op == "convolution":
                total.warnings.append(f"convolution {instr.name}: flops not modeled")

        self._memo[name] = total
        return total

    def module_cost(self) -> Cost:
        if not self.entry:
            c = Cost()
            c.warnings.append("no ENTRY computation found")
            return c
        return self.comp_cost(self.entry)


def module_cost(hlo_text: str) -> Cost:
    """Trip-count-aware (flops, bytes, collective bytes) for one HLO module."""
    return HloCostModel(hlo_text).module_cost()


_METADATA_RE = re.compile(r'op_name="([^"]*)"')


def cost_breakdown(hlo_text: str, top_k: int = 25) -> dict:
    """Loop-scaled per-instruction attribution: the dry-run 'profile'.

    Returns {"by_bytes": [(desc, bytes)], "by_flops": [(desc, flops)]} with
    the jaxpr op_name metadata (model source path) as the description, so a
    hillclimb can see *which model code* owns the dominant roofline term.
    """
    model = HloCostModel(hlo_text)
    entries: dict[str, list[float]] = {}

    def leaf(instr: Instr, comp_shapes: dict, mult: float):
        sub = HloCostModel.__new__(HloCostModel)
        sub.comps, sub._memo = model.comps, {}
        one = Cost()
        # reuse the single-instruction accounting by running comp_cost on a
        # synthetic computation is overkill; inline the same rules instead
        op = instr.opcode
        base = op[:-6] if op.endswith("-start") else op
        out_bytes = _buffer_bytes(instr.result_type)
        opnd_bytes = sum(_buffer_bytes(comp_shapes.get(o, "")) for o in instr.operands)
        if op.endswith("-done") or op in _FREE_BYTES:
            return
        if model._is_pure_convert_fusion(instr):
            return  # bf16-legalization artifact, absent on TPU
        if op == "fusion" and instr.called("calls") in getattr(model, "_dus_fusions", {}):
            one.bytes = 2.0 * model._dus_fusions[instr.called("calls")]
            e = entries.setdefault(f"fusion[in-place dus] {instr.result_type.split('{')[0]}", [0.0, 0.0])
            e[1] += one.bytes * mult
            return
        if base in _COLLECTIVE_KINDS:
            one.bytes = opnd_bytes + out_bytes
        elif op in ("dynamic-slice", "slice"):
            one.bytes = 2.0 * out_bytes
        elif op == "dynamic-update-slice":
            one.bytes = 2.0 * (_buffer_bytes(comp_shapes.get(instr.operands[1], "")) if len(instr.operands) > 1 else 0)
        elif op == "gather":
            one.bytes = 2.0 * out_bytes
        elif op == "scatter":
            one.bytes = 2.0 * (_buffer_bytes(comp_shapes.get(instr.operands[-1], "")) if instr.operands else 0)
        else:
            one.bytes = opnd_bytes + out_bytes
        if op in _ELEMENTWISE:
            one.flops = _out_elems(instr.result_type)
        elif op == "dot":
            one.flops = model._dot_flops(instr, comp_shapes)
        elif op in ("reduce", "reduce-window"):
            one.flops = _out_elems(comp_shapes.get(instr.operands[0], "")) if instr.operands else 0
        elif op == "fusion":
            callee = instr.called("calls")
            if callee:
                one.flops = model._fusion_flops(callee, set())
        m = _METADATA_RE.search(instr.attrs)
        src = m.group(1) if m else instr.name
        key = f"{op} {instr.result_type.split('{')[0]} [{src}]"
        e = entries.setdefault(key, [0.0, 0.0])
        e[0] += one.flops * mult
        e[1] += one.bytes * mult

    def walk(comp_name: str, mult: float, stack: tuple):
        if comp_name not in model.comps or comp_name in stack:
            return
        comp = model.comps[comp_name]
        shapes = {i.name: i.result_type for i in comp}
        for instr in comp:
            op = instr.opcode
            if op == "while":
                tc = instr.trip_count() or 1
                body, cond = instr.called("body"), instr.called("condition")
                if body:
                    walk(body, mult * tc, stack + (comp_name,))
                if cond:
                    walk(cond, mult * (tc + 1), stack + (comp_name,))
            elif op == "conditional":
                for b in instr.branches():
                    walk(b, mult, stack + (comp_name,))
            elif op in ("call", "async-start"):
                callee = instr.called("calls") or instr.called("to_apply")
                if callee:
                    walk(callee, mult, stack + (comp_name,))
            else:
                leaf(instr, shapes, mult)

    walk(model.entry, 1.0, ())
    by_bytes = sorted(entries.items(), key=lambda kv: -kv[1][1])[:top_k]
    by_flops = sorted(entries.items(), key=lambda kv: -kv[1][0])[:top_k]
    return {
        "by_bytes": [(k, v[1]) for k, v in by_bytes],
        "by_flops": [(k, v[0]) for k, v in by_flops],
    }


def collective_bytes(hlo_text: str) -> dict:
    """Per-device collective bytes by kind, loop-scaled (ring accounting)."""
    c = module_cost(hlo_text)
    out = {k: c.collective[k] for k in _COLLECTIVE_KINDS}
    out["total"] = c.collective_total
    return out
