"""--arch zamba2-7b (see registry.py for the exact published config)."""
from repro.configs.registry import ZAMBA2_7B as CONFIG

__all__ = ["CONFIG"]
