"""--arch deepseek-coder-33b (see registry.py for the exact published config)."""
from repro.configs.registry import DEEPSEEK_CODER_33B as CONFIG

__all__ = ["CONFIG"]
