"""--arch xlstm-350m (see registry.py for the exact published config)."""
from repro.configs.registry import XLSTM_350M as CONFIG

__all__ = ["CONFIG"]
