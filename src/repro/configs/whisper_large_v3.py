"""--arch whisper-large-v3 (see registry.py for the exact published config)."""
from repro.configs.registry import WHISPER_LARGE_V3 as CONFIG

__all__ = ["CONFIG"]
