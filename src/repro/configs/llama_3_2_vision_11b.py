"""--arch llama-3.2-vision-11b (see registry.py for the exact published config)."""
from repro.configs.registry import LLAMA32_VISION_11B as CONFIG

__all__ = ["CONFIG"]
