"""--arch mixtral-8x22b (see registry.py for the exact published config)."""
from repro.configs.registry import MIXTRAL_8X22B as CONFIG

__all__ = ["CONFIG"]
