"""--arch stablelm-1.6b (see registry.py for the exact published config)."""
from repro.configs.registry import STABLELM_1_6B as CONFIG

__all__ = ["CONFIG"]
