"""--arch llama4-scout-17b-a16e (see registry.py for the exact published config)."""
from repro.configs.registry import LLAMA4_SCOUT as CONFIG

__all__ = ["CONFIG"]
