"""--arch bert-base (see registry.py for the exact published config)."""
from repro.configs.registry import BERT_BASE as CONFIG

__all__ = ["CONFIG"]
