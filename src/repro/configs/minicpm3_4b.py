"""--arch minicpm3-4b (see registry.py for the exact published config)."""
from repro.configs.registry import MINICPM3_4B as CONFIG

__all__ = ["CONFIG"]
