"""Config dataclasses: model architecture + run shapes.

Every assigned architecture is a ``ModelConfig`` instance in its own
``configs/<id>.py``; the paper's technique is selected with the
``softmax_impl`` / ``norm_impl`` strings (see repro.core.api).
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    capacity_factor: float = 1.25
    group_size: int = 2048  # GShard dispatch group (tokens)


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    kind: str = "mamba2"  # 'mamba2' | 'mlstm'
    state_dim: int = 64
    head_dim: int = 64
    expand: int = 2
    conv_dim: int = 4
    # chunked-SSD block length for train/prefill (perf iteration C1, see
    # EXPERIMENTS.md §Perf): the recurrent per-token scan reads+writes the
    # (B,H,dh,N) f32 state every step — chunking turns that into per-chunk
    # MXU matmuls.  0 disables (pure recurrent form everywhere).
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 => d_model // n_heads

    # non-GEMM implementation choice (the paper's axis)
    softmax_impl: str = "gn"
    norm_impl: str = "gn_rms"  # llama-family default; LN archs override

    # MoE token routing: 'einsum' (GShard one-hot dispatch) or 'gather'
    # (scatter/gather permutation).  Perf A3 (§Perf): 'gather' removes the
    # dispatch-einsum flops (-45% compute on mixtral train_4k) but GSPMD
    # reshards around the scatters so badly that bytes +47% / collective
    # +2x — net WORSE on the measured roofline, so 'einsum' stays the
    # default; 'gather' is the right base for a future ragged/megablox-style
    # TPU kernel.
    moe_dispatch: str = "einsum"

    # family extensions
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    sliding_window: int = 0        # 0 = full attention (mixtral: 4096)
    attn_every: int = 0            # hybrid: shared attn block cadence (zamba2)
    cross_attn_every: int = 0      # vlm: gated cross-attn cadence
    encoder_layers: int = 0        # encdec: encoder depth
    encoder_seq: int = 1500        # audio frames after the (stubbed) conv frontend
    num_patches: int = 1601        # vlm patches from the (stubbed) vision tower
    mlp_act: str = "swiglu"        # swiglu | gelu

    rope_theta: float = 10000.0
    dtype: str = "bfloat16"        # activation/compute dtype
    param_dtype: str = "float32"

    # execution knobs
    scan_layers: bool = True
    remat: str = "full"            # none | full | dots
    use_pallas: bool = False       # single-chip TPU hot path (interpret-tested)
    # Adam m/v dtype (perf A7): 'bfloat16' halves optimizer-state HBM for
    # the 141B-param mixtral, the tightest (model x 256-chip) combination.
    opt_state_dtype: str = "float32"
    # gradient-accumulation microbatches for train shapes (perf iteration A1):
    # chosen per arch so the train_4k temp fits v5e HBM (16 GiB/chip) with
    # margin; see EXPERIMENTS.md §Perf for the per-arch measurements.
    microbatches: int = 1

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def q_features(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_features(self) -> int:
        return self.n_kv_heads * self.head_dim

    def param_count(self) -> int:
        """Total parameters N (for 6·N·D model-flops accounting)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        if self.family in ("ssm", "hybrid"):
            return _ssm_param_count(self)
        attn = d * self.q_features + 2 * d * self.kv_features + self.q_features * d
        if self.mla is not None:
            m = self.mla
            attn = (
                d * m.q_lora_rank
                + m.q_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                + m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
                + self.n_heads * m.v_head_dim * d
            )
        if self.moe is not None:
            mlp = self.moe.num_experts * 3 * d * f + d * self.moe.num_experts
        elif self.mlp_act == "swiglu":
            mlp = 3 * d * f
        else:
            mlp = 2 * d * f
        per_layer = attn + mlp + 2 * d
        total = self.n_layers * per_layer + v * d + d * v + d
        if self.family == "encdec":
            total += self.encoder_layers * (attn + mlp + 2 * d)
            total += self.n_layers * (attn + d)  # decoder cross-attn
        if self.family == "vlm" and self.cross_attn_every:
            total += (self.n_layers // self.cross_attn_every) * (attn + 2 * d)
        return total

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: top-k experts only)."""
        if self.moe is None:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        dense = self.param_count() - self.n_layers * self.moe.num_experts * 3 * d * f
        return dense + self.n_layers * self.moe.top_k * 3 * d * f


def _ssm_param_count(cfg: ModelConfig) -> int:
    d = cfg.d_model
    s = cfg.ssm
    d_in = s.expand * d
    if s.kind == "mlstm":
        dh = d_in // cfg.n_heads
        per_layer = d * 2 * d_in + 3 * cfg.n_heads * dh * dh + 3 * d_in + d_in * d + 2 * d
    else:  # mamba2
        nheads = d_in // s.head_dim
        per_layer = (
            d * (2 * d_in + 2 * s.state_dim + nheads)
            + s.conv_dim * (d_in + 2 * s.state_dim)
            + d_in * d
            + 2 * d
            + 2 * nheads
        )
    total = cfg.n_layers * per_layer + 2 * cfg.vocab * d + d
    if cfg.family == "hybrid" and cfg.attn_every:
        attn = d * cfg.q_features + 2 * d * cfg.kv_features + cfg.q_features * d
        total += attn + d  # one shared attention block (zamba2 trick)
    return total


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)

# long_500k needs sub-quadratic attention: run only where the arch provides it
# (SSM state, hybrid, or sliding-window); skips recorded in DESIGN.md §6.
LONG_CONTEXT_ARCHS = ("xlstm-350m", "zamba2-7b", "mixtral-8x22b")


def shapes_for_arch(arch_name: str):
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if arch_name in LONG_CONTEXT_ARCHS:
        out.append(LONG_500K)
    return tuple(out)
