"""Architecture registry: --arch <id> resolves here.

Each assigned architecture has its exact published config; reduced variants
(for CPU smoke tests) are derived systematically by `reduce_config`.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig, ModelConfig, MoEConfig, ShapeConfig, SSMConfig

_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> list[str]:
    return sorted(_REGISTRY)


# ---------------------------------------------------------------------------
# The 10 assigned architectures (exact configs from the assignment table).
# ---------------------------------------------------------------------------

WHISPER_LARGE_V3 = register(ModelConfig(
    name="whisper-large-v3", family="encdec",
    microbatches=4,
    n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20, d_ff=5120,
    vocab=51866, mlp_act="gelu", norm_impl="gn_ln",
    encoder_layers=32, encoder_seq=1500,
))

DEEPSEEK_CODER_33B = register(ModelConfig(
    name="deepseek-coder-33b", family="dense",
    microbatches=16,
    n_layers=62, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=19200,
    vocab=32256, norm_impl="gn_rms", rope_theta=100000.0,
))

INTERNLM2_1_8B = register(ModelConfig(
    name="internlm2-1.8b", family="dense",
    microbatches=2,
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8, d_ff=8192,
    vocab=92544, norm_impl="gn_rms", rope_theta=1000000.0,
))

MINICPM3_4B = register(ModelConfig(
    name="minicpm3-4b", family="dense",
    microbatches=8,
    n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40, d_ff=6400,
    vocab=73448, norm_impl="gn_rms",
    mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256,
                  qk_nope_head_dim=64, qk_rope_head_dim=32, v_head_dim=64),
    head_dim=96,  # qk_nope + qk_rope
))

STABLELM_1_6B = register(ModelConfig(
    name="stablelm-1.6b", family="dense",
    microbatches=2,
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=5632,
    vocab=100352, norm_impl="gn_ln",
))

LLAMA4_SCOUT = register(ModelConfig(
    name="llama4-scout-17b-a16e", family="moe",
    microbatches=8,
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=8192,
    vocab=202048, norm_impl="gn_rms", rope_theta=500000.0,
    moe=MoEConfig(num_experts=16, top_k=1),
))

MIXTRAL_8X22B = register(ModelConfig(
    name="mixtral-8x22b", family="moe",
    microbatches=16, opt_state_dtype="bfloat16",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=16384,
    vocab=32768, norm_impl="gn_rms", sliding_window=4096,
    moe=MoEConfig(num_experts=8, top_k=2),
))

XLSTM_350M = register(ModelConfig(
    name="xlstm-350m", family="ssm",
    microbatches=8,
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab=50304, norm_impl="gn_ln",
    ssm=SSMConfig(kind="mlstm", expand=2, conv_dim=4),
    head_dim=256,
))

ZAMBA2_7B = register(ModelConfig(
    name="zamba2-7b", family="hybrid",
    microbatches=16,
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, d_ff=14336,
    vocab=32000, norm_impl="gn_rms",
    ssm=SSMConfig(kind="mamba2", state_dim=64, head_dim=64, expand=2, conv_dim=4),
    attn_every=9,  # 81 = 9 groups x 9 layers; shared-attn block per group
))

LLAMA32_VISION_11B = register(ModelConfig(
    name="llama-3.2-vision-11b", family="vlm",
    microbatches=16,
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab=128256, norm_impl="gn_rms", rope_theta=500000.0,
    cross_attn_every=5, num_patches=1601,
))

# The paper's own evaluation backbones (reduced variants used by benchmarks).
GPT_NEO_1_3B = register(ModelConfig(
    name="gpt-neo-1.3b", family="dense",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=8192,
    vocab=50257, norm_impl="gn_ln", mlp_act="gelu",
))

BERT_BASE = register(ModelConfig(
    name="bert-base", family="dense",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_ff=3072,
    vocab=30522, norm_impl="gn_ln", mlp_act="gelu",
))

ASSIGNED_ARCHS = (
    "whisper-large-v3", "deepseek-coder-33b", "internlm2-1.8b", "minicpm3-4b",
    "stablelm-1.6b", "llama4-scout-17b-a16e", "mixtral-8x22b", "xlstm-350m",
    "zamba2-7b", "llama-3.2-vision-11b",
)


# ---------------------------------------------------------------------------
def reduce_config(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Systematically shrink a config for CPU smoke tests (same family/code)."""
    small: dict = dict(
        n_layers=max(2, (cfg.attn_every or 2) if cfg.family == "hybrid" else 2),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads < cfg.n_heads else 4,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab=256,
        head_dim=16,
        encoder_layers=2 if cfg.encoder_layers else 0,
        encoder_seq=16 if cfg.family == "encdec" else cfg.encoder_seq,
        num_patches=8 if cfg.family == "vlm" else cfg.num_patches,
        attn_every=2 if cfg.family == "hybrid" else cfg.attn_every,
        cross_attn_every=2 if cfg.family == "vlm" else cfg.cross_attn_every,
        sliding_window=8 if cfg.sliding_window else 0,
        remat="none",
        microbatches=1,
    )
    if cfg.family == "hybrid":
        small["n_layers"] = 4  # 2 groups x 2
    if cfg.family == "vlm":
        small["n_layers"] = 4
    if cfg.moe is not None:
        small["moe"] = MoEConfig(num_experts=4, top_k=cfg.moe.top_k, group_size=64)
    if cfg.mla is not None:
        small["mla"] = MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                                 qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16)
        small["head_dim"] = 24
    if cfg.ssm is not None:
        small["ssm"] = SSMConfig(kind=cfg.ssm.kind, state_dim=8,
                                 head_dim=16, expand=2, conv_dim=4)
        if cfg.ssm.kind == "mlstm":
            small["n_heads"] = 2
            small["head_dim"] = 64
    small.update(overrides)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **small)


# ---------------------------------------------------------------------------
def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of the given shape.

    train/prefill: token batch (+ modality stubs).  decode: one new token +
    the KV/state cache of seq_len + position scalar.
    """
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind in ("train", "prefill"):
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        if cfg.family == "encdec":
            specs["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.dtype)
            )
        if cfg.family == "vlm":
            specs["patches"] = jax.ShapeDtypeStruct(
                (b, cfg.num_patches, cfg.d_model), jnp.dtype(cfg.dtype)
            )
        return specs
    # decode: token + cache(seq_len) + pos
    from repro.models.transformer import make_model

    model = make_model(cfg)
    return {
        "token": jax.ShapeDtypeStruct((b, 1), i32),
        "cache": model.cache_specs(b, s),
        "pos": jax.ShapeDtypeStruct((), i32),
    }


def input_logical_axes(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Logical sharding axes parallel to input_specs(cfg, shape)."""
    if shape.kind in ("train", "prefill"):
        axes = {"tokens": ("batch", "seq")}
        if cfg.family == "encdec":
            axes["frames"] = ("batch", None, None)
        if cfg.family == "vlm":
            axes["patches"] = ("batch", None, None)
        return axes
    from repro.models.transformer import make_model

    return {
        "token": ("batch", None),
        "cache": make_model(cfg).cache_logical_axes(),
        "pos": (),
    }
