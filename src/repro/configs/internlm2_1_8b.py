"""--arch internlm2-1.8b (see registry.py for the exact published config)."""
from repro.configs.registry import INTERNLM2_1_8B as CONFIG

__all__ = ["CONFIG"]
