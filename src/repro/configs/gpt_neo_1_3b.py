"""--arch gpt-neo-1.3b (see registry.py for the exact published config)."""
from repro.configs.registry import GPT_NEO_1_3B as CONFIG

__all__ = ["CONFIG"]
