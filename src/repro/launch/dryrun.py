import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first lines, before any jax import: the dry-run (and ONLY the
# dry-run) builds the 512-chip production mesh out of host platform devices.

"""Multi-pod dry-run: AOT lower + compile every (arch x shape x mesh) cell.

For each cell this proves, without hardware:
  * the sharding config is coherent (SPMD partitioner accepts it),
  * the per-device program fits (memory_analysis),
  * and extracts the roofline terms (cost_analysis + HLO collective bytes).

Results are written incrementally to experiments/dryrun/<cell>.json so the
run is resumable; benchmarks/roofline_table.py renders EXPERIMENTS.md tables
from them.

Usage:
  python -m repro.launch.dryrun --arch mixtral-8x22b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod both|single|multi]
"""
import argparse
import functools
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs.base import ShapeConfig, shapes_for_arch
from repro.configs.registry import (
    ASSIGNED_ARCHS,
    get_config,
    input_logical_axes,
    input_specs,
)
from repro.launch.mesh import make_production_mesh
from repro.models.transformer import make_model
from repro.parallel.sharding import SP_OVERRIDES, current_ctx, use_sharding
from repro.roofline.analysis import analyze, model_flops_for
from repro.train.loop import make_train_step
from repro.train.optimizer import OptimizerConfig


def _sds_with_sharding(struct_tree, axes_tree):
    """Attach NamedShardings (from logical axes) to ShapeDtypeStructs."""
    ctx = current_ctx()

    def one(s, ax):
        return jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=ctx.sharding_for_shape(s.shape, tuple(ax)))

    return jax.tree.map(
        one, struct_tree, axes_tree,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )


def _param_structs_sharded(model):
    from repro.models.layers import ParamSpec
    from repro.parallel.sharding import current_ctx

    ctx = current_ctx()
    specs = model.param_specs()
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape,
            jax.numpy.dtype(s.dtype),
            sharding=ctx.sharding_for_shape(s.shape, s.logical_axes),
        ),
        specs,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def _opt_structs(params_sds, state_dtype="float32"):
    """Optimizer-state structs mirroring the param shardings."""
    import jax.numpy as jnp

    sdt = jnp.dtype(state_dtype)
    mk = lambda s: jax.ShapeDtypeStruct(s.shape, sdt, sharding=s.sharding)
    return {
        "m": jax.tree.map(mk, params_sds),
        "v": jax.tree.map(mk, params_sds),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def lower_cell(arch: str, shape: ShapeConfig, multi_pod: bool):
    """Returns (lowered, n_chips, model_flops)."""
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    overrides = SP_OVERRIDES if shape.name == "long_500k" else None

    with use_sharding(mesh, overrides):
        model = make_model(cfg)
        params_sds = _param_structs_sharded(model)
        in_sds = _sds_with_sharding(
            input_specs(cfg, shape), input_logical_axes(cfg, shape)
        )

        with mesh:
            if shape.kind == "train":
                opt_cfg = OptimizerConfig(state_dtype=cfg.opt_state_dtype)
                step = make_train_step(model, opt_cfg, microbatches=cfg.microbatches)
                opt_sds = _opt_structs(params_sds, cfg.opt_state_dtype)
                lowered = jax.jit(step, donate_argnums=(0, 1)).lower(
                    params_sds, opt_sds, in_sds
                )
            elif shape.kind == "prefill":
                fn = functools.partial(model.prefill, max_seq=shape.seq_len)
                lowered = jax.jit(fn).lower(params_sds, in_sds)
            else:  # decode
                lowered = jax.jit(model.decode_step, donate_argnums=(1,)).lower(
                    params_sds, in_sds["cache"], in_sds["token"], in_sds["pos"]
                )
    return lowered, n_chips, model_flops_for(cfg, shape)


def run_cell(arch: str, shape: ShapeConfig, multi_pod: bool, outdir: Path) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell = f"{arch}__{shape.name}__{mesh_name}"
    out_path = outdir / f"{cell}.json"
    t0 = time.time()
    rec: dict = {"arch": arch, "shape": shape.name, "mesh": mesh_name, "ok": False}
    try:
        lowered, n_chips, mflops = lower_cell(arch, shape, multi_pod)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        mem = compiled.memory_analysis()
        hlo_text = compiled.as_text()
        roof = analyze(compiled, n_chips, mflops, hlo_text=hlo_text)
        from repro.roofline.hlo_cost import collective_bytes as coll_bytes_scaled

        coll = coll_bytes_scaled(hlo_text)
        # XLA's own (loop-body-counted-once) numbers, kept for reference
        xla_cost = compiled.cost_analysis()
        if isinstance(xla_cost, list):
            xla_cost = xla_cost[0]
        rec.update(
            ok=True,
            lower_s=round(t1 - t0, 2),
            compile_s=round(t2 - t1, 2),
            memory={
                "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
                "output_bytes": getattr(mem, "output_size_in_bytes", 0),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
                "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", 0),
            },
            collective_bytes=coll,
            roofline=roof.to_dict(),
            xla_cost_naive={
                "flops": float(xla_cost.get("flops", 0.0)),
                "bytes_accessed": float(xla_cost.get("bytes accessed", 0.0)),
            },
        )
        print(
            f"[ok] {cell}: compile {t2-t1:.1f}s  "
            f"temp={rec['memory']['temp_bytes']/2**30:.2f}GiB  "
            f"bottleneck={roof.bottleneck}  "
            f"terms(c/m/coll)={roof.compute_s:.4f}/{roof.memory_s:.4f}/{roof.collective_s:.4f}s",
            flush=True,
        )
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"[FAIL] {cell}: {rec['error'][:300]}", flush=True)
    outdir.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(rec, indent=2, default=float))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=("single", "multi", "both"), default="both")
    ap.add_argument("--outdir", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    outdir = Path(args.outdir)
    archs = ASSIGNED_ARCHS if (args.all or not args.arch) else (args.arch,)
    meshes = {"single": (False,), "multi": (True,), "both": (False, True)}[
        args.multi_pod
    ]

    n_ok = n_fail = 0
    for arch in archs:
        for shape in shapes_for_arch(arch):
            if args.shape and shape.name != args.shape:
                continue
            for mp in meshes:
                mesh_name = "pod2x16x16" if mp else "pod16x16"
                if (
                    args.skip_existing
                    and (outdir / f"{arch}__{shape.name}__{mesh_name}.json").exists()
                ):
                    prev = json.loads(
                        (outdir / f"{arch}__{shape.name}__{mesh_name}.json").read_text()
                    )
                    if prev.get("ok"):
                        n_ok += 1
                        continue
                rec = run_cell(arch, shape, mp, outdir)
                n_ok += rec["ok"]
                n_fail += not rec["ok"]
    print(f"done: {n_ok} ok, {n_fail} failed")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
