"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  Single pod: (data=16, model=16) = 256 chips of a
v5e pod.  Multi-pod: a leading 'pod' axis, (2, 16, 16) = 512 chips; the pod
axis carries pure data parallelism + gradient all-reduce and is the axis that
scales to 1000+ nodes (the per-pod mesh never changes).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CI-scale integration tests (host platform devices)."""
    return jax.make_mesh(shape, axes)
