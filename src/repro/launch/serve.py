"""Batched serving driver: prefill + decode with the GN non-GEMM datapath.

The serving analogue of launch/train.py — loads (or initializes) weights,
then serves deterministic synthetic request batches through the
prefill/decode engine, reporting per-batch latency and score-oriented
integrity (mean log-prob of the generated continuations under the model,
which is exactly the quantity guaranteed normalization protects).

Usage (CPU smoke scale):
  python -m repro.launch.serve --arch internlm2-1.8b --smoke --batches 3
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.checkpoint import store
from repro.configs.registry import get_config, list_archs, reduce_config
from repro.data.synthetic import DataConfig, batch_at
from repro.models.transformer import make_model
from repro.serve.engine import ServeConfig, generate, perplexity


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b", choices=list_archs())
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--batches", type=int, default=3)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--ckpt", default=None, help="checkpoint dir to restore")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduce_config(cfg)
    model = make_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if args.ckpt:
        step = store.latest_step(args.ckpt)
        (params,), _ = store.restore(args.ckpt, step, (params,))
        print(f"restored checkpoint step {step} from {args.ckpt}")

    data = DataConfig(vocab=cfg.vocab, seq_len=args.prompt_len,
                      global_batch=args.batch_size, seed=11)
    scfg = ServeConfig(max_new_tokens=args.new_tokens, temperature=args.temperature)

    total_tok = 0.0
    t_all = time.time()
    for i in range(args.batches):
        req = batch_at(data, i)
        if cfg.family == "encdec":
            req["frames"] = jnp.zeros((args.batch_size, cfg.encoder_seq, cfg.d_model))
        if cfg.family == "vlm":
            req["patches"] = jnp.zeros((args.batch_size, cfg.num_patches, cfg.d_model))
        t0 = time.time()
        out = generate(model, params, req, scfg)
        dt = time.time() - t0
        new_tok = args.batch_size * args.new_tokens
        total_tok += new_tok
        ppl = perplexity(model, params, {**req, "tokens": out})
        print(f"batch {i}: {out.shape} in {dt:.2f}s "
              f"({new_tok/dt:.1f} tok/s)  seq ppl {ppl:.3f}")
    dt_all = time.time() - t_all
    print(f"served {args.batches} batches, {total_tok/dt_all:.1f} tok/s overall "
          f"(softmax={cfg.softmax_impl}, norm={cfg.norm_impl})")


if __name__ == "__main__":
    main()
