"""Serving driver: static batches or continuous batching, GN datapath.

The serving analogue of launch/train.py — loads (or initializes) weights,
then serves synthetic request workloads, reporting latency/throughput and
score-oriented integrity (mean log-prob of the generated continuations,
which is exactly the quantity guaranteed normalization protects).

Two modes:
  * static (default): the seed engine — uniform-length prompt batches,
    everyone decodes to --new-tokens.  Kept as the correctness oracle.
  * --continuous: FCFS continuous batching over a slot-paged KV pool with
    chunked prefill fused into a single jitted per-tick step — prompts are
    bucketed to the chunk grid and stream through idle lanes while other
    slots decode (see serve/engine.ContinuousEngine).  Greedy outputs are
    verified token-identical to the static path.

Usage (CPU smoke scale):
  python -m repro.launch.serve --arch internlm2-1.8b --smoke --batches 3
  python -m repro.launch.serve --smoke --continuous
  python -m repro.launch.serve --smoke --continuous --devices 2

``--continuous --devices N`` shards the slot pool over an N-device mesh
(slot-axis NamedSharding, least-loaded admission — docs/serving.md §Device
mesh).  Under ``--smoke`` (CPU) the launcher forces N host-platform devices
itself; on real hardware export the matching XLA/topology env first.
"""
from __future__ import annotations

import argparse
import sys as _sys
import time

from repro.launch._host_devices import force_host_devices

# --smoke --devices N on CPU: force N host-platform devices BEFORE jax
# initializes (XLA reads the flag once at backend creation).  Only fires
# for the smoke path; an explicit operator XLA_FLAGS always wins.
if "--smoke" in _sys.argv:
    force_host_devices()

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import store
from repro.configs.registry import get_config, list_archs, reduce_config
from repro.data.synthetic import DataConfig, batch_at
from repro.models.transformer import make_model
from repro.serve.engine import (
    ContinuousEngine,
    ServeConfig,
    generate,
    perplexity,
    round_slots_to_devices,
    static_reference,
)
from repro.serve.workload import required_max_seq, staggered_requests


def _serve_static(model, cfg, params, args, scfg):
    data = DataConfig(vocab=cfg.vocab, seq_len=args.prompt_len,
                      global_batch=args.batch_size, seed=11)
    total_tok = 0.0
    t_all = time.time()
    for i in range(args.batches):
        req = batch_at(data, i)
        if cfg.family == "encdec":
            req["frames"] = jnp.zeros((args.batch_size, cfg.encoder_seq, cfg.d_model))
        if cfg.family == "vlm":
            req["patches"] = jnp.zeros((args.batch_size, cfg.num_patches, cfg.d_model))
        t0 = time.time()
        out = generate(model, params, req, scfg)
        dt = time.time() - t0
        new_tok = args.batch_size * args.new_tokens
        total_tok += new_tok
        ppl = perplexity(model, params, {**req, "tokens": out})
        print(f"batch {i}: {out.shape} in {dt:.2f}s "
              f"({new_tok/dt:.1f} tok/s)  seq ppl {ppl:.3f}")
    dt_all = time.time() - t_all
    print(f"served {args.batches} batches, {total_tok/dt_all:.1f} tok/s overall "
          f"(softmax={cfg.softmax_impl}, norm={cfg.norm_impl})")


def _serve_continuous(model, cfg, params, args, scfg):
    reqs = staggered_requests(
        cfg, n_requests=args.requests, base_len=args.prompt_len,
        max_new_tokens=args.new_tokens, stagger=args.stagger, seed=11,
    )
    max_seq = required_max_seq(reqs)
    num_slots = round_slots_to_devices(args.num_slots, args.devices)
    engine = ContinuousEngine(model, params, num_slots=num_slots,
                              max_seq=max_seq, cfg=scfg, chunk=args.chunk,
                              devices=args.devices)
    t0 = time.time()
    comps = engine.run(reqs)
    dt = time.time() - t0
    m = engine.metrics()
    gen_tok = m["generated_tokens"]
    print(f"continuous: {len(comps)} requests, {gen_tok} tokens in {dt:.2f}s "
          f"({gen_tok/dt:.1f} tok/s)  slots={num_slots} "
          f"util={m['mean_slot_utilization']:.2f}")
    if m["num_devices"] > 1:
        print(f"slot pool sharded over {m['num_devices']} devices "
              f"({m['per_device_slots']} slots each): admissions/device "
              f"{m['device_admits']}, balance {m['shard_balance']:.2f}")
    print(f"fused step compiled {m['fused_step_compilations']}x, decode "
          f"{m['decode_compilations']}x, per-prompt-length prefill "
          f"{m['prefill_compilations']}x  (chunk={m['chunk']}, intake "
          f"padding {m['intake_padding']} tok)")
    if m["kv_paged"]:
        print(f"paged reads: {m['read_path']}; horizon buckets "
              f"{m['horizon_buckets']} of grid {m['horizon_bucket_grid']} "
              f"(mean attended {m['mean_attended_tokens_per_tick']:.1f} "
              "tok/tick)")

    # per-tick slot phase occupancy: the fusion benefit made visible —
    # prefill chunks ride lanes that would otherwise idle while decoding.
    print("tick phases (P=prefill lanes, D=decode lanes, .=idle):")
    for chunk_rows in range(0, len(engine.phase_log), 20):
        rows = engine.phase_log[chunk_rows : chunk_rows + 20]
        lanes = " ".join(
            f"{'P'*p}{'D'*d}{'.'*(engine.num_slots-p-d)}" for p, d in rows
        )
        print(f"  tick {chunk_rows:3d}+ [{lanes}]")
    pf = m["prefill_lane_fraction"]
    print(f"  {m['fused_ticks']}/{m['decode_steps']} ticks carried prefill "
          f"chunks ({pf*100:.0f}% of busy lanes were prefill)")
    for c in sorted(comps, key=lambda c: c.request_id):
        print(f"  req {c.request_id}: prompt {len(c.prompt_tokens)} "
              f"+{len(c.new_tokens)} [{c.finish_reason}]  "
              f"arrive@{c.arrival_step} admit@{c.admit_step} "
              f"finish@{c.finish_step}  latency {c.latency_s*1e3:.0f}ms")

    # Counters are explicit trace counts (always ints).  Slab engines
    # compile the fused step exactly once (decode fast path may be unused);
    # paged engines compile once per (step kind, horizon bucket actually
    # seen), bounded by the bucket grid — see docs/serving.md §Paged read
    # paths.
    if m["kv_paged"]:
        grid = m["horizon_bucket_grid"]
        assert m["fused_step_compilations"] == len(m["fused_buckets"]) <= len(grid), \
            "fused step recompiled beyond the bucket bound!"
        assert m["decode_compilations"] == len(m["decode_buckets"]) <= len(grid), \
            "decode step recompiled beyond the bucket bound!"
    else:
        assert m["fused_step_compilations"] == 1, "fused step recompiled!"
        assert m["decode_compilations"] in (0, 1), "decode step recompiled!"
    assert m["prefill_compilations"] == 0, "per-prompt-length prefill is back?!"
    if scfg.temperature == 0:
        ref = static_reference(model, params, reqs, scfg)
        same = all(np.array_equal(c.tokens, ref[c.request_id]) for c in comps)
        print(f"greedy outputs token-identical to static path: {same}")
        assert same, "continuous batching diverged from the static oracle"


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b", choices=list_archs())
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--batches", type=int, default=3)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--ckpt", default=None, help="checkpoint dir to restore")
    ap.add_argument("--continuous", action="store_true",
                    help="continuous batching (staggered-arrival workload)")
    ap.add_argument("--requests", type=int, default=8,
                    help="continuous: number of requests in the workload")
    ap.add_argument("--num-slots", type=int, default=4,
                    help="continuous: KV pool capacity (concurrent sequences)")
    ap.add_argument("--stagger", type=int, default=2,
                    help="continuous: arrival gap between requests (steps)")
    ap.add_argument("--chunk", type=int, default=8,
                    help="continuous: prefill chunk size (fused-step lanes)")
    ap.add_argument("--devices", type=int, default=1,
                    help="continuous: shard the slot pool over N devices "
                         "(--smoke forces N host-platform devices itself)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduce_config(cfg)
    model = make_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if args.ckpt:
        step = store.latest_step(args.ckpt)
        (params,), _ = store.restore(args.ckpt, step, (params,))
        print(f"restored checkpoint step {step} from {args.ckpt}")

    scfg = ServeConfig(max_new_tokens=args.new_tokens, temperature=args.temperature)
    if args.continuous:
        _serve_continuous(model, cfg, params, args, scfg)
    else:
        _serve_static(model, cfg, params, args, scfg)


if __name__ == "__main__":
    main()
