"""Pre-jax-init host-device forcing for CPU demos.

XLA reads ``--xla_force_host_platform_device_count`` exactly once, at
backend creation — so any entry point that wants ``--devices N`` to "just
work" on CPU must set the flag BEFORE its first ``import jax``.  This
module deliberately imports nothing but the stdlib so it is safe to import
first; callers gate it themselves (the smoke launcher only fires under
``--smoke``, the example demo always — both are reduced-config CPU paths).
"""
from __future__ import annotations

import os
import sys
from typing import Optional


def devices_from_argv(argv: Optional[list] = None) -> Optional[int]:
    """The value of ``--devices N`` / ``--devices=N`` in ``argv`` (default
    ``sys.argv``), or None when absent/malformed — argparse will report the
    malformed case properly later."""
    argv = sys.argv if argv is None else argv
    for i, arg in enumerate(argv):
        if arg == "--devices" and i + 1 < len(argv):
            val = argv[i + 1]
        elif arg.startswith("--devices="):
            val = arg.split("=", 1)[1]
        else:
            continue
        try:
            return int(val)
        except ValueError:
            return None
    return None


def force_host_devices(argv: Optional[list] = None) -> None:
    """Set ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` for the
    ``--devices N`` found in ``argv``, unless the operator already set the
    flag (an explicit setting always wins).  No-op for N <= 1 or no flag."""
    n = devices_from_argv(argv)
    if n is None or n <= 1:
        return
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={n}".strip()
        )
