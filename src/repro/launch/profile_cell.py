import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Dry-run 'profiler': loop-scaled per-instruction flops/bytes attribution.

This is the hillclimb tool: it shows which model ops own the dominant
roofline term of a compiled (arch x shape x mesh) cell.

  python -m repro.launch.profile_cell --arch mixtral-8x22b --shape train_4k
"""
import argparse

from repro.configs.base import shapes_for_arch
from repro.launch.dryrun import lower_cell
from repro.roofline.analysis import HBM_BW, PEAK_FLOPS
from repro.roofline.hlo_cost import cost_breakdown, module_cost


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top", type=int, default=20)
    args = ap.parse_args()

    shape = next(s for s in shapes_for_arch(args.arch) if s.name == args.shape)
    lowered, n_chips, mflops = lower_cell(args.arch, shape, args.multi_pod)
    compiled = lowered.compile()
    text = compiled.as_text()
    cost = module_cost(text)
    print(f"== {args.arch} x {args.shape} ({n_chips} chips) ==")
    print(f"flops/device: {cost.flops:.3e}  ({cost.flops/PEAK_FLOPS:.3f}s)")
    print(f"bytes/device: {cost.bytes:.3e}  ({cost.bytes/HBM_BW:.3f}s)")
    print(f"collective:   {cost.collective_total:.3e} B")
    bd = cost_breakdown(text, top_k=args.top)
    print(f"\n-- top {args.top} by bytes --")
    for desc, b in bd["by_bytes"]:
        print(f"  {b:14.3e}  {desc[:140]}")
    print(f"\n-- top {args.top} by flops --")
    for desc, f in bd["by_flops"]:
        print(f"  {f:14.3e}  {desc[:140]}")
    mem = compiled.memory_analysis()
    print(
        f"\nmemory_analysis: args={mem.argument_size_in_bytes/2**30:.2f}GiB "
        f"out={mem.output_size_in_bytes/2**30:.2f}GiB "
        f"temp={mem.temp_size_in_bytes/2**30:.2f}GiB"
    )


if __name__ == "__main__":
    main()
