"""Fault-tolerant training driver.

Features exercised by tests/test_fault_tolerance.py:
  * checkpoint every K steps (atomic), auto-resume from the latest valid one;
  * deterministic, step-keyed data (restart reproduces the uninterrupted run
    bit-for-bit);
  * failure injection: ``--fail-at N`` (or REPRO_FAIL_AT_STEP) hard-kills the
    process mid-run to simulate a node failure;
  * straggler watchdog: per-step wall time against a running median — slow
    steps are logged with a restart hint (on real multi-pod deployments this
    feeds the controller that evicts the slow host);
  * optional mesh (``--mesh dxm``) with FSDP+TP sharding rules, optional int8
    error-feedback gradient compression for the cross-pod axis.

Usage (CPU-scale):
  python -m repro.launch.train --arch internlm2-1.8b --smoke --steps 20
"""
from __future__ import annotations

import argparse
import json
import os
import statistics
import time
from pathlib import Path

import jax

from repro.checkpoint import store
from repro.configs.registry import get_config, reduce_config
from repro.data.synthetic import DataConfig, batch_at
from repro.models.transformer import make_model
from repro.parallel.sharding import use_sharding
from repro.train.loop import make_train_step
from repro.train.optimizer import OptimizerConfig, init_opt_state


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--checkpoint-every", type=int, default=5)
    ap.add_argument("--outdir", default="runs/default")
    ap.add_argument("--fail-at", type=int, default=int(os.environ.get("REPRO_FAIL_AT_STEP", -1)))
    ap.add_argument("--mesh", default=None, help="e.g. 2x2 (data x model)")
    ap.add_argument("--grad-compression", type=int, default=None)
    ap.add_argument("--straggler-factor", type=float, default=3.0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduce_config(cfg)
    model = make_model(cfg)
    data_cfg = DataConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch, seed=1234
    )
    opt_cfg = OptimizerConfig(
        lr=args.lr, warmup_steps=5, total_steps=args.steps,
        grad_compression=args.grad_compression,
    )

    mesh_ctx = None
    if args.mesh:
        d, m = (int(x) for x in args.mesh.split("x"))
        mesh = jax.make_mesh((d, m), ("data", "model"))
        mesh_ctx = use_sharding(mesh)

    outdir = Path(args.outdir)
    ckpt_dir = outdir / "ckpt"
    outdir.mkdir(parents=True, exist_ok=True)
    log_path = outdir / "train_log.jsonl"

    def run():
        params = model.init(jax.random.PRNGKey(0))
        opt_state = init_opt_state(opt_cfg, params)
        start = 0
        latest = store.latest_step(ckpt_dir)
        if latest is not None:
            (params, opt_state), man = store.restore(
                ckpt_dir, latest, (params, opt_state)
            )
            start = man["step"]
            print(f"[resume] from checkpoint step {start}", flush=True)

        train_step = jax.jit(make_train_step(model, opt_cfg), donate_argnums=(0, 1))
        times: list[float] = []
        log = open(log_path, "a")
        for step in range(start, args.steps):
            t0 = time.time()
            batch = batch_at(data_cfg, step)
            params, opt_state, metrics = train_step(params, opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            times.append(dt)
            med = statistics.median(times[-20:])
            straggler = len(times) > 3 and dt > args.straggler_factor * med
            rec = {"step": step + 1, "loss": loss, "sec": round(dt, 4),
                   "straggler": bool(straggler)}
            log.write(json.dumps(rec) + "\n")
            log.flush()
            print(f"step {step+1:5d} loss {loss:.4f} {dt*1e3:7.1f}ms"
                  + ("  [STRAGGLER]" if straggler else ""), flush=True)
            if (step + 1) % args.checkpoint_every == 0 or step + 1 == args.steps:
                store.save(ckpt_dir, step + 1, (params, opt_state),
                           extra={"arch": cfg.name})
            if args.fail_at == step + 1:
                print(f"[failure-injection] dying at step {step+1}", flush=True)
                os._exit(42)  # hard kill: no cleanup, like a real node loss
        log.close()
        final = float(metrics["loss"])
        print(f"[done] final loss {final:.4f}")
        return final

    if mesh_ctx is not None:
        with mesh_ctx:
            return run()
    return run()


if __name__ == "__main__":
    main()
