"""Sequence-state models: mLSTM (xLSTM) and Mamba2 (SSD), scan-based.

Both are written in their *recurrent* (state-passing) form with
``jax.lax.scan`` over time — O(1) state per token, which is what makes the
``long_500k`` decode shape tractable.  The paper's technique applies to these
blocks through their norms (CoRN rsqrt) — their mixers are softmax-free, as
recorded in DESIGN.md §6.

Decode paths carry (conv window, state) caches and cost O(1) per token.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import gn_rmsnorm
from repro.models.layers import ParamSpec


# =============================================================== mLSTM ======
def mlstm_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    s = cfg.ssm
    d_in = s.expand * d
    return {
        "w_up": ParamSpec((d, 2 * d_in), ("embed_fsdp", "ff")),
        "conv_w": ParamSpec((s.conv_dim, d_in), (None, "ff")),
        # block-diagonal per-head projections (xLSTM paper) — 4x fewer params
        "wq": ParamSpec((cfg.n_heads, d // cfg.n_heads * s.expand, d // cfg.n_heads * s.expand), (None, "heads_tp", None)),
        "wk": ParamSpec((cfg.n_heads, d // cfg.n_heads * s.expand, d // cfg.n_heads * s.expand), (None, "heads_tp", None)),
        "wv": ParamSpec((cfg.n_heads, d // cfg.n_heads * s.expand, d // cfg.n_heads * s.expand), (None, "heads_tp", None)),
        "w_gate": ParamSpec((d_in, 2 * cfg.n_heads), ("ff", None)),
        "b_gate": ParamSpec((2 * cfg.n_heads,), (None,), init="zeros"),
        "w_down": ParamSpec((d_in, d), ("ff", "embed_fsdp")),
    }


# On *exact* (serving) calls, below this length the K-tap shift-add form is
# used instead of the fused grouped conv.  The two differ in accumulation
# order (the conv accumulates in f32, the shift-add chain rounds per tap in
# the activation dtype), so every serving path — monolithic prefill, chunked
# prefill, single-token decode — must land on the same side of the threshold
# to keep greedy continuous batching bit-identical to the static oracle.
# Serve prompts and chunks sit well below 256; training and long-prefill
# shapes keep the fused conv and its memory win (perf iteration C2).
_CONV_FUSED_MIN = 256


def _causal_conv(x: jax.Array, w: jax.Array, state=None, n_valid=None,
                 exact=False):
    """Depthwise causal conv along time.  x: (B,S,C), w: (K,C).

    With ``state`` (B,K-1,C) provided, uses it as left context (decode);
    returns (out, new_state).  With ``n_valid`` (traced scalar), only the
    first n_valid time steps are real: the returned state is the K-1 inputs
    ending at step n_valid-1, so a partially-valid chunk hands the next
    chunk exactly the context a contiguous pass would have.

    Long sequences use one grouped ``lax.conv_general_dilated`` — perf
    iteration C2 (§Perf): the unrolled K-tap shift-add materializes ~2K
    (B,S,C) tensors per pass; the fused conv touches x and the output once.
    ``exact`` (serving paths: decode/chunk via their carries, monolithic
    prefill via the block kwarg) raises the fused-conv floor to
    _CONV_FUSED_MIN so every serve-sized call uses the shift-add form,
    which is per-position bit-identical across S.  Training keeps the
    plain S >= K rule.
    """
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (B, S+K-1, C)
    if x.shape[1] >= (max(k, _CONV_FUSED_MIN) if exact else k):
        c = x.shape[2]
        out = jax.lax.conv_general_dilated(
            xp,
            w[:, None, :].astype(x.dtype),  # (K, 1, C) = (W, I/group, O)
            window_strides=(1,),
            padding="VALID",
            dimension_numbers=("NWC", "WIO", "NWC"),
            feature_group_count=c,
        )
    else:
        out = jnp.zeros_like(x)
        for i in range(k):
            out = out + xp[:, i : i + x.shape[1]] * w[i][None, None, :]
    if k <= 1:
        new_state = jnp.zeros((x.shape[0], 0, x.shape[2]), x.dtype)
    elif n_valid is None:
        new_state = xp[:, -(k - 1) :]
    else:
        # last K-1 inputs of the *valid* prefix: xp[:, n_valid : n_valid+K-1]
        new_state = jax.lax.dynamic_slice(
            xp, (0, n_valid, 0), (xp.shape[0], k - 1, xp.shape[2])
        )
    return out, new_state


def _mlstm_heads(cfg, q, k, v, i_raw, f_raw, carry):
    """One time-step of the mLSTM cell (stabilized exponential gating).

    q/k/v: (B,H,dh); i_raw/f_raw: (B,H); carry = (C, n, m).
    """
    C, n, m = carry
    dh = q.shape[-1]
    m_new = jnp.maximum(f_raw + m, i_raw)
    i_g = jnp.exp(i_raw - m_new)
    f_g = jnp.exp(f_raw + m - m_new)
    C = f_g[..., None, None] * C + i_g[..., None, None] * (
        v[..., :, None] * k[..., None, :] * (dh**-0.5)
    )  # (B,H,dh,dh)
    n = f_g[..., None] * n + i_g[..., None] * k * (dh**-0.5)
    h_num = jnp.einsum("bhij,bhj->bhi", C, q)
    h_den = jnp.maximum(jnp.abs(jnp.einsum("bhj,bhj->bh", n, q)), jnp.exp(-m_new))
    h = h_num / h_den[..., None]
    return (C, n, m_new), h


def _mlstm_chunked(q, k, v, i_raw, f_raw, carry, chunk: int):
    """Chunkwise-parallel mLSTM — perf iteration X1 (§Perf), the xLSTM
    analogue of the chunked SSD (C1).

    The recurrent form reads+writes the (B,H,dh,dh) f32 matrix memory every
    token (dh=512 for xlstm-350m -> ~1.6e16 HBM bytes/device on train_4k).
    Chunkwise, with F the within-chunk inclusive cumsum of log-forget,
    s_j = i_j - F_j and the running stabilizer M_t = max(m_in, cummax_j s_j):

        m_t   = F_t + M_t                       (identical to the recurrence)
        C~q_t = sum_{j<=t} exp(s_j - M_t)(k_j.q_t)/sqrt(dh) v_j
                + exp(m_in - M_t) C_in q_t
        n_t   = sum_{j<=t} exp(s_j - M_t) k_j/sqrt(dh) + exp(m_in - M_t) n_in
        h_t   = C~q_t / max(|n_t.q_t|, exp(-m_t))

    i.e. masked intra-chunk matmuls + one (C,n,m) state pass per chunk.
    Equivalence to the recurrence is property-tested
    (tests/test_mlstm_chunked.py), including the stabilizer path.

    q/k/v: (B,S,H,dh) f32; i_raw/f_raw: (B,S,H) f32 (f_raw = log-sigmoid).
    Returns (h (B,S,H*dh) f32 flattened later, (C,n,m)).
    """
    b, s, H, dh = q.shape
    nc, Q = s // chunk, chunk
    scale = dh**-0.5

    qc = q.reshape(b, nc, Q, H, dh)
    kc = k.reshape(b, nc, Q, H, dh) * scale
    vc = v.reshape(b, nc, Q, H, dh)
    ic = i_raw.reshape(b, nc, Q, H)
    fc = f_raw.reshape(b, nc, Q, H)

    F = jnp.cumsum(fc, axis=2)              # (b,nc,Q,H) inclusive
    s_j = ic - F                            # (b,nc,Q,H)
    s_cummax = jax.lax.cummax(s_j, axis=2)  # running max over t
    F_last = F[:, :, -1]                    # (b,nc,H)
    s_max = s_cummax[:, :, -1]

    # intra-chunk decay matrix pieces that don't depend on the carry:
    tri = jnp.tril(jnp.ones((Q, Q), bool))

    def chunk_step(state, inp):
        C_in, n_in, m_in = state
        q_c, k_c, v_c, sj_c, scm_c, Fl_c, sm_c, F_c = inp
        M = jnp.maximum(m_in[:, None], scm_c)          # (b,Q,H)
        # D[t,j] = exp(s_j - M_t) masked j<=t
        D = jnp.exp(
            jnp.where(tri[None, :, :, None], sj_c[:, None, :, :] - M[:, :, None, :], -jnp.inf)
        )  # (b,t,j,H)
        G = jnp.einsum("bthd,bjhd->bthj", q_c, k_c)    # scores (k pre-scaled)
        num = jnp.einsum("bthj,btjh,bjhd->bthd", G, D, v_c)
        n_t = jnp.einsum("btjh,bjhd->bthd", D, k_c)
        carry_w = jnp.exp(m_in[:, None] - M)           # (b,Q,H)
        num = num + carry_w[..., None] * jnp.einsum("bhij,bthj->bthi", C_in, q_c)
        n_t = n_t + carry_w[..., None] * n_in[:, None]
        m_t = F_c + M
        qn = jnp.abs(jnp.einsum("bthd,bthd->bth", n_t, q_c))
        h = num / jnp.maximum(qn, jnp.exp(-m_t))[..., None]

        # ---- state to the next chunk (stabilizer = last row's M) ----------
        M_out = jnp.maximum(m_in, sm_c)                # (b,H)
        w_j = jnp.exp(sj_c - M_out[:, None])           # (b,Q,H)
        cw = jnp.exp(m_in - M_out)                     # (b,H)
        C_out = cw[..., None, None] * C_in + jnp.einsum(
            "bjh,bjhd,bjhe->bhde", w_j, v_c, k_c
        )
        n_out = cw[..., None] * n_in + jnp.einsum("bjh,bjhd->bhd", w_j, k_c)
        m_out = Fl_c + M_out
        return (C_out, n_out, m_out), h

    xs = jax.tree.map(
        lambda a: jnp.moveaxis(a, 1, 0), (qc, kc, vc, s_j, s_cummax, F_last, s_max, F)
    )
    state, hs = jax.lax.scan(chunk_step, carry, xs)
    h = jnp.moveaxis(hs, 0, 1).reshape(b, s, H * dh)
    return h, state


def mlstm_block(cfg: ModelConfig, p: dict, x: jax.Array, carry=None, n_valid=None,
                exact=False):
    """x: (B,S,D) -> (y, carry).  carry=None initializes zero state.

    ``n_valid`` (traced scalar, chunked-prefill lanes) freezes the carry
    after the first n_valid time steps: steps >= n_valid produce don't-care
    outputs and leave (conv state, C, n, m) exactly where a contiguous pass
    over the valid prefix would.  ``exact`` marks a serving call (monolithic
    prefill) so the conv path matches decode/chunk accumulation order;
    decode/chunk calls are exact implicitly via their carry/n_valid."""
    dt = x.dtype
    s_cfg = cfg.ssm
    b, s, d = x.shape
    d_in = s_cfg.expand * d
    h_heads = cfg.n_heads
    dh = d_in // h_heads

    up = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(dt))
    u, z = up[..., :d_in], up[..., d_in:]
    if carry is None:
        conv_state = None
        C0 = jnp.zeros((b, h_heads, dh, dh), jnp.float32)
        n0 = jnp.zeros((b, h_heads, dh), jnp.float32)
        m0 = jnp.full((b, h_heads), -1e30, jnp.float32)
    else:
        conv_state, C0, n0, m0 = carry
    exact = exact or carry is not None or n_valid is not None
    uc, conv_state = _causal_conv(u, p["conv_w"].astype(dt), conv_state, n_valid, exact)
    uc = jax.nn.silu(uc)

    uch = uc.reshape(b, s, h_heads, dh)
    uh = u.reshape(b, s, h_heads, dh)
    q = jnp.einsum("bshd,hde->bshe", uch, p["wq"].astype(dt))
    k = jnp.einsum("bshd,hde->bshe", uch, p["wk"].astype(dt))
    v = jnp.einsum("bshd,hde->bshe", uh, p["wv"].astype(dt))
    gates = jnp.einsum("bsf,fg->bsg", uc, p["w_gate"].astype(dt)) + p["b_gate"].astype(dt)
    i_raw = gates[..., :h_heads].astype(jnp.float32)
    f_raw = jax.nn.log_sigmoid(gates[..., h_heads:].astype(jnp.float32))

    def step(carry, inp):
        qt, kt, vt, it, ft, t = inp
        new_carry, h = _mlstm_heads(
            cfg, qt.astype(jnp.float32), kt.astype(jnp.float32), vt.astype(jnp.float32), it, ft, carry
        )
        if n_valid is not None:  # freeze the state on don't-care lanes
            keep = t < n_valid
            new_carry = jax.tree.map(
                lambda nw, old: jnp.where(keep, nw, old), new_carry, carry
            )
        return new_carry, h

    chunk = s_cfg.chunk
    if chunk and s > chunk and s % chunk == 0 and n_valid is None:
        hs_bshd, (C, n, m) = _mlstm_chunked(
            q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
            i_raw, f_raw, (C0, n0, m0), chunk,
        )
        h = hs_bshd.reshape(b, s, d_in).astype(dt)
    else:
        xs = (
            q.transpose(1, 0, 2, 3),
            k.transpose(1, 0, 2, 3),
            v.transpose(1, 0, 2, 3),
            i_raw.transpose(1, 0, 2),
            f_raw.transpose(1, 0, 2),
            jnp.arange(s),
        )
        (C, n, m), hs = jax.lax.scan(step, (C0, n0, m0), xs)
        h = hs.transpose(1, 0, 2, 3).reshape(b, s, d_in).astype(dt)  # (B,S,d_in)
    h = gn_rmsnorm(h)  # per-block normalizer (CoRN unit)
    out = h * jax.nn.silu(z)
    y = jnp.einsum("bsf,fd->bsd", out, p["w_down"].astype(dt))
    return y, (conv_state, C, n, m)


def mlstm_cache_shape(cfg: ModelConfig, batch: int) -> tuple:
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    h = cfg.n_heads
    dh = d_in // h
    dt = jnp.dtype(cfg.dtype)
    return (
        jax.ShapeDtypeStruct((batch, s.conv_dim - 1, d_in), dt),
        jax.ShapeDtypeStruct((batch, h, dh, dh), jnp.float32),
        jax.ShapeDtypeStruct((batch, h, dh), jnp.float32),
        jax.ShapeDtypeStruct((batch, h), jnp.float32),
    )


# ============================================================== Mamba2 ======
def _ssd_recurrent(xs, B, C, dt_v, decay, h0, n_valid=None):
    """SSD in per-token recurrent form (decode / odd lengths).

    xs: (B,S,H,dh); B/C: (B,S,N); dt_v/decay: (B,S,H); h0: (B,H,dh,N).
    Returns (y (B,S,H,dh) float32, h_final).  With ``n_valid`` (traced
    scalar) the state freezes after the first n_valid steps (chunked-prefill
    don't-care lanes).
    """

    def step(h, inp):
        xt, bt, ct, dct, dtt, t = inp  # (B,H,dh) (B,N) (B,N) (B,H) (B,H) ()
        h_new = h * dct[..., None, None] + (
            dtt[..., None, None] * xt[..., None] * bt[:, None, None, :]
        )
        yt = jnp.einsum("bhdn,bn->bhd", h_new, ct)
        if n_valid is not None:
            h_new = jnp.where(t < n_valid, h_new, h)
        return h_new, yt

    s = xs.shape[1]
    seq = (
        xs.transpose(1, 0, 2, 3).astype(jnp.float32),
        B.transpose(1, 0, 2).astype(jnp.float32),
        C.transpose(1, 0, 2).astype(jnp.float32),
        decay.transpose(1, 0, 2),
        dt_v.transpose(1, 0, 2),
        jnp.arange(s),
    )
    h_final, ys = jax.lax.scan(step, h0, seq)
    return ys.transpose(1, 0, 2, 3), h_final


def _ssd_chunked(xs, B, C, dt_v, decay, h0, chunk: int):
    """SSD in the chunked (block) form — perf iteration C1 (§Perf).

    The recurrent form reads+writes the (B,H,dh,N) f32 state every token:
    ~1e16 HBM bytes/device on zamba2 train_4k.  Chunking recovers the actual
    Mamba2 SSD algorithm: within a chunk of Q tokens the output is an
    attention-like pair of MXU matmuls; the state crosses chunk boundaries
    once per chunk.  Identical math (test: tests/test_ssd_chunked.py).

      y_t = sum_{j<=t} exp(l_t - l_j) dt_j (C_t . B_j) x_j   [intra, j in chunk]
            + exp(l_t) C_t . h_in                            [inter]
      h_out = exp(l_last) h_in + sum_j exp(l_last - l_j) dt_j x_j B_j^T

    with l the inclusive cumsum of log decay within the chunk.
    """
    b, s, H, dh = xs.shape
    n = B.shape[-1]
    nc, Q = s // chunk, chunk

    xs = xs.reshape(b, nc, Q, H, dh).astype(jnp.float32)
    Bc = B.reshape(b, nc, Q, n).astype(jnp.float32)
    Cc = C.reshape(b, nc, Q, n).astype(jnp.float32)
    dt_c = dt_v.reshape(b, nc, Q, H)
    # log decay cumsum; decay = exp(dt*A) with A<0 so l is non-increasing
    llog = jnp.log(jnp.maximum(decay.reshape(b, nc, Q, H), 1e-38))
    l = jnp.cumsum(llog, axis=2)  # (b,nc,Q,H) inclusive
    l_last = l[:, :, -1]  # (b,nc,H)

    # ---- intra-chunk: M[i,j] = (C_i.B_j) exp(l_i-l_j) dt_j  for j<=i -------
    # (vectorized over chunks: measured better than building tiles inside the
    # chunk scan — the scan variant pays moveaxis copies of every input and
    # the same peak, zamba2 prefill_32k 125.7 s vs 145.0 s memory term)
    g = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)  # (b,nc,Q,Q)
    ldiff = l[:, :, :, None, :] - l[:, :, None, :, :]  # (b,nc,Q(i),Q(j),H)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    ldiff = jnp.where(mask[None, None, :, :, None], ldiff, -jnp.inf)
    w = jnp.exp(ldiff) * dt_c[:, :, None, :, :]  # (b,nc,i,j,H)
    y_intra = jnp.einsum("bcij,bcijh,bcjhd->bcihd", g, w, xs)

    # ---- inter-chunk: per-chunk state contribution + carried state ---------
    # S_c = sum_j exp(l_last - l_j) dt_j x_j B_j^T   (b,nc,H,dh,n)
    wj = jnp.exp(l_last[:, :, None] - l) * dt_c  # (b,nc,Q,H)
    s_c = jnp.einsum("bcqh,bcqhd,bcqn->bchdn", wj, xs, Bc)
    g_last = jnp.exp(l_last)  # (b,nc,H)
    c_e = Cc[:, :, :, None, :] * jnp.exp(l)[..., None]  # (b,nc,Q,H,n)

    def chunk_step(h, inp):
        ce_c, sc_c, gl_c = inp  # (b,Q,H,n) (b,H,dh,n) (b,H)
        y_inter = jnp.einsum("bhdn,bqhn->bqhd", h, ce_c)
        h = h * gl_c[..., None, None] + sc_c
        return h, y_inter

    h_final, y_inter = jax.lax.scan(
        chunk_step,
        h0,
        (
            c_e.transpose(1, 0, 2, 3, 4),
            s_c.transpose(1, 0, 2, 3, 4),
            g_last.transpose(1, 0, 2),
        ),
    )
    y = y_intra + y_inter.transpose(1, 0, 2, 3, 4)  # (b,nc,Q,H,dh)
    return y.reshape(b, s, H, dh), h_final


def mamba2_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    s = cfg.ssm
    d_in = s.expand * d
    nheads = d_in // s.head_dim
    conv_ch = d_in + 2 * s.state_dim
    return {
        "in_proj": ParamSpec(
            (d, 2 * d_in + 2 * s.state_dim + nheads), ("embed_fsdp", "ff")
        ),
        "conv_w": ParamSpec((s.conv_dim, conv_ch), (None, None)),
        "a_log": ParamSpec((nheads,), (None,), init="zeros"),
        "d_skip": ParamSpec((nheads,), (None,), init="ones"),
        "dt_bias": ParamSpec((nheads,), (None,), init="zeros"),
        "norm": ParamSpec((d_in,), (None,), init="ones"),
        "out_proj": ParamSpec((d_in, d), ("ff", "embed_fsdp")),
    }


def mamba2_block(cfg: ModelConfig, p: dict, x: jax.Array, carry=None, n_valid=None,
                 exact=False):
    """SSD in recurrent form.  x: (B,S,D) -> (y, carry).

    ``n_valid`` (traced scalar, chunked-prefill lanes) freezes (conv state,
    h) after the first n_valid time steps; ``exact`` marks a serving call —
    see ``mlstm_block``."""
    dt_ = x.dtype
    s_cfg = cfg.ssm
    b, s, d = x.shape
    d_in = s_cfg.expand * d
    nst = s_cfg.state_dim
    dh = s_cfg.head_dim
    nheads = d_in // dh

    zxbcdt = jnp.einsum("bsd,df->bsf", x, p["in_proj"].astype(dt_))
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in : 2 * d_in + 2 * nst]
    dt_raw = zxbcdt[..., 2 * d_in + 2 * nst :]  # (B,S,H)

    if carry is None:
        conv_state = None
        h0 = jnp.zeros((b, nheads, dh, nst), jnp.float32)
    else:
        conv_state, h0 = carry
    exact = exact or carry is not None or n_valid is not None
    xbc, conv_state = _causal_conv(xbc, p["conv_w"].astype(dt_), conv_state, n_valid, exact)
    xbc = jax.nn.silu(xbc)
    xs = xbc[..., :d_in].reshape(b, s, nheads, dh)
    B = xbc[..., d_in : d_in + nst]  # (B,S,N) shared across heads
    C = xbc[..., d_in + nst :]

    dt_v = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    A = -jnp.exp(p["a_log"].astype(jnp.float32))  # (H,) negative decay rates
    decay = jnp.exp(dt_v * A)  # (B,S,H)

    chunk = s_cfg.chunk
    if chunk and s > chunk and s % chunk == 0 and n_valid is None:
        y, h_final = _ssd_chunked(xs, B, C, dt_v, decay, h0, chunk)
    else:
        y, h_final = _ssd_recurrent(xs, B, C, dt_v, decay, h0, n_valid)
    y = y + p["d_skip"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(b, s, d_in).astype(dt_)
    y = gn_rmsnorm(y * jax.nn.silu(z), p["norm"])
    out = jnp.einsum("bsf,fd->bsd", y, p["out_proj"].astype(dt_))
    return out, (conv_state, h_final)


def mamba2_cache_shape(cfg: ModelConfig, batch: int) -> tuple:
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nheads = d_in // s.head_dim
    dt = jnp.dtype(cfg.dtype)
    return (
        jax.ShapeDtypeStruct((batch, s.conv_dim - 1, d_in + 2 * s.state_dim), dt),
        jax.ShapeDtypeStruct((batch, nheads, s.head_dim, s.state_dim), jnp.float32),
    )
