"""Multi-head Latent Attention (MiniCPM3 / DeepSeek-V2 style).

Q and KV are projected through low-rank latents; the decode cache stores only
the compressed latent + shared rope key (kv_lora_rank + qk_rope_head_dim per
token) — MLA's memory win.  Softmax is pluggable exactly as in attention.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import get_softmax
from repro.models.attention import NEG_INF, causal_mask
from repro.models.layers import ParamSpec
from repro.models.rope import apply_rope


def mla_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    m = cfg.mla
    h = cfg.n_heads
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wq_a": ParamSpec((d, m.q_lora_rank), ("embed_fsdp", None)),
        "q_norm": ParamSpec((m.q_lora_rank,), (None,), init="ones"),
        "wq_b": ParamSpec((m.q_lora_rank, h * qk_head), (None, "heads_tp")),
        "wkv_a": ParamSpec((d, m.kv_lora_rank + m.qk_rope_head_dim), ("embed_fsdp", None)),
        "kv_norm": ParamSpec((m.kv_lora_rank,), (None,), init="ones"),
        "wkv_b": ParamSpec(
            (m.kv_lora_rank, h * (m.qk_nope_head_dim + m.v_head_dim)),
            (None, "heads_tp"),
        ),
        "wo": ParamSpec((h * m.v_head_dim, d), ("heads_tp", "embed_fsdp")),
    }


def _project(cfg: ModelConfig, p: dict, x, positions):
    """Compute per-head q (nope+rope) and the compressed kv latent."""
    from repro.core import gn_rmsnorm

    dt = x.dtype
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads

    q_lat = jnp.einsum("bsd,dr->bsr", x, p["wq_a"].astype(dt))
    q_lat = gn_rmsnorm(q_lat, p["q_norm"])
    q = jnp.einsum("bsr,rf->bsf", q_lat, p["wq_b"].astype(dt))
    q = q.reshape(b, s, h, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim :]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv_a = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"].astype(dt))
    c_kv = gn_rmsnorm(kv_a[..., : m.kv_lora_rank], p["kv_norm"])
    k_rope = kv_a[..., m.kv_lora_rank :]  # (b, s, rope_dim) shared across heads
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)
    return q_nope, q_rope, c_kv, k_rope


def _attend(cfg: ModelConfig, p: dict, q_nope, q_rope, c_kv, k_rope, mask,
            probe_lanes=None):
    """Attention against the expanded latent.  c_kv: (b,t,r); k_rope: (b,t,dr).

    ``probe_lanes`` ((b, s) live-lane mask) switches on the GN sentinel
    probe for the paged gathered oracle: the return becomes (out, probe0)
    with probe0 the (b,) Σp/finiteness residual (see
    ``attention._probe_sum_residual``)."""
    dt = q_nope.dtype
    m = cfg.mla
    h = cfg.n_heads
    b, s = q_nope.shape[:2]
    t = c_kv.shape[1]

    kv = jnp.einsum("btr,rf->btf", c_kv, p["wkv_b"].astype(dt))
    kv = kv.reshape(b, t, h, m.qk_nope_head_dim + m.v_head_dim)
    k_nope = kv[..., : m.qk_nope_head_dim]
    v = kv[..., m.qk_nope_head_dim :]

    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    scores = (
        jnp.einsum("bshd,bthd->bhst", q_nope, k_nope)
        + jnp.einsum("bshd,btd->bhst", q_rope, k_rope)
    ) * scale
    scores = jnp.where(mask, scores.astype(jnp.float32), NEG_INF)
    pmat = get_softmax(cfg.softmax_impl)(scores).astype(v.dtype)
    att = jnp.einsum("bhst,bthd->bshd", pmat, v)
    out = jnp.einsum("bsf,fd->bsd", att.reshape(b, s, h * m.v_head_dim),
                     p["wo"].astype(dt))
    if probe_lanes is not None:
        from repro.models.attention import _probe_sum_residual

        valid = jnp.broadcast_to(mask, (b, 1, s, t))[:, 0]  # (b, s, t)
        return out, _probe_sum_residual(pmat, scores, att, valid, probe_lanes)
    return out


def _attend_chunked(cfg: ModelConfig, p: dict, q_nope, q_rope, c_kv, k_rope):
    """Streaming (flash) MLA attention — perf B2 applied to MLA (§Perf).

    The score decomposition q_nope.k_nope + q_rope.k_rope folds exactly into
    one concatenated head dim, so the chunked GN attention applies verbatim:
    q' = [q_nope | q_rope], k' = [k_nope | k_rope(broadcast)].  Removes the
    (b,h,s,t) f32 score tensor (minicpm3 prefill_32k: 1063 s -> see §Perf).
    """
    from repro.models.chunked_attention import causal_chunked

    dt = q_nope.dtype
    m = cfg.mla
    h = cfg.n_heads
    b, s = q_nope.shape[:2]
    t = c_kv.shape[1]

    kv = jnp.einsum("btr,rf->btf", c_kv, p["wkv_b"].astype(dt))
    kv = kv.reshape(b, t, h, m.qk_nope_head_dim + m.v_head_dim)
    k_nope = kv[..., : m.qk_nope_head_dim]
    v = kv[..., m.qk_nope_head_dim :]

    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    qq = jnp.concatenate([q_nope, q_rope], axis=-1)  # (b,s,h,dn+dr)
    kk = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None], (b, t, h, k_rope.shape[-1]))],
        axis=-1,
    )
    from repro.parallel.sharding import shard

    qt = shard(qq.transpose(0, 2, 1, 3), "batch", "heads_act", None, None)
    kt = shard(kk.transpose(0, 2, 1, 3), "batch", "heads_act", None, None)
    vt = shard(v.transpose(0, 2, 1, 3), "batch", "heads_act", None, None)
    out = causal_chunked(qt, kt, vt, impl=cfg.softmax_impl, scale=scale)
    out = out.astype(dt).transpose(0, 2, 1, 3).reshape(b, s, h * m.v_head_dim)
    return jnp.einsum("bsf,fd->bsd", out, p["wo"].astype(dt))


def _use_chunked_mla(cfg, s: int) -> bool:
    return s > 2048 and cfg.softmax_impl in ("gn", "exact")


def mla_self_attention(cfg: ModelConfig, p: dict, x, positions, causal=True):
    b, s, _ = x.shape
    q_nope, q_rope, c_kv, k_rope = _project(cfg, p, x, positions)
    if causal and _use_chunked_mla(cfg, s):
        return _attend_chunked(cfg, p, q_nope, q_rope, c_kv, k_rope)
    mask = causal_mask(s, s) if causal else jnp.ones((1, 1, s, s), bool)
    return _attend(cfg, p, q_nope, q_rope, c_kv, k_rope, mask)


def mla_cache_shape(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    m = cfg.mla
    return {
        "c_kv": jax.ShapeDtypeStruct((batch, max_seq, m.kv_lora_rank), jnp.dtype(cfg.dtype)),
        "k_rope": jax.ShapeDtypeStruct((batch, max_seq, m.qk_rope_head_dim), jnp.dtype(cfg.dtype)),
    }


def mla_prefill(cfg: ModelConfig, p: dict, x, positions):
    b, s, _ = x.shape
    q_nope, q_rope, c_kv, k_rope = _project(cfg, p, x, positions)
    if _use_chunked_mla(cfg, s):
        out = _attend_chunked(cfg, p, q_nope, q_rope, c_kv, k_rope)
    else:
        mask = causal_mask(s, s)
        out = _attend(cfg, p, q_nope, q_rope, c_kv, k_rope, mask)
    return out, {"c_kv": c_kv, "k_rope": k_rope}


def mla_decode_chunk(cfg: ModelConfig, p: dict, cache: dict, x, pos, n_valid):
    """Chunked append-decode over the latent cache (see attention.py
    ``attn_decode_chunk`` for the lane/masking contract).  x: (B,C,D);
    pos/n_valid: traced scalars.  Lanes >= n_valid drop their cache writes
    (out-of-bounds scatter) and produce don't-care outputs."""
    b, c_len = x.shape[:2]
    offs = jnp.arange(c_len)
    rows = pos + offs
    posv = jnp.broadcast_to(rows[None], (b, c_len))
    q_nope, q_rope, c_new, kr_new = _project(cfg, p, x, posv)
    t = cache["c_kv"].shape[1]
    widx = jnp.where(offs < n_valid, rows, t)  # invalid lanes -> dropped
    c_kv = cache["c_kv"].at[:, widx].set(
        c_new.astype(cache["c_kv"].dtype), mode="drop"
    )
    k_rope = cache["k_rope"].at[:, widx].set(
        kr_new.astype(cache["k_rope"].dtype), mode="drop"
    )
    mask = (jnp.arange(t)[None, :] <= rows[:, None])[None, None]  # (1,1,C,t)
    out = _attend(cfg, p, q_nope, q_rope, c_kv, k_rope, mask)
    return out, {"c_kv": c_kv, "k_rope": k_rope}


def mla_paged_read_path(cfg: ModelConfig) -> str:
    """Which paged read the MLA serving tick uses: 'streamed' (block-tile
    scan expanding the latent per tile — gather-free) or 'gathered' (full
    logical-stream materialization; baselines-only oracle).  There is no
    Pallas MLA kernel — the latent expansion keeps the hot loop a matmul."""
    from repro.models import attention

    if attention.FORCE_PAGED_READ in ("streamed", "gathered"):
        return attention.FORCE_PAGED_READ
    # a forced 'pallas' falls through to the auto choice: there is no MLA
    # kernel, and the reported path must always be the one that actually ran
    return "streamed" if cfg.softmax_impl in ("gn", "exact") else "gathered"


def _mla_stream_tiles(cfg: ModelConfig, p: dict, q_nope, q_rope, arena_ckv,
                      arena_krope, tables, rows, scales=None, probe_nv=None):
    """Gather-free MLA paged read: lax.scan over latent block tiles.

    Each k-scan step expands ONE (N, bs) latent tile through wkv_b and emits
    its score tile (score decomposition q_nope·k_nope + q_rope·k_rope, the
    same expression ``_attend`` evaluates on the gathered stream — each
    element is an independent rank/head-dim contraction, so the stacked
    score row is bitwise identical to the gathered read's) plus the
    expanded value tile.  The one-pass GN softmax runs on the stacked row
    exactly as in ``_attend`` (identical probabilities, exactly-zero
    numerators on every masked/stale column), and the weighted-value
    contraction is ``_attend``'s own einsum over the stacked tiles — the
    whole read is bitwise identical to the gathered path.  Nothing wider
    than the tick's block horizon — tables arrives horizon-sliced from the
    engine — is ever resident, and the gathered latent stream itself is
    never materialized (the expansion is computed per tile from the
    arenas).
    Returns (N, C, h·v_head_dim) in activation dtype."""
    dt = q_nope.dtype
    m = cfg.mla
    h = cfg.n_heads
    n, c = rows.shape
    bs = arena_ckv.shape[1]
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    c_scale, r_scale = scales if scales is not None else (None, None)
    tbls = jnp.moveaxis(tables, 1, 0)  # (H, N)

    def k_body(_, tbl_j):  # tbl_j: (N,) physical block id of logical j
        c_tile = arena_ckv[tbl_j].astype(dt)  # (N, bs, rank)
        r_tile = arena_krope[tbl_j].astype(dt)  # (N, bs, dr)
        if c_scale is not None:
            # dequantize the int8 latent tile AFTER the per-tile gather —
            # the arena-wide latent stream is never fp-resident
            c_tile = c_tile * c_scale[tbl_j].astype(dt)[:, None, None]
            r_tile = r_tile * r_scale[tbl_j].astype(dt)[:, None, None]
        kv = jnp.einsum("btr,rf->btf", c_tile, p["wkv_b"].astype(dt))
        kv = kv.reshape(n, bs, h, m.qk_nope_head_dim + m.v_head_dim)
        k_nope = kv[..., : m.qk_nope_head_dim]
        v_tile = kv[..., m.qk_nope_head_dim :]
        s = (
            jnp.einsum("bshd,bthd->bhst", q_nope, k_nope)
            + jnp.einsum("bshd,btd->bhst", q_rope, r_tile)
        ) * scale
        return None, (s, v_tile)

    _, (s_tiles, v_tiles) = jax.lax.scan(k_body, None, tbls, unroll=8)
    scores = jnp.moveaxis(s_tiles, 0, 3)  # (N, h, C, H, bs)
    scores = scores.reshape(*scores.shape[:3], -1)  # logical column order

    t = scores.shape[-1]  # horizon * bs, tail masked below
    valid = (jnp.arange(t)[None, None, :] <= rows[:, :, None])[:, None]
    scores = jnp.where(valid, scores.astype(jnp.float32), NEG_INF)
    pmat = get_softmax(cfg.softmax_impl)(scores).astype(dt)
    # the expanded value tiles in logical column order, horizon-bounded —
    # one AV contraction, bitwise equal to _attend's
    v_at = jnp.moveaxis(v_tiles, 0, 1).reshape(n, -1, h, m.v_head_dim)
    out = jnp.einsum("bhst,bthd->bshd", pmat, v_at)
    if probe_nv is not None:
        from repro.models.attention import _probe_sum_residual

        lane_ok = jnp.arange(c)[None, :] < probe_nv[:, None]
        probe0 = _probe_sum_residual(pmat, scores, out, valid[:, 0], lane_ok)
        return out.reshape(n, c, h * m.v_head_dim), probe0
    return out.reshape(n, c, h * m.v_head_dim)


def mla_paged_chunk(cfg: ModelConfig, p: dict, arena_ckv, arena_krope, x,
                    positions, n_valid, tables, scales=None, probe=False):
    """Block-paged chunked append-decode over the latent cache, batched over
    slots (see attention.paged ``attn_paged_chunk`` for the table/guard
    contract).  x: (N, C, D); positions/n_valid: (N,); tables: (N, max_bt) —
    horizon-sliced by the engine, so the read scans only the tick's live
    block horizon; arena_ckv: (num_blocks, block_size, kv_lora_rank);
    arena_krope: (num_blocks, block_size, qk_rope_head_dim).  MLA's
    compressed latent is what makes paging cheap here: a block holds
    block_size * (rank + rope) scalars instead of full per-head KV.  The
    read is streamed per block tile (``mla_paged_read_path``); the gathered
    full-stream path survives as the baselines/tests oracle.

    ``scales=(c_kv_scale, k_rope_scale)`` ((num_blocks,) f32 each) switches
    both arenas to int8 with freeze-at-first-write per-block scales (see
    ``attention.paged_quant_write``); reads dequantize per tile after the
    gather, and the returned arenas tuple grows the two new scale rows.
    Returns (out, (new arenas)) — plus the (N, 3) GN sentinel health word
    when ``probe=True`` (a static Python bool; see
    ``attention.attn_paged_chunk``)."""
    from repro.models.attention import (paged_probe_word, paged_quant_write,
                                        paged_write_indices)

    b, c_len = x.shape[:2]
    nb, bs = arena_ckv.shape[:2]
    offs = jnp.arange(c_len)
    rows = positions[:, None] + offs[None, :]
    q_nope, q_rope, c_new, kr_new = _project(cfg, p, x, rows)

    dest = paged_write_indices(rows, n_valid, tables, bs, nb)
    flat_c = arena_ckv.reshape(nb * bs, -1)
    flat_r = arena_krope.reshape(nb * bs, -1)
    clip_tok = None
    if scales is not None:
        c_scale, r_scale = scales
        if probe:
            flat_c, c_scale, cclip = paged_quant_write(
                flat_c, c_scale, c_new.reshape(b * c_len, -1), dest, bs,
                return_clip=True)
            flat_r, r_scale, rclip = paged_quant_write(
                flat_r, r_scale, kr_new.reshape(b * c_len, -1), dest, bs,
                return_clip=True)
            clip_tok = cclip | rclip
        else:
            flat_c, c_scale = paged_quant_write(
                flat_c, c_scale, c_new.reshape(b * c_len, -1), dest, bs)
            flat_r, r_scale = paged_quant_write(
                flat_r, r_scale, kr_new.reshape(b * c_len, -1), dest, bs)
        arenas = (flat_c.reshape(arena_ckv.shape),
                  flat_r.reshape(arena_krope.shape), c_scale, r_scale)
        rd_scales = (c_scale, r_scale)
    else:
        flat_c = flat_c.at[dest].set(c_new.reshape(b * c_len, -1).astype(flat_c.dtype), mode="drop")
        flat_r = flat_r.at[dest].set(kr_new.reshape(b * c_len, -1).astype(flat_r.dtype), mode="drop")
        arenas = (flat_c.reshape(arena_ckv.shape), flat_r.reshape(arena_krope.shape))
        rd_scales = None

    if mla_paged_read_path(cfg) == "streamed":
        res = _mla_stream_tiles(
            cfg, p, q_nope, q_rope,
            flat_c.reshape(nb, bs, -1), flat_r.reshape(nb, bs, -1),
            tables, rows, scales=rd_scales,
            probe_nv=n_valid if probe else None,
        )  # (N, C, h*dv) in activation dtype
        dt = x.dtype
        if probe:
            out, probe0 = res
            return (jnp.einsum("bsf,fd->bsd", out, p["wo"].astype(dt)), arenas,
                    paged_probe_word(probe0, positions, n_valid, tables, bs,
                                     rd_scales, clip_tok))
        return jnp.einsum("bsf,fd->bsd", res, p["wo"].astype(dt)), arenas

    dt = x.dtype
    c_kv = flat_c.reshape(nb, bs, -1)[tables]
    k_rope = flat_r.reshape(nb, bs, -1)[tables]
    if rd_scales is not None:
        # the oracle gathers int8 blocks, then dequantizes its stream
        c_kv = c_kv.astype(dt) * c_scale[tables].astype(dt)[..., None, None]
        k_rope = k_rope.astype(dt) * r_scale[tables].astype(dt)[..., None, None]
    c_kv = c_kv.reshape(b, -1, flat_c.shape[-1])
    k_rope = k_rope.reshape(b, -1, flat_r.shape[-1])
    t = c_kv.shape[1]
    mask = (jnp.arange(t)[None, None, :] <= rows[:, :, None])[:, None]  # (N,1,C,T)
    if probe:
        lane_ok = offs[None, :] < n_valid[:, None]
        out, probe0 = _attend(cfg, p, q_nope, q_rope, c_kv, k_rope, mask,
                              probe_lanes=lane_ok)
        return out, arenas, paged_probe_word(
            probe0, positions, n_valid, tables, bs, rd_scales, clip_tok)
    out = _attend(cfg, p, q_nope, q_rope, c_kv, k_rope, mask)
    return out, arenas


def mla_decode_step(cfg: ModelConfig, p: dict, cache: dict, x, pos):
    b = x.shape[0]
    posv = jnp.full((b, 1), pos, jnp.int32)
    q_nope, q_rope, c_new, kr_new = _project(cfg, p, x, posv)
    c_kv = jax.lax.dynamic_update_slice(
        cache["c_kv"], c_new.astype(cache["c_kv"].dtype), (0, pos, 0)
    )
    k_rope = jax.lax.dynamic_update_slice(
        cache["k_rope"], kr_new.astype(cache["k_rope"].dtype), (0, pos, 0)
    )
    t = c_kv.shape[1]
    mask = (jnp.arange(t) <= pos)[None, None, None, :]
    out = _attend(cfg, p, q_nope, q_rope, c_kv, k_rope, mask)
    return out, {"c_kv": c_kv, "k_rope": k_rope}
