"""Rotary position embeddings (supports offsetting for decode)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D) or (..., S, D); positions: broadcastable to (..., S)."""
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)  # (half,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)
    sin = jnp.sin(angles)
    if x.ndim == angles.ndim + 1:  # (..., S, H, D): broadcast over heads
        cos = cos[..., None, :]
        sin = sin[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)
