"""Chunked (flash-style) attention with streaming GN-Softmax — perf B2.

Why (EXPERIMENTS.md §Perf, cell B): the one-pass ``_sdpa`` materializes the
full (B,KV,G,S,T) float32 score tensor — 1.2e15 bytes/device on the deepseek
prefill_32k cell, plus a partitioner-inserted all-reduce over that tensor.
This module never materializes more than a (.., S_q, kv_chunk) tile.

The GN (guaranteed-normalization) softmax survives streaming exactly:

  * the stabilizer is the running max *snapped up to the Δ grid* — identical
    to the one-pass ``gn_softmax`` stabilizer once all chunks are seen;
  * every exponential — numerators AND the rescale of previous partial sums —
    goes through the paper's two-LUT factorized exp (``factorized_exp_ste``);
  * the final division is a single reciprocal by the *true accumulated sum of
    the approximated numerators*, so sum(p) = 1 to one rounding, independent
    of chunking (test: ``attention of constant v returns that constant``).

Causal attention uses a hierarchical halves decomposition instead of masked
tiles: at level l the high half of each of 2^l blocks attends the low half
(an unmasked rectangle, batched over blocks), and only the final ``leaf``-
sized diagonal blocks pay the triangular masking waste (= leaf/S of total
flops, ~6% at 2048/32768, vs 100% for naive chunk masking).  Sliding-window
attention uses a banded q-chunk scan with a static (window + chunk) kv slice.

All shapes here are (B, H, S, dh) with kv-heads already broadcast to H; the
dispatcher in models/attention.py handles GQA broadcast and layout.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.gn_softmax import factorized_exp_ste
from repro.core.luts import SoftmaxLUTConfig, TPU_SOFTMAX_LUT
from repro.parallel.sharding import shard

NEG = -1e30


def _exp_pair(impl: str, lut_cfg: SoftmaxLUTConfig):
    """-> (exp_fn(delta >= 0) = approx e^-delta, grid step or None)."""
    if impl.startswith("gn"):
        return functools.partial(factorized_exp_ste, cfg=lut_cfg), lut_cfg.step
    return (lambda d: jnp.exp(-d)), None


def _snap_up(m, step):
    return jnp.ceil(m / step) * step if step else m


def _update(state, s, v_c, exp_fn, step, guard: bool = True):
    """Online-softmax accumulate of one score tile.

    state: (acc (...,Sq,dh) f32, m (...,Sq) f32, z (...,Sq) f32)
    s: (..., Sq, Kc) f32 scores (masked entries = NEG); v_c: (..., Kc, dh).
    ``guard=False`` skips the masked-entry zeroing for tiles known to be
    fully valid (the unmasked hierarchy levels) — perf B3 (§Perf): the
    redundant select materialized an extra f32 tile per chunk.
    """
    acc, m, z = state
    m_c = _snap_up(jnp.max(s, axis=-1), step)
    m_new = jnp.maximum(m, m_c)
    resc = exp_fn(jnp.maximum(m_new - m, 0.0))  # e^-(m_new-m), on-grid
    y = exp_fn(jnp.maximum(m_new[..., None] - s, 0.0))  # numerators
    if guard:
        # masked entries have delta ~ 1e30 -> exp underflows the fixed-point
        # grid to exactly 0; keep an explicit zero for the float path.
        y = jnp.where(s <= NEG / 2, 0.0, y)
    z = z * resc + jnp.sum(y, axis=-1)
    pv = jnp.einsum(
        "...qk,...kd->...qd",
        y.astype(v_c.dtype),
        v_c,
        preferred_element_type=jnp.float32,
    )
    acc = acc * resc[..., None] + pv
    return (acc, m_new, z)


def _init_state(lead, dh):
    """lead = q.shape[:-1] (i.e. (..., Sq)); state rows parallel q rows."""
    return (
        jnp.zeros((*lead, dh), jnp.float32),
        jnp.full(lead, NEG, jnp.float32),
        jnp.zeros(lead, jnp.float32),
    )


def _stream_rect(q, k, v, state, exp_fn, step, kv_chunk, scale, mask_fn=None):
    """Unmasked (or mask_fn-masked) rectangle: q (...,Sq,dh) x kv (...,T,dh).

    Scans kv in chunks; mask_fn(chunk_idx) -> (Sq, Kc) bool or None.
    """
    t = k.shape[-2]
    kc = min(kv_chunk, t)
    nk, rem = divmod(t, kc)
    assert rem == 0, f"kv len {t} % chunk {kc}"

    ks = jnp.moveaxis(k.reshape(*k.shape[:-2], nk, kc, k.shape[-1]), -3, 0)
    vs = jnp.moveaxis(v.reshape(*v.shape[:-2], nk, kc, v.shape[-1]), -3, 0)

    def body(st, inp):
        i, k_c, v_c = inp
        s = jnp.einsum(
            "...qd,...kd->...qk", q, k_c, preferred_element_type=jnp.float32
        ) * scale
        if mask_fn is not None:
            s = jnp.where(mask_fn(i), s, NEG)
        return _update(st, s, v_c, exp_fn, step, guard=mask_fn is not None), None

    state, _ = jax.lax.scan(body, state, (jnp.arange(nk), ks, vs))
    return state


def _finalize(state):
    acc, _, z = state
    return acc * (1.0 / jnp.maximum(z, 1e-30))[..., None]


# ---------------------------------------------------------------- causal ---
def causal_chunked(
    q, k, v,
    *,
    impl: str = "gn",
    lut_cfg: SoftmaxLUTConfig = TPU_SOFTMAX_LUT,
    kv_chunk: int = 1024,
    leaf: int = 2048,
    scale: Optional[float] = None,
):
    """Causal self-attention, (B,H,S,dh) -> (B,H,S,dh), hierarchical halves."""
    b, h, s, dh = q.shape
    dv = v.shape[-1]  # value head dim may differ (MLA)
    scale = dh**-0.5 if scale is None else scale
    exp_fn, step = _exp_pair(impl, lut_cfg)
    leaf = min(leaf, s)
    while s % leaf:
        leaf //= 2
    kc = min(kv_chunk, leaf)

    state = _init_state(q.shape[:-1], dv)

    # --- diagonal leaves: (B,H,nl,leaf) blocks, causal mask inside ----------
    nl = s // leaf
    blk = lambda x: x.reshape(b, h, nl, leaf, x.shape[-1])
    rows = jnp.arange(leaf)[:, None]

    def leaf_mask(i):  # kv chunk i within the leaf
        cols = i * kc + jnp.arange(kc)[None, :]
        return cols <= rows

    acc, m, z = state
    st_blk = (blk(acc), m.reshape(b, h, nl, leaf), z.reshape(b, h, nl, leaf))
    st_blk = _stream_rect(
        blk(q), blk(k), blk(v), st_blk, exp_fn, step, kc, scale, mask_fn=leaf_mask
    )
    state = (
        st_blk[0].reshape(b, h, s, dv),
        st_blk[1].reshape(b, h, s),
        st_blk[2].reshape(b, h, s),
    )

    # --- off-diagonal levels: high half attends low half, batched -----------
    w = s
    nb = 1
    while w > leaf:
        w2 = w // 2
        qv = q.reshape(b, h, nb, 2, w2, dh)
        kv_ = k.reshape(b, h, nb, 2, w2, dh)
        vv = v.reshape(b, h, nb, 2, w2, dv)
        acc, m, z = state
        accv = acc.reshape(b, h, nb, 2, w2, dv)
        mv = m.reshape(b, h, nb, 2, w2)
        zv = z.reshape(b, h, nb, 2, w2)
        st_hi = (accv[:, :, :, 1], mv[:, :, :, 1], zv[:, :, :, 1])
        st_hi = _stream_rect(
            qv[:, :, :, 1], kv_[:, :, :, 0], vv[:, :, :, 0],
            st_hi, exp_fn, step, min(kv_chunk, w2), scale,
        )
        acc = jnp.stack([accv[:, :, :, 0], st_hi[0]], axis=3).reshape(b, h, s, dv)
        m = jnp.stack([mv[:, :, :, 0], st_hi[1]], axis=3).reshape(b, h, s)
        z = jnp.stack([zv[:, :, :, 0], st_hi[2]], axis=3).reshape(b, h, s)
        state = (acc, m, z)
        nb *= 2
        w = w2

    return _finalize(state)


# ---------------------------------------------------------------- window ---
def windowed_chunked(
    q, k, v,
    *,
    window: int,
    impl: str = "gn",
    lut_cfg: SoftmaxLUTConfig = TPU_SOFTMAX_LUT,
    q_chunk: int = 1024,
    scale: Optional[float] = None,
):
    """Causal sliding-window attention via a banded q-chunk scan.

    Each q chunk sees a static (window + q_chunk)-wide kv slice of the
    front-padded sequence — no O(S^2) tiles, ~(q_chunk/(window+q_chunk))
    masking waste.
    """
    b, h, s, dh = q.shape
    scale = dh**-0.5 if scale is None else scale
    exp_fn, step = _exp_pair(impl, lut_cfg)
    qc = min(q_chunk, s)
    while s % qc:
        qc //= 2
    nq = s // qc
    band = window + qc

    pad = jnp.zeros((b, h, window, dh), k.dtype)
    kp = jnp.concatenate([pad, k], axis=2)  # position j -> index j + window
    vp = jnp.concatenate([pad, v], axis=2)

    qs = jnp.moveaxis(q.reshape(b, h, nq, qc, dh), 2, 0)

    def body(_, inp):
        i, q_c = inp
        start = i * qc  # kv slice [start, start+band) in padded coords
        k_c = jax.lax.dynamic_slice_in_dim(kp, start, band, axis=2)
        v_c = jax.lax.dynamic_slice_in_dim(vp, start, band, axis=2)
        srow = jnp.einsum(
            "bhqd,bhkd->bhqk", q_c, k_c, preferred_element_type=jnp.float32
        ) * scale
        # global row r = start + qi, global col c = start + ki - window
        qi = jnp.arange(qc)[:, None]
        ki = jnp.arange(band)[None, :]
        col = ki - window  # relative to row block start
        valid = (col <= qi) & (col > qi - window) & (start + col >= 0)
        srow = jnp.where(valid, srow, NEG)
        st = _init_state((b, h, qc), dh)
        st = _update(st, srow, v_c, exp_fn, step)
        return None, _finalize(st)

    _, outs = jax.lax.scan(body, None, (jnp.arange(nq), qs))
    return jnp.moveaxis(outs, 0, 2).reshape(b, h, s, dh)


# ------------------------------------------------------------- dispatcher ---
def chunked_self_attention(
    cfg, q, k, v, causal: bool, lut_cfg: SoftmaxLUTConfig = TPU_SOFTMAX_LUT
):
    """(B,S,H,dh) q + (B,T,KV,dh) kv -> (B,S,H,dh).  GQA broadcast + layout +
    sharding (flat query heads over the TP axis; small kv replicated)."""
    bsz, s, hq, dh = q.shape
    group = hq // k.shape[2]
    if group > 1:
        k = jnp.repeat(k, group, axis=2)
        v = jnp.repeat(v, group, axis=2)
    qt = shard(q.transpose(0, 2, 1, 3), "batch", "heads_act", None, None)
    kt = shard(k.transpose(0, 2, 1, 3), "batch", "heads_act", None, None)
    vt = shard(v.transpose(0, 2, 1, 3), "batch", "heads_act", None, None)
    impl = cfg.softmax_impl
    if causal and cfg.sliding_window and s > cfg.sliding_window:
        out = windowed_chunked(qt, kt, vt, window=cfg.sliding_window, impl=impl, lut_cfg=lut_cfg)
    elif causal:
        out = causal_chunked(qt, kt, vt, impl=impl, lut_cfg=lut_cfg)
    else:
        st = _init_state(qt.shape[:-1], dh)
        st = _stream_rect(qt, kt, vt, st, *_exp_pair(impl, lut_cfg), 1024, dh**-0.5)
        out = _finalize(st)
    return out.astype(q.dtype).transpose(0, 2, 1, 3)
