"""Shared building blocks: init, norm dispatch, MLP, embeddings.

Everything is functional: params are nested dicts of jnp arrays; modules are
(init, apply) function pairs.  Logical sharding axes for every parameter are
declared alongside its initializer (see ``ParamSpec``) so the dry-run can
materialize ShapeDtypeStructs with NamedShardings without allocating.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import get_norm
from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple
    logical_axes: tuple  # logical axis name (or None) per dim
    init: str = "normal"  # normal | zeros | ones
    scale: float = 0.02
    dtype: str = "float32"


def make_param(key, spec: ParamSpec):
    dtype = jnp.dtype(spec.dtype)
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    return (jax.random.normal(key, spec.shape) * spec.scale).astype(dtype)


def init_tree(key, spec_tree):
    """Initialize a pytree of ParamSpec into a pytree of arrays."""
    leaves, treedef = jax.tree.flatten(
        spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec)
    )
    keys = jax.random.split(key, len(leaves))
    vals = [make_param(k, s) for k, s in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, vals)


def spec_struct(spec_tree):
    """ParamSpec tree -> ShapeDtypeStruct tree (for AOT lowering)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype)),
        spec_tree,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def stack_specs(spec_tree, n: int, axis_name=None):
    """Prepend a stacked (scan) layer dimension to every ParamSpec."""
    return jax.tree.map(
        lambda s: ParamSpec(
            (n, *s.shape), (axis_name, *s.logical_axes), s.init, s.scale, s.dtype
        ),
        spec_tree,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


# ------------------------------------------------------------------- norms --
def norm_specs(cfg: ModelConfig, d: int | None = None) -> dict:
    d = d or cfg.d_model
    specs = {"gamma": ParamSpec((d,), (None,), init="ones")}
    if _norm_has_beta(cfg.norm_impl):
        specs["beta"] = ParamSpec((d,), (None,), init="zeros")
    return specs


def _norm_has_beta(norm_impl: str) -> bool:
    return "ln" in norm_impl  # LayerNorm variants carry beta; RMS variants don't


def apply_norm(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    fn = get_norm(cfg.norm_impl)
    gamma = p["gamma"]
    beta = p.get("beta")
    return fn(x, gamma, beta) if beta is not None else fn(x, gamma)


# -------------------------------------------------------------------- MLP ---
def mlp_specs(cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    if cfg.mlp_act == "swiglu":
        return {
            "wi": ParamSpec((d, f), ("embed_fsdp", "ff")),
            "wg": ParamSpec((d, f), ("embed_fsdp", "ff")),
            "wo": ParamSpec((f, d), ("ff", "embed_fsdp")),
        }
    return {
        "wi": ParamSpec((d, f), ("embed_fsdp", "ff")),
        "wo": ParamSpec((f, d), ("ff", "embed_fsdp")),
    }


def apply_mlp(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    dt = x.dtype
    if cfg.mlp_act == "swiglu":
        h = jnp.einsum("...d,df->...f", x, p["wi"].astype(dt))
        g = jnp.einsum("...d,df->...f", x, p["wg"].astype(dt))
        h = jax.nn.silu(g) * h
    else:
        h = jnp.einsum("...d,df->...f", x, p["wi"].astype(dt))
        h = jax.nn.gelu(h)
    return jnp.einsum("...f,fd->...d", h, p["wo"].astype(dt))


# -------------------------------------------------------------- embeddings --
def embed_specs(cfg: ModelConfig) -> dict:
    return {
        "tok": ParamSpec((cfg.vocab, cfg.d_model), ("vocab", "embed_fsdp")),
    }


def lm_head_specs(cfg: ModelConfig) -> dict:
    return {"w": ParamSpec((cfg.d_model, cfg.vocab), ("embed_fsdp", "vocab"))}
