"""Mixture-of-Experts layer: GShard-style grouped top-k dispatch.

Tokens are partitioned into groups (aligned with the data-parallel sharding),
each group routes its tokens to experts under a per-group capacity; dispatch
and combine are one-hot einsums (MXU-friendly, shardable — the expert dim is
sharded over the 'model' axis, which makes XLA emit the canonical GShard
all-to-all pattern).

The **router softmax is score-oriented**: its probabilities weight expert
outputs directly and feed the load-balance loss, so normalization errors bias
both the mixture and the auxiliary objective — running it through GN-Softmax
(``cfg.softmax_impl``) is a first-class application of the paper.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import get_softmax
from repro.models.layers import ParamSpec


def moe_specs(cfg: ModelConfig) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe.num_experts
    # EP when the expert count divides the production TP width (16); otherwise
    # tensor-parallel *within* every expert (mixtral: 8 experts, 16-way TP).
    if e % 16 == 0:
        wi_ax, wo_ax = ("expert", "embed_fsdp", None), ("expert", None, "embed_fsdp")
    else:
        wi_ax, wo_ax = (None, "embed_fsdp", "ff"), (None, "ff", "embed_fsdp")
    return {
        "router": ParamSpec((d, e), ("embed_fsdp", None)),
        "wi": ParamSpec((e, d, f), wi_ax),
        "wg": ParamSpec((e, d, f), wi_ax),
        "wo": ParamSpec((e, f, d), wo_ax),
    }


def _top_k(gates: jax.Array, k: int):
    """Iterative top-k (k<=2 in all assigned archs). gates: (..., E)."""
    idxs, vals = [], []
    g = gates
    for _ in range(k):
        i = jnp.argmax(g, axis=-1)
        v = jnp.take_along_axis(g, i[..., None], axis=-1)[..., 0]
        idxs.append(i)
        vals.append(v)
        g = g - jax.nn.one_hot(i, gates.shape[-1], dtype=g.dtype) * 1e9
    return jnp.stack(idxs, -1), jnp.stack(vals, -1)  # (..., k)


def apply_moe(cfg: ModelConfig, p: dict, x: jax.Array):
    """x: (B, S, D) -> (y, aux) with load-balance + router z metrics."""
    dt = x.dtype
    m = cfg.moe
    b, s, d = x.shape
    e, k = m.num_experts, m.top_k
    tokens = b * s
    g_sz = min(m.group_size, tokens)
    n_groups = tokens // g_sz
    assert n_groups * g_sz == tokens, (tokens, g_sz)
    cap = max(int(g_sz * k * m.capacity_factor / e), 1)

    xg = x.reshape(n_groups, g_sz, d)

    logits = jnp.einsum("gtd,de->gte", xg, p["router"].astype(dt)).astype(jnp.float32)
    gates = get_softmax(cfg.softmax_impl)(logits)  # (g, t, e) score-oriented!
    idx, val = _top_k(gates, k)  # (g, t, k)
    # normalize the selected gate mass (mixtral-style)
    val = val / jnp.maximum(jnp.sum(val, -1, keepdims=True), 1e-9)

    # position-in-expert via cumsum over the group (drop beyond capacity)
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.int32)  # (g, t, k, e)
    # earlier k-choices claim capacity first, then earlier tokens
    flat = onehot.reshape(n_groups, g_sz * k, e)
    pos = jnp.cumsum(flat, axis=1) - flat  # (g, t*k, e) slots already taken
    pos = pos.reshape(n_groups, g_sz, k, e)
    in_cap = (pos < cap) & (onehot > 0)
    slot = jnp.sum(pos * onehot, -1)  # (g, t, k) capacity slot per choice
    keep = jnp.any(in_cap, -1)  # (g, t, k)

    if cfg.moe_dispatch == "gather":
        # Gather/scatter dispatch — perf iteration A3 (§Perf).  The one-hot
        # dispatch/combine einsums cost 2*g*t*(e*cap)*d flops EACH — as much
        # as the expert matmuls themselves (~45% of the mixtral train_4k
        # compute term).  Routing is a permutation, not a matmul: scatter the
        # kept (token, choice) pairs into their (expert, slot) cells, gather
        # token embeddings in, gather expert outputs back out.  Identical
        # math (tests/test_moe_dispatch.py), O(t*k*d) bytes, ~zero flops.
        tk = g_sz * k
        dest = jnp.where(keep, idx * cap + slot, e * cap).reshape(n_groups, tk)
        tok_of = jnp.broadcast_to(
            jnp.arange(g_sz)[:, None], (g_sz, k)
        ).reshape(tk)
        grow = jnp.arange(n_groups)[:, None]
        src = jnp.zeros((n_groups, e * cap + 1), jnp.int32)
        src = src.at[grow, dest].set(tok_of[None, :], mode="drop")
        filled = jnp.zeros((n_groups, e * cap + 1), dt)
        filled = filled.at[grow, dest].set(1.0, mode="drop")
        src, filled = src[:, :-1], filled[:, :-1]

        expert_in = jnp.take_along_axis(xg, src[..., None], axis=1)
        expert_in = (expert_in * filled[..., None]).reshape(n_groups, e, cap, d)
        h = jnp.einsum("gecd,edf->gecf", expert_in, p["wi"].astype(dt))
        gate = jnp.einsum("gecd,edf->gecf", expert_in, p["wg"].astype(dt))
        h = jax.nn.silu(gate) * h
        expert_out = jnp.einsum("gecf,efd->gecd", h, p["wo"].astype(dt))

        flat_out = expert_out.reshape(n_groups, e * cap, d)
        back = jnp.take_along_axis(
            flat_out, jnp.minimum(dest, e * cap - 1)[..., None], axis=1
        ).reshape(n_groups, g_sz, k, d)
        w = (val.astype(dt) * keep.astype(dt)).reshape(n_groups, g_sz, k)
        y = jnp.einsum("gtk,gtkd->gtd", w, back)
    else:  # 'einsum': the GShard one-hot reference path
        # dispatch/combine one-hots: (g, t, e, cap)
        slot_oh = jax.nn.one_hot(slot, cap, dtype=dt)  # (g, t, k, cap)
        exp_oh_d = onehot.astype(dt) * keep[..., None].astype(dt)  # (g, t, k, e)
        dispatch = jnp.einsum("gtke,gtkc->gtec", exp_oh_d, slot_oh)
        combine = jnp.einsum("gtke,gtkc,gtk->gtec", exp_oh_d, slot_oh, val.astype(dt))

        expert_in = jnp.einsum("gtec,gtd->gecd", dispatch, xg)  # (g, e, cap, d)
        h = jnp.einsum("gecd,edf->gecf", expert_in, p["wi"].astype(dt))
        gate = jnp.einsum("gecd,edf->gecf", expert_in, p["wg"].astype(dt))
        h = jax.nn.silu(gate) * h
        expert_out = jnp.einsum("gecf,efd->gecd", h, p["wo"].astype(dt))
        y = jnp.einsum("gtec,gecd->gtd", combine, expert_out)

    # aux losses (Switch): load-balance + router z-loss ingredients
    exp_oh = onehot.astype(jnp.float32) * keep[..., None].astype(jnp.float32)
    density = jnp.mean(exp_oh.sum(2), axis=1)  # (g, e) fraction routed
    prob_mass = jnp.mean(gates, axis=1)  # (g, e)
    lb_loss = e * jnp.mean(jnp.sum(density * prob_mass, -1))
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, -1)))
    aux = {"load_balance": lb_loss, "router_z": z_loss,
           "dropped_frac": 1.0 - jnp.mean(keep.astype(jnp.float32))}
    return y.reshape(b, s, d), aux
