"""Attention: GQA/MHA (+sliding window), cross-attention, and decode paths.

The softmax is pluggable (``cfg.softmax_impl``) — 'gn' routes through the
paper's Algorithm 1; baselines and the FP32 oracle are selectable for the
accuracy experiments.  ``cfg.use_pallas`` switches the training/prefill path
to the fused GN flash-attention Pallas kernel (single-chip hot path).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import get_softmax
from repro.models.layers import ParamSpec
from repro.models.rope import apply_rope

NEG_INF = -1e30


def attn_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    return {
        "wq": ParamSpec((d, cfg.q_features), ("embed_fsdp", "heads_tp")),
        "wk": ParamSpec((d, cfg.kv_features), ("embed_fsdp", "heads_tp")),
        "wv": ParamSpec((d, cfg.kv_features), ("embed_fsdp", "heads_tp")),
        "wo": ParamSpec((cfg.q_features, d), ("heads_tp", "embed_fsdp")),
    }


def _split_heads(x, n_heads, head_dim):
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, head_dim)


def _sdpa(cfg: ModelConfig, q, k, v, mask) -> jax.Array:
    """q: (B,S,H,dh), k/v: (B,T,KV,dh), mask: (B,1,S,T) or (1,1,S,T) bool."""
    b, s, h, dh = q.shape
    t = k.shape[1]
    kv = k.shape[2]
    group = h // kv
    qg = q.reshape(b, s, kv, group, dh)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k) * (dh**-0.5)
    scores = scores.astype(jnp.float32)
    scores = jnp.where(mask[:, :, None] if mask.ndim == 4 else mask, scores, NEG_INF)
    softmax = get_softmax(cfg.softmax_impl)
    p = softmax(scores).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", p, v)
    return out.reshape(b, s, h, dh)


def _use_chunked(cfg: ModelConfig, s: int) -> bool:
    """Chunked (flash-style) attention for long sequences — perf B2 (§Perf).

    The one-pass path materializes (S,T) f32 scores; past ~2k tokens that
    dominates the memory roofline.  The chunked path requires the GN or exact
    softmax (baselines are one-pass-only, used in small accuracy studies).
    """
    return s > 2048 and cfg.softmax_impl in ("gn", "exact")


def causal_mask(s: int, t: int, window: int = 0) -> jax.Array:
    """(1, 1, s, t) bool; t >= s (query block is the suffix of the kv span)."""
    rows = jnp.arange(s)[:, None] + (t - s)
    cols = jnp.arange(t)[None, :]
    m = cols <= rows
    if window:
        m &= cols > rows - window
    return m[None, None]


def self_attention(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,  # (B, S, D)
    positions: jax.Array,  # (B, S)
    causal: bool = True,
) -> jax.Array:
    dt = x.dtype
    b, s, d = x.shape
    q = _split_heads(jnp.einsum("bsd,df->bsf", x, p["wq"].astype(dt)), cfg.n_heads, cfg.head_dim)
    k = _split_heads(jnp.einsum("bsd,df->bsf", x, p["wk"].astype(dt)), cfg.n_kv_heads, cfg.head_dim)
    v = _split_heads(jnp.einsum("bsd,df->bsf", x, p["wv"].astype(dt)), cfg.n_kv_heads, cfg.head_dim)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    if cfg.use_pallas:
        from repro.kernels.gn_attention.ops import gn_attention

        interp = jax.devices()[0].platform != "tpu"
        out = gn_attention(
            q.transpose(0, 2, 1, 3),
            k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3),
            causal=causal,
            interpret=interp,
        ).transpose(0, 2, 1, 3)
    elif _use_chunked(cfg, s):
        from repro.models.chunked_attention import chunked_self_attention

        out = chunked_self_attention(cfg, q, k, v, causal)
    else:
        if causal:
            mask = causal_mask(s, s, cfg.sliding_window)
        else:
            mask = jnp.ones((1, 1, s, s), bool)
        out = _sdpa(cfg, q, k, v, mask)
    out = out.reshape(b, s, cfg.q_features)
    return jnp.einsum("bsf,fd->bsd", out, p["wo"].astype(dt))


# ------------------------------------------------------------ cross-attn ---
def cross_attn_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    return {
        "wq": ParamSpec((d, cfg.q_features), ("embed_fsdp", "heads_tp")),
        "wk": ParamSpec((d, cfg.kv_features), ("embed_fsdp", "heads_tp")),
        "wv": ParamSpec((d, cfg.kv_features), ("embed_fsdp", "heads_tp")),
        "wo": ParamSpec((cfg.q_features, d), ("heads_tp", "embed_fsdp")),
    }


def cross_attention(cfg: ModelConfig, p: dict, x, memory) -> jax.Array:
    """x: (B,S,D) queries; memory: (B,M,D) encoder/vision states (no rope)."""
    dt = x.dtype
    b, s, d = x.shape
    m = memory.shape[1]
    q = _split_heads(jnp.einsum("bsd,df->bsf", x, p["wq"].astype(dt)), cfg.n_heads, cfg.head_dim)
    k = _split_heads(jnp.einsum("bmd,df->bmf", memory.astype(dt), p["wk"].astype(dt)), cfg.n_kv_heads, cfg.head_dim)
    v = _split_heads(jnp.einsum("bmd,df->bmf", memory.astype(dt), p["wv"].astype(dt)), cfg.n_kv_heads, cfg.head_dim)
    mask = jnp.ones((1, 1, s, m), bool)
    out = _sdpa(cfg, q, k, v, mask).reshape(b, s, cfg.q_features)
    return jnp.einsum("bsf,fd->bsd", out, p["wo"].astype(dt))


# ----------------------------------------------------------------- decode ---
def attn_cache_shape(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    win = cfg.sliding_window or 0
    slots = min(max_seq, win) if win else max_seq
    kv = (batch, slots, cfg.n_kv_heads, cfg.head_dim)
    return {
        "k": jax.ShapeDtypeStruct(kv, jnp.dtype(cfg.dtype)),
        "v": jax.ShapeDtypeStruct(kv, jnp.dtype(cfg.dtype)),
    }


def attn_prefill(cfg: ModelConfig, p: dict, x, positions):
    """Run self-attention over the prompt AND return the kv cache to reuse."""
    dt = x.dtype
    b, s, _ = x.shape
    k = _split_heads(jnp.einsum("bsd,df->bsf", x, p["wk"].astype(dt)), cfg.n_kv_heads, cfg.head_dim)
    v = _split_heads(jnp.einsum("bsd,df->bsf", x, p["wv"].astype(dt)), cfg.n_kv_heads, cfg.head_dim)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = _split_heads(jnp.einsum("bsd,df->bsf", x, p["wq"].astype(dt)), cfg.n_heads, cfg.head_dim)
    q = apply_rope(q, positions, cfg.rope_theta)
    if _use_chunked(cfg, s):
        from repro.models.chunked_attention import chunked_self_attention

        out = chunked_self_attention(cfg, q, k, v, causal=True).reshape(b, s, cfg.q_features)
    else:
        mask = causal_mask(s, s, cfg.sliding_window)
        out = _sdpa(cfg, q, k, v, mask).reshape(b, s, cfg.q_features)
    out = jnp.einsum("bsf,fd->bsd", out, p["wo"].astype(dt))
    if cfg.sliding_window and s > cfg.sliding_window:
        k = k[:, -cfg.sliding_window :]
        v = v[:, -cfg.sliding_window :]
    return out, {"k": k, "v": v}


def attn_decode_chunk(cfg: ModelConfig, p: dict, cache: dict, x, pos, n_valid):
    """Chunked append-decode: C tokens enter the cache at absolute positions
    [pos, pos+n_valid); lanes >= ``n_valid`` are don't-care (their cache
    writes are dropped via an out-of-bounds scatter index, their outputs are
    garbage the caller ignores).  x: (B,C,D); pos/n_valid: traced scalars.

    This is the chunked-prefill workhorse of the fused serving step: row i
    attends over cache[0 .. pos+i] exactly like ``attn_decode_step`` at
    position pos+i, so streaming a prompt through it chunk-by-chunk writes
    the same cache and logits the monolithic ``attn_prefill`` produces.
    Sliding-window configs keep the ring-buffer layout (writes land at
    (pos+i) % slots) and need chunk <= window.
    """
    dt = x.dtype
    b, c_len = x.shape[:2]
    offs = jnp.arange(c_len)
    rows = pos + offs  # absolute positions, one per chunk lane
    posv = jnp.broadcast_to(rows[None], (b, c_len))
    q = _split_heads(jnp.einsum("bsd,df->bsf", x, p["wq"].astype(dt)), cfg.n_heads, cfg.head_dim)
    k_new = _split_heads(jnp.einsum("bsd,df->bsf", x, p["wk"].astype(dt)), cfg.n_kv_heads, cfg.head_dim)
    v_new = _split_heads(jnp.einsum("bsd,df->bsf", x, p["wv"].astype(dt)), cfg.n_kv_heads, cfg.head_dim)
    q = apply_rope(q, posv, cfg.rope_theta)
    k_new = apply_rope(k_new, posv, cfg.rope_theta)

    slots = cache["k"].shape[1]
    win = cfg.sliding_window or 0
    widx = (rows % slots) if win else rows
    widx = jnp.where(offs < n_valid, widx, slots)  # invalid lanes -> dropped
    k = cache["k"].at[:, widx].set(k_new.astype(cache["k"].dtype), mode="drop")
    v = cache["v"].at[:, widx].set(v_new.astype(cache["v"].dtype), mode="drop")

    idx = jnp.arange(slots)
    if win:
        # A chunk of C writes evicts C ring entries the chunk's *earliest*
        # queries still need, so attend the pre-write ring and the chunk's
        # own k/v side by side instead of the post-write ring.  Pre-write
        # entry j holds absolute position (pos-1) - age_j; it is in row i's
        # window iff it is >= pos+i-win+1 (and exists, >= 0).
        age_old = (((pos - 1) % slots) - idx) % slots  # 0 = newest pre-write
        abs_old = (pos - 1) - age_old  # (slots,)
        valid_old = (abs_old[None, :] >= rows[:, None] - (win - 1)) & (
            abs_old[None, :] >= 0
        )
        valid_new = offs[None, :] <= offs[:, None]  # in-chunk causal (C <= win)
        valid = jnp.concatenate([valid_old, valid_new], axis=1)  # (C, slots+C)
        k_at = jnp.concatenate([cache["k"], k_new.astype(cache["k"].dtype)], axis=1)
        v_at = jnp.concatenate([cache["v"], v_new.astype(cache["v"].dtype)], axis=1)
    else:
        valid = idx[None, :] <= rows[:, None]  # (C, slots)
        k_at, v_at = k, v
    mask = valid[None, None, None]  # broadcast over (b, kv, group)

    kv = cfg.n_kv_heads
    group = cfg.n_heads // kv
    qg = q.reshape(b, c_len, kv, group, cfg.head_dim)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k_at) * (cfg.head_dim**-0.5)
    scores = jnp.where(mask, scores.astype(jnp.float32), NEG_INF)
    from repro.core import get_softmax

    pmat = get_softmax(cfg.softmax_impl)(scores).astype(v_at.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", pmat, v_at).reshape(b, c_len, cfg.q_features)
    out = jnp.einsum("bsf,fd->bsd", out, p["wo"].astype(dt))
    return out, {"k": k, "v": v}


# Test/bench override for the paged read dispatch below: None (auto) or one
# of 'pallas' / 'streamed' / 'gathered'.  The gathered path materializes the
# full logical stream and exists as the identity oracle the streamed paths
# are pinned against (tests/test_serve_paged.py) — the serving tick itself
# never takes it unless forced or running an exotic baseline softmax.
FORCE_PAGED_READ: str | None = None

# GN sentinel: any per-block dequantization scale above this is treated as
# corrupt by the in-tick scale-sanity probe.  Legitimate scales are
# QUANT_MARGIN * amax / 127 — O(activation magnitude / 60) — so the ceiling
# has orders of magnitude of headroom; only a scribbled/overflowed scale
# leaf can cross it.
SCALE_SANITY_MAX = 1e4


def _probe_sum_residual(pmat, scores, out, valid, lane_ok):
    """GN sentinel channel 0, per slot: the Σp residual of this layer's
    paged attention read, with nonfinite laundering ruled out.

    pmat/scores: (N, *head_axes, C, T); valid: (N, C, T) causal-column
    mask; lane_ok: (N, C) live-lane mask; out: (N, C, ...) the attention
    output (pre-wo).  Returns (N,) f32: max over the slot's live lanes of
    |Σp − 1| — the paper's guaranteed-normalization residual, analytically
    bounded by (t+1)·2⁻²³ for the GN softmax — forced to +inf when any
    live-region score or output element is nonfinite.  The explicit
    finiteness channels matter: GN's snap-to-grid exp *launders* NaN scores
    into a valid (finite, Σp = 1) distribution, so a poisoned KV block is
    invisible to the residual alone; NaN K surfaces in ``scores``, NaN V in
    ``out``."""
    n = pmat.shape[0]
    heads = pmat.ndim - 3
    lane = lane_ok.reshape(n, *([1] * heads), -1)
    v = valid.reshape(n, *([1] * heads), valid.shape[1], valid.shape[2])
    sumres = jnp.abs(jnp.sum(pmat.astype(jnp.float32), axis=-1) - 1.0)
    res = jnp.max(jnp.where(lane, sumres, 0.0), axis=tuple(range(1, 2 + heads)))
    bad = (~jnp.isfinite(scores)) & v & lane[..., None]
    bad = jnp.any(bad, axis=tuple(range(1, 3 + heads)))
    oflat = out.astype(jnp.float32).reshape(n, out.shape[1], -1)
    obad = jnp.any((~jnp.isfinite(oflat)) & lane_ok[:, :, None], axis=(1, 2))
    return jnp.where(bad | obad, jnp.inf, res)


def paged_probe_word(probe0, positions, n_valid, tables, block_size: int,
                     rd_scales, clip_tok):
    """Assemble one layer's (N, 3) sentinel health word.

    Channels: [0] the Σp/finiteness residual from ``_probe_sum_residual``
    (+inf on any nonfinite live value); [1] the fraction of this tick's
    int8 writes that saturated (freeze-at-first-write scales clip, never
    rescale — persistent clipping means the block's frozen scale no longer
    covers the stream and is the engine's cue for int8→fp fallback); [2] a
    scale-sanity flag over the slot's live-horizon per-block scales
    (nonfinite, negative, or > SCALE_SANITY_MAX ⇒ corrupt scale leaf).
    Parked lanes (n_valid == 0) read stale arena content by design, so
    every channel is zeroed for them — health is only meaningful for live
    slots."""
    n = positions.shape[0]
    active = n_valid > 0
    if clip_tok is not None:
        c_len = clip_tok.shape[0] // n
        lane_ok = jnp.arange(c_len)[None, :] < n_valid[:, None]
        ct = clip_tok.reshape(n, c_len)
        clip = (jnp.sum(jnp.where(lane_ok, ct, False).astype(jnp.float32), axis=1)
                / jnp.maximum(n_valid, 1).astype(jnp.float32))
    else:
        clip = jnp.zeros((n,), jnp.float32)
    if rd_scales is not None:
        h = tables.shape[1]
        max_blk = (positions + jnp.maximum(n_valid, 1) - 1) // block_size
        blk_ok = jnp.arange(h)[None, :] <= max_blk[:, None]
        sbad = jnp.zeros((n,), bool)
        for s in rd_scales:
            s_at = s[tables]  # (N, H) — tiny, horizon-bounded
            bad = (~jnp.isfinite(s_at)) | (s_at < 0) | (s_at > SCALE_SANITY_MAX)
            sbad = sbad | jnp.any(bad & blk_ok, axis=1)
        scalebad = sbad.astype(jnp.float32)
    else:
        scalebad = jnp.zeros((n,), jnp.float32)
    zero = jnp.zeros((n,), jnp.float32)
    return jnp.stack([
        jnp.where(active, probe0, zero),
        jnp.where(active, clip, zero),
        jnp.where(active, scalebad, zero),
    ], axis=1)


# Headroom multiplier on the first-write per-block amax: a block's scale is
# set once, from the first token written into it, and later appends to the
# same block saturate (clip to ±127) rather than rescale — rescaling would
# rewrite already-quantized history and break the bitwise COW/spill/restore
# contract.  The margin absorbs later-token amax drift within a block; the
# GN softmax bounds whatever error saturation leaves (masked numerators are
# exactly zero and Σp = 1 holds over any numerator perturbation).
QUANT_MARGIN = 2.0


def paged_quant_write(flat_arena, scale, new_vals, dest, block_size: int,
                      return_clip: bool = False):
    """Freeze-at-first-write int8 block scatter.

    flat_arena: (nb*bs, ...) int8; scale: (nb,) f32 per-block scales;
    new_vals: (n_tok, ...) fp values for destinations ``dest`` ((n_tok,)
    flattened arena indices, invalid lanes >= nb*bs and dropped).  Returns
    (new flat_arena, new scale) — plus, with ``return_clip``, an (n_tok,)
    bool of which writes saturated the ±127 range (the sentinel's
    clip-fraction channel; frozen scales clip rather than rescale, so
    persistent clipping is a live overflow signal, not a transient).

    Scale discipline: appends are strictly in-order, so the first write any
    tenant makes to a physical block lands at in-block offset 0 — that write
    (re)sets the block's scale from the tick's per-block amax (with
    ``QUANT_MARGIN`` headroom), which also makes recycled blocks safe
    without zeroing: the new tenant's offset-0 write overwrites the stale
    scale.  Every other write reuses the frozen scale and saturates.  A
    COW-forked partial block keeps its donor's frozen scale (the fork
    resumes mid-block, offset > 0), so the shared quantized prefix stays
    bitwise identical through the fork."""
    nb = scale.shape[0]
    blk = dest // block_size  # invalid lanes -> nb, dropped by the scatters
    red = tuple(range(1, new_vals.ndim))
    amax = jnp.max(jnp.abs(new_vals.astype(jnp.float32)), axis=red)  # (n_tok,)
    blk_amax = jnp.zeros((nb,), jnp.float32).at[blk].max(amax, mode="drop")
    first = jnp.zeros((nb,), jnp.int32).at[blk].max(
        (dest % block_size == 0).astype(jnp.int32), mode="drop"
    ) > 0
    scale = jnp.where(first, QUANT_MARGIN * blk_amax / 127.0, scale)
    s_tok = jnp.take(scale, jnp.minimum(blk, nb - 1))  # (n_tok,)
    denom = jnp.where(s_tok > 0, s_tok, 1.0).reshape(
        (new_vals.shape[0],) + (1,) * (new_vals.ndim - 1)
    )
    q_f = jnp.round(new_vals.astype(jnp.float32) / denom)
    q = jnp.clip(q_f, -127.0, 127.0).astype(jnp.int8)
    out = flat_arena.at[dest].set(q, mode="drop")
    if return_clip:
        return out, scale, jnp.any(jnp.abs(q_f) > 127.0, axis=red)
    return out, scale


def paged_read_path(cfg: ModelConfig) -> str:
    """Which paged attention read the serving tick uses for dense KV:
    'pallas' (TPU kernel, online GN accumulation), 'streamed' (lax.scan
    over block tiles emitting score tiles — bitwise equal to the gathered
    read without materializing the K stream), or 'gathered' (full-stream
    materialization; baselines-only oracle)."""
    if FORCE_PAGED_READ is not None:
        return FORCE_PAGED_READ
    if cfg.use_pallas:
        return "pallas"
    # the online accumulation needs a streaming-stable softmax: GN (snap-to-
    # Δ-grid LUT exp) or the exact float path; one-pass-only baselines fall
    # back to the gathered oracle
    return "streamed" if cfg.softmax_impl in ("gn", "exact") else "gathered"


def _stream_paged_tiles(cfg: ModelConfig, qg, arena_k, arena_v, tables, rows,
                        scales=None, probe_nv=None):
    """Gather-free dense paged read: lax.scan over block tiles.

    qg: (N, C, KV, G, dh) in activation dtype; arena_k/arena_v:
    (nb, bs, KV, dh) in cache dtype; tables: (N, H) physical block ids
    (H = the tick's block horizon — compute and HBM traffic scale with live
    context, not max_seq); rows: (N, C) absolute positions.
    Returns (N, C, KV, G, dh) in activation dtype.

    The k scan emits one (.., C, bs) *score* tile per block — each score
    element is an independent dh-dot of the same operands the gathered read
    contracts, so the stacked score row is **bitwise identical** to the
    gathered read's, column for column, without ever materializing the
    gathered K stream.  The one-pass GN softmax then runs on that row
    exactly as in the gathered path (identical probabilities, identical
    Σp = 1 guarantee: masked columns — every stale/foreign table entry
    included — get exactly-zero numerators), and the weighted-value
    contraction is the gathered path's own einsum over the horizon-bounded
    V blocks — so the whole read is **bitwise identical** to the gathered
    oracle while halving the stream materialization and bounding it by the
    live horizon.  (One big AV contraction beats a per-tile value scan on
    every backend tried; the Pallas kernel is the truly stream-resident
    form — single-pass online (m, l, acc) state, LUT'd corrections,
    nothing materialized — equivalent up to LUT-entry rounding.)
    """
    bs = arena_k.shape[1]
    scale = cfg.head_dim**-0.5
    dt = qg.dtype
    k_scale, v_scale = scales if scales is not None else (None, None)
    tbls = jnp.moveaxis(tables, 1, 0)  # (H, N)
    # unroll a constant factor only: full unrolling would make trace/HLO
    # size linear in the top horizon bucket (512 tiles at max_seq 4096 /
    # block 8), exactly the compile blow-up horizon bucketing exists to cap

    def k_body(_, tbl_j):  # tbl_j: (N,) physical block id of logical j
        k_c = arena_k[tbl_j]  # (N, bs, KV, dh)
        if k_scale is not None:
            # dequantize strictly AFTER the per-tile gather: the stream-
            # sized object stays int8, only one (N, bs, KV, dh) tile is
            # ever fp-resident
            k_c = k_c.astype(dt) * k_scale[tbl_j].astype(dt)[:, None, None, None]
        return None, jnp.einsum("bskgd,btkd->bkgst", qg, k_c) * scale

    _, s_tiles = jax.lax.scan(k_body, None, tbls, unroll=8)  # (H, N, KV, G, C, bs)
    scores = jnp.moveaxis(s_tiles, 0, 4)  # (N, KV, G, C, H, bs)
    scores = scores.reshape(*scores.shape[:4], -1)  # logical column order

    t = scores.shape[-1]  # horizon * bs, tail masked below
    valid = jnp.arange(t)[None, None, :] <= rows[:, :, None]  # (N, C, T)
    scores = jnp.where(valid[:, None, None], scores.astype(jnp.float32), NEG_INF)
    from repro.core import get_softmax

    n = rows.shape[0]
    kv, dh = arena_v.shape[2], arena_v.shape[3]
    v_at = arena_v[tables]  # horizon-bounded V blocks (N, H, bs, KV, dh)
    if v_scale is not None:
        # int8 blocks gathered first, dequantized per block after the gather
        v_at = v_at.astype(dt) * v_scale[tables].astype(dt)[..., None, None, None]
    v_at = v_at.reshape(n, -1, kv, dh)
    pmat = get_softmax(cfg.softmax_impl)(scores).astype(v_at.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", pmat, v_at)
    if probe_nv is not None:
        lane_ok = jnp.arange(rows.shape[1])[None, :] < probe_nv[:, None]
        return out, _probe_sum_residual(pmat, scores, out, valid, lane_ok)
    return out


def attn_paged_chunk(cfg: ModelConfig, p: dict, arena_k, arena_v, x, positions,
                     n_valid, tables, scales=None, probe=False):
    """Block-paged chunked append-decode, batched over slots.

    The slot-monolithic ``attn_decode_chunk`` owns a (max_seq,) slab per
    sequence; here every sequence owns only a *block table* into a shared KV
    arena, so resident HBM scales with live tokens instead of worst-case
    length.  x: (N, C, D); positions/n_valid: (N,) int32 per-slot vectors;
    tables: (N, max_bt) int32 physical-block ids per logical block — the
    engine passes a *horizon-sliced* table (max_bt = the tick's bucketed
    block horizon), so per-tick attention work is bounded by live context;
    arena_k/arena_v: (num_blocks, block_size, KV, dh).

    Lane (s, i) writes absolute position positions[s]+i through the table
    (lanes >= n_valid[s] scatter out of bounds and are dropped — n_valid=0
    drops a whole slot, which is how inactive lanes are kept away from
    blocks they don't own) and attends the logical stream
    [0, positions[s]+i].  Table entries past a slot's allocated prefix may
    point at recycled or foreign blocks: every such column sits beyond the
    causal mask, and the GN softmax turns masked scores into *exactly zero*
    numerators (LUT saturation), so stale block contents cannot leak into
    either the weighted sum or the normalizer — Σp = 1 over the same score
    multiset as the slab path, independent of block layout.

    The read itself is dispatched by ``paged_read_path``: the Pallas kernel
    (TPU; chunked queries included), the streamed block-tile scan (CPU/GPU
    default — bitwise equal to the gathered read, K stream never
    materialized), or the gathered oracle (baselines/tests only).

    ``scales=(k_scale, v_scale)`` ((num_blocks,) f32 each) switches the
    arenas to int8 with per-block dequantization scales: writes quantize
    through ``paged_quant_write`` (freeze-at-first-write) and every read
    path dequantizes strictly *after* its per-block/per-tile gather, so the
    fp stream is never materialized at arena width.  The GN LUT-saturation
    guarantee is what makes this safe: Σp = 1 holds over the dequantized
    numerators exactly as over the fp ones.

    Returns (out (N, C, D), (new arena_k, new arena_v)) — plus
    (new k_scale, new v_scale) appended when ``scales`` is given.

    ``probe=True`` (a static Python bool — the engine binds it as a closure
    constant, so it adds no trace keys) appends a third return: this
    layer's (N, 3) GN sentinel health word (see ``paged_probe_word``).  The
    streamed and gathered reads compute the full Σp-residual/finiteness
    probe from their materialized score rows; the Pallas kernel keeps its
    probabilities in-kernel, so its probe is reduced to output finiteness
    (documented coverage gap: NaN-K laundering is only certified on the
    streamed/gathered paths — the CPU/GPU default and the CI path).
    """
    dt = x.dtype
    b, c_len = x.shape[:2]
    nb, bs = arena_k.shape[:2]
    offs = jnp.arange(c_len)
    rows = positions[:, None] + offs[None, :]  # (N, C) absolute positions
    q = _split_heads(jnp.einsum("bsd,df->bsf", x, p["wq"].astype(dt)), cfg.n_heads, cfg.head_dim)
    k_new = _split_heads(jnp.einsum("bsd,df->bsf", x, p["wk"].astype(dt)), cfg.n_kv_heads, cfg.head_dim)
    v_new = _split_heads(jnp.einsum("bsd,df->bsf", x, p["wv"].astype(dt)), cfg.n_kv_heads, cfg.head_dim)
    q = apply_rope(q, rows, cfg.rope_theta)
    k_new = apply_rope(k_new, rows, cfg.rope_theta)

    dest = paged_write_indices(rows, n_valid, tables, bs, nb)
    kv, dh = cfg.n_kv_heads, cfg.head_dim
    flat_k = arena_k.reshape(nb * bs, kv, dh)
    flat_v = arena_v.reshape(nb * bs, kv, dh)
    clip_tok = None
    if scales is not None:
        k_scale, v_scale = scales
        if probe:
            flat_k, k_scale, kclip = paged_quant_write(
                flat_k, k_scale, k_new.reshape(b * c_len, kv, dh), dest, bs,
                return_clip=True)
            flat_v, v_scale, vclip = paged_quant_write(
                flat_v, v_scale, v_new.reshape(b * c_len, kv, dh), dest, bs,
                return_clip=True)
            clip_tok = kclip | vclip
        else:
            flat_k, k_scale = paged_quant_write(
                flat_k, k_scale, k_new.reshape(b * c_len, kv, dh), dest, bs)
            flat_v, v_scale = paged_quant_write(
                flat_v, v_scale, v_new.reshape(b * c_len, kv, dh), dest, bs)
        arenas = (flat_k.reshape(arena_k.shape), flat_v.reshape(arena_v.shape),
                  k_scale, v_scale)
        rd_scales = (k_scale, v_scale)
    else:
        flat_k = flat_k.at[dest].set(k_new.reshape(b * c_len, kv, dh).astype(flat_k.dtype), mode="drop")
        flat_v = flat_v.at[dest].set(v_new.reshape(b * c_len, kv, dh).astype(flat_v.dtype), mode="drop")
        arenas = (flat_k.reshape(arena_k.shape), flat_v.reshape(arena_v.shape))
        rd_scales = None

    path = paged_read_path(cfg)
    group = cfg.n_heads // kv
    if path == "pallas":
        # single-chip TPU hot path: the Pallas kernel chases the block table
        # with scalar-prefetched index maps instead of materializing the
        # gathered stream (interpret-mode on CPU); same GN datapath, tiled.
        # Chunked queries ride the same kernel (causal intra-chunk mask);
        # int8 arenas dequantize in-kernel, per block, after the DMA.
        from repro.kernels.gn_paged_attention.ops import gn_paged_attention_chunk

        interp = jax.devices()[0].platform != "tpu"
        out = gn_paged_attention_chunk(
            q,
            flat_k.reshape(nb, bs, kv, dh),
            flat_v.reshape(nb, bs, kv, dh),
            tables,
            positions,
            n_valid,
            interpret=interp,
            scales=rd_scales,
        ).reshape(b, c_len, cfg.q_features)
        if probe:
            # reduced probe: probabilities stay in-kernel, so only output
            # finiteness is observable here (see docstring)
            lane_ok = jnp.arange(c_len)[None, :] < n_valid[:, None]
            obad = jnp.any(
                (~jnp.isfinite(out.astype(jnp.float32))) & lane_ok[:, :, None],
                axis=(1, 2),
            )
            probe0 = jnp.where(obad, jnp.inf, 0.0)
        out = jnp.einsum("bsf,fd->bsd", out.astype(dt), p["wo"].astype(dt))
        if probe:
            return out, arenas, paged_probe_word(
                probe0, positions, n_valid, tables, bs, rd_scales, clip_tok)
        return out, arenas

    if path == "streamed":
        qg = q.reshape(b, c_len, kv, group, dh)
        res = _stream_paged_tiles(
            cfg, qg,
            flat_k.reshape(nb, bs, kv, dh), flat_v.reshape(nb, bs, kv, dh),
            tables, rows, scales=rd_scales,
            probe_nv=n_valid if probe else None,
        )
        if probe:
            res, probe0 = res
        out = res.reshape(b, c_len, cfg.q_features)
        out = jnp.einsum("bsf,fd->bsd", out.astype(dt), p["wo"].astype(dt))
        if probe:
            return out, arenas, paged_probe_word(
                probe0, positions, n_valid, tables, bs, rd_scales, clip_tok)
        return out, arenas

    # gathered oracle: materialize each slot's logical KV stream (post-write,
    # so the chunk's own keys are already in place — no side concat needed).
    # Tests pin the streamed paths against this; the tick never runs it
    # unless forced or serving a one-pass-only baseline softmax.  Quantized
    # arenas gather int8 blocks first and dequantize the gathered stream —
    # the oracle is allowed its materialization.
    k_at = flat_k.reshape(nb, bs, kv, dh)[tables]
    v_at = flat_v.reshape(nb, bs, kv, dh)[tables]
    if rd_scales is not None:
        k_at = k_at.astype(dt) * k_scale[tables].astype(dt)[..., None, None, None]
        v_at = v_at.astype(dt) * v_scale[tables].astype(dt)[..., None, None, None]
    k_at = k_at.reshape(b, -1, kv, dh)
    v_at = v_at.reshape(b, -1, kv, dh)
    t = k_at.shape[1]  # horizon * bs, tail masked below

    valid = jnp.arange(t)[None, None, :] <= rows[:, :, None]  # (N, C, T)
    mask = valid[:, None, None]  # broadcast over (kv, group)

    qg = q.reshape(b, c_len, kv, group, cfg.head_dim)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k_at) * (cfg.head_dim**-0.5)
    scores = jnp.where(mask, scores.astype(jnp.float32), NEG_INF)
    from repro.core import get_softmax

    pmat = get_softmax(cfg.softmax_impl)(scores).astype(v_at.dtype)
    att = jnp.einsum("bkgst,btkd->bskgd", pmat, v_at)
    out = jnp.einsum("bsf,fd->bsd", att.reshape(b, c_len, cfg.q_features),
                     p["wo"].astype(dt))
    if probe:
        lane_ok = jnp.arange(c_len)[None, :] < n_valid[:, None]
        probe0 = _probe_sum_residual(pmat, scores, att, valid, lane_ok)
        return out, arenas, paged_probe_word(
            probe0, positions, n_valid, tables, bs, rd_scales, clip_tok)
    return out, arenas


def paged_write_indices(rows, n_valid, tables, block_size: int, num_blocks: int):
    """Flattened arena destinations for a (N, C) grid of absolute positions:
    physical = table[row // bs] * bs + row % bs, with lanes >= n_valid sent
    out of bounds (num_blocks * bs) so `.at[].set(mode='drop')` discards
    them.  Shared by the dense and MLA paged writers."""
    n, c_len = rows.shape
    log_blk = rows // block_size
    phys = jnp.take_along_axis(tables, log_blk, axis=1)  # (N, C)
    dest = phys * block_size + rows % block_size
    lane_ok = jnp.arange(c_len)[None, :] < n_valid[:, None]
    return jnp.where(lane_ok, dest, num_blocks * block_size).reshape(-1)


def attn_decode_step(cfg: ModelConfig, p: dict, cache: dict, x, pos):
    """One-token decode.  x: (B,1,D); pos: scalar int32 (current position).

    Full-attention: cache slot ``pos`` is written.  Sliding window: ring
    buffer slot ``pos % window`` (sub-quadratic memory, the mixtral path).
    """
    dt = x.dtype
    b = x.shape[0]
    q = _split_heads(jnp.einsum("bsd,df->bsf", x, p["wq"].astype(dt)), cfg.n_heads, cfg.head_dim)
    k_new = _split_heads(jnp.einsum("bsd,df->bsf", x, p["wk"].astype(dt)), cfg.n_kv_heads, cfg.head_dim)
    v_new = _split_heads(jnp.einsum("bsd,df->bsf", x, p["wv"].astype(dt)), cfg.n_kv_heads, cfg.head_dim)
    posv = jnp.full((b, 1), pos, jnp.int32)
    q = apply_rope(q, posv, cfg.rope_theta)
    k_new = apply_rope(k_new, posv, cfg.rope_theta)

    slots = cache["k"].shape[1]
    win = cfg.sliding_window or 0
    slot = (pos % slots) if win else pos
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype), (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype), (0, slot, 0, 0))

    idx = jnp.arange(slots)
    if win:
        # ring buffer: slot i holds absolute position  i + floor((pos-i)/slots)*slots
        age = (slot - idx) % slots  # 0 = newest
        valid = (age < win) & (age <= pos)
    else:
        valid = idx <= pos
    mask = valid[None, None, None, :]  # (1,1,1,slots)

    kv = cfg.n_kv_heads
    group = cfg.n_heads // kv
    qg = q.reshape(b, 1, kv, group, cfg.head_dim)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k) * (cfg.head_dim**-0.5)
    scores = jnp.where(mask[:, :, None], scores.astype(jnp.float32), NEG_INF)
    from repro.core import get_softmax

    pmat = get_softmax(cfg.softmax_impl)(scores).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", pmat, v).reshape(b, 1, cfg.q_features)
    out = jnp.einsum("bsf,fd->bsd", out, p["wo"].astype(dt))
    return out, {"k": k, "v": v}
