"""Model assembly: all 10 assigned families behind one functional API.

``Model(cfg)`` exposes:
  * ``param_specs()`` / ``init(key)``           — ParamSpec tree / materialized params
  * ``loss(params, batch)`` / ``forward``       — training path (scan over layers)
  * ``cache_specs`` / ``init_cache``            — decode-cache ShapeDtypeStructs
  * ``prefill(params, batch, max_seq)``         — prompt pass, returns (logits, cache)
  * ``decode_step(params, cache, token, pos)``  — one-token serve step

Families:
  dense (GQA/MHA/MLA)   — standard pre-norm residual blocks
  moe                   — dense attention + GShard top-k MoE MLP
  ssm                   — mLSTM (xLSTM) blocks, no separate MLP
  hybrid                — Zamba2: groups of Mamba2 layers, each group preceded
                          by ONE weight-shared attention block (its KV cache is
                          per-application); grouped two-level scan
  encdec                — Whisper: encoder over stub frame embeddings, decoder
                          with self+cross attention
  vlm                   — Llama-3.2-V: gated cross-attention every K layers
                          over stub patch embeddings; grouped two-level scan

Layers are scanned (stacked params) so HLO size is depth-independent; remat
policy per config.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import get_norm
from repro.models import attention as attn
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    ParamSpec,
    apply_mlp,
    apply_norm,
    embed_specs,
    init_tree,
    lm_head_specs,
    mlp_specs,
    norm_specs,
    spec_struct,
    stack_specs,
)
from repro.parallel.sharding import shard


def _remat(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def _lm_head(cfg, params, x):
    x = apply_norm(cfg, params["final_norm"], x)
    logits = jnp.einsum(
        "bsd,dv->bsv", x, params["lm_head"]["w"].astype(x.dtype)
    )
    return shard(logits, "batch", "seq", "vocab")


def _chunk_head(cfg, params, x, n_valid, last_only):
    """LM head for a chunk step: project all C rows, or (last_only) just the
    next-token row n_valid-1 — per-row matmuls make the gather bit-exact."""
    if last_only:
        x = jax.lax.dynamic_slice_in_dim(x, n_valid - 1, 1, axis=1)
    return _lm_head(cfg, params, x)


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        if cfg.family == "hybrid":
            assert cfg.n_layers % cfg.attn_every == 0, "hybrid needs L % cadence == 0"
        if cfg.family == "vlm":
            assert cfg.n_layers % cfg.cross_attn_every == 0

    # ------------------------------------------------------------- specs ---
    def _mixer_specs(self) -> dict:
        cfg = self.cfg
        if cfg.mla is not None:
            return mla_mod.mla_specs(cfg)
        if cfg.family == "ssm":
            return (
                ssm_mod.mlstm_specs(cfg)
                if cfg.ssm.kind == "mlstm"
                else ssm_mod.mamba2_specs(cfg)
            )
        if cfg.family == "hybrid":
            return ssm_mod.mamba2_specs(cfg)
        return attn.attn_specs(cfg)

    def _block_specs(self) -> dict:
        cfg = self.cfg
        specs: dict[str, Any] = {"ln1": norm_specs(cfg), "mixer": self._mixer_specs()}
        if cfg.family in ("ssm", "hybrid"):
            return specs  # these archs carry no separate MLP (d_ff folded in)
        specs["ln2"] = norm_specs(cfg)
        specs["mlp"] = moe_mod.moe_specs(cfg) if cfg.moe else mlp_specs(cfg)
        return specs

    def _attn_block_specs(self) -> dict:
        cfg = self.cfg
        return {
            "ln1": norm_specs(cfg),
            "attn": attn.attn_specs(cfg),
            "ln2": norm_specs(cfg),
            "mlp": mlp_specs(cfg),
        }

    def param_specs(self) -> dict:
        cfg = self.cfg
        specs: dict[str, Any] = {
            "embed": embed_specs(cfg),
            "layers": stack_specs(self._block_specs(), cfg.n_layers, "layers"),
            "final_norm": norm_specs(cfg),
            "lm_head": lm_head_specs(cfg),
        }
        if cfg.family == "hybrid":
            specs["shared_attn"] = self._attn_block_specs()  # ONE shared block
        if cfg.family == "encdec":
            specs["encoder"] = {
                "layers": stack_specs(self._attn_block_specs(), cfg.encoder_layers, "layers"),
                "pos": ParamSpec((cfg.encoder_seq, cfg.d_model), (None, "embed_fsdp")),
                "final_norm": norm_specs(cfg),
            }
            dec_block = dict(self._block_specs())
            dec_block["ln_x"] = norm_specs(cfg)
            dec_block["xattn"] = attn.cross_attn_specs(cfg)
            specs["layers"] = stack_specs(dec_block, cfg.n_layers, "layers")
        if cfg.family == "vlm":
            n_x = cfg.n_layers // cfg.cross_attn_every
            xblock = {
                "ln": norm_specs(cfg),
                "xattn": attn.cross_attn_specs(cfg),
                "gate": ParamSpec((1,), (None,), init="zeros"),
                "vis_proj": ParamSpec((cfg.d_model, cfg.d_model), ("embed_fsdp", None)),
            }
            specs["xattn_layers"] = stack_specs(xblock, n_x, "layers")
        return specs

    def init(self, key) -> dict:
        return init_tree(key, self.param_specs())

    def param_structs(self) -> dict:
        return spec_struct(self.param_specs())

    # ------------------------------------------------------ train blocks ---
    def _mixer_train(self, lp, h, positions):
        cfg = self.cfg
        if cfg.mla is not None:
            return mla_mod.mla_self_attention(cfg, lp["mixer"], h, positions)
        if cfg.family == "ssm":
            blk = ssm_mod.mlstm_block if cfg.ssm.kind == "mlstm" else ssm_mod.mamba2_block
            y, _ = blk(cfg, lp["mixer"], h)
            return y
        if cfg.family == "hybrid":
            y, _ = ssm_mod.mamba2_block(cfg, lp["mixer"], h)
            return y
        return attn.self_attention(cfg, lp["mixer"], h, positions)

    def _block_train(self, lp, x, positions, memory=None):
        cfg = self.cfg
        x = shard(x, "batch", "seq", "embed_act")
        aux = {}
        x = x + self._mixer_train(lp, apply_norm(cfg, lp["ln1"], x), positions)
        if memory is not None and "xattn" in lp:
            hx = apply_norm(cfg, lp["ln_x"], x)
            x = x + attn.cross_attention(cfg, lp["xattn"], hx, memory)
        if "mlp" in lp:
            h2 = apply_norm(cfg, lp["ln2"], x)
            if cfg.moe:
                y, aux = moe_mod.apply_moe(cfg, lp["mlp"], h2)
            else:
                y = apply_mlp(cfg, lp["mlp"], h2)
            x = x + y
        return shard(x, "batch", "seq", "embed_act"), aux

    def _attn_block_train(self, sp, x, positions, causal=True):
        cfg = self.cfg
        h = apply_norm(cfg, sp["ln1"], x)
        x = x + attn.self_attention(cfg, sp["attn"], h, positions, causal=causal)
        h = apply_norm(cfg, sp["ln2"], x)
        return x + apply_mlp(cfg, sp["mlp"], h)

    def _xattn_block(self, xp, x, patches):
        cfg = self.cfg
        mem = jnp.einsum(
            "bmd,de->bme", patches.astype(x.dtype), xp["vis_proj"].astype(x.dtype)
        )
        h = apply_norm(cfg, xp["ln"], x)
        y = attn.cross_attention(cfg, xp["xattn"], h, mem)
        return x + jnp.tanh(xp["gate"].astype(x.dtype)) * y

    def _group_tree(self, tree, n_groups):
        return jax.tree.map(lambda a: a.reshape(n_groups, -1, *a.shape[1:]), tree)

    def _run_layers_train(self, params, x, positions, memory=None):
        """Returns (x, aux_sums).  Handles plain / hybrid / vlm groupings."""
        cfg = self.cfg

        if cfg.family == "hybrid":
            g = cfg.n_layers // cfg.attn_every
            layers = self._group_tree(params["layers"], g)
            shared = params["shared_attn"]

            def group_body(x, gp):
                x = self._attn_block_train(shared, x, positions)

                def inner(x2, lp):
                    x2, _ = self._block_train(lp, x2, positions)
                    return x2, None

                x, _ = jax.lax.scan(_remat(cfg, inner), x, gp)
                return x, None

            x, _ = jax.lax.scan(group_body, x, layers)
            return x, jnp.zeros((2,))

        if cfg.family == "vlm":
            g = cfg.n_layers // cfg.cross_attn_every
            layers = self._group_tree(params["layers"], g)

            def group_body(x, scanned):
                gp, xp = scanned
                x = self._xattn_block(xp, x, memory)

                def inner(x2, lp):
                    x2, _ = self._block_train(lp, x2, positions)
                    return x2, None

                x, _ = jax.lax.scan(_remat(cfg, inner), x, gp)
                return x, None

            x, _ = jax.lax.scan(group_body, x, (layers, params["xattn_layers"]))
            return x, jnp.zeros((2,))

        def body(x, lp):
            x, aux = self._block_train(lp, x, positions, memory=memory)
            aux_vec = jnp.stack(
                [
                    jnp.asarray(aux.get("load_balance", 0.0), jnp.float32),
                    jnp.asarray(aux.get("router_z", 0.0), jnp.float32),
                ]
            )
            return x, aux_vec

        x, auxs = jax.lax.scan(_remat(cfg, body), x, params["layers"])
        return x, jnp.sum(auxs, 0)

    def _encode(self, params, frames):
        """Whisper encoder over stub frame embeddings (B, M, D)."""
        cfg = self.cfg
        enc = params["encoder"]
        dt = jnp.dtype(cfg.dtype)
        x = frames.astype(dt) + enc["pos"][None].astype(dt)
        positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])

        def body(x, lp):
            return self._attn_block_train(lp, x, positions, causal=False), None

        x, _ = jax.lax.scan(_remat(cfg, body), x, enc["layers"])
        return apply_norm(cfg, enc["final_norm"], x)

    # ----------------------------------------------------------- forward ---
    def forward(self, params, batch: dict):
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        tokens = batch["tokens"]
        x = params["embed"]["tok"].astype(dt)[tokens]
        x = shard(x, "batch", "seq", "embed_act")
        positions = jnp.broadcast_to(jnp.arange(tokens.shape[1]), tokens.shape)

        memory = None
        if cfg.family == "encdec":
            memory = self._encode(params, batch["frames"])
        elif cfg.family == "vlm":
            memory = batch["patches"]
        x, aux = self._run_layers_train(params, x, positions, memory=memory)
        logits = _lm_head(cfg, params, x)
        return logits, {"load_balance": aux[0], "router_z": aux[1]}

    def loss(self, params, batch: dict):
        cfg = self.cfg
        logits, aux = self.forward(params, batch)
        logits = logits.astype(jnp.float32)
        targets = batch["tokens"][:, 1:]
        logits = logits[:, :-1]
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
        loss = jnp.mean(nll)
        metrics = {"nll": loss}
        if cfg.moe is not None:
            loss = loss + 0.01 * aux["load_balance"] / cfg.n_layers
            loss = loss + 1e-3 * aux["router_z"] / cfg.n_layers
            metrics.update(aux)
        return loss, metrics

    # ------------------------------------------------------------- cache ---
    def _ssm_cache_tuple(self, batch):
        cfg = self.cfg
        if cfg.family == "ssm" and cfg.ssm.kind == "mlstm":
            return ssm_mod.mlstm_cache_shape(cfg, batch)
        return ssm_mod.mamba2_cache_shape(cfg, batch)

    def cache_specs(self, batch: int, max_seq: int):
        cfg = self.cfg
        L = cfg.n_layers

        def stack(tree, n=L):
            return jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((n, *s.shape), s.dtype), tree
            )

        if cfg.mla is not None:
            cache = {"layers": stack(mla_mod.mla_cache_shape(cfg, batch, max_seq))}
        elif cfg.family == "ssm":
            cache = {"layers": stack({i: s for i, s in enumerate(self._ssm_cache_tuple(batch))})}
        elif cfg.family == "hybrid":
            g = L // cfg.attn_every
            cache = {
                "layers": stack({i: s for i, s in enumerate(self._ssm_cache_tuple(batch))}),
                "shared": stack(attn.attn_cache_shape(cfg, batch, max_seq), n=g),
            }
        else:
            cache = {"layers": stack(attn.attn_cache_shape(cfg, batch, max_seq))}
        if cfg.family == "encdec":
            kvshape = (L, batch, cfg.encoder_seq, cfg.n_kv_heads, cfg.head_dim)
            cache["cross"] = {
                "k": jax.ShapeDtypeStruct(kvshape, jnp.dtype(cfg.dtype)),
                "v": jax.ShapeDtypeStruct(kvshape, jnp.dtype(cfg.dtype)),
            }
        if cfg.family == "vlm":
            cache["patches"] = jax.ShapeDtypeStruct(
                (batch, cfg.num_patches, cfg.d_model), jnp.dtype(cfg.dtype)
            )
        return cache

    def cache_logical_axes(self):
        """Logical sharding axes tree, parallel to cache_specs (see
        parallel/sharding.py for the rules; 'kv_seq' switches to SP for
        long-context decode)."""
        cfg = self.cfg
        if cfg.mla is not None:
            cache = {"layers": {
                "c_kv": ("layers", "batch", "kv_seq", None),
                "k_rope": ("layers", "batch", "kv_seq", None),
            }}
        elif cfg.family == "ssm" and cfg.ssm.kind == "mlstm":
            cache = {"layers": {
                0: ("layers", "batch", None, "ff"),
                1: ("layers", "batch", None, None, "heads_tp"),
                2: ("layers", "batch", None, "heads_tp"),
                3: ("layers", "batch", None),
            }}
        elif cfg.family in ("ssm", "hybrid"):
            cache = {"layers": {
                0: ("layers", "batch", None, "ff"),
                1: ("layers", "batch", "heads_tp", None, None),
            }}
        else:
            kvax = ("layers", "batch", "kv_seq", "heads_tp", None)
            cache = {"layers": {"k": kvax, "v": kvax}}
        if cfg.family == "hybrid":
            cache["shared"] = {
                "k": ("layers", "batch", "kv_seq", "heads_tp", None),
                "v": ("layers", "batch", "kv_seq", "heads_tp", None),
            }
        if cfg.family == "encdec":
            cache["cross"] = {
                "k": ("layers", "batch", None, "heads_tp", None),
                "v": ("layers", "batch", None, "heads_tp", None),
            }
        if cfg.family == "vlm":
            cache["patches"] = ("batch", None, None)
        return cache

    def init_cache(self, batch: int, max_seq: int):
        return jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), self.cache_specs(batch, max_seq)
        )

    # ------------------------------------------------- slot-cache helpers ---
    def cache_batch_axes(self):
        """Tree parallel to ``cache_specs`` giving the batch-axis index of
        every cache leaf (derived from ``cache_logical_axes``).  The serving
        engine treats the batch dim as a *slot* dim; these indices drive the
        per-slot insert/extract below and the vmapped multi-position decode."""
        return jax.tree.map(
            lambda ax: ax.index("batch"),
            self.cache_logical_axes(),
            is_leaf=lambda x: isinstance(x, tuple),
        )

    def insert_cache_slot(self, pool_cache, request_cache, slot):
        """Write a single-request cache (batch dim 1, same max_seq layout)
        into slot ``slot`` of a pool cache (batch dim = num_slots).  ``slot``
        may be a traced scalar, so one jit covers every slot."""

        def upd(dst, src, ax):
            starts = tuple(slot if i == ax else 0 for i in range(dst.ndim))
            return jax.lax.dynamic_update_slice(dst, src.astype(dst.dtype), starts)

        return jax.tree.map(upd, pool_cache, request_cache, self.cache_batch_axes())

    def extract_cache_slot(self, pool_cache, slot):
        """Read slot ``slot`` back out as a single-request (batch=1) cache."""

        def ext(src, ax):
            starts = tuple(slot if i == ax else 0 for i in range(src.ndim))
            sizes = tuple(1 if i == ax else d for i, d in enumerate(src.shape))
            return jax.lax.dynamic_slice(src, starts, sizes)

        return jax.tree.map(ext, pool_cache, self.cache_batch_axes())

    def decode_step_slots(self, params, cache, tokens, positions):
        """Per-slot decode for continuous batching: like ``decode_step`` but
        every batch row carries its *own* position.  tokens: (N, 1) int32;
        positions: (N,) int32.  Returns (logits (N, 1, V), new cache).

        Implemented as a vmap of the single-sequence decode over the cache's
        batch axes, so every family's decode path (dense/mla/ssm/hybrid/
        encdec/vlm) is reused unchanged and numerics match the static engine.
        """
        axes = self.cache_batch_axes()

        def one(c, t, pos):
            # vmap strips the mapped batch axis; decode_step wants batch=1.
            c = jax.tree.map(jnp.expand_dims, c, axes)
            logits, nc = self.decode_step(params, c, t[None], pos)
            nc = jax.tree.map(jnp.squeeze, nc, axes)
            return logits[0], nc

        return jax.vmap(one, in_axes=(axes, 0, 0), out_axes=(0, axes))(
            cache, tokens, positions
        )

    # ------------------------------------------------- fused chunk step ---
    def fresh_request_cache(self, max_seq: int):
        """Batch-1 cache tree in the family's *initial* (pre-prompt) state —
        the chunked-prefill entry point.  Zeros everywhere except the mLSTM
        stabilizer m, whose empty value is -1e30 (``mlstm_block``'s
        carry=None init); a zero m would corrupt the first chunk's gating."""
        cache = self.init_cache(1, max_seq)
        if self.cfg.family == "ssm" and self.cfg.ssm.kind == "mlstm":
            cache["layers"][3] = jnp.full_like(cache["layers"][3], -1e30)
        return cache

    def encode_cross_kv(self, params, frames):
        """encdec admission path: run the encoder once and project the
        per-layer cross k/v the decoder's chunked prefill will attend to.
        frames: (B, M_frames, D) -> {'k','v'}: (L, B, M, KV, dh), exactly the
        ``cache['cross']`` layout ``prefill`` produces."""

        memory = self._encode(params, frames)

        def body(_, lp):
            k, v = _project_cross_kv(self.cfg, lp["xattn"], memory)
            return None, (k, v)

        _, (ks, vs) = jax.lax.scan(body, None, params["layers"])
        return {"k": ks, "v": vs}

    def prefill_chunk(self, params, cache, tokens, pos, n_valid, last_only=False):
        """Masked, position-offset multi-token step: process ``tokens``
        (B, C) at absolute positions [pos, pos+n_valid), appending into the
        decode cache at the traced write offset ``pos``.  Lanes >= n_valid
        are don't-care: their cache writes are dropped and recurrent carries
        frozen (see attn_decode_chunk / mlstm_block / mamba2_block).

        With n_valid=1 this is a decode step whose extra lanes are padding;
        with full chunks it streams a prompt into the cache chunk-by-chunk.
        At serve scales (prompt < the conv-fusion / chunked-SSD / chunked-
        attention thresholds) the result is bit-identical to the monolithic
        ``prefill`` followed by ``decode_step``s, which is what keeps greedy
        continuous batching token-identical to the static oracle.

        Returns (logits, new cache).  With ``last_only=False`` logits is
        (B, C, V) and row n_valid-1 is the next-token distribution after the
        chunk; with ``last_only=True`` only that row is projected through
        the LM head — (B, 1, V) — which skips (C-1)/C of the vocab matmul
        on serving ticks (the row gather is bit-identical to slicing the
        full projection, matmul rows being independent).
        """
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        x = params["embed"]["tok"].astype(dt)[tokens]  # (B, C, D)
        x = shard(x, "batch", None, "embed_act")

        if cfg.family == "hybrid":
            return self._hybrid_chunk(params, cache, x, pos, n_valid, last_only)
        if cfg.family == "vlm":
            return self._vlm_chunk(params, cache, x, pos, n_valid, last_only)
        if cfg.family == "encdec":
            return self._encdec_chunk(params, cache, x, pos, n_valid, last_only)

        def body(x, scanned):
            lp, lcache = scanned
            h = apply_norm(cfg, lp["ln1"], x)
            if cfg.mla is not None:
                y, nc = mla_mod.mla_decode_chunk(cfg, lp["mixer"], lcache, h, pos, n_valid)
            elif cfg.family == "ssm":
                blk = ssm_mod.mlstm_block if cfg.ssm.kind == "mlstm" else ssm_mod.mamba2_block
                carry = tuple(lcache[i] for i in sorted(lcache))
                y, ncarry = blk(cfg, lp["mixer"], h, carry, n_valid=n_valid)
                nc = {i: c for i, c in enumerate(ncarry)}
            else:
                y, nc = attn.attn_decode_chunk(cfg, lp["mixer"], lcache, h, pos, n_valid)
            x = x + y
            if "mlp" in lp:
                h2 = apply_norm(cfg, lp["ln2"], x)
                y = (
                    moe_mod.apply_moe(cfg, lp["mlp"], h2)[0]
                    if cfg.moe
                    else apply_mlp(cfg, lp["mlp"], h2)
                )
                x = x + y
            return x, nc

        x, new_layers = jax.lax.scan(body, x, (params["layers"], cache["layers"]))
        logits = _chunk_head(cfg, params, x, n_valid, last_only)
        return logits, {**cache, "layers": new_layers}

    def _hybrid_chunk(self, params, cache, x, pos, n_valid, last_only=False):
        cfg = self.cfg
        g = cfg.n_layers // cfg.attn_every
        layers = self._group_tree(params["layers"], g)
        lcache = self._group_tree(cache["layers"], g)
        shared = params["shared_attn"]

        def group_body(x, scanned):
            gp, gc, skv = scanned
            h = apply_norm(cfg, shared["ln1"], x)
            y, new_skv = attn.attn_decode_chunk(cfg, shared["attn"], skv, h, pos, n_valid)
            x = x + y
            h = apply_norm(cfg, shared["ln2"], x)
            x = x + apply_mlp(cfg, shared["mlp"], h)

            def inner(x2, s2):
                lp, lc = s2
                h2 = apply_norm(cfg, lp["ln1"], x2)
                carry = tuple(lc[i] for i in sorted(lc))
                y2, ncarry = ssm_mod.mamba2_block(cfg, lp["mixer"], h2, carry, n_valid=n_valid)
                return x2 + y2, {i: c for i, c in enumerate(ncarry)}

            x, ncarries = jax.lax.scan(inner, x, (gp, gc))
            return x, (ncarries, new_skv)

        x, (ncar, nskv) = jax.lax.scan(group_body, x, (layers, lcache, cache["shared"]))
        L = cfg.n_layers
        ncar = jax.tree.map(lambda a: a.reshape(L, *a.shape[2:]), ncar)
        logits = _chunk_head(cfg, params, x, n_valid, last_only)
        return logits, {"layers": ncar, "shared": nskv}

    def _vlm_chunk(self, params, cache, x, pos, n_valid, last_only=False):
        cfg = self.cfg
        g = cfg.n_layers // cfg.cross_attn_every
        layers = self._group_tree(params["layers"], g)
        lcache = self._group_tree(cache["layers"], g)
        patches = cache["patches"]

        def group_body(x, scanned):
            gp, xp, gc = scanned
            x = self._xattn_block(xp, x, patches)

            def inner(x2, s2):
                lp, lc = s2
                h = apply_norm(cfg, lp["ln1"], x2)
                y, nc = attn.attn_decode_chunk(cfg, lp["mixer"], lc, h, pos, n_valid)
                x2 = x2 + y
                h2 = apply_norm(cfg, lp["ln2"], x2)
                x2 = x2 + apply_mlp(cfg, lp["mlp"], h2)
                return x2, nc

            x, ngc = jax.lax.scan(inner, x, (gp, gc))
            return x, ngc

        x, nlc = jax.lax.scan(group_body, x, (layers, params["xattn_layers"], lcache))
        nlc = jax.tree.map(lambda a: a.reshape(cfg.n_layers, *a.shape[2:]), nlc)
        logits = _chunk_head(cfg, params, x, n_valid, last_only)
        return logits, {**cache, "layers": nlc}

    def _encdec_chunk(self, params, cache, x, pos, n_valid, last_only=False):
        cfg = self.cfg

        def body(x, scanned):
            lp, lcache, xk, xv = scanned
            h = apply_norm(cfg, lp["ln1"], x)
            y, nc = attn.attn_decode_chunk(cfg, lp["mixer"], lcache, h, pos, n_valid)
            x = x + y
            hx = apply_norm(cfg, lp["ln_x"], x)
            x = x + _cross_attend_cached(cfg, lp["xattn"], hx, xk, xv)
            h2 = apply_norm(cfg, lp["ln2"], x)
            x = x + apply_mlp(cfg, lp["mlp"], h2)
            return x, nc

        x, new_layers = jax.lax.scan(
            body, x, (params["layers"], cache["layers"], cache["cross"]["k"], cache["cross"]["v"])
        )
        logits = _chunk_head(cfg, params, x, n_valid, last_only)
        return logits, {**cache, "layers": new_layers}

    def fused_step_slots(self, params, cache, tokens, positions, n_valid):
        """Per-slot fused prefill/decode for continuous batching: every slot
        processes its own C-token chunk at its own write offset.  tokens:
        (N, C) int32; positions/n_valid: (N,) int32 (all traced -> a single
        compilation regardless of the prompt-length mix).  Returns (logits
        (N, 1, V) — each slot's next-token row n_valid-1, the only one a
        serving tick consumes — and the new cache).  Decode slots pass their
        one sampled token in lane 0 with n_valid=1; prefill slots pass the
        next prompt chunk.

        Like ``decode_step_slots``, a vmap of the single-sequence step over
        the cache's batch axes, so all seven cache families reuse their
        chunk path unchanged.
        """
        axes = self.cache_batch_axes()

        def one(c, t, pos, nv):
            c = jax.tree.map(jnp.expand_dims, c, axes)
            logits, nc = self.prefill_chunk(params, c, t[None], pos, nv,
                                            last_only=True)
            nc = jax.tree.map(jnp.squeeze, nc, axes)
            return logits[0], nc

        return jax.vmap(one, in_axes=(axes, 0, 0, 0), out_axes=(0, axes))(
            cache, tokens, positions, n_valid
        )

    # ------------------------------------------------- block-paged cache ---
    @property
    def paged_read_path(self) -> str:
        """How the serving tick reads paged KV for this family: 'pallas'
        (dense TPU kernel), 'streamed' (block-tile scan, the CPU/GPU
        default) or 'gathered' (full-stream oracle, baselines only).  The
        engine surfaces this in ``metrics()`` and the bench folds it into
        the workload hash so trajectories don't mix read paths."""
        if self.cfg.mla is not None:
            return mla_mod.mla_paged_read_path(self.cfg)
        return attn.paged_read_path(self.cfg)

    @property
    def supports_paging(self) -> bool:
        """Block-granular KV paging applies to the families whose per-layer
        cache is a full-attention KV (dense/moe/encdec/vlm) or MLA latent
        stream: those grow with the sequence, so HBM scales with worst-case
        length under slab pooling.  SSM/hybrid carries are O(1) state and
        sliding-window configs keep their ring buffer — nothing to page."""
        return self.cfg.sliding_window == 0 and self.cfg.family not in ("ssm", "hybrid")

    def paged_cache_specs(self, num_slots: int, num_blocks: int,
                          block_size: int, max_seq: int, kv_dtype: str = "fp"):
        """Cache specs with the ``layers`` leaves re-laid as shared block
        arenas: the (slot, max_seq) dims of every per-layer KV/latent leaf
        become (num_blocks, block_size), indexed through per-slot block
        tables instead of a batch dim.  Non-sequence leaves (encdec cross KV,
        vlm patches) keep their slot-batched layout.

        ``kv_dtype='int8'`` stores every arena in int8 and adds one
        ``<leaf>_scale`` (L, num_blocks) float32 leaf per arena — the
        per-block dequantization scale, carried *inside* ``layers`` so every
        block-axis operation (COW fork, preemption spill/restore, the fused
        tick's layer scan) moves a block's scale with its payload for free."""
        if not self.supports_paging:
            raise ValueError(f"family {self.cfg.family!r} (sliding_window="
                             f"{self.cfg.sliding_window}) has no pageable KV")
        if kv_dtype not in ("fp", "int8"):
            raise ValueError(f"kv_dtype must be 'fp' or 'int8', got {kv_dtype!r}")
        specs = self.cache_specs(num_slots, max_seq)

        def repage(s):
            # every 'layers' leaf here is (L, slot, kv_seq, ...): see
            # cache_logical_axes for the dense/MLA families
            dtype = jnp.int8 if kv_dtype == "int8" else s.dtype
            return jax.ShapeDtypeStruct(
                (s.shape[0], num_blocks, block_size, *s.shape[3:]), dtype
            )

        layers = {k: repage(s) for k, s in specs["layers"].items()}
        if kv_dtype == "int8":
            layers.update({
                f"{k}_scale": jax.ShapeDtypeStruct((v.shape[0], num_blocks),
                                                   jnp.float32)
                for k, v in layers.items()
            })
        return {**specs, "layers": layers}

    def paged_cache_logical_axes(self, kv_dtype: str = "fp"):
        """Logical sharding axes tree parallel to ``paged_cache_specs``.

        The per-layer arenas trade the (slot, kv_seq) dims for (num_blocks,
        block_size): the *block* axis inherits the slot pool's 'batch' rule —
        the serving mesh shards blocks over the same device axis as slots,
        and the pool hands each slot blocks from its own device's range, so
        a sequence's KV stays resident with its slot shard — while the
        intra-block dim is replicated like any other sequence dim.  Non-paged
        leaves (encdec cross KV, vlm patches) keep their slot-batched axes.
        Quantized pools add (layer, block) scale leaves whose block axis
        shards exactly like the arena it scales.
        """
        axes = self.cache_logical_axes()

        def repage(ax):
            # (layers, batch/slot, kv_seq, *rest) -> (layers, blocks, in-block, *rest)
            return (ax[0], "batch", None) + tuple(ax[3:])

        layers = {k: repage(ax) for k, ax in axes["layers"].items()}
        if kv_dtype == "int8":
            layers.update({
                f"{k}_scale": (ax[0], "batch") for k, ax in layers.items()
            })
        return {**axes, "layers": layers}

    def init_paged_cache(self, num_slots: int, num_blocks: int,
                         block_size: int, max_seq: int, kv_dtype: str = "fp"):
        return jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype),
            self.paged_cache_specs(num_slots, num_blocks, block_size, max_seq,
                                   kv_dtype=kv_dtype),
        )

    def insert_cache_slot_extras(self, pool_cache, request_cache, slot):
        """Slot-insert for the non-paged leaves of a paged pool cache (encdec
        cross KV, vlm patches).  The block arenas under ``layers`` have no
        slot dim — prompts stream into them through the block table — so
        admission only pages the per-request side inputs in."""
        axes = {k: v for k, v in self.cache_batch_axes().items() if k != "layers"}

        def upd(dst, src, ax):
            starts = tuple(slot if i == ax else 0 for i in range(dst.ndim))
            return jax.lax.dynamic_update_slice(dst, src.astype(dst.dtype), starts)

        extras = {k: pool_cache[k] for k in axes}
        request = {k: request_cache[k] for k in axes}
        return {**pool_cache, **jax.tree.map(upd, extras, request, axes)}

    def fused_step_slots_paged(self, params, cache, tokens, positions, n_valid,
                               tables, sentinel=False):
        """Block-paged counterpart of ``fused_step_slots``: every slot
        processes its own C-token chunk at its own write offset, but KV lives
        in shared block arenas addressed through per-slot block tables
        instead of per-slot max_seq slabs.  tokens: (N, C) int32;
        positions/n_valid: (N,) int32; tables: (N, max_bt) int32 — all
        traced, so one compilation covers every phase/length/table mix.

        Where the slab path vmaps the single-sequence chunk step over the
        cache's slot axis, the arenas are *shared* across slots (that is the
        memory win), so this path runs the layer stack batched: projections,
        norms and MLPs are row-independent, and the paged attention read
        gathers each slot's logical stream through its table.  n_valid=0
        parks a lane completely (no writes — an inactive slot owns no
        blocks).  Returns (logits (N, 1, V) — each slot's next-token row
        n_valid-1 — and the new cache).

        ``sentinel`` is a static Python bool bound at closure time (never a
        trace key): when True the return gains a third element, a health
        pytree ``{"layers": (L, N, 3) f32, "head": (N,) f32}`` of GN
        sentinel probes (Σp residual / clip fraction / scale sanity per
        layer, σ residual at the head) accumulated on-device — no host
        transfer and no extra compile keys."""
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        x = params["embed"]["tok"].astype(dt)[tokens]  # (N, C, D)
        x = shard(x, "batch", None, "embed_act")

        if cfg.family == "vlm":
            return self._vlm_paged(params, cache, x, positions, n_valid,
                                   tables, sentinel)
        if cfg.family == "encdec":
            return self._encdec_paged(params, cache, x, positions, n_valid,
                                      tables, sentinel)

        def body(x, scanned):
            lp, lcache = scanned
            h = apply_norm(cfg, lp["ln1"], x)
            if cfg.mla is not None:
                if "c_kv_scale" in lcache:  # int8 arenas + per-block scales
                    y, (nck, nkr, ncs, nrs), *pr = mla_mod.mla_paged_chunk(
                        cfg, lp["mixer"], lcache["c_kv"], lcache["k_rope"], h,
                        positions, n_valid, tables,
                        scales=(lcache["c_kv_scale"], lcache["k_rope_scale"]),
                        probe=sentinel)
                    nc = {"c_kv": nck, "k_rope": nkr,
                          "c_kv_scale": ncs, "k_rope_scale": nrs}
                else:
                    y, (nck, nkr), *pr = mla_mod.mla_paged_chunk(
                        cfg, lp["mixer"], lcache["c_kv"], lcache["k_rope"], h,
                        positions, n_valid, tables, probe=sentinel)
                    nc = {"c_kv": nck, "k_rope": nkr}
            else:
                if "k_scale" in lcache:  # int8 arenas + per-block scales
                    y, (nk, nv, nks, nvs), *pr = attn.attn_paged_chunk(
                        cfg, lp["mixer"], lcache["k"], lcache["v"], h,
                        positions, n_valid, tables,
                        scales=(lcache["k_scale"], lcache["v_scale"]),
                        probe=sentinel)
                    nc = {"k": nk, "v": nv, "k_scale": nks, "v_scale": nvs}
                else:
                    y, (nk, nv), *pr = attn.attn_paged_chunk(
                        cfg, lp["mixer"], lcache["k"], lcache["v"], h,
                        positions, n_valid, tables, probe=sentinel)
                    nc = {"k": nk, "v": nv}
            x = x + y
            if "mlp" in lp:
                h2 = apply_norm(cfg, lp["ln2"], x)
                y = (
                    moe_mod.apply_moe(cfg, lp["mlp"], h2)[0]
                    if cfg.moe
                    else apply_mlp(cfg, lp["mlp"], h2)
                )
                x = x + y
            return x, ((nc, pr[0]) if sentinel else nc)

        x, ys = jax.lax.scan(body, x, (params["layers"], cache["layers"]))
        if sentinel:
            new_layers, probes = ys
            logits, head = self._paged_head(params, x, n_valid, probe=True)
            return (logits, {**cache, "layers": new_layers},
                    {"layers": probes, "head": head})
        return self._paged_head(params, x, n_valid), {**cache, "layers": ys}

    def _paged_head(self, params, x, n_valid, probe=False):
        """Next-token logits per slot: gather row n_valid-1 (clamped for
        parked lanes), then project only that row — per-row matmuls make the
        gather bit-exact vs slicing the full projection.

        With ``probe`` (static bool), also returns a (N,) f32 GN-LayerNorm
        σ-residual sentinel: |mean(x̂²) − 1| of the final-norm output on the
        gathered row (unit gamma — re-running the registry norm fn keeps
        the probe pinned to the same impl the head used), forced to +inf
        when the row or its logits contain nonfinite values, and zeroed for
        parked lanes."""
        n = x.shape[0]
        idx = jnp.maximum(n_valid - 1, 0)[:, None, None]
        xr = jnp.take_along_axis(x, jnp.broadcast_to(idx, (n, 1, x.shape[-1])), axis=1)
        logits = _lm_head(self.cfg, params, xr)
        if not probe:
            return logits
        xhat = get_norm(self.cfg.norm_impl)(xr.astype(jnp.float32))
        sig = jnp.abs(jnp.mean(xhat * xhat, axis=-1) - 1.0)[:, 0]
        bad = jnp.any(~jnp.isfinite(logits.astype(jnp.float32)),
                      axis=(1, 2)) | jnp.any(~jnp.isfinite(xr.astype(jnp.float32)),
                                             axis=(1, 2))
        head = jnp.where(n_valid > 0,
                         jnp.where(bad, jnp.inf, sig),
                         jnp.zeros_like(sig))
        return logits, head

    def _encdec_paged(self, params, cache, x, positions, n_valid, tables,
                      sentinel=False):
        cfg = self.cfg

        def body(x, scanned):
            lp, lcache, xk, xv = scanned
            h = apply_norm(cfg, lp["ln1"], x)
            if "k_scale" in lcache:
                y, (nk, nv, nks, nvs), *pr = attn.attn_paged_chunk(
                    cfg, lp["mixer"], lcache["k"], lcache["v"], h,
                    positions, n_valid, tables,
                    scales=(lcache["k_scale"], lcache["v_scale"]),
                    probe=sentinel)
                nc = {"k": nk, "v": nv, "k_scale": nks, "v_scale": nvs}
            else:
                y, (nk, nv), *pr = attn.attn_paged_chunk(
                    cfg, lp["mixer"], lcache["k"], lcache["v"], h,
                    positions, n_valid, tables, probe=sentinel)
                nc = {"k": nk, "v": nv}
            x = x + y
            hx = apply_norm(cfg, lp["ln_x"], x)
            x = x + _cross_attend_cached(cfg, lp["xattn"], hx, xk, xv)
            h2 = apply_norm(cfg, lp["ln2"], x)
            x = x + apply_mlp(cfg, lp["mlp"], h2)
            return x, ((nc, pr[0]) if sentinel else nc)

        x, ys = jax.lax.scan(
            body, x, (params["layers"], cache["layers"], cache["cross"]["k"], cache["cross"]["v"])
        )
        if sentinel:
            new_layers, probes = ys
            logits, head = self._paged_head(params, x, n_valid, probe=True)
            return (logits, {**cache, "layers": new_layers},
                    {"layers": probes, "head": head})
        return self._paged_head(params, x, n_valid), {**cache, "layers": ys}

    def _vlm_paged(self, params, cache, x, positions, n_valid, tables,
                   sentinel=False):
        cfg = self.cfg
        g = cfg.n_layers // cfg.cross_attn_every
        layers = self._group_tree(params["layers"], g)
        lcache = self._group_tree(cache["layers"], g)
        patches = cache["patches"]

        def group_body(x, scanned):
            gp, xp, gc = scanned
            x = self._xattn_block(xp, x, patches)

            def inner(x2, s2):
                lp, lc = s2
                h = apply_norm(cfg, lp["ln1"], x2)
                if "k_scale" in lc:
                    y, (nk, nv, nks, nvs), *pr = attn.attn_paged_chunk(
                        cfg, lp["mixer"], lc["k"], lc["v"], h,
                        positions, n_valid, tables,
                        scales=(lc["k_scale"], lc["v_scale"]),
                        probe=sentinel)
                    nc = {"k": nk, "v": nv, "k_scale": nks, "v_scale": nvs}
                else:
                    y, (nk, nv), *pr = attn.attn_paged_chunk(
                        cfg, lp["mixer"], lc["k"], lc["v"], h,
                        positions, n_valid, tables, probe=sentinel)
                    nc = {"k": nk, "v": nv}
                x2 = x2 + y
                h2 = apply_norm(cfg, lp["ln2"], x2)
                x2 = x2 + apply_mlp(cfg, lp["mlp"], h2)
                return x2, ((nc, pr[0]) if sentinel else nc)

            x, ys2 = jax.lax.scan(inner, x, (gp, gc))
            return x, ys2

        x, ys = jax.lax.scan(group_body, x, (layers, params["xattn_layers"], lcache))
        if sentinel:
            nlc, probes = ys
            nlc = jax.tree.map(lambda a: a.reshape(cfg.n_layers, *a.shape[2:]), nlc)
            probes = probes.reshape(cfg.n_layers, *probes.shape[2:])
            logits, head = self._paged_head(params, x, n_valid, probe=True)
            return (logits, {**cache, "layers": nlc},
                    {"layers": probes, "head": head})
        nlc = jax.tree.map(lambda a: a.reshape(cfg.n_layers, *a.shape[2:]), ys)
        return self._paged_head(params, x, n_valid), {**cache, "layers": nlc}

    # ----------------------------------------------------------- prefill ---
    def prefill(self, params, batch: dict, max_seq: int | None = None):
        """Prompt pass.  Returns (full-seq logits, decode-ready cache)."""
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        tokens = batch["tokens"]
        b, s = tokens.shape
        max_seq = max_seq or s
        x = params["embed"]["tok"].astype(dt)[tokens]
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))

        def fill_kv(kv):  # (B,S,KV,dh) -> (B,T,KV,dh) at positions [0, s)
            win = cfg.sliding_window or 0
            slots = min(max_seq, win) if win else max_seq
            out = jnp.zeros((b, slots, *kv.shape[2:]), kv.dtype)
            if win and s > win:
                kv = kv[:, -win:]
                out = jax.lax.dynamic_update_slice(out, kv, (0, 0, 0, 0))
                return jnp.roll(out, shift=s % win, axis=1) if win != slots else jnp.roll(out, shift=s % win, axis=1)
            return jax.lax.dynamic_update_slice(out, kv, (0, 0, 0, 0))

        memory = None
        if cfg.family == "encdec":
            memory = self._encode(params, batch["frames"])
        elif cfg.family == "vlm":
            memory = batch["patches"].astype(dt)

        if cfg.family == "hybrid":
            return self._hybrid_prefill(params, x, positions, max_seq, fill_kv)
        if cfg.family == "vlm":
            return self._vlm_prefill(params, x, positions, memory, fill_kv)

        def body(x, lp):
            h = apply_norm(cfg, lp["ln1"], x)
            if cfg.mla is not None:
                y, kv = mla_mod.mla_prefill(cfg, lp["mixer"], h, positions)
                kv = {
                    k: jax.lax.dynamic_update_slice(
                        jnp.zeros((b, max_seq, v.shape[-1]), v.dtype), v, (0, 0, 0)
                    )
                    for k, v in kv.items()
                }
            elif cfg.family == "ssm":
                blk = ssm_mod.mlstm_block if cfg.ssm.kind == "mlstm" else ssm_mod.mamba2_block
                y, carry = blk(cfg, lp["mixer"], h, exact=True)
                kv = {i: c for i, c in enumerate(carry)}
            else:
                y, kv = attn.attn_prefill(cfg, lp["mixer"], h, positions)
                kv = {k: fill_kv(v) for k, v in kv.items()}
            x = x + y
            if memory is not None and "xattn" in lp:
                hx = apply_norm(cfg, lp["ln_x"], x)
                x = x + attn.cross_attention(cfg, lp["xattn"], hx, memory)
                mk, mv = _project_cross_kv(cfg, lp["xattn"], memory)
                kv = {"k": kv["k"], "v": kv["v"], "xk": mk, "xv": mv}
            if "mlp" in lp:
                h2 = apply_norm(cfg, lp["ln2"], x)
                y = (
                    moe_mod.apply_moe(cfg, lp["mlp"], h2)[0]
                    if cfg.moe
                    else apply_mlp(cfg, lp["mlp"], h2)
                )
                x = x + y
            return x, kv

        x, kvs = jax.lax.scan(body, x, params["layers"])
        logits = _lm_head(cfg, params, x)
        if cfg.family == "encdec":
            cache = {
                "layers": {"k": kvs["k"], "v": kvs["v"]},
                "cross": {"k": kvs["xk"], "v": kvs["xv"]},
            }
        else:
            cache = {"layers": kvs}
        return logits, cache

    def _hybrid_prefill(self, params, x, positions, max_seq, fill_kv):
        cfg = self.cfg
        g = cfg.n_layers // cfg.attn_every
        layers = self._group_tree(params["layers"], g)
        shared = params["shared_attn"]
        b = x.shape[0]

        def group_body(x, gp):
            h = apply_norm(cfg, shared["ln1"], x)
            y, kv = attn.attn_prefill(cfg, shared["attn"], h, positions)
            x = x + y
            h = apply_norm(cfg, shared["ln2"], x)
            x = x + apply_mlp(cfg, shared["mlp"], h)
            kv = {k: fill_kv(v) for k, v in kv.items()}

            def inner(x2, lp):
                h2 = apply_norm(cfg, lp["ln1"], x2)
                y2, carry = ssm_mod.mamba2_block(cfg, lp["mixer"], h2, exact=True)
                return x2 + y2, {i: c for i, c in enumerate(carry)}

            x, carries = jax.lax.scan(inner, x, gp)
            return x, (kv, carries)

        x, (shared_kv, carries) = jax.lax.scan(group_body, x, layers)
        logits = _lm_head(cfg, params, x)
        L = cfg.n_layers
        carries = jax.tree.map(lambda a: a.reshape(L, *a.shape[2:]), carries)
        return logits, {"layers": carries, "shared": shared_kv}

    def _vlm_prefill(self, params, x, positions, patches, fill_kv):
        cfg = self.cfg
        g = cfg.n_layers // cfg.cross_attn_every
        layers = self._group_tree(params["layers"], g)

        def group_body(x, scanned):
            gp, xp = scanned
            x = self._xattn_block(xp, x, patches)

            def inner(x2, lp):
                h = apply_norm(cfg, lp["ln1"], x2)
                y, kv = attn.attn_prefill(cfg, lp["mixer"], h, positions)
                x2 = x2 + y
                h2 = apply_norm(cfg, lp["ln2"], x2)
                x2 = x2 + apply_mlp(cfg, lp["mlp"], h2)
                return x2, {k: fill_kv(v) for k, v in kv.items()}

            x, kvs = jax.lax.scan(inner, x, gp)
            return x, kvs

        x, kvs = jax.lax.scan(group_body, x, (layers, params["xattn_layers"]))
        logits = _lm_head(cfg, params, x)
        L = cfg.n_layers
        kvs = jax.tree.map(lambda a: a.reshape(L, *a.shape[2:]), kvs)
        return logits, {"layers": kvs, "patches": patches}

    # ------------------------------------------------------------ decode ---
    def decode_step(self, params, cache, token, pos):
        """token: (B, 1) int32; pos: scalar int32.  Returns (logits, cache)."""
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        x = params["embed"]["tok"].astype(dt)[token]  # (B, 1, D)
        x = shard(x, "batch", None, "embed_act")

        if cfg.family == "hybrid":
            return self._hybrid_decode(params, cache, x, pos)
        if cfg.family == "vlm":
            return self._vlm_decode(params, cache, x, pos)
        if cfg.family == "encdec":
            return self._encdec_decode(params, cache, x, pos)

        def body(x, scanned):
            lp, lcache = scanned
            h = apply_norm(cfg, lp["ln1"], x)
            if cfg.mla is not None:
                y, nc = mla_mod.mla_decode_step(cfg, lp["mixer"], lcache, h, pos)
            elif cfg.family == "ssm":
                blk = ssm_mod.mlstm_block if cfg.ssm.kind == "mlstm" else ssm_mod.mamba2_block
                carry = tuple(lcache[i] for i in sorted(lcache))
                y, ncarry = blk(cfg, lp["mixer"], h, carry)
                nc = {i: c for i, c in enumerate(ncarry)}
            else:
                y, nc = attn.attn_decode_step(cfg, lp["mixer"], lcache, h, pos)
            x = x + y
            if "mlp" in lp:
                h2 = apply_norm(cfg, lp["ln2"], x)
                y = (
                    moe_mod.apply_moe(cfg, lp["mlp"], h2)[0]
                    if cfg.moe
                    else apply_mlp(cfg, lp["mlp"], h2)
                )
                x = x + y
            return x, nc

        x, new_layers = jax.lax.scan(body, x, (params["layers"], cache["layers"]))
        logits = _lm_head(cfg, params, x)
        return logits, {**cache, "layers": new_layers}

    def _hybrid_decode(self, params, cache, x, pos):
        cfg = self.cfg
        g = cfg.n_layers // cfg.attn_every
        layers = self._group_tree(params["layers"], g)
        lcache = self._group_tree(cache["layers"], g)
        shared = params["shared_attn"]

        def group_body(x, scanned):
            gp, gc, skv = scanned
            h = apply_norm(cfg, shared["ln1"], x)
            y, new_skv = attn.attn_decode_step(cfg, shared["attn"], skv, h, pos)
            x = x + y
            h = apply_norm(cfg, shared["ln2"], x)
            x = x + apply_mlp(cfg, shared["mlp"], h)

            def inner(x2, s2):
                lp, lc = s2
                h2 = apply_norm(cfg, lp["ln1"], x2)
                carry = tuple(lc[i] for i in sorted(lc))
                y2, ncarry = ssm_mod.mamba2_block(cfg, lp["mixer"], h2, carry)
                return x2 + y2, {i: c for i, c in enumerate(ncarry)}

            x, ncarries = jax.lax.scan(inner, x, (gp, gc))
            return x, (ncarries, new_skv)

        x, (ncar, nskv) = jax.lax.scan(group_body, x, (layers, lcache, cache["shared"]))
        L = cfg.n_layers
        ncar = jax.tree.map(lambda a: a.reshape(L, *a.shape[2:]), ncar)
        logits = _lm_head(cfg, params, x)
        return logits, {"layers": ncar, "shared": nskv}

    def _vlm_decode(self, params, cache, x, pos):
        cfg = self.cfg
        g = cfg.n_layers // cfg.cross_attn_every
        layers = self._group_tree(params["layers"], g)
        lcache = self._group_tree(cache["layers"], g)
        patches = cache["patches"]

        def group_body(x, scanned):
            gp, xp, gc = scanned
            x = self._xattn_block(xp, x, patches)

            def inner(x2, s2):
                lp, lc = s2
                h = apply_norm(cfg, lp["ln1"], x2)
                y, nc = attn.attn_decode_step(cfg, lp["mixer"], lc, h, pos)
                x2 = x2 + y
                h2 = apply_norm(cfg, lp["ln2"], x2)
                x2 = x2 + apply_mlp(cfg, lp["mlp"], h2)
                return x2, nc

            x, ngc = jax.lax.scan(inner, x, (gp, gc))
            return x, ngc

        x, nlc = jax.lax.scan(group_body, x, (layers, params["xattn_layers"], lcache))
        nlc = jax.tree.map(lambda a: a.reshape(cfg.n_layers, *a.shape[2:]), nlc)
        logits = _lm_head(cfg, params, x)
        return logits, {**cache, "layers": nlc}

    def _encdec_decode(self, params, cache, x, pos):
        cfg = self.cfg

        def body(x, scanned):
            lp, lcache, xk, xv = scanned
            h = apply_norm(cfg, lp["ln1"], x)
            y, nc = attn.attn_decode_step(cfg, lp["mixer"], lcache, h, pos)
            x = x + y
            hx = apply_norm(cfg, lp["ln_x"], x)
            x = x + _cross_attend_cached(cfg, lp["xattn"], hx, xk, xv)
            h2 = apply_norm(cfg, lp["ln2"], x)
            x = x + apply_mlp(cfg, lp["mlp"], h2)
            return x, nc

        x, new_layers = jax.lax.scan(
            body, x, (params["layers"], cache["layers"], cache["cross"]["k"], cache["cross"]["v"])
        )
        logits = _lm_head(cfg, params, x)
        return logits, {**cache, "layers": new_layers}


def _project_cross_kv(cfg: ModelConfig, p: dict, memory):
    dt = memory.dtype
    b, m, _ = memory.shape
    k = jnp.einsum("bmd,df->bmf", memory, p["wk"].astype(dt)).reshape(
        b, m, cfg.n_kv_heads, cfg.head_dim
    )
    v = jnp.einsum("bmd,df->bmf", memory, p["wv"].astype(dt)).reshape(
        b, m, cfg.n_kv_heads, cfg.head_dim
    )
    return k, v


def _cross_attend_cached(cfg: ModelConfig, p: dict, x, k, v):
    """Cross-attn with precomputed memory kv.  x: (B,S,D); k/v: (B,M,KV,dh)."""
    dt = x.dtype
    b, s, _ = x.shape
    q = jnp.einsum("bsd,df->bsf", x, p["wq"].astype(dt)).reshape(
        b, s, cfg.n_heads, cfg.head_dim
    )
    kvh = cfg.n_kv_heads
    group = cfg.n_heads // kvh
    qg = q.reshape(b, s, kvh, group, cfg.head_dim)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k) * (cfg.head_dim**-0.5)
    from repro.core import get_softmax

    pmat = get_softmax(cfg.softmax_impl)(scores.astype(jnp.float32)).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", pmat, v).reshape(b, s, cfg.q_features)
    return jnp.einsum("bsf,fd->bsd", out, p["wo"].astype(dt))


def make_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
