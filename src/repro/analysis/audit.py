"""Pass A: the jaxpr/HLO invariant auditor.

For each registry arch, build a smoke-scale engine (the same
``reduce_config`` shapes the serve tests pin), run a small mixed-length
workload so the ``CountingJit`` entry points capture their real call
signatures (as ShapeDtypeStructs — donated buffers are never held), then
re-trace every jitted serving entry point and assert the structural
invariants:

* **A-GATHER** — paged tick jaxprs contain no stream-materializing arena
  gather beyond the read path's budget (streamed dense KV: exactly the
  one bucketed V read; streamed MLA: zero — both latent tiles stream;
  pallas: zero outside the kernel; the gathered oracle: its two
  full-stream reads, and no more).
* **A-DONATE** — every ``donate_argnums`` leaf produces an input-output
  aliasing mark in the lowered module (``tf.aliasing_output``) and, for
  the tick entry points, an ``input_output_alias`` entry in the compiled
  executable.  Catches silently-dropped donation that doubles KV HBM.
* **A-F64** — no float64/complex128 value anywhere in a tick jaxpr (the
  classic silent-upcast hazard on CPU hosts with x64 enabled).
* **A-TRANSFER** — no host-transfer/callback primitive inside a tick
  body (the runtime twin is the ``jax.transfer_guard`` around tick
  dispatch in ``engine.step``).
* **A-TRACEKEY** — the statically enumerated (step kind × horizon
  bucket) trace-key space (``tracekeys``) contains every key the run
  actually traced, and the CountingJit totals equal the per-kind seen
  counts, bounded by the derived grid — the same single-source bound
  ``tests/_serve_helpers.assert_exact_compile_counters`` asserts.
* **A-QUANT** — quantized-mode (kv_dtype=int8) programs never hold a
  floating-typed value at a full KV arena shape: the int8 arena is the
  only arena, dequant happens strictly per gathered tile (after the
  block-table read), and in particular no upcast-then-gather — a float
  gather operand at arena shape means the whole fp stream was
  materialized before the table was consulted, which is exactly the
  HBM-doubling rewrite the quantized path exists to avoid.
* **A-SENTINEL** — when GN runtime sentinels are enabled, the tick's
  trailing health outputs are data-dependent on the tick's inputs
  (backward-reachable to the jaxpr invars).  A constant-foldable health
  word means the probes were disconnected — the engine would read
  "healthy" forever while corruption flows through undetected.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import tracekeys
from repro.analysis.findings import Finding
from repro.analysis.jaxpr_walk import (
    eqns_by_name,
    iter_eqns,
    out_dtypes,
    primitive_names,
)
from repro.configs.registry import get_config, list_archs, reduce_config
from repro.models import attention
from repro.models.transformer import make_model
from repro.serve import kv_cache
from repro.serve.engine import ContinuousEngine, ServeConfig
from repro.serve.workload import required_max_seq, staggered_requests

# Primitives that move data across the host boundary (or call back into
# python) — none may appear inside a tick body.  device_put is checked
# separately: jnp.asarray on a traced value lowers to a no-op aliasing
# device_put (devices=[None]) that XLA elides; only an explicit target
# device or memory kind is a real transfer.
TRANSFER_PRIMITIVES = frozenset({
    "infeed", "outfeed",
    "pure_callback", "io_callback", "debug_callback", "callback",
})

# Float dtypes a tick may produce.  Everything else (notably float64 /
# complex128) is an upcast bug: the serving stack computes in the model
# dtype and accumulates in float32, never wider.
ALLOWED_FLOAT_DTYPES = {"bfloat16", "float16", "float32"}

# Per-read-path stream-gather budgets for dense-KV / MLA paged ticks.
GATHER_BUDGETS = {
    ("streamed", False): 1,   # the bucketed V read; K streams tile-by-tile
    ("streamed", True): 0,    # MLA: latent + rope tiles both stream
    ("pallas", False): 0,     # the kernel IS the read; nothing outside it
    ("gathered", False): 2,   # the oracle's full K and V streams
    ("gathered", True): 2,    # the MLA oracle's latent + rope streams
}


@dataclasses.dataclass
class EntryPoint:
    """One jitted serving entry point, described abstractly."""

    name: str
    jitfn: object                      # has .trace(*avals)
    avals: tuple                       # ShapeDtypeStruct pytree per arg
    donate: tuple = ()
    gather_budget: Optional[int] = None  # None: skip the gather audit
    bucket: Optional[int] = None       # horizon bucket of this signature
    compile_donation: bool = False     # verify aliasing in the executable
    quantized: bool = False            # run the A-QUANT no-fp-arena check
    sentinel_outputs: int = 0          # trailing flat outputs = health words


def read_path_for(cfg) -> str:
    from repro.models.mla import mla_paged_read_path

    return (mla_paged_read_path(cfg) if cfg.mla is not None
            else attention.paged_read_path(cfg))


def build_engine(arch: str, *, num_slots: int = 2, chunk: int = 4,
                 block_size: int = 4, kv_dtype: str = "fp"):
    """Smoke-scale engine + its workload for one registry arch."""
    cfg = reduce_config(get_config(arch))
    model = make_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    reqs = staggered_requests(cfg, n_requests=4, base_len=12,
                              max_new_tokens=4, stagger=1)
    kw = dict(num_slots=num_slots, max_seq=required_max_seq(reqs),
              cfg=ServeConfig(), chunk=chunk)
    if model.supports_paging:
        kw["block_size"] = block_size
        kw["kv_dtype"] = kv_dtype
    engine = ContinuousEngine(model, params, **kw)
    return engine, reqs


def _to_avals(tree):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def _captured_signature(cjit, *, largest_bucket: bool):
    """Pick one captured aval signature from a CountingJit.

    Paged tick signatures differ only in the block-table width (the
    horizon bucket, the trailing arg's second dim); the gather audit needs
    the widest one so tile-sized and stream-sized reads are
    distinguishable (they coincide at bucket 1)."""
    sigs = cjit.capture_avals or {}
    if not sigs:
        return None, None
    if not largest_bucket:
        return next(iter(sigs.values())), None

    def bucket_of(avals):
        tables = jax.tree.leaves(avals[-1])
        return tables[0].shape[1] if tables and len(tables[0].shape) == 2 else 0

    best = max(sigs.values(), key=bucket_of)
    return best, bucket_of(best)


def collect_entry_points(engine, *, paged_budget_path: Optional[str] = None,
                         compile_donation: bool = True) -> list[EntryPoint]:
    """Every jitted serving entry point the engine/pool can dispatch, with
    the aval signatures a real workload produced (ticks) or the pool's
    state implies (fork/spill/insert)."""
    eps: list[EntryPoint] = []
    paged = engine.paged
    cfg = engine.model.cfg
    if paged_budget_path is None and paged:
        paged_budget_path = read_path_for(cfg)
    budget = (GATHER_BUDGETS.get((paged_budget_path, cfg.mla is not None))
              if paged else None)

    # sentinel-enabled ticks append the health pytree {'head', 'layers'} —
    # two trailing flat outputs that A-SENTINEL pins to the tick's inputs
    n_sentinel = 2 if getattr(engine, "sentinels", False) else 0
    for name, cjit in (("fused_tick", engine._fused),
                       ("decode_tick", engine._decode)):
        avals, bucket = _captured_signature(cjit, largest_bucket=paged)
        if avals is None:
            continue
        eps.append(EntryPoint(
            name=name, jitfn=cjit, avals=avals,
            donate=cjit.donate_argnums,
            gather_budget=budget, bucket=bucket,
            compile_donation=compile_donation,
            sentinel_outputs=n_sentinel,
        ))

    cache_avals = _to_avals(engine.pool.cache)
    i32 = jax.ShapeDtypeStruct((), jnp.int32)
    if paged:
        pool = engine.pool
        npad = pool.max_blocks_per_slot
        ix = jax.ShapeDtypeStruct((npad,), jnp.int32)
        layers_avals = _to_avals(pool.cache["layers"])
        host_avals = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct((a.shape[0], npad) + a.shape[2:],
                                           a.dtype),
            layers_avals,
        )
        eps.append(EntryPoint(
            name="prefix_cow_fork",
            jitfn=jax.jit(kv_cache.fork_block, donate_argnums=(0,)),
            avals=(cache_avals, i32, i32), donate=(0,),
        ))
        eps.append(EntryPoint(
            name="spill_gather",
            jitfn=jax.jit(kv_cache.spill_gather),
            avals=(layers_avals, ix), donate=(),
        ))
        eps.append(EntryPoint(
            name="spill_restore",
            jitfn=jax.jit(kv_cache.spill_scatter, donate_argnums=(0,)),
            avals=(cache_avals, host_avals, ix), donate=(0,),
        ))
    else:
        request_avals = jax.eval_shape(
            lambda: engine.model.init_cache(1, engine.max_seq)
        )
        eps.append(EntryPoint(
            name="slot_insert",
            jitfn=jax.jit(engine.model.insert_cache_slot, donate_argnums=(0,)),
            avals=(cache_avals, request_avals, i32), donate=(0,),
        ))
    return eps


# ------------------------------------------------------------- checks ---
def _arena_block_elems(shape, layer_leaf_shapes) -> Optional[int]:
    """If ``shape`` is a paged arena leaf (possibly layer-stripped or
    block-flattened), return the element count of ONE block; else None."""
    for leaf in layer_leaf_shapes:
        if len(leaf) < 3:
            continue  # per-block scale leaves (L, nb) are not arenas
        L, nb, bs, *rest = leaf
        rest = tuple(rest)
        block = bs * int(np.prod(rest, dtype=np.int64)) if rest else bs
        if shape in ((L, nb, bs) + rest, (nb, bs) + rest, (nb * bs,) + rest):
            return block
    return None


def stream_gather_hits(jaxpr, layer_leaf_shapes, num_slots: int,
                       bucket: int) -> list[str]:
    """Gather equations whose operand is a paged arena and whose output
    materializes at least the full bucketed stream (num_slots × bucket
    blocks) — the reads the streamed/pallas paths exist to eliminate."""
    hits = []
    for eqn in iter_eqns(jaxpr):
        if eqn.primitive.name != "gather":
            continue
        op = eqn.invars[0].aval
        block = _arena_block_elems(tuple(op.shape), layer_leaf_shapes)
        if block is None:
            continue
        out = eqn.outvars[0].aval
        if out.size >= num_slots * bucket * block:
            hits.append(f"{tuple(op.shape)} -> {tuple(out.shape)}")
    return hits


def _is_fp_arena(aval, layer_leaf_shapes) -> bool:
    try:
        fp = (np.issubdtype(aval.dtype, np.floating)
              or np.issubdtype(aval.dtype, np.complexfloating))
    except TypeError:
        return False
    return (fp and _arena_block_elems(tuple(aval.shape), layer_leaf_shapes)
            is not None)


def quantized_fp_arena_hits(jaxpr, layer_leaf_shapes) -> list[str]:
    """Floating-typed values at a full KV arena shape in a quantized-mode
    program.  The int8 contract: the arena leaves stay int8 end to end and
    dequant is per gathered tile (strictly after the block-table read) —
    so ANY fp value the size of the whole arena means the fp stream was
    materialized.  The gather case is called out separately: a float
    arena-shaped gather operand is the silent upcast-then-gather rewrite
    (dequantize everything, then read), which doubles arena HBM."""
    hits = []
    for eqn in iter_eqns(jaxpr):
        if (eqn.primitive.name == "gather" and eqn.invars
                and _is_fp_arena(eqn.invars[0].aval, layer_leaf_shapes)):
            op = eqn.invars[0].aval
            hits.append(
                f"upcast-then-gather: gather over fp arena "
                f"{tuple(op.shape)} {op.dtype}"
            )
        for v in eqn.outvars:
            if _is_fp_arena(v.aval, layer_leaf_shapes):
                hits.append(
                    f"{eqn.primitive.name} -> fp arena-shaped "
                    f"{tuple(v.aval.shape)} {v.aval.dtype}"
                )
    return hits


def sentinel_constant_outputs(jaxpr, n_outputs: int) -> list[str]:
    """The trailing ``n_outputs`` outvars of ``jaxpr`` that are NOT
    data-dependent on any jaxpr input — backward reachability over the
    top-level equations (sub-jaxpr bodies need not be entered: a scan/cond
    whose *equation* consumes an input makes its outputs dependent).  A
    health output that only reaches literals/constants is a disconnected
    probe: it would fold to the same 'healthy' word for every tick."""
    from jax._src.core import Literal

    core = jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr
    producers = {v: eqn for eqn in core.eqns for v in eqn.outvars}
    invars = set(core.invars)
    hits = []
    for i, out in enumerate(core.outvars[-n_outputs:]):
        if isinstance(out, Literal):
            hits.append(f"output[-{n_outputs - i}]: literal {out.val!r}")
            continue
        stack, seen, dependent = [out], set(), False
        while stack:
            v = stack.pop()
            if v in invars:
                dependent = True
                break
            if id(v) in seen:
                continue
            seen.add(id(v))
            eqn = producers.get(v)
            if eqn is None:
                continue  # constvar: constant-folded, keep scanning others
            stack.extend(iv for iv in eqn.invars
                         if not isinstance(iv, Literal))
        if not dependent:
            hits.append(
                f"output[-{n_outputs - i}] {out.aval}: no data path to any "
                "tick input"
            )
    return hits


def audit_entry_point(ep: EntryPoint, where: str, *,
                      layer_leaf_shapes=(), num_slots: int = 1) -> list[Finding]:
    findings: list[Finding] = []
    traced = ep.jitfn.trace(*ep.avals)
    jaxpr = traced.jaxpr

    # A-SENTINEL
    if ep.sentinel_outputs:
        hits = sentinel_constant_outputs(jaxpr, ep.sentinel_outputs)
        if hits:
            findings.append(Finding(
                "A-SENTINEL", "error", where,
                f"{len(hits)} sentinel health output(s) not data-dependent "
                f"on the tick inputs (probe disconnected — corruption would "
                f"read as healthy): {hits}",
            ))

    # A-QUANT
    if ep.quantized and layer_leaf_shapes:
        hits = quantized_fp_arena_hits(jaxpr, layer_leaf_shapes)
        if hits:
            findings.append(Finding(
                "A-QUANT", "error", where,
                f"{len(hits)} fp-typed KV arena value(s) in a quantized-mode "
                f"program (int8 arenas must never materialize the fp "
                f"stream): {hits}",
            ))

    # A-GATHER
    if ep.gather_budget is not None and ep.bucket and ep.bucket > 1:
        hits = stream_gather_hits(jaxpr, layer_leaf_shapes, num_slots,
                                  ep.bucket)
        if len(hits) > ep.gather_budget:
            findings.append(Finding(
                "A-GATHER", "error", where,
                f"{len(hits)} stream-materializing arena gathers, budget "
                f"{ep.gather_budget} (bucket={ep.bucket}): {hits}",
            ))

    # A-DONATE
    if ep.donate:
        expected = sum(len(jax.tree.leaves(ep.avals[i])) for i in ep.donate)
        lowered = traced.lower()
        marks = lowered.as_text().count("tf.aliasing_output")
        if marks != expected:
            findings.append(Finding(
                "A-DONATE", "error", where,
                f"donate_argnums={ep.donate}: {expected} donated leaves but "
                f"{marks} aliasing marks in the lowered module — donation "
                "dropped (the buffer will be copied, not reused)",
            ))
        elif ep.compile_donation:
            txt = lowered.compile().as_text()
            aliased = txt.count("may-alias") + txt.count("must-alias")
            if aliased != expected:
                findings.append(Finding(
                    "A-DONATE", "error", where,
                    f"compiled executable aliases {aliased} buffers, expected "
                    f"{expected} (input_output_alias dropped by the backend)",
                ))

    # A-F64
    def _wide_float(dt) -> bool:
        try:
            return (np.issubdtype(dt, np.floating)
                    or np.issubdtype(dt, np.complexfloating))
        except TypeError:
            return False  # extended dtypes (PRNG keys) aren't numpy dtypes
    bad = sorted(
        str(dt) for dt in out_dtypes(jaxpr)
        if _wide_float(dt) and str(dt) not in ALLOWED_FLOAT_DTYPES
    )
    if bad:
        findings.append(Finding(
            "A-F64", "error", where,
            f"wide float dtypes in traced program: {bad} (allowed: "
            f"{sorted(ALLOWED_FLOAT_DTYPES)})",
        ))

    # A-TRANSFER
    present = primitive_names(jaxpr) & TRANSFER_PRIMITIVES
    if present:
        findings.append(Finding(
            "A-TRANSFER", "error", where,
            f"host-transfer/callback primitives inside the body: {sorted(present)}",
        ))
    placed = [
        eqn.params for eqn in eqns_by_name(jaxpr, "device_put")
        if any(d is not None for d in eqn.params.get("devices", []))
        or any(s is not None for s in eqn.params.get("srcs", []))
    ]
    if placed:
        findings.append(Finding(
            "A-TRANSFER", "error", where,
            f"device_put with an explicit placement inside the body "
            f"(forces a transfer): {placed}",
        ))
    return findings


def check_trace_keys(metrics: dict, where: str, *, paged: bool,
                     max_seq: int = 0, block_size: int = 0,
                     engine_grid=None) -> list[Finding]:
    """Engine-independent core of the A-TRACEKEY audit (fixture-drivable):
    derive the grid from config, compare it to what the engine/metrics
    carry, and pin the CountingJit totals to the seen-key counts."""
    findings: list[Finding] = []
    if paged:
        derived = tracekeys.horizon_bucket_grid(max_seq, block_size)
        for label, grid in (("engine", engine_grid),
                            ("metrics", metrics.get("horizon_bucket_grid"))):
            if grid is not None and list(grid) != derived:
                findings.append(Finding(
                    "A-TRACEKEY", "error", where,
                    f"{label} grid {list(grid)} != derived grid {derived} "
                    f"(max_seq={max_seq}, block_size={block_size})",
                ))
                return findings
        expected = tracekeys.trace_key_space(paged=True, grid=derived)
        bound = tracekeys.compile_bound(paged=True, grid=derived)
    else:
        expected = tracekeys.trace_key_space(paged=False)
        bound = tracekeys.compile_bound(paged=False)
    seen = tracekeys.seen_trace_keys(metrics)
    counts = {"fused": metrics["fused_step_compilations"],
              "decode": metrics["decode_compilations"]}
    diff = tracekeys.format_trace_key_diff(expected, seen, counts)
    if not seen <= expected:
        findings.append(Finding(
            "A-TRACEKEY", "error", where,
            "traced keys outside the enumerated space\n" + diff,
        ))
    if paged:
        exact = {k: sum(1 for kind, _ in seen if kind == k)
                 for k in tracekeys.STEP_KINDS}
    else:
        exact = {"fused": min(1, metrics.get("fused_ticks", 1)),
                 "decode": counts["decode"]}  # decode tick is workload-dependent
    for kind in tracekeys.STEP_KINDS:
        if counts[kind] != exact[kind] or counts[kind] > bound[kind]:
            findings.append(Finding(
                "A-TRACEKEY", "error", where,
                f"{kind} compilations {counts[kind]} != seen-key count "
                f"{exact[kind]} (bound {bound[kind]})\n" + diff,
            ))
    if metrics.get("prefill_compilations", 0) != 0:
        findings.append(Finding(
            "A-TRACEKEY", "error", where,
            f"prefill_compilations={metrics['prefill_compilations']} — "
            "per-prompt-length tracing reintroduced",
        ))
    return findings


def audit_trace_keys(engine, metrics: dict, where: str) -> list[Finding]:
    return check_trace_keys(
        metrics, where, paged=engine.paged,
        max_seq=engine.max_seq,
        block_size=engine.pool.block_size if engine.paged else 0,
        engine_grid=engine.horizon_bucket_grid if engine.paged else None,
    )


# -------------------------------------------------------------- driver ---
def audit_arch(arch: str, *, tier: str = "full",
               compile_donation: bool = True) -> list[Finding]:
    """Run the full Pass A audit for one arch.  ``tier='full'`` adds the
    forced gathered-oracle and (dense-KV) pallas read-path variants."""
    findings: list[Finding] = []
    engine, reqs = build_engine(arch)
    engine._fused.capture_avals = {}
    engine._decode.capture_avals = {}
    engine.run(reqs)
    metrics = engine.metrics()
    findings.extend(audit_trace_keys(engine, metrics, f"{arch}:trace_keys"))
    leaf_shapes = ([tuple(l.shape)
                    for l in jax.tree.leaves(engine.pool.cache["layers"])]
                   if engine.paged else ())
    for ep in collect_entry_points(engine, compile_donation=compile_donation):
        findings.extend(audit_entry_point(
            ep, f"{arch}:{ep.name}",
            layer_leaf_shapes=leaf_shapes, num_slots=engine.num_slots,
        ))

    if tier == "full" and engine.paged:
        # Re-trace the tick under each forced read path: the gathered
        # oracle must stay within ITS budget (2 full-stream reads), and the
        # pallas path must route through the kernel with zero XLA-level
        # stream gathers.  Trace-only — no run, no compile.
        variants = ["gathered"]
        if engine.model.cfg.mla is None:
            variants.append("pallas")
        base_sig, bucket = _captured_signature(engine._fused,
                                              largest_bucket=True)
        for path in variants:
            prev = attention.FORCE_PAGED_READ
            attention.FORCE_PAGED_READ = path
            try:
                v_engine, _ = build_engine(arch)
                ep = EntryPoint(
                    name=f"fused_tick[{path}]", jitfn=v_engine._fused,
                    avals=base_sig, donate=v_engine._fused.donate_argnums,
                    gather_budget=GATHER_BUDGETS[
                        (path, engine.model.cfg.mla is not None)],
                    bucket=bucket, compile_donation=False,
                )
                findings.extend(audit_entry_point(
                    ep, f"{arch}:{ep.name}",
                    layer_leaf_shapes=leaf_shapes,
                    num_slots=v_engine.num_slots,
                ))
                if path == "pallas":
                    traced = ep.jitfn.trace(*ep.avals)
                    if "pallas_call" not in primitive_names(traced.jaxpr):
                        findings.append(Finding(
                            "A-GATHER", "error", f"{arch}:{ep.name}",
                            "forced pallas read path traced without a "
                            "pallas_call — the kernel is not wired in",
                        ))
            finally:
                attention.FORCE_PAGED_READ = prev

        # Quantized-mode variant: run the same smoke workload with int8
        # arenas, then audit every entry point with the A-QUANT no-fp-arena
        # check active and re-pin the trace-key bounds — kv_dtype must not
        # add compile keys (the bucket grid is dtype-independent).
        q_engine, q_reqs = build_engine(arch, kv_dtype="int8")
        q_engine._fused.capture_avals = {}
        q_engine._decode.capture_avals = {}
        q_engine.run(q_reqs)
        q_metrics = q_engine.metrics()
        findings.extend(audit_trace_keys(
            q_engine, q_metrics, f"{arch}:int8:trace_keys"))
        q_leaf_shapes = [tuple(l.shape)
                         for l in jax.tree.leaves(q_engine.pool.cache["layers"])]
        for ep in collect_entry_points(q_engine, compile_donation=False):
            ep.quantized = True
            findings.extend(audit_entry_point(
                ep, f"{arch}:int8:{ep.name}",
                layer_leaf_shapes=q_leaf_shapes,
                num_slots=q_engine.num_slots,
            ))
    return findings


def run_audit(archs: Optional[list[str]] = None, *, tier: str = "full",
              compile_donation: bool = True,
              log=lambda msg: None) -> tuple[list[Finding], list[str]]:
    archs = list(archs) if archs else list_archs()
    findings: list[Finding] = []
    for arch in archs:
        log(f"audit: {arch}")
        findings.extend(
            audit_arch(arch, tier=tier, compile_donation=compile_donation)
        )
    return findings, archs
