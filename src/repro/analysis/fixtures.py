"""Known-bad / known-good fixtures for the Pass A audit rules.

Each audit rule gets a compact synthetic program that violates exactly one
invariant, fed through the *real* audit machinery (``audit_entry_point`` /
``check_trace_keys``) — plus a good twin that passes clean.  These back
``--self-check`` (every rule still catches its fixture) and
``--break-invariant RULE`` (non-zero exit with the responsible rule id,
the acceptance-criteria drill).

Lint-rule fixtures are source snippets and live on the rules themselves
(``rules.LINT_RULES``); this module covers the rules that need traced
programs rather than source text.
"""
from __future__ import annotations

import warnings

import jax
import jax.numpy as jnp

from repro.analysis.audit import EntryPoint, audit_entry_point, check_trace_keys
from repro.analysis.findings import Finding

# A tiny synthetic paged arena: (L=1, nb=8, bs=4, d=6), 2 slots, bucket 4.
# The (L, nb) entry mirrors a quantized pool's per-block scale leaf — the
# shape matchers must skip it (it is not an arena).
_L, _NB, _BS, _D = 1, 8, 4, 6
_N, _BUCKET = 2, 4
_LEAF_SHAPES = [(_L, _NB, _BS, _D), (_L, _NB)]
_ARENA = jax.ShapeDtypeStruct((_NB, _BS, _D), jnp.float32)
_ARENA_I8 = jax.ShapeDtypeStruct((_NB, _BS, _D), jnp.int8)
_SCALE = jax.ShapeDtypeStruct((_NB,), jnp.float32)
_TABLES = jax.ShapeDtypeStruct((_N, _BUCKET), jnp.int32)


def _gathered_read(arena, tables):
    # materializes the whole bucketed stream in one arena gather
    stream = arena[tables]                      # (N, BUCKET, BS, D)
    return stream.reshape(_N, _BUCKET * _BS, _D).sum(axis=1)


def _streamed_read(arena, tables):
    # one tile at a time: no gather output ever exceeds a single block
    def body(acc, tbl_col):
        tile = arena[tbl_col]                   # (N, BS, D)
        return acc + tile.sum(axis=1), None
    init = jnp.zeros((_N, _D), jnp.float32)
    acc, _ = jax.lax.scan(body, init, tables.T)
    return acc


def _entry(name, fn, avals, *, donate=(), budget=None, bucket=None,
           quantized=False, sentinel_outputs=0):
    return EntryPoint(
        name=name, jitfn=jax.jit(fn, donate_argnums=donate), avals=avals,
        donate=donate, gather_budget=budget, bucket=bucket,
        quantized=quantized, sentinel_outputs=sentinel_outputs,
    )


def _audit(ep) -> list[Finding]:
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")  # the dropped-donation UserWarning
        return audit_entry_point(
            ep, f"fixture:{ep.name}",
            layer_leaf_shapes=_LEAF_SHAPES, num_slots=_N,
        )


# --------------------------------------------------------- per-rule pairs --
def _gather_bad():
    return _audit(_entry("gathered_read_as_streamed", _gathered_read,
                         (_ARENA, _TABLES), budget=0, bucket=_BUCKET))


def _gather_good():
    return _audit(_entry("streamed_read", _streamed_read,
                         (_ARENA, _TABLES), budget=0, bucket=_BUCKET))


def _donate_bad():
    # cache donated but never used -> jax drops the donation silently
    def f(cache, x):
        return x * 2.0
    return _audit(_entry("donated_unused", f, (_ARENA, _ARENA), donate=(0,)))


def _donate_good():
    def f(cache, x):
        return cache + x
    return _audit(_entry("donated_in_place", f, (_ARENA, _ARENA), donate=(0,)))


def _f64_bad():
    def f(x):
        return jnp.cumsum(x) * 2.0
    aval = jax.ShapeDtypeStruct((8,), jnp.float64)
    with jax.experimental.enable_x64(True):
        return _audit(_entry("f64_tick", f, (aval,)))


def _f64_good():
    def f(x):
        return jnp.cumsum(x) * 2.0
    return _audit(_entry("f32_tick", f, (jax.ShapeDtypeStruct((8,), jnp.float32),)))


def _transfer_bad():
    def f(x):
        jax.debug.print("tick {}", x.sum())
        return x * 2.0
    return _audit(_entry("callback_in_tick", f,
                         (jax.ShapeDtypeStruct((8,), jnp.float32),)))


def _transfer_good():
    def f(x):
        return x * 2.0
    return _audit(_entry("pure_tick", f,
                         (jax.ShapeDtypeStruct((8,), jnp.float32),)))


def _metrics(fused_buckets, decode_buckets, grid, extra_fused=0):
    return {
        "horizon_bucket_grid": list(grid),
        "fused_buckets": list(fused_buckets),
        "decode_buckets": list(decode_buckets),
        "fused_step_compilations": len(fused_buckets) + extra_fused,
        "decode_compilations": len(decode_buckets),
        "prefill_compilations": 0,
        "fused_ticks": 1,
        "kv_paged": True,
    }


def _tracekey_bad():
    # one more fused compilation than buckets seen: an off-grid retrace
    m = _metrics([1, 2], [1], grid=[1, 2, 4], extra_fused=1)
    return check_trace_keys(m, "fixture:tracekey_extra_compile",
                            paged=True, max_seq=16, block_size=4,
                            engine_grid=[1, 2, 4])


def _quant_bad():
    # a quantized-mode tick reading a FLOAT arena: the fp stream exists in
    # HBM and the gather upcasts nothing — it was never int8 to begin with
    return _audit(_entry("fp_arena_in_quant_mode", _gathered_read,
                         (_ARENA, _TABLES), quantized=True))


def _quant_good():
    # int8 arena + per-block scales, dequant per streamed tile AFTER the
    # table read — no fp value ever has the arena's shape
    def quant_streamed(arena, scale, tables):
        def body(acc, tbl_col):
            tile = arena[tbl_col].astype(jnp.float32)   # (N, BS, D)
            tile = tile * scale[tbl_col][:, None, None]
            return acc + tile.sum(axis=1), None
        init = jnp.zeros((_N, _D), jnp.float32)
        acc, _ = jax.lax.scan(body, init, tables.T)
        return acc
    return _audit(_entry("int8_streamed_dequant", quant_streamed,
                         (_ARENA_I8, _SCALE, _TABLES), quantized=True))


def _sentinel_bad():
    # the probe was "optimized away": the tick emits a constant healthy
    # word regardless of what flows through the attention read
    def tick(arena, tables):
        out = _streamed_read(arena, tables)
        health = jnp.zeros((_N,), jnp.float32)          # disconnected
        return out, health
    return _audit(_entry("constant_health_tick", tick, (_ARENA, _TABLES),
                         sentinel_outputs=1))


def _sentinel_good():
    # health derived from the read itself: Σ-residual style reduction
    def tick(arena, tables):
        out = _streamed_read(arena, tables)
        health = jnp.abs(out.sum(axis=-1) - 1.0)
        return out, health
    return _audit(_entry("probed_tick", tick, (_ARENA, _TABLES),
                         sentinel_outputs=1))


def _tracekey_good():
    m = _metrics([1, 2], [1], grid=[1, 2, 4])
    return check_trace_keys(m, "fixture:tracekey_exact",
                            paged=True, max_seq=16, block_size=4,
                            engine_grid=[1, 2, 4])


AUDIT_FIXTURES = {
    "A-GATHER": (_gather_bad, _gather_good),
    "A-DONATE": (_donate_bad, _donate_good),
    "A-F64": (_f64_bad, _f64_good),
    "A-TRANSFER": (_transfer_bad, _transfer_good),
    "A-TRACEKEY": (_tracekey_bad, _tracekey_good),
    "A-QUANT": (_quant_bad, _quant_good),
    "A-SENTINEL": (_sentinel_bad, _sentinel_good),
}


def run_fixture(rule_id: str, which: str = "bad") -> list[Finding]:
    """Run one audit fixture through the real pipeline; returns findings."""
    bad, good = AUDIT_FIXTURES[rule_id]
    return bad() if which == "bad" else good()
