"""CLI for the static invariant auditor + lint.

    python -m repro.analysis --all                    # CI gate
    python -m repro.analysis --audit --archs internlm2-1.8b,minicpm3-4b
    python -m repro.analysis --lint src benchmarks examples
    python -m repro.analysis --self-check             # fixtures still bite
    python -m repro.analysis --break-invariant A-GATHER
    python -m repro.analysis --all --json findings.json

Exit code 0 iff no error-severity finding (warnings report but pass).
"""
from __future__ import annotations

import argparse
import sys

from repro.analysis import lint as lint_mod
from repro.analysis.findings import Finding, Report
from repro.analysis.rules import ALL_RULES, LINT_RULES

DEFAULT_LINT_PATHS = ["src", "benchmarks", "examples", "launch"]


def _log(msg: str) -> None:
    print(msg, file=sys.stderr, flush=True)


def run_self_check() -> tuple[list[Finding], dict]:
    """Every rule must flag its bad fixture and pass its good twin."""
    from repro.analysis.fixtures import AUDIT_FIXTURES

    findings: list[Finding] = []
    results: dict[str, str] = {}
    for rule_id, rule in LINT_RULES.items():
        bad = lint_mod.lint_source(rule.bad_fixture, f"fixture:{rule_id}:bad")
        good = lint_mod.lint_source(rule.good_fixture, f"fixture:{rule_id}:good")
        bad_hit = any(f.rule == rule_id for f in bad)
        good_hit = any(f.rule == rule_id for f in good)
        results[rule_id] = "ok" if bad_hit and not good_hit else "BROKEN"
        if not bad_hit:
            findings.append(Finding(
                rule_id, "error", f"fixture:{rule_id}:bad",
                "rule did not flag its known-bad fixture (rule is blind)",
            ))
        if good_hit:
            findings.append(Finding(
                rule_id, "error", f"fixture:{rule_id}:good",
                "rule flagged its known-good twin (false positive)",
            ))
    for rule_id, (bad_fn, good_fn) in AUDIT_FIXTURES.items():
        bad_hit = any(f.rule == rule_id for f in bad_fn())
        good = good_fn()
        results[rule_id] = "ok" if bad_hit and not good else "BROKEN"
        if not bad_hit:
            findings.append(Finding(
                rule_id, "error", f"fixture:{rule_id}:bad",
                "audit did not flag its known-bad fixture (rule is blind)",
            ))
        for f in good:
            findings.append(Finding(
                rule_id, "error", f"fixture:{rule_id}:good",
                f"audit flagged the known-good twin: {f.message}",
            ))
    return findings, results


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static invariant auditor (jaxpr/HLO) + recompile-hazard lint",
    )
    ap.add_argument("--all", action="store_true",
                    help="audit the full registry + lint + self-check (CI gate)")
    ap.add_argument("--audit", action="store_true", help="run Pass A")
    ap.add_argument("--lint", action="store_true", help="run Pass B")
    ap.add_argument("--self-check", action="store_true",
                    help="run every rule against its bad/good fixtures")
    ap.add_argument("--break-invariant", metavar="RULE",
                    help="feed RULE's known-bad fixture through the real "
                         "pipeline (must exit non-zero with that rule id)")
    ap.add_argument("--archs", default="",
                    help="comma-separated registry archs (default: all)")
    ap.add_argument("--tier", choices=("default", "full"), default="full",
                    help="'full' adds forced gathered/pallas read-path "
                         "variants per paged arch")
    ap.add_argument("--no-compile", action="store_true",
                    help="skip the compiled-executable donation check "
                         "(lowering-level aliasing marks only)")
    ap.add_argument("paths", nargs="*",
                    help=f"lint paths (default: {' '.join(DEFAULT_LINT_PATHS)})")
    ap.add_argument("--json", metavar="FILE", help="write the findings report")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule in ALL_RULES.values():
            print(f"{rule.id:20s} {rule.pass_name:5s} {rule.severity:7s} "
                  f"{rule.summary}")
        return 0

    report = Report()

    if args.break_invariant:
        rule_id = args.break_invariant
        if rule_id not in ALL_RULES:
            ap.error(f"unknown rule {rule_id!r} (see --list-rules)")
        report.passes.append(f"break-invariant:{rule_id}")
        if rule_id in LINT_RULES:
            found = lint_mod.lint_source(
                LINT_RULES[rule_id].bad_fixture, f"fixture:{rule_id}:bad"
            )
        else:
            from repro.analysis.fixtures import run_fixture
            found = run_fixture(rule_id, "bad")
        report.extend(found)
        hit = any(f.rule == rule_id for f in found)
        if not hit:
            report.extend([Finding(
                rule_id, "error", f"fixture:{rule_id}:bad",
                "fixture did NOT trigger its rule — the audit is blind",
            )])
        _finish(report, args)
        # broken invariant => non-zero, by design
        return 1 if hit or not report.ok else 0

    if args.all:
        args.audit = args.lint = args.self_check = True

    if not (args.audit or args.lint or args.self_check):
        ap.error("nothing to do: pass --all, --audit, --lint or --self-check")

    if args.lint:
        report.passes.append("lint")
        paths = args.paths or DEFAULT_LINT_PATHS
        findings, n = lint_mod.lint_paths(paths)
        report.extend(findings)
        report.linted_files = n
        _log(f"lint: {n} files, {len(findings)} findings")

    if args.self_check:
        report.passes.append("self-check")
        findings, results = run_self_check()
        report.extend(findings)
        report.self_check = results
        broken = [r for r, v in results.items() if v != "ok"]
        _log(f"self-check: {len(results)} rules, "
             + (f"BROKEN: {broken}" if broken else "all fixtures bite"))

    if args.audit:
        from repro.analysis.audit import run_audit

        report.passes.append("audit")
        archs = [a for a in args.archs.split(",") if a] or None
        findings, audited = run_audit(
            archs, tier=args.tier,
            compile_donation=not args.no_compile, log=_log,
        )
        report.extend(findings)
        report.audited_archs = audited

    _finish(report, args)
    return 0 if report.ok else 1


def _finish(report: Report, args) -> None:
    for f in report.findings:
        print(f.format())
    d = report.to_dict()
    print(f"passes={','.join(report.passes)} findings={d['num_findings']} "
          f"errors={d['num_errors']} ok={report.ok}")
    if args.json:
        with open(args.json, "w") as fh:
            fh.write(report.to_json())
        _log(f"report written to {args.json}")


if __name__ == "__main__":
    sys.exit(main())
