"""Static enumeration of the engine's (step-kind × horizon-bucket) trace keys.

The engine promises compile-once ticks: every jitted step specializes only
on the step kind (fused vs decode) and, for paged pools, on the horizon
bucket (the power-of-two number of block-table columns the tick reads).
This module derives that trace-key space *from configuration alone* — no
engine, no tracing — so the compile-count pins in
``tests/_serve_helpers.assert_exact_compile_counters`` and the Pass A
``A-TRACEKEY`` audit share one source of truth instead of an empirical
constant.
"""
from __future__ import annotations

from typing import Iterable, Optional

STEP_KINDS = ("fused", "decode")


def horizon_bucket_grid(max_seq: int, block_size: int) -> list[int]:
    """Power-of-two horizon buckets for a paged pool.

    Mirrors ``ContinuousEngine.__init__``: buckets double from 1 up to the
    per-slot block capacity, which is always the final bucket (so the
    full-horizon read is representable even when capacity is not a power
    of two).
    """
    if max_seq <= 0 or block_size <= 0:
        raise ValueError(f"max_seq={max_seq}, block_size={block_size} must be positive")
    max_blocks_per_slot = -(-max_seq // block_size)
    grid: list[int] = []
    b = 1
    while b < max_blocks_per_slot:
        grid.append(b)
        b *= 2
    grid.append(max_blocks_per_slot)
    return grid


def trace_key_space(
    *,
    paged: bool,
    max_seq: Optional[int] = None,
    block_size: Optional[int] = None,
    grid: Optional[Iterable[int]] = None,
) -> set[tuple[str, Optional[int]]]:
    """All (step_kind, bucket) keys a compliant engine may ever trace.

    Slab pools have no horizon dimension: the key space is
    ``{(fused, None), (decode, None)}``.  Paged pools cross the step kinds
    with the bucket grid (pass ``grid`` explicitly, or ``max_seq`` +
    ``block_size`` to derive it).
    """
    if not paged:
        return {(kind, None) for kind in STEP_KINDS}
    if grid is None:
        if max_seq is None or block_size is None:
            raise ValueError("paged trace_key_space needs grid or max_seq+block_size")
        grid = horizon_bucket_grid(max_seq, block_size)
    return {(kind, int(b)) for kind in STEP_KINDS for b in grid}


def compile_bound(
    *,
    paged: bool,
    max_seq: Optional[int] = None,
    block_size: Optional[int] = None,
    grid: Optional[Iterable[int]] = None,
) -> dict[str, int]:
    """Max compilations per step kind implied by the trace-key space."""
    keys = trace_key_space(paged=paged, max_seq=max_seq, block_size=block_size, grid=grid)
    return {kind: sum(1 for k, _ in keys if k == kind) for kind in STEP_KINDS}


def seen_trace_keys(metrics: dict) -> set[tuple[str, Optional[int]]]:
    """Trace keys an engine actually compiled, from ``engine.metrics()``."""
    if "horizon_bucket_grid" in metrics:
        return {("fused", int(b)) for b in metrics.get("fused_buckets", [])} | {
            ("decode", int(b)) for b in metrics.get("decode_buckets", [])
        }
    seen: set[tuple[str, Optional[int]]] = set()
    if metrics.get("fused_step_compilations", 0):
        seen.add(("fused", None))
    if metrics.get("decode_compilations", 0):
        seen.add(("decode", None))
    return seen


def format_trace_key_diff(
    expected: set[tuple[str, Optional[int]]],
    seen: set[tuple[str, Optional[int]]],
    counts: Optional[dict[str, int]] = None,
) -> str:
    """Human-readable expected-vs-seen trace-key table for assert messages."""

    def _fmt(keys: set[tuple[str, Optional[int]]]) -> str:
        if not keys:
            return "(none)"
        return ", ".join(
            f"({kind}, bucket={bucket})" if bucket is not None else f"({kind},)"
            for kind, bucket in sorted(keys, key=lambda k: (k[0], -1 if k[1] is None else k[1]))
        )

    lines = [
        "trace-key space (step kind, horizon bucket):",
        f"  allowed : {_fmt(expected)}",
        f"  seen    : {_fmt(seen)}",
    ]
    extra = seen - expected
    if extra:
        lines.append(f"  EXTRA (recompile hazard!): {_fmt(extra)}")
    if counts:
        lines.append(
            "  compilations: "
            + ", ".join(f"{kind}={n}" for kind, n in sorted(counts.items()))
        )
    return "\n".join(lines)
