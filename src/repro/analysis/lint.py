"""Pass B: repo-wide AST lint for recompile/correctness hazards.

The rules (ids, severities, fixtures) live in ``rules.py``; this module is
the engine.  Per file it builds:

* an import table (module-level imports and their use counts),
* a *jit registry*: functions that are jitted — decorated with
  ``jax.jit`` / ``functools.partial(jax.jit, ...)``, or referenced by a
  ``jax.jit(fn, ...)`` / ``CountingJit(fn, ...)`` call — together with
  their ``static_argnums/argnames`` and ``donate_argnums``,
* per-function traced-parameter sets (params minus self/static),

then walks every function body once, emitting findings keyed by rule id.
The analysis is deliberately syntactic: it never imports the linted code,
so it runs identically on fixtures, benchmarks and the live tree.
"""
from __future__ import annotations

import ast
import dataclasses
from pathlib import Path
from typing import Optional

from repro.analysis.findings import Finding

MUTABLE_CALLS = {"list", "dict", "set", "deque", "defaultdict", "Counter",
                 "OrderedDict", "bytearray"}
CAST_CALLS = {"int", "float", "bool", "complex"}
ITEM_METHODS = {"item", "tolist", "__index__"}
NUMPY_ALIASES = {"np", "numpy"}
# numpy attributes that are pure metadata/constants — safe on traced values
NUMPY_SAFE_ATTRS = {"shape", "ndim", "dtype", "float32", "float64", "int32",
                    "int64", "bool_", "uint32", "pi", "inf", "nan", "newaxis",
                    "intp", "issubdtype", "floating", "complexfloating",
                    "integer", "number", "result_type", "promote_types"}


def dotted(node: ast.AST) -> Optional[str]:
    """'self.pool.cache'-style dotted name for Name/Attribute chains."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


@dataclasses.dataclass
class JitInfo:
    fn_name: Optional[str]          # module-local callee name, if resolvable
    static_argnums: tuple = ()
    static_argnames: tuple = ()
    donate_argnums: tuple = ()
    lineno: int = 0


def _const_tuple(node) -> tuple:
    if isinstance(node, ast.Constant) and isinstance(node.value, (int, str)):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        return tuple(e.value for e in node.elts
                     if isinstance(e, ast.Constant))
    return ()


def _jit_call_info(call: ast.Call) -> Optional[JitInfo]:
    """If ``call`` is jax.jit(...) / jit(...) / CountingJit(...) /
    functools.partial(jax.jit, ...), extract the jit metadata."""
    fname = dotted(call.func)
    if fname in ("functools.partial", "partial") and call.args:
        inner = dotted(call.args[0])
        if inner in ("jax.jit", "jit"):
            info = JitInfo(fn_name=None, lineno=call.lineno)
            _fill_kwargs(info, call.keywords)
            return info
        return None
    if fname not in ("jax.jit", "jit", "CountingJit", "engine.CountingJit"):
        return None
    info = JitInfo(fn_name=None, lineno=call.lineno)
    if call.args:
        target = dotted(call.args[0])
        if target:
            info.fn_name = target.split(".")[-1]  # methods bind by attr name
    _fill_kwargs(info, call.keywords)
    return info


def _fill_kwargs(info: JitInfo, keywords) -> None:
    for kw in keywords:
        if kw.arg == "static_argnums":
            info.static_argnums = _const_tuple(kw.value)
        elif kw.arg == "static_argnames":
            info.static_argnames = _const_tuple(kw.value)
        elif kw.arg == "donate_argnums":
            info.donate_argnums = _const_tuple(kw.value)


class _FunctionIndex(ast.NodeVisitor):
    """All function defs (any nesting) + which are jitted and how."""

    def __init__(self):
        self.defs: dict[str, ast.FunctionDef] = {}
        self.jits: dict[str, JitInfo] = {}          # fn name -> jit info
        self.jit_targets: dict[str, JitInfo] = {}   # bound name -> jit info

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self.defs.setdefault(node.name, node)
        for dec in node.decorator_list:
            name = dotted(dec)
            if name in ("jax.jit", "jit"):
                self.jits[node.name] = JitInfo(fn_name=node.name,
                                               lineno=node.lineno)
            elif isinstance(dec, ast.Call):
                info = _jit_call_info(dec)
                if info is not None:
                    info.fn_name = node.name
                    self.jits[node.name] = info
        self.generic_visit(node)

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Call(self, node: ast.Call) -> None:
        info = _jit_call_info(node)
        if info is not None and info.fn_name:
            self.jits.setdefault(info.fn_name, info)
        self.generic_visit(node)

    def visit_Assign(self, node: ast.Assign) -> None:
        if isinstance(node.value, ast.Call):
            info = _jit_call_info(node.value)
            if info is not None:
                for t in node.targets:
                    name = dotted(t)
                    if name:
                        self.jit_targets[name] = info
                if info.fn_name:
                    self.jits.setdefault(info.fn_name, info)
        self.generic_visit(node)


def _traced_params(fn: ast.FunctionDef, info: JitInfo) -> set[str]:
    args = [a.arg for a in fn.args.posonlyargs + fn.args.args]
    offset = 1 if args and args[0] in ("self", "cls") else 0
    traced = []
    for i, name in enumerate(args[offset:]):
        if i in info.static_argnums or name in info.static_argnames:
            continue
        traced.append(name)
    return {n for n in traced if n not in ("self", "cls")}


def _stmt_sequence(body: list[ast.stmt]):
    """Statements of a function body in source order, descending into
    compound statements (the donated-rebind scan needs linear order)."""
    for stmt in body:
        yield stmt
        for field in ("body", "orelse", "finalbody"):
            sub = getattr(stmt, field, None)
            if sub:
                yield from _stmt_sequence(sub)
        for handler in getattr(stmt, "handlers", []) or []:
            yield from _stmt_sequence(handler.body)


def _names_loaded(node: ast.AST) -> set[str]:
    out = set()
    for sub in ast.walk(node):
        if isinstance(sub, (ast.Name, ast.Attribute)):
            d = dotted(sub)
            if d and isinstance(getattr(sub, "ctx", None), ast.Load):
                out.add(d)
    return out


def _assign_targets(stmt: ast.stmt) -> set[str]:
    targets = []
    if isinstance(stmt, ast.Assign):
        targets = stmt.targets
    elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)) and stmt.target:
        targets = [stmt.target]
    elif isinstance(stmt, ast.For):
        targets = [stmt.target]
    elif isinstance(stmt, (ast.With, ast.AsyncWith)):
        targets = [i.optional_vars for i in stmt.items if i.optional_vars]
    out = set()
    for t in targets:
        for el in ast.walk(t):
            if isinstance(el, (ast.Name, ast.Attribute)):
                d = dotted(el)
                if d:
                    out.add(d)
    return out


def _header_exprs(stmt: ast.stmt) -> list[ast.AST]:
    """The expressions a compound statement evaluates *itself* — its body
    statements are yielded (and checked) separately by _stmt_sequence, so
    scanning the whole subtree here would double-count nested reads
    against their own rebinds."""
    if isinstance(stmt, (ast.If, ast.While)):
        return [stmt.test]
    if isinstance(stmt, (ast.For, ast.AsyncFor)):
        return [stmt.iter]
    if isinstance(stmt, (ast.With, ast.AsyncWith)):
        return [i.context_expr for i in stmt.items]
    if isinstance(stmt, ast.Try):
        return []
    return [stmt]


class FileLinter:
    def __init__(self, path: str, src: str):
        self.path = path
        self.tree = ast.parse(src)
        self.findings: list[Finding] = []
        self.index = _FunctionIndex()
        self.index.visit(self.tree)

    def _emit(self, rule: str, severity: str, lineno: int, msg: str) -> None:
        self.findings.append(
            Finding(rule, severity, f"{self.path}:{lineno}", msg)
        )

    # ------------------------------------------------------- module level --
    def check_unused_imports(self) -> None:
        if Path(self.path).name == "__init__.py":
            return  # re-export surface: unused-at-module-scope is the point
        imported: dict[str, int] = {}
        for node in self.tree.body:
            if isinstance(node, ast.Import):
                for a in node.names:
                    name = a.asname or a.name.split(".")[0]
                    imported[name] = node.lineno
            elif isinstance(node, ast.ImportFrom):
                if node.module == "__future__":
                    continue
                for a in node.names:
                    if a.name == "*":
                        continue
                    if a.asname == a.name:
                        continue  # explicit re-export (PEP 484 idiom)
                    imported[a.asname or a.name] = node.lineno
        used: set[str] = set()
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Name):
                used.add(node.id)
            elif isinstance(node, ast.Attribute):
                d = dotted(node)
                if d:
                    used.add(d.split(".")[0])
            elif isinstance(node, ast.Constant) and isinstance(node.value, str):
                pass
        # names quoted in __all__
        for node in self.tree.body:
            if (isinstance(node, ast.Assign)
                    and any(dotted(t) == "__all__" for t in node.targets)):
                for el in ast.walk(node.value):
                    if isinstance(el, ast.Constant) and isinstance(el.value, str):
                        used.add(el.value)
        for name, lineno in imported.items():
            if name not in used:
                self._emit("L-UNUSED-IMPORT", "warning", lineno,
                           f"import '{name}' is never used")

    # ----------------------------------------------------- function level --
    def check_functions(self) -> None:
        for fn in (n for n in ast.walk(self.tree)
                   if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))):
            self._check_mutable_defaults(fn)
            info = self.index.jits.get(fn.name)
            if info is not None:
                self._check_traced_body(fn, info)
                self._check_static_hashability(fn, info)
            self._check_donated_rebind(fn)

    def _check_mutable_defaults(self, fn) -> None:
        for default in list(fn.args.defaults) + [
            d for d in fn.args.kw_defaults if d is not None
        ]:
            mutable = isinstance(default, (ast.List, ast.Dict, ast.Set,
                                           ast.ListComp, ast.DictComp,
                                           ast.SetComp))
            if isinstance(default, ast.Call):
                callee = dotted(default.func)
                mutable = callee in MUTABLE_CALLS
            if mutable:
                self._emit("L-MUT-DEFAULT", "error", default.lineno,
                           f"mutable default argument in '{fn.name}' is "
                           "shared across calls (and hash-unstable if the "
                           "function is ever jitted with it static)")

    def _check_static_hashability(self, fn, info: JitInfo) -> None:
        args = [a.arg for a in fn.args.posonlyargs + fn.args.args]
        offset = 1 if args and args[0] in ("self", "cls") else 0
        static = {args[offset + i] for i in info.static_argnums
                  if isinstance(i, int) and offset + i < len(args)}
        static |= set(info.static_argnames)
        defaults = fn.args.defaults
        defaulted = args[len(args) - len(defaults):]
        for name, default in zip(defaulted, defaults):
            if name not in static:
                continue
            unhashable = isinstance(default, (ast.List, ast.Dict, ast.Set))
            if isinstance(default, ast.Call):
                unhashable = dotted(default.func) in MUTABLE_CALLS
            if unhashable:
                self._emit("L-STATIC-UNHASHABLE", "error", default.lineno,
                           f"static arg '{name}' of jitted '{fn.name}' has an "
                           "unhashable default — every call raises (or, with "
                           "a hashable-but-mutable value, silently retraces)")

    def _check_traced_body(self, fn, info: JitInfo) -> None:
        traced = _traced_params(fn, info)
        if not traced:
            return

        def is_traced(node) -> bool:
            return any(isinstance(sub, ast.Name) and sub.id in traced
                       for sub in ast.walk(node))

        def identity_test(node) -> bool:
            # `x is None` / `x is not y` never concretizes a tracer
            return (isinstance(node, ast.Compare)
                    and all(isinstance(op, (ast.Is, ast.IsNot))
                            for op in node.ops))

        for node in ast.walk(fn):
            if identity_test(getattr(node, "test", None)):
                continue
            if isinstance(node, (ast.If, ast.While)) and is_traced(node.test):
                self._emit("L-TRACED-BRANCH", "error", node.lineno,
                           f"python branch on traced value in jitted "
                           f"'{fn.name}' — concretization error at trace "
                           "time (use lax.cond/jnp.where)")
            elif isinstance(node, ast.IfExp) and is_traced(node.test):
                self._emit("L-TRACED-BRANCH", "error", node.lineno,
                           f"conditional expression on traced value in "
                           f"jitted '{fn.name}' (use jnp.where)")
            elif isinstance(node, ast.Call):
                callee = dotted(node.func)
                if (callee in CAST_CALLS
                        and any(is_traced(a) for a in node.args)):
                    self._emit("L-TRACED-CAST", "error", node.lineno,
                               f"{callee}() on traced value in jitted "
                               f"'{fn.name}' — host sync / concretization "
                               "at trace time")
                elif (isinstance(node.func, ast.Attribute)
                      and node.func.attr in ITEM_METHODS
                      and is_traced(node.func.value)):
                    self._emit("L-TRACED-CAST", "error", node.lineno,
                               f".{node.func.attr}() on traced value in "
                               f"jitted '{fn.name}' — host sync inside jit")
                elif (callee and callee.split(".")[0] in NUMPY_ALIASES
                      and callee.split(".")[-1] not in NUMPY_SAFE_ATTRS
                      and any(is_traced(a) for a in node.args)):
                    self._emit("L-NP-TRACED", "error", node.lineno,
                               f"numpy call {callee}() on traced value in "
                               f"jitted '{fn.name}' — silent host round-trip "
                               "(use jnp)")

    def _check_donated_rebind(self, fn) -> None:
        stmts = list(_stmt_sequence(fn.body))
        hazards: dict[str, int] = {}  # dotted name -> lineno of donating call
        for stmt in stmts:
            headers = _header_exprs(stmt)
            # use-before-rebind of an already-donated buffer?
            if hazards:
                loaded = set()
                for h in headers:
                    loaded |= _names_loaded(h)
                targets = _assign_targets(stmt)
                for name in list(hazards):
                    if name in loaded and name not in targets:
                        self._emit(
                            "L-DONATED-REBIND", "error", stmt.lineno,
                            f"'{name}' was donated to a jitted call at line "
                            f"{hazards[name]} and read again before being "
                            "rebound — donated buffers are invalidated",
                        )
                        del hazards[name]
            targets = _assign_targets(stmt)
            for name in targets:
                hazards.pop(name, None)
            for call in (n for h in headers for n in ast.walk(h)
                         if isinstance(n, ast.Call)):
                callee = dotted(call.func)
                info = (self.index.jit_targets.get(callee)
                        if callee else None)
                if info is None or not info.donate_argnums:
                    continue
                for i in info.donate_argnums:
                    if not isinstance(i, int) or i >= len(call.args):
                        continue
                    name = dotted(call.args[i])
                    if name and name not in targets:
                        hazards[name] = stmt.lineno

    def run(self) -> list[Finding]:
        self.check_unused_imports()
        self.check_functions()
        return self.findings


def lint_source(src: str, path: str = "<string>") -> list[Finding]:
    return FileLinter(path, src).run()


def lint_paths(paths: list[str], root: str = ".") -> tuple[list[Finding], int]:
    """Lint every .py file under ``paths`` (files or directories, relative
    to ``root``).  Returns (findings, files_linted)."""
    rootp = Path(root)
    files: list[Path] = []
    for p in paths:
        pp = rootp / p
        if pp.is_dir():
            files.extend(sorted(pp.rglob("*.py")))
        elif pp.suffix == ".py":
            files.append(pp)
    findings: list[Finding] = []
    for f in files:
        rel = str(f.relative_to(rootp)) if f.is_relative_to(rootp) else str(f)
        findings.extend(lint_source(f.read_text(), rel))
    return findings, len(files)
