"""Findings and the machine-readable report the CLI emits for CI."""
from __future__ import annotations

import dataclasses
import json
from typing import Optional


@dataclasses.dataclass
class Finding:
    """One rule violation.

    ``rule`` is a registered rule id (see ``rules.ALL_RULES``); ``where``
    locates it — ``path:line`` for lint findings, ``arch:entry_point`` for
    audit findings — and ``severity`` decides the exit code (any 'error'
    finding fails the gate; 'warning' findings are reported but pass).
    """

    rule: str
    severity: str  # 'error' | 'warning'
    where: str
    message: str

    def format(self) -> str:
        return f"{self.severity.upper():7s} {self.rule:18s} {self.where}: {self.message}"


@dataclasses.dataclass
class Report:
    """The full run: which passes ran, over what, and what they found."""

    findings: list[Finding] = dataclasses.field(default_factory=list)
    passes: list[str] = dataclasses.field(default_factory=list)
    audited_archs: list[str] = dataclasses.field(default_factory=list)
    linted_files: int = 0
    self_check: Optional[dict] = None

    def extend(self, findings: list[Finding]) -> None:
        self.findings.extend(findings)

    @property
    def errors(self) -> list[Finding]:
        return [f for f in self.findings if f.severity == "error"]

    @property
    def ok(self) -> bool:
        return not self.errors

    def to_dict(self) -> dict:
        by_rule: dict[str, int] = {}
        for f in self.findings:
            by_rule[f.rule] = by_rule.get(f.rule, 0) + 1
        return {
            "ok": self.ok,
            "passes": self.passes,
            "audited_archs": self.audited_archs,
            "linted_files": self.linted_files,
            "num_findings": len(self.findings),
            "num_errors": len(self.errors),
            "findings_by_rule": by_rule,
            "findings": [dataclasses.asdict(f) for f in self.findings],
            "self_check": self.self_check,
        }

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2, sort_keys=True)
