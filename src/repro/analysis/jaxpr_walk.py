"""Recursive jaxpr equation walker used by the Pass A auditor.

Walks every equation in a closed jaxpr, descending into sub-jaxprs held in
equation params (scan/while/cond bodies, custom_vjp calls, ...).  Bodies
of primitives named in ``OPAQUE_PRIMITIVES`` are *not* entered: a
``pallas_call`` kernel body manipulates refs inside the kernel's own
index space, so its loads/stores are not XLA gathers and are audited as a
unit (the kernel is the gather-free read, by construction).
"""
from __future__ import annotations

from typing import Iterator

from jax._src.core import ClosedJaxpr, Jaxpr, JaxprEqn

# Kernel-body primitives whose inner jaxpr is not XLA dataflow.
OPAQUE_PRIMITIVES = frozenset({"pallas_call"})


def _sub_jaxprs(params: dict) -> Iterator[Jaxpr]:
    for v in params.values():
        if isinstance(v, (ClosedJaxpr, Jaxpr)):
            yield v.jaxpr if isinstance(v, ClosedJaxpr) else v
        elif isinstance(v, (list, tuple)):
            for item in v:
                if isinstance(item, (ClosedJaxpr, Jaxpr)):
                    yield item.jaxpr if isinstance(item, ClosedJaxpr) else item


def iter_eqns(jaxpr, *, skip=OPAQUE_PRIMITIVES) -> Iterator[JaxprEqn]:
    """Yield every equation reachable from ``jaxpr``, outermost first."""
    if isinstance(jaxpr, ClosedJaxpr):
        jaxpr = jaxpr.jaxpr
    for eqn in jaxpr.eqns:
        yield eqn
        if eqn.primitive.name in skip:
            continue
        for sub in _sub_jaxprs(eqn.params):
            yield from iter_eqns(sub, skip=skip)


def eqns_by_name(jaxpr, name: str) -> list[JaxprEqn]:
    """All equations (recursively) whose primitive is ``name``."""
    return [e for e in iter_eqns(jaxpr) if e.primitive.name == name]


def primitive_names(jaxpr) -> set[str]:
    """The set of primitive names appearing anywhere in ``jaxpr``."""
    return {e.primitive.name for e in iter_eqns(jaxpr)}


def out_dtypes(jaxpr) -> set:
    """Dtypes of every equation output in ``jaxpr`` (recursively)."""
    dts = set()
    for eqn in iter_eqns(jaxpr):
        for v in eqn.outvars:
            aval = getattr(v, "aval", None)
            dt = getattr(aval, "dtype", None)
            if dt is not None:
                dts.add(dt)
    return dts
