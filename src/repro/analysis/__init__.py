"""repro.analysis — static invariant auditor + recompile-hazard lint.

Every guarantee the serving stack advertises (gather-free paged reads,
donated in-place cache ticks, bounded compile counts per horizon bucket,
no f64/upcast drift, no host transfers inside a tick) is enforced at
runtime by counter asserts and identity oracles.  This package proves the
same invariants *statically*, from the traced program:

* **Pass A** (``audit``) lowers every jitted serving entry point — fused
  and decode ticks, spill gather/scatter, prefix COW fork, slot insert —
  to jaxpr + compiled HLO for each registry arch and asserts structural
  invariants (see ``docs/analysis.md`` for the rule catalog).
* **Pass B** (``lint``) is a repo-wide AST lint for recompile/correctness
  hazards: Python branching or casts on traced values inside jitted
  functions, hash-unstable static args, mutable default args, ``np.``
  leaking into traced code, rebinding a donated buffer after use.

CLI: ``python -m repro.analysis --all`` (CI gate).  Each rule carries a
known-bad fixture it must flag and a known-good twin it must pass
(``--self-check``); ``--break-invariant RULE`` feeds the bad fixture
through the real pipeline and must exit non-zero with that rule id.
"""
from repro.analysis.findings import Finding, Report
from repro.analysis.rules import ALL_RULES, AUDIT_RULES, LINT_RULES
from repro.analysis.tracekeys import (
    compile_bound,
    format_trace_key_diff,
    horizon_bucket_grid,
    trace_key_space,
)

__all__ = [
    "Finding",
    "Report",
    "ALL_RULES",
    "AUDIT_RULES",
    "LINT_RULES",
    "horizon_bucket_grid",
    "trace_key_space",
    "compile_bound",
    "format_trace_key_diff",
]
