"""The rule registry: every auditor/lint rule with id, severity, summary,
and a known-bad fixture it must flag plus a known-good twin it must pass.

``--self-check`` runs each lint fixture through the real lint engine and
each audit fixture through the real audit checks (see ``fixtures.py``),
so a refactor that silently blinds a rule fails CI the same way a real
violation would.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class Rule:
    id: str
    pass_name: str        # 'audit' | 'lint'
    severity: str         # 'error' | 'warning'
    summary: str
    bad_fixture: Optional[str] = None   # lint: source that must flag
    good_fixture: Optional[str] = None  # lint: twin that must pass


LINT_RULES: dict[str, Rule] = {}
AUDIT_RULES: dict[str, Rule] = {}


def _lint(rule: Rule) -> Rule:
    LINT_RULES[rule.id] = rule
    return rule


def _audit(rule: Rule) -> Rule:
    AUDIT_RULES[rule.id] = rule
    return rule


# ------------------------------------------------------------ Pass A ------
_audit(Rule(
    "A-GATHER", "audit", "error",
    "paged tick jaxpr materializes the block stream with an arena gather "
    "beyond the read path's budget (streamed dense: 1, streamed MLA: 0, "
    "pallas: 0, gathered oracle: 2)",
))
_audit(Rule(
    "A-DONATE", "audit", "error",
    "a donate_argnums buffer produces no input-output aliasing in the "
    "lowered/compiled program — donation silently dropped, the tick "
    "copies the cache instead of updating in place",
))
_audit(Rule(
    "A-F64", "audit", "error",
    "float64/complex128 value inside a jitted serving entry point "
    "(unintended upcast; ticks compute in the model dtype + f32)",
))
_audit(Rule(
    "A-TRANSFER", "audit", "error",
    "host transfer or callback primitive inside a tick body",
))
_audit(Rule(
    "A-TRACEKEY", "audit", "error",
    "the engine traced a (step kind, horizon bucket) key outside the "
    "statically enumerated space, or CountingJit totals disagree with "
    "the derived per-kind bound",
))
_audit(Rule(
    "A-QUANT", "audit", "error",
    "quantized-mode (kv_dtype=int8) program holds a floating-typed value "
    "at a full KV arena shape — the fp stream was materialized (or "
    "upcast-then-gathered) instead of per-tile dequant after the "
    "block-table read",
))
_audit(Rule(
    "A-SENTINEL", "audit", "error",
    "a sentinel-enabled tick's trailing health output is not "
    "data-dependent on the tick inputs (constant-foldable) — the GN "
    "runtime probe is disconnected and corruption reads as healthy",
))


# ------------------------------------------------------------ Pass B ------
_lint(Rule(
    "L-TRACED-BRANCH", "lint", "error",
    "python if/while on a traced value inside a jitted function",
    bad_fixture="""\
import jax

@jax.jit
def tick(x, active):
    if active:
        return x + 1
    return x
""",
    good_fixture="""\
import jax
import jax.numpy as jnp

@jax.jit
def tick(x, active):
    return jnp.where(active, x + 1, x)
""",
))
_lint(Rule(
    "L-TRACED-CAST", "lint", "error",
    "int()/float()/.item() on a traced value inside a jitted function "
    "(host sync / concretization error)",
    bad_fixture="""\
import jax

@jax.jit
def tick(x, pos):
    return x[int(pos)]
""",
    good_fixture="""\
import jax

@jax.jit
def tick(x, pos):
    return jax.lax.dynamic_index_in_dim(x, pos, keepdims=False)
""",
))
_lint(Rule(
    "L-NP-TRACED", "lint", "error",
    "numpy (not jnp) call on a traced value inside a jitted function — "
    "silent host round-trip, breaks under transfer guard",
    bad_fixture="""\
import jax
import numpy as np

@jax.jit
def tick(x):
    return np.sum(x)
""",
    good_fixture="""\
import jax
import jax.numpy as jnp
import numpy as np

@jax.jit
def tick(x):
    return jnp.sum(x) * np.float32(2.0)
""",
))
_lint(Rule(
    "L-STATIC-UNHASHABLE", "lint", "error",
    "a static_argnums/argnames arg of a jitted function has an unhashable "
    "default (every call raises — or silently retraces)",
    bad_fixture="""\
import functools
import jax

@functools.partial(jax.jit, static_argnames=("dims",))
def reduce_over(x, dims=[0, 1]):
    return x.sum(dims)
""",
    good_fixture="""\
import functools
import jax

@functools.partial(jax.jit, static_argnames=("dims",))
def reduce_over(x, dims=(0, 1)):
    return x.sum(dims)
""",
))
_lint(Rule(
    "L-MUT-DEFAULT", "lint", "error",
    "mutable default argument (shared across calls)",
    bad_fixture="""\
def admit(req, queue=[]):
    queue.append(req)
    return queue
""",
    good_fixture="""\
def admit(req, queue=None):
    queue = [] if queue is None else queue
    queue.append(req)
    return queue
""",
))
_lint(Rule(
    "L-DONATED-REBIND", "lint", "error",
    "a buffer passed through donate_argnums is read again before being "
    "rebound — donated buffers are invalidated by the call",
    bad_fixture="""\
import jax

def _tick(cache, x):
    return cache + x, x.sum()

step = jax.jit(_tick, donate_argnums=(0,))

def run(cache, x):
    out, s = step(cache, x)
    return cache.sum() + s
""",
    good_fixture="""\
import jax

def _tick(cache, x):
    return cache + x, x.sum()

step = jax.jit(_tick, donate_argnums=(0,))

def run(cache, x):
    cache, s = step(cache, x)
    return cache.sum() + s
""",
))
_lint(Rule(
    "L-UNUSED-IMPORT", "lint", "warning",
    "module-level import never used (outside __init__.py re-exports)",
    bad_fixture="""\
import os
import sys

def main():
    return sys.argv
""",
    good_fixture="""\
import sys

def main():
    return sys.argv
""",
))

ALL_RULES: dict[str, Rule] = {**AUDIT_RULES, **LINT_RULES}
