"""AdamW in pure JAX (pytrees), with global-norm clipping, cosine schedule and
optional int8 error-feedback gradient compression (cross-pod DP trick).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    grad_compression: Optional[int] = None  # bits (e.g. 8) or None
    state_dtype: str = "float32"  # Adam m/v storage dtype (perf A7)


def lr_at(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    """Linear warmup then cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / max(cfg.warmup_steps, 1)
    t = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1
    )
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def init_opt_state(cfg: OptimizerConfig, params) -> dict:
    sdt = jnp.dtype(cfg.state_dtype)
    zeros = lambda: jax.tree.map(lambda p: jnp.zeros_like(p, sdt), params)
    state = {"m": zeros(), "v": zeros(), "step": jnp.zeros((), jnp.int32)}
    if cfg.grad_compression:
        state["ef_error"] = zeros()  # error-feedback residual
    return state


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gnorm


def compress_with_error_feedback(grads, error, bits: int):
    """Per-tensor symmetric int-``bits`` quantization with error feedback.

    Models the cross-pod gradient exchange: on a real deployment the quantized
    payload is what crosses the (slow) pod interconnect inside a shard_map'd
    psum over the 'pod' axis; the residual stays local and is re-injected next
    step (EF-SGD), which keeps convergence unbiased.  Returns (deq, new_error).
    """
    qmax = float(2 ** (bits - 1) - 1)

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / qmax
        q = jnp.round(g32 / scale)
        q = jnp.clip(q, -qmax, qmax)
        deq = q * scale
        return deq.astype(g.dtype), (g32 - deq)

    flat, treedef = jax.tree.flatten(grads)
    eflat = jax.tree.leaves(error)
    out = [one(g, e) for g, e in zip(flat, eflat)]
    deq = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_e = jax.tree.unflatten(treedef, [o[1] for o in out])
    return deq, new_e


def adamw_update(cfg: OptimizerConfig, params, grads, state):
    """Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    metrics = {"grad_norm": gnorm}
    if cfg.grad_compression:
        grads, new_err = compress_with_error_feedback(
            grads, state["ef_error"], cfg.grad_compression
        )
    step = state["step"] + 1
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        # moments update in f32; stored at cfg.state_dtype (perf A7)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new.astype(m.dtype), v_new.astype(v.dtype)

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    # unzip the 3-tuples
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    new_state = {"m": new_m, "v": new_v, "step": step}
    if cfg.grad_compression:
        new_state["ef_error"] = new_err
    metrics["lr"] = lr
    return new_params, new_state, metrics
