"""Train-step factory: loss -> grads -> (clip, compress) -> AdamW."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.transformer import Model
from repro.train.optimizer import OptimizerConfig, adamw_update


def make_train_step(model: Model, opt_cfg: OptimizerConfig, microbatches: int = 1):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    Designed for jit with donated (params, opt_state).

    ``microbatches > 1`` enables gradient accumulation (perf iteration A1,
    EXPERIMENTS.md §Perf): the global batch is split along dim 0 and the
    fwd+bwd runs as a scan, dividing peak activation memory by the microbatch
    count at the cost of one extra f32 grad buffer.  Collective volume for the
    gradient reduction is unchanged (grads are accumulated locally, reduced
    once by the sharded optimizer update).
    """

    compute_dt = jnp.dtype(model.cfg.dtype)

    def _cast_for_compute(params):
        """f32 master params -> compute dtype *before* the layer stack, so
        the FSDP all-gathers move bf16, not f32 (perf A4, §Perf).  Grads come
        back in compute dtype and are accumulated/applied in f32."""
        if compute_dt == jnp.float32:
            return params
        return jax.tree.map(
            lambda p: p.astype(compute_dt)
            if isinstance(p, jax.Array) and p.dtype == jnp.float32 and p.ndim >= 2
            else p,
            params,
        )

    def _grads(params, batch):
        def loss_fn(pc, batch):
            return model.loss(pc, batch)

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            _cast_for_compute(params), batch
        )
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        return loss, metrics, grads

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            loss, metrics, grads = _grads(params, batch)
        else:
            from repro.parallel.sharding import shard

            mb = jax.tree.map(
                lambda x: x.reshape(microbatches, x.shape[0] // microbatches, *x.shape[1:]),
                batch,
            )
            # keep the per-microbatch batch dim on the data axes (no-op
            # without a sharding context)
            mb = {
                k: shard(v, None, "batch", *([None] * (v.ndim - 2)))
                for k, v in mb.items()
            }

            def body(acc, one):
                loss, metrics, grads = _grads(params, one)
                acc = jax.tree.map(jnp.add, acc, (loss, metrics, grads))
                return acc, None

            zero_l, zero_m, zero_g = jax.eval_shape(_grads, params, jax.tree.map(lambda x: x[0], mb))
            zeros = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), (zero_l, zero_m, zero_g))
            (loss, metrics, grads), _ = jax.lax.scan(body, zeros, mb)
            inv = 1.0 / microbatches
            loss, metrics, grads = jax.tree.map(
                lambda x: (x * inv).astype(x.dtype), (loss, metrics, grads)
            )
        params, opt_state, opt_metrics = adamw_update(opt_cfg, params, grads, opt_state)
        metrics = {**metrics, **opt_metrics, "loss": loss}
        return params, opt_state, metrics

    return train_step


def make_eval_step(model: Model):
    def eval_step(params, batch):
        loss, metrics = model.loss(params, batch)
        return metrics

    return eval_step
