"""Logical-axis sharding rules (MaxText-style) for FSDP + TP + EP + SP.

Parameters and activations carry *logical* axis names; a rules table maps them
to mesh axes.  The launcher installs a :class:`ShardingCtx`; without one every
helper is a no-op, so models run unmodified on a single CPU device (tests).

Default mapping (see DESIGN.md §4):
  * ``embed_fsdp``  -> 'data'            (FSDP: params sharded over data axis)
  * ``heads_tp``/``ff``/``vocab``/``expert`` -> 'model'   (tensor/expert parallel)
  * ``batch``       -> ('pod', 'data')   (pure DP across pods)
  * ``kv_seq``      -> None, except long-context decode (SP) where the KV/state
                       sequence dim shards over ('pod', 'data')
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

DEFAULT_RULES: dict[str, object] = {
    "embed_fsdp": "data",
    "heads_tp": "model",
    "ff": "model",
    "vocab": "model",
    "expert": "model",
    "layers": None,
    "batch": ("pod", "data"),
    # NOTE (perf A5, refuted — §Perf): flipping this to "model" (Megatron-SP
    # residual stream) made every term *worse* (collective 99.7s -> 765s on
    # mixtral train_4k): under GSPMD + scanned heterogeneous blocks the single
    # rule flip causes resharding ping-pong at every block-internal
    # constraint.  Real SP needs explicit gather/scatter segments; kept
    # replicated-seq as the measured optimum.
    "seq": None,
    "kv_seq": "model",  # decode: KV cache sharded along sequence (GQA kv_heads
    #                       rarely divide TP=16; seq always does at 32k)
    "group": ("pod", "data"),   # MoE dispatch groups follow the batch axis
    "embed_act": None,          # residual-stream feature dim
    "heads_act": "model",       # activation heads dim (TP)
    "ff_act": "model",
}

# Sequence-parallel override for batch=1 long-context decode.
SP_OVERRIDES = {
    "batch": None,
    "kv_seq": ("pod", "data", "model"),
}


def make_slot_mesh(num_devices: int) -> Mesh:
    """1-D serving mesh over the slot/batch axis.

    The continuous-batching engine shards its slot pool along the cache's
    ``batch`` (= slot) dimension; under the default rules ``batch`` maps to
    the ``data`` mesh axis, so a 1-D ``("data",)`` mesh over the first
    ``num_devices`` devices is all the serving path needs — every other
    logical axis (``kv_seq``/``heads_tp``/``vocab`` -> 'model') drops out
    because the mesh has no 'model' axis, leaving per-device slot shards
    with replicated params.  Device d owns the contiguous slot range
    [d*per_device, (d+1)*per_device), matching NamedSharding's row-major
    layout, so host-side range accounting and XLA placement agree.
    """
    devs = jax.devices()
    if num_devices < 1:
        raise ValueError(f"num_devices must be >= 1, got {num_devices}")
    if num_devices > len(devs):
        raise ValueError(
            f"slot mesh wants {num_devices} devices but only {len(devs)} are "
            "visible; on CPU export XLA_FLAGS=--xla_force_host_platform_"
            f"device_count={num_devices} before the process starts"
        )
    return Mesh(np.asarray(devs[:num_devices]), ("data",))


def slot_ctx(mesh: Mesh) -> ShardingCtx:
    """Sharding context for the serving slot pool (default rules: the cache
    'batch' axis — the slot axis — shards over 'data')."""
    return ShardingCtx(mesh, dict(DEFAULT_RULES))


@dataclasses.dataclass
class ShardingCtx:
    mesh: Mesh
    rules: dict

    def spec(self, logical_axes: tuple) -> P:
        parts = []
        used: set[str] = set()  # a mesh axis may shard at most one dim;
        #                         first logical axis wins (e.g. logits carry
        #                         both 'seq' and 'vocab' under SP rules)
        for ax in logical_axes:
            m = self.rules.get(ax) if ax is not None else None
            # drop mesh axes the mesh doesn't have (e.g. 'pod' on single-pod)
            if isinstance(m, tuple):
                m = tuple(a for a in m if a in self.mesh.axis_names and a not in used)
                m = m if m else None
                if isinstance(m, tuple) and len(m) == 1:
                    m = m[0]
            elif m is not None and (m not in self.mesh.axis_names or m in used):
                m = None
            if m is not None:
                used.update(m if isinstance(m, tuple) else (m,))
            parts.append(m)
        return P(*parts)

    def sharding(self, logical_axes: tuple) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical_axes))

    def sharding_for_shape(self, shape: tuple, logical_axes: tuple) -> NamedSharding:
        """Shape-aware: jit *argument* shardings must divide dims exactly, so
        any mesh axis whose size doesn't divide the dim is dropped (the value
        is replicated along it) — recorded as a known padding/replication
        trade-off in EXPERIMENTS.md."""
        sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        base = self.spec(logical_axes)
        parts = []
        used: set[str] = set()  # a mesh axis may shard at most one dim
        for dim, m in zip(shape, tuple(base) + (None,) * (len(shape) - len(base))):
            if m is None:
                parts.append(None)
                continue
            axes = m if isinstance(m, tuple) else (m,)
            total = 1
            kept = []
            for a in axes:
                if a not in used and dim % (total * sizes[a]) == 0:
                    kept.append(a)
                    used.add(a)
                    total *= sizes[a]
            parts.append(tuple(kept) if len(kept) > 1 else (kept[0] if kept else None))
        return NamedSharding(self.mesh, P(*parts))


_tls = threading.local()


def current_ctx() -> Optional[ShardingCtx]:
    return getattr(_tls, "ctx", None)


@contextlib.contextmanager
def use_sharding(mesh: Mesh, overrides: dict | None = None):
    rules = dict(DEFAULT_RULES)
    if overrides:
        rules.update(overrides)
    prev = current_ctx()
    _tls.ctx = ShardingCtx(mesh, rules)
    try:
        yield _tls.ctx
    finally:
        _tls.ctx = prev


def shard(x: jax.Array, *logical_axes) -> jax.Array:
    """Annotate an activation with logical axes (no-op without a context)."""
    ctx = current_ctx()
    if ctx is None:
        return x
    return jax.lax.with_sharding_constraint(x, ctx.sharding(tuple(logical_axes)))


def param_sharding_tree(spec_tree):
    """ParamSpec tree -> NamedSharding tree (None context -> None tree)."""
    from repro.models.layers import ParamSpec

    ctx = current_ctx()
    if ctx is None:
        return None
    return jax.tree.map(
        lambda s: ctx.sharding(s.logical_axes),
        spec_tree,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )
