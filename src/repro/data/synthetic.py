"""Deterministic synthetic LM corpus: a Zipf-successor Markov chain.

Each token has exactly K possible successors, deterministic functions of the
current token; which one follows is drawn from a Zipf distribution.  So the
true conditional entropy is known in closed form and the optimal perplexity
is ``exp(H(zipf))`` — which makes the paper's score-oriented experiments
quantitative: any normalization error in softmax/LN shows up as a perplexity
gap against an analytically known floor.

Everything is keyed by (seed, step, shard): stateless, resumable (the
fault-tolerance test relies on bitwise reproducibility after restart) and
shardable across data-parallel workers without coordination.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int = 256
    seq_len: int = 64
    global_batch: int = 8
    branching: int = 8          # K successors per token
    zipf_a: float = 1.5
    seed: int = 1234


def zipf_probs(cfg: DataConfig) -> np.ndarray:
    w = 1.0 / np.arange(1, cfg.branching + 1) ** cfg.zipf_a
    return (w / w.sum()).astype(np.float32)


def optimal_perplexity(cfg: DataConfig) -> float:
    p = zipf_probs(cfg)
    h = -(p * np.log(p)).sum()
    return float(np.exp(h))


def _successor(cfg: DataConfig, cur: jax.Array, k: jax.Array) -> jax.Array:
    """k-th successor of token cur (deterministic hash)."""
    return (cur * 31 + k * 1000003 + 12345) % cfg.vocab


def batch_at(cfg: DataConfig, step: int, shard: int = 0, num_shards: int = 1) -> dict:
    """Generate the (deterministic) batch for a global step / data shard."""
    assert cfg.global_batch % num_shards == 0
    b_local = cfg.global_batch // num_shards
    key = jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step), shard
    )
    k0, k1 = jax.random.split(key)
    x0 = jax.random.randint(k0, (b_local,), 0, cfg.vocab)
    probs = jnp.asarray(zipf_probs(cfg))
    ks = jax.random.choice(
        k1, cfg.branching, shape=(b_local, cfg.seq_len - 1), p=probs
    )

    def step_fn(cur, k):
        nxt = _successor(cfg, cur, k)
        return nxt, nxt

    _, rest = jax.lax.scan(step_fn, x0, ks.T)
    tokens = jnp.concatenate([x0[:, None], rest.T], axis=1)
    return {"tokens": tokens.astype(jnp.int32)}


def classification_batch(cfg: DataConfig, step: int, n_classes: int = 4) -> dict:
    """Rank-oriented companion task: classify a sequence by its chain family.

    Class c uses successor hash offset by c, so the label is recoverable from
    transition statistics — a pure *ordering* problem (GLUE analogue).
    """
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed + 999), step)
    kc, k0, k1 = jax.random.split(key, 3)
    labels = jax.random.randint(kc, (cfg.global_batch,), 0, n_classes)
    x0 = jax.random.randint(k0, (cfg.global_batch,), 0, cfg.vocab)
    probs = jnp.asarray(zipf_probs(cfg))
    ks = jax.random.choice(
        k1, cfg.branching, shape=(cfg.global_batch, cfg.seq_len - 1), p=probs
    )

    def step_fn(carry, k):
        cur, lab = carry
        nxt = (cur * 31 + (k + lab * 7) * 1000003 + 12345) % cfg.vocab
        return (nxt, lab), nxt

    (_, _), rest = jax.lax.scan(step_fn, (x0, labels), ks.T)
    tokens = jnp.concatenate([x0[:, None], rest.T], axis=1)
    return {"tokens": tokens.astype(jnp.int32), "labels": labels}
