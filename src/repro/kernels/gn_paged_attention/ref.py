"""Pure-jnp oracles for the paged GN attention kernel.

Semantics: gather each sequence's logical KV stream out of the block arena
through its block table, then run the one-pass GN-Softmax attention over the
causally visible prefix.  The kernel accumulates the *same* LUT'd numerators
into both the weighted value sum and the denominator block-by-block, so it
equals these references up to float associativity — and both normalize by
the numerators' own sum, so Σp = 1 to one rounding regardless of how the
blocks are laid out in the arena.

(The *streamed* block-tile algorithm the serving tick runs on CPU/GPU — the
same online accumulation as the kernel, in jnp — lives in
``models/attention.py``; this module is the gathered one-pass oracle both
are tested against.)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.luts import SoftmaxLUTConfig, TPU_SOFTMAX_LUT
from repro.kernels.gn_softmax.ref import gn_softmax_ref


def gn_paged_attention_ref(
    q: jax.Array,  # (N, H, D) one decode query per sequence
    k_arena: jax.Array,  # (nb, bs, H, D)  (kv heads already broadcast to H)
    v_arena: jax.Array,  # (nb, bs, H, D)
    tables: jax.Array,  # (N, max_bt) int32 physical block ids
    lengths: jax.Array,  # (N,) int32 context lengths (tokens)
    sm_scale: float | None = None,
    cfg: SoftmaxLUTConfig = TPU_SOFTMAX_LUT,
) -> jax.Array:
    if sm_scale is None:
        sm_scale = q.shape[-1] ** -0.5
    n = q.shape[0]
    nb, bs = k_arena.shape[:2]
    # gather the logical streams: (N, max_bt*bs, H, D)
    k = k_arena[tables].reshape(n, -1, *k_arena.shape[2:])
    v = v_arena[tables].reshape(n, -1, *v_arena.shape[2:])
    s = jnp.einsum("nhd,nthd->nht", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * sm_scale
    t = s.shape[-1]
    valid = jnp.arange(t)[None, None, :] < lengths[:, None, None]
    s = jnp.where(valid, s, -1e30)
    p = gn_softmax_ref(s, cfg)
    out = jnp.einsum("nht,nthd->nhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def gn_paged_attention_chunk_ref(
    q: jax.Array,  # (N, C, H, D) one query chunk per sequence
    k_arena: jax.Array,  # (nb, bs, H, D)  (kv heads already broadcast to H)
    v_arena: jax.Array,  # (nb, bs, H, D)
    tables: jax.Array,  # (N, max_bt) int32 physical block ids
    starts: jax.Array,  # (N,) int32 absolute position of query row 0
    n_valid: jax.Array,  # (N,) int32 valid lanes per sequence
    sm_scale: float | None = None,
    cfg: SoftmaxLUTConfig = TPU_SOFTMAX_LUT,
) -> jax.Array:
    """Chunked-query oracle: row i of sequence n attends the gathered stream
    [0, starts[n] + i] (causal intra-chunk), bounded by the post-write
    context starts + n_valid.  Rows past n_valid are don't-care to callers
    but deterministic (they attend the clipped stream), matching the kernel
    row for row."""
    if sm_scale is None:
        sm_scale = q.shape[-1] ** -0.5
    n, c = q.shape[:2]
    k = k_arena[tables].reshape(n, -1, *k_arena.shape[2:])
    v = v_arena[tables].reshape(n, -1, *v_arena.shape[2:])
    s = jnp.einsum("nchd,nthd->nhct", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * sm_scale
    t = s.shape[-1]
    col = jnp.arange(t)[None, None, :]  # (1, 1, T)
    rows = starts[:, None] + jnp.arange(c)[None, :]  # (N, C)
    lengths = starts + n_valid
    valid = (col <= rows[:, :, None]) & (col < lengths[:, None, None])
    s = jnp.where(valid[:, None], s, -1e30)
    p = gn_softmax_ref(s, cfg)
    out = jnp.einsum("nhct,nthd->nchd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def gn_paged_softmax_ref(
    scores: jax.Array,  # (..., T) with masked tail already at -inf
    cfg: SoftmaxLUTConfig = TPU_SOFTMAX_LUT,
) -> jax.Array:
    """Row-wise GN softmax over a gathered score row — exposed so property
    tests can check Σp = 1 on the exact probabilities the paged read uses."""
    return gn_softmax_ref(scores, cfg)
