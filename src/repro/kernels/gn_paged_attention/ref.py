"""Pure-jnp oracle for the paged GN decode-attention kernel.

Semantics: gather each sequence's logical KV stream out of the block arena
through its block table, then run the one-pass GN-Softmax attention over the
valid prefix.  The kernel accumulates the *same* LUT'd numerators into both
the weighted value sum and the denominator block-by-block, so it equals this
reference up to float associativity — and both normalize by the numerators'
own sum, so Σp = 1 to one rounding regardless of how the blocks are laid
out in the arena.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.luts import SoftmaxLUTConfig, TPU_SOFTMAX_LUT
from repro.kernels.gn_softmax.ref import gn_softmax_ref


def gn_paged_attention_ref(
    q: jax.Array,  # (N, H, D) one decode query per sequence
    k_arena: jax.Array,  # (nb, bs, H, D)  (kv heads already broadcast to H)
    v_arena: jax.Array,  # (nb, bs, H, D)
    tables: jax.Array,  # (N, max_bt) int32 physical block ids
    lengths: jax.Array,  # (N,) int32 context lengths (tokens)
    sm_scale: float | None = None,
    cfg: SoftmaxLUTConfig = TPU_SOFTMAX_LUT,
) -> jax.Array:
    if sm_scale is None:
        sm_scale = q.shape[-1] ** -0.5
    n = q.shape[0]
    nb, bs = k_arena.shape[:2]
    # gather the logical streams: (N, max_bt*bs, H, D)
    k = k_arena[tables].reshape(n, -1, *k_arena.shape[2:])
    v = v_arena[tables].reshape(n, -1, *v_arena.shape[2:])
    s = jnp.einsum("nhd,nthd->nht", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * sm_scale
    t = s.shape[-1]
    valid = jnp.arange(t)[None, None, :] < lengths[:, None, None]
    s = jnp.where(valid, s, -1e30)
    p = gn_softmax_ref(s, cfg)
    out = jnp.einsum("nht,nthd->nhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def gn_paged_softmax_ref(
    scores: jax.Array,  # (..., T) with masked tail already at -inf
    cfg: SoftmaxLUTConfig = TPU_SOFTMAX_LUT,
) -> jax.Array:
    """Row-wise GN softmax over a gathered score row — exposed so property
    tests can check Σp = 1 on the exact probabilities the paged read uses."""
    return gn_softmax_ref(scores, cfg)
