"""Jit'd public wrappers for paged GN attention (padding + GQA).

Layout contract with the serving pool: the arena arrives in the pool's
(num_blocks, block_size, KV, dh) layout; these wrappers transpose it to the
kernel's head-major block layout and lane-pad the head dim, pad the query
chunk to the 8-row sublane grid, and trim everything back off the output.

``gn_paged_attention_chunk`` is the fused serving tick's entry point: a
(N, C, H, D) query chunk per sequence, causal within the chunk, the prior
context read through the block table.  ``gn_paged_attention`` keeps the
original single-row decode signature as the C=1 special case.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.luts import SoftmaxLUTConfig, TPU_SOFTMAX_LUT
from repro.kernels.gn_paged_attention.kernel import gn_paged_attention_pallas

LANE = 128
SUBLANE = 8


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@functools.partial(
    jax.jit, static_argnames=("cfg", "sm_scale", "interpret")
)
def gn_paged_attention_chunk(
    q: jax.Array,  # (N, C, H, D) one query chunk per sequence
    k_arena: jax.Array,  # (nb, bs, Hkv, D) — the pool's arena layout
    v_arena: jax.Array,  # (nb, bs, Hkv, D)
    tables: jax.Array,  # (N, max_bt) int32
    starts: jax.Array,  # (N,) int32 absolute position of query row 0
    n_valid: jax.Array,  # (N,) int32 valid lanes (KV read bound; rows past
    #                      it produce don't-care outputs)
    cfg: SoftmaxLUTConfig = TPU_SOFTMAX_LUT,
    sm_scale: float | None = None,
    interpret: bool = False,
    scales: tuple[jax.Array, jax.Array] | None = None,  # ((nb,), (nb,)) f32
) -> jax.Array:
    """Chunked-query paged read.  Row i of sequence n attends the logical
    stream [0, starts[n] + i] (causal intra-chunk), bounded by the post-write
    context starts + n_valid.  Returns (N, C, H, D).

    ``scales`` marks the arenas as int8: per-physical-block dequant scales
    for k and v, applied inside the kernel after each block tile's DMA."""
    n, c, h, d = q.shape
    nb, bs, hkv, _ = k_arena.shape
    if sm_scale is None:
        sm_scale = d**-0.5  # scale uses the TRUE head dim, not the padded one
    k_scale = v_scale = None
    if scales is not None:
        k_scale = scales[0].astype(jnp.float32)
        v_scale = scales[1].astype(jnp.float32)

    d_p = _round_up(d, LANE)
    # quantized (int8) arenas need the (32, 128) minimum TPU tile in the
    # sublane dim; fp arenas keep the 8-row grid
    sub = SUBLANE
    if scales is not None:
        sub = 32
    bs_p = _round_up(bs, sub)
    c_p = _round_up(c, SUBLANE)

    qp = jnp.pad(
        q.transpose(0, 2, 1, 3), ((0, 0), (0, 0), (0, c_p - c), (0, d_p - d))
    )  # (N, H, c_p, d_p)
    kp = jnp.pad(
        k_arena.transpose(0, 2, 1, 3), ((0, 0), (0, 0), (0, bs_p - bs), (0, d_p - d))
    )
    vp = jnp.pad(
        v_arena.transpose(0, 2, 1, 3), ((0, 0), (0, 0), (0, bs_p - bs), (0, d_p - d))
    )

    out = gn_paged_attention_pallas(
        qp,
        kp,
        vp,
        tables.astype(jnp.int32),
        starts.astype(jnp.int32),
        (starts + n_valid).astype(jnp.int32),
        cfg=cfg,
        sm_scale=float(sm_scale),
        block_size=bs,
        interpret=interpret,
        k_scale=k_scale,
        v_scale=v_scale,
    )
    return out[:, :, :c, :d].transpose(0, 2, 1, 3)


@functools.partial(
    jax.jit, static_argnames=("cfg", "sm_scale", "interpret")
)
def gn_paged_attention(
    q: jax.Array,  # (N, H, D) one decode query per sequence
    k_arena: jax.Array,  # (nb, bs, Hkv, D) — the pool's arena layout
    v_arena: jax.Array,  # (nb, bs, Hkv, D)
    tables: jax.Array,  # (N, max_bt) int32
    lengths: jax.Array,  # (N,) int32 context lengths (incl. the new token)
    cfg: SoftmaxLUTConfig = TPU_SOFTMAX_LUT,
    sm_scale: float | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Single-row decode read: the C=1 chunk whose query sits at position
    lengths - 1.  Returns (N, H, D)."""
    lengths = lengths.astype(jnp.int32)
    starts = jnp.maximum(lengths - 1, 0)
    out = gn_paged_attention_chunk(
        q[:, None],
        k_arena,
        v_arena,
        tables,
        starts,
        # empty sequences read nothing (all blocks skipped -> zero output),
        # exactly like the pre-chunk decode kernel
        jnp.where(lengths > 0, 1, 0),
        cfg=cfg,
        sm_scale=sm_scale,
        interpret=interpret,
    )
    return out[:, 0]
