"""Jit'd public wrapper for paged GN decode attention (padding + GQA).

Layout contract with the serving pool: the arena arrives in the pool's
(num_blocks, block_size, KV, dh) layout; this wrapper transposes it to the
kernel's head-major block layout and lane-pads the head dim, pads the query
to the 8-row sublane grid, and trims everything back off the output.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.luts import SoftmaxLUTConfig, TPU_SOFTMAX_LUT
from repro.kernels.gn_paged_attention.kernel import gn_paged_attention_pallas

LANE = 128
SUBLANE = 8


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@functools.partial(
    jax.jit, static_argnames=("cfg", "sm_scale", "interpret")
)
def gn_paged_attention(
    q: jax.Array,  # (N, H, D) one decode query per sequence
    k_arena: jax.Array,  # (nb, bs, Hkv, D) — the pool's arena layout
    v_arena: jax.Array,  # (nb, bs, Hkv, D)
    tables: jax.Array,  # (N, max_bt) int32
    lengths: jax.Array,  # (N,) int32 context lengths
    cfg: SoftmaxLUTConfig = TPU_SOFTMAX_LUT,
    sm_scale: float | None = None,
    interpret: bool = False,
) -> jax.Array:
    n, h, d = q.shape
    nb, bs, hkv, _ = k_arena.shape
    if sm_scale is None:
        sm_scale = d**-0.5  # scale uses the TRUE head dim, not the padded one

    d_p = _round_up(d, LANE)
    bs_p = _round_up(bs, SUBLANE)

    qp = jnp.pad(q, ((0, 0), (0, 0), (0, d_p - d)))[:, :, None]  # (N, H, 1, d_p)
    qp = jnp.pad(qp, ((0, 0), (0, 0), (0, SUBLANE - 1), (0, 0)))
    kp = jnp.pad(
        k_arena.transpose(0, 2, 1, 3), ((0, 0), (0, 0), (0, bs_p - bs), (0, d_p - d))
    )
    vp = jnp.pad(
        v_arena.transpose(0, 2, 1, 3), ((0, 0), (0, 0), (0, bs_p - bs), (0, d_p - d))
    )

    out = gn_paged_attention_pallas(
        qp,
        kp,
        vp,
        tables.astype(jnp.int32),
        lengths.astype(jnp.int32),
        cfg=cfg,
        sm_scale=float(sm_scale),
        block_size=bs,
        interpret=interpret,
    )
    return out[:, :, 0, :d]
