"""Paged-KV chunked-query attention with online GN-Softmax — Pallas TPU kernel.

The serving engine's block-paged KV pool stores each sequence as a chain of
``block_size``-token blocks scattered through a shared arena; a per-sequence
block *table* maps logical block j to its physical arena slot.  This kernel
streams a *chunk* of queries (decode is the chunk=1 special case) over that
chain exactly like ``gn_attention`` streams over a contiguous row: the k/v
BlockSpec index map reads the physical block id out of a scalar-prefetched
table (so the DMA engine chases the table, no gather materialization in
HBM), and the (max, sum, acc) carries use the same snap-to-Δ-grid
stabilizer:

  * the running max is snapped *up* to the Δ grid, so the online correction
    e^{m_old − m_new} goes through the same LUT unit grid-exactly and the
    per-block accumulation order drops out of the result;
  * the final division acc / l divides the accumulated LUT'd numerators by
    their own sum — Σp = 1 holds to one rounding *independent of the block
    layout*, which is the normalization guarantee the paged pool must not
    break.

Chunked-query contract (the fused serving tick): query row i of sequence n
sits at absolute position ``starts[n] + i`` and attends the logical stream
``[0, starts[n] + i]`` — causal *within* the chunk, full prefix before it —
while KV reads are bounded by ``lengths[n]`` (the post-write context
``starts + n_valid``), so rows past a slot's valid lane count read nothing
beyond what the pool actually allocated.  Their outputs are don't-care and
the caller discards them.

Grid: (n_seqs, q_heads, max_blocks_per_seq), block axis innermost/arbitrary;
GQA maps k/v to head ``h // group``.  Blocks at or past a sequence's context
length are skipped entirely (their table entries may point at recycled or
foreign blocks — never read).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.luts import SoftmaxLUTConfig, TPU_SOFTMAX_LUT
from repro.kernels.common import exp_lut_operands, factorized_exp, snap_up_to_grid

# jax renamed TPUCompilerParams -> CompilerParams across 0.4/0.5; accept both.
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _gn_paged_attention_kernel(
    tables_ref,  # scalar prefetch: (N, max_bt) int32 physical block ids
    starts_ref,  # scalar prefetch: (N,) int32 absolute position of q row 0
    lens_ref,  # scalar prefetch: (N,) int32 post-write context lengths
    *refs,
    # quantized=True prepends two scalar-prefetch refs to ``refs``:
    #   kscale_ref,  # (nb,) f32 per-physical-block K dequant scales
    #   vscale_ref,  # (nb,) f32 per-physical-block V dequant scales
    # then, in both modes:
    #   q_ref,  # (1, 1, bq, d) — rows [0, chunk) are the chunk queries
    #   k_ref,  # (1, 1, bs_p, d) — physical block tables_ref[n, j]
    #   v_ref,  # (1, 1, bs_p, d)
    #   coarse_ref,  # (1, 128) exp LUT operand
    #   residual_ref,  # (1, 128k) exp LUT operand
    #   o_ref,  # (1, 1, bq, d)
    #   acc_ref,  # (bq, d) f32 scratch
    #   m_ref,  # (bq, 128) f32 scratch
    #   l_ref,  # (bq, 128) f32 scratch
    cfg: SoftmaxLUTConfig,
    sm_scale: float,
    block_size: int,  # true tokens per block (bs_p >= block_size is padding)
    block_pad: int,
    quantized: bool = False,
):
    if quantized:
        kscale_ref, vscale_ref = refs[:2]
        refs = refs[2:]
    (q_ref, k_ref, v_ref, coarse_ref, residual_ref,
     o_ref, acc_ref, m_ref, l_ref) = refs
    n = pl.program_id(0)
    j = pl.program_id(2)
    nj = pl.num_programs(2)
    start = starts_ref[n]
    length = lens_ref[n]

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * sm_scale
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        if quantized:
            # per-block dequant AFTER the DMA: the int8 tile is what
            # streamed in; multiply by the physical block's frozen scale
            # (the same clamped table index the BlockSpec DMA'd from)
            last = jnp.maximum((length - 1) // block_size, 0)
            phys = tables_ref[n, jnp.minimum(j, last)]
            k = k * kscale_ref[phys]
            v = v * vscale_ref[phys]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (bq, bs_p)
        bq, bs_p = s.shape

        # mask: query row qi (absolute position start + qi) attends absolute
        # column j*block_size + r iff the column is causally visible
        # (col <= start + qi), inside the written context (col < length), and
        # not in the padded tail rows (r >= block_size) of the physical block
        qi = jax.lax.broadcasted_iota(jnp.int32, (bq, bs_p), 0)
        r = jax.lax.broadcasted_iota(jnp.int32, (bq, bs_p), 1)
        col = j * block_size + r
        mask = (r < block_size) & (col < length) & (col <= start + qi)
        s = jnp.where(mask, s, NEG_INF)

        m_old = m_ref[:, :1]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = snap_up_to_grid(jnp.maximum(m_old, m_cur), cfg)
        any_valid = jnp.max(mask.astype(jnp.int32), axis=-1, keepdims=True) > 0
        m_new = jnp.where(any_valid | (m_old > NEG_INF / 2), m_new, m_old)

        corr_delta = jnp.clip(m_new - m_old, 0.0, cfg.step * (cfg.max_delta_int + 1))
        corr = factorized_exp(corr_delta, coarse_ref[...], residual_ref[...], cfg)
        corr = jnp.where(m_old > NEG_INF / 2, corr, 0.0)

        y = factorized_exp(
            jnp.maximum(m_new - s, 0.0), coarse_ref[...], residual_ref[...], cfg
        )
        y = jnp.where(mask & (m_new > NEG_INF / 2), y, 0.0)

        l_new = l_ref[:, :1] * corr + jnp.sum(y, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            y, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    # skip blocks wholly past the context: their table entries may name
    # recycled/foreign blocks and must never be read
    pl.when(j * block_size < length)(_body)

    @pl.when(j == nj - 1)
    def _fini():
        # guaranteed normalization: same LUT'd numerators over their own sum
        l = l_ref[:, :1]
        l = jnp.where(l > 0, l, 1.0)
        o_ref[0, 0] = (acc_ref[...] * (1.0 / l)).astype(o_ref.dtype)

    del block_pad  # layout bookkeeping lives in ops.py


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "sm_scale", "block_size", "interpret"),
)
def gn_paged_attention_pallas(
    q: jax.Array,  # (N, H, bq, d) — rows [0, chunk) are the chunk queries
    k_arena: jax.Array,  # (nb, Hkv, bs_p, d)
    v_arena: jax.Array,  # (nb, Hkv, bs_p, d)
    tables: jax.Array,  # (N, max_bt) int32
    starts: jax.Array,  # (N,) int32 absolute position of query row 0
    lengths: jax.Array,  # (N,) int32 post-write context lengths
    cfg: SoftmaxLUTConfig = TPU_SOFTMAX_LUT,
    sm_scale: float | None = None,
    block_size: int | None = None,
    interpret: bool = False,
    k_scale: jax.Array | None = None,  # (nb,) f32 per-block dequant scales
    v_scale: jax.Array | None = None,  # (nb,) f32
) -> jax.Array:
    n, h, bq, d = q.shape
    nb, hkv, bs_p, _ = k_arena.shape
    if h % hkv:
        raise ValueError(f"q heads {h} not a multiple of kv heads {hkv}")
    group = h // hkv
    max_bt = tables.shape[1]
    block_size = bs_p if block_size is None else block_size
    if sm_scale is None:
        sm_scale = d**-0.5
    quantized = k_scale is not None

    coarse, residual = exp_lut_operands(cfg)
    grid = (n, h, max_bt)
    kernel = functools.partial(
        _gn_paged_attention_kernel,
        cfg=cfg,
        sm_scale=float(sm_scale),
        block_size=int(block_size),
        block_pad=bs_p - block_size,
        quantized=quantized,
    )

    # index maps take *_ so the same lambdas serve both prefetch arities
    # (3 scalars fp, 5 scalars with the two per-block scale vectors)
    def kv_index(n_, h_, j, tbl, starts_, lens, *_):
        # clamp skipped grid steps (j past the sequence's last valid block)
        # to the last valid logical block: the kernel's pl.when already
        # skips their compute, and a repeated index lets the pipeline elide
        # the redundant DMA instead of streaming dead blocks for the whole
        # max_bt tail of every short sequence
        last = jnp.maximum((lens[n_] - 1) // block_size, 0)
        return (tbl[n_, jnp.minimum(j, last)], h_ // group, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=5 if quantized else 3,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda n_, h_, j, *_: (n_, h_, 0, 0)),
            pl.BlockSpec((1, 1, bs_p, d), kv_index),
            pl.BlockSpec((1, 1, bs_p, d), kv_index),
            pl.BlockSpec(coarse.shape, lambda n_, h_, j, *_: (0, 0)),
            pl.BlockSpec(residual.shape, lambda n_, h_, j, *_: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda n_, h_, j, *_: (n_, h_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
        ],
    )
    scalars = (tables, starts, lengths)
    if quantized:
        scalars = scalars + (
            k_scale.astype(jnp.float32),
            v_scale.astype(jnp.float32),
        )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(*scalars, q, k_arena, v_arena, coarse, residual)
