"""Pure-jnp oracle for the GN-LayerNorm (CoRN-LN) Pallas kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.gn_layernorm import newton_rsqrt
from repro.core.luts import PAPER_RSQRT, RsqrtConfig


def gn_layernorm_ref(
    x: jax.Array,
    gamma: jax.Array | None = None,
    beta: jax.Array | None = None,
    cfg: RsqrtConfig = PAPER_RSQRT,
    subtract_mean: bool = True,
) -> jax.Array:
    x32 = x.astype(jnp.float32)
    if subtract_mean:
        mu = jnp.mean(x32, axis=-1, keepdims=True)
        centered = x32 - mu
    else:
        centered = x32
    var = jnp.mean(jnp.square(centered), axis=-1, keepdims=True)
    rstd = newton_rsqrt(var + 1e-8, cfg)
    y = centered * rstd
    if gamma is not None:
        y = y * gamma.astype(jnp.float32)
    if beta is not None:
        y = y + beta.astype(jnp.float32)
    return y.astype(x.dtype)
