"""Jit'd public wrapper for the GN-LayerNorm Pallas kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.luts import PAPER_RSQRT, RsqrtConfig
from repro.kernels.gn_layernorm.kernel import gn_layernorm_pallas

LANE = 128
SUBLANE = 8


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@functools.partial(
    jax.jit, static_argnames=("cfg", "block_rows", "interpret", "subtract_mean")
)
def gn_layernorm(
    x: jax.Array,
    gamma: jax.Array | None = None,
    beta: jax.Array | None = None,
    cfg: RsqrtConfig = PAPER_RSQRT,
    block_rows: int = 256,
    interpret: bool = False,
    subtract_mean: bool = True,
) -> jax.Array:
    """GN-LayerNorm over the last axis of an arbitrarily-shaped array."""
    orig_shape = x.shape
    cols = orig_shape[-1]
    rows = 1
    for d in orig_shape[:-1]:
        rows *= d
    x2 = x.reshape(rows, cols)
    if gamma is None:
        gamma = jnp.ones((cols,), jnp.float32)
    if beta is None:
        beta = jnp.zeros((cols,), jnp.float32)

    cols_p = _round_up(cols, LANE)
    block_rows = min(block_rows, _round_up(rows, SUBLANE))
    rows_p = _round_up(rows, block_rows)
    x2 = jnp.pad(x2, ((0, rows_p - rows), (0, cols_p - cols)))
    g2 = jnp.pad(gamma.reshape(1, cols), ((0, 0), (0, cols_p - cols)))
    b2 = jnp.pad(beta.reshape(1, cols), ((0, 0), (0, cols_p - cols)))
    out = gn_layernorm_pallas(
        x2,
        g2,
        b2,
        cfg=cfg,
        block_rows=block_rows,
        interpret=interpret,
        valid_cols=cols,
        subtract_mean=subtract_mean,
    )
    return out[:rows, :cols].reshape(orig_shape)


def gn_rmsnorm(x, gamma=None, cfg: RsqrtConfig = PAPER_RSQRT, **kw):
    """sigma-guaranteed RMSNorm via the same kernel (mean path off)."""
    return gn_layernorm(x, gamma, None, cfg=cfg, subtract_mean=False, **kw)
