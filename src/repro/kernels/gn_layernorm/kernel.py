"""GN-LayerNorm (CoRN-LN) Pallas TPU kernel.

Fig. 4's two-stage datapath mapped to a VMEM-tiled kernel:

  stage (i)  — mean & variance over the feature axis (row-local reduction);
  stage (ii) — normalization with the CoRN reciprocal-sqrt:
               LOD == float32 exponent-field extraction (bitcast, mask),
               compressed mantissa LUT == one-hot matmul against a (1, 128)
               VMEM table operand, then ``iters`` mul-only Newton steps
               x <- x(1.5 - 0.5 n x^2).

gamma/beta ride along as (1, cols) blocks replicated over the row grid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.luts import INV_SQRT2, PAPER_RSQRT, RsqrtConfig
from repro.kernels.common import lut_lookup, rsqrt_lut_operand


def _newton_rsqrt_block(n: jax.Array, lut2d: jax.Array, cfg: RsqrtConfig) -> jax.Array:
    """CoRN rsqrt on an (r, 1) block: LOD + mantissa LUT + NR steps."""
    bits = jax.lax.bitcast_convert_type(n, jnp.int32)
    e = ((bits >> 23) & 0xFF) - 127                      # LOD
    idx = (bits >> (23 - cfg.mantissa_bits)) & ((1 << cfg.mantissa_bits) - 1)
    m_r = lut_lookup(idx, lut2d)
    e_half = e >> 1
    odd = (e & 1).astype(jnp.float32)
    pow2 = jax.lax.bitcast_convert_type(
        ((127 - e_half) << 23).astype(jnp.int32), jnp.float32
    )
    x = m_r * pow2 * jnp.where(odd > 0, jnp.float32(INV_SQRT2), jnp.float32(1.0))
    for _ in range(cfg.iters):
        x = x * (1.5 - 0.5 * n * x * x)
    return x


def _gn_layernorm_kernel(
    x_ref,
    gamma_ref,
    beta_ref,
    lut_ref,
    o_ref,
    *,
    cfg: RsqrtConfig,
    valid_cols: int,
    subtract_mean: bool,
):
    x = x_ref[...].astype(jnp.float32)
    rows, cols = x.shape
    lane = jax.lax.broadcasted_iota(jnp.int32, (rows, cols), 1)
    valid = lane < valid_cols
    x = jnp.where(valid, x, 0.0)
    inv_c = jnp.float32(1.0 / valid_cols)

    # stage (i): moments (padding contributes zeros; divide by true C)
    if subtract_mean:
        mu = jnp.sum(x, axis=-1, keepdims=True) * inv_c
        centered = jnp.where(valid, x - mu, 0.0)
    else:
        centered = x
    var = jnp.sum(centered * centered, axis=-1, keepdims=True) * inv_c

    # stage (ii): CoRN reciprocal sqrt + multiply-only output stage
    rstd = _newton_rsqrt_block(var + 1e-8, lut_ref[...], cfg)
    y = centered * rstd
    y = y * gamma_ref[...].astype(jnp.float32)
    y = y + beta_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "block_rows", "interpret", "valid_cols", "subtract_mean"),
)
def gn_layernorm_pallas(
    x: jax.Array,
    gamma: jax.Array,
    beta: jax.Array,
    cfg: RsqrtConfig = PAPER_RSQRT,
    block_rows: int = 256,
    interpret: bool = False,
    valid_cols: int | None = None,
    subtract_mean: bool = True,
) -> jax.Array:
    """2D entry: x (rows, cols_p), gamma/beta (1, cols_p); rows % block == 0."""
    rows, cols = x.shape
    if valid_cols is None:
        valid_cols = cols
    if rows % block_rows:
        raise ValueError(f"rows {rows} not a multiple of block_rows {block_rows}")
    lut = rsqrt_lut_operand(cfg)
    grid = (rows // block_rows,)
    return pl.pallas_call(
        functools.partial(
            _gn_layernorm_kernel,
            cfg=cfg,
            valid_cols=valid_cols,
            subtract_mean=subtract_mean,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
            pl.BlockSpec((1, cols), lambda i: (0, 0)),
            pl.BlockSpec((1, cols), lambda i: (0, 0)),
            pl.BlockSpec(lut.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), x.dtype),
        interpret=interpret,
    )(x, gamma, beta, lut)
