"""Shared in-kernel helpers for the GN Pallas kernels.

The LUT units of the paper, expressed MXU-idiomatically: a ROM lookup is a
one-hot × table matmul; the factorized exponential is two such lookups plus a
fixed-point-rounded product (Eq. 4).

LUTs are passed into kernels as (1, 128) lane-padded VMEM operands (Pallas
forbids captured array constants).  One-hot columns beyond the true entry
count are never set, so the zero padding is inert.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import luts as lut_lib
from repro.core.luts import RsqrtConfig, SoftmaxLUTConfig

LANE = 128


def pad_lut(values: np.ndarray) -> jnp.ndarray:
    """1-D LUT -> (1, 128k) lane-aligned operand."""
    n = values.shape[0]
    n_p = (n + LANE - 1) // LANE * LANE
    out = np.zeros((1, n_p), np.float32)
    out[0, :n] = values
    return jnp.asarray(out)


def exp_lut_operands(cfg: SoftmaxLUTConfig) -> tuple[jnp.ndarray, jnp.ndarray]:
    coarse, residual = lut_lib.exp_luts(cfg)
    return pad_lut(coarse), pad_lut(residual)


def rsqrt_lut_operand(cfg: RsqrtConfig) -> jnp.ndarray:
    return pad_lut(lut_lib.rsqrt_mantissa_lut(cfg))


def lut_lookup(idx: jax.Array, lut2d: jax.Array) -> jax.Array:
    """ROM lookup as one-hot matmul.  idx int32 (r, c), lut2d (1, np)."""
    r, c = idx.shape
    n_p = lut2d.shape[-1]
    flat = idx.reshape(r * c, 1)
    iota = jax.lax.broadcasted_iota(jnp.int32, (r * c, n_p), 1)
    onehot = (flat == iota).astype(jnp.float32)
    vals = jax.lax.dot_general(
        onehot,
        lut2d.reshape(n_p, 1),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    return vals.reshape(r, c)


def factorized_exp(
    delta: jax.Array,
    coarse2d: jax.Array,
    residual2d: jax.Array,
    cfg: SoftmaxLUTConfig,
) -> jax.Array:
    """e^{-Δ} on the fixed-point grid via the coarse/residual LUT pair.

    Δ >= 0 float32 (any 2D block shape).  Entries beyond the coarse LUT's
    reach saturate to 0, exactly like the RTL.
    """
    inv_step = jnp.float32(1.0 / cfg.step)
    d_int = jnp.round(delta * inv_step).astype(jnp.int32)
    sat = d_int > cfg.max_delta_int
    d_int = jnp.clip(d_int, 0, cfg.max_delta_int)
    frac = d_int >> (3 + cfg.frac_bits)
    rem = d_int & (cfg.residual_entries - 1)
    y = lut_lookup(frac, coarse2d) * lut_lookup(rem, residual2d)
    scale = jnp.float32(1 << cfg.lut_value_bits)
    y = jnp.round(y * scale) / scale
    return jnp.where(sat, 0.0, y)


def snap_up_to_grid(m: jax.Array, cfg: SoftmaxLUTConfig) -> jax.Array:
    """Ceil a running max onto the Δ grid.

    With the row max on the grid, online-softmax correction factors
    e^{m_old - m_new} are grid-exact, so tiled accumulation matches the
    single-pass reference up to LUT-entry rounding only (see kernel.py).
    The uniform shift cancels in the final normalization.
    """
    step = jnp.float32(cfg.step)
    return jnp.ceil(m / step) * step
