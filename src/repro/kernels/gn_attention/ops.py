"""Jit'd public wrapper for GN flash attention (padding + GQA plumbing)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.luts import SoftmaxLUTConfig, TPU_SOFTMAX_LUT
from repro.kernels.gn_attention.kernel import gn_attention_pallas

LANE = 128


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "causal", "sm_scale", "block_q", "block_k", "interpret"),
)
def gn_attention(
    q: jax.Array,  # (B, H, Sq, D)
    k: jax.Array,  # (B, Hkv, Sk, D)
    v: jax.Array,  # (B, Hkv, Sk, D)
    cfg: SoftmaxLUTConfig = TPU_SOFTMAX_LUT,
    causal: bool = False,
    sm_scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, h, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    if sm_scale is None:
        sm_scale = d**-0.5  # scale uses the TRUE head dim, not the padded one

    block_q = min(block_q, _round_up(sq, 8))
    block_k = min(block_k, _round_up(sk, 8))
    sq_p = _round_up(sq, block_q)
    sk_p = _round_up(sk, block_k)
    d_p = _round_up(d, LANE)

    qp = jnp.pad(q, ((0, 0), (0, 0), (0, sq_p - sq), (0, d_p - d)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, sk_p - sk), (0, d_p - d)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, sk_p - sk), (0, d_p - d)))

    out = gn_attention_pallas(
        qp,
        kp,
        vp,
        cfg=cfg,
        causal=causal,
        sm_scale=float(sm_scale),
        block_q=block_q,
        block_k=block_k,
        interpret=interpret,
        seq_q_valid=sq,
        seq_k_valid=sk,
    )
    return out[:, :, :sq, :d]
