"""Flash attention with online GN-Softmax — Pallas TPU kernel.

This is the paper's technique moved to where transformer softmax actually
lives: inside tiled attention.  The RTL's streaming N-cycle pipeline becomes
a (q_block × k_block) VMEM tiling with running (max, sum, acc) carries:

  * numerators are the two-LUT factorized exponentials of Algorithm 1;
  * the running max is snapped *up* to the Δ grid (common.snap_up_to_grid), so
    the online correction factor e^{m_old − m_new} goes through the *same* LUT
    unit grid-exactly, and tiled accumulation equals the one-pass reference up
    to LUT-entry rounding;
  * the final division — acc / l — divides the accumulated LUT'd numerators by
    their own sum: the normalization guarantee (Σp = 1) survives tiling.

Grid: (batch, q_heads, q_blocks, k_blocks), k innermost/arbitrary; GQA is
handled by index-mapping k/v blocks to head ``h // group`` (no KV repetition
in HBM).  Scratch: acc (bq, d), running m/l as (bq, 128) lane-replicated.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.luts import SoftmaxLUTConfig, TPU_SOFTMAX_LUT
from repro.kernels.common import exp_lut_operands, factorized_exp, snap_up_to_grid

# jax renamed TPUCompilerParams -> CompilerParams across 0.4/0.5; accept both.
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _gn_attention_kernel(
    q_ref,  # (1, 1, bq, d)
    k_ref,  # (1, 1, bk, d)
    v_ref,  # (1, 1, bk, d)
    coarse_ref,  # (1, 128) exp LUT operand
    residual_ref,  # (1, 128k) exp LUT operand
    o_ref,  # (1, 1, bq, d)
    acc_ref,  # (bq, d) f32 scratch
    m_ref,  # (bq, 128) f32 scratch
    l_ref,  # (bq, 128) f32 scratch
    *,
    cfg: SoftmaxLUTConfig,
    sm_scale: float,
    causal: bool,
    block_q: int,
    block_k: int,
    seq_q: int,
    seq_k: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # causal: skip blocks strictly above the diagonal band
    offset = seq_k - seq_q  # KV prefix length (k may be longer than q)

    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * sm_scale
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (bq, bk)

        # masks: causal diagonal + right-edge padding of the kv axis
        col = jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1) + ik * block_k
        mask = col < seq_k
        if causal:
            row = (
                jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
                + iq * block_q
            )
            mask &= col <= (row + offset)
        s = jnp.where(mask, s, NEG_INF)

        m_old = m_ref[:, :1]                                   # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = snap_up_to_grid(jnp.maximum(m_old, m_cur), cfg)
        # all-masked rows (above diagonal): keep m at NEG_INF
        any_valid = jnp.max(mask.astype(jnp.int32), axis=-1, keepdims=True) > 0
        m_new = jnp.where(any_valid | (m_old > NEG_INF / 2), m_new, m_old)

        # correction for previously accumulated numerators: e^{m_old - m_new}
        # through the same LUT unit (grid-exact because both are on-grid).
        corr_delta = jnp.clip(m_new - m_old, 0.0, cfg.step * (cfg.max_delta_int + 1))
        corr = factorized_exp(corr_delta, coarse_ref[...], residual_ref[...], cfg)
        corr = jnp.where(m_old > NEG_INF / 2, corr, 0.0)       # first block: no history

        y = factorized_exp(
            jnp.maximum(m_new - s, 0.0), coarse_ref[...], residual_ref[...], cfg
        )  # (bq, bk) numerators
        y = jnp.where(mask & (m_new > NEG_INF / 2), y, 0.0)

        l_new = l_ref[:, :1] * corr + jnp.sum(y, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            y, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    if causal:
        run = (ik * block_k) <= (iq * block_q + block_q - 1 + offset)
        pl.when(run)(_body)
    else:
        _body()

    @pl.when(ik == nk - 1)
    def _fini():
        # guaranteed normalization: same LUT'd numerators over their own sum
        l = l_ref[:, :1]
        l = jnp.where(l > 0, l, 1.0)
        o_ref[0, 0] = (acc_ref[...] * (1.0 / l)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "cfg",
        "causal",
        "sm_scale",
        "block_q",
        "block_k",
        "interpret",
        "seq_q_valid",
        "seq_k_valid",
    ),
)
def gn_attention_pallas(
    q: jax.Array,  # (B, H, Sq, D)
    k: jax.Array,  # (B, Hkv, Sk, D)
    v: jax.Array,  # (B, Hkv, Sk, D)
    cfg: SoftmaxLUTConfig = TPU_SOFTMAX_LUT,
    causal: bool = False,
    sm_scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
    seq_q_valid: int | None = None,
    seq_k_valid: int | None = None,
) -> jax.Array:
    b, h, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    if h % hkv:
        raise ValueError(f"q heads {h} not a multiple of kv heads {hkv}")
    group = h // hkv
    if sq % block_q or sk % block_k:
        raise ValueError("padded seq lens must divide block sizes (see ops.py)")
    if sm_scale is None:
        sm_scale = d**-0.5
    seq_q_valid = sq if seq_q_valid is None else seq_q_valid
    seq_k_valid = sk if seq_k_valid is None else seq_k_valid

    coarse, residual = exp_lut_operands(cfg)
    grid = (b, h, sq // block_q, sk // block_k)
    kernel = functools.partial(
        _gn_attention_kernel,
        cfg=cfg,
        sm_scale=float(sm_scale),
        causal=causal,
        block_q=block_q,
        block_k=block_k,
        seq_q=seq_q_valid,
        seq_k=seq_k_valid,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
            pl.BlockSpec(
                (1, 1, block_k, d), lambda b_, h_, iq, ik: (b_, h_ // group, ik, 0)
            ),
            pl.BlockSpec(
                (1, 1, block_k, d), lambda b_, h_, iq, ik: (b_, h_ // group, ik, 0)
            ),
            pl.BlockSpec(coarse.shape, lambda b_, h_, iq, ik: (0, 0)),
            pl.BlockSpec(residual.shape, lambda b_, h_, iq, ik: (0, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, block_q, d), lambda b_, h_, iq, ik: (b_, h_, iq, 0)
        ),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
        ],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v, coarse, residual)
