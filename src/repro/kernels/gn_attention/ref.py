"""Pure-jnp oracle for the GN flash-attention kernel.

Semantics: scaled dot-product attention whose softmax is the paper's
GN-Softmax (two-LUT factorized exp on the Δ grid + renormalization by the
true sum).  Because the kernel accumulates the *same* LUT'd numerators into
both the weighted value sum and the denominator, it equals this reference up
to float associativity.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.luts import SoftmaxLUTConfig, TPU_SOFTMAX_LUT
from repro.kernels.gn_softmax.ref import gn_softmax_ref


def gn_attention_ref(
    q: jax.Array,  # (B, H, Sq, D)
    k: jax.Array,  # (B, H, Sk, D)  (kv heads already broadcast to H)
    v: jax.Array,  # (B, H, Sk, D)
    causal: bool = False,
    sm_scale: float | None = None,
    cfg: SoftmaxLUTConfig = TPU_SOFTMAX_LUT,
) -> jax.Array:
    if sm_scale is None:
        sm_scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * sm_scale
    if causal:
        sq, sk = s.shape[-2], s.shape[-1]
        mask = jnp.tril(jnp.ones((sq, sk), bool), k=sk - sq)
        s = jnp.where(mask, s, -1e30)
    p = gn_softmax_ref(s, cfg)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
