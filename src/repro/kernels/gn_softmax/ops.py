"""Jit'd public wrapper for the GN-Softmax Pallas kernel.

Handles arbitrary leading dims, lane padding to 128 and row padding to the
block size, then dispatches to the kernel.  ``interpret=True`` runs the kernel
body in Python on CPU (how this container validates it); on a real TPU the
same code compiles to Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.luts import SoftmaxLUTConfig, TPU_SOFTMAX_LUT
from repro.kernels.gn_softmax.kernel import gn_softmax_pallas

LANE = 128
SUBLANE = 8


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@functools.partial(jax.jit, static_argnames=("cfg", "block_rows", "interpret"))
def gn_softmax(
    x: jax.Array,
    cfg: SoftmaxLUTConfig = TPU_SOFTMAX_LUT,
    block_rows: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """GN-Softmax over the last axis of an arbitrarily-shaped array."""
    orig_shape = x.shape
    cols = orig_shape[-1]
    rows = 1
    for d in orig_shape[:-1]:
        rows *= d
    x2 = x.reshape(rows, cols)

    cols_p = _round_up(cols, LANE)
    block_rows = min(block_rows, _round_up(rows, SUBLANE))
    rows_p = _round_up(rows, block_rows)
    x2 = jnp.pad(
        x2,
        ((0, rows_p - rows), (0, cols_p - cols)),
        constant_values=-1e30,  # padding lanes never win the max
    )
    out = gn_softmax_pallas(
        x2, cfg=cfg, block_rows=block_rows, interpret=interpret, valid_cols=cols
    )
    return out[:rows, :cols].reshape(orig_shape)
