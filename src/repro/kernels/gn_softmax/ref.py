"""Pure-jnp oracle for the GN-Softmax Pallas kernel.

Semantics: row-wise Algorithm 1 over the last axis, float-faithful datapath.
This must match ``kernel.py`` bit-for-bit up to float associativity.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.luts import SoftmaxLUTConfig, TPU_SOFTMAX_LUT, exp_luts


def gn_softmax_ref(x: jax.Array, cfg: SoftmaxLUTConfig = TPU_SOFTMAX_LUT) -> jax.Array:
    """Reference: stabilize -> two-LUT factorized exp -> renormalize."""
    coarse_np, residual_np = exp_luts(cfg)
    coarse = jnp.asarray(coarse_np)
    residual = jnp.asarray(residual_np)

    x32 = x.astype(jnp.float32)
    m = jnp.max(x32, axis=-1, keepdims=True)
    m = jnp.ceil(m / cfg.step) * cfg.step    # grid-snapped stabilizer
    delta = jnp.maximum(m - x32, 0.0)
    d_int = jnp.round(delta / cfg.step).astype(jnp.int32)
    d_int = jnp.clip(d_int, 0, cfg.max_delta_int)
    frac = d_int >> (3 + cfg.frac_bits)
    rem = d_int & (cfg.residual_entries - 1)
    y = coarse[frac] * residual[rem]
    scale = float(1 << cfg.lut_value_bits)
    y = jnp.round(y * scale) / scale
    z = jnp.sum(y, axis=-1, keepdims=True)
    return (y / z).astype(x.dtype)
