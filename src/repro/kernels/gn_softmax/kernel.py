"""GN-Softmax Pallas TPU kernel.

TPU adaptation of the paper's Fig. 3 datapath (see DESIGN.md §2):

* rows stream through VMEM in ``(block_rows, cols)`` tiles — the Pallas
  analogue of the RTL's N-cycle streaming pipeline;
* the two exponential LUTs (7-entry coarse, ``R·2^f``-entry residual) ride in
  as (1, 128) VMEM operands and are applied as **one-hot × LUT matmuls** — the
  MXU-idiomatic equivalent of a ROM lookup (TPU has no cheap per-lane gather);
* the single per-row reciprocal (FxP_Div in silicon) is one VPU ``1/z``;
  numerator and denominator use the same approximated ``y``, so ``Σp = 1``.

Lane/sublane alignment: ``cols`` must be a multiple of 128 and ``block_rows``
a multiple of 8 (callers pad; see ops.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.luts import SoftmaxLUTConfig, TPU_SOFTMAX_LUT
from repro.kernels.common import exp_lut_operands, factorized_exp


def _gn_softmax_kernel(
    x_ref, coarse_ref, residual_ref, o_ref, *, cfg: SoftmaxLUTConfig, valid_cols: int
):
    x = x_ref[...].astype(jnp.float32)
    rows, cols = x.shape

    # mask padding lanes so they contribute nothing
    lane = jax.lax.broadcasted_iota(jnp.int32, (rows, cols), 1)
    valid = lane < valid_cols
    x = jnp.where(valid, x, jnp.full_like(x, -1e30))

    # (i) max-subtraction stage (stabilizer snapped onto the Δ grid, matching
    # the RTL's integer-domain max; see core/gn_softmax.py)
    m = jnp.max(x, axis=-1, keepdims=True)
    m = jnp.ceil(m * jnp.float32(1.0 / cfg.step)) * jnp.float32(cfg.step)
    delta = jnp.maximum(m - x, 0.0)

    # (ii) exponential stage: Δ-grid quantization + two-LUT factorization
    y = factorized_exp(delta, coarse_ref[...], residual_ref[...], cfg)
    y = jnp.where(valid, y, 0.0)

    # (iii) normalization stage: one reciprocal per row, shared numerator /
    # denominator => sum(p) == 1 up to the reciprocal rounding.
    z = jnp.sum(y, axis=-1, keepdims=True)
    p = y * (1.0 / z)
    o_ref[...] = p.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("cfg", "block_rows", "interpret", "valid_cols")
)
def gn_softmax_pallas(
    x: jax.Array,
    cfg: SoftmaxLUTConfig = TPU_SOFTMAX_LUT,
    block_rows: int = 256,
    interpret: bool = False,
    valid_cols: int | None = None,
) -> jax.Array:
    """2D entry point: x (rows, cols_padded); rows % block_rows == 0.

    ``valid_cols``: true (unpadded) width — lanes beyond it are masked out of
    the max and the sum.  Use :func:`repro.kernels.gn_softmax.ops.gn_softmax`
    for arbitrary shapes.
    """
    rows, cols = x.shape
    if valid_cols is None:
        valid_cols = cols
    if rows % block_rows:
        raise ValueError(f"rows {rows} not a multiple of block_rows {block_rows}")
    coarse, residual = exp_lut_operands(cfg)
    grid = (rows // block_rows,)
    return pl.pallas_call(
        functools.partial(_gn_softmax_kernel, cfg=cfg, valid_cols=valid_cols),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
            pl.BlockSpec(coarse.shape, lambda i: (0, 0)),
            pl.BlockSpec(residual.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), x.dtype),
        interpret=interpret,
    )(x, coarse, residual)
