"""Checkpoint store: atomic npz shards + JSON manifest, with elastic restore.

Arrays are saved *logically* (fully replicated host values), so a checkpoint
written on one mesh restores onto any other mesh shape — `restore_sharded`
re-device_puts every leaf under the target sharding.  Writes are atomic
(tmp dir + rename) so a crash mid-save never corrupts the latest checkpoint.
"""
from __future__ import annotations

import json
import os
import shutil
from pathlib import Path

import jax
import numpy as np


def _flatten(tree):
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in leaves_with_paths:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[name] = leaf
    return out


def save(path: str | Path, step: int, tree, extra: dict | None = None) -> Path:
    """Atomically write checkpoint ``step`` under ``path``."""
    path = Path(path)
    final = path / f"step_{step:08d}"
    tmp = path / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    flat = _flatten(tree)
    arrays = {}
    dtypes = {}
    for k, v in flat.items():
        a = np.asarray(jax.device_get(v))
        dtypes[k] = str(a.dtype)
        if a.dtype.kind == "V" or a.dtype.name in ("bfloat16", "float8_e4m3fn", "float8_e5m2"):
            # npz can't serialize ml_dtypes natively: store raw bits
            a = a.view(np.uint16 if a.dtype.itemsize == 2 else np.uint8)
        arrays[k] = a
    np.savez(tmp / "arrays.npz", **arrays)
    manifest = {
        "step": step,
        "keys": sorted(arrays.keys()),
        "dtypes": dtypes,
        "extra": extra or {},
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=2))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    # prune stale tmp dirs from crashed saves
    for stale in path.glob(".tmp_step_*"):
        shutil.rmtree(stale, ignore_errors=True)
    return final


def latest_step(path: str | Path) -> int | None:
    path = Path(path)
    if not path.exists():
        return None
    steps = sorted(
        int(p.name.split("_")[1]) for p in path.glob("step_*") if p.is_dir()
    )
    return steps[-1] if steps else None


def restore(path: str | Path, step: int, like) -> tuple:
    """Restore into the structure of ``like`` (pytree of arrays/structs).

    Returns (tree, manifest).  Leaf order is matched by flattened path name.
    """
    path = Path(path) / f"step_{step:08d}"
    manifest = json.loads((path / "manifest.json").read_text())
    data = np.load(path / "arrays.npz")
    flat_like = _flatten(like)
    missing = set(flat_like) - set(data.files)
    if missing:
        raise ValueError(f"checkpoint missing keys: {sorted(missing)[:5]} ...")
    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    names = [
        "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path_)
        for path_, _ in leaves_with_paths
    ]
    dtypes = manifest.get("dtypes", {})
    import ml_dtypes  # noqa: F401 — registers bfloat16 etc. with numpy

    vals = []
    for n in names:
        a = data[n]
        want = dtypes.get(n)
        if want and str(a.dtype) != want:
            a = a.view(np.dtype(want))  # undo the raw-bits encoding
        vals.append(a)
    return jax.tree_util.tree_unflatten(treedef, vals), manifest


def restore_flat(path: str | Path, step: int) -> tuple[dict, dict]:
    """Restore a checkpoint as its flat ``{path-name: array}`` dict plus the
    manifest, without a ``like`` tree.  For callers whose tree structure is
    data-dependent — e.g. the serving engine's crash-consistent snapshots,
    where per-slot / per-request / per-spill keys exist only while occupied —
    so no statically-known template can describe the saved set.  Dtypes are
    decoded exactly as ``restore`` does (raw-bits leaves viewed back)."""
    path = Path(path) / f"step_{step:08d}"
    manifest = json.loads((path / "manifest.json").read_text())
    data = np.load(path / "arrays.npz")
    dtypes = manifest.get("dtypes", {})
    import ml_dtypes  # noqa: F401 — registers bfloat16 etc. with numpy

    flat = {}
    for n in data.files:
        a = data[n]
        want = dtypes.get(n)
        if want and str(a.dtype) != want:
            a = a.view(np.dtype(want))  # undo the raw-bits encoding
        flat[n] = a
    return flat, manifest


def restore_sharded(path, step, like, shardings):
    """Elastic restore: place every leaf under the target mesh's sharding.

    ``shardings`` is a pytree of NamedSharding parallel to ``like`` (or None
    for single-device).  The checkpoint may have been written on a different
    mesh — arrays are logical, so this is a pure re-placement.
    """
    tree, manifest = restore(path, step, like)
    if shardings is None:
        tree = jax.tree.map(jax.numpy.asarray, tree)
    else:
        tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree, shardings)
    return tree, manifest
