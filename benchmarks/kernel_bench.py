"""Kernel micro-benchmarks: Pallas GN kernels vs jnp references.

On this CPU container the Pallas kernels execute in interpret mode, so
wall-times are NOT TPU projections — reported for relative tracking only.
The structural numbers (VMEM working set per BlockSpec tile, HLO flops and
bytes of the reference path) are hardware-independent and feed §Perf.

``--paged-only`` runs just the paged-attention read sweep (block_size x
block horizon, streamed vs gathered) and merges it into the existing
kernel_bench.json — the CI smoke invocation.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import writeout
from repro.core.luts import TPU_SOFTMAX_LUT
from repro.kernels.gn_attention.ref import gn_attention_ref
from repro.kernels.gn_softmax.ref import gn_softmax_ref
from repro.kernels.gn_layernorm.ref import gn_layernorm_ref


def _time(fn, *args, reps=5):
    # one warmup evaluation; jax.block_until_ready handles tuples/pytrees
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6  # us


def _ref_cost(fn, *args) -> dict:
    c = jax.jit(fn).lower(*args).compile().cost_analysis()
    if isinstance(c, list):
        c = c[0]
    return {"flops": float(c.get("flops", 0)), "bytes": float(c.get("bytes accessed", 0))}


def vmem_bytes_softmax(block_rows=256, cols=2048):
    # x tile + y + LUT operands, f32
    return (block_rows * cols * 2 + 2 * 128) * 4


def vmem_bytes_attention(bq=128, bk=128, d=128):
    # q,k,v tiles + acc + m/l + scores
    return (bq * d * 2 + 2 * bk * d + bq * bk + 2 * bq * 128) * 4


def paged_sweep() -> dict:
    """Paged-attention read sweep: streamed (gather-free block-tile scan)
    vs gathered (full-stream materialization, the PR 3 path) through the
    SAME block tables, across block_size x block-horizon.  Wall times are
    CPU-relative only; the HLO ``bytes`` column is the hardware-independent
    story — the gathered read's traffic carries the materialized
    (N, H*bs, KV, dh) stream, the streamed read touches each arena tile
    once per pass.  (The Pallas kernel itself is interpret-checked in
    tests/test_serve_paged.py; timing it interpreted would measure the
    interpreter, not the kernel.)"""
    from repro.configs.registry import get_config, reduce_config
    from repro.models import attention as attention_mod

    cfg = reduce_config(get_config("internlm2-1.8b"))
    rng = np.random.default_rng(0)
    n_slots, chunk, d = 4, 4, cfg.d_model
    p = {
        "wq": jnp.asarray(rng.normal(size=(d, cfg.q_features)) * 0.05, jnp.float32),
        "wk": jnp.asarray(rng.normal(size=(d, cfg.kv_features)) * 0.05, jnp.float32),
        "wv": jnp.asarray(rng.normal(size=(d, cfg.kv_features)) * 0.05, jnp.float32),
        "wo": jnp.asarray(rng.normal(size=(cfg.q_features, d)) * 0.05, jnp.float32),
    }
    rows = []
    for bs in (4, 8):
        for horizon in (2, 8, 16):
            nb = n_slots * horizon
            ak = jnp.asarray(
                rng.normal(size=(nb, bs, cfg.n_kv_heads, cfg.head_dim)),
                jnp.float32)
            av = jnp.asarray(
                rng.normal(size=(nb, bs, cfg.n_kv_heads, cfg.head_dim)),
                jnp.float32)
            x = jnp.asarray(rng.normal(size=(n_slots, chunk, d)) * 0.1,
                            jnp.float32)
            tables = jnp.asarray(
                rng.permutation(nb).reshape(n_slots, horizon), jnp.int32)
            positions = jnp.full((n_slots,), horizon * bs - chunk, jnp.int32)
            n_valid = jnp.full((n_slots,), chunk, jnp.int32)
            row = {"block_size": bs, "horizon_blocks": horizon,
                   "attended_tokens": horizon * bs}
            for path in ("gathered", "streamed"):
                attention_mod.FORCE_PAGED_READ = path
                try:
                    fn = jax.jit(lambda ak_, av_, x_, pos_, nv_, tb_:
                                 attention_mod.attn_paged_chunk(
                                     cfg, p, ak_, av_, x_, pos_, nv_, tb_)[0])
                    args = (ak, av, x, positions, n_valid, tables)
                    row[f"{path}_us"] = _time(fn, *args)
                    cost = fn.lower(*args).compile().cost_analysis()
                    if isinstance(cost, list):
                        cost = cost[0]
                    row[f"{path}_bytes"] = float(cost.get("bytes accessed", 0))
                    row[f"{path}_flops"] = float(cost.get("flops", 0))
                finally:
                    attention_mod.FORCE_PAGED_READ = None
            row["speedup"] = row["gathered_us"] / row["streamed_us"]
            rows.append(row)
    return {"shape": {"n_slots": n_slots, "chunk": chunk,
                      "kv_heads": cfg.n_kv_heads, "head_dim": cfg.head_dim},
            "sweep": rows}


def run(paged_only: bool = False) -> dict:
    if paged_only:
        # merge into the existing file so the smoke invocation never wipes
        # the full-suite numbers
        path = (Path(__file__).resolve().parent.parent / "experiments"
                / "results" / "kernel_bench.json")
        out = {}
        if path.exists():
            try:
                out = json.loads(path.read_text())
            except json.JSONDecodeError:
                out = {}
        out["gn_paged_attention"] = paged_sweep()
        return writeout("kernel_bench", out)
    return _run_full()


def _run_full() -> dict:
    out = {}
    x = jax.random.normal(jax.random.PRNGKey(0), (256, 2048))
    j_ref = jax.jit(lambda v: gn_softmax_ref(v, TPU_SOFTMAX_LUT))
    out["gn_softmax"] = {
        "ref_us": _time(j_ref, x),
        **_ref_cost(lambda v: gn_softmax_ref(v, TPU_SOFTMAX_LUT), x),
        "vmem_tile_bytes": vmem_bytes_softmax(),
    }
    g = jnp.ones((2048,))
    b = jnp.zeros((2048,))
    j_ln = jax.jit(lambda v: gn_layernorm_ref(v, g, b))
    out["gn_layernorm"] = {
        "ref_us": _time(j_ln, x),
        **_ref_cost(lambda v: gn_layernorm_ref(v, g, b), x),
        "vmem_tile_bytes": vmem_bytes_softmax(),
    }
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 4, 256, 64)) * 0.3
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 4, 256, 64)) * 0.3
    v = jax.random.normal(jax.random.PRNGKey(3), (1, 4, 256, 64))
    j_at = jax.jit(lambda a, b2, c: gn_attention_ref(a, b2, c, causal=True))
    out["gn_attention"] = {
        "ref_us": _time(j_at, q, k, v),
        **_ref_cost(lambda a, b2, c: gn_attention_ref(a, b2, c, causal=True), q, k, v),
        "vmem_tile_bytes": vmem_bytes_attention(),
    }
    out["gn_paged_attention"] = paged_sweep()
    return writeout("kernel_bench", out)


def _print_paged(sweep: dict):
    print(f"\npaged read sweep (streamed vs gathered, "
          f"shape {sweep['shape']}):")
    print(f"{'bs':>4s} {'horizon':>8s} {'tok':>5s} {'gathered_us':>12s} "
          f"{'streamed_us':>12s} {'speedup':>8s} {'gath_MB':>8s} {'strm_MB':>8s}")
    for r in sweep["sweep"]:
        print(f"{r['block_size']:4d} {r['horizon_blocks']:8d} "
              f"{r['attended_tokens']:5d} {r['gathered_us']:12.1f} "
              f"{r['streamed_us']:12.1f} {r['speedup']:8.2f} "
              f"{r['gathered_bytes']/1e6:8.2f} {r['streamed_bytes']/1e6:8.2f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--paged-only", action="store_true",
                    help="run just the paged-attention sweep (CI smoke); "
                         "merges into the existing kernel_bench.json")
    args = ap.parse_args()
    rows = run(paged_only=args.paged_only)
    if not args.paged_only:
        print(f"{'kernel':14s} {'ref_us':>10s} {'MFLOP':>8s} {'MB':>8s} {'VMEM_KB':>8s}")
        for k, m in rows.items():
            if k == "gn_paged_attention":
                continue
            print(f"{k:14s} {m['ref_us']:10.1f} {m['flops']/1e6:8.2f} "
                  f"{m['bytes']/1e6:8.2f} {m['vmem_tile_bytes']/1024:8.1f}")
    _print_paged(rows["gn_paged_attention"])


if __name__ == "__main__":
    main()
