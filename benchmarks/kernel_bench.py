"""Kernel micro-benchmarks: Pallas GN kernels vs jnp references.

On this CPU container the Pallas kernels execute in interpret mode, so
wall-times are NOT TPU projections — reported for relative tracking only.
The structural numbers (VMEM working set per BlockSpec tile, HLO flops and
bytes of the reference path) are hardware-independent and feed §Perf.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import writeout
from repro.core.luts import TPU_SOFTMAX_LUT
from repro.kernels.gn_attention.ref import gn_attention_ref
from repro.kernels.gn_softmax.ref import gn_softmax_ref
from repro.kernels.gn_layernorm.ref import gn_layernorm_ref


def _time(fn, *args, reps=5):
    # one warmup evaluation; jax.block_until_ready handles tuples/pytrees
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6  # us


def _ref_cost(fn, *args) -> dict:
    c = jax.jit(fn).lower(*args).compile().cost_analysis()
    if isinstance(c, list):
        c = c[0]
    return {"flops": float(c.get("flops", 0)), "bytes": float(c.get("bytes accessed", 0))}


def vmem_bytes_softmax(block_rows=256, cols=2048):
    # x tile + y + LUT operands, f32
    return (block_rows * cols * 2 + 2 * 128) * 4


def vmem_bytes_attention(bq=128, bk=128, d=128):
    # q,k,v tiles + acc + m/l + scores
    return (bq * d * 2 + 2 * bk * d + bq * bk + 2 * bq * 128) * 4


def run() -> dict:
    out = {}
    x = jax.random.normal(jax.random.PRNGKey(0), (256, 2048))
    j_ref = jax.jit(lambda v: gn_softmax_ref(v, TPU_SOFTMAX_LUT))
    out["gn_softmax"] = {
        "ref_us": _time(j_ref, x),
        **_ref_cost(lambda v: gn_softmax_ref(v, TPU_SOFTMAX_LUT), x),
        "vmem_tile_bytes": vmem_bytes_softmax(),
    }
    g = jnp.ones((2048,))
    b = jnp.zeros((2048,))
    j_ln = jax.jit(lambda v: gn_layernorm_ref(v, g, b))
    out["gn_layernorm"] = {
        "ref_us": _time(j_ln, x),
        **_ref_cost(lambda v: gn_layernorm_ref(v, g, b), x),
        "vmem_tile_bytes": vmem_bytes_softmax(),
    }
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 4, 256, 64)) * 0.3
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 4, 256, 64)) * 0.3
    v = jax.random.normal(jax.random.PRNGKey(3), (1, 4, 256, 64))
    j_at = jax.jit(lambda a, b2, c: gn_attention_ref(a, b2, c, causal=True))
    out["gn_attention"] = {
        "ref_us": _time(j_at, q, k, v),
        **_ref_cost(lambda a, b2, c: gn_attention_ref(a, b2, c, causal=True), q, k, v),
        "vmem_tile_bytes": vmem_bytes_attention(),
    }
    return writeout("kernel_bench", out)


def main():
    rows = run()
    print(f"{'kernel':14s} {'ref_us':>10s} {'MFLOP':>8s} {'MB':>8s} {'VMEM_KB':>8s}")
    for k, m in rows.items():
        print(f"{k:14s} {m['ref_us']:10.1f} {m['flops']/1e6:8.2f} "
              f"{m['bytes']/1e6:8.2f} {m['vmem_tile_bytes']/1024:8.1f}")


if __name__ == "__main__":
    main()
