"""Table II analogue: score-task degradation, ours vs prior approximations.

Paper claim: unnormalized designs (Softermax [5], quant-approx [13], SoftmAP
[14]) lose 0.49–13.68% on score-oriented tasks; guaranteed normalization
loses ~0.  Same protocol as table1: inject each non-GEMM implementation into
the FP32-trained model and measure perplexity degradation.
"""
from __future__ import annotations

from benchmarks.common import eval_metrics, train_tiny, with_impls, writeout

METHODS = {
    # label: (softmax_impl, norm_impl) — norm baselines paired as in refs
    "Softermax[5]-style": ("softermax", "exact_ln"),
    "QuantApprox[13]-style": ("log_domain", "integer_ln"),
    "PseudoSoftmax[6]-style": ("pseudo", "exact_ln"),
    "LUT-LN[15]-style": ("exact", "lut_ln"),
    "Proposed(GN)": ("gn", "gn_ln"),
}


def run(steps: int = 300) -> dict:
    cfg, model, params = train_tiny(steps)
    base = eval_metrics(cfg, params)
    rows = {"FP32": {**base, "ppl_drop_%": 0.0}}
    for label, (sm, nm) in METHODS.items():
        m = eval_metrics(with_impls(cfg, sm, nm), params)
        m["ppl_drop_%"] = 100.0 * (m["perplexity"] - base["perplexity"]) / base["perplexity"]
        rows[label] = m
    return writeout("table2_score_tasks", rows)


def main():
    rows = run()
    print(f"{'method':24s} {'ppl':>9s} {'drop%':>8s} {'top1':>7s}")
    for k, m in rows.items():
        print(f"{k:24s} {m['perplexity']:9.3f} {m['ppl_drop_%']:8.3f} {m['top1_acc']:7.4f}")


if __name__ == "__main__":
    main()
