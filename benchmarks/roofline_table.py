"""Render the dry-run JSON records into the EXPERIMENTS.md roofline tables."""
from __future__ import annotations

import json
from pathlib import Path

DRYRUN = Path(__file__).resolve().parent.parent / "experiments" / "dryrun"


def load(mesh: str = "pod16x16") -> list[dict]:
    rows = []
    for p in sorted(DRYRUN.glob(f"*__{mesh}.json")):
        rows.append(json.loads(p.read_text()))
    return rows


def render(mesh: str = "pod16x16") -> str:
    rows = load(mesh)
    lines = [
        f"### Roofline — mesh {mesh}",
        "",
        "| arch | shape | compute s | memory s | collective s | bottleneck | "
        "useful/HLO flops | roofline frac | temp GiB |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if not r.get("ok"):
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | FAILED | — | — | — |"
            )
            continue
        f = r["roofline"]
        mem = r["memory"]["temp_bytes"] / 2**30
        lines.append(
            f"| {r['arch']} | {r['shape']} | {f['compute_s']:.4f} | "
            f"{f['memory_s']:.4f} | {f['collective_s']:.4f} | {f['bottleneck']} | "
            f"{f['useful_flops_ratio']:.3f} | {f['roofline_fraction']:.3f} | {mem:.2f} |"
        )
    return "\n".join(lines)


def main():
    for mesh in ("pod16x16", "pod2x16x16"):
        print(render(mesh))
        print()


if __name__ == "__main__":
    main()
