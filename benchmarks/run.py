"""Benchmark entry point — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV per the harness contract.
``REPRO_BENCH_STEPS`` (default 300) controls the shared tiny-model training
budget; results are cached under experiments/artifacts.
"""
from __future__ import annotations

import os
import time


def _timed(fn, *a, **k):
    t0 = time.perf_counter()
    out = fn(*a, **k)
    return out, (time.perf_counter() - t0) * 1e6


def main() -> None:
    steps = int(os.environ.get("REPRO_BENCH_STEPS", "300"))
    rows = []

    from benchmarks import (  # noqa: PLC0415
        fig5_norm_error,
        kernel_bench,
        table1_accuracy,
        table2_score_tasks,
        table3_hw_cost,
    )

    t1, us = _timed(table1_accuracy.run, steps)
    rows.append(("table1_accuracy", us, f"gn_ppl_delta_pct={t1['FP32+Ours']['ppl_delta_%']:.4f}"))

    t2, us = _timed(table2_score_tasks.run, steps)
    worst = max(
        (m["ppl_drop_%"] for k, m in t2.items() if k not in ("FP32", "Proposed(GN)")),
    )
    rows.append((
        "table2_score_tasks", us,
        f"gn_drop_pct={t2['Proposed(GN)']['ppl_drop_%']:.4f};worst_baseline_drop_pct={worst:.3f}",
    ))

    f5, us = _timed(fig5_norm_error.run, steps)
    rows.append((
        "fig5_norm_error", us,
        "gn_sm_below2e-7={:.3f};gn_ln_below2e-7={:.3f}".format(
            f5["softmax"]["gn"]["frac_below_0.2e-6"],
            f5["layernorm"]["gn_ln"]["frac_below_0.2e-6"],
        ),
    ))

    t3, us = _timed(table3_hw_cost.run)
    rows.append((
        "table3_hw_cost", us,
        f"gn_softmax_area_proxy={t3['softmax/gn']['area_proxy']:.1f};"
        f"exact_softmax_area_proxy={t3['softmax/exact']['area_proxy']:.1f}",
    ))

    kb, us = _timed(kernel_bench.run)
    rows.append(("kernel_bench", us, f"attn_ref_us={kb['gn_attention']['ref_us']:.1f}"))

    try:
        from benchmarks import roofline_table

        tbl, us = _timed(roofline_table.load, "pod16x16")
        ok = sum(1 for r in tbl if r.get("ok"))
        rows.append(("roofline_table", us, f"cells_ok={ok}/{len(tbl)}"))
    except Exception:  # dry-run may not have been run yet
        pass

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.0f},{derived}")


if __name__ == "__main__":
    main()
