"""Fig. 5 analogue: distribution of normalization errors during evaluation.

Collects |1 - Σp| over every attention softmax row and |1 - σ| over every
LayerNorm row while the trained model evaluates held-out batches, per
implementation.  Paper: 77.1% of softmax and 100% of LN errors < 0.2e-6
for the proposed design; baselines orders of magnitude worse.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import TINY_DATA, train_tiny, writeout
from repro.core import error_histogram, get_norm, get_softmax, metrics
from repro.data.synthetic import batch_at


def _collect_attention_inputs(cfg, model, params, n_batches=2):
    """Grab raw attention scores + pre-norm activations via a probe forward."""
    from repro.models.rope import apply_rope

    scores_all, acts_all = [], []
    fwd = jax.jit(model.forward)
    # probe: recompute the first layer's scores/activations explicitly
    for i in range(n_batches):
        batch = batch_at(TINY_DATA, 20_000 + i)
        toks = batch["tokens"]
        x = params["embed"]["tok"][toks].astype(jnp.float32)
        acts_all.append(np.asarray(x.reshape(-1, x.shape[-1])))
        lp = jax.tree.map(lambda a: a[0], params["layers"])
        b, s, d = x.shape
        h, hd = cfg.n_heads, cfg.head_dim
        q = (x @ lp["mixer"]["wq"]).reshape(b, s, h, hd)
        k = (x @ lp["mixer"]["wk"]).reshape(b, s, h, hd)
        pos = jnp.broadcast_to(jnp.arange(s), (b, s))
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
        sc = jnp.einsum("bshd,bthd->bhst", q, k) * hd**-0.5
        mask = jnp.tril(jnp.ones((s, s), bool))
        sc = jnp.where(mask[None, None], sc, -1e30)
        scores_all.append(np.asarray(sc.reshape(-1, s)))
    return np.concatenate(scores_all), np.concatenate(acts_all)


def run(steps: int = 300) -> dict:
    cfg, model, params = train_tiny(steps)
    scores, acts = _collect_attention_inputs(cfg, model, params)
    scores = jnp.asarray(scores)
    acts = jnp.asarray(acts)

    out = {"softmax": {}, "layernorm": {}}
    for name in ("exact", "gn", "gn_hwsim", "softermax", "pseudo", "log_domain"):
        p = get_softmax(name)(scores)
        err = np.asarray(metrics.softmax_norm_error(p))
        out["softmax"][name] = error_histogram(err)
    for name in ("exact_ln", "gn_ln", "gn_ln_hwsim", "integer_ln", "lut_ln"):
        y = get_norm(name)(acts)
        err = np.asarray(metrics.layernorm_norm_error(y))
        out["layernorm"][name] = error_histogram(err)
    return writeout("fig5_norm_error", out)


def main():
    out = run()
    for fam in ("softmax", "layernorm"):
        print(f"--- {fam} normalization error ---")
        print(f"{'impl':12s} {'mean':>10s} {'max':>10s} {'<2e-7':>7s}")
        for k, h in out[fam].items():
            print(f"{k:12s} {h['mean']:10.2e} {h['max']:10.2e} "
                  f"{100*h['frac_below_0.2e-6']:6.1f}%")


if __name__ == "__main__":
    main()
