"""Shared benchmark plumbing: train-once/eval-many tiny models.

The paper evaluates by *injecting* the approximation units into an FP32
model at inference (``FP32 + Ours``).  We mirror that: train a reduced
GPT-Neo backbone (the paper's perplexity backbone) on the synthetic
Zipf-Markov corpus with exact non-GEMM ops, cache the params, then re-evaluate
the same params under every softmax/norm implementation.
"""
from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import store
from repro.configs.registry import get_config, reduce_config
from repro.data.synthetic import DataConfig, batch_at, optimal_perplexity
from repro.models.transformer import make_model
from repro.train.loop import make_train_step
from repro.train.optimizer import OptimizerConfig, init_opt_state

ART = Path(__file__).resolve().parent.parent / "experiments" / "artifacts"

TINY_DATA = DataConfig(vocab=512, seq_len=64, global_batch=16, branching=8, zipf_a=1.5)


def tiny_cfg(**over):
    cfg = reduce_config(
        get_config("gpt-neo-1.3b"),
        d_model=128, n_layers=4, n_heads=4, n_kv_heads=4, d_ff=512,
        vocab=TINY_DATA.vocab,
    )
    # FP32-exact non-GEMM for the baseline training run
    return dataclasses.replace(
        cfg, softmax_impl="exact", norm_impl="exact_ln", dtype="float32", **over
    )


def train_tiny(steps: int = 300, tag: str = "tiny_lm", **cfg_over):
    """Train (or load cached) the shared tiny backbone.  Returns (cfg, model, params)."""
    cfg = tiny_cfg(**cfg_over)
    model = make_model(cfg)
    ckdir = ART / tag
    latest = store.latest_step(ckdir)
    params = model.init(jax.random.PRNGKey(0))
    if latest == steps:
        (params,), _ = store.restore(ckdir, steps, (params,))
        return cfg, model, params
    opt_cfg = OptimizerConfig(lr=3e-3, warmup_steps=20, total_steps=steps,
                              weight_decay=0.01)
    opt_state = init_opt_state(opt_cfg, params)
    step_fn = jax.jit(make_train_step(model, opt_cfg), donate_argnums=(0, 1))
    t0 = time.time()
    for step in range(steps):
        params, opt_state, m = step_fn(params, opt_state, batch_at(TINY_DATA, step))
        if step % 50 == 0 or step == steps - 1:
            print(f"  [train {tag}] step {step} loss {float(m['loss']):.4f} "
                  f"({time.time()-t0:.0f}s)", flush=True)
    store.save(ckdir, steps, (params,))
    return cfg, model, params


def eval_metrics(cfg, params, n_batches: int = 4, seed0: int = 10_000) -> dict:
    """Held-out perplexity (score) + next-token top-1 accuracy (rank)."""
    model = make_model(cfg)
    fwd = jax.jit(model.forward)
    nlls, accs = [], []
    for i in range(n_batches):
        batch = batch_at(TINY_DATA, seed0 + i)
        logits, _ = fwd(params, batch)
        logits = logits[:, :-1].astype(jnp.float32)
        targets = batch["tokens"][:, 1:]
        logp = jax.nn.log_softmax(logits, -1)
        nll = -jnp.take_along_axis(logp, targets[..., None], -1)[..., 0]
        nlls.append(np.asarray(nll).ravel())
        accs.append(np.asarray(jnp.argmax(logits, -1) == targets).ravel())
    nll = np.concatenate(nlls)
    acc = np.concatenate(accs)
    return {
        "perplexity": float(np.exp(nll.mean())),
        "top1_acc": float(acc.mean()),
        "optimal_perplexity": optimal_perplexity(TINY_DATA),
    }


def with_impls(cfg, softmax_impl: str, norm_impl: str):
    return dataclasses.replace(cfg, softmax_impl=softmax_impl, norm_impl=norm_impl)


def writeout(name: str, payload: dict):
    out = ART.parent / "results"
    out.mkdir(parents=True, exist_ok=True)
    (out / f"{name}.json").write_text(json.dumps(payload, indent=2, default=float))
    return payload
