"""Table III analogue: hardware-cost proxy per non-GEMM implementation.

We cannot synthesize Verilog in this environment; instead we report the
mechanical cost measures available from the computation graph itself:

  * primitive-op census from the closed jaxpr (mul / add / div / exp / ...)
    per row of N elements — the multiplier/divider/exp counts are exactly
    what dominates ASIC area (the paper's mul-/div-free claims are directly
    checkable here);
  * LUT storage bytes (the ROMs a hardware unit would carry);
  * latency model in cycles (paper: N for softmax, N+1 for LN);
  * an area proxy = weighted op count (28nm-ish relative gate weights:
    div 20x, exp 30x, mul 10x, add 1x, LUT byte 0.05x) — stated as a PROXY,
    not µm².
"""
from __future__ import annotations

import collections

import jax
import jax.numpy as jnp

from benchmarks.common import writeout
from repro.core import get_norm, get_softmax
from repro.core.luts import PAPER_RSQRT, PAPER_SOFTMAX_LUT, exp_luts, rsqrt_mantissa_lut

N = 128  # elements per row for the census

# ops that map to expensive datapath blocks
WEIGHTS = {
    "div": 20.0, "exp": 30.0, "log": 30.0, "pow": 30.0, "rsqrt": 25.0,
    "sqrt": 25.0, "dot_general": 10.0, "mul": 10.0,
    "add": 1.0, "sub": 1.0, "max": 1.0, "min": 1.0, "reduce": 1.0,
    "shift_left": 0.5, "shift_right_logical": 0.5, "shift_right_arithmetic": 0.5,
    "and": 0.5, "or": 0.5, "xor": 0.5,
}


def _census(fn, *args) -> dict:
    jaxpr = jax.make_jaxpr(fn)(*args)
    counts: collections.Counter = collections.Counter()

    def walk(jp):
        for eqn in jp.eqns:
            counts[eqn.primitive.name] += 1
            for sub in eqn.params.values():
                if hasattr(sub, "jaxpr"):
                    walk(sub.jaxpr)
                if isinstance(sub, (list, tuple)):
                    for s2 in sub:
                        if hasattr(s2, "jaxpr"):
                            walk(s2.jaxpr)
    walk(jaxpr.jaxpr)
    return dict(counts)


def _area_proxy(counts: dict, lut_bytes: int) -> float:
    a = 0.0
    for op, n in counts.items():
        for key, w in WEIGHTS.items():
            if op.startswith(key):
                a += w * n
                break
    return a + 0.05 * lut_bytes


def lut_bytes_for(impl: str) -> int:
    if impl.startswith("gn"):
        if "ln" in impl:
            return len(rsqrt_mantissa_lut(PAPER_RSQRT)) * 2  # 16-bit entries
        c, r = exp_luts(PAPER_SOFTMAX_LUT)
        return (len(c) + len(r)) * 2
    if impl in ("log_domain", "lut_ln"):
        return (1 << 4) * 2
    return 0


def run() -> dict:
    x = jnp.linspace(-4, 4, N)[None, :]
    rows = {}
    for impl in ("exact", "gn", "softermax", "pseudo", "log_domain"):
        counts = _census(lambda v: get_softmax(impl)(v), x)
        lb = lut_bytes_for(impl)
        rows[f"softmax/{impl}"] = {
            "mul_ops": sum(n for o, n in counts.items() if o.startswith(("mul", "dot"))),
            "div_ops": sum(n for o, n in counts.items() if o.startswith("div")),
            "exp_ops": sum(n for o, n in counts.items() if o.startswith(("exp", "pow", "log"))),
            "lut_bytes": lb,
            "latency_cycles": "N",
            "area_proxy": _area_proxy(counts, lb),
        }
    for impl in ("exact_ln", "gn_ln", "integer_ln", "lut_ln"):
        counts = _census(lambda v: get_norm(impl)(v), x)
        lb = lut_bytes_for(impl)
        rows[f"norm/{impl}"] = {
            "mul_ops": sum(n for o, n in counts.items() if o.startswith(("mul", "dot"))),
            "div_ops": sum(n for o, n in counts.items() if o.startswith("div")),
            "sqrt_ops": sum(n for o, n in counts.items() if "sqrt" in o),
            "lut_bytes": lb,
            "latency_cycles": "N+1" if impl == "gn_ln" else "N",
            "area_proxy": _area_proxy(counts, lb),
        }
    # paper-reported areas for context (µm², Samsung 28nm)
    rows["paper_reference_um2"] = {"softmax": 942, "layernorm": 1199,
                                   "SCIS24_softmax": 2492, "SCIS24_ln": 17388,
                                   "TCASII20_softmax": 10081}
    return writeout("table3_hw_cost", rows)


def main():
    rows = run()
    print(f"{'unit':22s} {'mul':>5s} {'div':>5s} {'exp/sqrt':>9s} {'LUT_B':>6s} {'area~':>8s}")
    for k, m in rows.items():
        if k == "paper_reference_um2":
            continue
        e = m.get("exp_ops", m.get("sqrt_ops", 0))
        print(f"{k:22s} {m['mul_ops']:5d} {m['div_ops']:5d} {e:9d} "
              f"{m['lut_bytes']:6d} {m['area_proxy']:8.1f}")
    print("paper ref (µm²):", rows["paper_reference_um2"])


if __name__ == "__main__":
    main()
