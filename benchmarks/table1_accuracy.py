"""Table I analogue: FP32 vs FP32+Ours on rank- and score-oriented tasks.

Paper claim being reproduced: injecting GN-Softmax + GN-LayerNorm into an
FP32-trained model leaves BOTH task families unchanged (GLUE +0.07%,
SQuAD -0.01%, ppl -0.09%).  Here: top-1 next-token accuracy (rank) and
held-out perplexity (score) on the synthetic corpus with a known entropy
floor.
"""
from __future__ import annotations

from benchmarks.common import eval_metrics, train_tiny, with_impls, writeout


def run(steps: int = 300) -> dict:
    cfg, model, params = train_tiny(steps)
    rows = {}
    for label, (sm, nm) in {
        "FP32": ("exact", "exact_ln"),
        "FP32+Ours": ("gn", "gn_ln"),
        "FP32+Ours(hwsim)": ("gn_hwsim", "gn_ln_hwsim"),
    }.items():
        rows[label] = eval_metrics(with_impls(cfg, sm, nm), params)
    base = rows["FP32"]
    for label, m in rows.items():
        m["ppl_delta_%"] = 100.0 * (m["perplexity"] - base["perplexity"]) / base["perplexity"]
        m["acc_delta_%"] = 100.0 * (m["top1_acc"] - base["top1_acc"]) / max(base["top1_acc"], 1e-9)
    return writeout("table1_accuracy", rows)


def main():
    rows = run()
    print(f"{'impl':20s} {'ppl':>8s} {'Δppl%':>8s} {'top1':>7s} {'Δacc%':>7s}")
    for k, m in rows.items():
        print(f"{k:20s} {m['perplexity']:8.3f} {m['ppl_delta_%']:8.3f} "
              f"{m['top1_acc']:7.4f} {m['acc_delta_%']:7.3f}")
    print(f"(optimal ppl = {rows['FP32']['optimal_perplexity']:.3f})")


if __name__ == "__main__":
    main()
