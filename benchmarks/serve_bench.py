"""Static vs continuous batching serving throughput (BENCH_serve.json).

Workload: staggered arrivals, mixed prompt lengths, mixed decode budgets —
the regime the static engine handles worst (it must group requests into
uniform-length batches and decode every group to its largest budget, paying
for retired sequences).  Continuous batching serves the same requests from
one slot pool with a single jitted fused prefill/decode step: prompts are
bucketed to the chunk grid at intake and stream through idle lanes chunk-
by-chunk while other slots keep decoding.

Both paths are warmed up first so compile time is excluded; each is then
timed end-to-end on the identical request set.  Emits the BENCH_serve.json
schema (written to experiments/results/) so future PRs can track the
serving-throughput trajectory:

  {"benchmark": "serve", "arch": ..., "workload": {... incl. "arch",
                "num_devices", "read_path", "kv_dtype"},
   "static": {"wall_s", "cold_wall_s", "tokens_per_s", "batches"},
   "continuous": {"wall_s", "cold_wall_s", "tokens_per_s", "decode_steps",
                  "fused_ticks", "mean_slot_utilization",
                  "prefill_lane_fraction", "chunk", "intake_padding",
                  "decode_compilations", "fused_step_compilations",
                  "prefill_compilations", "kv_hbm_bytes", "read_path",
                  "num_devices", "per_device_slots", "shard_balance",
                  + paged: "num_blocks", "block_size", "peak_blocks_in_use",
                  "peak_blocks_reserved", "block_utilization",
                  "horizon_bucket_grid", "horizon_buckets",
                  "mean_attended_tokens_per_tick"},
   "kv": {"paged", "slab_hbm_bytes", "kv_hbm_bytes",
          + paged: "num_blocks", "block_size", "slab_slots_at_equal_hbm",
          "equal_hbm_slots_gain"},
   "speedup": ..., "cold_speedup": ..., "greedy_token_identical": ...,
   "kv_dtype": ..., "greedy_lcp_min": ..., "greedy_lcp_mean": ...,
   "history": [{"git_sha", "arch", "workload_hash", "timestamp", "speedup",
                "cold_speedup", "tokens_per_s", "prefill_compilations",
                "decode_compilations", "fused_step_compilations",
                "kv_hbm_bytes", "read_path", "kv_dtype", "greedy_lcp_min",
                "greedy_lcp_mean", "num_devices",
                "per_device_slots", "shard_balance", "num_blocks",
                "block_utilization", "equal_hbm_slots_gain",
                "horizon_buckets", "mean_attended_tokens_per_tick"}, ...]}

``read_path`` (gathered / streamed / pallas / slab) is part of the workload
identity: the gather-free streamed read and the PR 3 gathered read are
different perf trajectories, so runs on different paths must not share a
``workload_hash``.  ``kv_dtype`` (fp / int8) likewise: the int8 pool halves
the arena and roughly doubles ``equal_hbm_slots_gain``, a different
trajectory from fp runs (rows predating the field read back as "fp"); the
quantized run is tolerance-pinned against the fp oracle via its greedy
longest-common-prefix fractions (``greedy_lcp_min``/``greedy_lcp_mean``).  ``horizon_buckets`` and
``mean_attended_tokens_per_tick`` track horizon bucketing — compile counts
pinned to one trace per (step kind, bucket), attended width scaling with
live context instead of max_seq.

``--devices N`` serves from a slot pool sharded over N devices (slot-axis
NamedSharding, least-loaded admission placement — see docs/serving.md
§Device mesh); ``num_devices``/``per_device_slots``/``shard_balance`` track
the scaling trajectory in history rows exactly like the warm/cold speedups.
On CPU export XLA_FLAGS=--xla_force_host_platform_device_count=N first.

The paged-KV measurement runs the workload twice on the continuous engine:
once with a slab-equivalent arena (never admission-blocks) to learn the
peak concurrent block reservation, then with the arena cut to exactly that
peak — proving the same slot count serves from a live-token-sized arena.
``workload`` (and therefore ``workload_hash``) includes ``arch``: older
rows without it remain readable but hash-segregated.

``cold_wall_s`` is the first serve of the workload including compile time —
the static path compiles a prefill per distinct prompt length and a decode
per distinct max_seq, while the fused engine compiles its two steps once
regardless of the length mix; ``wall_s``/``speedup`` are warm (compile
excluded).

``history`` is append-only across runs (keyed by git SHA + workload hash,
newest last) so compile-count and throughput regressions show up in the
perf trajectory instead of being overwritten.

Run:  PYTHONPATH=src python -m benchmarks.serve_bench [--arch internlm2-1.8b]
"""
from __future__ import annotations

import argparse
import hashlib
import json
import subprocess
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import writeout
from repro.configs.registry import get_config, list_archs, reduce_config
from repro.models.transformer import make_model
from repro.serve.engine import (
    ContinuousEngine,
    ServeConfig,
    round_slots_to_devices,
    static_reference,
)
from repro.serve.kv_cache import tree_bytes
from repro.serve.workload import (
    required_max_seq,
    shared_prefix_requests,
    sla_requests,
    staggered_requests,
)

_RESULTS = Path(__file__).resolve().parent.parent / "experiments" / "results"
_HISTORY_MAX = 200  # keep the trajectory bounded


def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, cwd=Path(__file__).parent, timeout=10,
        ).stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def _workload_hash(workload: dict) -> str:
    return hashlib.sha1(
        json.dumps(workload, sort_keys=True, default=float).encode()
    ).hexdigest()[:12]


def _load_history() -> list:
    path = _RESULTS / "BENCH_serve.json"
    if path.exists():
        try:
            return list(json.loads(path.read_text()).get("history", []))
        except (json.JSONDecodeError, OSError):
            return []
    return []


def _upsert_history(history: list, row: dict) -> list:
    """Dedupe history on (git_sha, workload_hash, arch, read_path, kv_dtype):
    a re-run of the same workload at the same commit overwrites its old row
    *in place* (position preserved — the trajectory stays chronological by
    first appearance) instead of appending a duplicate.  Different SHAs,
    archs, workloads, read paths or KV dtypes never collide, so genuine
    trajectory points are all kept.  Rows predating the quantized pool have
    no ``kv_dtype`` field and default to "fp" (what they measured); rows
    predating fault injection have no ``faults`` field and default to
    "none" (they measured a fault-free engine), so a chaos run never
    overwrites the clean-trajectory row for the same workload."""
    def _key(r):
        return (r.get("git_sha"), r.get("workload_hash"), r.get("arch"),
                r.get("read_path"), r.get("kv_dtype", "fp"),
                r.get("faults", "none"))

    for i, old in enumerate(history):
        if _key(old) == _key(row):
            history[i] = row
            return history
    history.append(row)
    return history


def run(arch: str = "internlm2-1.8b", n_requests: int = 12, base_len: int = 16,
        max_new: int = 16, num_slots: int = 0, stagger: int = 1,
        chunk: int = 8, reps: int = 10, tail_len: int = -1,
        devices: int = 1, force_read: str = "", kv_dtype: str = "fp") -> dict:
    if not force_read:
        return _run(arch, n_requests, base_len, max_new, num_slots, stagger,
                    chunk, reps, tail_len, devices, kv_dtype)
    # pin the paged read path (e.g. --force-read gathered to re-measure the
    # PR 3 full-stream baseline on the same host as a streamed run;
    # read_path is folded into workload_hash so the trajectories stay
    # separate).  The override is process-global, so clear it even when the
    # run raises — a stuck force would silently relabel every later run.
    from repro.models import attention as attention_mod

    attention_mod.FORCE_PAGED_READ = force_read
    try:
        return _run(arch, n_requests, base_len, max_new, num_slots, stagger,
                    chunk, reps, tail_len, devices, kv_dtype)
    finally:
        attention_mod.FORCE_PAGED_READ = None


def _greedy_lcp_fractions(comps, ref) -> list:
    """Per-request longest-common-prefix fraction of each continuous greedy
    stream against the fp static oracle (the int8 tolerance metric)."""
    fracs = []
    for c in comps:
        want = np.asarray(ref[c.request_id])
        got = np.asarray(c.tokens)
        lcp = 0
        for a, b in zip(want, got):
            if a != b:
                break
            lcp += 1
        fracs.append(lcp / max(1, len(want)))
    return fracs


def _run(arch, n_requests, base_len, max_new, num_slots, stagger,
         chunk, reps, tail_len, devices, kv_dtype="fp") -> dict:
    cfg = reduce_config(get_config(arch))
    model = make_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # long-tail mix (one 8x-base prompt per 8 requests): the regime where a
    # slab pool's HBM is capped by the tail length while a block-paged pool
    # only spends blocks on live tokens.  --tail-len 0 disables.
    if tail_len < 0:
        tail_len = 8 * base_len
    reqs = staggered_requests(cfg, n_requests=n_requests, base_len=base_len,
                              max_new_tokens=max_new, stagger=stagger, seed=23,
                              tail_len=tail_len, tail_every=8 if tail_len else 0)
    # half the request count keeps the pool busy (~70% util) while static
    # still pays per-group batch fragmentation — the measured sweet spot;
    # rounded up to a device multiple so the slot axis shards evenly
    num_slots = round_slots_to_devices(num_slots or max(2, n_requests // 2),
                                       devices)
    max_seq = required_max_seq(reqs)
    useful = sum(r.max_new_tokens for r in reqs)
    n_groups = len({(r.prompt_len, r.max_new_tokens) for r in reqs})

    scfg = ServeConfig()
    # Cold pass: first serve of the workload INCLUDING compile time.  The
    # static path compiles a prefill per distinct prompt length and a decode
    # per distinct max_seq; the fused engine compiles its two steps exactly
    # once regardless of the length mix — the compile-count win the warm
    # numbers below deliberately exclude.  A throwaway device op first keeps
    # one-time backend init out of whichever path is timed first, and engine
    # construction (pool allocation, fresh-cache build) counts toward the
    # continuous cold time.
    jax.block_until_ready(jnp.zeros(()) + 1)
    t0 = time.time()
    ref = static_reference(model, params, reqs, scfg)
    cold_static_s = time.time() - t0
    t0 = time.time()
    engine = ContinuousEngine(model, params, num_slots=num_slots,
                              max_seq=max_seq, cfg=scfg, chunk=chunk,
                              devices=devices, kv_dtype=kv_dtype)
    engine.run(reqs)
    cold_cont_s = time.time() - t0

    # Paged families: the cold engine's slab-equivalent arena never
    # admission-blocks, so its peak reservation measures the workload's true
    # concurrent-token footprint.  Re-run with the arena cut to exactly that
    # peak — proving the workload still serves — and report HBM against the
    # slab baseline (tight-engine compiles are excluded from cold_wall_s,
    # which times the default-arena engine above).
    per_slot_slab_bytes = tree_bytes(model.cache_specs(1, max_seq))
    kv = {"paged": engine.paged, "slab_hbm_bytes": num_slots * per_slot_slab_bytes}
    if engine.paged:
        # size each device's shard for ITS reservation peak (== the global
        # peak when devices=1), so the tight arena still serves the same
        # workload under least-loaded placement imbalance
        tight_blocks = int(engine.pool.peak_reserved_per_device.max()) * devices
        engine = ContinuousEngine(model, params, num_slots=num_slots,
                                  max_seq=max_seq, cfg=scfg, chunk=chunk,
                                  num_blocks=tight_blocks, devices=devices,
                                  kv_dtype=kv_dtype)
        engine.run(reqs)  # warm the tight engine (and prove it serves)
        paged_hbm = engine.pool.hbm_bytes()
        slab_slots = paged_hbm // per_slot_slab_bytes
        kv.update(
            kv_hbm_bytes=paged_hbm,
            num_blocks=tight_blocks,
            block_size=engine.pool.block_size,
            # how many slab slots the paged pool's HBM would buy, and the
            # slot multiplier at equal memory (the acceptance number)
            slab_slots_at_equal_hbm=int(slab_slots),
            equal_hbm_slots_gain=num_slots / max(1, int(slab_slots)),
        )
    else:
        kv.update(kv_hbm_bytes=engine.pool.hbm_bytes())

    # The two engines are timed back-to-back in interleaved rep pairs and
    # the reported wall time is the *mean over reps of the summed* time per
    # engine: on a noisy shared host, contention bursts are shorter than a
    # rep, so extreme-picking (best-of / median-of) samples the noise while
    # the interleaved totals integrate it out of the ratio.
    static_total = cont_total = 0.0
    for _ in range(reps):
        t0 = time.time()
        ref = static_reference(model, params, reqs, scfg)
        static_total += time.time() - t0
        engine.reset()
        t0 = time.time()
        comps = engine.run(reqs)
        cont_total += time.time() - t0
    static_s, cont_s = static_total / reps, cont_total / reps
    m = engine.metrics()

    identical = all(np.array_equal(c.tokens, ref[c.request_id]) for c in comps)
    lcp = _greedy_lcp_fractions(comps, ref)
    if kv_dtype != "fp":
        # the quantized engine is compared against the SAME fp oracle:
        # greedy streams may diverge late (score noise), but the
        # longest-common-prefix fractions are pinned — the same tolerance
        # discipline tests/test_serve_quant.py enforces per family
        assert min(lcp) >= 0.5 and float(np.mean(lcp)) >= 0.7, \
            f"kv_dtype={kv_dtype}: greedy outputs drifted from the fp " \
            f"oracle beyond the pinned tolerance (lcp fractions {lcp})"
    else:
        assert identical, "fp continuous output diverged from the oracle"
    workload = {
        # arch is part of the workload identity: without it, runs with
        # different --arch hashed alike and polluted one history trajectory
        # (masking per-arch compile-count regressions)
        "arch": arch,
        "n_requests": n_requests,
        "prompt_lens": sorted({r.prompt_len for r in reqs}),
        "max_new_tokens": sorted({r.max_new_tokens for r in reqs}),
        "useful_tokens": useful,
        "arrival_stagger": stagger,
        "num_slots": num_slots,
        "chunk": chunk,
        "tail_len": tail_len,
        # part of the workload identity: a 2-device run is a different
        # trajectory than a 1-device run (same precedent as adding arch)
        "num_devices": devices,
        # likewise the read path: gathered vs streamed vs pallas (vs slab)
        # are different perf trajectories and must not share a hash
        "read_path": m["read_path"],
        # and the KV arena dtype: int8 halves the pool and shifts the
        # equal-HBM trajectory — it must never share a hash with fp runs
        "kv_dtype": kv_dtype,
    }
    payload = {
        "benchmark": "serve",
        "arch": arch,
        "workload": workload,
        "static": {
            "wall_s": static_s,
            "cold_wall_s": cold_static_s,
            "tokens_per_s": useful / static_s,
            "batches": n_groups,
        },
        "continuous": {
            "wall_s": cont_s,
            "cold_wall_s": cold_cont_s,
            "tokens_per_s": useful / cont_s,
            "decode_steps": m["decode_steps"],
            "fused_ticks": m["fused_ticks"],
            "mean_slot_utilization": m["mean_slot_utilization"],
            "prefill_lane_fraction": m["prefill_lane_fraction"],
            "chunk": m["chunk"],
            "intake_padding": m["intake_padding"],
            "decode_compilations": m["decode_compilations"],
            "fused_step_compilations": m["fused_step_compilations"],
            "prefill_compilations": m["prefill_compilations"],
            "kv_hbm_bytes": m["kv_hbm_bytes"],
            "read_path": m["read_path"],
            "num_devices": m["num_devices"],
            "per_device_slots": m["per_device_slots"],
            "shard_balance": m["shard_balance"],
            **({"num_blocks": m["num_blocks"],
                "block_size": m["block_size"],
                "peak_blocks_in_use": m["peak_blocks_in_use"],
                "peak_blocks_reserved": m["peak_blocks_reserved"],
                "block_utilization": m["block_utilization"],
                "horizon_bucket_grid": m["horizon_bucket_grid"],
                "horizon_buckets": m["horizon_buckets"],
                "mean_attended_tokens_per_tick":
                    m["mean_attended_tokens_per_tick"]}
               if m["kv_paged"] else {}),
        },
        "kv": kv,
        "speedup": static_s / cont_s,
        "cold_speedup": cold_static_s / cold_cont_s,
        "greedy_token_identical": identical,
        "kv_dtype": kv_dtype,
        "greedy_lcp_min": float(min(lcp)),
        "greedy_lcp_mean": float(np.mean(lcp)),
    }
    history = _load_history()
    _upsert_history(history, {
        "git_sha": _git_sha(),
        "arch": arch,
        "workload_hash": _workload_hash(workload),
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "speedup": payload["speedup"],
        "cold_speedup": payload["cold_speedup"],
        "tokens_per_s": payload["continuous"]["tokens_per_s"],
        "greedy_token_identical": identical,
        "prefill_compilations": m["prefill_compilations"],
        "decode_compilations": m["decode_compilations"],
        "fused_step_compilations": m["fused_step_compilations"],
        "kv_hbm_bytes": m["kv_hbm_bytes"],
        "read_path": m["read_path"],
        "kv_dtype": kv_dtype,
        "greedy_lcp_min": float(min(lcp)),
        "greedy_lcp_mean": float(np.mean(lcp)),
        "num_devices": m["num_devices"],
        "per_device_slots": m["per_device_slots"],
        "shard_balance": m["shard_balance"],
        # paged-only columns are omitted (not nulled) on slab archs, like
        # the payload's continuous section — nulls read as broken counters
        **({"num_blocks": m["num_blocks"],
            "block_utilization": m["block_utilization"],
            "equal_hbm_slots_gain": kv["equal_hbm_slots_gain"],
            "horizon_buckets": m["horizon_buckets"],
            "mean_attended_tokens_per_tick":
                m["mean_attended_tokens_per_tick"]}
           if m["kv_paged"] else {}),
    })
    payload["history"] = history[-_HISTORY_MAX:]
    return writeout("BENCH_serve", payload)


# ------------------------------------------------------ shared-prefix scenario
def run_shared_prefix(arch: str = "internlm2-1.8b", n_users: int = 16,
                      n_personas: int = 4, system_len: int = 64,
                      persona_len: int = 16, user_len: int = 8,
                      max_new: int = 8, num_slots: int = 0, stagger: int = 4,
                      chunk: int = 8, reps: int = 5, devices: int = 1,
                      force_read: str = "") -> dict:
    """The prefix-sharing headline: N users x M personas over one common
    system prompt, served twice on the same host — prefix cache OFF
    (baseline) and ON — and compared on `prefix_hit_rate`, cold-TTFT (wall
    seconds AND deterministic admit->first-token engine steps: with cached
    prefixes, prefill shrinks to the unshared tail) and
    `equal_hbm_slots_gain` (each engine re-run on an arena cut to its own
    peak block residency; sharing dedupes the common prefix so the ON arena
    is smaller at the same slot count).  Greedy outputs of BOTH engines are
    checked token-identical to the static unshared oracle.  History rows
    carry scenario="shared-prefix" and hash separately from the default
    workload."""
    if force_read:
        from repro.models import attention as attention_mod

        attention_mod.FORCE_PAGED_READ = force_read
        try:
            return run_shared_prefix(arch, n_users, n_personas, system_len,
                                     persona_len, user_len, max_new, num_slots,
                                     stagger, chunk, reps, devices)
        finally:
            attention_mod.FORCE_PAGED_READ = None
    cfg = reduce_config(get_config(arch))
    model = make_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    reqs = shared_prefix_requests(cfg, n_users=n_users, n_personas=n_personas,
                                  system_len=system_len, persona_len=persona_len,
                                  user_len=user_len, max_new_tokens=max_new,
                                  stagger=stagger, seed=11)
    num_slots = round_slots_to_devices(num_slots or max(2, n_users // 3), devices)
    max_seq = required_max_seq(reqs)
    useful = sum(r.max_new_tokens for r in reqs)

    scfg = ServeConfig()
    jax.block_until_ready(jnp.zeros(()) + 1)
    ref = static_reference(model, params, reqs, scfg)

    def _ttft(comps):
        steps = [c.first_token_step - c.admit_step for c in comps]
        secs = [c.ttft_s for c in comps]
        return float(np.mean(steps)), float(np.mean(secs))

    engines, cold, sides = {}, {}, {}
    for name, on in (("off", False), ("on", True)):
        t0 = time.time()
        eng = ContinuousEngine(model, params, num_slots=num_slots,
                               max_seq=max_seq, cfg=scfg, chunk=chunk,
                               devices=devices, prefix_cache=on)
        comps = eng.run(reqs)
        cold[name] = time.time() - t0
        ttft_steps, ttft_s = _ttft(comps)
        assert all(np.array_equal(c.tokens, ref[c.request_id]) for c in comps), \
            f"prefix_cache={on}: continuous output diverged from the oracle"
        engines[name] = eng
        sides[name] = {"cold_wall_s": cold[name],
                       "cold_ttft_steps": ttft_steps, "cold_ttft_s": ttft_s}

    # warm interleaved reps (same rationale as _run: integrate host noise
    # out of the ratio); reset replays identical hit/evict sequences
    totals = {"off": 0.0, "on": 0.0}
    warm_ttft: dict[str, list] = {"off": [], "on": []}
    for _ in range(reps):
        for name, eng in engines.items():
            eng.reset()
            t0 = time.time()
            comps = eng.run(reqs)
            totals[name] += time.time() - t0
            warm_ttft[name].append(_ttft(comps))
    for name, eng in engines.items():
        m = eng.metrics()
        sides[name].update(
            wall_s=totals[name] / reps,
            tokens_per_s=useful / (totals[name] / reps),
            mean_ttft_steps=float(np.mean([t[0] for t in warm_ttft[name]])),
            mean_ttft_s=float(np.mean([t[1] for t in warm_ttft[name]])),
            decode_steps=m["decode_steps"],
            fused_ticks=m["fused_ticks"],
            decode_compilations=m["decode_compilations"],
            fused_step_compilations=m["fused_step_compilations"],
            prefill_compilations=m["prefill_compilations"],
            peak_blocks_in_use=m["peak_blocks_in_use"],
        )
        if name == "on":
            sides[name].update(
                prefix_hit_rate=m["prefix_hit_rate"],
                prefix_hit_requests=m["prefix_hit_requests"],
                prefix_forks=m["prefix_forks"],
                prefix_evictions=m["prefix_evictions"],
                prefix_cached_blocks=m["prefix_cached_blocks"],
            )
        # equal-HBM: re-run on an arena cut to this engine's own peak block
        # residency per device (reservations under-count ON-side residency
        # — cached chains belong to no reservation — so the cut uses
        # peak_used_per_device).  Sharing dedupes the common prefix, so the
        # ON arena is smaller for the same slots -> a larger slots gain.
        tight_blocks = int(eng.pool.peak_used_per_device.max()) * devices
        tight = ContinuousEngine(model, params, num_slots=num_slots,
                                 max_seq=max_seq, cfg=scfg, chunk=chunk,
                                 num_blocks=tight_blocks, devices=devices,
                                 prefix_cache=(name == "on"))
        comps = tight.run(reqs)  # prove the tight arena serves (evicting)
        assert all(np.array_equal(c.tokens, ref[c.request_id]) for c in comps), \
            f"{name}: tight-arena output diverged from the oracle"
        per_slot_slab_bytes = tree_bytes(model.cache_specs(1, max_seq))
        hbm = tight.pool.hbm_bytes()
        slab_slots = int(hbm // per_slot_slab_bytes)
        sides[name].update(
            tight_num_blocks=tight_blocks,
            kv_hbm_bytes=hbm,
            slab_slots_at_equal_hbm=slab_slots,
            equal_hbm_slots_gain=num_slots / max(1, slab_slots),
        )

    m_on = engines["on"].metrics()
    workload = {
        "scenario": "shared-prefix",
        "arch": arch,
        "n_users": n_users,
        "n_personas": n_personas,
        "system_len": system_len,
        "persona_len": persona_len,
        "user_len": user_len,
        "max_new_tokens": max_new,
        "arrival_stagger": stagger,
        "num_slots": num_slots,
        "chunk": chunk,
        "num_devices": devices,
        "read_path": m_on["read_path"],
    }
    payload = {
        "benchmark": "serve",
        "scenario": "shared-prefix",
        "arch": arch,
        "workload": workload,
        "baseline": sides["off"],   # prefix cache off, same host/run
        "prefix": sides["on"],
        "speedup": sides["off"]["wall_s"] / sides["on"]["wall_s"],
        "cold_ttft_steps_speedup": (
            sides["off"]["cold_ttft_steps"] / max(1e-9, sides["on"]["cold_ttft_steps"])
        ),
        "equal_hbm_gain_ratio": (
            sides["on"]["equal_hbm_slots_gain"]
            / max(1e-9, sides["off"]["equal_hbm_slots_gain"])
        ),
        "greedy_token_identical": True,  # asserted above, both engines
    }
    history = _load_history()
    _upsert_history(history, {
        "git_sha": _git_sha(),
        "arch": arch,
        "scenario": "shared-prefix",
        "workload_hash": _workload_hash(workload),
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "read_path": m_on["read_path"],
        "num_devices": devices,
        "greedy_token_identical": True,
        "prefix_hit_rate": sides["on"]["prefix_hit_rate"],
        "prefix_forks": sides["on"]["prefix_forks"],
        "prefix_evictions": sides["on"]["prefix_evictions"],
        "cold_ttft_steps_on": sides["on"]["cold_ttft_steps"],
        "cold_ttft_steps_off": sides["off"]["cold_ttft_steps"],
        "cold_ttft_steps_speedup": payload["cold_ttft_steps_speedup"],
        "cold_ttft_s_on": sides["on"]["cold_ttft_s"],
        "cold_ttft_s_off": sides["off"]["cold_ttft_s"],
        "equal_hbm_slots_gain_on": sides["on"]["equal_hbm_slots_gain"],
        "equal_hbm_slots_gain_off": sides["off"]["equal_hbm_slots_gain"],
        "tokens_per_s": sides["on"]["tokens_per_s"],
        "speedup": payload["speedup"],
        "decode_compilations": sides["on"]["decode_compilations"],
        "fused_step_compilations": sides["on"]["fused_step_compilations"],
        "prefill_compilations": sides["on"]["prefill_compilations"],
    })
    payload["history"] = history[-_HISTORY_MAX:]
    return writeout("BENCH_serve", payload)


# ---------------------------------------------------------------- sla scenario
def _pct(vals, q: float) -> float:
    return float(np.percentile(vals, q)) if len(vals) else -1.0


def _class_stats(comps, klass: str) -> dict:
    """Arrival-anchored step-clock latency stats for one request class.
    Percentiles are over *served* requests (rejected ones never produced a
    token — they are counted, not averaged in)."""
    cls = [c for c in comps if c.req_class == klass]
    served = [c for c in cls if c.finish_reason != "rejected"]
    ttft = [c.ttft_steps for c in served if c.ttft_steps >= 0]
    qwait = [c.queue_wait_steps for c in served]
    tpot = [c.tpot_steps for c in served if c.tpot_steps > 0]
    return {
        "n": len(cls),
        "served": len(served),
        "rejected": len(cls) - len(served),
        "preemptions": sum(c.preemptions for c in served),
        "ttft_steps_p50": _pct(ttft, 50),
        "ttft_steps_p99": _pct(ttft, 99),
        "queue_wait_steps_p50": _pct(qwait, 50),
        "queue_wait_steps_p99": _pct(qwait, 99),
        "tpot_steps_mean": float(np.mean(tpot)) if tpot else -1.0,
    }


def run_sla(arch: str = "internlm2-1.8b", n_requests: int = 24,
            base_len: int = 16, rates: tuple = (0.25, 0.5),
            num_slots: int = 0, chunk: int = 8, reps: int = 2,
            devices: int = 1, preempt: str = "spill",
            aging_steps: int = 48, shed_backlog: int = 0,
            seed: int = 13) -> dict:
    """The SLA headline: open-loop bursty arrivals (``sla_requests``, a
    seeded two-state MMPP with interactive and batch classes) served at
    each offered load twice on the same host — FCFS (baseline) vs
    PriorityScheduler + preemption — and compared on per-class
    arrival-anchored TTFT/TPOT percentiles measured on the deterministic
    engine step clock.  The acceptance number is
    ``interactive_ttft_p99_improvement``: class-aware admission plus
    block-level eviction of batch victims must cut the interactive tail at
    the same offered load.  Every served request (preempted-and-resumed
    ones included) is asserted greedy token-identical to the static
    oracle, each priority engine is reset and replayed to assert an
    identical event trace, and compile counters are asserted at the PR 5
    per-bucket bounds — robustness must not cost determinism or compiles.
    History rows carry scenario="sla"."""
    cfg = reduce_config(get_config(arch))
    model = make_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    num_slots = round_slots_to_devices(num_slots or max(2, n_requests // 8),
                                       devices)

    scfg = ServeConfig()
    jax.block_until_ready(jnp.zeros(()) + 1)

    def _assert_counters(m: dict) -> None:
        # the PR 5 bound: exactly one trace per (step kind, horizon bucket),
        # each capped by the grid; no per-prompt-length prefill jits —
        # preemption/resume must not add a single extra compile
        assert m["prefill_compilations"] == 0, m
        if m["kv_paged"]:
            assert m["fused_step_compilations"] == len(m["fused_buckets"]), m
            assert m["decode_compilations"] == len(m["decode_buckets"]), m
            grid = len(m["horizon_bucket_grid"])
            assert m["fused_step_compilations"] <= grid, m
            assert m["decode_compilations"] <= grid, m
        else:
            assert m["fused_step_compilations"] <= 1, m
            assert m["decode_compilations"] <= 1, m

    sweep = []
    for rate in rates:
        reqs = sla_requests(cfg, n_requests=n_requests, base_len=base_len,
                            rate=rate, seed=seed)
        max_seq = required_max_seq(reqs)
        ref = static_reference(model, params, reqs, scfg)
        span = max(1, max(r.arrival_step for r in reqs))
        point = {
            "rate": rate,
            "offered_tokens_per_step": sum(r.max_new_tokens for r in reqs) / span,
            "arrival_span_steps": span,
        }
        for side, kwargs in (
            ("fcfs", dict(sched="fcfs")),
            ("priority", dict(sched="priority", preempt=preempt,
                              aging_steps=aging_steps,
                              shed_backlog=shed_backlog)),
        ):
            t0 = time.time()
            eng = ContinuousEngine(model, params, num_slots=num_slots,
                                   max_seq=max_seq, cfg=scfg, chunk=chunk,
                                   devices=devices, **kwargs)
            comps = eng.run(reqs)
            cold_s = time.time() - t0
            served = [c for c in comps if c.finish_reason != "rejected"]
            assert all(np.array_equal(c.tokens, ref[c.request_id])
                       for c in served), \
                f"{side}@{rate}: served output diverged from the oracle " \
                "(preempted-and-resumed requests must be token-identical)"
            trace = list(eng.event_log)
            total = 0.0
            for _ in range(reps):
                eng.reset()
                t0 = time.time()
                eng.run(reqs)
                total += time.time() - t0
            assert eng.event_log == trace, \
                f"{side}@{rate}: replay produced a different event trace"
            m = eng.metrics()
            _assert_counters(m)
            useful = sum(int(np.asarray(c.new_tokens).shape[0]) for c in served)
            point[side] = {
                "interactive": _class_stats(comps, "interactive"),
                "batch": _class_stats(comps, "batch"),
                "preemptions": m["preemptions"],
                "preempt_resumes": m["preempt_resumes"],
                "rejections": m["rejections"],
                "decode_steps": m["decode_steps"],
                "cold_wall_s": cold_s,
                "wall_s": total / reps,
                "served_tokens_per_s": useful / (total / reps),
                "fused_step_compilations": m["fused_step_compilations"],
                "decode_compilations": m["decode_compilations"],
                "prefill_compilations": m["prefill_compilations"],
            }
        f99 = point["fcfs"]["interactive"]["ttft_steps_p99"]
        p99 = point["priority"]["interactive"]["ttft_steps_p99"]
        point["interactive_ttft_p99_improvement"] = f99 / max(1e-9, p99)
        sweep.append(point)

    workload = {
        "scenario": "sla",
        "arch": arch,
        "n_requests": n_requests,
        "base_len": base_len,
        "rates": list(rates),
        "num_slots": num_slots,
        "chunk": chunk,
        "num_devices": devices,
        "preempt": preempt,
        "aging_steps": aging_steps,
        "shed_backlog": shed_backlog,
        "seed": seed,
    }
    top = sweep[-1]  # highest offered load = the headline point
    payload = {
        "benchmark": "serve",
        "scenario": "sla",
        "arch": arch,
        "workload": workload,
        "sweep": sweep,
        "interactive_ttft_p99_improvement":
            top["interactive_ttft_p99_improvement"],
        "greedy_token_identical": True,   # asserted per side above
        "deterministic_replay": True,     # asserted per side above
    }
    history = _load_history()
    _upsert_history(history, {
        "git_sha": _git_sha(),
        "arch": arch,
        "scenario": "sla",
        "workload_hash": _workload_hash(workload),
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "num_devices": devices,
        "greedy_token_identical": True,
        "interactive_ttft_p99_improvement":
            top["interactive_ttft_p99_improvement"],
        "interactive_ttft_p99_fcfs": top["fcfs"]["interactive"]["ttft_steps_p99"],
        "interactive_ttft_p99_priority":
            top["priority"]["interactive"]["ttft_steps_p99"],
        "batch_ttft_p99_priority": top["priority"]["batch"]["ttft_steps_p99"],
        "preemptions": top["priority"]["preemptions"],
        "preempt_resumes": top["priority"]["preempt_resumes"],
        "rejections": top["priority"]["rejections"],
        "preempt_mode": preempt,
        "tokens_per_s": top["priority"]["served_tokens_per_s"],
        "decode_compilations": top["priority"]["decode_compilations"],
        "fused_step_compilations": top["priority"]["fused_step_compilations"],
        "prefill_compilations": top["priority"]["prefill_compilations"],
    })
    payload["history"] = history[-_HISTORY_MAX:]
    return writeout("BENCH_serve", payload)


# fault events the sentinels (or the table check) log on detection; a
# FaultRecord is "detected" when one of these lands at step >= its
# injection step (docs/serving.md §Fault tolerance)
_DETECT_EVENTS = ("fault", "fault_table_repair", "device_lost")


def _detection_latencies(records, event_log) -> tuple[list, int]:
    """Per detectable injected fault: engine steps from injection to the
    first fault event at or after it.  Returns (latencies, undetected)."""
    steps = sorted(e[1] for e in event_log if e[0] in _DETECT_EVENTS)
    latencies, undetected = [], 0
    for rec in records:
        if not rec.detectable:
            continue
        hit = next((s for s in steps if s >= rec.step), None)
        if hit is None:
            undetected += 1
        else:
            latencies.append(hit - rec.step)
    return latencies, undetected


def run_chaos(arch: str = "internlm2-1.8b", n_requests: int = 8,
              base_len: int = 10, max_new: int = 8, num_slots: int = 0,
              chunk: int = 8, devices: int = 1,
              fault_rates: tuple = (0.0, 0.1, 0.25),
              kinds: tuple = ("nan_tile", "inf_tile", "table"),
              seed: int = 0) -> dict:
    """The fault-tolerance headline: the same workload served under a
    sweep of per-tick fault-injection rates (seeded ``FaultInjector``,
    faults landed between ticks so the compile story is untouched),
    reporting goodput (useful tokens/s from non-failed completions),
    detection latency in engine ticks, and the recovery-identity rate —
    asserted at 1.0: every completion the engine does not fail closed is
    greedy token-identical to the fault-free static oracle even while
    blocks are being poisoned under it.  One engine serves every rate
    point (reset between points; detected blocks are scrubbed at
    quarantine time, so a reset pool recycles no poisoned tile), keeping
    the sweep inside the PR 5 compile bounds.  History rows carry
    scenario="chaos" and a ``faults`` config string that is part of the
    dedupe key, so chaos rows never collide with the clean trajectory."""
    from repro.serve.faults import FaultInjector

    cfg = reduce_config(get_config(arch))
    model = make_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    num_slots = round_slots_to_devices(num_slots or max(2, n_requests // 2),
                                       devices)
    scfg = ServeConfig()
    jax.block_until_ready(jnp.zeros(()) + 1)

    reqs = staggered_requests(cfg, n_requests=n_requests, base_len=base_len,
                              max_new_tokens=max_new, stagger=0, seed=23,
                              tail_len=0, tail_every=0)
    max_seq = required_max_seq(reqs)
    ref = static_reference(model, params, reqs, scfg)
    # a generous retry budget: the sweep measures detection + recovery,
    # not budget exhaustion (that path is pinned by test_serve_faults)
    eng = ContinuousEngine(model, params, num_slots=num_slots,
                           max_seq=max_seq, cfg=scfg, chunk=chunk,
                           devices=devices, fault_retry_budget=8)
    assert eng.sentinels, "chaos scenario needs the sentinel-probed engine"
    eng.run(reqs)  # warm every trace, so the rate-0 baseline isn't cold

    sweep = []
    for rate in fault_rates:
        eng.reset()
        inj = FaultInjector(eng, seed=seed)
        rng = np.random.default_rng([seed, int(rate * 1000)])
        # cap injections so quarantine can't eat the arena at high rates —
        # the rate still sets the *pressure* (faults per tick early on)
        cap = max(2, int(round(rate * 20))) if rate else 0
        for r in reqs:
            eng.submit(r)
        injected = 0
        inject_s = 0.0  # harness cost: each inject round-trips the arena
        t0 = time.time()
        while eng.step():
            if injected < cap and rng.random() < rate:
                kind = kinds[int(rng.integers(len(kinds)))]
                ti = time.time()
                hit = inj.inject(kind)
                inject_s += time.time() - ti
                if hit:
                    injected += 1
        # goodput charges the engine (detection, quarantine, recompute) but
        # not the injector's host round-trips — those are the chaos harness,
        # not the system under test
        wall = time.time() - t0 - inject_s
        eng.pool.check_ledger()
        m = eng.metrics()

        comps = eng.completions
        ok = [c for c in comps if c.finish_reason in ("length", "stop")]
        identical = [c for c in ok
                     if np.array_equal(c.tokens, ref[c.request_id])]
        # the recovery guarantee: anything not failed closed is exact
        assert len(identical) == len(ok), \
            f"rate={rate}: a recovered completion diverged from the oracle"
        latencies, undetected = _detection_latencies(inj.records, eng.event_log)
        assert undetected == 0, \
            f"rate={rate}: {undetected} detectable fault(s) never detected"
        assert all(l <= 1 for l in latencies), \
            f"rate={rate}: detection exceeded one tick ({latencies})"
        if rate == 0.0:
            assert m["sentinel_checks"] > 0 and m["sentinel_violations"] == 0, \
                "fault-free run tripped (or never ran) the sentinels"
            assert len(ok) == len(comps) == len(reqs), \
                "fault-free run failed requests"
        useful = sum(int(np.asarray(c.new_tokens).shape[0]) for c in ok)
        sweep.append({
            "fault_rate": rate,
            "faults_injected": injected,
            "faults_detected": len(latencies),
            "detection_latency_ticks_mean":
                float(np.mean(latencies)) if latencies else 0.0,
            "detection_latency_ticks_max":
                int(max(latencies)) if latencies else 0,
            "recovery_identity_rate": len(identical) / max(1, len(ok)),
            "completions_ok": len(ok),
            "completions_failed": m["failed_completions"],
            "goodput_tokens_per_s": useful / max(1e-9, wall),
            "wall_s": wall,
            "sentinel_checks": m["sentinel_checks"],
            "sentinel_violations": m["sentinel_violations"],
            "quarantined_blocks": m["quarantined_blocks"],
            "retries": m["retries"],
            "table_repairs": m["table_repairs"],
            "fused_step_compilations": m["fused_step_compilations"],
            "decode_compilations": m["decode_compilations"],
            "prefill_compilations": m["prefill_compilations"],
        })

    faults_cfg = (f"kinds={'+'.join(kinds)};"
                  f"rates={','.join(str(r) for r in fault_rates)};seed={seed}")
    workload = {
        "scenario": "chaos",
        "arch": arch,
        "n_requests": n_requests,
        "base_len": base_len,
        "max_new": max_new,
        "num_slots": num_slots,
        "chunk": chunk,
        "num_devices": devices,
        "faults": faults_cfg,
    }
    base, top = sweep[0], sweep[-1]
    payload = {
        "benchmark": "serve",
        "scenario": "chaos",
        "arch": arch,
        "workload": workload,
        "faults": faults_cfg,
        "sweep": sweep,
        "goodput_retention":
            top["goodput_tokens_per_s"] / max(1e-9,
                                              base["goodput_tokens_per_s"]),
        "detection_latency_ticks_max":
            max(pt["detection_latency_ticks_max"] for pt in sweep),
        "recovery_identity_rate":
            min(pt["recovery_identity_rate"] for pt in sweep),
    }
    history = _load_history()
    _upsert_history(history, {
        "git_sha": _git_sha(),
        "arch": arch,
        "scenario": "chaos",
        "workload_hash": _workload_hash(workload),
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "num_devices": devices,
        "faults": faults_cfg,
        "goodput_retention": payload["goodput_retention"],
        "detection_latency_ticks_max": payload["detection_latency_ticks_max"],
        "recovery_identity_rate": payload["recovery_identity_rate"],
        "faults_injected": sum(pt["faults_injected"] for pt in sweep),
        "quarantined_blocks": top["quarantined_blocks"],
        "completions_failed": sum(pt["completions_failed"] for pt in sweep),
        "tokens_per_s": top["goodput_tokens_per_s"],
        "fused_step_compilations": top["fused_step_compilations"],
        "decode_compilations": top["decode_compilations"],
        "prefill_compilations": top["prefill_compilations"],
    })
    payload["history"] = history[-_HISTORY_MAX:]
    return writeout("BENCH_serve", payload)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b", choices=list_archs())
    ap.add_argument("--scenario", default="default",
                    choices=["default", "shared-prefix", "sla", "chaos"],
                    help="'shared-prefix': N users x M personas over a "
                         "common system prompt, prefix cache on vs off; "
                         "'sla': bursty two-class open-loop load, FCFS vs "
                         "priority+preemption per offered rate; 'chaos': "
                         "seeded fault injection swept over per-tick rates "
                         "— goodput, detection latency, recovery identity")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--base-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--num-slots", type=int, default=0, help="0 = n_requests/2")
    ap.add_argument("--chunk", type=int, default=8, help="prefill chunk size")
    ap.add_argument("--tail-len", type=int, default=-1,
                    help="long-tail prompt length (-1 = 8*base_len, 0 = off)")
    ap.add_argument("--devices", type=int, default=1,
                    help="shard the slot pool over N devices (CPU: export "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    ap.add_argument("--force-read", default="",
                    choices=["", "gathered", "streamed", "pallas"],
                    help="pin the paged read path (same-host baseline "
                         "comparisons; hashed into the workload identity)")
    ap.add_argument("--kv-dtype", default="fp", choices=["fp", "int8"],
                    help="paged KV arena dtype (int8: per-block scales, "
                         "per-tile dequant after the block-table read; "
                         "hashed into the workload identity)")
    # shared-prefix scenario shape (ignored for --scenario default)
    ap.add_argument("--users", type=int, default=16)
    ap.add_argument("--personas", type=int, default=4)
    ap.add_argument("--system-len", type=int, default=64)
    ap.add_argument("--persona-len", type=int, default=16)
    ap.add_argument("--user-len", type=int, default=8)
    ap.add_argument("--stagger", type=int, default=4)
    # sla scenario shape (ignored for the other scenarios)
    ap.add_argument("--rates", default="0.25,0.5",
                    help="comma-separated offered arrival rates (requests "
                         "per engine step, calm-state mean) to sweep")
    ap.add_argument("--preempt", default="spill",
                    choices=["spill", "recompute"],
                    help="preemption mechanism for the priority side")
    ap.add_argument("--aging", type=int, default=48,
                    help="batch anti-starvation bound (engine steps)")
    ap.add_argument("--shed-backlog", type=int, default=0,
                    help="overload shed watermark in pool units (0 = off)")
    # chaos scenario shape (ignored for the other scenarios)
    ap.add_argument("--fault-rates", default="0.0,0.1,0.25",
                    help="comma-separated per-tick fault-injection "
                         "probabilities to sweep (0.0 = the clean baseline "
                         "the goodput retention is measured against)")
    ap.add_argument("--fault-kinds", default="nan_tile,inf_tile,table",
                    help="comma-separated FaultInjector kinds to draw from "
                         "(nan_tile, inf_tile, scale, table, bit_flip, "
                         "device_loss)")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed for the injection schedule + target draws")
    args = ap.parse_args()
    if args.scenario == "chaos":
        payload = run_chaos(
            args.arch, n_requests=args.requests, base_len=args.base_len,
            max_new=args.new_tokens, num_slots=args.num_slots,
            chunk=args.chunk, devices=args.devices,
            fault_rates=tuple(float(r) for r in args.fault_rates.split(",")),
            kinds=tuple(args.fault_kinds.split(",")), seed=args.fault_seed,
        )
        print(json.dumps({k: v for k, v in payload.items() if k != "history"},
                         indent=2, default=float))
        print(f"\n{'rate':>6} {'goodput tok/s':>13} {'inj/det':>8} "
              f"{'lat max':>7} {'quar':>5} {'retry':>5} {'failed':>6} "
              f"{'identity':>8}")
        for pt in payload["sweep"]:
            print(f"{pt['fault_rate']:6.2f} "
                  f"{pt['goodput_tokens_per_s']:13.1f} "
                  f"{pt['faults_injected']:3d}/{pt['faults_detected']:<4d} "
                  f"{pt['detection_latency_ticks_max']:7d} "
                  f"{pt['quarantined_blocks']:5d} {pt['retries']:5d} "
                  f"{pt['completions_failed']:6d} "
                  f"{pt['recovery_identity_rate']*100:7.0f}%")
        print(f"goodput retention at top fault rate: "
              f"{payload['goodput_retention']*100:.0f}%  detection <= "
              f"{payload['detection_latency_ticks_max']} tick(s)  "
              f"recovery identity {payload['recovery_identity_rate']*100:.0f}% "
              f"({payload['faults']})  "
              f"(history: {len(payload['history'])} runs)")
        return
    if args.scenario == "sla":
        payload = run_sla(
            args.arch, n_requests=args.requests, base_len=args.base_len,
            rates=tuple(float(r) for r in args.rates.split(",")),
            num_slots=args.num_slots, chunk=args.chunk, devices=args.devices,
            preempt=args.preempt, aging_steps=args.aging,
            shed_backlog=args.shed_backlog,
        )
        print(json.dumps({k: v for k, v in payload.items() if k != "history"},
                         indent=2, default=float))
        print(f"\n{'rate':>6} {'side':>9} {'int p50/p99 ttft':>17} "
              f"{'batch p99 ttft':>14} {'preempt':>7} {'reject':>6}")
        for pt in payload["sweep"]:
            for side in ("fcfs", "priority"):
                st = pt[side]
                i, b = st["interactive"], st["batch"]
                print(f"{pt['rate']:6.2f} {side:>9} "
                      f"{i['ttft_steps_p50']:7.1f}/{i['ttft_steps_p99']:6.1f} "
                      f"{b['ttft_steps_p99']:14.1f} "
                      f"{st['preemptions']:7d} {st['rejections']:6d}")
        print(f"interactive p99 TTFT improvement at top load: "
              f"{payload['interactive_ttft_p99_improvement']:.2f}x "
              f"({args.preempt}, aging {args.aging}, "
              f"shed {args.shed_backlog})  token-identical="
              f"{payload['greedy_token_identical']}  "
              f"(history: {len(payload['history'])} runs)")
        return
    if args.scenario == "shared-prefix":
        payload = run_shared_prefix(
            args.arch, n_users=args.users, n_personas=args.personas,
            system_len=args.system_len, persona_len=args.persona_len,
            user_len=args.user_len, max_new=args.new_tokens,
            num_slots=args.num_slots, stagger=args.stagger, chunk=args.chunk,
            devices=args.devices, force_read=args.force_read,
        )
        base, pre = payload["baseline"], payload["prefix"]
        print(json.dumps({k: v for k, v in payload.items() if k != "history"},
                         indent=2, default=float))
        print(f"\nprefix hit rate {pre['prefix_hit_rate']*100:.0f}% "
              f"({pre['prefix_hit_requests']} hit requests, "
              f"{pre['prefix_forks']} COW forks, "
              f"{pre['prefix_evictions']} evictions)")
        print(f"cold TTFT  {base['cold_ttft_steps']:.1f} -> "
              f"{pre['cold_ttft_steps']:.1f} engine steps "
              f"({payload['cold_ttft_steps_speedup']:.2f}x; wall "
              f"{base['cold_ttft_s']*1e3:.0f} -> {pre['cold_ttft_s']*1e3:.0f} ms)")
        print(f"equal-HBM  {base['equal_hbm_slots_gain']:.1f}x -> "
              f"{pre['equal_hbm_slots_gain']:.1f}x slots vs slab "
              f"(arena {base['tight_num_blocks']} -> "
              f"{pre['tight_num_blocks']} blocks at {payload['workload']['num_slots']} slots)")
        print(f"warm wall  {base['wall_s']:.2f}s -> {pre['wall_s']:.2f}s "
              f"({payload['speedup']:.2f}x)  token-identical="
              f"{payload['greedy_token_identical']}  "
              f"(history: {len(payload['history'])} runs)")
        return
    payload = run(args.arch, args.requests, args.base_len, args.new_tokens,
                  args.num_slots, chunk=args.chunk, tail_len=args.tail_len,
                  devices=args.devices, force_read=args.force_read,
                  kv_dtype=args.kv_dtype)
    print(json.dumps({k: v for k, v in payload.items() if k != "history"},
                     indent=2, default=float))
    s, c = payload["static"], payload["continuous"]
    print(f"\nstatic     {s['tokens_per_s']:8.1f} tok/s  ({s['batches']} batches)")
    print(f"continuous {c['tokens_per_s']:8.1f} tok/s  "
          f"(util {c['mean_slot_utilization']*100:.0f}%, "
          f"prefill lanes {c['prefill_lane_fraction']*100:.0f}%)")
    print(f"speedup    {payload['speedup']:.2f}x warm, "
          f"{payload['cold_speedup']:.2f}x cold "
          f"(static cold {s['cold_wall_s']:.1f}s vs continuous "
          f"{c['cold_wall_s']:.1f}s incl. compiles)  "
          f"token-identical={payload['greedy_token_identical']}")
    print(f"compilations: fused={c['fused_step_compilations']} "
          f"decode={c['decode_compilations']} prefill={c['prefill_compilations']}"
          f"  (history: {len(payload['history'])} runs)")
    if c["num_devices"] > 1:
        print(f"sharded: {c['num_devices']} devices x {c['per_device_slots']} "
              f"slots, admission balance {c['shard_balance']:.2f} "
              "(1.0 = perfectly even)")
    kv = payload["kv"]
    if payload["kv_dtype"] != "fp":
        print(f"quantized KV: kv_dtype={payload['kv_dtype']}  greedy LCP vs "
              f"fp oracle min {payload['greedy_lcp_min']:.2f} / mean "
              f"{payload['greedy_lcp_mean']:.2f} (pinned >= 0.5 / 0.7)")
    if kv["paged"]:
        print(f"paged KV: {c['num_blocks']} blocks x {c['block_size']} tok "
              f"= {kv['kv_hbm_bytes']/1024:.1f} KiB resident "
              f"(slab pool: {kv['slab_hbm_bytes']/1024:.1f} KiB); at equal HBM "
              f"the slab serves {kv['slab_slots_at_equal_hbm']} slots vs "
              f"{payload['workload']['num_slots']} paged -> "
              f"{kv['equal_hbm_slots_gain']:.1f}x slots "
              f"(peak util {c['block_utilization']*100:.0f}%)")
        print(f"paged reads: {c['read_path']}; horizon buckets "
              f"{c['horizon_buckets']} of grid {c['horizon_bucket_grid']}; "
              f"mean attended {c['mean_attended_tokens_per_tick']:.1f} "
              "tok/tick")
    else:
        print(f"slot-slab KV (family has no pageable cache): "
              f"{kv['kv_hbm_bytes']/1024:.1f} KiB resident")


if __name__ == "__main__":
    main()
