"""Static vs continuous batching serving throughput (BENCH_serve.json).

Workload: staggered arrivals, mixed prompt lengths, mixed decode budgets —
the regime the static engine handles worst (it must group requests into
uniform-length batches and decode every group to its largest budget, paying
for retired sequences).  Continuous batching serves the same requests from
one slot pool with a single jitted decode step.

Both paths are warmed up first so compile time is excluded; each is then
timed end-to-end on the identical request set.  Emits the BENCH_serve.json
schema (written to experiments/results/) so future PRs can track the
serving-throughput trajectory:

  {"benchmark": "serve", "arch": ..., "workload": {...},
   "static": {"wall_s", "tokens_per_s", "batches"},
   "continuous": {"wall_s", "tokens_per_s", "decode_steps",
                  "mean_slot_utilization", "decode_compilations"},
   "speedup": ...}

Run:  PYTHONPATH=src python -m benchmarks.serve_bench [--arch internlm2-1.8b]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from benchmarks.common import writeout
from repro.configs.registry import get_config, list_archs, reduce_config
from repro.models.transformer import make_model
from repro.serve.engine import ContinuousEngine, ServeConfig, static_reference
from repro.serve.workload import required_max_seq, staggered_requests


def run(arch: str = "internlm2-1.8b", n_requests: int = 12, base_len: int = 16,
        max_new: int = 16, num_slots: int = 0, stagger: int = 1,
        reps: int = 3) -> dict:
    cfg = reduce_config(get_config(arch))
    model = make_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    reqs = staggered_requests(cfg, n_requests=n_requests, base_len=base_len,
                              max_new_tokens=max_new, stagger=stagger, seed=23)
    # half the request count keeps the pool busy (~70% util) while static
    # still pays per-group batch fragmentation — the measured sweet spot
    num_slots = num_slots or max(2, n_requests // 2)
    max_seq = required_max_seq(reqs)
    useful = sum(r.max_new_tokens for r in reqs)
    n_groups = len({(r.prompt_len, r.max_new_tokens) for r in reqs})

    scfg = ServeConfig()
    static_reference(model, params, reqs, scfg)  # warm up per-group jits
    static_s = float("inf")
    for _ in range(reps):  # best-of-reps: standard noise rejection
        t0 = time.time()
        ref = static_reference(model, params, reqs, scfg)
        static_s = min(static_s, time.time() - t0)

    engine = ContinuousEngine(model, params, num_slots=num_slots,
                              max_seq=max_seq, cfg=scfg)
    engine.run(reqs)  # warm up prefill-per-length + the one decode jit
    cont_s = float("inf")
    for _ in range(reps):
        engine.reset()
        t0 = time.time()
        comps = engine.run(reqs)
        cont_s = min(cont_s, time.time() - t0)
    m = engine.metrics()

    identical = all(np.array_equal(c.tokens, ref[c.request_id]) for c in comps)
    payload = {
        "benchmark": "serve",
        "arch": arch,
        "workload": {
            "n_requests": n_requests,
            "prompt_lens": sorted({r.prompt_len for r in reqs}),
            "max_new_tokens": sorted({r.max_new_tokens for r in reqs}),
            "useful_tokens": useful,
            "arrival_stagger": stagger,
            "num_slots": num_slots,
        },
        "static": {
            "wall_s": static_s,
            "tokens_per_s": useful / static_s,
            "batches": n_groups,
        },
        "continuous": {
            "wall_s": cont_s,
            "tokens_per_s": useful / cont_s,
            "decode_steps": m["decode_steps"],
            "mean_slot_utilization": m["mean_slot_utilization"],
            "decode_compilations": m["decode_compilations"],
        },
        "speedup": static_s / cont_s,
        "greedy_token_identical": identical,
    }
    return writeout("BENCH_serve", payload)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b", choices=list_archs())
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--base-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--num-slots", type=int, default=0, help="0 = n_requests/2")
    args = ap.parse_args()
    payload = run(args.arch, args.requests, args.base_len, args.new_tokens,
                  args.num_slots)
    print(json.dumps(payload, indent=2, default=float))
    s, c = payload["static"], payload["continuous"]
    print(f"\nstatic     {s['tokens_per_s']:8.1f} tok/s  ({s['batches']} batches)")
    print(f"continuous {c['tokens_per_s']:8.1f} tok/s  "
          f"(util {c['mean_slot_utilization']*100:.0f}%)")
    print(f"speedup    {payload['speedup']:.2f}x  "
          f"token-identical={payload['greedy_token_identical']}")


if __name__ == "__main__":
    main()
