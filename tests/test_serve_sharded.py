"""Multi-device sharded serving tests (slot-pool sharding over the batch axis).

Pinned invariants:
  1. a 2-device engine (slot-axis NamedSharding over a 1-D 'data' mesh) is
     greedy token-identical to BOTH the single-device continuous engine and
     the static oracle, for dense and MLA, slab and block-paged pools;
  2. compile counters stay exact under the mesh: fused=1 / decode=1 /
     prefill=0 — sharding must not introduce retracing;
  3. admission placement is least-loaded-first across device slot ranges
     (one hot device cannot strand free slots elsewhere), and the paged
     pool's per-device block ranges keep reservations device-local;
  4. ``devices=1`` builds no mesh and stays bit-identical to the unsharded
     engine (the pools collapse to a single global FIFO range).

The mesh tests need >= 2 jax devices and skip otherwise; CI runs them in a
dedicated step with XLA_FLAGS=--xla_force_host_platform_device_count=2, and
``test_sharded_suite_under_forced_host_devices`` (slow) re-runs this module
in a 2-device subprocess so RUN_SLOW tier-1 covers SPMD even on one device.
Host-side range/placement accounting needs no devices and always runs.
"""
import os
import subprocess
import sys
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.configs.registry import get_config, reduce_config
from repro.data.synthetic import DataConfig, batch_at
from repro.models.transformer import make_model
from repro.serve.engine import ContinuousEngine, ServeConfig, static_reference
from repro.serve.kv_cache import BlockPagedKVPool, SlotKVPool
from repro.serve.scheduler import Request
from repro.serve.workload import required_max_seq

from _serve_helpers import assert_exact_compile_counters

REPO = Path(__file__).resolve().parents[1]
CHUNK = 4
TWO_DEV = jax.device_count() >= 2
requires_mesh = pytest.mark.skipif(
    not TWO_DEV,
    reason="needs >= 2 devices "
    "(export XLA_FLAGS=--xla_force_host_platform_device_count=2)",
)


@pytest.fixture(scope="module")
def dense():
    cfg = reduce_config(get_config("internlm2-1.8b"))
    model = make_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def mla():
    cfg = reduce_config(get_config("minicpm3-4b"))
    model = make_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


def _prompt(cfg, length, seed):
    data = DataConfig(vocab=cfg.vocab, seq_len=length, global_batch=1, seed=seed)
    return np.asarray(batch_at(data, 0)["tokens"][0], np.int32)


def _mixed_requests(cfg, max_new=6):
    # >= 4 distinct prompt lengths, none grid-aligned, staggered arrivals,
    # more requests than per-device slots -> placement and recycling both
    # exercise; max_new large enough that all-decode ticks hit the fast path
    lens = [5, 9, 14, 22, 7]
    return [
        Request(id=i, tokens=_prompt(cfg, L, seed=500 + i), max_new_tokens=max_new,
                arrival_step=i)
        for i, L in enumerate(lens)
    ]


# --------------------------------------------- host-side range accounting ---
def test_slot_pool_ranges_and_least_loaded_pick(dense):
    _, model, _ = dense
    pool = SlotKVPool(model, num_slots=4, max_seq=16, num_devices=2)
    assert pool.per_device_slots == 2
    assert [pool.device_of(s) for s in range(4)] == [0, 0, 1, 1]
    # empty pool: tie breaks toward device 0, FIFO within the range
    assert pool.pick_device() == 0
    assert pool.allocate(device=0) == 0
    # device 0 now has 1 free, device 1 has 2 -> least-loaded is 1
    assert pool.pick_device() == 1
    assert pool.allocate(device=1) == 2
    assert pool.pick_device() in (0, 1)  # tied again at 1 free each
    pool.free(0)
    assert pool.free_slots_on(0) == 2 and pool.free_slots_on(1) == 1
    assert pool.pick_device() == 0


def test_slot_pool_rejects_indivisible_slots(dense):
    _, model, _ = dense
    with pytest.raises(ValueError, match="divide evenly"):
        SlotKVPool(model, num_slots=3, max_seq=16, num_devices=2)


def test_paged_pool_per_device_blocks_and_reservations(dense):
    _, model, _ = dense
    pool = BlockPagedKVPool(model, num_slots=4, max_seq=16, block_size=4,
                            num_blocks=8, num_devices=2)
    assert pool.blocks_per_device == 4 and pool.max_request_blocks == 4
    # device 0's range is blocks [0, 4), device 1's is [4, 8)
    s0 = pool.allocate(reserve_tokens=16, device=0)   # 4 blocks: fills dev 0
    assert pool.device_of(s0) == 0
    assert not pool.can_reserve(4, device=0)          # dev 0 ledger is full
    assert pool.can_reserve(16, device=1)             # dev 1 untouched
    assert pool.pick_device(4) == 1                   # placement skips dev 0
    s1 = pool.allocate(reserve_tokens=8, device=1)
    pool.ensure(s0, 6)                                # 2 blocks from dev 0
    pool.ensure(s1, 6)                                # 2 blocks from dev 1
    assert list(pool.tables[s0, :2]) == [0, 1]
    assert list(pool.tables[s1, :2]) == [4, 5]        # device-local blocks
    assert pool.blocks_in_use_on(0) == 2 and pool.blocks_in_use_on(1) == 2
    pool.free(s0)
    # blocks recycle to their OWN device's FIFO list
    assert pool.free_blocks_on(0) == 4 and pool.free_blocks_on(1) == 2
    s2 = pool.allocate(reserve_tokens=4, device=0)
    pool.ensure(s2, 2)
    assert pool.tables[s2, 0] == 2                    # dev-0 FIFO continues
    pool.free(s1)
    pool.free(s2)
    assert pool.blocks_in_use == 0 and pool.blocks_reserved == 0


def test_paged_pool_rounds_arena_to_device_multiple(dense):
    _, model, _ = dense
    pool = BlockPagedKVPool(model, num_slots=2, max_seq=16, block_size=4,
                            num_blocks=7, num_devices=2)
    assert pool.num_blocks == 8  # rounded up so the block axis shards evenly
    assert pool.blocks_per_device == 4


def test_legacy_allocate_checks_the_popped_slots_device(dense):
    # a no-device allocate() must check the reservation ledger of the device
    # the FIFO-head slot actually lands on — not device 0's (which may be
    # full while the head slot's device has plenty of headroom)
    _, model, _ = dense
    pool = BlockPagedKVPool(model, num_slots=4, max_seq=16, block_size=4,
                            num_blocks=8, num_devices=2)
    pool.allocate(reserve_tokens=16, device=0)  # device 0 fully reserved
    pool.allocate(device=0)                     # drain device 0's free slots
    # FIFO head is now slot 2 (device 1): legacy call must succeed
    s = pool.allocate(reserve_tokens=8)
    assert pool.device_of(s) == 1
    # and a failing legacy call restores FIFO order
    pool.allocate(reserve_tokens=4)             # slot 3: 1 more dev-1 block
    pool.free(s)                                # dev 1 ledger back to 1/4
    with pytest.raises(RuntimeError, match="device 1"):
        pool.allocate(reserve_tokens=16)        # head is dev 1: 4 > 3 free
    assert pool._free_slots[0] == s             # pushed back at the front


def test_force_host_devices_parses_both_flag_forms(monkeypatch):
    # the pre-jax-init hook must honor --devices=N as well as --devices N
    # (argparse accepts both; the hook silently doing nothing for one form
    # crashed the documented smoke command)
    from repro.launch._host_devices import devices_from_argv, force_host_devices

    assert devices_from_argv(["prog", "--devices", "2"]) == 2
    assert devices_from_argv(["prog", "--devices=3"]) == 3
    assert devices_from_argv(["prog"]) is None
    assert devices_from_argv(["prog", "--devices", "x"]) is None
    monkeypatch.delenv("XLA_FLAGS", raising=False)
    force_host_devices(["prog", "--devices=2"])
    assert "--xla_force_host_platform_device_count=2" in os.environ["XLA_FLAGS"]
    monkeypatch.setenv("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
    force_host_devices(["prog", "--devices", "2"])  # operator setting wins
    assert os.environ["XLA_FLAGS"] == "--xla_force_host_platform_device_count=8"


def test_single_device_pools_keep_global_fifo(dense):
    # num_devices=1: one range covering the whole pool, FIFO order exactly
    # the historical global order (devices=1 bit-identity rests on this)
    _, model, _ = dense
    pool = SlotKVPool(model, num_slots=3, max_seq=16)
    assert pool.num_devices == 1 and pool.per_device_slots == 3
    assert [pool.allocate(device=pool.pick_device()) for _ in range(3)] == [0, 1, 2]
    pool.free(1)
    pool.free(0)
    assert pool.allocate(device=pool.pick_device()) == 1  # FIFO, not LIFO


# ----------------------------------------------------- 2-device SPMD tests ---
@requires_mesh
@pytest.mark.parametrize("family", ["dense", "mla"])
@pytest.mark.parametrize("paged", [True, False], ids=["paged", "slab"])
def test_sharded_greedy_identity_and_counters(dense, mla, family, paged):
    """2-device engine == single-device engine == static oracle (greedy),
    with exact compile counters under the mesh, for slab and paged pools."""
    cfg, model, params = dense if family == "dense" else mla
    scfg = ServeConfig()
    reqs = _mixed_requests(cfg)
    max_seq = required_max_seq(reqs)

    sharded = ContinuousEngine(model, params, num_slots=4, max_seq=max_seq,
                               cfg=scfg, chunk=CHUNK, devices=2, paged=paged)
    assert sharded.mesh is not None and sharded.num_devices == 2
    comps2 = {c.request_id: c.tokens for c in sharded.run(reqs)}

    single = ContinuousEngine(model, params, num_slots=4, max_seq=max_seq,
                              cfg=scfg, chunk=CHUNK, devices=1, paged=paged)
    assert single.mesh is None
    comps1 = {c.request_id: c.tokens for c in single.run(reqs)}

    ref = static_reference(model, params, reqs, scfg)
    assert comps2.keys() == comps1.keys() == ref.keys()
    for rid in ref:
        assert np.array_equal(comps2[rid], ref[rid]), f"req {rid} vs oracle"
        assert np.array_equal(comps2[rid], comps1[rid]), f"req {rid} vs 1-dev"

    m = sharded.metrics()
    assert m["num_devices"] == 2 and m["per_device_slots"] == 2
    assert_exact_compile_counters(m)
    assert 0.0 < m["shard_balance"] <= 1.0
    assert sum(m["device_admits"]) == len(reqs)
    if paged:
        assert sharded.pool.blocks_in_use == 0  # drained on both shards


@requires_mesh
def test_least_loaded_admission_places_across_devices(dense):
    """Two simultaneously-arriving requests must land on DIFFERENT devices
    (slots 0 and 2 on a 4-slot/2-device pool), not fill device 0 first."""
    cfg, model, params = dense
    reqs = [
        Request(id=i, tokens=_prompt(cfg, 8, seed=520 + i), max_new_tokens=8,
                arrival_step=0)
        for i in range(2)
    ]
    engine = ContinuousEngine(model, params, num_slots=4, max_seq=16,
                              cfg=ServeConfig(), chunk=CHUNK, devices=2)
    for r in reqs:
        engine.submit(r)
    engine.step()
    assert engine.device_occupancy() == [1, 1]
    occupied = [s for s, st in enumerate(engine._slots) if st is not None]
    assert occupied == [0, 2]  # FIFO head of each device's range
    assert list(engine._device_admits) == [1, 1]
    engine.run([])  # drain so the pool is clean


@requires_mesh
def test_sharded_cache_leaves_are_slot_sharded(dense):
    """The pool actually places leaves with a slot-axis NamedSharding: each
    leaf's batch/slot (or block-arena) dim is split over the 'data' axis."""
    cfg, model, params = dense
    engine = ContinuousEngine(model, params, num_slots=4, max_seq=16,
                              devices=2)
    assert engine.paged
    axes = model.paged_cache_logical_axes()
    leaves_ax = jax.tree.leaves(axes, is_leaf=lambda x: isinstance(x, tuple))
    leaves = jax.tree.leaves(engine.pool.cache)
    assert len(leaves) == len(leaves_ax)
    for leaf, ax in zip(leaves, leaves_ax):
        dim = ax.index("batch")
        spec = leaf.sharding.spec
        assert len(spec) > dim and spec[dim] is not None, (ax, spec)
    # tick state shards over slots too
    assert engine._pos_dev.sharding.spec[0] is not None


@requires_mesh
def test_devices_exceeding_visible_raises():
    cfg = reduce_config(get_config("internlm2-1.8b"))
    model = make_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="devices"):
        ContinuousEngine(model, params, num_slots=jax.device_count() + 1,
                         max_seq=16, devices=jax.device_count() + 1)


# ------------------------------------------------- subprocess SPMD driver ---
@pytest.mark.slow  # jax re-init + 4 engine compiles in a child process
@pytest.mark.skipif(TWO_DEV, reason="already running under >= 2 devices")
def test_sharded_suite_under_forced_host_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-q", "-p", "no:cacheprovider",
         str(REPO / "tests" / "test_serve_sharded.py")],
        env=env, cwd=REPO, capture_output=True, text=True, timeout=3000,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout[-3000:]}\nstderr:\n{r.stderr[-2000:]}"
