"""SLA-aware serving tests (PR 7): priority scheduling, block-level
preemption with exact resume, and graceful overload degradation.

Pinned invariants:
  1. exact resume: a preempted-then-resumed request's greedy tokens are
     IDENTICAL to the uninterrupted static oracle, for both preemption
     mechanisms (``recompute``: free the block chain, re-prefill
     prompt+generated as an extended prompt; ``spill``: host-mirror the
     chain payload + held logits, restore bitwise into a freshly-ensured
     chain) — on dense and MLA caches, slab and paged pools, with and
     without the prefix cache.  The GN guarantee is what makes the
     recycled/restored blocks safe without zeroing: masked scores produce
     exactly-zero numerators, so stale block contents beyond the written
     horizon are never read into a normalized distribution;
  2. determinism: the same seed replays the identical arrival/admission/
     preemption/eviction trace (``event_log``) after ``reset()``;
  3. the aging bound: an interactive head outranks a batch head iff
     ``i.arrival < b.arrival + aging_steps`` — step-independent, so batch
     traffic is delayed at most ``aging_steps`` of interactive arrivals
     and can never starve, and the engine reuses the same rule for
     preemption victim eligibility (no admit/preempt livelock);
  4. graceful degradation: beyond the ``shed_backlog`` watermark, arrived
     batch backlog is rejected (``finish_reason='rejected'``) head-ordered
     and deterministically; interactive and preempted-resumed requests
     are never shed;
  5. compile counters stay exact under preemption/resume: one trace per
     (step kind, horizon bucket), prefill = 0 — eviction, spill/restore
     and re-admission must not add a single step compilation;
  6. the engine clock fast-forwards over provably-idle ticks (no live
     slot, no arrived request) without changing the event trace;
  7. completions carry arrival-anchored step-clock SLA fields
     (``queue_wait_steps``, ``ttft_steps``, ``tpot_steps``) next to the
     wall-clock ones.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.registry import get_config, reduce_config
from repro.data.synthetic import DataConfig, batch_at
from repro.models.transformer import make_model
from repro.serve.engine import ContinuousEngine, ServeConfig, static_reference
from repro.serve.scheduler import (
    Completion,
    FCFSScheduler,
    PriorityScheduler,
    Request,
)
from repro.serve.workload import required_max_seq, sla_requests

from _serve_helpers import assert_exact_compile_counters

CHUNK = 4
TWO_DEV = jax.device_count() >= 2
requires_mesh = pytest.mark.skipif(
    not TWO_DEV,
    reason="needs >= 2 devices "
    "(export XLA_FLAGS=--xla_force_host_platform_device_count=2)",
)


@pytest.fixture(scope="module")
def dense():
    cfg = reduce_config(get_config("internlm2-1.8b"))
    model = make_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def mla():
    cfg = reduce_config(get_config("minicpm3-4b"))
    model = make_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


def _prompt(cfg, length, seed):
    data = DataConfig(vocab=cfg.vocab, seq_len=length, global_batch=1, seed=seed)
    return np.asarray(batch_at(data, 0)["tokens"][0], np.int32)


def _preempt_reqs(cfg, slots=2):
    """Force a preemption: ``slots`` long batch requests saturate every
    slot at step 0, then an interactive request arrives mid-decode with no
    free slot — admission must evict a batch victim to serve it."""
    batch = [
        Request(id=i, tokens=_prompt(cfg, 9 + i, 400 + i), max_new_tokens=10,
                arrival_step=0, req_class="batch")
        for i in range(slots)
    ]
    inter = [
        Request(id=slots, tokens=_prompt(cfg, 6, 410), max_new_tokens=4,
                arrival_step=8, req_class="interactive")
    ]
    return batch + inter


def _assert_oracle_identity(comps, oracle, tag=""):
    for c in comps:
        if c.finish_reason == "rejected":
            continue
        ref = oracle[c.request_id]
        assert c.tokens.shape == ref.shape and np.array_equal(c.tokens, ref), (
            f"{tag} req {c.request_id}: resumed output diverged from the "
            f"uninterrupted oracle"
        )


# ------------------------------------------ exact resume: paged, dense+MLA --
@pytest.mark.parametrize("mode", ["recompute", "spill"])
@pytest.mark.parametrize(
    "family",
    ["dense", pytest.param("mla", marks=pytest.mark.slow)],
)
def test_preempt_resume_identity_paged(dense, mla, family, mode):
    cfg, model, params = dense if family == "dense" else mla
    reqs = _preempt_reqs(cfg)
    scfg = ServeConfig()
    oracle = static_reference(model, params, reqs, scfg)
    engine = ContinuousEngine(model, params, num_slots=2, max_seq=32,
                              cfg=scfg, chunk=CHUNK,
                              sched="priority", preempt=mode)
    comps = engine.run(reqs)
    assert len(comps) == len(reqs)
    _assert_oracle_identity(comps, oracle, f"{family}/{mode}")
    m = engine.metrics()
    assert m["preemptions"] >= 1 and m["preempt_resumes"] == m["preemptions"]
    # the victim's completion records its eviction count; the interactive
    # request that triggered it was never preempted itself
    by_id = {c.request_id: c for c in comps}
    assert sum(c.preemptions for c in comps) == m["preemptions"]
    assert by_id[2].preemptions == 0 and by_id[2].req_class == "interactive"
    # invariant 5: eviction/resume adds no step compilations, no prefill
    # jits (recompute-resume re-prefills through the same fused step)
    assert_exact_compile_counters(m)
    # drained clean: every block chain was freed or restored exactly once
    assert engine.pool.blocks_in_use == 0
    # invariant 2: a reset engine replays the identical event trace
    trace = list(engine.event_log)
    assert any(e[0] == "preempt" for e in trace)
    assert any(e[0] == "resume" for e in trace)
    engine.reset()
    replay = engine.run(reqs)
    assert engine.event_log == trace
    assert {c.request_id: c.tokens.tolist() for c in replay} == {
        c.request_id: c.tokens.tolist() for c in comps
    }


# --------------------------------------------------- exact resume: slab ----
@pytest.mark.parametrize(
    "mode",
    ["spill", pytest.param("recompute", marks=pytest.mark.slow)],
)
def test_preempt_resume_identity_slab(dense, mode):
    """Slab pool: the 'chain' is the whole slot row; spill mirrors
    ``pool.extract`` and restores via ``insert(payload, slot, position)``."""
    cfg, model, params = dense
    reqs = _preempt_reqs(cfg)
    scfg = ServeConfig()
    oracle = static_reference(model, params, reqs, scfg)
    engine = ContinuousEngine(model, params, num_slots=2, max_seq=32,
                              cfg=scfg, chunk=CHUNK, paged=False,
                              sched="priority", preempt=mode)
    comps = engine.run(reqs)
    _assert_oracle_identity(comps, oracle, f"slab/{mode}")
    m = engine.metrics()
    assert m["preemptions"] >= 1
    assert_exact_compile_counters(m)


# ------------------------------------------- exact resume + prefix cache ---
@pytest.mark.parametrize(
    "mode",
    ["spill", pytest.param("recompute", marks=pytest.mark.slow)],
)
def test_preempt_resume_identity_with_prefix_cache(dense, mode):
    """Preemption composes with prefix sharing: a victim whose chain holds
    attached (refcount > 1) cache blocks releases its references at
    eviction; resume rebuilds privately-owned blocks (spill restores the
    shared values into them bitwise) and stays oracle-identical.  Resumed
    admissions skip the prefix lookup — matching a cached chain against a
    prompt whose KV is being restored would double-attach."""
    cfg, model, params = dense
    # two batch requests share a 9-token prompt prefix so the victim's
    # chain really does hold cache-indexed blocks when it is evicted
    base = _prompt(cfg, 12, seed=500)
    b0 = base[:9]
    reqs = [
        Request(id=0, tokens=b0, max_new_tokens=10, arrival_step=0,
                req_class="batch"),
        Request(id=1, tokens=base, max_new_tokens=10, arrival_step=1,
                req_class="batch"),
        Request(id=2, tokens=_prompt(cfg, 6, 510), max_new_tokens=4,
                arrival_step=10, req_class="interactive"),
    ]
    scfg = ServeConfig()
    oracle = static_reference(model, params, reqs, scfg)
    engine = ContinuousEngine(model, params, num_slots=2, max_seq=32,
                              cfg=scfg, chunk=CHUNK, prefix_cache=True,
                              num_blocks=24,
                              sched="priority", preempt=mode)
    comps = engine.run(reqs)
    _assert_oracle_identity(comps, oracle, f"prefix/{mode}")
    m = engine.metrics()
    assert m["preemptions"] >= 1
    assert m["prefix_cache"] is True
    assert_exact_compile_counters(m)
    # drained: only cache-held chains remain resident
    assert engine.pool.num_free == engine.pool.num_slots
    assert engine.pool.blocks_in_use == engine.pool.cached_blocks


# ------------------------------------------------------ full sla workload --
def test_sla_workload_trace_determinism(dense):
    """The bench scenario in miniature: a seeded bursty two-class workload
    served under priority + preemption is oracle-identical and replays the
    exact event trace (admit/resume/preempt/reject/finish, with steps)."""
    cfg, model, params = dense
    reqs = sla_requests(cfg, n_requests=8, base_len=8, rate=0.6, seed=13,
                        max_new_interactive=4, max_new_batch=8)
    # seeded generator determinism, field by field
    again = sla_requests(cfg, n_requests=8, base_len=8, rate=0.6, seed=13,
                         max_new_interactive=4, max_new_batch=8)
    for a, b in zip(reqs, again):
        assert (a.arrival_step, a.req_class, a.max_new_tokens) == (
            b.arrival_step, b.req_class, b.max_new_tokens)
        assert np.array_equal(a.tokens, b.tokens)
    assert {r.req_class for r in reqs} == {"interactive", "batch"}

    scfg = ServeConfig()
    oracle = static_reference(model, params, reqs, scfg)
    engine = ContinuousEngine(model, params, num_slots=2,
                              max_seq=required_max_seq(reqs), cfg=scfg,
                              chunk=CHUNK, sched="priority", preempt="spill",
                              aging_steps=32)
    comps = engine.run(reqs)
    assert len(comps) == len(reqs)
    _assert_oracle_identity(comps, oracle, "sla-workload")
    trace = list(engine.event_log)
    engine.reset()
    engine.run(reqs)
    assert engine.event_log == trace
    assert_exact_compile_counters(engine.metrics())


# ------------------------------------------------------- the aging bound ---
def test_aging_prevents_batch_starvation(dense):
    """One slot, occupied by a long interactive request when a batch
    request arrives at step 1, with a steady interactive stream behind
    it.  With ``aging_steps=6`` only interactive requests arriving
    strictly before 1 + 6 = 7 outrank the batch head — later ones queue
    behind it, so the batch request is admitted (and completes) despite a
    continuous interactive supply.  FCFS-order within each class holds."""
    cfg, model, params = dense
    reqs = [Request(id=0, tokens=_prompt(cfg, 8, 600), max_new_tokens=6,
                    arrival_step=0, req_class="interactive"),
            Request(id=1, tokens=_prompt(cfg, 8, 601), max_new_tokens=4,
                    arrival_step=1, req_class="batch")]
    reqs += [
        Request(id=2 + i, tokens=_prompt(cfg, 4, 610 + i), max_new_tokens=2,
                arrival_step=2 + 2 * i, req_class="interactive")
        for i in range(6)  # arrivals 2,4,...,12 — 3 outrank the batch head
    ]
    scfg = ServeConfig()
    oracle = static_reference(model, params, reqs, scfg)
    engine = ContinuousEngine(model, params, num_slots=1, max_seq=16,
                              cfg=scfg, chunk=CHUNK,
                              sched="priority", preempt="off", aging_steps=6)
    comps = engine.run(reqs)
    assert len(comps) == len(reqs)
    _assert_oracle_identity(comps, oracle, "aging")
    order = [e[2] for e in engine.event_log if e[0] == "admit"]
    batch_pos = order.index(1)
    # the starvation bound: every interactive admitted before the batch
    # request arrived strictly less than aging_steps after it (rank rule:
    # i.arrival < b.arrival + aging = 1 + 6); everything later aged out
    # behind it — the batch request is bounded-delayed, never starved
    before = order[:batch_pos]
    after = order[batch_pos + 1:]
    assert before and after, order  # the contest actually happened
    assert all(reqs[i].arrival_step < 1 + 6 for i in before), order
    assert all(reqs[i].arrival_step >= 1 + 6 for i in after), order
    assert before == sorted(before) and after == sorted(after)  # FCFS in class
    finished = {c.request_id: c.finish_reason for c in comps}
    assert finished[1] == "length"  # the batch request was never starved


# ------------------------------------------- backpressure: shedding --------
def test_backpressure_sheds_batch_only_and_deterministically(dense):
    """Paged pool, shed watermark below total demand: arrived batch
    backlog beyond the watermark is rejected head-ordered; interactive
    requests are never shed; rejected completions carry the arrival-
    anchored step fields and empty tokens; the run drains clean and a
    reset replays the identical rejection set."""
    cfg, model, params = dense
    # 4 batch + 2 interactive, all nearly simultaneous; footprints of
    # 16+8=24 tokens = 6 blocks each (block_size=4)
    reqs = [
        Request(id=i, tokens=_prompt(cfg, 16, 700 + i), max_new_tokens=8,
                arrival_step=0, req_class="batch")
        for i in range(4)
    ]
    reqs += [
        Request(id=4 + i, tokens=_prompt(cfg, 8, 720 + i), max_new_tokens=4,
                arrival_step=1, req_class="interactive")
        for i in range(2)
    ]
    scfg = ServeConfig()
    oracle = static_reference(model, params, reqs, scfg)
    # watermark: 2 batch footprints + the interactive demand fit; the
    # 3rd/4th batch request would push reserved+queued past it
    engine = ContinuousEngine(model, params, num_slots=2, max_seq=32,
                              cfg=scfg, chunk=CHUNK,
                              sched="priority", preempt="spill",
                              shed_backlog=20)
    comps = engine.run(reqs)
    assert len(comps) == len(reqs)
    rejected = [c for c in comps if c.finish_reason == "rejected"]
    served = [c for c in comps if c.finish_reason != "rejected"]
    assert rejected and all(c.req_class == "batch" for c in rejected)
    assert {c.request_id for c in comps if c.req_class == "interactive"} <= {
        c.request_id for c in served
    }
    for c in rejected:
        assert c.admit_step == -1 and c.first_token_step == -1
        assert c.ttft_steps == -1 and c.new_tokens.shape == (0,)
        assert c.queue_wait_steps == c.finish_step - c.arrival_step >= 0
    _assert_oracle_identity(comps, oracle, "shed")
    m = engine.metrics()
    assert m["rejections"] == len(rejected) == m["shed_count"]
    assert engine.pool.blocks_in_use == 0  # drained despite rejections
    rejected_ids = sorted(c.request_id for c in rejected)
    engine.reset()
    comps2 = engine.run(reqs)
    assert sorted(c.request_id for c in comps2
                  if c.finish_reason == "rejected") == rejected_ids


def test_resumed_requests_are_never_shed(dense):
    """A preempted victim re-enters its queue head as admitted debt: even
    with a watermark that would reject it as a fresh submission, it is
    counted as demand but never shed — the engine already spent prefill
    on it, and dropping it would break the exact-resume contract."""
    cfg, model, params = dense
    reqs = _preempt_reqs(cfg)
    scfg = ServeConfig()
    oracle = static_reference(model, params, reqs, scfg)
    # watermark chosen so the preempted victim's footprint (5 blocks) plus
    # live reservations exceeds it at resume time — shed would drop it
    engine = ContinuousEngine(model, params, num_slots=2, max_seq=32,
                              cfg=scfg, chunk=CHUNK,
                              sched="priority", preempt="spill",
                              shed_backlog=10)
    comps = engine.run(reqs)
    m = engine.metrics()
    assert m["preemptions"] >= 1
    # every batch request either completed normally or was shed BEFORE it
    # was ever admitted; the preempted one (which had been admitted) is
    # guaranteed to have finished
    preempted = [c for c in comps if c.preemptions > 0]
    assert preempted and all(c.finish_reason == "length" for c in preempted)
    _assert_oracle_identity(comps, oracle, "resume-shed")


# -------------------------------------------------- idle fast-forward ------
def test_idle_fast_forward_jumps_to_next_arrival(dense):
    """A request arriving at step 400 on an empty engine must not cost 400
    engine iterations: with no live slot the clock jumps to the earliest
    queued arrival.  The completion's step fields anchor on arrival, so
    the jump is observationally identical to burning the ticks."""
    cfg, model, params = dense
    reqs = [Request(id=0, tokens=_prompt(cfg, 8, 800), max_new_tokens=4,
                    arrival_step=400, req_class="interactive")]
    scfg = ServeConfig()
    engine = ContinuousEngine(model, params, num_slots=1, max_seq=16,
                              cfg=scfg, chunk=CHUNK, sched="priority")
    for r in reqs:
        engine.submit(r)
    iters = 0
    while engine.step():
        iters += 1
        assert iters < 50, "idle ticks were burned one by one"
    (c,) = engine.completions
    assert engine.step_count >= 400
    assert c.admit_step >= 400 and c.queue_wait_steps == c.admit_step - 400
    assert c.ttft_steps >= 0 and c.tpot_steps >= 1.0
    # FCFS path fast-forwards too (head-blocking: jump to head arrival)
    engine2 = ContinuousEngine(model, params, num_slots=1, max_seq=16,
                               cfg=scfg, chunk=CHUNK, sched="fcfs")
    engine2.submit(dataclasses.replace(reqs[0]))
    iters = 0
    while engine2.step():
        iters += 1
        assert iters < 50
    assert engine2.step_count >= 400


# ------------------------------------------------ scheduler unit tests -----
def test_priority_scheduler_rank_rule_and_order():
    tok = np.arange(4, dtype=np.int32)
    s = PriorityScheduler(aging_steps=10)
    s.submit(Request(tokens=tok, arrival_step=0, req_class="batch"))
    s.submit(Request(tokens=tok, arrival_step=5, req_class="interactive"))
    s.submit(Request(tokens=tok, arrival_step=12, req_class="interactive"))
    # step 5: interactive head (arr 5) outranks batch head (arr 0): 5 < 10
    assert s.peek_ready(5).req_class == "interactive"
    # the rule is step-independent: still true at any later step
    assert s.peek_ready(100).req_class == "interactive"
    assert s.pop_ready(100).arrival_step == 5
    # next interactive arrived at 12 >= 0 + 10: batch has aged past it
    assert s.pop_ready(100).req_class == "batch"
    assert s.pop_ready(100).arrival_step == 12
    assert not s.has_pending()
    # ties: outranks is strict '<' so arrival 10 vs batch 0 @ aging 10 loses
    assert not s.outranks(10, 0)
    assert s.outranks(9, 0)


def test_priority_scheduler_next_ready_and_requeue():
    tok = np.arange(4, dtype=np.int32)
    s = PriorityScheduler(aging_steps=10)
    s.submit(Request(tokens=tok, arrival_step=7, req_class="batch"))
    s.submit(Request(tokens=tok, arrival_step=3, req_class="interactive"))
    # min over both class heads (FCFS would be head-blocked per queue)
    assert s.next_ready_step() == 3
    assert s.peek_ready(2) is None
    r = s.pop_ready(3)
    assert r.arrival_step == 3
    s.requeue_front(r)
    assert r.id in s._resumed
    assert s.pop_ready(3).id == r.id  # back at its class head
    assert r.id not in s._resumed  # pop clears the resumed mark
    fc = FCFSScheduler()
    fc.submit(Request(tokens=tok, arrival_step=7))
    fc.submit(Request(tokens=tok, arrival_step=3))
    assert fc.next_ready_step() == 7  # FCFS is head-blocking by design


def test_priority_scheduler_shed_watermark():
    tok = np.arange(4, dtype=np.int32)
    s = PriorityScheduler(aging_steps=10, shed_backlog=5)
    ids = [s.submit(Request(tokens=tok, arrival_step=0, req_class="batch"))
           for _ in range(4)]
    s.submit(Request(tokens=tok, arrival_step=0, req_class="interactive"))
    s.submit(Request(tokens=tok, arrival_step=50, req_class="batch"))
    # units: 1 per request; live=1 + interactive 1 -> batch fits 3 more;
    # the 4th arrived batch request breaches the watermark.  The batch
    # request arriving at step 50 is beyond the arrived zone: untouched.
    shed = s.poll_shed(0, 1, lambda r: 1)
    assert [r.id for r in shed] == [ids[3]]
    assert s.shed_count == 1
    assert len(s) == 5  # 3 kept batch + 1 future batch + 1 interactive
    # resumed (preempted) requests are demand, never shed
    r = s.pop_ready(0)  # interactive head
    v = s.pop_ready(0)  # batch head
    s.requeue_front(v)
    shed = s.poll_shed(0, 4, lambda r: 1)  # live 4 + resumed 1 == watermark
    assert shed == [] or v.id not in [x.id for x in shed]
    assert v.id in s._resumed


def test_request_class_validation():
    tok = np.arange(4, dtype=np.int32)
    s = PriorityScheduler()
    with pytest.raises(ValueError, match="req_class"):
        s.submit(Request(tokens=tok, req_class="bulk"))
    with pytest.raises(ValueError, match="aging_steps"):
        PriorityScheduler(aging_steps=0)


def test_engine_rejects_preempt_without_priority(dense):
    cfg, model, params = dense
    with pytest.raises(ValueError, match="priority"):
        ContinuousEngine(model, params, num_slots=1, max_seq=16,
                         chunk=CHUNK, sched="fcfs", preempt="spill")


# ---------------------------------------- completion step-clock fields -----
def test_completion_sla_fields():
    c = Completion(
        request_id=0, prompt_tokens=np.arange(4, dtype=np.int32),
        new_tokens=np.arange(3, dtype=np.int32), finish_reason="length",
        arrival_step=10, admit_step=14, first_token_step=16, finish_step=22,
        admit_time=0.0, first_token_time=0.0, finish_time=0.0,
        req_class="batch", preemptions=1,
    )
    assert c.queue_wait_steps == 4
    assert c.ttft_steps == 6
    assert c.tpot_steps == (22 - 16) / 2  # preemption gap inflates > 1.0
    r = Completion(
        request_id=1, prompt_tokens=np.arange(4, dtype=np.int32),
        new_tokens=np.zeros(0, np.int32), finish_reason="rejected",
        arrival_step=10, admit_step=-1, first_token_step=-1, finish_step=12,
        admit_time=0.0, first_token_time=0.0, finish_time=0.0,
        req_class="batch",
    )
    assert r.ttft_steps == -1
    assert r.queue_wait_steps == 2  # wait-to-verdict for rejections
    assert r.tpot_steps == 0.0


# ------------------------------------------------------------ device mesh --
@requires_mesh
def test_preempt_resume_identity_two_devices(dense):
    """2-device slot-pool sharding: preemption frees a victim on one
    device shard, resume may land on either; tokens stay oracle-identical
    and the trace replays."""
    cfg, model, params = dense
    reqs = _preempt_reqs(cfg, slots=2)
    scfg = ServeConfig()
    oracle = static_reference(model, params, reqs, scfg)
    engine = ContinuousEngine(model, params, num_slots=2, max_seq=32,
                              cfg=scfg, chunk=CHUNK, devices=2,
                              sched="priority", preempt="spill")
    comps = engine.run(reqs)
    _assert_oracle_identity(comps, oracle, "2dev")
    m = engine.metrics()
    assert m["num_devices"] == 2 and m["preemptions"] >= 1
    assert_exact_compile_counters(m)
    trace = list(engine.event_log)
    engine.reset()
    engine.run(reqs)
    assert engine.event_log == trace
