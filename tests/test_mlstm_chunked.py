"""Chunkwise mLSTM (perf X1) must match the per-token recurrence exactly.

Same contract as tests/test_ssd_chunked.py: the chunked form is an algebraic
regrouping (with the running-max stabilizer carried per chunk); agreement to
f32 tolerance across chunk sizes, with zero and nonzero initial state, and
through gradients.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # bare container: deterministic fixed-seed sweeps
    from _hypothesis_fallback import given, settings, st

from repro.models.ssm import _mlstm_chunked, _mlstm_heads


def _recurrent(q, k, v, i_raw, f_raw, carry):
    class _Cfg:  # _mlstm_heads only reads shapes
        pass

    def step(c, inp):
        qt, kt, vt, it, ft = inp
        return _mlstm_heads(_Cfg, qt, kt, vt, it, ft, c)

    xs = (
        q.transpose(1, 0, 2, 3),
        k.transpose(1, 0, 2, 3),
        v.transpose(1, 0, 2, 3),
        i_raw.transpose(1, 0, 2),
        f_raw.transpose(1, 0, 2),
    )
    state, hs = jax.lax.scan(step, carry, xs)
    b, s = q.shape[0], q.shape[1]
    return hs.transpose(1, 0, 2, 3).reshape(b, s, -1), state


def _inputs(key, b, s, h, dh, zero_state=True):
    ks = jax.random.split(key, 7)
    q = jax.random.normal(ks[0], (b, s, h, dh))
    k = jax.random.normal(ks[1], (b, s, h, dh))
    v = jax.random.normal(ks[2], (b, s, h, dh))
    i_raw = jax.random.normal(ks[3], (b, s, h)) * 2.0
    f_raw = jax.nn.log_sigmoid(jax.random.normal(ks[4], (b, s, h)) * 2.0 + 1.0)
    if zero_state:
        carry = (
            jnp.zeros((b, h, dh, dh), jnp.float32),
            jnp.zeros((b, h, dh), jnp.float32),
            jnp.full((b, h), -1e30, jnp.float32),
        )
    else:
        carry = (
            jax.random.normal(ks[5], (b, h, dh, dh)).astype(jnp.float32),
            jax.random.normal(ks[6], (b, h, dh)).astype(jnp.float32),
            jnp.zeros((b, h), jnp.float32),  # finite m_in
        )
    return q, k, v, i_raw, f_raw, carry


@pytest.mark.parametrize("chunk", [2, 4, 8])
def test_matches_recurrent(chunk):
    q, k, v, i_raw, f_raw, carry = _inputs(jax.random.PRNGKey(0), 2, 16, 2, 4)
    h_r, (C_r, n_r, m_r) = _recurrent(q, k, v, i_raw, f_raw, carry)
    h_c, (C_c, n_c, m_c) = _mlstm_chunked(q, k, v, i_raw, f_raw, carry, chunk)
    np.testing.assert_allclose(h_c, h_r, rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(C_c, C_r, rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(n_c, n_r, rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(m_c, m_r, rtol=3e-5, atol=3e-5)


def test_nonzero_state():
    q, k, v, i_raw, f_raw, carry = _inputs(
        jax.random.PRNGKey(1), 1, 12, 3, 4, zero_state=False
    )
    h_r, st_r = _recurrent(q, k, v, i_raw, f_raw, carry)
    h_c, st_c = _mlstm_chunked(q, k, v, i_raw, f_raw, carry, 4)
    np.testing.assert_allclose(h_c, h_r, rtol=3e-5, atol=3e-5)
    for a, b_ in zip(st_c, st_r):
        np.testing.assert_allclose(a, b_, rtol=3e-5, atol=3e-5)


def test_gradients_match():
    q, k, v, i_raw, f_raw, carry = _inputs(jax.random.PRNGKey(2), 1, 8, 2, 4)

    g_c = jax.grad(lambda q: jnp.sum(_mlstm_chunked(q, k, v, i_raw, f_raw, carry, 4)[0] ** 2))(q)
    g_r = jax.grad(lambda q: jnp.sum(_recurrent(q, k, v, i_raw, f_raw, carry)[0] ** 2))(q)
    np.testing.assert_allclose(g_c, g_r, rtol=1e-3, atol=1e-3)


@settings(max_examples=15, deadline=None)
@given(
    nc=st.integers(1, 4),
    q_len=st.sampled_from([2, 4]),
    h=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_chunk_invariance(nc, q_len, h, seed):
    s = nc * q_len
    q, k, v, i_raw, f_raw, carry = _inputs(
        jax.random.PRNGKey(seed), 2, s, h, 4, zero_state=(seed % 2 == 0)
    )
    h_r, _ = _recurrent(q, k, v, i_raw, f_raw, carry)
    h_c, _ = _mlstm_chunked(q, k, v, i_raw, f_raw, carry, q_len)
    np.testing.assert_allclose(h_c, h_r, rtol=1e-4, atol=1e-4)
