"""Multi-device integration tests (subprocess: 8 fake host devices).

The smoke/bench processes must see 1 device, so everything multi-device runs
in a child process with its own XLA_FLAGS (same pattern as launch/dryrun.py).
"""
import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]


def _run(arch: str):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-W", "ignore", str(REPO / "tests" / "distributed_check.py"), arch],
        env=env, capture_output=True, text=True, timeout=1200,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout[-3000:]}\nstderr:\n{r.stderr[-3000:]}"
    assert "ALL_DISTRIBUTED_CHECKS_PASSED" in r.stdout
    return r.stdout


@pytest.mark.slow  # ~20s/arch: multi-host sim train + elastic restore
@pytest.mark.parametrize("arch", ["internlm2-1.8b", "mixtral-8x22b"])
def test_sharded_training_and_elastic_restore(arch):
    out = _run(arch)
    assert "SPMD forward == single-device forward: OK" in out
    assert "elastic re-mesh (2,4)->(4,2) restore + step: OK" in out
