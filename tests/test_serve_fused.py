"""Fused chunked-prefill serving step tests (PR 2).

Pinned invariants:
  1. ONE fused-step compilation across a workload with >= 4 distinct prompt
     lengths, and ZERO per-prompt-length prefill compilations;
  2. greedy continuous batching stays token-identical to the static oracle
     when prompts cross a chunk boundary mid-prompt (length not a multiple
     of the chunk) — dense, ssm and hybrid families;
  3. the intake bucketing rule: prompts quantize to the chunk grid with
     bounded padding, and pad tokens never reach the cache;
  4. offset-ranged slot-position advances (kv_cache) validate bounds;
  5. `Model.prefill_chunk` streamed over a prompt reproduces the monolithic
     `prefill` cache and next-token logits bit-exactly (dense).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config, reduce_config
from repro.data.synthetic import DataConfig, batch_at
from repro.models.transformer import make_model
from repro.serve.engine import ContinuousEngine, ServeConfig, static_reference
from repro.serve.kv_cache import SlotKVPool
from repro.serve.scheduler import FCFSScheduler, Request, pad_to_grid
from repro.serve.workload import required_max_seq

from _serve_helpers import assert_exact_compile_counters


def _prompt(cfg, length, seed):
    data = DataConfig(vocab=cfg.vocab, seq_len=length, global_batch=1, seed=seed)
    return np.asarray(batch_at(data, 0)["tokens"][0], np.int32)


def _model_for(arch):
    cfg = reduce_config(get_config(arch))
    model = make_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


# -------------------------------------------------- one compilation, ever ---
def test_fused_step_compiles_once_across_prompt_length_mix():
    cfg, model, params = _model_for("internlm2-1.8b")
    scfg = ServeConfig()
    # >= 4 distinct prompt lengths, none aligned to the chunk grid
    lens = [5, 9, 14, 22, 7, 17]
    reqs = [
        Request(id=i, tokens=_prompt(cfg, L, seed=100 + i), max_new_tokens=4,
                arrival_step=i)
        for i, L in enumerate(lens)
    ]
    engine = ContinuousEngine(model, params, num_slots=3,
                              max_seq=required_max_seq(reqs), cfg=scfg, chunk=4)
    comps = engine.run(reqs)
    assert len(comps) == len(lens)
    m = engine.metrics()
    # the whole point: compile counts depend on the bucket grid, never on
    # the prompt-length mix, and no per-prompt-length prefill jit at all
    assert_exact_compile_counters(m)
    assert m["fused_ticks"] > 0
    ref = static_reference(model, params, reqs, scfg)
    for c in comps:
        assert np.array_equal(c.tokens, ref[c.request_id]), f"req {c.request_id}"


# ------------------------------------- chunk-boundary greedy identity -------
def _extras_for(cfg):
    if cfg.family == "encdec":
        return {"frames": np.zeros((cfg.encoder_seq, cfg.d_model), np.float32)}
    if cfg.family == "vlm":
        return {"patches": np.zeros((cfg.num_patches, cfg.d_model), np.float32)}
    return {}


@pytest.mark.parametrize("arch", [
    "internlm2-1.8b",        # dense
    "xlstm-350m",            # ssm (mlstm carries + stabilizer init)
    "zamba2-7b",             # hybrid (shared attn kv + mamba2 carries)
    "minicpm3-4b",           # mla (latent cache chunk writes)
    "whisper-large-v3",      # encdec (encode_cross_kv admission path)
    "llama-3.2-vision-11b",  # vlm (per-slot patches memory)
])
def test_chunk_boundary_greedy_identity(arch):
    """Prompt lengths that are NOT multiples of the chunk size (the final
    chunk is partial: masked lanes must neither enter the cache nor advance
    recurrent state) across every family the chunk path claims bit-identity
    for (MoE is excluded by design: GShard capacity is group-dependent)."""
    cfg, model, params = _model_for(arch)
    scfg = ServeConfig()
    chunk = 4
    # 6, 10: cross one / two chunk boundaries with a partial tail; 3: a
    # single partial chunk; 8: exact multiple as the control
    reqs = [
        Request(id=i, tokens=_prompt(cfg, L, seed=200 + i), max_new_tokens=5,
                arrival_step=i, extras=_extras_for(cfg))
        for i, L in enumerate([6, 10, 3, 8])
    ]
    engine = ContinuousEngine(model, params, num_slots=2,
                              max_seq=required_max_seq(reqs), cfg=scfg,
                              chunk=chunk)
    comps = engine.run(reqs)
    ref = static_reference(model, params, reqs, scfg)
    assert len(comps) == 4
    for c in comps:
        assert np.array_equal(c.tokens, ref[c.request_id]), f"req {c.request_id}"
    m = engine.metrics()
    assert_exact_compile_counters(m)


# ------------------------------------------- MoE near-identity (caveat) -----
def test_moe_chunked_prefill_near_identity_tolerance_pinned():
    """The one family the fused path does NOT claim bit-identity for: GShard
    capacity dropping depends on the dispatch group, so chunked prefill can
    route borderline tokens differently than the monolithic pass and greedy
    outputs may diverge mid-stream (see docs/serving.md §MoE caveat and the
    ROADMAP item on capacity-aware chunking).  This pins the caveat as a
    *bounded* regression instead of prose: the longest-common-prefix
    fraction vs the static oracle must stay high (measured at PR 4:
    per-request min 0.70, mean ~0.86 for llama4-scout at smoke scale), and
    divergence must not break serving (all requests finish, compile
    counters stay exact).  A capacity-aware chunked prefill should push
    these floors to 1.0 — ratchet them then."""
    cfg, model, params = _model_for("llama4-scout-17b-a16e")
    assert cfg.moe is not None
    scfg = ServeConfig()
    reqs = [
        Request(id=i, tokens=_prompt(cfg, L, seed=400 + i), max_new_tokens=6,
                arrival_step=i)
        for i, L in enumerate([5, 9, 14, 7])
    ]
    engine = ContinuousEngine(model, params, num_slots=2,
                              max_seq=required_max_seq(reqs), cfg=scfg, chunk=4)
    comps = engine.run(reqs)
    ref = static_reference(model, params, reqs, scfg)
    assert len(comps) == len(reqs)
    fracs = []
    for c in comps:
        want, got = ref[c.request_id], c.tokens
        lcp = 0
        for a, b in zip(got, want):
            if a != b:
                break
            lcp += 1
        fracs.append(lcp / len(want))
    assert min(fracs) >= 0.5, f"per-request LCP fractions collapsed: {fracs}"
    assert float(np.mean(fracs)) >= 0.7, f"mean LCP fraction regressed: {fracs}"
    m = engine.metrics()
    assert_exact_compile_counters(m)


# ----------------------------------------------------------- bucketing ------
def test_pad_to_grid_bounds_and_identity():
    t = np.arange(11, dtype=np.int32)
    padded = pad_to_grid(t, 4)
    assert padded.shape[0] == 12  # next grid point, padding < grid
    assert np.array_equal(padded[:11], t)
    assert np.array_equal(pad_to_grid(t, 1), t)   # grid 1 = no-op
    assert np.array_equal(pad_to_grid(t, 0), t)
    assert pad_to_grid(np.arange(8, dtype=np.int32), 4).shape[0] == 8  # aligned


def test_scheduler_buckets_at_submit_and_tracks_padding():
    sched = FCFSScheduler(chunk_grid=8)
    r1 = Request(tokens=np.arange(5, dtype=np.int32))   # +3 pad
    r2 = Request(tokens=np.arange(16, dtype=np.int32))  # aligned, +0
    id1 = sched.submit(r1)
    id2 = sched.submit(r2)
    # submit is side-effect-free on the caller's objects: bucketing and id
    # assignment land on the queued copies only (re-submitting one workload
    # list across oracle runs / engine resets / bench reps stays clean)
    assert r1.padded_tokens is None and r1.id == -1
    assert r2.padded_tokens is None and r2.id == -1
    q1, q2 = sched.pop_ready(0), sched.pop_ready(0)
    assert (q1.id, q2.id) == (id1, id2) == (0, 1)
    assert q1.padded_tokens.shape[0] == 8
    assert q2.padded_tokens.shape[0] == 16
    assert np.array_equal(q1.padded_tokens[:5], r1.tokens)
    assert sched.intake_padding == 3


def test_scheduler_resubmit_does_not_carry_stale_grid_state():
    # the same caller Request goes through two schedulers on different
    # chunk grids; each queued copy is padded to ITS grid
    req = Request(tokens=np.arange(5, dtype=np.int32))
    a = FCFSScheduler(chunk_grid=8)
    b = FCFSScheduler(chunk_grid=4)
    a.submit(req)
    b.submit(req)
    c = FCFSScheduler(chunk_grid=3)
    c.submit(req)
    assert a.pop_ready(0).padded_tokens.shape[0] == 8   # 5 -> grid 8
    assert b.pop_ready(0).padded_tokens.shape[0] == 8   # 5 -> grid 4
    assert c.pop_ready(0).padded_tokens.shape[0] == 6   # 5 -> grid 3
    assert req.padded_tokens is None


def test_chunk_must_fit_cache():
    cfg, model, params = _model_for("internlm2-1.8b")
    with pytest.raises(ValueError):
        ContinuousEngine(model, params, num_slots=1, max_seq=8, chunk=9)


# ------------------------------------------------- offset-ranged advance ----
def test_pool_offset_ranged_advance():
    cfg, model, _ = _model_for("internlm2-1.8b")
    pool = SlotKVPool(model, num_slots=2, max_seq=10)
    pool.allocate(), pool.allocate()
    pool.advance({0: 4, 1: 1})
    assert pool.positions[0] == 4 and pool.positions[1] == 1
    pool.advance([0, 1])  # legacy iterable form: +1 each
    assert pool.positions[0] == 5 and pool.positions[1] == 2
    with pytest.raises(ValueError):
        pool.advance({0: 6})  # 5 + 6 > max_seq


# ------------------------------------- model-level chunk-stream identity ----
def test_prefill_chunk_stream_matches_monolithic_prefill():
    cfg, model, params = _model_for("internlm2-1.8b")
    plen, chunk, max_seq = 11, 4, 16
    toks = _prompt(cfg, plen, seed=7)
    logits_ref, cache_ref = jax.jit(
        lambda p, b: model.prefill(p, b, max_seq)
    )(params, {"tokens": jnp.asarray(toks)[None]})

    cache = model.fresh_request_cache(max_seq)
    step = jax.jit(model.prefill_chunk)
    padded = pad_to_grid(toks, chunk)
    written, last = 0, None
    while written < plen:
        take = min(chunk, plen - written)
        logits, cache = step(
            params, cache, jnp.asarray(padded[written:written + chunk])[None],
            jnp.int32(written), jnp.int32(take),
        )
        last = logits[0, take - 1]
        written += take

    assert bool(jnp.all(last == logits_ref[0, -1]))
    ref_leaves = jax.tree.leaves(cache_ref)
    new_leaves = jax.tree.leaves(cache)
    for a, b in zip(ref_leaves, new_leaves):
        assert np.array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
