"""Core GN-Softmax / GN-LayerNorm behaviour tests + property tests.

The paper's central invariants:
  * Softmax:  |1 - sum p| ~ 0 regardless of approximation coarseness.
  * LayerNorm: |1 - std(y)| ~ 0 via the CoRN Newton rsqrt.
  * Approximations preserve ordering (rank) AND scores (normalization).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # bare container: deterministic fixed-seed sweeps
    from _hypothesis_fallback import given, settings, st

from repro.core import (
    exact_layernorm,
    exact_softmax,
    gn_layernorm,
    gn_layernorm_hwsim,
    gn_rmsnorm,
    gn_softmax,
    gn_softmax_hwsim,
    newton_rsqrt,
)
from repro.core import baselines, metrics
from repro.core.luts import (
    PAPER_RSQRT,
    PAPER_SOFTMAX_LUT,
    TPU_SOFTMAX_LUT,
    RsqrtConfig,
    SoftmaxLUTConfig,
    exp_luts,
)

KEY = jax.random.PRNGKey(42)


# ----------------------------------------------------------------- softmax --
class TestGNSoftmax:
    def test_normalization_guarantee_float(self):
        x = jax.random.normal(KEY, (32, 128)) * 5.0
        p = gn_softmax(x)
        err = metrics.softmax_norm_error(p)
        assert float(jnp.max(err)) < 1e-6  # paper Fig. 5: near-zero

    def test_normalization_guarantee_hwsim(self):
        x = jax.random.normal(KEY, (16, 64)) * 4.0
        p = gn_softmax_hwsim(x)
        err = metrics.softmax_norm_error(p)
        # 24-bit rescale with round-to-nearest: err ~ sqrt(N)*2^-25
        assert float(jnp.max(err)) < 1e-5

    def test_close_to_exact(self):
        x = jax.random.normal(KEY, (8, 256))
        p = gn_softmax(x)
        p_ref = exact_softmax(x)
        assert float(jnp.max(jnp.abs(p - p_ref))) < 0.02

    def test_order_preserved(self):
        x = jax.random.normal(KEY, (64, 33)) * 3.0
        p = np.asarray(gn_softmax(x))
        xs = np.asarray(x)
        # rank preservation up to grid ties: the true-argmax element must get
        # the maximal probability (possibly tied after Δ-grid quantization)
        rows = np.arange(64)
        assert (p[rows, xs.argmax(-1)] >= p.max(-1) - 1e-9).all()

    def test_factorization_exact_on_grid(self):
        """Eq. 4: on-grid deltas give exactly-factorized exponentials."""
        cfg = PAPER_SOFTMAX_LUT
        coarse, residual = exp_luts(cfg)
        for d in range(0, cfg.max_delta_int + 1):
            a = coarse[d >> 3]
            b = residual[d & 7]
            want = np.exp(-float(d))
            got = a * b
            # error only from Q1.15 rounding of the two entries
            assert abs(got - want) < 3e-5, (d, got, want)

    def test_uniform_rows(self):
        x = jnp.zeros((4, 100))
        p = gn_softmax(x)
        np.testing.assert_allclose(np.asarray(p), 1.0 / 100, rtol=1e-4)

    def test_one_hot_limit(self):
        x = jnp.array([[100.0, 0.0, 0.0, 0.0]])
        p = np.asarray(gn_softmax(x))
        assert p[0, 0] > 0.999
        assert abs(p.sum() - 1) < 1e-6

    def test_bf16_dtype(self):
        x = jax.random.normal(KEY, (4, 64), dtype=jnp.bfloat16)
        p = gn_softmax(x)
        assert p.dtype == jnp.bfloat16
        assert float(jnp.max(metrics.softmax_norm_error(p))) < 0.01

    def test_grad_rows_sum_to_zero(self):
        """Tangent of the guarantee: sum dp = 0."""
        x = jax.random.normal(KEY, (4, 32))
        v = jax.random.normal(jax.random.PRNGKey(1), (4, 32))
        _, dp = jax.jvp(lambda x: gn_softmax(x), (x,), (v,))
        assert float(jnp.max(jnp.abs(jnp.sum(dp, -1)))) < 1e-6

    def test_grad_matches_exact_softmax_direction(self):
        x = jax.random.normal(KEY, (4, 32))
        g_gn = jax.grad(lambda x: -jnp.sum(jnp.log(gn_softmax(x)[..., 0])))(x)
        g_ex = jax.grad(lambda x: -jnp.sum(jnp.log(exact_softmax(x)[..., 0])))(x)
        cos = jnp.sum(g_gn * g_ex) / (jnp.linalg.norm(g_gn) * jnp.linalg.norm(g_ex))
        assert float(cos) > 0.99

    @pytest.mark.parametrize("n", [1, 2, 7, 128, 1000])
    def test_shapes(self, n):
        x = jax.random.normal(KEY, (3, n))
        p = gn_softmax(x)
        assert p.shape == (3, n)
        assert float(jnp.max(metrics.softmax_norm_error(p))) < 1e-5

    @settings(max_examples=30, deadline=None)
    @given(
        rows=st.integers(1, 5),
        cols=st.integers(1, 300),
        scale=st.floats(0.01, 30.0),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_property_sum_to_one(self, rows, cols, scale, seed):
        """PROPERTY: sum p = 1 for arbitrary inputs and widths."""
        x = jax.random.normal(jax.random.PRNGKey(seed), (rows, cols)) * scale
        p = gn_softmax(x)
        assert float(jnp.max(metrics.softmax_norm_error(p))) < 2e-6
        assert bool(jnp.all(p >= 0))

    @settings(max_examples=15, deadline=None)
    @given(
        frac_bits=st.integers(0, 4),
        scale=st.floats(0.05, 2.0),
        seed=st.integers(0, 1000),
    )
    def test_property_guarantee_independent_of_approx_level(
        self, frac_bits, scale, seed
    ):
        """Fig. 2's point: normalization error does NOT grow with coarser LUTs."""
        cfg = SoftmaxLUTConfig(frac_bits=frac_bits, delta_scale=scale)
        x = jax.random.normal(jax.random.PRNGKey(seed), (4, 64)) * 3.0
        p = gn_softmax(x, cfg)
        assert float(jnp.max(metrics.softmax_norm_error(p))) < 2e-6


class TestBaselineSoftmaxes:
    """The baselines must exhibit the normalization error the paper ascribes."""

    def test_softermax_unnormalized(self):
        x = jax.random.normal(KEY, (32, 128)) * 3.0
        p = baselines.softermax(x)
        err = metrics.softmax_norm_error(p)
        gn_err = metrics.softmax_norm_error(gn_softmax(x))
        assert float(jnp.mean(err)) > 10 * float(jnp.mean(gn_err))

    def test_pseudo_softmax_unnormalized_but_ordered(self):
        x = jax.random.normal(KEY, (32, 64)) * 3.0
        p = baselines.pseudo_softmax(x)
        err = metrics.softmax_norm_error(p)
        assert float(jnp.max(err)) > 1e-3  # mantissa dropped => big score error
        np.testing.assert_array_equal(
            np.asarray(p).argmax(-1), np.asarray(x).argmax(-1)
        )

    def test_log_domain_unnormalized(self):
        x = jax.random.normal(KEY, (32, 64)) * 3.0
        err = metrics.softmax_norm_error(baselines.log_domain_softmax(x))
        assert float(jnp.mean(err)) > 1e-4


# --------------------------------------------------------------- layernorm --
class TestGNLayerNorm:
    def test_sigma_guarantee(self):
        x = jax.random.normal(KEY, (64, 512)) * 7.0 + 3.0
        y = gn_layernorm(x)
        err = metrics.layernorm_norm_error(y)
        assert float(jnp.max(err)) < 1e-5

    def test_matches_exact(self):
        x = jax.random.normal(KEY, (8, 256)) * 2.0
        np.testing.assert_allclose(
            np.asarray(gn_layernorm(x)), np.asarray(exact_layernorm(x)),
            atol=2e-4, rtol=1e-4,
        )

    def test_gamma_beta(self):
        x = jax.random.normal(KEY, (4, 64))
        g = jnp.full((64,), 2.0)
        b = jnp.full((64,), 0.5)
        y = gn_layernorm(x, g, b)
        y_ref = exact_layernorm(x, g, b)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-3)

    def test_rmsnorm_variant(self):
        x = jax.random.normal(KEY, (4, 128)) * 3.0
        y = gn_rmsnorm(x)
        ms = jnp.mean(jnp.square(y), axis=-1)
        np.testing.assert_allclose(np.asarray(ms), 1.0, atol=1e-5)

    def test_hwsim_sigma(self):
        x = jax.random.normal(KEY, (16, 256)) * 3.0
        y = gn_layernorm_hwsim(x)
        err = metrics.layernorm_norm_error(y)
        # Q8.8 output quantization floor
        assert float(jnp.max(err)) < 2e-3

    def test_newton_rsqrt_accuracy(self):
        n = jnp.logspace(-6, 6, 500, dtype=jnp.float32)
        r = newton_rsqrt(n)
        rel = jnp.abs(r * jnp.sqrt(n) - 1.0)
        assert float(jnp.max(rel)) < 1e-5  # paper: 2 Newton cycles suffice

    def test_newton_rsqrt_iters_converge(self):
        n = jnp.logspace(-4, 4, 100, dtype=jnp.float32)
        errs = []
        for it in range(4):
            r = newton_rsqrt(n, RsqrtConfig(mantissa_bits=4, iters=it))
            errs.append(float(jnp.max(jnp.abs(r * jnp.sqrt(n) - 1.0))))
        assert errs[1] < errs[0] and errs[2] < errs[1]  # quadratic convergence

    def test_grad_finite_and_correct_shape(self):
        x = jax.random.normal(KEY, (4, 64))
        g = jnp.ones((64,))
        b = jnp.zeros((64,))
        grads = jax.grad(lambda x, g, b: jnp.sum(gn_layernorm(x, g, b) ** 2), (0, 1, 2))(
            x, g, b
        )
        for gr in grads:
            assert bool(jnp.all(jnp.isfinite(gr)))

    def test_grad_matches_exact_ln(self):
        x = jax.random.normal(KEY, (4, 64))
        g1 = jax.grad(lambda x: jnp.sum(jnp.sin(gn_layernorm(x))))(x)
        g2 = jax.grad(lambda x: jnp.sum(jnp.sin(exact_layernorm(x))))(x)
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-3)

    @settings(max_examples=30, deadline=None)
    @given(
        cols=st.integers(8, 1024),
        scale=st.floats(0.01, 100.0),
        shift=st.floats(-50.0, 50.0),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_property_unit_variance(self, cols, scale, shift, seed):
        """PROPERTY: output std = 1 for arbitrary input distributions."""
        x = jax.random.normal(jax.random.PRNGKey(seed), (4, cols)) * scale + shift
        y = gn_layernorm(x)
        # threshold = Newton error + the eps floor's contribution eps/(2 var)
        var = float(jnp.min(jnp.var(x.astype(jnp.float32), axis=-1)))
        tol = 1e-4 + 1e-8 / (2.0 * max(var, 1e-12))
        assert float(jnp.max(metrics.layernorm_norm_error(y))) < tol


class TestBaselineNorms:
    def test_integer_ln_sigma_error(self):
        x = jax.random.normal(KEY, (64, 256)) * 3.0
        err = metrics.layernorm_norm_error(baselines.integer_layernorm(x))
        gn_err = metrics.layernorm_norm_error(gn_layernorm(x))
        assert float(jnp.mean(err)) > 100 * float(jnp.mean(gn_err))
        assert float(jnp.max(err)) < 0.5  # but bounded by sqrt2-ish

    def test_lut_ln_sigma_error(self):
        x = jax.random.normal(KEY, (64, 256)) * 3.0
        err = metrics.layernorm_norm_error(baselines.lut_layernorm(x))
        assert 1e-5 < float(jnp.mean(err)) < 0.05


class TestMetrics:
    def test_histogram(self):
        h = metrics.error_histogram(np.array([0.0, 1e-7, 1e-3]))
        assert abs(sum(h["fraction"]) - 1.0) < 1e-9
        assert h["frac_below_0.2e-6"] == pytest.approx(2 / 3)
