"""Quantized int8 paged KV on the GN fixed-point substrate (PR 9).

Pinned invariants:
  1. guaranteed normalization survives quantization: Σp = 1 to one rounding
     for the paged GN-softmax read over int8-dequantized blocks — swept
     over block sizes {chunk, 2·chunk}, read paths {streamed, gathered,
     pallas-interpret} and the dense + MLA families (property-based via
     hypothesis / the fallback shim).  Quantization perturbs *scores*; the
     GN guarantee is score-independent (the same approximated numerators
     feed the one reciprocal, masked columns saturate to exact zeros);
  2. the paged-serving-read normalization error stays within the analytic
     bound ((t+1)·2^-23 — one reciprocal rounding + one f32 rounding per
     accumulated numerator), pinned through the `norm_error_study` helper;
  3. int8 composes bitwise with every pool subsystem: preempt-spill→restore
     and prefix COW-fork move arena *and* per-block scales bit-exactly,
     including under the 2-device sharded pool;
  4. serving identity/tolerance: an int8 engine runs the fused tick end to
     end (dense + MLA), greedy outputs tolerance-pinned against the fp
     engine (LCP fractions), exact compile counters (kv_dtype adds no trace
     keys), reset-replay bit-identical (recycled blocks re-freeze their
     scale at the new tenant's offset-0 write — no zeroing);
  5. the quantized pool halves+ KV HBM: `hbm_bytes` for int8 arenas + f32
     scales is well under the fp pool's at equal block counts.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # bare container: deterministic fixed-seed sweeps
    from _hypothesis_fallback import given, settings, st

from repro.configs.registry import get_config, reduce_config
from repro.data.synthetic import DataConfig, batch_at
from repro.kernels.gn_paged_attention.ops import gn_paged_attention_chunk
from repro.models import attention as attention_mod
from repro.models import mla as mla_mod
from repro.models.transformer import make_model
from repro.core import get_softmax
from repro.serve.engine import ContinuousEngine, ServeConfig
from repro.serve.kv_cache import BlockPagedKVPool
from repro.serve.scheduler import Request
from repro.serve.workload import required_max_seq

from _serve_helpers import assert_exact_compile_counters

CHUNK = 4
TWO_DEV = jax.device_count() >= 2
requires_mesh = pytest.mark.skipif(
    not TWO_DEV,
    reason="needs >= 2 devices "
    "(export XLA_FLAGS=--xla_force_host_platform_device_count=2)",
)


@pytest.fixture(scope="module")
def dense():
    cfg = reduce_config(get_config("internlm2-1.8b"))
    model = make_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def mla():
    cfg = reduce_config(get_config("minicpm3-4b"))
    model = make_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


def _prompt(cfg, length, seed):
    data = DataConfig(vocab=cfg.vocab, seq_len=length, global_batch=1, seed=seed)
    return np.asarray(batch_at(data, 0)["tokens"][0], np.int32)


def _mixed_requests(cfg, max_new=4):
    lens = [5, 9, 14, 22, 7]
    return [
        Request(id=i, tokens=_prompt(cfg, L, seed=300 + i), max_new_tokens=max_new,
                arrival_step=i)
        for i, L in enumerate(lens)
    ]


def _quantize_arena(arr):
    """Tight per-block int8 quantization of an (nb, bs, ...) fp arena."""
    nb = arr.shape[0]
    amax = np.abs(arr).reshape(nb, -1).max(axis=1)
    scale = np.maximum(amax, 1e-30) / 127.0
    bcast = scale.reshape((nb,) + (1,) * (arr.ndim - 1))
    q = np.clip(np.round(arr / bcast), -127, 127).astype(np.int8)
    return jnp.asarray(q), jnp.asarray(scale, jnp.float32)


def _ones_arena(shape):
    """An int8 arena + scale that dequantizes to EXACTLY 1.0 everywhere
    (64 · 2^-6: both powers of two, no rounding)."""
    nb = shape[0]
    return (jnp.full(shape, 64, jnp.int8),
            jnp.full((nb,), 1.0 / 64.0, jnp.float32))


# given()-decorated tests can't take pytest fixtures (the fallback shim
# rewrites the signature), so the property tests build their own light
# config/params once per module
import functools


@functools.lru_cache(maxsize=None)
def _dense_cfg():
    return reduce_config(get_config("internlm2-1.8b"))


@functools.lru_cache(maxsize=None)
def _mla_setup():
    cfg = reduce_config(get_config("minicpm3-4b"))
    params = make_model(cfg).init(jax.random.PRNGKey(0))
    # layer-0 slice of the stacked (scan-format) per-layer params
    p = jax.tree.map(lambda leaf: leaf[0], params["layers"])["mixer"]
    return cfg, p


# ------------------------------------------------- Σp = 1 property (dense) --
@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10**6), bs_mult=st.sampled_from([1, 2]),
       path=st.sampled_from(["streamed", "gathered", "pallas"]))
def test_dense_paged_gn_read_sums_to_one_over_int8_blocks(seed, bs_mult,
                                                          path):
    """V dequantizes to exactly 1 → the read's output IS Σp per query row.
    The K arena is a real per-block int8 quantization of Gaussian data, the
    block layout a random permutation: Σp = 1 to one rounding must hold for
    every read path, through any layout, over int8-dequantized scores."""
    cfg = _dense_cfg()
    rng = np.random.default_rng(seed)
    kv, dh = cfg.n_kv_heads, cfg.head_dim
    g = cfg.n_heads // kv
    n, c, bs, nb = 3, CHUNK, CHUNK * bs_mult, 12
    max_bt = nb // n

    kf = rng.standard_normal((nb, bs, kv, dh)).astype(np.float32) * 2.0
    arena_k, k_scale = _quantize_arena(kf)
    arena_v, v_scale = _ones_arena((nb, bs, kv, dh))
    scales = (k_scale, v_scale)
    tables = jnp.asarray(rng.permutation(nb).reshape(n, max_bt), jnp.int32)
    positions = jnp.asarray(rng.integers(0, (max_bt - 1) * bs, size=n),
                            jnp.int32)
    rows = positions[:, None] + jnp.arange(c)[None, :]

    if path == "streamed":
        qg = jnp.asarray(rng.standard_normal((n, c, kv, g, dh)) * 2.0,
                         jnp.float32)
        out = attention_mod._stream_paged_tiles(
            cfg, qg, arena_k, arena_v, tables, rows, scales=scales)
    elif path == "pallas":
        q = jnp.asarray(rng.standard_normal((n, c, cfg.n_heads, dh)) * 2.0,
                        jnp.float32)
        out = gn_paged_attention_chunk(
            q, arena_k, arena_v, tables, positions,
            jnp.full((n,), c, jnp.int32), interpret=True, scales=scales)
    else:  # gathered oracle: dequantize the gathered stream, same dequant
        # expression the oracle in attn_paged_chunk uses
        qg = jnp.asarray(rng.standard_normal((n, c, kv, g, dh)) * 2.0,
                         jnp.float32)
        k_at = (arena_k[tables].astype(jnp.float32)
                * k_scale[tables][..., None, None, None])
        v_at = (arena_v[tables].astype(jnp.float32)
                * v_scale[tables][..., None, None, None])
        k_at = k_at.reshape(n, -1, kv, dh)
        v_at = v_at.reshape(n, -1, kv, dh)
        scores = jnp.einsum("bskgd,btkd->bkgst", qg, k_at) * dh**-0.5
        t = scores.shape[-1]
        valid = jnp.arange(t)[None, None, :] <= rows[:, :, None]
        scores = jnp.where(valid[:, None, None], scores, attention_mod.NEG_INF)
        pmat = get_softmax(cfg.softmax_impl)(scores)
        out = jnp.einsum("bkgst,btkd->bskgd", pmat, v_at)

    err = float(jnp.max(jnp.abs(1.0 - out)))
    t_max = int(rows.max()) + 1
    assert err <= (t_max + 1) * 2.0**-23, (
        f"Σp drifted: path={path} bs={bs} err={err:.3e}")


# --------------------------------------------------- Σp = 1 property (MLA) --
@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10**6), bs_mult=st.sampled_from([1, 2]))
def test_mla_paged_gn_read_sums_to_one_over_int8_blocks(seed, bs_mult):
    """MLA probabilities over int8-dequantized latent blocks sum to 1.  The
    value side rides the latent expansion (no exact-ones trick), so the
    probabilities are computed with the read's own building blocks: the
    gathered branch's dequant expression + score decomposition + the
    configured GN softmax.  The streamed leg is covered through the pinned
    bitwise streamed≡gathered equality of ``mla_paged_chunk`` (asserted
    below over the SAME int8 arenas), which transfers the property."""
    cfg, p = _mla_setup()
    m = cfg.mla
    rng = np.random.default_rng(seed)
    n, c, bs, nb = 3, CHUNK, CHUNK * bs_mult, 12
    max_bt = nb // n
    h = cfg.n_heads

    cf = rng.standard_normal((nb, bs, m.kv_lora_rank)).astype(np.float32)
    rf = rng.standard_normal((nb, bs, m.qk_rope_head_dim)).astype(np.float32)
    arena_c, c_scale = _quantize_arena(cf)
    arena_r, r_scale = _quantize_arena(rf)
    tables = jnp.asarray(rng.permutation(nb).reshape(n, max_bt), jnp.int32)
    positions = jnp.asarray(rng.integers(0, (max_bt - 1) * bs, size=n),
                            jnp.int32)
    rows = positions[:, None] + jnp.arange(c)[None, :]
    x = jnp.asarray(rng.standard_normal((n, c, cfg.d_model)) * 0.3,
                    jnp.float32)
    q_nope, q_rope, _, _ = mla_mod._project(cfg, p, x, rows)

    dt = jnp.float32
    c_kv = (arena_c[tables].astype(dt)
            * c_scale[tables][..., None, None]).reshape(n, -1, m.kv_lora_rank)
    k_rope = (arena_r[tables].astype(dt)
              * r_scale[tables][..., None, None]).reshape(
                  n, -1, m.qk_rope_head_dim)
    kvx = jnp.einsum("btr,rf->btf", c_kv, p["wkv_b"].astype(dt))
    kvx = kvx.reshape(n, -1, h, m.qk_nope_head_dim + m.v_head_dim)
    k_nope = kvx[..., : m.qk_nope_head_dim]
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    scores = (
        jnp.einsum("bshd,bthd->bhst", q_nope, k_nope)
        + jnp.einsum("bshd,btd->bhst", q_rope, k_rope)
    ) * scale
    t = scores.shape[-1]
    mask = (jnp.arange(t)[None, None, :] <= rows[:, :, None])[:, None]
    scores = jnp.where(mask, scores, attention_mod.NEG_INF)
    pmat = get_softmax(cfg.softmax_impl)(scores)
    sums = jnp.sum(pmat, axis=-1)
    t_max = int(rows.max()) + 1
    err = float(jnp.max(jnp.abs(1.0 - sums)))
    assert err <= (t_max + 1) * 2.0**-23, f"Σp drifted: err={err:.3e}"
    # LUT saturation: masked (stale/foreign) columns are EXACT zeros
    assert float(jnp.max(jnp.where(mask, 0.0, pmat))) == 0.0

    # streamed ≡ gathered over the same int8 arenas — transfers the Σp
    # property to the streamed leg bit-for-bit
    prev = attention_mod.FORCE_PAGED_READ
    outs = {}
    try:
        for rd in ("streamed", "gathered"):
            attention_mod.FORCE_PAGED_READ = rd
            out, _ = mla_mod.mla_paged_chunk(
                cfg, p, arena_c, arena_r, x, positions,
                jnp.zeros((n,), jnp.int32),  # read-only: no writes this tick
                tables, scales=(c_scale, r_scale))
            outs[rd] = np.asarray(out)
    finally:
        attention_mod.FORCE_PAGED_READ = prev
    np.testing.assert_allclose(outs["streamed"], outs["gathered"],
                               atol=2e-6, rtol=2e-5)


# --------------------------------- norm-error study: measured vs bound pin --
def test_paged_read_norm_error_within_analytic_bound():
    import pathlib
    import sys
    ex = str(pathlib.Path(__file__).resolve().parents[1] / "examples")
    if ex not in sys.path:
        sys.path.insert(0, ex)
    from norm_error_study import paged_int8_read_norm_error

    for kd in ("fp", "int8"):
        measured, bound, t = paged_int8_read_norm_error(kv_dtype=kd)
        assert measured <= bound, (
            f"kv_dtype={kd}: measured |1-Σp| {measured:.3e} exceeds the "
            f"analytic bound {bound:.3e} at t={t}")


# ----------------------------------------------- bitwise pool round-trips --
def _randomize_quant_cache(pool, seed=0):
    """Fill every paged layers leaf with random values of its own dtype
    (int8 arenas, f32 scales) so bitwise moves are distinguishable."""
    rng = np.random.default_rng(seed)

    def rand(leaf):
        if leaf.dtype == jnp.int8:
            return jnp.asarray(
                rng.integers(-127, 128, size=leaf.shape), jnp.int8)
        return jnp.asarray(
            rng.uniform(0.01, 1.0, size=leaf.shape).astype(np.float32))

    cache = dict(pool.cache)
    cache["layers"] = jax.tree.map(rand, pool.cache["layers"])
    pool.cache = cache


@pytest.mark.parametrize("family", ["dense", "mla"])
def test_quantized_cow_fork_bitwise(dense, mla, family):
    """A COW block fork copies arena content AND the per-block scale column
    bit-exactly — the forked tenant reads the shared prefix through the
    donor's frozen scale."""
    _, model, _ = dense if family == "dense" else mla
    pool = BlockPagedKVPool(model, num_slots=2, max_seq=16, block_size=4,
                            kv_dtype="int8")
    _randomize_quant_cache(pool, seed=3)
    src, dst = 2, 5
    before = jax.tree.map(np.asarray, pool.cache["layers"])
    pool._fork_copy(src, dst)
    after = jax.tree.map(np.asarray, pool.cache["layers"])
    for k in before:
        np.testing.assert_array_equal(
            after[k][:, dst], before[k][:, src],
            err_msg=f"{k}: forked block differs from donor")
        # untouched blocks stay bitwise put
        keep = [i for i in range(before[k].shape[1]) if i != dst]
        np.testing.assert_array_equal(after[k][:, keep], before[k][:, keep])


@pytest.mark.parametrize("family", ["dense", "mla"])
def test_quantized_spill_restore_bitwise(dense, mla, family):
    """Preempt-spill then restore into a DIFFERENT physical chain is
    bitwise for int8 arenas + scales (payload carries both; only logical
    order matters)."""
    _, model, _ = dense if family == "dense" else mla
    pool = BlockPagedKVPool(model, num_slots=2, max_seq=16, block_size=4,
                            kv_dtype="int8")
    _randomize_quant_cache(pool, seed=7)
    s0 = pool.allocate(reserve_tokens=12)
    pool.ensure(s0, 11)  # 3 blocks
    chain0 = pool.chain_of(s0)
    values0 = {
        k: np.asarray(v)[:, chain0]
        for k, v in pool.cache["layers"].items()
    }
    payload = pool.extract_blocks(s0)
    pool.free(s0)
    # occupy the old chain so the restore lands on different physical blocks
    s_hold = pool.allocate(reserve_tokens=12)
    pool.ensure(s_hold, 11)
    s1 = pool.allocate(reserve_tokens=12)
    pool.ensure(s1, 11)
    chain1 = pool.chain_of(s1)
    assert list(chain1) != list(chain0), "restore chain must differ"
    pool.restore_blocks(s1, payload)
    for k, v in pool.cache["layers"].items():
        np.testing.assert_array_equal(
            np.asarray(v)[:, chain1], values0[k],
            err_msg=f"{k}: restore not bitwise (arena or scale)")


@requires_mesh
def test_quantized_spill_restore_bitwise_sharded(dense):
    """Same bitwise round-trip through a 2-device sharded slot pool: the
    scale leaves shard/replicate with the arenas and survive the spill
    gather/scatter bit-exactly."""
    from repro.parallel.sharding import make_slot_mesh

    _, model, _ = dense
    mesh = make_slot_mesh(2)
    pool = BlockPagedKVPool(model, num_slots=2, max_seq=16, block_size=4,
                            mesh=mesh, num_devices=2, kv_dtype="int8")
    _randomize_quant_cache(pool, seed=11)
    s0 = pool.allocate(reserve_tokens=12)
    pool.ensure(s0, 11)
    chain0 = pool.chain_of(s0)
    values0 = {k: np.asarray(v)[:, chain0]
               for k, v in pool.cache["layers"].items()}
    payload = pool.extract_blocks(s0)
    pool.free(s0)
    s1 = pool.allocate(reserve_tokens=12)
    pool.ensure(s1, 11)
    pool.restore_blocks(s1, payload)
    chain1 = pool.chain_of(s1)
    for k, v in pool.cache["layers"].items():
        np.testing.assert_array_equal(np.asarray(v)[:, chain1], values0[k],
                                      err_msg=f"{k}: sharded restore drifted")


# ------------------------------------------- engine identity / tolerance ---
def _greedy(model, params, reqs, max_seq, **kw):
    eng = ContinuousEngine(model, params, num_slots=2, max_seq=max_seq,
                           cfg=ServeConfig(), chunk=CHUNK, block_size=CHUNK,
                           **kw)
    comps = eng.run(reqs)
    return {c.request_id: np.asarray(c.tokens) for c in comps}, eng


@pytest.mark.parametrize("family", ["dense", "mla"])
def test_int8_engine_greedy_tolerance_pinned_vs_fp(dense, mla, family):
    """Greedy int8 serving vs the fp engine: per-request longest-common-
    prefix fractions stay pinned (min ≥ 0.5, mean ≥ 0.7 — the same
    tolerance discipline as the fused-vs-oracle pin), compile counters are
    exact, and metrics report the kv_dtype."""
    cfg, model, params = dense if family == "dense" else mla
    reqs = _mixed_requests(cfg)
    max_seq = required_max_seq(reqs)
    want, _ = _greedy(model, params, reqs, max_seq, kv_dtype="fp")
    got, eng = _greedy(model, params, reqs, max_seq, kv_dtype="int8")
    m = eng.metrics()
    assert m["kv_dtype"] == "int8"
    assert_exact_compile_counters(m)
    fracs = []
    for rid, w in want.items():
        g = got[rid]
        lcp = 0
        for a, b in zip(w, g):
            if a != b:
                break
            lcp += 1
        fracs.append(lcp / len(w))
    assert min(fracs) >= 0.5, f"per-request LCP fractions collapsed: {fracs}"
    assert float(np.mean(fracs)) >= 0.7, f"mean LCP fraction regressed: {fracs}"
    # drained clean, blocks recycled mid-run (5 reqs, 2 slots)
    assert eng.pool.blocks_in_use == 0


def test_int8_engine_reset_replay_bit_identical(dense):
    """Recycled-block safety under quantization: a reset int8 engine
    replays the same workload bit-identically.  The new tenant's offset-0
    write re-freezes the block scale, so stale scales (like stale arena
    contents) are unreachable without zeroing."""
    cfg, model, params = dense
    reqs = _mixed_requests(cfg)
    max_seq = required_max_seq(reqs)
    eng = ContinuousEngine(model, params, num_slots=2, max_seq=max_seq,
                           cfg=ServeConfig(), chunk=CHUNK, block_size=CHUNK,
                           kv_dtype="int8")
    first = {c.request_id: np.asarray(c.tokens) for c in eng.run(reqs)}
    eng.reset()
    second = {c.request_id: np.asarray(c.tokens) for c in eng.run(reqs)}
    for rid in first:
        np.testing.assert_array_equal(first[rid], second[rid])


@requires_mesh
def test_int8_engine_sharded_identity(dense):
    """2-device int8 engine is greedy token-identical to the 1-device int8
    engine — quantization must not perturb SPMD slot sharding."""
    cfg, model, params = dense
    reqs = _mixed_requests(cfg)
    max_seq = required_max_seq(reqs)
    one, _ = _greedy(model, params, reqs, max_seq, kv_dtype="int8")
    two, eng = _greedy(model, params, reqs, max_seq, kv_dtype="int8",
                       devices=2)
    assert eng.metrics()["kv_dtype"] == "int8"
    for rid in one:
        np.testing.assert_array_equal(one[rid], two[rid])


def test_int8_pool_hbm_well_under_fp(dense):
    """Equal block counts: int8 arenas halve the (bf16) fp pool's arena
    bytes, and the f32 per-block scale rows add only ~1% back — the
    headline equal-HBM lever."""
    _, model, _ = dense
    fp = BlockPagedKVPool(model, num_slots=2, max_seq=32, block_size=4)
    q = BlockPagedKVPool(model, num_slots=2, max_seq=32, block_size=4,
                         kv_dtype="int8")
    assert q.num_blocks == fp.num_blocks
    assert q.hbm_bytes() < 0.55 * fp.hbm_bytes()
    # the quantized cache really is int8 arenas + one f32 scale row per arena
    dtypes = {k: v.dtype for k, v in q.cache["layers"].items()}
    arena_keys = [k for k in dtypes if not k.endswith("_scale")]
    assert arena_keys and all(dtypes[k] == jnp.int8 for k in arena_keys)
    scale_keys = [k for k in dtypes if k.endswith("_scale")]
    assert set(scale_keys) == {f"{k}_scale" for k in arena_keys}
    assert all(dtypes[k] == jnp.float32 for k in scale_keys)


def test_int8_requires_paged_pool(dense):
    _, model, params = dense
    with pytest.raises(ValueError, match="int8"):
        ContinuousEngine(model, params, num_slots=2, max_seq=16,
                         paged=False, kv_dtype="int8")
    with pytest.raises(ValueError, match="kv_dtype"):
        ContinuousEngine(model, params, num_slots=2, max_seq=16,
                         kv_dtype="int4")
