"""Chunked/streaming GN attention (perf B2) vs the one-pass oracles.

Invariants pinned here:
  1. exact-impl chunked == one-pass exact softmax attention (tight tolerance);
  2. gn-impl chunked == one-pass GN attention reference (LUT-rounding tol);
  3. chunk-size / leaf-size invariance (property, hypothesis);
  4. the normalization guarantee survives streaming: attention over a
     constant value tensor returns exactly that constant (sum p = 1);
  5. sliding-window chunked == masked one-pass oracle;
  6. gradients flow (STE) and match the exact-softmax jacobian closely.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # bare container: deterministic fixed-seed sweeps
    from _hypothesis_fallback import given, settings, st

from repro.core.luts import TPU_SOFTMAX_LUT
from repro.kernels.gn_attention.ref import gn_attention_ref
from repro.models.chunked_attention import (
    _exp_pair,
    _finalize,
    _init_state,
    _stream_rect,
    causal_chunked,
    windowed_chunked,
)

B, H, DH = 2, 3, 16


def _qkv(key, s, t=None, dh=DH):
    t = s if t is None else t
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, H, s, dh)) * 1.5
    k = jax.random.normal(ks[1], (B, H, t, dh)) * 1.5
    v = jax.random.normal(ks[2], (B, H, t, dh))
    return q, k, v


def _exact_sdpa(q, k, v, causal=False, window=0):
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) * (q.shape[-1] ** -0.5)
    sq, sk = s.shape[-2], s.shape[-1]
    if causal:
        rows = jnp.arange(sq)[:, None] + (sk - sq)
        cols = jnp.arange(sk)[None, :]
        mask = cols <= rows
        if window:
            mask &= cols > rows - window
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v)


class TestExactImpl:
    @pytest.mark.parametrize("s,kv_chunk,leaf", [(64, 16, 32), (128, 32, 32), (256, 64, 128)])
    def test_causal_matches_exact(self, s, kv_chunk, leaf):
        q, k, v = _qkv(jax.random.PRNGKey(0), s)
        got = causal_chunked(q, k, v, impl="exact", kv_chunk=kv_chunk, leaf=leaf)
        want = _exact_sdpa(q, k, v, causal=True)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    def test_rect_matches_exact(self):
        q, k, v = _qkv(jax.random.PRNGKey(1), 32, t=128)
        exp_fn, step = _exp_pair("exact", TPU_SOFTMAX_LUT)
        st = _init_state(q.shape[:-1], DH)
        st = _stream_rect(q, k, v, st, exp_fn, step, 32, DH**-0.5)
        got = _finalize(st)
        want = _exact_sdpa(q, k, v, causal=False)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("window", [16, 48])
    def test_windowed_matches_exact(self, window):
        s = 128
        q, k, v = _qkv(jax.random.PRNGKey(2), s)
        got = windowed_chunked(q, k, v, window=window, impl="exact", q_chunk=32)
        want = _exact_sdpa(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


class TestGNImpl:
    def test_causal_matches_gn_ref(self):
        s = 128
        q, k, v = _qkv(jax.random.PRNGKey(3), s)
        got = causal_chunked(q, k, v, impl="gn", kv_chunk=32, leaf=64)
        want = gn_attention_ref(q, k, v, causal=True)
        # one-pass vs streaming differ by compounded LUT rounding of rescales
        np.testing.assert_allclose(got, want, rtol=5e-3, atol=5e-3)

    def test_guarantee_constant_value(self):
        """sum(p)=1 under streaming: attention over constant v == constant."""
        s = 256
        q, k, _ = _qkv(jax.random.PRNGKey(4), s)
        v = jnp.full((B, H, s, DH), 3.25)
        got = causal_chunked(q, k, v, impl="gn", kv_chunk=64, leaf=64)
        np.testing.assert_allclose(got, jnp.full_like(got, 3.25), rtol=1e-5, atol=1e-5)

    def test_guarantee_windowed(self):
        s = 128
        q, k, _ = _qkv(jax.random.PRNGKey(5), s)
        v = jnp.full((B, H, s, DH), -1.5)
        got = windowed_chunked(q, k, v, window=32, impl="gn", q_chunk=32)
        np.testing.assert_allclose(got, jnp.full_like(got, -1.5), rtol=1e-5, atol=1e-5)

    def test_gradients_flow(self):
        s = 64
        q, k, v = _qkv(jax.random.PRNGKey(6), s)

        g_gn = jax.grad(lambda q: causal_chunked(q, k, v, impl="gn", kv_chunk=16, leaf=32).sum())(q)
        g_ex = jax.grad(lambda q: _exact_sdpa(q, k, v, causal=True).sum())(q)
        assert jnp.isfinite(g_gn).all()
        # STE backward ~= exact softmax jacobian at near-identical p.  The
        # residual error is the gn-vs-exact forward p difference (LUT grid);
        # bound bulk statistics, not the max (a few boundary elements jump).
        err = np.abs(np.asarray(g_gn) - np.asarray(g_ex))
        assert err.mean() < 0.02
        assert np.quantile(err, 0.99) < 0.08
        assert err.max() < 0.5


@settings(max_examples=15, deadline=None)
@given(
    log_s=st.integers(5, 8),
    log_kc=st.integers(3, 5),
    log_leaf=st.integers(4, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_chunk_invariance(log_s, log_kc, log_leaf, seed):
    s, kc, leaf = 2**log_s, 2**log_kc, 2**log_leaf
    q, k, v = _qkv(jax.random.PRNGKey(seed), s)
    got = causal_chunked(q, k, v, impl="exact", kv_chunk=kc, leaf=min(leaf, s))
    want = _exact_sdpa(q, k, v, causal=True)
    np.testing.assert_allclose(got, want, rtol=5e-5, atol=5e-5)
