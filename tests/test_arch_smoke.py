"""Per-architecture smoke tests: reduced config, one forward + train step +
prefill/decode consistency, asserting shapes and no NaNs.  (The FULL configs
are exercised only via the AOT dry-run — see launch/dryrun.py.)
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeConfig
from repro.configs.registry import ASSIGNED_ARCHS, get_config, input_specs, reduce_config
from repro.models.transformer import make_model

B, S = 2, 32


def _batch_for(cfg, key=jax.random.PRNGKey(0)):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(key, (B, cfg.encoder_seq, cfg.d_model)) * 0.1
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(key, (B, cfg.num_patches, cfg.d_model)) * 0.1
    return batch


@pytest.fixture(scope="module")
def built():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = reduce_config(get_config(arch))
            model = make_model(cfg)
            params = model.init(jax.random.PRNGKey(0))
            cache[arch] = (cfg, model, params)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_forward_shapes_and_finite(arch, built):
    cfg, model, params = built(arch)
    batch = _batch_for(cfg)
    logits, _ = jax.jit(model.forward)(params, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_train_step_decreases_loss(arch, built):
    cfg, model, params = built(arch)
    batch = _batch_for(cfg)

    @jax.jit
    def step(params):
        (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(
            params, batch
        )
        params = jax.tree.map(lambda p, g: p - 0.5 * g, params, grads)
        return params, loss

    params2, loss0 = step(params)
    _, loss1 = step(params2)
    assert bool(jnp.isfinite(loss0)) and bool(jnp.isfinite(loss1))
    assert float(loss1) < float(loss0)  # one SGD step on the same batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_prefill_then_decode_matches_forward(arch, built):
    """Teacher-forced decode must reproduce the training forward logits."""
    cfg, model, params = built(arch)
    batch = _batch_for(cfg)
    full_logits, _ = jax.jit(model.forward)(params, batch)

    prompt = S // 2
    max_seq = S
    pre_batch = dict(batch)
    pre_batch["tokens"] = batch["tokens"][:, :prompt]
    logits_p, cache = jax.jit(lambda p, b: model.prefill(p, b, max_seq))(
        params, pre_batch
    )
    np.testing.assert_allclose(
        np.asarray(logits_p, np.float32),
        np.asarray(full_logits[:, :prompt], np.float32),
        atol=0.2,  # bf16 accumulation-order differences across the two paths
        rtol=0.05,
    )

    decode = jax.jit(model.decode_step)
    errs = []
    for t in range(prompt, min(prompt + 3, S)):
        tok = batch["tokens"][:, t : t + 1]
        logits_d, cache = decode(params, cache, tok, jnp.int32(t))
        errs.append(
            np.max(
                np.abs(
                    np.asarray(logits_d[:, 0], np.float32)
                    - np.asarray(full_logits[:, t], np.float32)
                )
            )
        )
    assert max(errs) < 0.25, errs


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_input_specs_complete(arch):
    cfg = get_config(arch)
    for kind, name in (("train", "train_4k"), ("decode", "decode_32k")):
        shape = ShapeConfig(name, 64, 2, kind)
        specs = input_specs(reduce_config(cfg), shape)
        assert "tokens" in specs or "cache" in specs


def test_param_counts_match_published_scale():
    """Full configs should land near their nameplate parameter counts."""
    expect = {
        "deepseek-coder-33b": (30e9, 36e9),
        "internlm2-1.8b": (1.5e9, 2.2e9),
        "minicpm3-4b": (3e9, 5e9),
        "stablelm-1.6b": (1.3e9, 2.0e9),
        "mixtral-8x22b": (130e9, 150e9),
        "xlstm-350m": (0.25e9, 0.5e9),
        "zamba2-7b": (6e9, 9e9),
        "llama-3.2-vision-11b": (8e9, 12e9),
        "whisper-large-v3": (1.2e9, 2.1e9),
        "llama4-scout-17b-a16e": (95e9, 120e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo < n < hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"
