"""Substrate tests: data, optimizer, checkpoint, serving, fault tolerance."""
import json
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store
from repro.configs.registry import get_config, reduce_config
from repro.data.synthetic import (
    DataConfig,
    batch_at,
    classification_batch,
    optimal_perplexity,
    zipf_probs,
)
from repro.models.transformer import make_model
from repro.serve.engine import ServeConfig, generate, perplexity
from repro.train.loop import make_train_step
from repro.train.optimizer import (
    OptimizerConfig,
    adamw_update,
    compress_with_error_feedback,
    init_opt_state,
    lr_at,
)

REPO = Path(__file__).resolve().parents[1]


class TestData:
    def test_deterministic_and_sharded(self):
        cfg = DataConfig(global_batch=8, seq_len=32)
        b1 = batch_at(cfg, step=3)
        b2 = batch_at(cfg, step=3)
        np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
        # shards partition the batch deterministically
        s0 = batch_at(cfg, 3, shard=0, num_shards=2)
        s1 = batch_at(cfg, 3, shard=1, num_shards=2)
        assert s0["tokens"].shape == (4, 32)
        assert not np.array_equal(np.asarray(s0["tokens"]), np.asarray(s1["tokens"]))

    def test_markov_structure_learnable(self):
        """Every transition must be one of the K hashed successors."""
        cfg = DataConfig(vocab=97, seq_len=64, global_batch=4, branching=8)
        toks = np.asarray(batch_at(cfg, 0)["tokens"])
        from repro.data.synthetic import _successor

        for row in toks:
            for t in range(len(row) - 1):
                succ = {int(_successor(cfg, jnp.int32(row[t]), jnp.int32(k))) for k in range(8)}
                assert int(row[t + 1]) in succ

    def test_optimal_perplexity_positive(self):
        cfg = DataConfig()
        assert 1.0 < optimal_perplexity(cfg) < cfg.branching + 1


class TestOptimizer:
    def _tiny(self):
        return {"w": jnp.ones((4, 4)), "b": jnp.zeros((4,))}

    def test_lr_schedule(self):
        cfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100)
        assert float(lr_at(cfg, jnp.int32(5))) == pytest.approx(0.5)
        assert float(lr_at(cfg, jnp.int32(10))) == pytest.approx(1.0, rel=1e-3)
        assert float(lr_at(cfg, jnp.int32(100))) == pytest.approx(cfg.min_lr_ratio, rel=1e-2)

    def test_adamw_reduces_quadratic(self):
        cfg = OptimizerConfig(lr=0.1, warmup_steps=0, total_steps=100, weight_decay=0.0)
        params = self._tiny()
        state = init_opt_state(cfg, params)
        loss = lambda p: jnp.sum(jnp.square(p["w"])) + jnp.sum(jnp.square(p["b"] - 1))
        l0 = float(loss(params))
        for _ in range(50):
            grads = jax.grad(loss)(params)
            params, state, _ = adamw_update(cfg, params, grads, state)
        assert float(loss(params)) < 0.1 * l0

    def test_error_feedback_compression_converges(self):
        """EF-int8 must track the uncompressed trajectory closely."""
        def train(compression):
            cfg = OptimizerConfig(lr=0.05, warmup_steps=0, total_steps=200,
                                  weight_decay=0.0, grad_compression=compression)
            params = {"w": jnp.full((8, 8), 2.0)}
            state = init_opt_state(cfg, params)
            loss = lambda p: jnp.sum(jnp.square(p["w"] - 0.5))
            for _ in range(100):
                grads = jax.grad(loss)(params)
                params, state, _ = adamw_update(cfg, params, grads, state)
            return float(loss(params))

        assert train(8) < 1e-2
        assert abs(train(8) - train(None)) < 1e-2

    def test_compression_error_feedback_identity(self):
        g = {"w": jnp.linspace(-1, 1, 64).reshape(8, 8)}
        e = {"w": jnp.zeros((8, 8))}
        deq, new_e = compress_with_error_feedback(g, e, bits=8)
        np.testing.assert_allclose(
            np.asarray(deq["w"] + new_e["w"]), np.asarray(g["w"]), atol=1e-6
        )  # deq + residual == input: nothing is lost, only delayed


class TestCheckpoint:
    def test_roundtrip_bitwise(self, tmp_path):
        tree = {
            "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "n": {"b": jnp.ones((2,), jnp.bfloat16), "s": jnp.int32(7)},
        }
        store.save(tmp_path, 5, tree, extra={"k": "v"})
        assert store.latest_step(tmp_path) == 5
        got, man = store.restore(tmp_path, 5, tree)
        assert man["extra"]["k"] == "v"
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
            assert np.asarray(a).dtype == np.asarray(b).dtype
            np.testing.assert_array_equal(
                np.asarray(a, np.float64), np.asarray(b, np.float64)
            )

    def test_atomicity_tmp_cleanup(self, tmp_path):
        tree = {"a": jnp.ones((2,))}
        # simulate a crashed save
        (tmp_path / ".tmp_step_00000003").mkdir(parents=True)
        store.save(tmp_path, 4, tree)
        assert not list(tmp_path.glob(".tmp_step_*"))
        assert store.latest_step(tmp_path) == 4

    def test_multiple_steps_latest(self, tmp_path):
        tree = {"a": jnp.ones((2,))}
        for s in (2, 7, 11):
            store.save(tmp_path, s, tree)
        assert store.latest_step(tmp_path) == 11


class TestServe:
    def test_generate_batched(self):
        cfg = reduce_config(get_config("internlm2-1.8b"))
        model = make_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        prompts = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (3, 8), 0, cfg.vocab)}
        out = generate(model, params, prompts, ServeConfig(max_new_tokens=4))
        assert out.shape == (3, 12)
        # greedy decode must be deterministic
        out2 = generate(model, params, prompts, ServeConfig(max_new_tokens=4))
        np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))

    def test_decode_matches_forward_argmax(self):
        """Greedy continuation equals argmax of the teacher-forced forward."""
        cfg = reduce_config(get_config("stablelm-1.6b"))
        model = make_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
        out = generate(model, params, {"tokens": toks}, ServeConfig(max_new_tokens=1))
        logits, _ = jax.jit(model.forward)(params, {"tokens": toks})
        np.testing.assert_array_equal(
            np.asarray(out[:, -1]), np.asarray(jnp.argmax(logits[:, -1], -1))
        )


class TestFaultTolerance:
    def _run(self, outdir, extra):
        env = dict(os.environ)
        env["PYTHONPATH"] = str(REPO / "src")
        cmd = [
            sys.executable, "-m", "repro.launch.train",
            "--arch", "internlm2-1.8b", "--smoke",
            "--steps", "8", "--seq", "16", "--batch", "4",
            "--checkpoint-every", "3", "--outdir", str(outdir),
        ] + extra
        return subprocess.run(cmd, env=env, capture_output=True, text=True, timeout=600)

    @pytest.mark.slow  # ~30s: full train-kill-restart subprocess cycle
    def test_checkpoint_restart_bitwise(self, tmp_path):
        # uninterrupted run
        r_full = self._run(tmp_path / "full", [])
        assert r_full.returncode == 0, r_full.stderr[-2000:]
        # failing run: dies at step 5 (after ckpt at step 3), then restarts
        r_fail = self._run(tmp_path / "ft", ["--fail-at", "5"])
        assert r_fail.returncode == 42
        r_resume = self._run(tmp_path / "ft", [])
        assert r_resume.returncode == 0, r_resume.stderr[-2000:]
        assert "[resume] from checkpoint step 3" in r_resume.stdout

        # deterministic data + step-keyed state => identical final loss
        def last_loss(d):
            lines = (d / "train_log.jsonl").read_text().strip().splitlines()
            return json.loads(lines[-1])["loss"]

        assert last_loss(tmp_path / "full") == pytest.approx(
            last_loss(tmp_path / "ft"), rel=1e-5
        )
