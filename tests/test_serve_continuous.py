"""Continuous-batching serving subsystem tests.

Pinned invariants:
  1. slot pool: allocate/free/reuse bookkeeping, insert/extract roundtrip;
  2. scheduler: strict FCFS admission (arrival gating, no queue jumping);
  3. greedy continuous batching is token-identical to the static ``generate``
     oracle — uniform workload, and mixed lengths with fewer slots than
     requests (queueing + slot reuse);
  4. the decode step compiles exactly once as requests join and leave;
  5. the static engine's preallocated output buffer preserves the prompt
     prefix and dtype;
  6. stop-token requests finish early and free their slot.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config, reduce_config
from repro.data.synthetic import DataConfig, batch_at
from repro.models.transformer import make_model
from repro.serve.engine import (
    ContinuousEngine,
    ServeConfig,
    generate,
    static_reference,
)
from repro.serve.kv_cache import SlotKVPool
from repro.serve.scheduler import FCFSScheduler, Request
from repro.serve.workload import required_max_seq, staggered_requests

from _serve_helpers import assert_exact_compile_counters


@pytest.fixture(scope="module")
def dense():
    cfg = reduce_config(get_config("internlm2-1.8b"))
    model = make_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


def _prompt(cfg, length, seed):
    data = DataConfig(vocab=cfg.vocab, seq_len=length, global_batch=1, seed=seed)
    return np.asarray(batch_at(data, 0)["tokens"][0], np.int32)


# ----------------------------------------------------------------- pool ---
def test_slot_alloc_free_reuse(dense):
    _, model, _ = dense
    pool = SlotKVPool(model, num_slots=3, max_seq=16)
    slots = [pool.allocate() for _ in range(3)]
    assert sorted(slots) == [0, 1, 2]
    assert pool.num_free == 0
    with pytest.raises(RuntimeError):
        pool.allocate()
    pool.free(1)
    assert pool.num_free == 1
    assert pool.allocate() == 1  # freed slot is recycled
    with pytest.raises(ValueError):
        pool.free(7)  # was never allocated
    pool.free(0)
    pool.free(1)
    pool.free(2)
    assert pool.num_free == 3 and pool.num_used == 0


def test_slot_insert_extract_roundtrip(dense):
    cfg, model, params = dense
    pool = SlotKVPool(model, num_slots=3, max_seq=20)
    batch = {"tokens": jnp.asarray(_prompt(cfg, 8, seed=1))[None]}
    _, one = jax.jit(lambda p, b: model.prefill(p, b, 20))(params, batch)
    slot = pool.allocate()
    pool.insert(one, slot, position=8)
    assert pool.positions[slot] == 8
    back = pool.extract(slot)
    chex_ok = jax.tree.map(
        lambda a, b: np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32)),
        one, back,
    )
    assert all(jax.tree.leaves(chex_ok))
    with pytest.raises(ValueError):
        pool.insert(one, slot, position=pool.max_seq + 1)
    pool.free(slot)
    assert pool.positions[slot] == 0


# ------------------------------------------------------------ scheduler ---
def test_scheduler_fcfs_admission_order():
    sched = FCFSScheduler()
    early = Request(tokens=np.zeros(4, np.int32), arrival_step=0)
    late = Request(tokens=np.zeros(4, np.int32), arrival_step=5)
    never_jumps = Request(tokens=np.zeros(4, np.int32), arrival_step=0)
    ids = [sched.submit(r) for r in (early, late, never_jumps)]
    assert ids == [0, 1, 2]

    assert sched.pop_ready(0).id == 0
    # head of queue hasn't arrived yet: the already-arrived request behind it
    # must NOT jump the line (strict FCFS)
    assert sched.pop_ready(0) is None
    assert sched.pop_ready(4) is None
    assert sched.pop_ready(5).id == 1
    assert sched.pop_ready(5).id == 2
    assert not sched.has_pending()


# ------------------------------------------ continuous vs static oracle ---
def test_uniform_workload_matches_static(dense):
    cfg, model, params = dense
    scfg = ServeConfig()
    reqs = [
        Request(id=i, tokens=_prompt(cfg, 10, seed=40 + i), max_new_tokens=5)
        for i in range(4)
    ]
    engine = ContinuousEngine(model, params, num_slots=4, max_seq=15, cfg=scfg)
    comps = engine.run(reqs)
    ref = static_reference(model, params, reqs, scfg)
    assert len(comps) == 4
    for c in comps:
        assert np.array_equal(c.tokens, ref[c.request_id])
    m = engine.metrics()
    assert_exact_compile_counters(m)
    assert m["mean_slot_utilization"] > 0.9  # everyone decodes in lockstep


def test_mixed_lengths_queueing_matches_static(dense):
    cfg, model, params = dense
    scfg = ServeConfig()
    reqs = staggered_requests(cfg, n_requests=6, base_len=12,
                              max_new_tokens=6, stagger=2, seed=9)
    # 2 slots for 6 requests: forces queueing AND slot reuse mid-flight
    engine = ContinuousEngine(model, params, num_slots=2,
                              max_seq=required_max_seq(reqs), cfg=scfg)
    comps = engine.run(reqs)
    ref = static_reference(model, params, reqs, scfg)
    assert len(comps) == 6
    for c in comps:
        assert np.array_equal(c.tokens, ref[c.request_id]), f"req {c.request_id}"
        assert c.admit_step >= c.arrival_step
    m = engine.metrics()
    assert_exact_compile_counters(m)
    # FCFS: admission order == request id order
    admits = sorted(comps, key=lambda c: (c.admit_step, c.request_id))
    assert [c.request_id for c in admits] == list(range(6))


def test_ssm_family_continuous_matches_static():
    cfg = reduce_config(get_config("xlstm-350m"))
    model = make_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    scfg = ServeConfig()
    reqs = [
        Request(id=i, tokens=_prompt(cfg, L, seed=60 + i), max_new_tokens=4,
                arrival_step=i)
        for i, L in enumerate([8, 12, 8])
    ]
    engine = ContinuousEngine(model, params, num_slots=2, max_seq=16, cfg=scfg)
    comps = engine.run(reqs)
    ref = static_reference(model, params, reqs, scfg)
    for c in comps:
        assert np.array_equal(c.tokens, ref[c.request_id])
    assert engine.metrics()["decode_compilations"] == 1


def test_stop_token_finishes_early_and_frees_slot(dense):
    cfg, model, params = dense
    # run once greedily to learn the 2nd generated token, then stop on it
    probe = Request(id=0, tokens=_prompt(cfg, 8, seed=77), max_new_tokens=6)
    engine = ContinuousEngine(model, params, num_slots=1, max_seq=14)
    (done,) = engine.run([probe])
    stop = int(done.new_tokens[1])

    engine.reset()
    req = Request(id=0, tokens=_prompt(cfg, 8, seed=77), max_new_tokens=6,
                  stop_token=stop)
    (c,) = engine.run([req])
    assert c.finish_reason == "stop"
    assert len(c.new_tokens) == 2
    assert engine.pool.num_free == 1  # slot recycled on completion


# ------------------------------------------------------------ static fix ---
def test_static_generate_preserves_prompt_prefix(dense):
    cfg, model, params = dense
    batch = {"tokens": jnp.stack([jnp.asarray(_prompt(cfg, 9, seed=4))] * 2)}
    out = generate(model, params, batch, ServeConfig(max_new_tokens=3))
    assert out.shape == (2, 12)
    assert out.dtype == jnp.int32
    assert np.array_equal(np.asarray(out)[:, :9], np.asarray(batch["tokens"]))


@pytest.mark.parametrize("temperature", [0.0, 0.7])
def test_engine_reset_replays_identically(dense, temperature):
    # temperature>0 exercises the per-slot key streams: replay determinism
    # requires reset() to restore the pool's slot assignment order too
    cfg, model, params = dense
    reqs = staggered_requests(cfg, n_requests=3, base_len=8,
                              max_new_tokens=4, stagger=1, seed=31)
    engine = ContinuousEngine(model, params, num_slots=2,
                              max_seq=required_max_seq(reqs),
                              cfg=ServeConfig(temperature=temperature, seed=5))
    first = {c.request_id: c.tokens for c in engine.run(reqs)}
    engine.reset()
    second = {c.request_id: c.tokens for c in engine.run(reqs)}
    assert first.keys() == second.keys()
    for rid in first:
        assert np.array_equal(first[rid], second[rid])


def test_static_reference_truncates_at_stop_token(dense):
    cfg, model, params = dense
    probe = Request(id=0, tokens=_prompt(cfg, 8, seed=88), max_new_tokens=6)
    engine = ContinuousEngine(model, params, num_slots=1, max_seq=14)
    (done,) = engine.run([probe])
    stop = int(done.new_tokens[1])

    req = Request(id=0, tokens=_prompt(cfg, 8, seed=88), max_new_tokens=6,
                  stop_token=stop)
    ref = static_reference(model, params, [req], ServeConfig())
    engine.reset()
    (c,) = engine.run([req])
    assert np.array_equal(c.tokens, ref[0])  # oracle honors the stop token
    with pytest.raises(ValueError):
        static_reference(model, params, [req], ServeConfig(temperature=0.5))
