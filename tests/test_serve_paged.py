"""Block-paged KV serving tests (PR 3).

Pinned invariants:
  1. greedy continuous batching under block-paged KV is token-identical to
     the static oracle for the dense and MLA families, across block sizes
     {chunk, 2*chunk}, with churn (fewer slots than requests -> finished
     requests recycle their blocks for later admits);
  2. compile counters are exact ints (no nulls): exactly one trace per
     (step kind, horizon bucket actually seen), bounded by the power-of-two
     bucket grid, prefill=0 — regardless of the prompt-length mix;
  3. block-table bookkeeping: on-demand growth, whole-request reservation
     admission (a request waits for *blocks*, not just a slot), FIFO
     recycling, and full drain back to an empty arena;
  4. recycled-block guard: a reset engine replays bit-identically after a
     sampled (non-greedy) run — stale arena contents are unreachable through
     the causal mask + exactly-zero GN numerators, no zeroing needed;
  5. the paged GN attention kernel preserves the paper's guarantee: Sigma p
     = 1 to one rounding through an arbitrary block layout, and matches the
     contiguous gn_attention reference on an identity table — decode AND
     chunked-query forms, across block sizes {chunk, 2*chunk};
  6. the gather-free streamed read (serving default) is greedy
     token-identical to the gathered full-stream oracle for dense and MLA,
     and per-tick attended width under horizon bucketing stays below the
     full max_bt stream.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config, reduce_config
from repro.data.synthetic import DataConfig, batch_at
from repro.kernels.gn_attention.ref import gn_attention_ref
from repro.kernels.gn_paged_attention.ops import (
    gn_paged_attention,
    gn_paged_attention_chunk,
)
from repro.kernels.gn_paged_attention.ref import (
    gn_paged_attention_chunk_ref,
    gn_paged_attention_ref,
)
from repro.models import attention as attention_mod
from repro.models.transformer import make_model
from repro.serve.engine import ContinuousEngine, ServeConfig, static_reference
from repro.serve.kv_cache import BlockPagedKVPool
from repro.serve.scheduler import Request
from repro.serve.workload import required_max_seq

from _serve_helpers import assert_exact_compile_counters

CHUNK = 4


@pytest.fixture(scope="module")
def dense():
    cfg = reduce_config(get_config("internlm2-1.8b"))
    model = make_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def mla():
    cfg = reduce_config(get_config("minicpm3-4b"))
    model = make_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


def _prompt(cfg, length, seed):
    data = DataConfig(vocab=cfg.vocab, seq_len=length, global_batch=1, seed=seed)
    return np.asarray(batch_at(data, 0)["tokens"][0], np.int32)


def _mixed_requests(cfg, max_new=4):
    # >= 4 distinct prompt lengths, none aligned to the chunk grid, more
    # requests than slots -> finished requests recycle blocks mid-run
    lens = [5, 9, 14, 22, 7]
    return [
        Request(id=i, tokens=_prompt(cfg, L, seed=300 + i), max_new_tokens=max_new,
                arrival_step=i)
        for i, L in enumerate(lens)
    ]


# ----------------------------------------- greedy identity under paging ----
@pytest.mark.parametrize("block_size", [CHUNK, 2 * CHUNK])
@pytest.mark.parametrize("family", ["dense", "mla"])
def test_paged_identity_vs_static_oracle(dense, mla, family, block_size):
    cfg, model, params = dense if family == "dense" else mla
    scfg = ServeConfig()
    reqs = _mixed_requests(cfg)
    engine = ContinuousEngine(model, params, num_slots=2,
                              max_seq=required_max_seq(reqs), cfg=scfg,
                              chunk=CHUNK, block_size=block_size)
    assert engine.paged and isinstance(engine.pool, BlockPagedKVPool)
    comps = engine.run(reqs)
    ref = static_reference(model, params, reqs, scfg)
    assert len(comps) == len(reqs)
    for c in comps:
        assert np.array_equal(c.tokens, ref[c.request_id]), f"req {c.request_id}"
    m = engine.metrics()
    # explicit trace counters: exact ints, never None — one per (step kind,
    # horizon bucket) under horizon bucketing
    assert_exact_compile_counters(m)
    assert m["read_path"] == "streamed"
    # the workload drained: every block is back on the free list
    assert engine.pool.blocks_in_use == 0
    assert engine.pool.num_free == engine.pool.num_slots
    assert m["peak_blocks_in_use"] > 0


# ------------------------------------------------ block-table bookkeeping ---
def test_pool_reserve_ensure_recycle(dense):
    _, model, _ = dense
    pool = BlockPagedKVPool(model, num_slots=3, max_seq=16, block_size=4,
                            num_blocks=6)
    s0 = pool.allocate(reserve_tokens=12)  # 3 blocks reserved
    s1 = pool.allocate(reserve_tokens=12)  # 3 more: arena fully reserved
    assert pool.blocks_reserved == 6
    assert not pool.can_reserve(1)  # free slot exists, but no block headroom
    with pytest.raises(RuntimeError):
        pool.allocate(reserve_tokens=4)

    pool.ensure(s0, 5)  # positions [0,5) -> 2 blocks materialize
    assert pool.tables[s0, 0] == 0 and pool.tables[s0, 1] == 1
    assert pool.blocks_in_use == 2 and pool.peak_blocks_in_use == 2
    pool.ensure(s1, 12)
    assert list(pool.tables[s1, :3]) == [2, 3, 4]

    pool.free(s0)  # blocks 0,1 recycle in allocation order
    assert pool.blocks_in_use == 3
    assert pool.can_reserve(8)
    s2 = pool.allocate(reserve_tokens=8)
    pool.ensure(s2, 8)
    # FIFO recycling: the freed blocks (then the never-used tail) are reused
    assert list(pool.tables[s2, :2]) == [5, 0]
    with pytest.raises(ValueError):
        pool.ensure(s2, 17)  # beyond max_seq
    pool.free(s1)
    pool.free(s2)
    assert pool.blocks_in_use == 0 and pool.blocks_reserved == 0
    assert pool.num_free == 3


def test_admission_waits_for_blocks_not_just_slots(dense):
    cfg, model, params = dense
    scfg = ServeConfig()
    reqs = [
        Request(id=i, tokens=_prompt(cfg, 8, seed=330 + i), max_new_tokens=4)
        for i in range(2)
    ]
    # footprint 12 tokens = 3 blocks each; a 3-block arena forces strictly
    # serial service even though TWO slots are free
    engine = ContinuousEngine(model, params, num_slots=2, max_seq=12,
                              cfg=scfg, chunk=CHUNK, block_size=CHUNK,
                              num_blocks=3)
    comps = engine.run(reqs)
    ref = static_reference(model, params, reqs, scfg)
    assert len(comps) == 2
    for c in comps:
        assert np.array_equal(c.tokens, ref[c.request_id])
    first, second = sorted(comps, key=lambda c: c.request_id)
    # req 1 could only be admitted after req 0 finished and recycled blocks
    assert second.admit_step >= first.finish_step
    assert engine.pool.peak_blocks_in_use <= 3


def test_unservable_footprint_raises_at_admission(dense):
    # a request needing more blocks than the whole arena must fail loudly at
    # admission (like the max_seq check), not spin idle until the drain
    # budget explodes with a generic error
    cfg, model, params = dense
    engine = ContinuousEngine(model, params, num_slots=2, max_seq=32,
                              chunk=CHUNK, block_size=CHUNK, num_blocks=4)
    req = Request(id=0, tokens=_prompt(cfg, 20, seed=340), max_new_tokens=8)
    with pytest.raises(ValueError, match="unservable"):
        engine.run([req])


def test_engine_rejects_paging_knobs_for_unpaged_families():
    cfg = reduce_config(get_config("xlstm-350m"))  # ssm: O(1) carries
    model = make_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError):
        ContinuousEngine(model, params, num_slots=1, max_seq=16, block_size=4)
    assert not model.supports_paging
    with pytest.raises(ValueError):
        model.paged_cache_specs(1, 4, 4, 16)
    engine = ContinuousEngine(model, params, num_slots=1, max_seq=16)
    assert not engine.paged  # falls back to the slot-slab pool


# ------------------------------------------- recycled-block stale guard ----
def test_sampled_run_then_reset_replays_bit_identically(dense):
    # a sampled run scatters non-greedy KV through the arena; reset() does
    # NOT zero it (guard, not scrub) — replay must still be bit-identical
    cfg, model, params = dense
    reqs = [
        Request(id=i, tokens=_prompt(cfg, L, seed=350 + i), max_new_tokens=4,
                arrival_step=i)
        for i, L in enumerate([6, 11, 9])
    ]
    engine = ContinuousEngine(model, params, num_slots=2,
                              max_seq=required_max_seq(reqs),
                              cfg=ServeConfig(temperature=0.8, seed=3),
                              chunk=CHUNK)
    assert engine.paged
    first = {c.request_id: c.tokens for c in engine.run(reqs)}
    engine.reset()
    second = {c.request_id: c.tokens for c in engine.run(reqs)}
    assert first.keys() == second.keys()
    for rid in first:
        assert np.array_equal(first[rid], second[rid])


# --------------------------------------------- paged GN kernel guarantees ---
def _paged_kernel_inputs(seed=0):
    rng = np.random.default_rng(seed)
    n, h, kv, d = 3, 4, 2, 16
    nb, bs, max_bt = 10, 4, 5
    q = jnp.asarray(rng.normal(size=(n, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(nb, bs, kv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(nb, bs, kv, d)), jnp.float32)
    # scrambled, non-contiguous block layout
    tables = jnp.asarray([[7, 2, 9, 0, 0], [1, 5, 0, 0, 0], [3, 8, 6, 4, 0]],
                         jnp.int32)
    lengths = jnp.asarray([11, 6, 17], jnp.int32)
    return q, k, v, tables, lengths, (h // kv, max_bt)


def test_paged_kernel_matches_gathered_ref():
    q, k, v, tables, lengths, (group, _) = _paged_kernel_inputs()
    got = gn_paged_attention(q, k, v, tables, lengths, interpret=True)
    kb = jnp.repeat(k, group, axis=2)
    vb = jnp.repeat(v, group, axis=2)
    want = gn_paged_attention_ref(q, kb, vb, tables, lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=5e-5)


def test_paged_kernel_matches_contiguous_gn_attention_ref():
    # identity table -> the paged read must reproduce the contiguous-slab
    # gn_attention reference on each sequence's valid prefix
    q, k, v, _, lengths, (group, max_bt) = _paged_kernel_inputs()
    n, h, d = q.shape
    tables = jnp.broadcast_to(jnp.arange(max_bt, dtype=jnp.int32), (n, max_bt))
    got = gn_paged_attention(q, k, v, tables, lengths, interpret=True)
    kb = jnp.repeat(k, group, axis=2)[tables].reshape(n, -1, h, d).transpose(0, 2, 1, 3)
    vb = jnp.repeat(v, group, axis=2)[tables].reshape(n, -1, h, d).transpose(0, 2, 1, 3)
    for i in range(n):
        t = int(lengths[i])
        want = gn_attention_ref(q[i][None, :, None], kb[i : i + 1, :, :t],
                                vb[i : i + 1, :, :t])
        np.testing.assert_allclose(
            np.asarray(got[i]), np.asarray(want[0, :, 0]), atol=5e-5
        )


def test_paged_kernel_sum_to_one_through_block_table():
    # v = 1 turns the output into Sigma p * 1: guaranteed normalization must
    # survive the block table exactly as it survives chunked streaming
    q, k, v, tables, lengths, _ = _paged_kernel_inputs(seed=5)
    out = gn_paged_attention(q, k, jnp.ones_like(v), tables, lengths,
                             interpret=True)
    np.testing.assert_allclose(np.asarray(out), 1.0, atol=1e-5)


def test_paged_softmax_rows_sum_to_one():
    # the jnp serving path's probabilities themselves: gathered scores with a
    # masked tail (stale/foreign block guard) still sum to exactly ~1
    from repro.kernels.gn_paged_attention.ref import gn_paged_softmax_ref

    rng = np.random.default_rng(11)
    s = jnp.asarray(rng.normal(size=(5, 37)) * 6, jnp.float32)
    masked = s.at[:, 29:].set(-1e30)  # tail beyond the causal prefix
    p = gn_paged_softmax_ref(masked)
    np.testing.assert_allclose(np.asarray(p).sum(-1), 1.0, atol=2e-6)
    assert float(np.asarray(p)[:, 29:].max()) == 0.0  # guard: exact zeros


# --------------------------------------- streamed vs gathered read paths ----
@pytest.mark.parametrize("family", ["dense", "mla"])
def test_streamed_read_token_identical_to_gathered_oracle(dense, mla, family):
    """The gather-free streamed read (the serving default) must produce the
    same greedy tokens as the full-stream gathered oracle — which PR 3
    proved slab-equal — for dense AND MLA.  Fresh engines per path: the
    forced read is baked in at trace time."""
    cfg, model, params = dense if family == "dense" else mla
    scfg = ServeConfig()
    reqs = _mixed_requests(cfg)
    results = {}
    for path in ("gathered", "streamed"):
        attention_mod.FORCE_PAGED_READ = path
        try:
            engine = ContinuousEngine(model, params, num_slots=2,
                                      max_seq=required_max_seq(reqs),
                                      cfg=scfg, chunk=CHUNK)
            assert engine.model.paged_read_path == path
            results[path] = {c.request_id: c.tokens for c in engine.run(reqs)}
        finally:
            attention_mod.FORCE_PAGED_READ = None
    assert results["gathered"].keys() == results["streamed"].keys()
    for rid in results["gathered"]:
        assert np.array_equal(results["streamed"][rid],
                              results["gathered"][rid]), f"req {rid}"


def test_slab_engine_reports_slab_read_path(dense):
    _, model, params = dense
    engine = ContinuousEngine(model, params, num_slots=1, max_seq=16,
                              paged=False)
    assert engine.metrics()["read_path"] == "slab"


# ------------------------------------------------- horizon bucketing --------
def test_horizon_bucketing_compile_bounds_and_attended_width(dense):
    """Compile counters under horizon bucketing: exactly one trace per
    (step kind, bucket actually seen), bucket grid = powers of two capped
    at max_blocks_per_slot, and the mean attended width per tick must sit
    strictly below the full max_bt stream on a mixed-length workload (the
    whole point: per-tick work scales with live context)."""
    cfg, model, params = dense
    scfg = ServeConfig()
    reqs = _mixed_requests(cfg)
    engine = ContinuousEngine(model, params, num_slots=2,
                              max_seq=required_max_seq(reqs), cfg=scfg,
                              chunk=CHUNK, block_size=CHUNK)
    grid = engine.horizon_bucket_grid
    max_bt = engine.pool.max_blocks_per_slot
    # powers of two, strictly increasing, capped at max_bt
    assert grid[-1] == max_bt
    assert all(b < b2 for b, b2 in zip(grid, grid[1:]))
    assert all(b & (b - 1) == 0 for b in grid[:-1])
    comps = engine.run(reqs)
    assert len(comps) == len(reqs)
    m = engine.metrics()
    assert_exact_compile_counters(m)
    assert m["horizon_buckets"]  # at least one bucket was traced
    # every tick's horizon fits its bucket, and never exceeds the grid cap
    for horizon, bucket in engine.horizon_log:
        assert horizon <= bucket <= max_bt
        assert bucket in grid
    # live-context scaling: the mixed workload spends most ticks well below
    # the full stream, so the mean attended width must be < max_bt * bs
    full = max_bt * engine.pool.block_size
    assert 0 < m["mean_attended_tokens_per_tick"] < full


# ---------------------------------------- chunked-query paged GN kernel -----
def _chunk_kernel_inputs(seed=0, bs=4):
    rng = np.random.default_rng(seed)
    n, c, h, kv, d = 3, CHUNK, 4, 2, 16
    nb = 12
    max_bt = -(-32 // bs)  # cover 32 tokens of context
    q = jnp.asarray(rng.normal(size=(n, c, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(nb, bs, kv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(nb, bs, kv, d)), jnp.float32)
    tables = jnp.asarray(rng.integers(0, nb, size=(n, max_bt)), jnp.int32)
    starts = jnp.asarray([9, 0, 17], jnp.int32)
    n_valid = jnp.asarray([c, c - 1, c], jnp.int32)
    return q, k, v, tables, starts, n_valid, h // kv


@pytest.mark.parametrize("block_size", [CHUNK, 2 * CHUNK])
def test_chunked_query_kernel_matches_gathered_chunk_ref(block_size):
    q, k, v, tables, starts, n_valid, group = _chunk_kernel_inputs(bs=block_size)
    got = gn_paged_attention_chunk(q, k, v, tables, starts, n_valid,
                                   interpret=True)
    kb = jnp.repeat(k, group, axis=2)
    vb = jnp.repeat(v, group, axis=2)
    want = gn_paged_attention_chunk_ref(q, kb, vb, tables, starts, n_valid)
    # online (single-pass) accumulation vs the one-pass reference: equal up
    # to LUT-entry rounding of the correction factors, not bitwise
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4)


@pytest.mark.parametrize("block_size", [CHUNK, 2 * CHUNK])
def test_chunked_query_kernel_matches_contiguous_gn_attention_ref(block_size):
    # identity table -> each sequence's chunk must reproduce the contiguous
    # gn_attention reference over its causal prefix (kv span = start + C)
    q, k, v, tables, starts, n_valid, group = _chunk_kernel_inputs(bs=block_size)
    n, c, h, d = q.shape
    max_bt = tables.shape[1]
    tables = jnp.broadcast_to(jnp.arange(max_bt, dtype=jnp.int32), (n, max_bt))
    got = gn_paged_attention_chunk(q, k, v, tables, starts,
                                   jnp.full_like(starts, c), interpret=True)
    kb = jnp.repeat(k, group, axis=2).reshape(-1, h, d).transpose(1, 0, 2)
    vb = jnp.repeat(v, group, axis=2).reshape(-1, h, d).transpose(1, 0, 2)
    for i in range(n):
        t = int(starts[i]) + c
        want = gn_attention_ref(
            q[i].transpose(1, 0, 2)[None], kb[None, :, :t], vb[None, :, :t],
            causal=True,
        )
        np.testing.assert_allclose(
            np.asarray(got[i]), np.asarray(want[0]).transpose(1, 0, 2),
            atol=2e-4,
        )


@pytest.mark.parametrize("block_size", [CHUNK, 2 * CHUNK])
def test_chunked_query_kernel_sum_to_one_through_block_table(block_size):
    # v = 1 turns the output into Sigma p * 1: guaranteed normalization must
    # survive chunked queries and any block layout to one rounding
    q, k, v, tables, starts, n_valid, _ = _chunk_kernel_inputs(seed=5,
                                                               bs=block_size)
    out = gn_paged_attention_chunk(q, k, jnp.ones_like(v), tables, starts,
                                   n_valid, interpret=True)
    c = q.shape[1]
    lane_ok = np.arange(c)[None, :] < np.asarray(n_valid)[:, None]
    np.testing.assert_allclose(np.asarray(out)[lane_ok], 1.0, atol=1e-5)


def test_paged_chunk_pallas_read_matches_gathered(dense):
    """Wiring test for the 'pallas' read path: a single attn_paged_chunk
    call (chunked queries, interpret mode on CPU) must agree with the
    gathered read through the same arenas to kernel tolerance."""
    cfg, _, _ = dense
    rng = np.random.default_rng(7)
    n, c_len, d_model = 2, CHUNK, cfg.d_model
    nb, bs = 8, CHUNK
    p = {
        "wq": jnp.asarray(rng.normal(size=(d_model, cfg.q_features)) * 0.05, jnp.float32),
        "wk": jnp.asarray(rng.normal(size=(d_model, cfg.kv_features)) * 0.05, jnp.float32),
        "wv": jnp.asarray(rng.normal(size=(d_model, cfg.kv_features)) * 0.05, jnp.float32),
        "wo": jnp.asarray(rng.normal(size=(cfg.q_features, d_model)) * 0.05, jnp.float32),
    }
    ak = jnp.asarray(rng.normal(size=(nb, bs, cfg.n_kv_heads, cfg.head_dim)), jnp.float32)
    av = jnp.asarray(rng.normal(size=(nb, bs, cfg.n_kv_heads, cfg.head_dim)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(n, c_len, d_model)) * 0.1, jnp.float32)
    tables = jnp.asarray(rng.permutation(nb)[: 2 * 4].reshape(n, 4), jnp.int32)
    positions = jnp.asarray([6, 1], jnp.int32)
    n_valid = jnp.asarray([c_len, c_len], jnp.int32)
    outs = {}
    for path in ("gathered", "pallas"):
        attention_mod.FORCE_PAGED_READ = path
        try:
            out, _ = attention_mod.attn_paged_chunk(
                cfg, p, ak, av, x, positions, n_valid, tables)
        finally:
            attention_mod.FORCE_PAGED_READ = None
        outs[path] = np.asarray(out)
    np.testing.assert_allclose(outs["pallas"], outs["gathered"], atol=5e-4)
