"""Fault-tolerant serving tests (PR 10): GN runtime sentinels, seeded fault
injection, block quarantine, and exact recovery.

Pinned invariants:
  1. clean runs are sentinel-silent: with probes enabled on every tick, a
     fault-free workload records zero violations, stays greedy
     token-identical to the static oracle, and keeps the exact
     compile-counter contract (the health word is a closure-constant
     plumbing change — no new trace keys);
  2. every injected fault class — NaN tile, Inf tile, int8 scale
     corruption, block-table scribble, whole-device loss — is detected
     within ONE tick of injection and attributed to (slot, layer, block);
  3. containment never touches healthy state: violating blocks are
     quarantined (never recycled) and scrubbed, the free/live/quarantined
     ledger reconciles after every transition, and quarantined blocks
     never leak back through admit/preempt/spill churn;
  4. recovery is exact: affected requests are rebuilt via free-and-
     recompute and finish greedy token-identical to the fault-free oracle;
     an exhausted retry budget yields finish_reason='failed' plus a fault
     record in the event log — never a silent wrong answer;
  5. falsifiability: the same faults against an engine with sentinels
     DISABLED go undetected (if they didn't, the detection claim would be
     untestable);
  6. bit_flip is the documented detection floor: GN renormalizes any
     finite score set to Σp=1, so a one-ulp flip yields a valid
     distribution — the injector records it as undetectable and the
     engine (correctly) stays silent.
"""
import jax
import numpy as np
import pytest

from repro.configs.registry import get_config, reduce_config
from repro.models.transformer import make_model
from repro.serve.engine import ContinuousEngine, ServeConfig, static_reference
from repro.serve.faults import FaultInjector, FaultRecord
from repro.serve.kv_cache import BlockPagedKVPool
from repro.serve.scheduler import FINISH_REASONS, Completion, Request

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # pragma: no cover
    from _hypothesis_fallback import given, settings, st

from _serve_helpers import assert_exact_compile_counters

CHUNK = 4
TWO_DEV = jax.device_count() >= 2
requires_mesh = pytest.mark.skipif(
    not TWO_DEV,
    reason="needs >= 2 devices "
    "(export XLA_FLAGS=--xla_force_host_platform_device_count=2)",
)


@pytest.fixture(scope="module")
def dense():
    cfg = reduce_config(get_config("internlm2-1.8b"))
    model = make_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def mla():
    cfg = reduce_config(get_config("minicpm3-4b"))
    model = make_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


def _requests(cfg, lens=(5, 9, 7), max_new=6, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(tokens=rng.integers(0, cfg.vocab, size=n).astype(np.int32),
                    max_new_tokens=max_new) for n in lens]


def _oracle(model, params, reqs, max_new=6):
    refs = [Request(tokens=r.tokens, max_new_tokens=r.max_new_tokens, id=i)
            for i, r in enumerate(reqs)]
    return static_reference(model, params, refs, ServeConfig(max_new_tokens=max_new))


def _assert_identity(completions, ref, n_requests):
    assert len(completions) == n_requests
    for c in completions:
        assert c.finish_reason in ("length", "stop"), c.finish_reason
        got = [int(t) for t in c.new_tokens]
        want = [int(t) for t in ref[c.request_id][len(c.prompt_tokens):]]
        assert got == want, (c.request_id, got, want)


def _assert_ledger(pool: BlockPagedKVPool):
    pool.check_ledger()
    live = {b for ch in pool._slot_blocks.values() for b in ch}
    free = {b for q in pool._free_blocks for b in q}
    assert not (pool.quarantined & live)
    assert not (pool.quarantined & free)
    assert len(free) + int((pool.refcounts > 0).sum()) + len(pool.quarantined) \
        == pool.num_blocks


# ------------------------------------------------------- finish reasons --
def test_finish_reason_closed_enum():
    assert set(FINISH_REASONS) == {"length", "stop", "rejected", "failed"}
    for reason in FINISH_REASONS:
        Completion(request_id=0, prompt_tokens=np.zeros(1, np.int32),
                   new_tokens=np.zeros(0, np.int32), finish_reason=reason,
                   arrival_step=0, admit_step=0, first_token_step=0,
                   finish_step=0, admit_time=0.0, first_token_time=0.0,
                   finish_time=0.0)
    with pytest.raises(ValueError, match="finish_reason"):
        Completion(request_id=0, prompt_tokens=np.zeros(1, np.int32),
                   new_tokens=np.zeros(0, np.int32), finish_reason="oom",
                   arrival_step=0, admit_step=0, first_token_step=0,
                   finish_step=0, admit_time=0.0, first_token_time=0.0,
                   finish_time=0.0)


# ----------------------------------------------------------- clean runs --
@pytest.mark.parametrize("family", ["dense", "mla"])
def test_clean_run_sentinel_silent_and_identical(family, dense, mla, request):
    cfg, model, params = request.getfixturevalue(family)
    reqs = _requests(cfg)
    ref = _oracle(model, params, reqs)
    eng = ContinuousEngine(model, params, num_slots=2, max_seq=64,
                           cfg=ServeConfig(max_new_tokens=6), chunk=CHUNK)
    assert eng.sentinels
    eng.run(reqs)
    m = eng.metrics()
    assert m["sentinel_checks"] > 0
    assert m["sentinel_violations"] == 0
    assert m["quarantined_blocks"] == 0
    assert m["retries"] == m["fallbacks"] == m["failed_completions"] == 0
    _assert_identity(eng.completions, ref, len(reqs))
    # sentinels add zero trace keys: the exact compile contract holds
    assert_exact_compile_counters(m)


def test_sentinels_rejected_without_paging(dense):
    cfg, model, params = dense
    with pytest.raises(ValueError, match="paged"):
        ContinuousEngine(model, params, num_slots=2, max_seq=64,
                         cfg=ServeConfig(), chunk=CHUNK, paged=False,
                         sentinels=True)


# ---------------------------------------------------------- fault matrix --
@pytest.mark.parametrize("family,kind,kv_dtype", [
    ("dense", "nan_tile", "fp"),
    ("dense", "inf_tile", "fp"),
    ("dense", "scale", "int8"),
    ("dense", "table", "fp"),
    ("mla", "nan_tile", "fp"),
    ("mla", "scale", "int8"),
    ("mla", "table", "fp"),
])
def test_fault_detected_contained_recovered(family, kind, kv_dtype,
                                            dense, mla, request):
    """Each fault class: detected <= 1 tick after injection, contained
    without touching healthy blocks, and the affected request recovered
    greedy token-identical to the fault-free oracle."""
    cfg, model, params = request.getfixturevalue(family)
    reqs = _requests(cfg)
    ref = _oracle(model, params, reqs)
    eng = ContinuousEngine(model, params, num_slots=2, max_seq=64,
                           cfg=ServeConfig(max_new_tokens=6), chunk=CHUNK,
                           kv_dtype=kv_dtype)
    inj = FaultInjector(eng, seed=1)
    for r in reqs:
        eng.submit(r)
    records: list[FaultRecord] = []
    while eng.step():
        if len(records) < 2:
            rec = inj.inject(kind)
            if rec is not None:
                records.append(rec)
    assert records, "injector never found a target"
    m = eng.metrics()
    assert m["sentinel_violations"] >= len(records)
    # detection latency: every injected fault is flagged on the very next
    # tick (fault / fault_table_repair event at the injection step)
    flag_kind = "fault_table_repair" if kind == "table" else "fault"
    flagged_steps = [e[1] for e in eng.event_log if e[0] == flag_kind]
    for rec in records:
        assert any(s - rec.step <= 1 for s in flagged_steps if s >= rec.step), \
            (rec, flagged_steps)
    if kind in ("nan_tile", "inf_tile", "scale"):
        assert m["quarantined_blocks"] >= 1
        assert m["retries"] >= 1
        # the poisoned blocks themselves are quarantined
        assert any(r.block in eng.pool.quarantined for r in records)
    else:  # table scribble: repaired in place, nothing quarantined
        assert m["table_repairs"] == len(records)
        assert m["quarantined_blocks"] == 0
        assert m["retries"] == 0
    _assert_ledger(eng.pool)
    _assert_identity(eng.completions, ref, len(reqs))


def test_nan_tile_rejected_on_int8_arena(dense):
    cfg, model, params = dense
    eng = ContinuousEngine(model, params, num_slots=2, max_seq=64,
                           cfg=ServeConfig(max_new_tokens=4), chunk=CHUNK,
                           kv_dtype="int8")
    inj = FaultInjector(eng, seed=0)
    for r in _requests(cfg, lens=(5,), max_new=4):
        eng.submit(r)
    eng.step()
    with pytest.raises(ValueError, match="nonfinite"):
        inj.inject("nan_tile")


# -------------------------------------------------------- falsifiability --
def test_sentinels_off_misses_fault(dense):
    """The detection claim must be falsifiable: the same NaN poison against
    an engine with probes disabled sails through unflagged (and corrupts
    the victim's output)."""
    cfg, model, params = dense
    reqs = _requests(cfg)
    ref = _oracle(model, params, reqs)
    eng = ContinuousEngine(model, params, num_slots=2, max_seq=64,
                           cfg=ServeConfig(max_new_tokens=6), chunk=CHUNK,
                           sentinels=False)
    assert not eng.sentinels
    inj = FaultInjector(eng, seed=1)
    injected = 0
    for r in reqs:
        eng.submit(r)
    while eng.step():
        if injected < 2 and inj.inject("nan_tile"):
            injected += 1
    assert injected
    m = eng.metrics()
    assert m["sentinel_checks"] == 0
    assert m["sentinel_violations"] == 0
    assert m["quarantined_blocks"] == 0
    # garbage flowed through undetected: at least one completion diverges
    mismatched = sum(
        1 for c in eng.completions
        if [int(t) for t in c.new_tokens]
        != [int(t) for t in ref[c.request_id][len(c.prompt_tokens):]]
    )
    assert mismatched >= 1


def test_bit_flip_below_detection_floor(dense):
    """A one-ulp mantissa flip renormalizes to a valid Σp=1 distribution —
    the injector documents it as undetectable and the sentinels stay
    silent (no false quarantine of an almost-right block)."""
    cfg, model, params = dense
    reqs = _requests(cfg)
    eng = ContinuousEngine(model, params, num_slots=2, max_seq=64,
                           cfg=ServeConfig(max_new_tokens=6), chunk=CHUNK)
    inj = FaultInjector(eng, seed=2)
    injected = 0
    for r in reqs:
        eng.submit(r)
    while eng.step():
        if injected < 2 and inj.inject("bit_flip"):
            injected += 1
    assert injected
    assert all(not r.detectable for r in inj.records)
    m = eng.metrics()
    assert m["sentinel_violations"] == 0
    assert m["quarantined_blocks"] == 0
    assert len(eng.completions) == len(reqs)


# ------------------------------------------------------------ retry path --
def test_retry_budget_exhaustion_fails_closed(dense):
    """A request whose every resume is re-poisoned exhausts its retry
    budget and finishes 'failed' with a fault record — never a silent
    wrong answer."""
    cfg, model, params = dense
    reqs = _requests(cfg, lens=(6,), max_new=6)
    eng = ContinuousEngine(model, params, num_slots=1, max_seq=64,
                           cfg=ServeConfig(max_new_tokens=6), chunk=CHUNK,
                           fault_retry_budget=1)
    inj = FaultInjector(eng, seed=3)
    for r in reqs:
        eng.submit(r)
    budget = 200
    while eng.step():
        inj.inject("nan_tile")  # poison every tick: recovery cannot win
        budget -= 1
        assert budget > 0
    assert [c.finish_reason for c in eng.completions] == ["failed"]
    m = eng.metrics()
    assert m["failed_completions"] == 1
    assert m["retries"] == 1  # budget consumed before failing closed
    assert any(e[0] == "fault" for e in eng.event_log)
    _assert_ledger(eng.pool)


def test_int8_fallback_completes_full_precision(dense):
    """The int8->fp escape hatch: a slot flipped to the static fp path
    mid-run still produces the oracle's greedy tokens and finishes with a
    normal reason plus a kv_fallback event."""
    cfg, model, params = dense
    reqs = _requests(cfg, lens=(6,), max_new=6)
    ref = _oracle(model, params, reqs)
    eng = ContinuousEngine(model, params, num_slots=1, max_seq=64,
                           cfg=ServeConfig(max_new_tokens=6), chunk=CHUNK,
                           kv_dtype="int8")
    for r in reqs:
        eng.submit(r)
    # run until the slot has generated at least one token, then force the
    # fallback the clip-streak watchdog would trigger
    while eng.step():
        st_ = eng._slots[0]
        if st_ is not None and len(st_.generated) >= 2:
            eng._int8_fallback(0)
    assert eng.metrics()["fallbacks"] == 1
    assert any(e[0] == "kv_fallback" for e in eng.event_log)
    assert len(eng.completions) == 1
    c = eng.completions[0]
    assert c.finish_reason in ("length", "stop")
    got = [int(t) for t in c.new_tokens]
    want = [int(t) for t in ref[c.request_id][len(c.prompt_tokens):]]
    assert got == want


# ------------------------------------------------------- quarantine churn --
_DENSE_CACHE = {}


def _dense_cached():
    # property tests can't take pytest fixtures through the hypothesis
    # wrapper (its signature hides them), so the model is cached here
    if not _DENSE_CACHE:
        cfg = reduce_config(get_config("internlm2-1.8b"))
        model = make_model(cfg)
        _DENSE_CACHE["v"] = (cfg, model, model.init(jax.random.PRNGKey(0)))
    return _DENSE_CACHE["v"]


@given(seed=st.integers(min_value=0, max_value=10_000),
       kind=st.sampled_from(["nan_tile", "inf_tile"]),
       preempt=st.sampled_from(["off", "spill", "recompute"]))
@settings(max_examples=6, deadline=None)
def test_quarantine_never_leaks_under_churn(seed, kind, preempt):
    """Property: across admit/preempt/fault churn, quarantined blocks never
    re-enter a chain or the free lists, and the ledger reconciles after
    every step."""
    cfg, model, params = _dense_cached()
    reqs = _requests(cfg, lens=(5, 9, 7, 6, 8), max_new=4, seed=seed)
    kw = dict(cfg=ServeConfig(max_new_tokens=4), chunk=CHUNK)
    if preempt != "off":
        kw.update(sched="priority", preempt=preempt)
        reqs[2].req_class = "interactive"
        for r in (reqs[0], reqs[3]):
            r.req_class = "batch"
    eng = ContinuousEngine(model, params, num_slots=2, max_seq=64, **kw)
    inj = FaultInjector(eng, seed=seed)
    for r in reqs:
        eng.submit(r)
    injected, quarantined_ever = 0, set()
    budget = 400
    while eng.step():
        if injected < 3 and inj.inject(kind):
            injected += 1
        quarantined_ever |= eng.pool.quarantined
        _assert_ledger(eng.pool)
        budget -= 1
        assert budget > 0
    assert injected
    # once quarantined, always quarantined (never recycled back)
    assert quarantined_ever == eng.pool.quarantined
    assert len(eng.completions) == len(reqs)


def test_ledger_reconciles_through_recycle_churn(dense):
    """Regression: the free/live/quarantined partition survives a full
    admit->finish->recycle cycle count larger than the arena (every block
    recycled at least once) with interleaved quarantines."""
    cfg, model, params = dense
    reqs = _requests(cfg, lens=(5, 9, 7, 6, 8, 5, 9, 7), max_new=3)
    eng = ContinuousEngine(model, params, num_slots=2, max_seq=64,
                           cfg=ServeConfig(max_new_tokens=3), chunk=CHUNK)
    inj = FaultInjector(eng, seed=5)
    for r in reqs:
        eng.submit(r)
    injected = 0
    while eng.step():
        if injected < 2 and eng.step_count % 3 == 0 and inj.inject("nan_tile"):
            injected += 1
        _assert_ledger(eng.pool)
    assert injected
    assert len(eng.completions) == len(reqs)
    # drain leaves only free + quarantined
    assert int((eng.pool.refcounts > 0).sum()) == 0
    _assert_ledger(eng.pool)


# ------------------------------------------------------------ device loss --
@requires_mesh
def test_device_loss_detected_and_survivors_complete(dense):
    """Poisoning an entire device's block range declares the device lost,
    quarantines its range, retires its slots from admission, and every
    request still completes token-identically on the survivors."""
    cfg, model, params = dense
    reqs = _requests(cfg, lens=(5, 9, 7, 6, 8, 5), max_new=5)
    ref = _oracle(model, params, reqs)
    eng = ContinuousEngine(model, params, num_slots=4, max_seq=64,
                           cfg=ServeConfig(max_new_tokens=5), chunk=CHUNK,
                           devices=2)
    inj = FaultInjector(eng, seed=3)
    for r in reqs:
        eng.submit(r)
    lost = False
    budget = 400
    while eng.step():
        if not lost and inj.inject("device_loss"):
            lost = True
        budget -= 1
        assert budget > 0
    assert lost
    dead = sorted(eng.pool._lost_devices)
    assert len(dead) == 1
    d = dead[0]
    assert any(e[0] == "device_lost" and e[2] == d for e in eng.event_log)
    # the whole device range is quarantined, and its slots retired
    lo = d * eng.pool.blocks_per_device
    assert set(range(lo, lo + eng.pool.blocks_per_device)) <= eng.pool.quarantined
    assert all(eng.pool.device_of(s) != d for s in eng.pool._free_slots)
    _assert_ledger(eng.pool)
    _assert_identity(eng.completions, ref, len(reqs))
