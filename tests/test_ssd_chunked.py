"""Chunked SSD (perf iteration C1) must match the recurrent oracle exactly.

The chunked form is an algebraic regrouping of the same recurrence; agreement
is to float32 accumulation-order tolerance, across chunk sizes, batch/head
shapes, and nonzero initial state (the prefill->decode handoff).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # bare container: deterministic fixed-seed sweeps
    from _hypothesis_fallback import given, settings, st

from repro.models.ssm import _ssd_chunked, _ssd_recurrent


def _rand_inputs(key, b, s, h, dh, n, zero_state=True):
    ks = jax.random.split(key, 6)
    xs = jax.random.normal(ks[0], (b, s, h, dh))
    B = jax.random.normal(ks[1], (b, s, n)) * 0.5
    C = jax.random.normal(ks[2], (b, s, n)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[3], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[4], (h,)) * 0.3)
    decay = jnp.exp(dt * A)
    h0 = (
        jnp.zeros((b, h, dh, n), jnp.float32)
        if zero_state
        else jax.random.normal(ks[5], (b, h, dh, n)).astype(jnp.float32)
    )
    return xs, B, C, dt, decay, h0


@pytest.mark.parametrize("chunk", [2, 4, 8])
def test_matches_recurrent(chunk):
    xs, B, C, dt, decay, h0 = _rand_inputs(jax.random.PRNGKey(0), 2, 16, 3, 4, 5)
    y_r, h_r = _ssd_recurrent(xs, B, C, dt, decay, h0)
    y_c, h_c = _ssd_chunked(xs, B, C, dt, decay, h0, chunk)
    np.testing.assert_allclose(y_c, y_r, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(h_c, h_r, rtol=2e-5, atol=2e-5)


def test_nonzero_initial_state():
    xs, B, C, dt, decay, h0 = _rand_inputs(
        jax.random.PRNGKey(1), 1, 12, 2, 4, 3, zero_state=False
    )
    y_r, h_r = _ssd_recurrent(xs, B, C, dt, decay, h0)
    y_c, h_c = _ssd_chunked(xs, B, C, dt, decay, h0, 4)
    np.testing.assert_allclose(y_c, y_r, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(h_c, h_r, rtol=2e-5, atol=2e-5)


def test_single_chunk_degenerate():
    """chunk == s: pure intra path (+ inter from h0)."""
    xs, B, C, dt, decay, h0 = _rand_inputs(
        jax.random.PRNGKey(2), 1, 8, 2, 3, 4, zero_state=False
    )
    y_r, h_r = _ssd_recurrent(xs, B, C, dt, decay, h0)
    y_c, h_c = _ssd_chunked(xs, B, C, dt, decay, h0, 8)
    np.testing.assert_allclose(y_c, y_r, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(h_c, h_r, rtol=2e-5, atol=2e-5)


def test_gradients_flow():
    xs, B, C, dt, decay, h0 = _rand_inputs(jax.random.PRNGKey(3), 1, 8, 2, 3, 4)

    def loss_c(xs):
        y, _ = _ssd_chunked(xs, B, C, dt, decay, h0, 4)
        return jnp.sum(y**2)

    def loss_r(xs):
        y, _ = _ssd_recurrent(xs, B, C, dt, decay, h0)
        return jnp.sum(y**2)

    g_c = jax.grad(loss_c)(xs)
    g_r = jax.grad(loss_r)(xs)
    np.testing.assert_allclose(g_c, g_r, rtol=5e-4, atol=5e-4)


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 3),
    nc=st.integers(1, 4),
    q=st.sampled_from([2, 4]),
    h=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_chunk_invariance(b, nc, q, h, seed):
    """Output independent of the chunking (property over random shapes)."""
    s = nc * q
    xs, B, C, dt, decay, h0 = _rand_inputs(
        jax.random.PRNGKey(seed), b, s, h, 3, 4, zero_state=(seed % 2 == 0)
    )
    y_r, h_r = _ssd_recurrent(xs, B, C, dt, decay, h0)
    y_c, h_c = _ssd_chunked(xs, B, C, dt, decay, h0, q)
    np.testing.assert_allclose(y_c, y_r, rtol=5e-5, atol=5e-5)
    np.testing.assert_allclose(h_c, h_r, rtol=5e-5, atol=5e-5)
