"""Shared pytest config: the ``slow`` marker and the fast tier-1 selection.

Tier-1 (``PYTHONPATH=src python -m pytest -x -q``) must finish in minutes on
CPU, so tests marked ``@pytest.mark.slow`` are deselected by default; run
them with ``--runslow`` (or ``RUN_SLOW=1``) in scheduled/full CI.  This file
also puts tests/ on sys.path so the hypothesis fallback shim resolves.
"""
from __future__ import annotations

import os
import pathlib
import sys

import pytest

_HERE = str(pathlib.Path(__file__).resolve().parent)
if _HERE not in sys.path:  # robust under --import-mode=importlib too
    sys.path.insert(0, _HERE)


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running test, deselected by default "
        "(enable with --runslow or RUN_SLOW=1)",
    )


def pytest_addoption(parser):
    parser.addoption(
        "--runslow", action="store_true", default=False,
        help="run tests marked slow",
    )


def pytest_collection_modifyitems(config, items):
    if config.getoption("--runslow") or os.environ.get("RUN_SLOW"):
        return
    skip = pytest.mark.skip(reason="slow: pass --runslow (or RUN_SLOW=1)")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip)
