"""Deterministic stand-in for ``hypothesis`` when it isn't installed.

The real library (pinned in requirements-dev.txt) is preferred — install it
with ``pip install -r requirements-dev.txt``.  On bare containers this
fallback keeps the property tests collecting AND running, as fixed-seed
parameter sweeps over the same strategy ranges.  API coverage is exactly
what tests/ uses: ``@settings(max_examples=..., deadline=...)``,
``@given(**strategies)`` and ``st.integers / st.floats / st.sampled_from``.
"""
from __future__ import annotations

import random

_DEFAULT_EXAMPLES = 10
_SEED = 0xC0FFEE


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random):
        return self._draw(rng)


class _Strategies:
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def floats(min_value, max_value):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def sampled_from(options):
        seq = list(options)
        return _Strategy(lambda rng: seq[rng.randrange(len(seq))])

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: bool(rng.randrange(2)))


st = _Strategies()


def given(**strategies):
    """Run the test body over ``max_examples`` fixed-seed draws.  Failures
    surface the drawn values through the normal assertion traceback."""

    def deco(fn):
        def wrapper(*args, **kwargs):
            rng = random.Random(_SEED)
            for _ in range(wrapper._max_examples):
                draws = {k: s.example(rng) for k, s in strategies.items()}
                fn(*args, **draws, **kwargs)

        # NOT functools.wraps: pytest must see the (*args, **kwargs)
        # signature, not the drawn parameters (they'd look like fixtures).
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper._max_examples = _DEFAULT_EXAMPLES
        wrapper._hypothesis_fallback = True
        return wrapper

    return deco


def settings(max_examples: int = _DEFAULT_EXAMPLES, deadline=None, **_ignored):
    def deco(fn):
        if hasattr(fn, "_max_examples"):
            fn._max_examples = max_examples
        return fn

    return deco
