"""Runs under 8 fake host devices (spawned by test_distributed.py).

Checks, on a real (2,4) mesh:
  1. sharded train steps run and decrease loss;
  2. sharded forward == single-device forward (SPMD correctness);
  3. checkpoint saved on (2,4) restores onto (4,2) — elastic re-mesh — and
     training continues bitwise-deterministically;
  4. MoE sharded output == unsharded output.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import store
from repro.configs.registry import get_config, reduce_config
from repro.data.synthetic import DataConfig, batch_at
from repro.models.layers import ParamSpec
from repro.models.transformer import make_model
from repro.parallel.sharding import use_sharding
from repro.train.loop import make_train_step
from repro.train.optimizer import OptimizerConfig, init_opt_state


def shard_tree(model, params, ctx):
    specs = model.param_specs()
    return jax.tree.map(
        lambda p, s: jax.device_put(p, ctx.sharding_for_shape(p.shape, s.logical_axes)),
        params,
        specs,
        is_leaf=lambda x: isinstance(x, ParamSpec) or hasattr(x, "shape"),
    )


def main():
    assert jax.device_count() == 8, jax.device_count()
    arch = sys.argv[1] if len(sys.argv) > 1 else "mixtral-8x22b"
    cfg = reduce_config(get_config(arch))
    model = make_model(cfg)
    data = DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=8)
    opt_cfg = OptimizerConfig(lr=1e-3, warmup_steps=2, total_steps=10)

    params = model.init(jax.random.PRNGKey(0))
    batch = batch_at(data, 0)

    # single-device reference forward
    ref_logits, _ = jax.jit(model.forward)(params, batch)

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    with use_sharding(mesh) as ctx, mesh:
        sharded = shard_tree(model, params, ctx)
        got, _ = jax.jit(model.forward)(sharded, batch)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(ref_logits, np.float32),
            atol=0.05, rtol=0.05,
        )
        print("SPMD forward == single-device forward: OK", flush=True)

        opt_state = init_opt_state(opt_cfg, sharded)
        step_fn = jax.jit(make_train_step(model, opt_cfg), donate_argnums=(0, 1))
        losses = []
        p, o = sharded, opt_state
        for i in range(4):
            p, o, m = step_fn(p, o, batch_at(data, i))
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0], losses
        print(f"sharded training loss {losses[0]:.3f} -> {losses[-1]:.3f}: OK", flush=True)

        ckdir = tempfile.mkdtemp()
        store.save(ckdir, 4, (p, o))

    # elastic: restore the (2,4) checkpoint onto a (4,2) mesh
    mesh2 = jax.make_mesh((4, 2), ("data", "model"))
    with use_sharding(mesh2) as ctx2, mesh2:
        like = (p, o)
        shardings = (
            jax.tree.map(
                lambda s: ctx2.sharding_for_shape(s.shape, s.logical_axes),
                model.param_specs(),
                is_leaf=lambda x: isinstance(x, ParamSpec),
            ),
            jax.tree.map(lambda x: None, o),
        )
        # place opt state with the same shardings as params where shapes match
        (p2, o2), _ = store.restore(ckdir, 4, like)
        p2 = jax.tree.map(lambda a, s: jax.device_put(a, s), p2, shardings[0])
        step_fn2 = jax.jit(make_train_step(model, opt_cfg), donate_argnums=(0, 1))
        p2, o2, m2 = step_fn2(p2, o2, batch_at(data, 4))
        assert np.isfinite(m2["loss"]), m2
        print(f"elastic re-mesh (2,4)->(4,2) restore + step: OK loss={float(m2['loss']):.3f}", flush=True)

    print("ALL_DISTRIBUTED_CHECKS_PASSED")


if __name__ == "__main__":
    main()
