"""Shared assertions for the serving test modules.

The compile-counter contract: slab engines compile each step kind at most
once; paged engines re-trace once per (step kind, horizon bucket actually
seen) — the traced block-table argument is sliced to the tick's bucketed
block horizon, so a new bucket is a new tick shape.  The counters stay
*exact* (CountingJit), bounded by the statically enumerated trace-key
space.  That space lives in ``repro.analysis.tracekeys`` — the same
single source of truth the Pass A ``A-TRACEKEY`` audit checks — so a
drift between the engine, the tests, and the auditor is impossible by
construction.
"""
from repro.analysis import tracekeys


def assert_exact_compile_counters(m: dict) -> None:
    """Pin compile counters to the derived trace-key space, exactly.

    On failure the assert message carries a readable expected-vs-seen
    trace-key table (``format_trace_key_diff``), not just two ints.
    """
    paged = bool(m.get("kv_paged"))
    grid = m.get("horizon_bucket_grid") if paged else None
    expected = tracekeys.trace_key_space(paged=paged, grid=grid)
    seen = tracekeys.seen_trace_keys(m)
    counts = {
        "fused": m["fused_step_compilations"],
        "decode": m["decode_compilations"],
        "prefill": m["prefill_compilations"],
    }
    diff = tracekeys.format_trace_key_diff(expected, seen, counts)

    assert m["prefill_compilations"] == 0, diff
    assert seen <= expected, diff
    if paged:
        # exactly one trace per (step kind, bucket seen), never more than
        # the grid allows
        bound = tracekeys.compile_bound(paged=True, grid=grid)
        assert m["fused_step_compilations"] == len(m["fused_buckets"]), diff
        assert m["decode_compilations"] == len(m["decode_buckets"]), diff
        assert m["fused_step_compilations"] <= bound["fused"], diff
        assert m["decode_compilations"] <= bound["decode"], diff
        assert m["horizon_buckets"] == sorted(
            set(m["fused_buckets"]) | set(m["decode_buckets"])
        ), diff
    else:
        assert m["fused_step_compilations"] == (1 if m["fused_ticks"] else 0), diff
        assert m["decode_compilations"] in (0, 1), diff
    assert_transfer_guarded(m)


def assert_transfer_guarded(m: dict) -> None:
    """Every engine step dispatched its tick under
    ``transfer_guard_host_to_device('disallow')``.

    ``transfer_guarded_ticks`` increments once per guarded jitted-tick
    dispatch and ``decode_steps`` once per engine step, so equality means
    no step slipped past the guard.
    """
    assert m["transfer_guarded_ticks"] == m["decode_steps"], (
        f"transfer_guarded_ticks={m['transfer_guarded_ticks']} != "
        f"decode_steps={m['decode_steps']}: some tick dispatched outside "
        "transfer_guard_host_to_device('disallow')"
    )
