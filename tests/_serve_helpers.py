"""Shared assertions for the serving test modules.

The compile-counter contract changed shape in the horizon-bucketing PR:
slab engines still compile each step at most once, but paged engines now
re-trace once per (step kind, horizon bucket actually seen) — the traced
block-table argument is sliced to the tick's bucketed block horizon, so a
new bucket is a new tick shape.  The counters stay *exact* (CountingJit),
just bounded by the bucket grid instead of pinned to 1; this helper is the
single place that bound is written down.
"""


def assert_exact_compile_counters(m: dict) -> None:
    assert m["prefill_compilations"] == 0
    if m.get("kv_paged"):
        grid = m["horizon_bucket_grid"]
        # exactly one trace per (step kind, bucket seen), never more than
        # the grid allows
        assert m["fused_step_compilations"] == len(m["fused_buckets"])
        assert m["decode_compilations"] == len(m["decode_buckets"])
        assert len(m["fused_buckets"]) <= len(grid)
        assert len(m["decode_buckets"]) <= len(grid)
        assert set(m["horizon_buckets"]) <= set(grid)
        assert m["horizon_buckets"] == sorted(
            set(m["fused_buckets"]) | set(m["decode_buckets"])
        )
    else:
        assert m["fused_step_compilations"] == (1 if m["fused_ticks"] else 0)
        assert m["decode_compilations"] in (0, 1)
