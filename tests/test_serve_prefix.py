"""Prefix-sharing serving tests (PR 6).

Pinned invariants:
  1. greedy continuous batching with the radix prefix cache ON is
     token-identical to the unshared static oracle for dense and MLA,
     across block sizes {chunk, 2*chunk} and all three paged read paths
     (pallas / streamed / gathered) — sharing prompt-position KV computed
     by the same jitted prefill at the same positions is exact by
     construction, and the GN guarantee (masked scores -> exactly-zero
     numerators with sum p = 1) makes a shared block readable through any
     slot's table;
  2. copy-on-write: a partially-matched shared block is forked into a
     private block at attach time, bitwise-identical to its source across
     every arena leaf, and ``write_barrier`` never observes a live slot
     about to write a refcount>1 block;
  3. refcounted recycling: a block returns to its device's FIFO free list
     only at refcount zero (owner + sharers + cache index each hold one);
     under block pressure the pool reclaims LRU cache-only chains
     leaf-first, so surviving chains stay matchable;
  4. admission charges only the *unshared* tail: a request sharing k
     cached blocks reserves blocks_for(footprint) - k, so it can be
     admitted into headroom that could never fit its full footprint —
     while the donor is still live;
  5. compile counters stay exact: one trace per (step kind, horizon
     bucket), prefill=0 — attach/fork/skip-prefill must not retrace;
  6. ``ensure`` growth and COW forks preserve the rest of the arena and
     all live block tables bit-identically;
  7. a reset engine replays the workload with identical tokens AND an
     identical hit/fork/evict sequence (the LRU clock is an op counter,
     never wall time).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config, reduce_config
from repro.data.synthetic import DataConfig, batch_at
from repro.models import attention as attention_mod
from repro.models.transformer import make_model
from repro.serve.engine import ContinuousEngine, ServeConfig, static_reference
from repro.serve.kv_cache import BlockPagedKVPool
from repro.serve.prefix_cache import PrefixCache
from repro.serve.scheduler import Request
from repro.serve.workload import required_max_seq, shared_prefix_requests

from _serve_helpers import assert_exact_compile_counters

CHUNK = 4
TWO_DEV = jax.device_count() >= 2
requires_mesh = pytest.mark.skipif(
    not TWO_DEV,
    reason="needs >= 2 devices "
    "(export XLA_FLAGS=--xla_force_host_platform_device_count=2)",
)


@pytest.fixture(scope="module")
def dense():
    cfg = reduce_config(get_config("internlm2-1.8b"))
    model = make_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def mla():
    cfg = reduce_config(get_config("minicpm3-4b"))
    model = make_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


def _prompt(cfg, length, seed):
    data = DataConfig(vocab=cfg.vocab, seq_len=length, global_batch=1, seed=seed)
    return np.asarray(batch_at(data, 0)["tokens"][0], np.int32)


def _shared_reqs(cfg, **kw):
    # system+persona = 18 tokens: block-misaligned for both block sizes
    # {4, 8}, so later arrivals fork the donor's partial block (COW path)
    kw.setdefault("n_users", 6)
    kw.setdefault("n_personas", 2)
    kw.setdefault("system_len", 12)
    kw.setdefault("persona_len", 6)
    kw.setdefault("user_len", 5)
    kw.setdefault("max_new_tokens", 4)
    # prompts pad to 24 -> 6 prefill ticks at chunk 4; stagger past that so
    # every later arrival sees the donor's phase-flip insert already indexed
    kw.setdefault("stagger", 7)
    return shared_prefix_requests(cfg, **kw)


def _run_prefix_engine(model, params, reqs, block_size, roomy=True, **kw):
    """Prefix-cache engine over ``reqs``.  ``roomy`` doubles the
    slab-equivalent arena so cached chains survive next to full
    reservations (the default arena is exactly num_slots full footprints —
    zero headroom, constant eviction; that regime gets its own test)."""
    num_slots = kw.pop("num_slots", 2)
    max_seq = required_max_seq(reqs)
    if roomy and "num_blocks" not in kw:
        kw["num_blocks"] = 2 * num_slots * -(-max_seq // block_size)
    engine = ContinuousEngine(
        model, params, num_slots=num_slots, max_seq=max_seq,
        cfg=ServeConfig(), chunk=CHUNK, block_size=block_size,
        prefix_cache=True, **kw,
    )
    comps = engine.run(reqs)
    return engine, comps


# ----------------------------------------- greedy identity, cache ON -------
@pytest.mark.parametrize("block_size", [CHUNK, 2 * CHUNK])
@pytest.mark.parametrize("family", ["dense", "mla"])
def test_prefix_identity_vs_unshared_oracle(dense, mla, family, block_size):
    cfg, model, params = dense if family == "dense" else mla
    reqs = _shared_reqs(cfg)
    engine, comps = _run_prefix_engine(model, params, reqs, block_size)
    ref = static_reference(model, params, reqs, ServeConfig())
    assert len(comps) == len(reqs)
    for c in comps:
        assert np.array_equal(c.tokens, ref[c.request_id]), f"req {c.request_id}"
    m = engine.metrics()
    assert m["prefix_cache"] is True
    # every request after the first shares at least the system prompt
    assert m["prefix_hit_requests"] == len(reqs) - 1
    assert m["prefix_hit_rate"] > 0
    # 18 % block_size != 0 for both sizes -> the persona boundary sits
    # mid-block and COW forks must have fired
    assert m["prefix_forks"] > 0
    assert_exact_compile_counters(m)
    # drained: slots are free, but the cache retains its indexed chains
    assert engine.pool.num_free == engine.pool.num_slots
    assert engine.pool.blocks_in_use == engine.pool.cached_blocks > 0
    held = np.flatnonzero(np.asarray(engine.pool.refcounts))
    assert (np.asarray(engine.pool.refcounts)[held] == 1).all()


@pytest.mark.parametrize("path", ["streamed", "gathered", "pallas"])
def test_prefix_identity_across_read_paths(dense, path):
    """Sharing must be exact through every paged read: the Pallas kernel,
    the gather-free streamed tiles, and the gathered full-stream oracle all
    walk the same block tables the prefix cache populated."""
    cfg, model, params = dense
    reqs = _shared_reqs(cfg)
    ref = static_reference(model, params, reqs, ServeConfig())
    attention_mod.FORCE_PAGED_READ = path
    try:
        engine, comps = _run_prefix_engine(model, params, reqs, CHUNK)
        assert engine.metrics()["read_path"] == path
    finally:
        attention_mod.FORCE_PAGED_READ = None
    for c in comps:
        assert np.array_equal(c.tokens, ref[c.request_id]), f"req {c.request_id}"
    assert engine.metrics()["prefix_hit_requests"] == len(reqs) - 1


def test_tight_arena_identity_under_eviction_churn(dense):
    """Regression for the subtree-cut eviction fallback: the slab-equivalent
    arena is exactly two full-footprint reservations, so every cached chain
    must be evicted to readmit — and a live slot's phase-flip insert pins
    descendants under refcount-1 ancestors, which leaf-first eviction alone
    can never reclaim (admission used to promise supply that ``ensure``
    then couldn't get, dying in ``_pop_block``)."""
    cfg, model, params = dense
    reqs = _shared_reqs(cfg, stagger=3)  # the original failing arrival mix
    engine, comps = _run_prefix_engine(model, params, reqs, CHUNK, roomy=False)
    ref = static_reference(model, params, reqs, ServeConfig())
    for c in comps:
        assert np.array_equal(c.tokens, ref[c.request_id]), f"req {c.request_id}"
    m = engine.metrics()
    # slab-equivalent arena == num_slots full footprints: readmission runs
    # the cache out of headroom, so the eviction path really fired
    assert m["num_blocks"] == 2 * engine.pool.max_blocks_per_slot
    assert m["prefix_evictions"] > 0
    assert_exact_compile_counters(m)


# ------------------------------------------------------ COW fork at attach --
def test_cow_fork_on_divergent_tail(dense):
    """Two requests share a block-misaligned 13-token prefix and then
    diverge: the second must fork the donor's partial block (never write
    it), produce oracle-identical tokens, and leave the donor's cached
    chain readable for a third, fully-matching request."""
    cfg, model, params = dense
    base = _prompt(cfg, 16, seed=900)
    div = base.copy()
    div[13:] = (div[13:] + 1) % cfg.vocab  # diverge mid-block (13 % 4 != 0)
    reqs = [
        Request(id=0, tokens=base, max_new_tokens=4, arrival_step=0),
        Request(id=1, tokens=div, max_new_tokens=4, arrival_step=20),
        Request(id=2, tokens=base.copy(), max_new_tokens=4, arrival_step=40),
    ]
    engine, comps = _run_prefix_engine(model, params, reqs, CHUNK)
    ref = static_reference(model, params, reqs, ServeConfig())
    for c in comps:
        assert np.array_equal(c.tokens, ref[c.request_id]), f"req {c.request_id}"
    hits = engine.request_prefix_hits
    assert 0 not in hits  # the donor paid the full prefill
    assert hits[1]["tokens"] == 13 and hits[1]["forked"] is True
    # req 2 matches the full cached prompt, capped at prompt_len - 1 = 15:
    # 3 full blocks + a forked tail (the donor's finish indexed tokens 12:16)
    assert hits[2]["tokens"] == 15 and hits[2]["forked"] is True
    assert engine.metrics()["prefix_forks"] == 2


def test_fork_copies_block_bitwise_and_preserves_arena(dense):
    """Pool-level invariant 6: ``ensure`` growth and an attach-time COW fork
    touch ONLY the destination block — every other arena block and every
    live block table is bit-identical before/after — and the forked block
    is a bitwise copy of its source."""
    _, model, _ = dense
    pool = BlockPagedKVPool(model, num_slots=3, max_seq=32, block_size=4,
                            num_blocks=12)
    pool.attach_prefix_cache(PrefixCache(4))
    # deterministic, per-position-distinct arena contents
    pool.cache = dict(pool.cache)
    pool.cache["layers"] = jax.tree.map(
        lambda l: jnp.arange(l.size, dtype=jnp.float32).reshape(l.shape)
        .astype(l.dtype),
        pool.cache["layers"],
    )
    leaves0 = [np.asarray(l) for l in jax.tree.leaves(pool.cache["layers"])]

    s0 = pool.allocate(reserve_tokens=16)
    pool.ensure(s0, 16)
    chain0 = pool.chain_of(s0)
    table0 = pool.tables[s0].copy()
    # growth for another slot must not disturb s0's arena blocks or table
    s1 = pool.allocate(reserve_tokens=8)
    pool.ensure(s1, 8)
    for a, b in zip(leaves0, jax.tree.leaves(pool.cache["layers"])):
        assert np.array_equal(a, np.asarray(b))  # ensure() is host-side only
    assert pool.chain_of(s0) == chain0
    assert np.array_equal(pool.tables[s0], table0)

    # index a 14-token prompt (3 full blocks + 2-token tail), drop the owner
    tokens = _prompt(model.cfg, 14, seed=901)
    pool.prefix_cache.insert(tokens, chain0[:4], 0)
    pool.free(s0)
    pool.free(s1)
    hit = pool.prefix_cache.lookup(tokens)
    assert hit.shared_len == 14 and hit.tail_src == chain0[3]

    s2 = pool.allocate(reserve_tokens=16, prefix=hit)
    pool.attach_prefix(s2, hit)
    assert pool.prefix_forks == 1
    dst = pool.chain_of(s2)[3]
    assert dst != hit.tail_src
    for before, leaf in zip(leaves0, jax.tree.leaves(pool.cache["layers"])):
        after = np.asarray(leaf)
        # the forked block is a bitwise copy of its source...
        assert np.array_equal(after[:, dst], before[:, hit.tail_src])
        # ...and every other block is untouched
        mask = np.ones(after.shape[1], bool)
        mask[dst] = False
        assert np.array_equal(after[:, mask], before[:, mask])
    # the write barrier accepts the private fork and rejects shared blocks
    pool.write_barrier(s2, 14)  # next write -> block idx 3 (the fork): ok
    with pytest.raises(RuntimeError, match="COW violation"):
        pool.write_barrier(s2, 8)  # block idx 2 is shared (refcount 2)


# ------------------------------------------- refcounts, recycle, eviction --
def test_refcount_recycle_and_lru_eviction(dense):
    _, model, _ = dense
    pool = BlockPagedKVPool(model, num_slots=3, max_seq=32, block_size=4,
                            num_blocks=8)
    cache = PrefixCache(4)
    pool.attach_prefix_cache(cache)
    tokens = _prompt(model.cfg, 16, seed=902)

    s0 = pool.allocate(reserve_tokens=16)
    pool.ensure(s0, 16)
    chain = pool.chain_of(s0)
    assert all(pool.refcounts[b] == 1 for b in chain)
    cache.insert(tokens, chain, 0)
    assert all(pool.refcounts[b] == 2 for b in chain)
    assert cache.cached_blocks() == 4

    # owner finishes: blocks stay resident (cache ref), none recycle
    pool.free(s0)
    assert all(pool.refcounts[b] == 1 for b in chain)
    assert pool.blocks_in_use == 4 and pool.free_blocks_on(0) == 4

    # a sharer attaches (+1), then finishes (-1): still cached, never freed
    hit = cache.lookup(tokens)
    assert hit.blocks == chain and hit.shared_len == 16 and hit.tail_src is None
    s1 = pool.allocate(reserve_tokens=20, prefix=hit)
    pool.attach_prefix(s1, hit)
    assert all(pool.refcounts[b] == 2 for b in chain)
    pool.ensure(s1, 20)  # pops exactly the 1 unshared block
    assert pool.chain_of(s1)[:4] == chain and len(pool.chain_of(s1)) == 5
    pool.free(s1)
    assert all(pool.refcounts[b] == 1 for b in chain)
    assert pool.blocks_in_use == 4

    # block pressure: a 24-token request needs 6 blocks, only 4 are free ->
    # _pop_block reclaims LRU cache-only blocks leaf-first (deepest chain
    # node first), and the surviving prefix stays matchable
    s2 = pool.allocate(reserve_tokens=24)
    pool.ensure(s2, 24)
    assert pool.prefix_evictions == 2 and cache.evictions == 2
    assert cache.cached_blocks() == 2
    surviving = cache.lookup(tokens, touch=False)
    assert surviving.shared_len == 8 and surviving.blocks == chain[:2]
    pool.free(s2)
    assert pool.blocks_in_use == 2  # only the surviving cached chain
    held = np.flatnonzero(np.asarray(pool.refcounts))
    assert sorted(held.tolist()) == sorted(chain[:2])


def test_admission_charges_only_unshared_tail(dense):
    """Invariant 4, while the donor is still LIVE (nothing evictable): a
    24-token footprint needs 6 blocks but only 2 are free — admission is
    possible only because 4 of them attach from the cache."""
    _, model, _ = dense
    pool = BlockPagedKVPool(model, num_slots=3, max_seq=32, block_size=4,
                            num_blocks=6)
    cache = PrefixCache(4)
    pool.attach_prefix_cache(cache)
    tokens = _prompt(model.cfg, 16, seed=903)

    s0 = pool.allocate(reserve_tokens=16)
    pool.ensure(s0, 16)
    chain = pool.chain_of(s0)
    cache.insert(tokens, chain, 0)  # donor live: refcounts 2, evictable 0
    assert pool.free_blocks_on(0) == 2
    assert cache.evictable_count(0, pool.refcounts) == 0

    hit = cache.lookup(tokens)
    assert not pool.can_reserve(24, 0)              # full charge: 6 > 2
    assert pool.can_reserve(24, 0, prefix=hit)      # tail charge: 2 <= 2
    with pytest.raises(RuntimeError, match="exhausted"):
        pool.allocate(reserve_tokens=24)
    s1 = pool.allocate(reserve_tokens=24, prefix=hit)
    assert int(pool._reserved[s1]) == 2             # blocks_for(24) - 4
    pool.attach_prefix(s1, hit)
    pool.ensure(s1, 24)
    assert int(pool._owned[s1]) == 2 and int(pool._shared[s1]) == 4
    assert pool.unfilled_on(0) == 0
    assert all(pool.refcounts[b] == 3 for b in chain)  # owner+sharer+cache
    pool.free(s0)
    pool.free(s1)
    assert pool.blocks_in_use == 4  # the cached chain survives the drain


def test_radix_cap_and_stampfree_hint(dense):
    """``lookup(cap=plen-1)`` always leaves >= 1 token to prefill (the
    sampled logits must come from the request's own final prompt position),
    and the scheduler's ``match_len`` hint never touches LRU stamps or
    hit/miss stats."""
    _, model, _ = dense
    pool = BlockPagedKVPool(model, num_slots=2, max_seq=32, block_size=4,
                            num_blocks=8)
    cache = PrefixCache(4)
    pool.attach_prefix_cache(cache)
    tokens = _prompt(model.cfg, 16, seed=904)
    s0 = pool.allocate(reserve_tokens=16)
    pool.ensure(s0, 16)
    cache.insert(tokens, pool.chain_of(s0), 0)

    h0, m0, clock0 = cache.hits, cache.misses, cache._clock
    assert cache.match_len(tokens) == 16
    assert (cache.hits, cache.misses, cache._clock) == (h0, m0, clock0)

    hit = cache.lookup(tokens, cap=15)
    assert hit.shared_len == 15  # 3 full blocks + 3 tokens forked from #4
    assert hit.tail_src == pool.chain_of(s0)[3]
    assert cache.hits == h0 + 1 and cache._clock > clock0


# ----------------------------------------------------- replay determinism --
def test_reset_replays_identical_hits_and_tokens(dense):
    cfg, model, params = dense
    reqs = _shared_reqs(cfg)
    engine, comps = _run_prefix_engine(model, params, reqs, CHUNK)

    def signature(engine, comps):
        m = engine.metrics()
        return (
            {c.request_id: c.tokens.tolist() for c in comps},
            m["prefix_hit_tokens"], m["prefix_forks"], m["prefix_evictions"],
            m["prefix_inserts"], dict(engine.request_prefix_hits),
        )

    first = signature(engine, comps)
    engine.reset()
    assert engine.pool.blocks_in_use == 0  # reset clears the radix cache too
    assert engine.prefix.cached_blocks() == 0
    second = signature(engine, engine.run(reqs))
    assert first == second


# ------------------------------------------------------------ device mesh --
@requires_mesh
def test_sharded_prefix_identity_and_locality(dense):
    """2-device engine, prefix cache ON: oracle-identical tokens, exact
    compile counters, and every hit is device-local (a slot only attaches
    chains from its own device's radix tree)."""
    cfg, model, params = dense
    reqs = _shared_reqs(cfg, n_users=8)
    engine, comps = _run_prefix_engine(model, params, reqs, CHUNK,
                                       num_slots=4, devices=2)
    ref = static_reference(model, params, reqs, ServeConfig())
    for c in comps:
        assert np.array_equal(c.tokens, ref[c.request_id]), f"req {c.request_id}"
    m = engine.metrics()
    assert m["num_devices"] == 2
    assert m["prefix_hit_requests"] > 0
    assert_exact_compile_counters(m)
    assert engine.prefix.num_devices == 2
    bpd = engine.pool.blocks_per_device
    for rid, h in engine.request_prefix_hits.items():
        assert h["device"] in (0, 1), rid
    # each device's radix tree only indexes its own block range
    for d in range(2):
        for node in engine.prefix._iter_nodes(d):
            for b in ([node.block] if node.block is not None else []) + (
                [node.tail[2]] if node.tail is not None else []
            ):
                assert d * bpd <= b < (d + 1) * bpd
