"""Crash-consistent engine snapshot tests (PR 10).

Pinned invariants:
  1. kill-at-every-tick: a writer engine snapshots after EVERY step; for
     each snapshot, a restored engine drains to completions bitwise
     identical (tokens, finish reasons, event log, counters) to the
     writer's — greedy resume is exact no matter where the crash lands;
  2. the matrix holds across dense + MLA, slab + paged pools, fp + int8
     arenas, and (when >= 2 devices) the sharded paged engine;
  3. snapshots capture fault-tolerance state: quarantined blocks stay
     quarantined through restore and the ledger reconciles;
  4. restore refuses a topology mismatch (wrong arch/slots/pool shape)
     instead of silently corrupting, and snapshot refuses an attached
     prefix cache (the radix index is not serialized);
  5. saves are atomic: a torn tmp dir from a killed save never shadows
     the latest durable snapshot.
"""
import jax
import numpy as np
import pytest

from repro.configs.registry import get_config, reduce_config
from repro.models.transformer import make_model
from repro.serve.engine import ContinuousEngine, ServeConfig, static_reference
from repro.serve.faults import FaultInjector
from repro.serve.scheduler import Request

CHUNK = 4
TWO_DEV = jax.device_count() >= 2
requires_mesh = pytest.mark.skipif(
    not TWO_DEV,
    reason="needs >= 2 devices "
    "(export XLA_FLAGS=--xla_force_host_platform_device_count=2)",
)


@pytest.fixture(scope="module")
def dense():
    cfg = reduce_config(get_config("internlm2-1.8b"))
    model = make_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def mla():
    cfg = reduce_config(get_config("minicpm3-4b"))
    model = make_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


def _requests(cfg, lens=(5, 9, 7), max_new=5, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(tokens=rng.integers(0, cfg.vocab, size=n).astype(np.int32),
                    max_new_tokens=max_new) for n in lens]


def _fingerprint(eng):
    return (
        [(c.request_id, tuple(int(t) for t in c.prompt_tokens),
          tuple(int(t) for t in c.new_tokens), c.finish_reason,
          c.finish_step, c.preemptions) for c in eng.completions],
        eng.event_log,
        eng.step_count,
    )


def _run_and_snapshot_everywhere(make_engine, reqs, path):
    """Writer: snapshot after every tick; returns its final fingerprint
    and the list of snapshotted steps."""
    writer = make_engine()
    for r in reqs:
        writer.submit(r)
    steps = []
    while writer.step():
        writer.snapshot(path)
        steps.append(writer.step_count)
    return _fingerprint(writer), steps


def _drain_from(restorer, path, step):
    restorer.restore(path, step=step)
    while restorer.step():
        pass
    return _fingerprint(restorer)


@pytest.mark.parametrize("family,paged,kv_dtype", [
    ("dense", True, "fp"),
    ("dense", True, "int8"),
    ("dense", False, "fp"),
    ("mla", True, "fp"),
])
def test_kill_at_every_tick_resumes_identically(family, paged, kv_dtype,
                                                dense, mla, request,
                                                tmp_path):
    """The headline guarantee: no matter which tick the engine dies on,
    restoring the last snapshot reproduces the exact remaining run —
    completions, finish metadata, and the event log all bitwise equal."""
    cfg, model, params = request.getfixturevalue(family)
    reqs = _requests(cfg)

    def make_engine():
        kw = dict(cfg=ServeConfig(max_new_tokens=5), chunk=CHUNK)
        if paged:
            kw["kv_dtype"] = kv_dtype
        else:
            kw["paged"] = False
        return ContinuousEngine(model, params, num_slots=2, max_seq=64, **kw)

    want, steps = _run_and_snapshot_everywhere(
        make_engine, reqs, tmp_path / "snap")
    assert len(steps) >= 5
    restorer = make_engine()  # ONE restorer: jit caches amortize the sweep
    for step in steps:
        got = _drain_from(restorer, tmp_path / "snap", step)
        assert got == want, f"divergence restoring from tick {step}"


def test_restore_latest_and_oracle_identity(dense, tmp_path):
    """restore() without a step picks the newest snapshot, and the resumed
    output equals the static oracle (not merely the writer): resume is
    correct, not just self-consistent."""
    cfg, model, params = dense
    reqs = _requests(cfg)
    refs = [Request(tokens=r.tokens, max_new_tokens=5, id=i)
            for i, r in enumerate(reqs)]
    ref = static_reference(model, params, refs, ServeConfig(max_new_tokens=5))
    eng = ContinuousEngine(model, params, num_slots=2, max_seq=64,
                           cfg=ServeConfig(max_new_tokens=5), chunk=CHUNK)
    for r in reqs:
        eng.submit(r)
    for _ in range(4):
        eng.step()
    eng.snapshot(tmp_path / "snap")
    fresh = ContinuousEngine(model, params, num_slots=2, max_seq=64,
                             cfg=ServeConfig(max_new_tokens=5), chunk=CHUNK)
    fresh.restore(tmp_path / "snap")
    while fresh.step():
        pass
    assert len(fresh.completions) == len(reqs)
    for c in fresh.completions:
        got = [int(t) for t in c.new_tokens]
        want = [int(t) for t in ref[c.request_id][len(c.prompt_tokens):]]
        assert got == want


def test_snapshot_preserves_quarantine_and_ledger(dense, tmp_path):
    """Fault state survives the crash: quarantined blocks restore as
    quarantined (never recycled by the resumed engine) and the ledger
    reconciles immediately after restore."""
    cfg, model, params = dense
    reqs = _requests(cfg, lens=(5, 9, 7, 6), max_new=4)
    eng = ContinuousEngine(model, params, num_slots=2, max_seq=64,
                           cfg=ServeConfig(max_new_tokens=4), chunk=CHUNK)
    inj = FaultInjector(eng, seed=1)
    for r in reqs:
        eng.submit(r)
    injected = 0
    while not eng.pool.quarantined:
        assert eng.step(), "drained before any quarantine happened"
        if injected < 3 and inj.inject("nan_tile"):
            injected += 1
    eng.snapshot(tmp_path / "snap")
    quarantined = set(eng.pool.quarantined)
    retries = dict(eng._fault_retries)
    fresh = ContinuousEngine(model, params, num_slots=2, max_seq=64,
                             cfg=ServeConfig(max_new_tokens=4), chunk=CHUNK)
    fresh.restore(tmp_path / "snap")
    assert fresh.pool.quarantined == quarantined
    assert fresh._fault_retries == retries
    fresh.pool.check_ledger()
    while fresh.step():
        fresh.pool.check_ledger()
        assert quarantined <= fresh.pool.quarantined
    assert len(fresh.completions) == len(reqs)


def test_restore_refuses_topology_mismatch(dense, tmp_path):
    cfg, model, params = dense
    eng = ContinuousEngine(model, params, num_slots=2, max_seq=64,
                           cfg=ServeConfig(max_new_tokens=4), chunk=CHUNK)
    for r in _requests(cfg, lens=(5,), max_new=4):
        eng.submit(r)
    eng.step()
    eng.snapshot(tmp_path / "snap")
    other = ContinuousEngine(model, params, num_slots=4, max_seq=64,
                             cfg=ServeConfig(max_new_tokens=4), chunk=CHUNK)
    with pytest.raises(ValueError, match="topology"):
        other.restore(tmp_path / "snap")


def test_snapshot_refuses_prefix_cache(dense, tmp_path):
    cfg, model, params = dense
    eng = ContinuousEngine(model, params, num_slots=2, max_seq=64,
                           cfg=ServeConfig(max_new_tokens=4), chunk=CHUNK,
                           prefix_cache=True)
    with pytest.raises(ValueError, match="prefix"):
        eng.snapshot(tmp_path / "snap")


def test_restore_missing_snapshot_raises(dense, tmp_path):
    cfg, model, params = dense
    eng = ContinuousEngine(model, params, num_slots=2, max_seq=64,
                           cfg=ServeConfig(max_new_tokens=4), chunk=CHUNK)
    with pytest.raises(FileNotFoundError):
        eng.restore(tmp_path / "nowhere")


def test_torn_save_never_shadows_latest(dense, tmp_path):
    """Atomicity: a stale tmp dir (a save killed mid-write) is invisible
    to latest_step and pruned by the next successful save."""
    from repro.checkpoint import store
    cfg, model, params = dense
    eng = ContinuousEngine(model, params, num_slots=2, max_seq=64,
                           cfg=ServeConfig(max_new_tokens=4), chunk=CHUNK)
    for r in _requests(cfg, lens=(5,), max_new=4):
        eng.submit(r)
    eng.step()
    eng.snapshot(tmp_path / "snap")
    good = store.latest_step(tmp_path / "snap")
    torn = tmp_path / "snap" / f".tmp_step_{good + 1:08d}"
    torn.mkdir()
    (torn / "arrays.npz").write_bytes(b"torn")
    assert store.latest_step(tmp_path / "snap") == good
    eng.step()
    eng.snapshot(tmp_path / "snap")
    assert not torn.exists()  # pruned by the atomic save
    assert store.latest_step(tmp_path / "snap") > good


@requires_mesh
def test_sharded_snapshot_resumes_identically(dense, tmp_path):
    """2-device paged engine: snapshot mid-run, restore into a fresh
    2-device engine, drain both — identical completions (arena leaves are
    re-placed under their original shardings on restore)."""
    cfg, model, params = dense
    reqs = _requests(cfg, lens=(5, 9, 7, 6, 8, 5), max_new=4)

    def make_engine():
        return ContinuousEngine(model, params, num_slots=4, max_seq=64,
                                cfg=ServeConfig(max_new_tokens=4),
                                chunk=CHUNK, devices=2)

    want, steps = _run_and_snapshot_everywhere(
        make_engine, reqs, tmp_path / "snap")
    restorer = make_engine()
    # sample the sweep: first, one mid-run, and the final tick
    for step in {steps[0], steps[len(steps) // 2], steps[-1]}:
        got = _drain_from(restorer, tmp_path / "snap", step)
        assert got == want, f"divergence restoring from tick {step}"
