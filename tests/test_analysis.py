"""Tests for repro.analysis — Pass A auditor, Pass B lint, CLI contract.

Default tier: every rule's known-bad fixture must flag and its known-good
twin must pass, the gather-free + donation audits run end-to-end on one
dense-KV and one MLA arch, and the CLI exit-code contract holds
(``--break-invariant RULE`` → non-zero with that rule id).  The
full-registry audit is slow-marked (CI runs it as the dedicated
``analysis`` job via ``python -m repro.analysis --all``).
"""
from __future__ import annotations

import json

import pytest

from repro.analysis import lint as lint_mod
from repro.analysis import tracekeys
from repro.analysis.__main__ import main as cli_main
from repro.analysis.fixtures import AUDIT_FIXTURES
from repro.analysis.rules import ALL_RULES, AUDIT_RULES, LINT_RULES
from repro.configs.registry import list_archs

DENSE_ARCH = "internlm2-1.8b"
MLA_ARCH = "minicpm3-4b"


# ---------------------------------------------------------------- rules ---
def test_every_rule_registered_with_fixture():
    assert set(ALL_RULES) == set(AUDIT_RULES) | set(LINT_RULES)
    for rule in LINT_RULES.values():
        assert rule.bad_fixture and rule.good_fixture, rule.id
    assert set(AUDIT_FIXTURES) == set(AUDIT_RULES)


@pytest.mark.parametrize("rule_id", sorted(LINT_RULES))
def test_lint_rule_flags_bad_and_passes_good(rule_id):
    rule = LINT_RULES[rule_id]
    bad = lint_mod.lint_source(rule.bad_fixture, f"{rule_id}:bad")
    good = lint_mod.lint_source(rule.good_fixture, f"{rule_id}:good")
    assert any(f.rule == rule_id for f in bad), f"{rule_id} is blind"
    assert not any(f.rule == rule_id for f in good), f"{rule_id} false-positives"


@pytest.mark.parametrize("rule_id", sorted(AUDIT_FIXTURES))
def test_audit_rule_flags_bad_and_passes_good(rule_id):
    bad_fn, good_fn = AUDIT_FIXTURES[rule_id]
    bad, good = bad_fn(), good_fn()
    assert any(f.rule == rule_id for f in bad), f"{rule_id} is blind"
    assert good == [], f"{rule_id} false-positives: {[f.format() for f in good]}"


# ----------------------------------------------------------- trace keys ---
def test_horizon_bucket_grid_matches_engine_rule():
    # doubles from 1, capacity always the final bucket
    assert tracekeys.horizon_bucket_grid(16, 4) == [1, 2, 4]
    assert tracekeys.horizon_bucket_grid(24, 4) == [1, 2, 4, 6]
    assert tracekeys.horizon_bucket_grid(4, 4) == [1]


def test_trace_key_space_and_bound():
    keys = tracekeys.trace_key_space(paged=True, max_seq=16, block_size=4)
    assert keys == {(k, b) for k in ("fused", "decode") for b in (1, 2, 4)}
    assert tracekeys.compile_bound(paged=True, grid=[1, 2, 4]) == {
        "fused": 3, "decode": 3,
    }
    assert tracekeys.trace_key_space(paged=False) == {
        ("fused", None), ("decode", None),
    }


def test_format_trace_key_diff_shows_extra_keys():
    expected = {("fused", 1), ("decode", 1)}
    seen = {("fused", 1), ("fused", 8)}
    txt = tracekeys.format_trace_key_diff(expected, seen, {"fused": 2})
    assert "EXTRA" in txt and "bucket=8" in txt and "fused=2" in txt


# ------------------------------------------------- end-to-end arch audit --
@pytest.mark.parametrize("arch", [DENSE_ARCH, MLA_ARCH])
def test_audit_arch_gather_free_and_donated(arch):
    from repro.analysis.audit import audit_arch

    findings = audit_arch(arch, tier="default", compile_donation=True)
    assert findings == [], "\n".join(f.format() for f in findings)


# ------------------------------------------------------------------ CLI ---
def test_cli_lint_repo_clean(capsys):
    assert cli_main(["--lint"]) == 0
    out = capsys.readouterr().out
    assert "ok=True" in out


def test_cli_self_check_green():
    assert cli_main(["--self-check"]) == 0


@pytest.mark.parametrize("rule_id", sorted(ALL_RULES))
def test_cli_break_invariant_nonzero_with_rule_id(rule_id, capsys):
    rc = cli_main(["--break-invariant", rule_id])
    out = capsys.readouterr().out
    assert rc != 0, f"{rule_id}: breaking the invariant must fail the gate"
    assert rule_id in out


def test_cli_json_report(tmp_path, capsys):
    path = tmp_path / "report.json"
    assert cli_main(["--self-check", "--json", str(path)]) == 0
    capsys.readouterr()
    d = json.loads(path.read_text())
    assert d["ok"] is True
    assert set(d["self_check"]) == set(ALL_RULES)


def test_cli_unknown_rule_errors():
    with pytest.raises(SystemExit):
        cli_main(["--break-invariant", "NO-SUCH-RULE"])


# ------------------------------------------------------------ full gate ---
@pytest.mark.slow
def test_cli_all_full_registry():
    # the CI `analysis` job: audit every registry arch + lint + self-check
    assert cli_main(["--all"]) == 0


@pytest.mark.slow
@pytest.mark.parametrize("arch", sorted(set(list_archs()) - {DENSE_ARCH, MLA_ARCH}))
def test_audit_arch_rest_of_registry(arch):
    from repro.analysis.audit import audit_arch

    findings = audit_arch(arch, tier="full", compile_donation=True)
    assert findings == [], "\n".join(f.format() for f in findings)
