"""Gather/scatter MoE dispatch (perf A3) == the GShard one-hot einsum oracle.

The two paths implement the same routing function (same router, same
capacity/dropping semantics) with different data movement; outputs, aux
losses, and gradients must agree.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # bare container: deterministic fixed-seed sweeps
    from _hypothesis_fallback import given, settings, st

from repro.configs.base import MoEConfig, ModelConfig
from repro.models.layers import init_tree
from repro.models.moe import apply_moe, moe_specs


def _cfg(dispatch, e=4, k=2, group=32, cf=1.25):
    return ModelConfig(
        name="t", family="moe", n_layers=1, d_model=16, n_heads=2, n_kv_heads=2,
        d_ff=32, vocab=64, dtype="float32", softmax_impl="gn",
        moe=MoEConfig(num_experts=e, top_k=k, capacity_factor=cf, group_size=group),
        moe_dispatch=dispatch,
    )


def _run(dispatch, key, b=2, s=32, e=4, k=2, cf=1.25):
    cfg = _cfg(dispatch, e=e, k=k, cf=cf)
    params = init_tree(jax.random.PRNGKey(0), moe_specs(cfg))
    x = jax.random.normal(key, (b, s, cfg.d_model))
    return cfg, params, x


@pytest.mark.parametrize("k", [1, 2])
@pytest.mark.parametrize("cf", [0.5, 1.25, 4.0])
def test_matches_einsum(k, cf):
    key = jax.random.PRNGKey(1)
    cfg_g, params, x = _run("gather", key, k=k, cf=cf)
    cfg_e = dataclasses.replace(cfg_g, moe_dispatch="einsum")
    y_g, aux_g = apply_moe(cfg_g, params, x)
    y_e, aux_e = apply_moe(cfg_e, params, x)
    np.testing.assert_allclose(y_g, y_e, rtol=1e-5, atol=1e-5)
    for key_ in ("load_balance", "router_z", "dropped_frac"):
        np.testing.assert_allclose(aux_g[key_], aux_e[key_], rtol=1e-5, atol=1e-6)


def test_gradients_match():
    key = jax.random.PRNGKey(2)
    cfg_g, params, x = _run("gather", key)
    cfg_e = dataclasses.replace(cfg_g, moe_dispatch="einsum")

    def loss(cfg):
        def f(params, x):
            y, _ = apply_moe(cfg, params, x)
            return jnp.sum(y**2)

        return f

    gp_g, gx_g = jax.grad(loss(cfg_g), argnums=(0, 1))(params, x)
    gp_e, gx_e = jax.grad(loss(cfg_e), argnums=(0, 1))(params, x)
    np.testing.assert_allclose(gx_g, gx_e, rtol=1e-4, atol=1e-5)
    for kk in gp_g:
        np.testing.assert_allclose(gp_g[kk], gp_e[kk], rtol=1e-4, atol=1e-5,
                                   err_msg=kk)


@settings(max_examples=15, deadline=None)
@given(
    e=st.sampled_from([2, 4, 8]),
    k=st.integers(1, 2),
    cf=st.sampled_from([0.5, 1.0, 2.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_dispatch_equivalence(e, k, cf, seed):
    key = jax.random.PRNGKey(seed)
    cfg_g, params, x = _run("gather", key, e=e, k=min(k, e), cf=cf)
    cfg_e = dataclasses.replace(cfg_g, moe_dispatch="einsum")
    y_g, _ = apply_moe(cfg_g, params, x)
    y_e, _ = apply_moe(cfg_e, params, x)
    np.testing.assert_allclose(y_g, y_e, rtol=2e-5, atol=2e-5)
