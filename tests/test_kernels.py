"""Per-kernel allclose tests: Pallas (interpret=True) vs pure-jnp oracle.

Sweeps shapes (odd/padded/lane-aligned) and dtypes per kernel, plus the
normalization-guarantee invariants on the kernel outputs themselves.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.luts import SoftmaxLUTConfig
from repro.kernels.gn_attention.ops import gn_attention
from repro.kernels.gn_attention.ref import gn_attention_ref
from repro.kernels.gn_layernorm.ops import gn_layernorm, gn_rmsnorm
from repro.kernels.gn_layernorm.ref import gn_layernorm_ref
from repro.kernels.gn_softmax.ops import gn_softmax
from repro.kernels.gn_softmax.ref import gn_softmax_ref

KEY = jax.random.PRNGKey(7)


def _rand(shape, dtype=jnp.float32, scale=3.0, key=KEY):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


SOFTMAX_SHAPES = [
    (8, 128),          # exactly one tile
    (4, 7, 300),       # ragged cols, 3-D
    (1, 1000),         # single row
    (257, 64),         # ragged rows, narrow cols
    (2, 3, 5, 130),    # 4-D, barely off-lane
]


class TestGNSoftmaxKernel:
    @pytest.mark.parametrize("shape", SOFTMAX_SHAPES)
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_allclose_vs_ref(self, shape, dtype):
        x = _rand(shape, dtype)
        got = gn_softmax(x, interpret=True)
        want = gn_softmax_ref(x)
        tol = 1e-6 if dtype == jnp.float32 else 1e-2
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol
        )

    @pytest.mark.parametrize(
        "cfg",
        [
            SoftmaxLUTConfig(frac_bits=0),
            SoftmaxLUTConfig(frac_bits=3),
            SoftmaxLUTConfig(frac_bits=4, delta_scale=0.5),
        ],
    )
    def test_cfg_sweep(self, cfg):
        x = _rand((16, 200))
        got = gn_softmax(x, cfg=cfg, interpret=True)
        want = gn_softmax_ref(x, cfg=cfg)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)

    def test_normalization_invariant(self):
        x = _rand((64, 333), scale=8.0)
        p = gn_softmax(x, interpret=True)
        np.testing.assert_allclose(np.asarray(p).sum(-1), 1.0, atol=2e-6)

    def test_block_rows_sweep(self):
        x = _rand((64, 256))
        want = gn_softmax_ref(x)
        for br in (8, 16, 64):
            got = gn_softmax(x, block_rows=br, interpret=True)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)


LN_SHAPES = [(8, 128), (5, 300), (2, 3, 640), (100, 64)]


class TestGNLayerNormKernel:
    @pytest.mark.parametrize("shape", LN_SHAPES)
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_allclose_vs_ref(self, shape, dtype):
        x = _rand(shape, dtype)
        g = _rand(shape[-1:], key=jax.random.PRNGKey(1), scale=1.0)
        b = _rand(shape[-1:], key=jax.random.PRNGKey(2), scale=0.5)
        got = gn_layernorm(x, g, b, interpret=True)
        want = gn_layernorm_ref(x, g, b)
        tol = 2e-5 if dtype == jnp.float32 else 5e-2
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol
        )

    def test_rms_variant(self):
        x = _rand((16, 256))
        g = jnp.ones((256,))
        got = gn_rmsnorm(x, g, interpret=True)
        want = gn_layernorm_ref(x, g, None, subtract_mean=False)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)

    def test_sigma_invariant(self):
        x = _rand((32, 512), scale=11.0)
        y = gn_layernorm(x, interpret=True)
        np.testing.assert_allclose(np.asarray(y).std(-1), 1.0, atol=1e-4)


ATTN_SHAPES = [
    # (B, H, Hkv, Sq, Sk, D)
    (1, 2, 2, 128, 128, 64),     # MHA, exact tiles
    (2, 4, 2, 200, 200, 64),     # GQA 2:1, ragged seq
    (1, 8, 1, 64, 256, 32),      # MQA, kv longer (prefix decode pattern)
    (1, 2, 2, 100, 100, 80),     # ragged head dim
]


class TestGNAttentionKernel:
    @pytest.mark.parametrize("shape", ATTN_SHAPES)
    @pytest.mark.parametrize("causal", [False, True])
    def test_allclose_vs_ref(self, shape, causal):
        b, h, hkv, sq, sk, d = shape
        q = _rand((b, h, sq, d), scale=0.5)
        k = _rand((b, hkv, sk, d), scale=0.5, key=jax.random.PRNGKey(1))
        v = _rand((b, hkv, sk, d), scale=1.0, key=jax.random.PRNGKey(2))
        got = gn_attention(q, k, v, causal=causal, interpret=True)
        kk = jnp.repeat(k, h // hkv, axis=1)
        vv = jnp.repeat(v, h // hkv, axis=1)
        want = gn_attention_ref(q, kk, vv, causal=causal)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=5e-5)

    def test_block_sweep(self):
        q = _rand((1, 2, 256, 64), scale=0.5)
        k = _rand((1, 2, 256, 64), scale=0.5, key=jax.random.PRNGKey(1))
        v = _rand((1, 2, 256, 64), key=jax.random.PRNGKey(2))
        want = gn_attention_ref(q, k, v, causal=True)
        for bq, bk in [(64, 64), (128, 256), (256, 128)]:
            got = gn_attention(
                q, k, v, causal=True, block_q=bq, block_k=bk, interpret=True
            )
            np.testing.assert_allclose(
                np.asarray(got), np.asarray(want), atol=5e-5,
                err_msg=f"block_q={bq} block_k={bk}",
            )

    def test_bf16(self):
        q = _rand((1, 2, 128, 64), jnp.bfloat16, scale=0.5)
        k = _rand((1, 2, 128, 64), jnp.bfloat16, scale=0.5, key=jax.random.PRNGKey(1))
        v = _rand((1, 2, 128, 64), jnp.bfloat16, key=jax.random.PRNGKey(2))
        got = gn_attention(q, k, v, interpret=True)
        want = gn_attention_ref(q, k, v)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32), atol=3e-2
        )

    def test_attention_rows_normalized(self):
        """Σp = 1 survives tiling: feed v = identity columns to read p back."""
        sk = 256
        q = _rand((1, 1, 128, 64), scale=0.5)
        k = _rand((1, 1, sk, 64), scale=0.5, key=jax.random.PRNGKey(1))
        v = jnp.ones((1, 1, sk, 1)) * jnp.eye(sk, 1)  # e1 basis probe
        v = jnp.ones((1, 1, sk, 64))  # sum of p equals output of all-ones v
        out = gn_attention(q, k, v, interpret=True)
        np.testing.assert_allclose(np.asarray(out), 1.0, atol=1e-5)
