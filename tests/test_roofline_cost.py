"""Calibration of the trip-count-aware HLO cost model (roofline/hlo_cost.py).

Oracle: XLA's own ``cost_analysis()`` on *loop-free* programs.  The whole
reason hlo_cost exists is that cost_analysis counts while bodies once; these
tests pin (a) agreement on unrolled programs, (b) trip-count scaling on
scanned programs against the unrolled oracle, (c) collective scaling.
"""
import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    pass  # tests run single-device; the sharded test builds its own tiny mesh

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.hlo_cost import module_cost, parse_hlo_computations


def _xla_cost(compiled):
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    return float(ca.get("flops", 0.0)), float(ca.get("bytes accessed", 0.0))


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


class TestLoopFree:
    def test_matmul_chain_flops_match_xla(self):
        def f(x):
            for _ in range(4):
                x = x @ x
            return x

        c = _compile(f, jax.ShapeDtypeStruct((256, 256), jnp.float32))
        xla_flops, _ = _xla_cost(c)
        mine = module_cost(c.as_text())
        # dots dominate; elementwise bookkeeping differs by <2%
        assert mine.flops == pytest.approx(xla_flops, rel=0.02)

    def test_matmul_exact_dot_flops(self):
        def f(a, b):
            return a @ b

        c = _compile(
            f,
            jax.ShapeDtypeStruct((128, 512), jnp.float32),
            jax.ShapeDtypeStruct((512, 64), jnp.float32),
        )
        mine = module_cost(c.as_text())
        assert mine.flops == pytest.approx(2 * 128 * 512 * 64, rel=0.01)

    def test_batched_dot_flops(self):
        def f(a, b):
            return jnp.einsum("bij,bjk->bik", a, b)

        c = _compile(
            f,
            jax.ShapeDtypeStruct((8, 64, 128), jnp.float32),
            jax.ShapeDtypeStruct((8, 128, 32), jnp.float32),
        )
        mine = module_cost(c.as_text())
        assert mine.flops == pytest.approx(2 * 8 * 64 * 128 * 32, rel=0.01)

    def test_bytes_same_order_as_xla(self):
        def f(a, b):
            return jnp.tanh(a @ b)

        c = _compile(
            f,
            jax.ShapeDtypeStruct((512, 512), jnp.float32),
            jax.ShapeDtypeStruct((512, 512), jnp.float32),
        )
        xla_flops, xla_bytes = _xla_cost(c)
        mine = module_cost(c.as_text())
        assert 0.5 * xla_bytes <= mine.bytes <= 2.0 * xla_bytes


class TestTripCountScaling:
    def test_scan_matches_unrolled_oracle(self):
        L = 8

        def body(x, _):
            return jnp.tanh(x @ x), None

        def f_scan(x):
            y, _ = jax.lax.scan(body, x, None, length=L)
            return y

        def f_unroll(x):
            for _ in range(L):
                x = jnp.tanh(x @ x)
            return x

        s = jax.ShapeDtypeStruct((256, 256), jnp.float32)
        c_scan = _compile(f_scan, s)
        c_unroll = _compile(f_unroll, s)

        oracle_flops, _ = _xla_cost(c_unroll)
        naive_flops, _ = _xla_cost(c_scan)
        mine = module_cost(c_scan.as_text())

        # the bug we're fixing: XLA counts the body once
        assert naive_flops < oracle_flops / (L - 1)
        # our model recovers the unrolled total
        assert mine.flops == pytest.approx(oracle_flops, rel=0.05)

    def test_nested_scan(self):
        def inner(x, _):
            return x @ x, None

        def outer(x, _):
            y, _ = jax.lax.scan(inner, x, None, length=3)
            return y, None

        def f(x):
            y, _ = jax.lax.scan(outer, x, None, length=5)
            return y

        c = _compile(f, jax.ShapeDtypeStruct((128, 128), jnp.float32))
        mine = module_cost(c.as_text())
        expect = 15 * 2 * 128**3  # 5 x 3 matmuls
        assert mine.flops == pytest.approx(expect, rel=0.05)

    def test_trip_count_parsed(self):
        def body(x, _):
            return x + 1.0, None

        def f(x):
            y, _ = jax.lax.scan(body, x, None, length=17)
            return y

        c = _compile(f, jax.ShapeDtypeStruct((64,), jnp.float32))
        comps, entry = parse_hlo_computations(c.as_text())
        assert entry
        trips = [
            i.trip_count()
            for comp in comps.values()
            for i in comp
            if i.opcode == "while"
        ]
        assert 17 in trips


class TestTpuNativeAdjustment:
    def test_bf16_dot_costed_native(self):
        """XLA:CPU legalizes bf16 dots via f32 converts; tpu_native accounting
        must price the dot at bf16 operand/output sizes and the convert
        fusions at zero."""

        def f(a, b):
            return a @ b

        s = jax.ShapeDtypeStruct((256, 256), jnp.bfloat16)
        c = _compile(f, s, s)
        native = module_cost(c.as_text())
        # 3 buffers x 256^2 x 2B (a, b, out) = 393216
        assert native.bytes == pytest.approx(3 * 256 * 256 * 2, rel=0.05)

        from repro.roofline.hlo_cost import HloCostModel

        raw = HloCostModel(c.as_text(), tpu_native=False).module_cost()
        assert raw.bytes > 2.5 * native.bytes  # the artifact being removed

    def test_f32_traffic_untouched(self):
        def f(a, b):
            return a @ b

        s = jax.ShapeDtypeStruct((256, 256), jnp.float32)
        c = _compile(f, s, s)
        native = module_cost(c.as_text())
        assert native.bytes == pytest.approx(3 * 256 * 256 * 4, rel=0.05)


class TestCollectives:
    @pytest.fixture(scope="class")
    def mesh8(self):
        if jax.device_count() < 8:
            pytest.skip("needs 8 host devices (run under dryrun env)")
        return jax.make_mesh((8,), ("d",))

    def test_psum_bytes_counted(self):
        # single-device fallback: parse a synthetic HLO line instead
        hlo = """
HloModule test

ENTRY %main (p0: f32[1024]) -> f32[1024] {
  %p0 = f32[1024]{0} parameter(0)
  ROOT %ar = f32[1024]{0} all-reduce(%p0), channel_id=1, replica_groups={{0,1,2,3}}, to_apply=%add
}
"""
        c = module_cost(hlo)
        assert c.collective["all-reduce"] == pytest.approx(2 * 1024 * 4)

    def test_collective_in_loop_scaled(self):
        hlo = """
HloModule test

%body (p: (s32[], f32[256])) -> (s32[], f32[256]) {
  %p = (s32[], f32[256]{0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[256]{0} get-tuple-element(%p), index=1
  %ag = f32[1024]{0} all-gather(%x), channel_id=1, replica_groups={{0,1,2,3}}, dimensions={0}
  %y = f32[256]{0} slice(%ag), slice={[0:256]}
  %c1 = s32[] constant(1)
  %ni = s32[] add(%i, %c1)
  ROOT %t = (s32[], f32[256]{0}) tuple(%ni, %y)
}

%cond (p: (s32[], f32[256])) -> pred[] {
  %p = (s32[], f32[256]{0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %lim = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %lim), direction=LT
}

ENTRY %main (p0: f32[256]) -> f32[256] {
  %p0 = f32[256]{0} parameter(0)
  %c0 = s32[] constant(0)
  %t = (s32[], f32[256]{0}) tuple(%c0, %p0)
  %w = (s32[], f32[256]{0}) while(%t), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
  ROOT %out = f32[256]{0} get-tuple-element(%w), index=1
}
"""
        c = module_cost(hlo)
        # all-gather output is 1024 f32 = 4096 B, x10 iterations
        assert c.collective["all-gather"] == pytest.approx(10 * 4096)
        assert not c.warnings


class TestEndToEndModel:
    def test_smoke_model_flops_sane(self):
        """A reduced dense model's HLO flops >= analytic 2*N*D (fwd)."""
        from repro.configs.registry import get_config, reduce_config
        from repro.models.transformer import make_model

        cfg = reduce_config(get_config("internlm2-1.8b"))
        model = make_model(cfg)
        b, s = 2, 32
        tokens = jax.ShapeDtypeStruct((b, s), jnp.int32)
        params = model.param_structs()

        def fwd(p, t):
            logits, _ = model.forward(p, {"tokens": t})
            return logits

        c = jax.jit(fwd).lower(params, tokens).compile()
        mine = module_cost(c.as_text())
        analytic = 2.0 * cfg.param_count() * b * s
        # forward flops should be within [0.5x, 4x] of 2*N*D for a tiny model
        # (embedding gather contributes no flops; attention adds seq^2 terms)
        assert mine.flops > 0.3 * analytic
        assert mine.flops < 6.0 * analytic


class TestInPlaceUpdatePricing:
    def test_donated_cache_update_priced_at_slice(self):
        """A jit-donated buffer updated via dynamic_update_slice must cost
        ~2x the update window, not 2x the buffer (the KV-cache pattern)."""

        def step(cache, new):
            return jax.lax.dynamic_update_slice(cache, new, (5, 0))

        cache = jax.ShapeDtypeStruct((4096, 256), jnp.float32)
        new = jax.ShapeDtypeStruct((1, 256), jnp.float32)
        c = jax.jit(step, donate_argnums=(0,)).lower(cache, new).compile()
        cost = module_cost(c.as_text())
        buffer_bytes = 4096 * 256 * 4
        assert cost.bytes < 0.05 * buffer_bytes  # slice-sized, not buffer-sized
